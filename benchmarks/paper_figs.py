"""Paper-evaluation benchmarks: Figures 2, 3 and 4 of the SKUEUE paper.

Same protocol as the paper's Sec. VII setup: per synchronous round, generate
requests at random nodes; after the generation window, drain; report the
average number of rounds per request.  Default sizes are scaled down for CI
speed (--full approaches the paper's 10^5 nodes / 1000 rounds)."""
from __future__ import annotations

import numpy as np

from repro.core.consistency import check_sequential_consistency
from repro.core.protocol import DEQ, ENQ, Skueue


def _run_instance(n, mode, p_enq, rounds, per_round, seed=0,
                  rate_per_node=None):
    sk = Skueue(n, mode=mode, seed=seed)
    rng = np.random.default_rng(seed + 1)

    def inject(s, rnd):
        if rnd > rounds:
            return
        nids = s.ring.node_ids()
        k = (per_round if rate_per_node is None
             else rng.binomial(len(nids), rate_per_node))
        for _ in range(k):
            s.inject(nids[int(rng.integers(len(nids)))],
                     ENQ if rng.random() < p_enq else DEQ)

    sk.run_rounds(rounds, inject_fn=inject)
    check_sequential_consistency(sk)
    lat = [r.t_done - r.t_issue for r in sk.requests if r.t_done >= 0]
    return float(np.mean(lat)), len(lat)


def fig2_queue(full=False):
    """Avg rounds/request vs n for ENQUEUE ratios p (paper Fig. 2)."""
    ns = [4, 16, 64, 256, 1024] + ([4096] if full else [])
    rounds = 300 if full else 80
    rows = []
    for p in (0.25, 0.5, 0.75):
        for n in ns:
            m, cnt = _run_instance(n, "queue", p, rounds, per_round=10,
                                   seed=n)
            rows.append(("fig2_queue", n, p, m, cnt))
    return rows


def fig3_stack(full=False):
    """Avg rounds/request vs n for PUSH ratios p (paper Fig. 3)."""
    ns = [4, 16, 64, 256] + ([1024] if full else [])
    rounds = 300 if full else 80
    rows = []
    for p in (0.0, 0.5, 0.75):
        for n in ns:
            m, cnt = _run_instance(n, "stack", p, rounds, per_round=10,
                                   seed=n + 7)
            rows.append(("fig3_stack", n, p, m, cnt))
    return rows


def fig4_rate(full=False):
    """Avg rounds/request vs per-node request rate at fixed n (paper Fig. 4:
    the stack IMPROVES with rate thanks to local push/pop combining)."""
    n = 1024 if full else 128
    rounds = 120 if full else 60
    rows = []
    for rate in (0.05, 0.25, 1.0):
        for mode in ("queue", "stack"):
            m, cnt = _run_instance(n, mode, 0.5, rounds, per_round=0,
                                   seed=int(rate * 100),
                                   rate_per_node=rate)
            rows.append((f"fig4_{mode}", n, rate, m, cnt))
    return rows
