"""Regenerate experiments/roofline_table.md from dry-run + costing JSONs."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
from benchmarks.roofline import load_all  # noqa: E402

rows = load_all()
pod1 = sorted([r for r in rows if r["mesh"] == "16x16"
               and r["cell"].endswith("__pod1")],  # baselines only
              key=lambda r: (r["arch"], r["shape"]))
out = ["# Roofline baselines — 16x16 mesh (256 chips), per device per step",
       "",
       "`corr` = loop-corrected via launch.costrun (exact unrolled costing);",
       "uncorrected rows are per-loop-body lower bounds.",
       "",
       "| cell | compute_s | memory_s | collective_s | dominant | useful | "
       "MFU-proxy | peak GiB (tpu) | corr |",
       "|---|---|---|---|---|---|---|---|---|"]
for r in pod1:
    out.append(
        f"| {r['arch']}/{r['shape']} | {r['compute_s']:.3g} | "
        f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | {r['dominant']} | "
        f"{min(r['useful_ratio'], 99):.2f} | {min(r['mfu_proxy'],9):.3f} | "
        f"{r['peak_gib']:.1f} ({r['peak_gib_tpu']:.1f}) | "
        f"{'Y' if r['loop_corrected'] else 'n'} |")
Path(__file__).resolve().parents[1].joinpath(
    "experiments/roofline_table.md").write_text("\n".join(out) + "\n")
print("\n".join(out[6:12]))
print(f"... {len(pod1)} cells; corrected: "
      f"{sum(r['loop_corrected'] for r in pod1)}")
