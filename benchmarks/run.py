# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow)")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args()

    # PR 1 extends the CSV with waves_per_sec and collectives_per_wave
    # columns (populated by the device-queue wave-pipeline rows).
    print("name,us_per_call,derived,waves_per_sec,collectives_per_wave")

    from . import paper_figs
    for fig in (paper_figs.fig2_queue, paper_figs.fig3_stack,
                paper_figs.fig4_rate):
        for name, n, p, mean_rounds, cnt in fig(full=args.full):
            # "us_per_call" column carries the figure's y-value
            print(f"{name}_n{n}_p{p},{mean_rounds:.2f},"
                  f"avg_rounds_per_request({cnt} reqs),,")
            sys.stdout.flush()

    from . import micro
    for row in micro.run_all():
        name, us, derived = row[:3]
        waves_per_sec = f"{row[3]:.1f}" if len(row) > 3 and row[3] != "" else ""
        coll = str(row[4]) if len(row) > 4 and row[4] != "" else ""
        print(f"{name},{us:.1f},{derived},{waves_per_sec},{coll}")
        sys.stdout.flush()

    if not args.skip_roofline:
        from . import roofline
        try:
            for name, dom, derived in roofline.bench_rows():
                print(f"{name},0,{dom} {derived},,")
        except Exception as e:  # dry-run artifacts missing
            print(f"roofline,0,unavailable: {e},,")


if __name__ == '__main__':
    main()
