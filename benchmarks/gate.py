"""The PR 9 perf regression gate: BENCH_PR9.json vs committed floors.

CI runs the compact-waves benchmark (``benchmarks.micro --pr9 --quick``)
and then this gate, which compares the fresh numbers against the
committed ``BENCH_BASELINE.json``:

* **throughput metrics** (waves/sec): fail when a current value drops
  more than ``tolerance_pct`` (default 25%) below its baseline value —
  a compact-wave speed regression breaks the build instead of rotting
  silently in an artifact nobody reads;
* **ratio floors** (compact-vs-full speedups): fail when a current
  ratio falls below its committed floor.  Ratios are machine-portable —
  they compare two timings taken on the same box in the same process —
  so their floors are absolute, not tolerance-banded.

The baseline is refreshed from a real run, never hand-edited::

    PYTHONPATH=src python -m benchmarks.micro --pr9 --quick
    PYTHONPATH=src python -m benchmarks.gate BENCH_PR9.json --update

Absolute waves/sec floors are tied to the machine class that produced
them (see docs/PERFORMANCE.md); ``--update`` re-records them while
keeping the ratio floors pinned at the acceptance threshold.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_BASELINE = os.path.join(_REPO_ROOT, "BENCH_BASELINE.json")

# dotted paths into BENCH_PR9.json whose waves/sec values are tracked
# against the committed baseline (higher is better)
TRACKED_THROUGHPUT = tuple(
    f"occupancy.disciplines.{d}.{occ}.{flavor}.waves_per_sec"
    for d in ("queue", "priority")
    for occ in ("5%", "25%", "100%")
    for flavor in ("compact", "full"))

# machine-portable ratio floors: compact must stay >= 1.3x at low
# occupancy (the PR 9 acceptance bar) and must never cost > 10% at full
RATIO_FLOORS = {
    **{f"occupancy.disciplines.{d}.{occ}.speedup_waves_per_sec": 1.3
       for d in ("queue", "priority") for occ in ("5%", "25%")},
    **{f"occupancy.disciplines.{d}.100%.speedup_waves_per_sec": 0.9
       for d in ("queue", "priority")},
}


def _lookup(doc: dict, path: str):
    cur = doc
    for part in path.split("."):
        cur = cur[part]
    return cur


def build_baseline(bench: dict, tolerance_pct: float = 25.0) -> dict:
    """Record the tracked throughput values of a fresh run as the new
    baseline, keeping the ratio floors pinned at the acceptance bar."""
    return {
        "tolerance_pct": tolerance_pct,
        "throughput": {p: _lookup(bench, p) for p in TRACKED_THROUGHPUT},
        "ratio_floors": dict(RATIO_FLOORS),
    }


def check(bench: dict, baseline: dict) -> list:
    """Return a list of human-readable failures (empty == gate passes)."""
    failures = []
    tol = float(baseline.get("tolerance_pct", 25.0)) / 100.0
    for path, base in baseline.get("throughput", {}).items():
        try:
            cur = float(_lookup(bench, path))
        except KeyError:
            failures.append(f"{path}: missing from the benchmark output")
            continue
        floor = float(base) * (1.0 - tol)
        if cur < floor:
            failures.append(
                f"{path}: {cur:.1f} waves/s is {100 * (1 - cur / base):.1f}%"
                f" below baseline {float(base):.1f} (floor {floor:.1f})")
    for path, floor in baseline.get("ratio_floors", {}).items():
        try:
            cur = float(_lookup(bench, path))
        except KeyError:
            failures.append(f"{path}: missing from the benchmark output")
            continue
        if cur < float(floor):
            failures.append(f"{path}: {cur:.2f}x below the committed "
                            f"floor {float(floor):.2f}x")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("bench", nargs="?", default="BENCH_PR9.json",
                    help="benchmark JSON to gate (default BENCH_PR9.json)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="committed baseline JSON (BENCH_BASELINE.json)")
    ap.add_argument("--update", action="store_true",
                    help="re-record the baseline from this run instead of "
                         "gating against it")
    cli = ap.parse_args(argv)
    bench_path = cli.bench if os.path.isabs(cli.bench) \
        else os.path.join(_REPO_ROOT, cli.bench)
    with open(bench_path) as f:
        bench = json.load(f)
    if cli.update:
        base = build_baseline(bench)
        with open(cli.baseline, "w") as f:
            json.dump(base, f, indent=2)
            f.write("\n")
        print(f"gate: baseline refreshed -> {cli.baseline} "
              f"({len(base['throughput'])} throughput metrics, "
              f"{len(base['ratio_floors'])} ratio floors)")
        return 0
    with open(cli.baseline) as f:
        baseline = json.load(f)
    failures = check(bench, baseline)
    n = len(baseline.get("throughput", {})) + len(
        baseline.get("ratio_floors", {}))
    if failures:
        print(f"gate: FAIL — {len(failures)}/{n} tracked metrics regressed")
        for msg in failures:
            print(f"  {msg}")
        return 1
    print(f"gate: PASS — {n} tracked metrics within "
          f"{baseline.get('tolerance_pct', 25)}% of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
