"""Framework microbenchmarks: scan-queue ops, device-queue steps, kernels."""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp


def _time_us(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def bench_scan_queue():
    from repro.core.scan_queue import QueueState, queue_scan
    rows = []
    for n in (1024, 16384, 262144):
        rng = np.random.default_rng(0)
        e = jnp.array(rng.random(n) < 0.6)
        v = jnp.ones((n,), bool)
        st = QueueState.empty()
        f = jax.jit(lambda a, b: queue_scan(a, QueueState.empty(), valid=b))
        us = _time_us(f, e, v)
        rows.append((f"scan_queue_n{n}", us, f"{n/us:.1f} ops/us"))
    return rows


def bench_segscan_kernel():
    from repro.kernels.segscan import queue_scan_pallas
    rows = []
    n = 4096
    rng = np.random.default_rng(1)
    e = jnp.array(rng.random(n) < 0.5)
    v = jnp.ones((n,), bool)
    us = _time_us(lambda a, b: queue_scan_pallas(a, b, jnp.int32(0),
                                                 jnp.int32(-1)), e, v,
                  iters=5)
    rows.append((f"segscan_pallas_interp_n{n}", us,
                 "interpret-mode (correctness path)"))
    return rows


def bench_device_queue():
    from repro.dqueue import DeviceQueue
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(n_data=len(jax.devices()))
    dq = DeviceQueue(mesh, "data", cap=1024, payload_width=4,
                     ops_per_shard=256)
    state = dq.init_state()
    n = dq.n_shards * dq.L
    rng = np.random.default_rng(2)
    is_enq = jnp.array(rng.random(n) < 0.6)
    valid = jnp.ones((n,), bool)
    payload = jnp.array(rng.integers(0, 100, (n, 4)), jnp.int32)

    def step(s):
        out = dq.step(s, is_enq, valid, payload)
        return out[0]

    us = _time_us(step, state, iters=10)
    return [(f"device_queue_step_{n}ops", us, f"{n/us:.2f} ops/us")]


def bench_attention():
    from repro.kernels.flash_attention import attention_ref
    rows = []
    rng = np.random.default_rng(3)
    B, H, L, D = 1, 8, 1024, 64
    q = jnp.array(rng.standard_normal((B * H, L, D)), jnp.bfloat16)
    f = jax.jit(lambda q: attention_ref(q, q, q))
    us = _time_us(f, q, iters=5)
    flops = 4 * B * H * L * L * D
    rows.append((f"attention_ref_L{L}", us, f"{flops/us/1e3:.1f} GF/s"))
    return rows


def run_all():
    rows = []
    for fn in (bench_scan_queue, bench_segscan_kernel, bench_device_queue,
               bench_attention):
        rows += fn()
    return rows
