"""Framework microbenchmarks: scan-queue ops, device-queue steps, kernels.

PR 1 adds the wave-pipeline benchmark: the seed single-wave dispatch
discipline (one jitted step per wave, host round-trip between waves,
five all_to_all collectives per wave) vs. the fused path (two collectives
per wave, donated state, K waves inside one lax.scan dispatch).  Results
are written to ``BENCH_PR1.json``; run directly with

    PYTHONPATH=src python -m benchmarks.micro --pr1 [path]

PR 2 adds the elastic-membership benchmark: steady-state waves/sec through
the ``ElasticDeviceQueue`` wrapper vs. the raw PR 1 fused path (acceptance:
within 10%), and the reshard cost of live grow/shrink migrations —
elements moved, bytes, collectives per migration (one packed all_to_all),
and wall time split into the jitted wave vs. the total including the
host-staged mesh crossing.  Results go to ``BENCH_PR2.json``:

    PYTHONPATH=src python -m benchmarks.micro --pr2 [path] [--quick]

PR 3 adds the priority-tier mixed-load benchmark: interactive + batch
traffic at identical arrival schedules and identical service capacity
through (a) the single-tier FIFO ``DeviceQueue`` and (b) the two-tier
``DevicePriorityQueue`` — per-class wait distributions (p50/p99 in waves)
show the tail-latency separation the priority fabric buys, plus the
steady-state wave overhead of the priority path and its collective count.
Results go to ``BENCH_PR3.json``:

    PYTHONPATH=src python -m benchmarks.micro --pr3 [path] [--quick]

PR 4 adds two measurements.  (a) The wave-pipelining benchmark: K-wave
bursts through the unified WaveEngine with the sequential schedule
(``pipelined=False``: request + reply all_to_all per wave, one wave at a
time) vs. the software-pipelined schedule (``pipelined=True``: wave k's
dispatch overlaps wave k-1's store rewrite and the two collectives fuse
into ONE all_to_all per wave) — waves/sec and static collective counts
for all three disciplines.  Results go to ``BENCH_PR4.json``:

    PYTHONPATH=src python -m benchmarks.micro --pr4 [path] [--quick]

(b) The ROADMAP relaxation study, folded into ``BENCH_PR3.json``: a
``relaxation=k`` sweep (k in {0, 1, 2}) under tier-skewed traffic with
per-shard dequeues, reporting the local-serve fraction (serves that avoid
the cross-shard hop) against the tier skew it costs.

PR 5 adds the deadline-scheduling benchmark: bursty traffic whose
per-request slack is continuous AND drifts mid-run, at the SAME arrival
schedule and SAME per-wave service capacity through (a) the single-tier
FIFO queue, (b) the two-tier priority queue with the best static cut
(the trace median — which each phase of a drifting distribution lands
almost entirely on one side of, degenerating to FIFO) and (c) the Seap
arbitrary-key queue with key = deadline wave — earliest-deadline-first
at bucket granularity, the directory rolling with the drift.
Deadline-miss rates and lateness per urgency band show what EDF buys
over both.  Results go to ``BENCH_PR5.json``:

    PYTHONPATH=src python -m benchmarks.micro --pr5 [path] [--quick]

PR 7 adds the Wavescope telemetry-cost benchmark: the SAME pipelined
K-wave burst with ``metrics=False`` vs ``metrics=True``, timed with the
two flavors interleaved inside one best-of loop (machine drift cancels),
plus the static all_to_all count of both lowered programs (must match:
telemetry adds ZERO collectives) and the burst-boundary drain cost timed
separately.  Results go to ``BENCH_PR7.json``:

    PYTHONPATH=src python -m benchmarks.micro --pr7 [path] [--quick]

PR 8 adds the backpressure benchmark: the SAME bursty arrival schedule
(10x bursts over a steady near-capacity base rate) through a ServeEngine
with (a) no admission policy — staging is unconditional and the burst
overflows the queue mid-wave, (b) shed, (c) defer, (d) degrade, and
(e) shed plus the hysteresis autoscale controller.  The baseline must
overflow; every policy must sustain ZERO QueueOverflowError, trading it
for structured sheds/spills — goodput, shed rate, resize count, and p99
admission-decision latency per flavor.  Results go to
``BENCH_PR8.json``:

    PYTHONPATH=src python -m benchmarks.micro --pr8 [path] [--quick]

PR 9 adds the compact-waves benchmark: the SAME logical op stream at 5% /
25% / 100% of the full wave envelope, staged (a) compact — each wave at
the smallest bucket-ladder width {L/4, L/2, L} that fits its live ops —
vs (b) padded to the full ``n_shards * L`` envelope, for the FIFO and
priority disciplines.  Bit-identical per-op outputs and final device
state are ASSERTED inside the emitter (compaction must not change one
answer), as is the headline >= 1.3x waves/sec at <= 25% occupancy.  A
second section times the segscan dispatch modes: the jnp core scan (the
compiled-XLA oracle / CPU hot path) vs the pallas kernel in interpret
mode, plus compiled pallas on TPU/GPU.  Results go to
``BENCH_PR9.json``, and ``benchmarks.gate`` compares them against the
committed ``BENCH_BASELINE.json`` floors (CI fails a >25% regression):

    PYTHONPATH=src python -m benchmarks.micro --pr9 [path] [--quick]
    PYTHONPATH=src python -m benchmarks.gate BENCH_PR9.json

**PR 10 (the runtime seam).**  ``BENCH_PR10.json`` prices the pluggable
mesh-runtime layer: a LocalRuntime parity section (the runtime-built
wave path asserted BIT-identical to the bare-mesh PR 9 path), a
SimRuntime latency sweep (steady-state waves/sec and the migration-wave
cost under modeled per-collective costs of 0 us / 100 us / 1 ms), and
the same measurement over a REAL wire — 2 ``jax.distributed`` processes
on localhost TCP via ``repro.runtime.launch_localhost``:

    PYTHONPATH=src python -m benchmarks.micro --pr10 [path] [--quick]

``--all [--quick]`` runs EVERY emitter above (the CI bench-smoke entry
point: one invocation emits every BENCH_PR*.json, and any emitter crash
fails the run — future PRs add an emitter here instead of editing the
workflow).  PR numbers with no benchmark (PR 6, the static analyzer)
are listed in ``_NO_BENCH`` and reported with an explicit skip line
instead of a silent hole in the artifact.  Each emitter re-execs itself
on a forced 8-device CPU mesh when needed.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _time_us(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def bench_scan_queue():
    from repro.core.scan_queue import QueueState, queue_scan
    rows = []
    for n in (1024, 16384, 262144):
        rng = np.random.default_rng(0)
        e = jnp.array(rng.random(n) < 0.6)
        v = jnp.ones((n,), bool)
        f = jax.jit(lambda a, b: queue_scan(a, QueueState.empty(), valid=b))
        us = _time_us(f, e, v)
        rows.append((f"scan_queue_n{n}", us, f"{n/us:.1f} ops/us"))
    return rows


def bench_segscan_kernel():
    from repro.kernels.segscan import queue_scan_pallas
    rows = []
    n = 4096
    rng = np.random.default_rng(1)
    e = jnp.array(rng.random(n) < 0.5)
    v = jnp.ones((n,), bool)
    us = _time_us(lambda a, b: queue_scan_pallas(a, b, jnp.int32(0),
                                                 jnp.int32(-1)), e, v,
                  iters=5)
    rows.append((f"segscan_pallas_interp_n{n}", us,
                 "interpret-mode (correctness path)"))
    return rows


def bench_device_queue():
    from repro.dqueue import DeviceQueue
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(n_data=len(jax.devices()))
    dq = DeviceQueue(mesh, "data", cap=1024, payload_width=4,
                     ops_per_shard=256)
    n = dq.n_shards * dq.L
    rng = np.random.default_rng(2)
    is_enq = jnp.array(rng.random(n) < 0.6)
    valid = jnp.ones((n,), bool)
    payload = jnp.array(rng.integers(0, 100, (n, 4)), jnp.int32)

    # the step donates its state argument, so thread it through the loop
    state = dq.init_state()
    for _ in range(3):  # warmup
        state = dq.step(state, is_enq, valid, payload)[0]
    jax.block_until_ready(state.store_full)
    iters = 10
    t0 = time.perf_counter()
    for _ in range(iters):
        state = dq.step(state, is_enq, valid, payload)[0]
    jax.block_until_ready(state.store_full)
    us = (time.perf_counter() - t0) / iters * 1e6
    return [(f"device_queue_step_{n}ops", us, f"{n/us:.2f} ops/us")]


# ------------------------------------------------- PR 1: wave pipeline -----
def count_all_to_all(jitted, args) -> int:
    """Number of all-to-all collectives in the compiled HLO of ``jitted``."""
    import re
    txt = jitted.lower(*args).compile().as_text()
    return len(re.findall(r"all-to-all(?:-start)?\(", txt))


def _measure_wave_pipeline(n_dev: int, K: int, ops_per_shard: int = 64,
                           iters: int = 10) -> dict:
    from repro.compat import make_mesh
    from repro.dqueue import DeviceQueue
    mesh = make_mesh((n_dev,), ("data",))
    kwargs = dict(cap=max(256, K * ops_per_shard // n_dev + 1),
                  payload_width=4, ops_per_shard=ops_per_shard)
    legacy = DeviceQueue(mesh, "data", fused=False, **kwargs)
    fused = DeviceQueue(mesh, "data", **kwargs)
    n = n_dev * ops_per_shard
    rng = np.random.default_rng(5)
    E = jnp.array(rng.random((K, n)) < 0.5)
    V = jnp.ones((K, n), bool)
    PW = jnp.array(rng.integers(0, 100, (K, n, 4)), jnp.int32)
    # pre-split per-wave inputs so slicing is not charged to the seed path
    Es = [E[k] for k in range(K)]
    Vs = [V[k] for k in range(K)]
    Ps = [PW[k] for k in range(K)]

    def run_single_wave_loop():
        # the seed dispatch discipline: one jitted call per wave with a host
        # round-trip (bool(overflow)) between waves, exactly what the seed
        # ServeEngine/WorkQueue did.
        state = legacy.init_state()
        for k in range(K):
            state, pos, m, dv, dok, ovf = legacy.step(
                state, Es[k], Vs[k], Ps[k])
            assert not bool(ovf)
        jax.block_until_ready(state.store_full)

    def run_fused_multi_wave():
        state = fused.init_state()
        out = fused.run_waves(state, E, V, PW)
        jax.block_until_ready(out[0].store_full)

    def best_time(fn):
        fn()  # warmup / compile
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    t_single = best_time(run_single_wave_loop)
    t_fused = best_time(run_fused_multi_wave)

    step_args = (legacy.init_state(), E[0], V[0], PW[0])
    coll_legacy = count_all_to_all(legacy._step, step_args)
    step_args = (fused.init_state(), E[0], V[0], PW[0])
    coll_fused = count_all_to_all(fused._step, step_args)
    return {
        "n_dev": n_dev, "K": K, "ops_per_wave": n,
        "seed_single_wave": {
            "waves_per_sec": K / t_single,
            "us_per_wave": t_single / K * 1e6,
            "collectives_per_wave": coll_legacy,
        },
        "fused_multi_wave": {
            "waves_per_sec": K / t_fused,
            "us_per_wave": t_fused / K * 1e6,
            "collectives_per_wave": coll_fused,
        },
        "speedup_waves_per_sec": t_single / t_fused,
    }


def _reexec_on_mesh(tag: str, path: str, n_dev: int, child_args: list):
    """Re-run ``benchmarks.micro`` in a subprocess on a forced ``n_dev``
    CPU mesh and return its JSON, or None if this process already has the
    right mesh (or IS the child).  Drops any pre-existing device-count flag
    (last one wins in XLA flag parsing) and marks the child so it never
    re-execs itself."""
    in_child = os.environ.get(f"_REPRO_BENCH_{tag}_CHILD") == "1"
    if in_child or (len(jax.devices()) == n_dev
                    and jax.default_backend() == "cpu"):
        return None
    env = dict(os.environ)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if not f.startswith("--xla_force_host_platform_device_count")]
    flags.append(f"--xla_force_host_platform_device_count={n_dev}")
    env["XLA_FLAGS"] = " ".join(flags)
    env["JAX_PLATFORMS"] = "cpu"
    env[f"_REPRO_BENCH_{tag}_CHILD"] = "1"
    env["PYTHONPATH"] = (os.path.join(_REPO_ROOT, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    subprocess.run([sys.executable, "-m", "benchmarks.micro"] + child_args,
                   cwd=_REPO_ROOT, env=env, check=True)
    with open(path) as f:
        return json.load(f)


def emit_bench_pr1(path: str = "BENCH_PR1.json", n_dev: int = 8,
                   K: int = 32, quick: bool = False) -> dict:
    """Measure the wave pipeline on an ``n_dev`` CPU mesh and write JSON."""
    if not os.path.isabs(path):
        path = os.path.join(_REPO_ROOT, path)
    if quick:
        K = min(K, 8)
    child = _reexec_on_mesh("PR1", path, n_dev,
                            ["--pr1", path, "--n-dev", str(n_dev),
                             "--waves", str(K)]
                            + (["--quick"] if quick else []))
    if child is not None:
        return child
    data = _measure_wave_pipeline(n_dev=n_dev, K=K,
                                  iters=3 if quick else 10)
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
    return data


# ----------------------------------------- PR 2: elastic membership --------
def _measure_elastic(n_dev: int, K: int, ops_per_shard: int = 64,
                     iters: int = 10, quick: bool = False) -> dict:
    from repro.compat import make_mesh
    from repro.dqueue import DeviceQueue, ElasticDeviceQueue
    if quick:
        K, iters = min(K, 8), 3
    cap = max(256, K * ops_per_shard // n_dev + 1)
    kwargs = dict(cap=cap, payload_width=4, ops_per_shard=ops_per_shard)
    n = n_dev * ops_per_shard
    rng = np.random.default_rng(5)
    E = jnp.array(rng.random((K, n)) < 0.5)
    V = jnp.ones((K, n), bool)
    PW = jnp.array(rng.integers(0, 100, (K, n, 4)), jnp.int32)

    # ---- steady state: raw fused path vs. the elastic wrapper ----
    mesh = make_mesh((n_dev,), ("data",))
    dq = DeviceQueue(mesh, "data", **kwargs)
    eq = ElasticDeviceQueue(n_dev, hlo_stats=True, **kwargs)

    def run_fused():
        state = dq.init_state()
        out = dq.run_waves(state, E, V, PW)
        jax.block_until_ready(out[0].store_full)

    def run_elastic():
        eq.state = eq.inner.init_state()  # fresh state (donated each burst)
        eq.run_waves(E, V, PW)
        jax.block_until_ready(eq.state.store_full)

    def best_time(fn):
        fn()  # warmup / compile
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    t_fused = best_time(run_fused)
    t_elastic = best_time(run_elastic)

    # ---- reshard cost: grow/shrink cycles with a loaded queue ----
    P_lo = max(1, n_dev // 2)
    eq2 = ElasticDeviceQueue(P_lo, hlo_stats=True, **kwargs)
    fill = min(P_lo * cap, 256 if quick else 2048)
    done = 0
    while done < fill:
        w = eq2.n_shards * eq2.L
        k = min(w, fill - done)
        e = np.zeros(w, bool)
        e[:k] = True
        pw = np.zeros((w, 4), np.int32)
        pw[:k, 0] = np.arange(done, done + k)
        eq2.step(e, e, pw)
        done += k
    eq2.resize(n_dev)  # warm both migration programs (compile outside timing)
    eq2.resize(P_lo)
    eq2.migrations.clear()
    for _ in range(2 if quick else 5):
        eq2.resize(n_dev)
        eq2.resize(P_lo)

    def summarize(kind):
        ms = [m for m in eq2.migrations if m["kind"] == kind]
        return {
            "migrations": len(ms),
            "moved_per_migration": ms[0]["moved"],
            "bytes_per_migration": ms[0]["bytes_moved"],
            "collectives_per_migration": ms[0]["collectives"],
            "wave_ms_best": min(m["wave_s"] for m in ms) * 1e3,
            "wave_ms_mean": sum(m["wave_s"] for m in ms) / len(ms) * 1e3,
            "total_ms_mean": sum(m["total_s"] for m in ms) / len(ms) * 1e3,
        }

    return {
        "n_dev": n_dev, "K": K, "ops_per_wave": n, "live_elements": fill,
        "steady_state": {
            "fused_device_queue_waves_per_sec": K / t_fused,
            "elastic_wrapper_waves_per_sec": K / t_elastic,
            "overhead_pct": (t_elastic - t_fused) / t_fused * 100.0,
        },
        "reshard": {
            f"grow_{P_lo}_to_{n_dev}": summarize("grow"),
            f"shrink_{n_dev}_to_{P_lo}": summarize("shrink"),
        },
        "hash_balance_last": eq2.migrations[-1].get("hash_balance"),
    }


def emit_bench_pr2(path: str = "BENCH_PR2.json", n_dev: int = 8,
                   K: int = 32, quick: bool = False) -> dict:
    """Measure elastic steady-state + reshard cost and write JSON
    (re-execs on a forced ``n_dev``-device CPU mesh when needed)."""
    if not os.path.isabs(path):
        path = os.path.join(_REPO_ROOT, path)
    child = _reexec_on_mesh(
        "PR2", path, n_dev,
        ["--pr2", path, "--n-dev", str(n_dev), "--waves", str(K)]
        + (["--quick"] if quick else []))
    if child is not None:
        return child
    data = _measure_elastic(n_dev=n_dev, K=K, quick=quick)
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
    return data


# -------------------------------- PR 3: priority tiers, mixed load ---------
def _measure_priority_mixed(n_dev: int, quick: bool = False) -> dict:
    """Interactive + batch traffic at the SAME arrival schedule and the
    SAME per-wave service capacity through the single-tier FIFO queue vs.
    the two-tier priority queue.  The total queue size evolves identically
    in both runs (arrivals and dequeue capacity are equal), so throughput
    is equal by construction — the difference is WHO waits: FIFO makes
    interactive requests queue behind every batch burst, the priority wave
    admits them first."""
    from repro.compat import make_mesh
    from repro.dqueue import DeviceQueue, DevicePriorityQueue

    L, W, C = 16, 2, 8                 # wave width / payload / service cap
    waves = 48 if quick else 160
    inter_rate = 2                     # interactive arrivals per wave
    batch_burst, batch_every = 32, 4   # avg 8/wave: with the interactive
    #                                    traffic the arrival window is
    #                                    oversubscribed (10 > C=8), so batch
    #                                    backlog grows until the drain tail
    iters = 3 if quick else 10
    cap = 4096                         # per shard (and per tier) — ample
    mesh = make_mesh((n_dev,), ("data",))
    n = n_dev * L
    INTER_BASE = 1_000_000             # rid space: class = rid >= base

    def arrivals(w):
        out = [(0, INTER_BASE + w * 64 + i) for i in range(inter_rate)]
        if w % batch_every == 0:
            out += [(1, w * 64 + i) for i in range(batch_burst)]
        return out

    def run(use_priority):
        if use_priority:
            q = DevicePriorityQueue(mesh, "data", n_prios=2, cap=cap,
                                    payload_width=W, ops_per_shard=L)
        else:
            q = DeviceQueue(mesh, "data", cap=cap, payload_width=W,
                            ops_per_shard=L)
        state = q.init_state()
        enq_wave = {}
        waits = {0: [], 1: []}
        backlog, w = 0, 0
        while w < waves or backlog > 0:   # drain tail: serve EVERY request
            arr = arrivals(w) if w < waves else []
            e = np.zeros(n, bool)
            v = np.zeros(n, bool)
            pr = np.zeros(n, np.int32)
            pw = np.zeros((n, W), np.int32)
            for j, (p, rid) in enumerate(arr):
                e[j] = v[j] = True
                pr[j] = p
                pw[j, 0] = rid
                enq_wave[rid] = w
            v[len(arr):len(arr) + C] = True          # C dequeue requests
            if use_priority:
                state, _, _, _, dv, dok, ovf, _ = q.step(
                    state, jnp.array(e), jnp.array(v), jnp.array(pr),
                    jnp.array(pw))
            else:
                state, _, _, dv, dok, ovf = q.step(
                    state, jnp.array(e), jnp.array(v), jnp.array(pw))
            assert not bool(np.asarray(ovf).any())
            dv, dok = np.asarray(dv), np.asarray(dok)
            served = 0
            for i in range(n):
                if dok[i]:
                    rid = int(dv[i, 0])
                    served += 1
                    waits[0 if rid >= INTER_BASE else 1].append(
                        w - enq_wave.pop(rid))
            backlog += len(arr) - served
            w += 1
        return waits, w

    def pct(xs):
        a = np.asarray(xs, np.float64)
        return {"n": len(xs), "mean": float(a.mean()),
                "p50": float(np.percentile(a, 50)),
                "p99": float(np.percentile(a, 99)),
                "max": float(a.max())}

    fifo_waits, fifo_total = run(False)
    pq_waits, pq_total = run(True)
    assert fifo_total == pq_total, "throughput diverged between runs"

    # ---- steady-state wave rate + collective count of the priority path ---
    K = 8 if quick else 32
    rng = np.random.default_rng(5)
    E = jnp.array(rng.random((K, n)) < 0.5)
    V = jnp.ones((K, n), bool)
    PR = jnp.array(rng.integers(0, 2, (K, n)), jnp.int32)
    PW = jnp.array(rng.integers(0, 100, (K, n, W)), jnp.int32)
    fifo = DeviceQueue(mesh, "data", cap=cap, payload_width=W,
                       ops_per_shard=L)
    pq = DevicePriorityQueue(mesh, "data", n_prios=2, cap=cap,
                             payload_width=W, ops_per_shard=L)

    def run_fifo():
        out = fifo.run_waves(fifo.init_state(), E, V, PW)
        jax.block_until_ready(out[0].store_full)

    def run_pq():
        out = pq.run_waves(pq.init_state(), E, V, PR, PW)
        jax.block_until_ready(out[0].store_full)

    def best_time(fn):
        fn()  # warmup / compile
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    t_fifo = best_time(run_fifo)
    t_pq = best_time(run_pq)
    zeros = (fifo.init_state(), jnp.zeros(n, bool), jnp.zeros(n, bool),
             jnp.zeros((n, W), jnp.int32))
    coll_fifo = count_all_to_all(fifo._step, zeros)
    zeros = (pq.init_state(), jnp.zeros(n, bool), jnp.zeros(n, bool),
             jnp.zeros(n, jnp.int32), jnp.zeros((n, W), jnp.int32))
    coll_pq = count_all_to_all(pq._step, zeros)

    return {
        "n_dev": n_dev, "waves": waves, "total_waves_to_drain": fifo_total,
        "capacity_per_wave": C,
        "arrivals": {"interactive_per_wave": inter_rate,
                     "batch_burst": batch_burst,
                     "batch_burst_every": batch_every},
        "fifo_baseline": {"interactive": pct(fifo_waits[0]),
                          "batch": pct(fifo_waits[1])},
        "priority_2tier": {"interactive": pct(pq_waits[0]),
                           "batch": pct(pq_waits[1])},
        "interactive_p99_speedup": (pct(fifo_waits[0])["p99"]
                                    / max(pct(pq_waits[0])["p99"], 0.5)),
        "steady_state": {
            "fifo_waves_per_sec": K / t_fifo,
            "priority_waves_per_sec": K / t_pq,
            "overhead_pct": (t_pq - t_fifo) / t_fifo * 100.0,
            "collectives_per_wave": {"fifo": coll_fifo, "priority": coll_pq},
        },
    }


def _measure_relaxation_sweep(n_dev: int, quick: bool = False) -> dict:
    """The ROADMAP relaxation study: what does ``relaxation=k`` buy?

    Tier-skewed traffic (most arrivals in the low-urgency tiers, so the
    best non-empty tier's head is usually remote) with one dequeue per
    shard per wave.  For k in {0, 1, 2}: the fraction of serves that were
    *local* (head owned by the issuing shard — the hop the relaxation
    exists to avoid) vs. the tier skew it costs (served tier minus the
    strictly-best tier at serve time, replayed exactly host-side)."""
    from repro.compat import make_mesh
    from repro.dqueue import DevicePriorityQueue

    P_, L, W = 4, 8, 2
    waves = 24 if quick else 96
    tier_probs = np.array([0.1, 0.2, 0.3, 0.4])
    mesh = make_mesh((n_dev,), ("data",))
    n = n_dev * L
    out = {}
    for k in (0, 1, 2):
        q = DevicePriorityQueue(mesh, "data", n_prios=P_, cap=4096,
                                payload_width=W, ops_per_shard=L,
                                relaxation=k)
        state = q.init_state()
        rng = np.random.default_rng(17)        # same traffic for every k
        sizes = [0] * P_                       # host mirror of tier sizes
        serves = local = relaxed = 0
        skews = []
        for w in range(waves):
            e = np.zeros(n, bool)
            v = np.zeros(n, bool)
            pr = np.zeros(n, np.int32)
            pw = np.zeros((n, W), np.int32)
            n_arr = int(rng.integers(n_dev, n_dev + 4))
            for j in range(n_arr):             # arrivals, tier-skewed, kept
                i = (j // (L - 1)) * L + j % (L - 1)  # off the last slot of
                e[i] = v[i] = True                    # each shard (reserved
                pr[i] = rng.choice(P_, p=tier_probs)  # for its dequeue)
            for s in range(n_dev):             # one dequeue per shard
                v[s * L + L - 1] = True
            state, tier, pos, m, dv, dok, ovf, nrel = q.step(
                state, jnp.array(e), jnp.array(v), jnp.array(pr),
                jnp.array(pw))
            assert not bool(np.asarray(ovf))
            tier, pos, m = map(np.asarray, (tier, pos, m))
            relaxed += int(np.asarray(nrel))
            # exact host replay, in wave order: enqueues first, then each
            # dequeue sees the sizes left by the previous ones
            for i in range(n):
                if e[i] and m[i]:
                    sizes[int(tier[i])] += 1
            for i in range(n):
                if v[i] and not e[i] and m[i]:
                    best = next(p for p in range(P_) if sizes[p] > 0)
                    t = int(tier[i])
                    skews.append(t - best)
                    sizes[t] -= 1
                    serves += 1
                    local += int(int(pos[i]) % n_dev == i // L)
        out[f"k={k}"] = {
            "serves": serves,
            "local_serve_fraction": local / max(serves, 1),
            "relaxed_fraction": relaxed / max(serves, 1),
            "tier_skew_mean": float(np.mean(skews)) if skews else 0.0,
            "tier_skew_max": int(max(skews)) if skews else 0,
        }
    return out


def emit_bench_pr3(path: str = "BENCH_PR3.json", n_dev: int = 8,
                   quick: bool = False) -> dict:
    """Measure priority-tier tail-latency separation under mixed load plus
    the relaxation=k sweep, and write JSON (re-execs on a forced
    ``n_dev``-device CPU mesh)."""
    if not os.path.isabs(path):
        path = os.path.join(_REPO_ROOT, path)
    child = _reexec_on_mesh(
        "PR3", path, n_dev,
        ["--pr3", path, "--n-dev", str(n_dev)]
        + (["--quick"] if quick else []))
    if child is not None:
        return child
    data = _measure_priority_mixed(n_dev=n_dev, quick=quick)
    data["relaxation_sweep"] = _measure_relaxation_sweep(n_dev=n_dev,
                                                         quick=quick)
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
    return data


# ------------------------------ PR 4: unified engine, wave pipelining ------
def _measure_pipelining(n_dev: int, K: int, ops_per_shard: int = 64,
                        iters: int = 10, quick: bool = False) -> dict:
    """K-wave bursts through the unified WaveEngine: the sequential burst
    schedule vs. the software-pipelined one (wave k's dispatch overlapped
    with wave k-1's store rewrite; request_k ‖ reply_{k-1} fused into ONE
    all_to_all per wave), for all three disciplines.  Identical op
    schedules, identical results — only the wave schedule differs."""
    from repro.compat import make_mesh
    from repro.dqueue import (DevicePriorityQueue, DeviceQueue, DeviceStack)
    if quick:
        K, iters = min(K, 8), 3
    mesh = make_mesh((n_dev,), ("data",))
    n = n_dev * ops_per_shard
    cap = max(256, K * ops_per_shard // n_dev + 1)
    rng = np.random.default_rng(5)
    E = jnp.array(rng.random((K, n)) < 0.5)
    V = jnp.ones((K, n), bool)
    PR = jnp.array(rng.integers(0, 2, (K, n)), jnp.int32)
    PW = jnp.array(rng.integers(0, 100, (K, n, 4)), jnp.int32)

    def best_time(fn):
        fn()  # warmup / compile
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    cases = {
        "queue": (lambda p: DeviceQueue(
            mesh, "data", cap=cap, payload_width=4,
            ops_per_shard=ops_per_shard, pipelined=p), (E, V, PW)),
        "stack": (lambda p: DeviceStack(
            mesh, "data", cap=cap, payload_width=4,
            ops_per_shard=ops_per_shard, slot_depth=4, pipelined=p),
            (E, V, PW)),
        "priority": (lambda p: DevicePriorityQueue(
            mesh, "data", n_prios=2, cap=cap, payload_width=4,
            ops_per_shard=ops_per_shard, pipelined=p), (E, V, PR, PW)),
    }
    out = {"n_dev": n_dev, "K": K, "ops_per_wave": n, "disciplines": {}}
    for name, (make, args) in cases.items():
        row = {}
        for mode, q in (("sequential", make(False)),
                        ("pipelined", make(True))):
            def run(q=q):
                res = q.run_waves(q.init_state(), *args)
                jax.block_until_ready(jax.tree.leaves(res[0])[0])
            t = best_time(run)
            hlo_args = (q.init_state(),) + args
            row[mode] = {
                "waves_per_sec": K / t,
                "us_per_wave": t / K * 1e6,
                # static count for the whole K-wave program: sequential =
                # 2 in the scan body; pipelined = 1 fused in the body + 1
                # drain epilogue (amortized (K+1)/K per wave)
                "all_to_all_static": count_all_to_all(q._run_waves,
                                                      hlo_args),
            }
        row["speedup_waves_per_sec"] = (row["pipelined"]["waves_per_sec"]
                                        / row["sequential"]["waves_per_sec"])
        out["disciplines"][name] = row
    return out


def emit_bench_pr4(path: str = "BENCH_PR4.json", n_dev: int = 8,
                   K: int = 32, quick: bool = False) -> dict:
    """Measure pipelined vs. sequential burst schedules on the unified
    engine and write JSON (re-execs on a forced ``n_dev`` CPU mesh)."""
    if not os.path.isabs(path):
        path = os.path.join(_REPO_ROOT, path)
    child = _reexec_on_mesh(
        "PR4", path, n_dev,
        ["--pr4", path, "--n-dev", str(n_dev), "--waves", str(K)]
        + (["--quick"] if quick else []))
    if child is not None:
        return child
    data = _measure_pipelining(n_dev=n_dev, K=K, quick=quick)
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
    return data


# ------------------------------- PR 5: Seap EDF deadline scheduling --------
def _measure_edf_mixed(n_dev: int, quick: bool = False) -> dict:
    """Deadline traffic with *heterogeneous, drifting per-request slack*
    at the SAME arrival schedule and SAME per-wave service capacity
    through FIFO, static 2-tier priority (slack below the trace median ->
    tier 0 — the best one static cut can do), and Seap with key = the
    deadline wave (EDF at bucket granularity).  The slack distribution
    DRIFTS mid-run (tight-slack phase, then loose-slack phase — think
    diurnal traffic): any cut tuned to the whole trace puts each phase
    almost entirely in one tier, so the static discipline degenerates to
    FIFO exactly when the periodic bursts pile up backlog, while EDF keys
    on each request's own deadline and the Seap directory re-zooms as the
    key distribution moves (splits chase the full buckets, drained ones
    merge away).  Total throughput is identical by construction — the
    difference is WHO waits, measured as deadline misses."""
    from repro.compat import make_mesh
    from repro.dqueue import (DevicePriorityQueue, DeviceQueue,
                              DeviceSeapQueue)

    L, W, C = 16, 2, 8                 # wave width / payload / service cap
    waves = 64 if quick else 192
    steady, burst, burst_every = 4, 40, 12  # avg ~7.3/wave vs C=8: near-
    #                                         critical, ~36-deep transient
    #                                         backlog after each burst that
    #                                         just drains before the next
    slack_lo, slack_hi = 2, 30         # overall slack range across phases
    phase_slacks = ((2, 9), (13, 30))  # tight-phase / loose-phase U[lo,hi)
    iters = 3 if quick else 10
    cap = 4096
    mesh = make_mesh((n_dev,), ("data",))
    n = n_dev * L

    # one arrival trace shared by every flavor (slack is per REQUEST and
    # its distribution drifts at half-time — the continuous, non-
    # stationary urgency a constant-P queue cannot key on)
    rng = np.random.default_rng(11)
    trace, slack_by_rid = [], {}
    rid = 0
    for w in range(waves):
        lo_, hi_ = phase_slacks[int(w >= waves // 2)]
        k = steady + (burst if w % burst_every == 0 else 0)
        arr = []
        for _ in range(k):
            slack = int(rng.integers(lo_, hi_))
            slack_by_rid[rid] = slack
            arr.append((w + slack, rid))
            rid += 1
        trace.append(arr)
    tier_cut = int(np.median(list(slack_by_rid.values())))

    def run(flavor):
        if flavor == "seap_edf":
            # seed a FINE grid over the near-term deadline range only (3
            # waves per bucket); the split/merge rule rolls the refined
            # window forward as early buckets drain and later deadlines
            # pile up, so far-future deadlines share coarse buckets until
            # they come due
            B, grid = 16, 3
            q = DeviceSeapQueue(mesh, "data", n_buckets=B, cap=cap,
                                payload_width=W, ops_per_shard=L,
                                split_occupancy=C // 2,
                                seed_bounds=[i * grid
                                             for i in range(1, B)])
        elif flavor == "priority_2tier":
            q = DevicePriorityQueue(mesh, "data", n_prios=2, cap=cap,
                                    payload_width=W, ops_per_shard=L)
        else:
            q = DeviceQueue(mesh, "data", cap=cap, payload_width=W,
                            ops_per_shard=L)
        state = q.init_state()
        deadline_of, lateness = {}, {}
        backlog, w = 0, 0
        while w < waves or backlog > 0:   # drain tail: serve EVERY request
            arr = trace[w] if w < waves else []
            e = np.zeros(n, bool)
            v = np.zeros(n, bool)
            pr = np.zeros(n, np.int32)
            pw = np.zeros((n, W), np.int32)
            for j, (dl, r) in enumerate(arr):
                e[j] = v[j] = True
                # seap keys on the deadline itself; the static discipline
                # can only threshold the slack into two tiers
                pr[j] = dl if flavor == "seap_edf" else int(dl - w >= tier_cut)
                pw[j, 0] = r
                deadline_of[r] = dl
            v[len(arr):len(arr) + C] = True          # C dequeue requests
            if flavor == "fifo":
                state, _, _, dv, dok, ovf = q.step(
                    state, jnp.array(e), jnp.array(v), jnp.array(pw))
            else:
                state, _, _, _, dv, dok, ovf, _ = q.step(
                    state, jnp.array(e), jnp.array(v), jnp.array(pr),
                    jnp.array(pw))
            if bool(np.asarray(ovf).any()):
                raise RuntimeError(f"{flavor} overflowed the benchmark cap")
            dv, dok = np.asarray(dv), np.asarray(dok)
            served = 0
            for i in range(n):
                if dok[i]:
                    r = int(dv[i, 0])
                    served += 1
                    lateness[r] = w - deadline_of.pop(r)
            backlog += len(arr) - served
            w += 1
        return lateness, w

    def summarize(late):
        a = np.asarray(late, np.float64)
        if a.size == 0:
            return {"n": 0}
        return {"n": int(a.size), "missed": int((a > 0).sum()),
                "miss_rate": float((a > 0).mean()),
                "lateness_mean": float(a.mean()),
                "lateness_p99": float(np.percentile(a, 99)),
                "lateness_max": float(a.max())}

    # slack band edges for the per-urgency breakdown
    bands = [(slack_lo, 8, "urgent_slack_2_7"),
             (8, 16, "mid_slack_8_15"),
             (16, slack_hi, "relaxed_slack_16_29")]

    out = {"n_dev": n_dev, "waves": waves, "capacity_per_wave": C,
           "arrivals": {"steady_per_wave": steady, "burst": burst,
                        "burst_every": burst_every,
                        "slack_uniform": [slack_lo, slack_hi],
                        "tier_cut_2tier": tier_cut}}
    totals = {}
    for flavor in ("fifo", "priority_2tier", "seap_edf"):
        late, total = run(flavor)
        totals[flavor] = total
        assert set(late) == set(slack_by_rid), "requests lost"
        row = {"overall": summarize(list(late.values()))}
        for lo_, hi_, name in bands:
            row[name] = summarize([lt for r, lt in late.items()
                                   if lo_ <= slack_by_rid[r] < hi_])
        out[flavor] = row
    assert len(set(totals.values())) == 1, f"throughput diverged: {totals}"
    for base in ("fifo", "priority_2tier"):
        # miss-count ratio with a floor of one EDF miss, so a zero-miss
        # EDF run reports "N missed -> at least N x fewer" finitely
        out[f"edf_miss_improvement_vs_{base}"] = (
            out[base]["overall"]["missed"]
            / max(out["seap_edf"]["overall"]["missed"], 1))

    # ---- steady-state wave rate + collective count of the seap path ----
    K = 8 if quick else 32
    rng = np.random.default_rng(5)
    E = jnp.array(rng.random((K, n)) < 0.5)
    V = jnp.ones((K, n), bool)
    KY = jnp.array(rng.integers(0, 1000, (K, n)), jnp.int32)
    PW = jnp.array(rng.integers(0, 100, (K, n, W)), jnp.int32)
    fifo = DeviceQueue(mesh, "data", cap=cap, payload_width=W,
                       ops_per_shard=L)
    sq = DeviceSeapQueue(mesh, "data", n_buckets=8, cap=cap,
                         payload_width=W, ops_per_shard=L)

    def best_time(fn):
        fn()  # warmup / compile
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    def run_fifo():
        out_ = fifo.run_waves(fifo.init_state(), E, V, PW)
        jax.block_until_ready(out_[0].store_full)

    def run_seap():
        out_ = sq.run_waves(sq.init_state(), E, V, KY, PW)
        jax.block_until_ready(out_[0].store_full)

    t_fifo, t_seap = best_time(run_fifo), best_time(run_seap)
    zeros = (sq.init_state(), jnp.zeros(n, bool), jnp.zeros(n, bool),
             jnp.zeros(n, jnp.int32), jnp.zeros((n, W), jnp.int32))
    out["steady_state"] = {
        "fifo_waves_per_sec": K / t_fifo,
        "seap_waves_per_sec": K / t_seap,
        "overhead_pct": (t_seap - t_fifo) / t_fifo * 100.0,
        "collectives_per_wave": count_all_to_all(sq._step, zeros),
    }
    return out


def emit_bench_pr5(path: str = "BENCH_PR5.json", n_dev: int = 8,
                   quick: bool = False) -> dict:
    """Measure EDF deadline-miss rates vs FIFO and static tiers and write
    JSON (re-execs on a forced ``n_dev``-device CPU mesh)."""
    if not os.path.isabs(path):
        path = os.path.join(_REPO_ROOT, path)
    child = _reexec_on_mesh(
        "PR5", path, n_dev,
        ["--pr5", path, "--n-dev", str(n_dev)]
        + (["--quick"] if quick else []))
    if child is not None:
        return child
    data = _measure_edf_mixed(n_dev=n_dev, quick=quick)
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
    return data


# ------------------------------- PR 7: Wavescope telemetry overhead --------
def _measure_telemetry(n_dev: int, K: int, ops_per_shard: int = 64,
                       iters: int = 40, quick: bool = False) -> dict:
    """Telemetry-on vs telemetry-off on the SAME pipelined K-wave burst:
    Wavescope's metrics row is pure arithmetic on values the wave already
    materializes, accumulated in a donated device ring — so the static
    all_to_all count must not move and the wall-clock overhead should be
    noise.  The burst-boundary drain (device->host read of the ring) is
    timed separately: it is the ONE sanctioned sync and happens once per
    burst, not per wave."""
    from repro.compat import make_mesh
    from repro.dqueue import DevicePriorityQueue, DeviceQueue
    if quick:
        K, iters = min(K, 8), 3
    mesh = make_mesh((n_dev,), ("data",))
    n = n_dev * ops_per_shard
    cap = max(256, K * ops_per_shard // n_dev + 1)
    rng = np.random.default_rng(7)
    E = jnp.array(rng.random((K, n)) < 0.5)
    V = jnp.ones((K, n), bool)
    PR = jnp.array(rng.integers(0, 2, (K, n)), jnp.int32)
    PW = jnp.array(rng.integers(0, 100, (K, n, 4)), jnp.int32)

    cases = {
        "queue": (lambda m: DeviceQueue(
            mesh, "data", cap=cap, payload_width=4,
            ops_per_shard=ops_per_shard, pipelined=True, metrics=m,
            metrics_ring=max(64, K)), (E, V, PW)),
        "priority": (lambda m: DevicePriorityQueue(
            mesh, "data", n_prios=2, cap=cap, payload_width=4,
            ops_per_shard=ops_per_shard, pipelined=True, metrics=m,
            metrics_ring=max(64, K)), (E, V, PR, PW)),
    }
    out = {"n_dev": n_dev, "K": K, "ops_per_wave": n, "disciplines": {}}
    for name, (make, args) in cases.items():
        row = {}
        q_off, q_on = make(False), make(True)

        def run(q):
            res = q.run_waves(q.init_state(), *args)
            jax.block_until_ready(jax.tree.leaves(res[0])[0])

        # interleave the off/on timings so machine drift (CI neighbors,
        # frequency scaling) hits both flavors symmetrically; best-of
        run(q_off), run(q_on)          # warmup / compile both first
        t_off = t_on = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            run(q_off)
            t_off = min(t_off, time.perf_counter() - t0)
            t0 = time.perf_counter()
            run(q_on)
            t_on = min(t_on, time.perf_counter() - t0)
        for mode, q, t in (("telemetry_off", q_off, t_off),
                           ("telemetry_on", q_on, t_on)):
            st = q.init_state()
            if q.engine.metrics:
                st = (st, q.engine.init_metrics_state())
            row[mode] = {
                "waves_per_sec": K / t,
                "us_per_wave": t / K * 1e6,
                "all_to_all_static": count_all_to_all(q._run_waves,
                                                      (st,) + args),
            }
        q_on.drain_metrics(reset=True)
        run(q_on)
        t0 = time.perf_counter()
        rows = q_on.drain_metrics(reset=True)
        row["drain_us_per_burst"] = (time.perf_counter() - t0) * 1e6
        row["rows_per_burst"] = len(rows)
        row["overhead_pct"] = 100.0 * (t_on / t_off - 1.0)
        row["all_to_all_added"] = (
            row["telemetry_on"]["all_to_all_static"]
            - row["telemetry_off"]["all_to_all_static"])
        out["disciplines"][name] = row
    return out


def emit_bench_pr7(path: str = "BENCH_PR7.json", n_dev: int = 8,
                   K: int = 32, quick: bool = False) -> dict:
    """Measure Wavescope telemetry overhead on the pipelined burst and
    write JSON (re-execs on a forced ``n_dev``-device CPU mesh)."""
    if not os.path.isabs(path):
        path = os.path.join(_REPO_ROOT, path)
    child = _reexec_on_mesh(
        "PR7", path, n_dev,
        ["--pr7", path, "--n-dev", str(n_dev), "--waves", str(K)]
        + (["--quick"] if quick else []))
    if child is not None:
        return child
    data = _measure_telemetry(n_dev=n_dev, K=K, quick=quick)
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
    return data


def _measure_backpressure(n_dev: int, quick: bool = False) -> dict:
    """The SAME 10x-burst arrival schedule through a ServeEngine with no
    admission policy (the pre-PR 8 baseline: staging is unconditional, so
    the burst overflows the device queue MID-WAVE and poisons the engine)
    vs. the shed / defer / degrade policies and shed + the hysteresis
    autoscale controller.  The baseline must overflow; every policy must
    finish with ZERO QueueOverflowError — overload becomes structured,
    resubmittable AdmissionRejected sheds (or host-side spills, or tier
    downgrades) decided BEFORE staging, against the zero-cost pressure
    API.  Reported per flavor: goodput, shed rate, overflow count, p99
    admission-decision latency; the autoscale flavor adds resize counts
    and the shard trajectory."""
    from repro.configs import get_config
    from repro.dqueue import QueueOverflowError
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.serve import (AdmissionRejected, HysteresisController,
                             Request, ServeEngine)

    steps = 30 if quick else 80
    steady, burst, burst_len, burst_every = 2, 20, 3, 10 if quick else 20
    max_slots, max_new, queue_cap = 6, 2, 8   # window cap = 2 shards x 8
    spill_cap = 64

    cfg = get_config("mamba2_130m").reduced(n_layers=1)
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.key(0))

    # one arrival trace shared by every flavor: steady near-capacity base
    # rate + 10x bursts that exceed the whole queue window several-fold
    # (offset into the cycle so the baseline provably serves the steady
    # rate fine before the first burst overflows it)
    arrivals = [steady + (burst if 4 <= (w % burst_every) < 4 + burst_len
                          else 0) for w in range(steps)]

    def make_engine(flavor):
        mesh = make_host_mesh(n_data=2)
        kw = dict(max_slots=max_slots, max_seq=4 + max_new + 2,
                  queue_cap=queue_cap, spill_cap=spill_cap)
        if flavor == "baseline":
            return ServeEngine(model, params, mesh, **kw)
        if flavor == "degrade":
            return ServeEngine(model, params, mesh, priorities=2,
                               admission="degrade", **kw)
        if flavor == "shed_autoscale":
            ctl = HysteresisController(high_watermark=0.6, high_patience=2,
                                       low_watermark=0.15, low_patience=12,
                                       cooldown=3, grow_k=2)
            return ServeEngine(model, params, mesh, admission="shed",
                               autoscale=ctl, **kw)
        return ServeEngine(model, params, mesh, admission=flavor, **kw)

    def run(flavor):
        eng = make_engine(flavor)
        offered = shed = spill_overflows = overflows = 0
        rid = 0
        for w in range(steps):
            reqs = [Request(rid=rid + j, prompt=[1, 2, 3],
                            max_new=max_new) for j in range(arrivals[w])]
            rid += len(reqs)
            offered += len(reqs)
            try:
                eng.submit(reqs)
            except AdmissionRejected as e:
                shed += len(e.shed)
                spill_overflows += int(e.kind == "spill-overflow")
            except QueueOverflowError:
                overflows += 1
                break
            try:
                eng.step()
            except QueueOverflowError:
                overflows += 1
                break
        else:
            try:
                eng.run_until_drained(max_steps=1000)
            except QueueOverflowError:
                overflows += 1
        st = eng.admission_stats
        lat = np.asarray(st["decide_us"], np.float64)
        row = {"offered": offered, "served": eng.stats["served"],
               "goodput": eng.stats["served"] / offered,
               "shed": shed, "shed_rate": shed / offered,
               "degraded": st["degraded"],
               "spill_peak": st["spill_peak"],
               "spill_overflow_rejects": spill_overflows,
               "queue_overflows": overflows,
               "admission_decide_us_p99":
                   float(np.percentile(lat, 99)) if lat.size else None}
        if eng.autoscale is not None:
            snap = eng.autoscale.snapshot()
            row["resizes"] = snap["grows"] + snap["shrinks"]
            row["grows"] = snap["grows"]
            row["shrinks"] = snap["shrinks"]
            row["final_shards"] = eng.queue.n_shards
        return row

    out = {"n_dev": n_dev, "n_shards": 2,
           "window_capacity": 2 * queue_cap, "steps": steps,
           "arrivals": {"steady_per_step": steady, "burst": burst,
                        "burst_len": burst_len,
                        "burst_every": burst_every},
           "service": {"max_slots": max_slots, "max_new": max_new},
           "spill_cap": spill_cap}
    for flavor in ("baseline", "shed", "defer", "degrade",
                   "shed_autoscale"):
        out[flavor] = run(flavor)
    # ---- the headline claims, asserted so the artifact can't lie ----
    assert out["baseline"]["queue_overflows"] > 0, \
        "baseline failed to overflow — the burst no longer stresses it"
    for flavor in ("shed", "defer", "degrade", "shed_autoscale"):
        assert out[flavor]["queue_overflows"] == 0, \
            f"{flavor} let the queue overflow"
        assert out[flavor]["served"] > out["baseline"]["served"], \
            f"{flavor} served less than the overflowing baseline"
    assert out["shed_autoscale"]["resizes"] > 0, \
        "controller never resized under sustained bursts"
    return out


def emit_bench_pr8(path: str = "BENCH_PR8.json", n_dev: int = 8,
                   quick: bool = False) -> dict:
    """Measure backpressure policies vs. the overflowing baseline under
    10x bursts and write JSON (re-execs on a forced ``n_dev``-device CPU
    mesh)."""
    if not os.path.isabs(path):
        path = os.path.join(_REPO_ROOT, path)
    child = _reexec_on_mesh(
        "PR8", path, n_dev,
        ["--pr8", path, "--n-dev", str(n_dev)]
        + (["--quick"] if quick else []))
    if child is not None:
        return child
    data = _measure_backpressure(n_dev=n_dev, quick=quick)
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
    return data


def _measure_compact_occupancy(n_dev: int, K: int, ops_per_shard: int = 256,
                               iters: int = 12, quick: bool = False) -> dict:
    """Occupancy-adaptive envelopes (PR 9): the SAME logical op stream
    driven (a) compact — every wave staged at the smallest bucket-ladder
    width that fits its live ops — vs (b) full — every wave padded to the
    full ``n_shards * L`` envelope.  At 5% / 25% occupancy the compact
    envelope is 4x narrower (ladder {L/4, L/2, L}); at 100% the bucket
    choice degenerates to the full width and the ratio must be ~1.  Both
    drivers advance twin queue instances through identical logical ops,
    so the emitter can assert bit-identical per-op outputs AND final
    device state (modulo the write-only padding scratch row) — the
    speedup is not allowed to change a single answer."""
    from repro.dqueue import ElasticDevicePriorityQueue, ElasticDeviceQueue
    if quick:
        K, iters = min(K, 8), 3
    L = ops_per_shard
    n_full = n_dev * L
    cap = max(512, 2 * K * L // n_dev)
    occupancies = [("5%", max(1, n_full * 5 // 100)),
                   ("25%", n_full // 4),
                   ("100%", n_full)]
    cases = {
        "queue": (lambda: ElasticDeviceQueue(
            n_dev, cap=cap, payload_width=4, ops_per_shard=L), False),
        "priority": (lambda: ElasticDevicePriorityQueue(
            n_dev, n_prios=2, cap=cap, payload_width=4, ops_per_shard=L),
            True),
    }
    out = {"n_dev": n_dev, "ops_per_shard": L, "full_ops_per_wave": n_full,
           "K": K, "bucket_ladder": None, "disciplines": {}}
    for name, (make, has_prio) in cases.items():
        rows = {}
        for label, n_ops in occupancies:
            eq_c, eq_f = make(), make()
            out["bucket_ladder"] = list(eq_c.bucket_widths())
            rng = np.random.default_rng(abs(hash((name, label))) % 9973)
            # exactly balanced enq/deq per wave keeps the depth bounded
            # across every warmup+timing pass without touching the cap
            enq = np.zeros((K, n_ops), bool)
            for k in range(K):
                enq[k, rng.permutation(n_ops)[:n_ops // 2 + 1]] = True
            pri = rng.integers(0, 2, (K, n_ops)).astype(np.int32)
            pay = rng.integers(0, 1 << 20, (K, n_ops, 4)).astype(np.int32)

            def drive(eq, compact, n_ops=n_ops, enq=enq, pri=pri, pay=pay,
                      has_prio=has_prio):
                outs = []
                for k in range(K):
                    w = eq.pick_width(n_ops) if compact else L
                    n = eq.n_shards * w
                    E = np.zeros(n, bool)
                    E[:n_ops] = enq[k]
                    V = np.zeros(n, bool)
                    V[:n_ops] = True
                    PW = np.zeros((n, 4), np.int32)
                    PW[:n_ops] = pay[k]
                    if has_prio:
                        PR = np.zeros(n, np.int32)
                        PR[:n_ops] = pri[k]
                        res = eq.step(E, V, PR, PW)[:5]
                    else:
                        res = eq.step(E, V, PW)[:4]
                    outs.append([np.asarray(x)[:n_ops] for x in res])
                return outs

            # compile pass: hits every envelope width each driver uses,
            # advancing BOTH states through the same logical ops
            drive(eq_c, True), drive(eq_f, False)
            t_c = t_f = float("inf")
            outs_c = outs_f = None
            for _ in range(iters):
                t0 = time.perf_counter()
                outs_c = drive(eq_c, True)
                t_c = min(t_c, time.perf_counter() - t0)
                t0 = time.perf_counter()
                outs_f = drive(eq_f, False)
                t_f = min(t_f, time.perf_counter() - t0)
            # ---- bit-identity, asserted so the artifact can't lie ----
            for k, (oc, of) in enumerate(zip(outs_c, outs_f)):
                for a, b in zip(oc, of):
                    assert np.array_equal(a, b), \
                        (name, label, k, "per-op outputs differ")
            dc, df = eq_c._state_dict(), eq_f._state_dict()
            assert set(dc) == set(df)
            for key in sorted(dc):
                a, b = np.asarray(dc[key]), np.asarray(df[key])
                if key in type(eq_c)._sharded_keys:
                    # the trailing slot row is write-only padding scratch;
                    # its garbage legitimately differs across widths
                    a, b = a[:, :-1], b[:, :-1]
                assert np.array_equal(a, b), \
                    (name, label, key, "final device state differs")
            rows[label] = {
                "n_ops_per_wave": n_ops,
                "bucket_width_compact": eq_c.pick_width(n_ops),
                "envelope_compact": n_dev * eq_c.pick_width(n_ops),
                "envelope_full": n_full,
                "compact": {"waves_per_sec": K / t_c,
                            "us_per_wave": t_c / K * 1e6},
                "full": {"waves_per_sec": K / t_f,
                         "us_per_wave": t_f / K * 1e6},
                "speedup_waves_per_sec": t_f / t_c,
                "bit_identical": True,
            }
        out["disciplines"][name] = rows
    # headline acceptance: >= 1.3x waves/sec at <= 25% occupancy
    for name, rows in out["disciplines"].items():
        for label in ("5%", "25%"):
            sp = rows[label]["speedup_waves_per_sec"]
            assert sp >= 1.3, \
                (name, label, f"compact speedup {sp:.2f}x < 1.3x")
    return out


def _measure_segscan_modes(n: int = 1 << 15, iters: int = 10) -> dict:
    """Single-shard segscan dispatch: the jnp core scan (the compiled-XLA
    oracle and the CPU hot path) vs the pallas kernel in interpret mode
    (the CPU CI correctness path) vs compiled pallas (TPU/GPU only — on
    the CPU mesh it is reported as null with a note, because
    ``use_fused_dispatch()`` keeps the wave off the kernel there)."""
    from repro.core.scan_queue import QueueState, queue_scan
    from repro.kernels.backend import default_interpret, use_fused_dispatch
    from repro.kernels.segscan import queue_scan_pallas

    rng = np.random.default_rng(5)
    e = jnp.array(rng.random(n) < 0.5)
    v = jnp.ones((n,), bool)
    f0, l0 = jnp.int32(0), jnp.int32(-1)
    core = jax.jit(lambda a, b: queue_scan(a, QueueState.empty(), valid=b))
    row = {"n": n, "backend": jax.default_backend(),
           "default_interpret": bool(default_interpret()),
           "use_fused_dispatch": bool(use_fused_dispatch())}
    row["core_jnp_us"] = _time_us(core, e, v, iters=iters)
    row["pallas_interpret_us"] = _time_us(
        lambda a, b: queue_scan_pallas(a, b, f0, l0, interpret=True), e, v,
        iters=max(3, iters // 3), warmup=1)
    if jax.default_backend() != "cpu":
        row["pallas_compiled_us"] = _time_us(
            lambda a, b: queue_scan_pallas(a, b, f0, l0, interpret=False),
            e, v, iters=iters)
    else:
        row["pallas_compiled_us"] = None
        row["note"] = ("compiled pallas needs a TPU/GPU backend; on the "
                       "CPU mesh the wave hot path keeps the jnp core "
                       "scans and tests drive the kernels in interpret "
                       "mode")
    # the interpret kernel must agree with the oracle on this input
    ref = core(e, v)
    pos, matched, *_ = queue_scan_pallas(e, v, f0, l0, interpret=True)
    assert np.array_equal(np.asarray(pos), np.asarray(ref[0])), \
        "pallas interpret pos diverged from the core oracle"
    return row


def emit_bench_pr9(path: str = "BENCH_PR9.json", n_dev: int = 8,
                   K: int = 32, quick: bool = False) -> dict:
    """Measure occupancy-adaptive compact waves vs the full envelope (with
    bit-identity asserted) plus segscan dispatch-mode timings, and write
    JSON (re-execs on a forced ``n_dev``-device CPU mesh)."""
    if not os.path.isabs(path):
        path = os.path.join(_REPO_ROOT, path)
    child = _reexec_on_mesh(
        "PR9", path, n_dev,
        ["--pr9", path, "--n-dev", str(n_dev), "--waves", str(K)]
        + (["--quick"] if quick else []))
    if child is not None:
        return child
    data = {
        "occupancy": _measure_compact_occupancy(n_dev=n_dev, K=K,
                                                quick=quick),
        "segscan": _measure_segscan_modes(iters=3 if quick else 10),
    }
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
    return data


PR10_WIRE_MARK = "PR10-WIRE-JSON "

_PR10_WIRE_CHILD = r"""
import json
import time

import numpy as np

from repro.runtime import DistributedRuntime

rt = DistributedRuntime.from_env()      # BEFORE any jax computation

from repro.dqueue import ElasticDeviceQueue

q = ElasticDeviceQueue(6, cap=64, payload_width=2, ops_per_shard=8,
                       runtime=rt)
K, reps = %(K)d, %(reps)d
n = q.n_shards * q.L
zb = np.zeros((K, n), bool)
zi = np.zeros((K, n, 2), np.int32)
q.run_waves(zb, zb, zi)                    # compile + warm the socket path
rt.sync()
t = time.perf_counter()
for _ in range(reps):
    q.run_waves(zb, zb, zi)
rt.sync()
steady_s = time.perf_counter() - t
ones = np.ones(n, bool)
fill = np.arange(n * 2, dtype=np.int32).reshape(n, 2)
for _ in range(4):
    q.step(ones, ones, fill)
rt.sync()
t = time.perf_counter()
q.grow(2)
grow_s = time.perf_counter() - t
t = time.perf_counter()
q.shrink([6, 7])
shrink_s = time.perf_counter() - t
out = {
    "n_procs": rt.process_role.count,
    "n_shards": 6,
    "waves": K * reps,
    "real_waves_per_sec": (K * reps) / steady_s,
    "migration": {
        "grow_us": grow_s * 1e6,
        "grow_bytes_moved": int(q.migrations[-2]["bytes_moved"]),
        "shrink_us": shrink_s * 1e6,
        "shrink_bytes_moved": int(q.migrations[-1]["bytes_moved"]),
    },
}
if rt.process_role.coordinator:
    print("%(mark)s" + json.dumps(out))
"""


def _measure_pr10_parity(n_dev: int, waves: int) -> dict:
    """Assert the runtime seam is behavior-preserving: the same op stream
    through a bare-mesh DeviceQueue (the PR 9 path) and a
    LocalRuntime-built one must be BIT-identical, state and outputs."""
    from repro.dqueue import DeviceQueue
    from repro.launch.mesh import make_elastic_mesh
    from repro.runtime import LocalRuntime

    mesh = make_elastic_mesh(n_dev)
    n = n_dev * 8
    rng = np.random.default_rng(17)
    ops = [(rng.random(n) < 0.5, rng.random(n) < 0.85,
            rng.integers(0, 1 << 20, (n, 2)).astype(np.int32))
           for _ in range(waves)]

    def drive(q):
        st = q.init_state()
        outs = []
        for e, v, pw in ops:
            st, *rest = q.step(st, e, v, pw)
            outs.append([np.asarray(x) for x in rest])
        return outs, [np.asarray(x) for x in jax.tree.leaves(st)]

    a, sa = drive(DeviceQueue(mesh, "data", cap=64, payload_width=2,
                              ops_per_shard=8))
    b, sb = drive(DeviceQueue(
        LocalRuntime(devices=list(mesh.devices.flat)), cap=64,
        payload_width=2, ops_per_shard=8))
    for xa, xb in zip(a, b):
        for ya, yb in zip(xa, xb):
            assert (ya == yb).all(), "runtime path diverged from mesh path"
    for la, lb in zip(sa, sb):
        assert (la == lb).all(), "runtime path diverged in final state"
    return {"bit_identical": True, "waves": waves, "n_shards": n_dev}


def _measure_pr10_sim_sweep(n_dev: int, K: int, quick: bool) -> dict:
    """Steady-state waves/sec and migration-wave cost under the SimRuntime
    latency points {0us, 100us, 1ms} (base per-collective cost; 8 us/MiB
    on the wire everywhere)."""
    from repro.dqueue import ElasticDeviceQueue
    from repro.runtime import LatencyModel, SimRuntime

    reps = 3 if quick else 10
    P0 = n_dev - 2
    out = {}
    for base_us in (0.0, 100.0, 1000.0):
        sim = SimRuntime(latency=LatencyModel(base_us=base_us,
                                              per_mib_us=8.0))
        q = ElasticDeviceQueue(P0, cap=64, payload_width=2,
                               ops_per_shard=8, runtime=sim)
        n = q.n_shards * q.L
        zb = np.zeros((K, n), bool)
        zi = np.zeros((K, n, 2), np.int32)
        q.run_waves(zb, zb, zi)            # compile
        wire0 = sim.sim_time_s
        t = time.perf_counter()
        for _ in range(reps):
            q.run_waves(zb, zb, zi)
        real_s = time.perf_counter() - t
        n_waves = K * reps
        wire_s = sim.sim_time_s - wire0
        # fill before migrating so the packed-migration wave carries a
        # real payload (an empty queue moves zero bytes)
        ones = np.ones(n, bool)
        fill = np.arange(n * 2, dtype=np.int32).reshape(n, 2)
        for _ in range(4):
            q.step(ones, ones, fill)
        q.grow(2)
        grow = dict(q.migrations[-1])
        q.shrink([P0, P0 + 1])
        shrink = dict(q.migrations[-1])
        # modeled waves/sec = compute-bound rate slowed by the modeled
        # wire (serial launches); the 3-point sweep prices the pipelined
        # K+1 schedule under LAN/WAN regimes
        modeled = n_waves / (real_s + wire_s)
        out[f"{base_us:g}us"] = {
            "real_waves_per_sec": n_waves / real_s,
            "sim_wire_us_per_wave": wire_s / n_waves * 1e6,
            "modeled_waves_per_sec": modeled,
            "migration": {
                "grow_bytes_moved": int(grow["bytes_moved"]),
                "grow_sim_us": float(grow["sim_s"]) * 1e6,
                "shrink_bytes_moved": int(shrink["bytes_moved"]),
                "shrink_sim_us": float(shrink["sim_s"]) * 1e6,
            },
        }
    return out


def _measure_pr10_wire(K: int, quick: bool) -> dict:
    """The same steady-state + migration measurement on the REAL wire: 2
    jax.distributed processes over localhost TCP (gloo collectives)."""
    from repro.runtime import launch_localhost

    reps = 2 if quick else 5
    code = _PR10_WIRE_CHILD % {"K": K, "reps": reps,
                               "mark": PR10_WIRE_MARK}
    results = launch_localhost(code=code, n_procs=2, devs_per_proc=4,
                               timeout=420.0)
    for line in results[0].stdout.splitlines():
        if line.startswith(PR10_WIRE_MARK):
            return json.loads(line[len(PR10_WIRE_MARK):])
    raise RuntimeError(
        f"2-process wire child emitted no result:\n{results[0].stdout}\n"
        f"{results[0].stderr}")


def emit_bench_pr10(path: str = "BENCH_PR10.json", n_dev: int = 8,
                    K: int = 16, quick: bool = False) -> dict:
    """Price the runtime seam (PR 10): LocalRuntime parity (asserted
    bit-identical vs the bare-mesh path), migration-wave cost and
    steady-state waves/sec under SimRuntime latency points
    {0us, 100us, 1ms}, and the same on a real 2-process localhost wire.
    Writes JSON (re-execs on a forced ``n_dev``-device CPU mesh)."""
    if not os.path.isabs(path):
        path = os.path.join(_REPO_ROOT, path)
    child = _reexec_on_mesh(
        "PR10", path, n_dev,
        ["--pr10", path, "--n-dev", str(n_dev), "--waves", str(K)]
        + (["--quick"] if quick else []))
    if child is not None:
        return child
    data = {
        "parity": _measure_pr10_parity(n_dev, waves=4 if quick else 12),
        "sim_sweep": _measure_pr10_sim_sweep(n_dev, K=K, quick=quick),
        "wire_2proc": _measure_pr10_wire(K=max(4, K // 4), quick=quick),
    }
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
    return data


# PR numbers that deliberately ship NO benchmark emitter.  emit_all
# prints one explicit skip line per entry so a missing BENCH_PRn.json in
# the CI artifact is documented output, not a silent gap (PR 8 satellite
# bugfix: --all used to skip PR 6 without a trace).
_NO_BENCH = {
    "BENCH_PR6.json": "PR 6 is the wavecheck static analyzer — nothing "
                      "to time; run `python -m repro.analysis --all`",
}


def emit_all(quick: bool = False, n_dev: int = 8) -> dict:
    """The CI bench-smoke entry point: run EVERY BENCH_PR*.json emitter.

    Any emitter crash fails the whole run (after attempting the rest, so
    one regression doesn't mask another's numbers).  PRs with no
    benchmark are announced via ``_NO_BENCH`` skip lines."""
    emitters = [("BENCH_PR1.json", lambda p: emit_bench_pr1(
                     p, n_dev=n_dev, quick=quick)),
                ("BENCH_PR2.json", lambda p: emit_bench_pr2(
                     p, n_dev=n_dev, quick=quick)),
                ("BENCH_PR3.json", lambda p: emit_bench_pr3(
                     p, n_dev=n_dev, quick=quick)),
                ("BENCH_PR4.json", lambda p: emit_bench_pr4(
                     p, n_dev=n_dev, quick=quick)),
                ("BENCH_PR5.json", lambda p: emit_bench_pr5(
                     p, n_dev=n_dev, quick=quick)),
                ("BENCH_PR7.json", lambda p: emit_bench_pr7(
                     p, n_dev=n_dev, quick=quick)),
                ("BENCH_PR8.json", lambda p: emit_bench_pr8(
                     p, n_dev=n_dev, quick=quick)),
                ("BENCH_PR9.json", lambda p: emit_bench_pr9(
                     p, n_dev=n_dev, quick=quick)),
                ("BENCH_PR10.json", lambda p: emit_bench_pr10(
                     p, n_dev=n_dev, quick=quick))]
    for path, why in sorted(_NO_BENCH.items()):
        print(f"bench: skipping {path} ({why})")
    out, failures = {}, []
    for path, emit in emitters:
        try:
            out[path] = emit(path)
        except Exception as e:
            failures.append(f"{path}: {type(e).__name__}: {e}")
    if failures:
        raise SystemExit("bench emitters failed:\n  " + "\n  ".join(failures))
    return out


def bench_wave_pipeline():
    try:
        data = emit_bench_pr1()
    except Exception as e:  # keep the rest of the CSV usable
        return [("dq_wave_pipeline", 0.0, f"unavailable: {e}", "", "")]
    rows = []
    for key, label in (("seed_single_wave", "dq_seed_single_wave"),
                       ("fused_multi_wave", "dq_fused_multi_wave")):
        d = data[key]
        rows.append((f"{label}_K{data['K']}", d["us_per_wave"],
                     f"{d['waves_per_sec']:.1f} waves/s",
                     d["waves_per_sec"], d["collectives_per_wave"]))
    rows.append((f"dq_fused_speedup_K{data['K']}", 0.0,
                 f"{data['speedup_waves_per_sec']:.2f}x waves/sec", "", ""))
    return rows


def bench_attention():
    from repro.kernels.flash_attention import attention_ref
    rows = []
    rng = np.random.default_rng(3)
    B, H, L, D = 1, 8, 1024, 64
    q = jnp.array(rng.standard_normal((B * H, L, D)), jnp.bfloat16)
    f = jax.jit(lambda q: attention_ref(q, q, q))
    us = _time_us(f, q, iters=5)
    flops = 4 * B * H * L * L * D
    rows.append((f"attention_ref_L{L}", us, f"{flops/us/1e3:.1f} GF/s"))
    return rows


def run_all():
    rows = []
    for fn in (bench_scan_queue, bench_segscan_kernel, bench_device_queue,
               bench_wave_pipeline, bench_attention):
        rows += fn()
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--pr1", nargs="?", const="BENCH_PR1.json", default=None,
                    help="measure the wave pipeline and write BENCH_PR1.json")
    ap.add_argument("--pr2", nargs="?", const="BENCH_PR2.json", default=None,
                    help="measure elastic reshard cost and write "
                         "BENCH_PR2.json")
    ap.add_argument("--pr3", nargs="?", const="BENCH_PR3.json", default=None,
                    help="measure priority-tier mixed-load latency and "
                         "write BENCH_PR3.json")
    ap.add_argument("--pr4", nargs="?", const="BENCH_PR4.json", default=None,
                    help="measure pipelined vs sequential wave bursts and "
                         "write BENCH_PR4.json")
    ap.add_argument("--pr5", nargs="?", const="BENCH_PR5.json", default=None,
                    help="measure EDF deadline-miss rates vs FIFO and "
                         "static tiers and write BENCH_PR5.json")
    ap.add_argument("--pr7", nargs="?", const="BENCH_PR7.json", default=None,
                    help="measure Wavescope telemetry overhead and write "
                         "BENCH_PR7.json")
    ap.add_argument("--pr8", nargs="?", const="BENCH_PR8.json", default=None,
                    help="measure admission backpressure vs the "
                         "overflowing baseline and write BENCH_PR8.json")
    ap.add_argument("--pr9", nargs="?", const="BENCH_PR9.json", default=None,
                    help="measure occupancy-adaptive compact waves vs the "
                         "full envelope and write BENCH_PR9.json")
    ap.add_argument("--pr10", nargs="?", const="BENCH_PR10.json",
                    default=None,
                    help="measure the runtime seam: LocalRuntime parity, "
                         "SimRuntime latency sweep, and the 2-process "
                         "localhost wire; write BENCH_PR10.json")
    ap.add_argument("--all", action="store_true",
                    help="run every BENCH_PR*.json emitter (CI bench smoke)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke mode: fewer waves/iterations")
    ap.add_argument("--n-dev", type=int, default=8)
    ap.add_argument("--waves", type=int, default=32)
    cli = ap.parse_args()
    if cli.all:
        out = emit_all(quick=cli.quick, n_dev=cli.n_dev)
        print(json.dumps({k: "ok" for k in out}, indent=2))
    elif cli.pr1:
        out = emit_bench_pr1(cli.pr1, n_dev=cli.n_dev, K=cli.waves,
                             quick=cli.quick)
        print(json.dumps(out, indent=2))
    elif cli.pr2:
        out = emit_bench_pr2(cli.pr2, n_dev=cli.n_dev, K=cli.waves,
                             quick=cli.quick)
        print(json.dumps(out, indent=2))
    elif cli.pr3:
        out = emit_bench_pr3(cli.pr3, n_dev=cli.n_dev, quick=cli.quick)
        print(json.dumps(out, indent=2))
    elif cli.pr4:
        out = emit_bench_pr4(cli.pr4, n_dev=cli.n_dev, K=cli.waves,
                             quick=cli.quick)
        print(json.dumps(out, indent=2))
    elif cli.pr5:
        out = emit_bench_pr5(cli.pr5, n_dev=cli.n_dev, quick=cli.quick)
        print(json.dumps(out, indent=2))
    elif cli.pr7:
        out = emit_bench_pr7(cli.pr7, n_dev=cli.n_dev, K=cli.waves,
                             quick=cli.quick)
        print(json.dumps(out, indent=2))
    elif cli.pr8:
        out = emit_bench_pr8(cli.pr8, n_dev=cli.n_dev, quick=cli.quick)
        print(json.dumps(out, indent=2))
    elif cli.pr9:
        out = emit_bench_pr9(cli.pr9, n_dev=cli.n_dev, K=cli.waves,
                             quick=cli.quick)
        print(json.dumps(out, indent=2))
    elif cli.pr10:
        out = emit_bench_pr10(cli.pr10, n_dev=cli.n_dev, K=cli.waves,
                              quick=cli.quick)
        print(json.dumps(out, indent=2))
    else:
        for row in run_all():
            print(",".join(str(c) for c in row))
