"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell:
  compute_s    = HLO_flops_per_device / 197e12        (bf16 peak per chip)
  memory_s     = HLO_bytes_per_device / 819e9         (HBM bw)
  collective_s = collective_bytes_per_device / 50e9   (per-link ICI, 1-link
                                                       conservative)
dominant term = the bottleneck; MODEL_FLOPS = 6·N·D (train) or 2·N_active·D
(inference); useful-compute ratio = MODEL_FLOPS_per_dev / HLO_flops; the
roofline fraction (the §Perf score) = compute_s / dominant_s.
"""
from __future__ import annotations

import glob
import json
from pathlib import Path

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

_PARAM_CACHE = {}


def _param_counts(arch: str):
    if arch in _PARAM_CACHE:
        return _PARAM_CACHE[arch]
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config(arch)
    p, _ = build_model(cfg).abstract_params()
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(p))
    n_active = n
    if cfg.n_experts:
        dead = (cfg.n_layers * (cfg.n_experts - cfg.top_k)
                * 3 * cfg.d_model * cfg.d_ff)
        n_active = n - dead
    _PARAM_CACHE[arch] = (n, n_active)
    return n, n_active


def model_flops(rec) -> float:
    """Global useful model flops for the lowered step."""
    n, n_active = _param_counts(rec["arch"])
    seq, gb, kind = rec["seq"], rec["global_batch"], rec["kind"]
    if kind == "train":
        return 6.0 * n_active * seq * gb
    if kind == "prefill":
        return 2.0 * n_active * seq * gb
    return 2.0 * n_active * gb  # decode: one token per sequence


COSTING_DIR = Path(__file__).resolve().parents[1] / "experiments" / "costing"


def _costing(arch, shape):
    p = COSTING_DIR / f"{arch}__{shape}.json"
    if p.exists():
        rec = json.load(open(p))
        if not rec.get("skipped"):
            return rec
    return None


def analyze_cell(rec) -> dict:
    chips = rec["chips"]
    flops_dev = rec["cost"]["flops"]
    bytes_dev = rec["cost"]["bytes_accessed"]
    coll_dev = rec["collectives"].get("total", 0)
    # loop-corrected costs (launch.costrun: unrolled reduced-depth lowering,
    # exact affine extrapolation in layer count)
    cost = _costing(rec["arch"], rec["shape"])
    corrected = cost is not None
    if corrected:
        scale = 1.0
        if rec["mesh"].get("pod"):
            scale = 0.5  # pod2 splits the same global batch over 2x chips
        flops_dev = cost["flops"] * scale
        bytes_dev = cost["bytes"] * scale
        coll_dev = max(coll_dev, cost["coll"] * scale)
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    coll_s = coll_dev / LINK_BW
    dominant_s = max(compute_s, memory_s, coll_s)
    dom = {compute_s: "compute", memory_s: "memory",
           coll_s: "collective"}[dominant_s]
    mf = model_flops(rec)
    useful_ratio = mf / chips / max(flops_dev, 1)
    mfu_proxy = (mf / chips / PEAK_FLOPS) / max(dominant_s, 1e-30)
    return {
        "cell": rec["cell"], "arch": rec["arch"], "shape": rec["shape"],
        "mesh": "2x16x16" if rec["mesh"].get("pod") else "16x16",
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dom,
        "model_flops": mf, "useful_ratio": useful_ratio,
        "mfu_proxy": mfu_proxy, "loop_corrected": corrected,
        "peak_gib": rec["memory"]["peak_bytes_per_device"] / 2**30,
        "peak_gib_tpu": rec["memory"]["peak_bytes_tpu_corrected"] / 2**30,
    }


def load_all(pattern="*.json"):
    out = []
    for f in sorted(glob.glob(str(DRYRUN_DIR / pattern))):
        rec = json.load(open(f))
        if "skipped" in rec:
            continue
        out.append(analyze_cell(rec))
    return out


def markdown_table(rows, only_mesh=None) -> str:
    hdr = ("| cell | compute s | memory s | collective s | dominant | "
           "useful flops ratio | MFU proxy | peak GiB (tpu-corr) |\n"
           "|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if only_mesh and r["mesh"] != only_mesh:
            continue
        lines.append(
            f"| {r['arch']}/{r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['mfu_proxy']:.3f} | {r['peak_gib']:.1f} "
            f"({r['peak_gib_tpu']:.1f}) |")
    return hdr + "\n".join(lines)


def bench_rows():
    """CSV rows for benchmarks.run (one line per dry-run cell)."""
    rows = []
    for r in load_all():
        rows.append((f"roofline_{r['cell']}", r["dominant"],
                     f"mfu_proxy={r['mfu_proxy']:.3f}"))
    return rows


if __name__ == "__main__":
    rows = load_all()
    print(markdown_table(rows, only_mesh="16x16"))
