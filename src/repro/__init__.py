"""SKUEUE on TPU: a sequentially-consistent distributed queue as a JAX
framework substrate.  See README.md / DESIGN.md."""
__version__ = "1.0.0"
