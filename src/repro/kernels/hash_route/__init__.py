from .ops import hash_route_pallas
from .ref import hash_route_ref

__all__ = ["hash_route_pallas", "hash_route_ref"]
