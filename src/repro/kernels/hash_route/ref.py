"""Pure-jnp oracle for the DHT hash-routing kernel.

Mirrors the paper's consistent hashing (Sec. II-B): position -> pseudorandom
key in [0,1) -> owning shard.  With equal-width shard intervals the owner is
``floor(key01 * n_shards)``.  The hash is a 32-bit splitmix finalizer
(TPU-friendly: no uint64)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _mix32(x: jax.Array) -> jax.Array:
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def hash_route_ref(pos: jax.Array, valid: jax.Array, n_shards: int):
    """Returns (owner[n] int32 with -1 for invalid, counts[n_shards] int32)."""
    h = _mix32(pos)
    owner = (h >> jnp.uint32(8)).astype(jnp.uint32) % jnp.uint32(n_shards)
    owner = jnp.where(valid, owner.astype(jnp.int32), -1)
    counts = jnp.sum(
        jax.nn.one_hot(jnp.where(valid, owner, n_shards), n_shards + 1,
                       dtype=jnp.int32),
        axis=0)[:n_shards]
    return owner, counts
