"""Pallas TPU kernel: DHT dispatch routing (SKUEUE Stage 4 front-end).

For a tile of positions: 32-bit splitmix hash (VPU integer ops), owner
bucket, and a per-tile owner histogram via a one-hot matmul (MXU-friendly:
[TILE, n_shards] one-hot contracted against ones).  Tiles are (8, 128) int32
in VMEM; histograms accumulate across a sequential grid axis into the output
block (same-index revisiting pattern)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

TILE_ROWS = 8
TILE_LANES = 128
TILE = TILE_ROWS * TILE_LANES


def _mix32(x):
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def _route_kernel(pos_ref, valid_ref, owner_ref, hist_ref, *, n_shards):
    t = pl.program_id(0)
    pos = pos_ref[...]
    valid = valid_ref[...] != 0
    h = _mix32(pos)
    owner = ((h >> jnp.uint32(8)) % jnp.uint32(n_shards)).astype(jnp.int32)
    owner = jnp.where(valid, owner, -1)
    owner_ref[...] = owner
    # one-hot histogram for this tile, accumulated across the grid
    flat = owner.reshape(-1)
    shard_ids = lax.broadcasted_iota(jnp.int32, (TILE, n_shards), 1)
    onehot = (flat[:, None] == shard_ids).astype(jnp.int32)
    tile_hist = jnp.sum(onehot, axis=0)  # [n_shards]

    @pl.when(t == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    hist_ref[...] += tile_hist.reshape(1, n_shards)


def hash_route_kernel(pos: jax.Array, valid: jax.Array, n_shards: int,
                      interpret: bool | None = None):
    if interpret is None:
        from ..backend import default_interpret
        interpret = default_interpret()
    n = pos.shape[0]
    assert n % TILE == 0
    T = n // TILE
    p2 = pos.astype(jnp.int32).reshape(T, TILE_ROWS, TILE_LANES)
    v2 = valid.astype(jnp.int32).reshape(T, TILE_ROWS, TILE_LANES)
    import functools
    owner, hist = pl.pallas_call(
        functools.partial(_route_kernel, n_shards=n_shards),
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, TILE_ROWS, TILE_LANES), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, TILE_ROWS, TILE_LANES), lambda t: (t, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, TILE_ROWS, TILE_LANES), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, n_shards), lambda t: (0, 0)),  # accumulated
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, TILE_ROWS, TILE_LANES), jnp.int32),
            jax.ShapeDtypeStruct((1, n_shards), jnp.int32),
        ],
        interpret=interpret,
    )(p2, v2)
    return owner.reshape(n), hist[0]
