"""Jit'd public wrapper for the hash_route kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..backend import default_interpret
from .kernel import TILE, hash_route_kernel


@functools.partial(jax.jit, static_argnames=("n_shards", "interpret"))
def _hash_route_pallas(pos, valid, n_shards, interpret):
    n = pos.shape[0]
    pad = (-n) % TILE
    if pad:
        pos = jnp.concatenate([pos, jnp.zeros((pad,), pos.dtype)])
        valid = jnp.concatenate([valid, jnp.zeros((pad,), valid.dtype)])
    owner, counts = hash_route_kernel(pos, valid, n_shards,
                                      interpret=interpret)
    return owner[:n], counts


def hash_route_pallas(pos: jax.Array, valid: jax.Array, n_shards: int,
                      interpret: bool | None = None):
    """Owner shard + per-shard counts for a batch of DHT positions.

    ``interpret=None`` autodetects: interpret on CPU, compiled on TPU/GPU
    (``REPRO_PALLAS_INTERPRET`` overrides — see docs/OPERATIONS.md).
    """
    if interpret is None:
        interpret = default_interpret()
    return _hash_route_pallas(pos, valid, n_shards, interpret)
