"""Jit'd public wrapper for the hash_route kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import TILE, hash_route_kernel


@functools.partial(jax.jit, static_argnames=("n_shards", "interpret"))
def hash_route_pallas(pos: jax.Array, valid: jax.Array, n_shards: int,
                      interpret: bool = True):
    """Owner shard + per-shard counts for a batch of DHT positions."""
    n = pos.shape[0]
    pad = (-n) % TILE
    if pad:
        pos = jnp.concatenate([pos, jnp.zeros((pad,), pos.dtype)])
        valid = jnp.concatenate([valid, jnp.zeros((pad,), valid.dtype)])
    owner, counts = hash_route_kernel(pos, valid, n_shards,
                                      interpret=interpret)
    return owner[:n], counts
