"""Pure-jnp oracle: softmax attention with causal / sliding-window masks."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True,
                  window: Optional[int] = None) -> jax.Array:
    """q: [B, Lq, D]; k/v: [B, Lk, D].  Queries are aligned to the END of the
    key sequence (decode convention: query i attends keys <= Lk-Lq+i)."""
    B, Lq, D = q.shape
    Lk = k.shape[1]
    scale = 1.0 / (D ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Lq)[:, None] + (Lk - Lq)
    kpos = jnp.arange(Lk)[None, :]
    mask = jnp.ones((Lq, Lk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
