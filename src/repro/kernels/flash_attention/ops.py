"""Public wrapper: GQA-aware flash attention (folds KV head groups)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..backend import default_interpret
from .kernel import flash_attention_kernel


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def _flash_attention(q, k, v, causal, window, block_q, block_k, interpret):
    B, Hq, Lq, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    # fold: [B*Hkv, G*Lq, D] queries share the kv head in one kernel batch
    qf = q.reshape(B, Hkv, G, Lq, D).reshape(B * Hkv, G * Lq, D)
    kf = k.reshape(B * Hkv, -1, D)
    vf = v.reshape(B * Hkv, -1, D)
    if G == 1:
        out = flash_attention_kernel(qf, kf, vf, causal=causal, window=window,
                                     block_q=block_q, block_k=block_k,
                                     interpret=interpret)
    else:
        # grouped queries must not cross-mask: run per group slice
        outs = []
        for g in range(G):
            outs.append(flash_attention_kernel(
                qf[:, g * Lq:(g + 1) * Lq], kf, vf, causal=causal,
                window=window, block_q=block_q, block_k=block_k,
                interpret=interpret))
        out = jnp.concatenate(outs, axis=1)
    return out.reshape(B, Hkv, G, Lq, D).reshape(B, Hq, Lq, D)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """q: [B, Hq, Lq, D]; k/v: [B, Hkv, Lk, D]; Hq % Hkv == 0 (GQA).

    Returns [B, Hq, Lq, D].  Queries align to the end of the key sequence.
    ``interpret=None`` autodetects: interpret on CPU, compiled on TPU/GPU
    (``REPRO_PALLAS_INTERPRET`` overrides — see docs/OPERATIONS.md).
    """
    if interpret is None:
        interpret = default_interpret()
    return _flash_attention(q, k, v, causal, window, block_q, block_k,
                            interpret)
