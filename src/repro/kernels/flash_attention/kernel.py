"""Pallas TPU kernel: FlashAttention forward (causal / sliding window).

Online-softmax tiling (Dao et al., adapted to TPU memory hierarchy):
  grid = (batch*heads, q_blocks, kv_blocks)  — kv innermost, sequential;
  q block (Bq, D) stays in VMEM across the kv sweep; running max ``m``,
  normalizer ``l`` and accumulator ``acc`` live in VMEM scratch (f32);
  each step is one (Bq, Bk) MXU matmul + rescale — MXU-aligned with
  Bq = Bk = 128 and D padded to a lane multiple.

Queries align to the END of the key sequence (decode convention), so the
same kernel serves prefill (Lq == Lk), chunked prefill and decode (Lq == 1).
Fully-masked kv blocks are skipped via ``@pl.when`` on block indices —
with causal masking this halves the work; with a sliding window the sweep
touches only O(window) keys per query block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               scale, causal, window, bq, bk, lq, lk, nk):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    off = lk - lq  # query row r corresponds to key position off + global_q

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # block-level relevance: any key in this block visible to any query here?
    q_lo = qi * bq + off
    q_hi = q_lo + bq - 1
    k_lo = ki * bk
    relevant = True
    if causal:
        relevant = jnp.logical_and(relevant, k_lo <= q_hi)
    if window is not None:
        k_hi = k_lo + bk - 1
        relevant = jnp.logical_and(relevant, k_hi > q_lo - window)

    @pl.when(relevant)
    def _step():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_lo
        kpos = lax.broadcasted_iota(jnp.int32, (bq, bk), 1) + k_lo
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _fin():
        l = l_ref[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[...] = (acc_ref[...] / safe).astype(o_ref.dtype)[None]


def flash_attention_kernel(q, k, v, *, causal=True, window=None,
                           block_q=128, block_k=128, interpret=None):
    """q: [B, Lq, D]; k/v: [B, Lk, D] -> [B, Lq, D]."""
    if interpret is None:
        from ..backend import default_interpret
        interpret = default_interpret()
    B, Lq, D = q.shape
    Lk = k.shape[1]
    bq = min(block_q, Lq)
    bk = min(block_k, Lk)
    assert Lq % bq == 0 and Lk % bk == 0, "pad sequence to block multiples"
    nq, nk = Lq // bq, Lk // bk
    scale = 1.0 / (D ** 0.5)
    kern = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, lq=Lq, lk=Lk, nk=nk)
    return pl.pallas_call(
        kern,
        grid=(B, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Lq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),   # acc
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # normalizer
        ],
        interpret=interpret,
    )(q, k, v)
