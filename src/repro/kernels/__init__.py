# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
from .backend import default_interpret, use_fused_dispatch

__all__ = ["default_interpret", "use_fused_dispatch"]
