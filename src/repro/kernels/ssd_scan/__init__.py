from .ops import ssd_scan_pallas
from .ref import ssd_scan_ref

__all__ = ["ssd_scan_pallas", "ssd_scan_ref"]
