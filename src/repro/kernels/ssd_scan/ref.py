"""Pure-jnp oracle for SSD (Mamba-2 state-space duality, arXiv:2405.21060).

The most-naive formulation: a sequential ``lax.scan`` over time of the
diagonal-A SSM recurrence

    S_t = exp(loga_t) * S_{t-1} + B_t ⊗ xt_t          (S: [N, P])
    y_t = C_t @ S_t

where ``xt = x * dt`` and ``loga = dt * A`` are precomputed by the caller
(so the oracle is purely the recurrence the chunked kernel reformulates).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def ssd_scan_ref(xt: jax.Array, loga: jax.Array, B: jax.Array,
                 C: jax.Array) -> jax.Array:
    """xt: [BH, L, P]; loga: [BH, L]; B/C: [BH, L, N] -> y [BH, L, P]."""
    BH, L, P = xt.shape
    N = B.shape[-1]

    def step(S, inp):
        xt_t, la_t, b_t, c_t = inp
        S = jnp.exp(la_t) * S + b_t[:, None] * xt_t[None, :]
        y = c_t @ S
        return S, y

    def per_head(args):
        xt_h, la_h, b_h, c_h = args
        S0 = jnp.zeros((N, P), jnp.float32)
        _, y = lax.scan(step, S0, (xt_h.astype(jnp.float32),
                                   la_h.astype(jnp.float32),
                                   b_h.astype(jnp.float32),
                                   c_h.astype(jnp.float32)))
        return y

    y = jax.vmap(lambda a, b, c, d: per_head((a, b, c, d)))(xt, loga, B, C)
    return y.astype(xt.dtype)
