"""Pallas TPU kernel: chunked SSD scan (Mamba-2 SSD, arXiv:2405.21060).

TPU adaptation of the SSD block decomposition: the sequence is split into
chunks of Q tokens; the grid is (batch*heads, n_chunks) with the chunk axis
sequential and the running state S [N, P] in f32 VMEM scratch:

    intra-chunk (MXU):  y_intra = (tril(C B^T) ∘ decay(i,j)) @ xt
    inter-chunk (MXU):  y_inter = (C * exp(l)) @ S
    state update (MXU): S <- exp(l_Q) S + (B * exp(l_Q - l))^T @ xt

where l = cumsum(loga) within the chunk (loga <= 0, so all exponents are
<= 0 — numerically safe without max-subtraction).  Q and N are chosen
MXU-aligned (128); P is the Mamba head dim (64).  The recurrence depth
drops from L to L/Q, everything else is dense matmul — exactly the
"duality" the paper exploits, mapped onto the MXU instead of tensor cores.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xt_ref, loga_ref, b_ref, c_ref, y_ref, s_ref, *, Q, N, P):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    xt = xt_ref[0, 0].astype(jnp.float32)    # [Q, P]
    la = loga_ref[0, 0, 0].astype(jnp.float32)  # [Q]
    b = b_ref[0, 0].astype(jnp.float32)      # [Q, N]
    c = c_ref[0, 0].astype(jnp.float32)      # [Q, N]
    l = jnp.cumsum(la)                       # [Q] inclusive log-decay

    # inter-chunk: contribution of the carried state
    c_dec = c * jnp.exp(l)[:, None]
    y_inter = lax.dot_general(c_dec, s_ref[...], (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [Q, P]

    # intra-chunk: masked decay-weighted attention-like matmul
    scores = lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # [Q, Q]
    li = l[:, None]
    lj = l[None, :]
    ii = lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    dec = jnp.where(ii >= jj, jnp.exp(li - lj), 0.0)
    y_intra = lax.dot_general(scores * dec, xt, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)

    y_ref[...] = (y_inter + y_intra).astype(y_ref.dtype)[None, None]

    # state update for the next chunk
    ltot = l[Q - 1]
    b_dec = b * jnp.exp(ltot - l)[:, None]
    s_new = lax.dot_general(b_dec, xt, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)    # [N, P]
    s_ref[...] = jnp.exp(ltot) * s_ref[...] + s_new


def ssd_scan_kernel(xt, loga, B, C, chunk: int = 128,
                    interpret: bool | None = None):
    """xt: [BH, L, P]; loga: [BH, L]; B/C: [BH, L, N] -> y [BH, L, P]."""
    if interpret is None:
        from ..backend import default_interpret
        interpret = default_interpret()
    BH, L, P = xt.shape
    N = B.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0, "pad sequence to a chunk multiple"
    nc = L // Q
    la2 = loga.reshape(BH, nc, 1, Q)  # row-major (1, Q) blocks
    kern = functools.partial(_ssd_kernel, Q=Q, N=N, P=P)
    y = pl.pallas_call(
        kern,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c: (b, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Q, P), lambda b, c: (b, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, nc, Q, P), xt.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xt.reshape(BH, nc, Q, P), la2, B.reshape(BH, nc, Q, N),
      C.reshape(BH, nc, Q, N))
    return y.reshape(BH, L, P)
