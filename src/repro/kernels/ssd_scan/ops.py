"""Jit'd public wrapper for the SSD scan kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..backend import default_interpret
from .kernel import ssd_scan_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _ssd_scan_pallas(xt, loga, B, C, chunk, interpret):
    L = xt.shape[1]
    if L % chunk and L > chunk:
        p = (-L) % chunk
        xt = jnp.pad(xt, ((0, 0), (0, p), (0, 0)))
        loga = jnp.pad(loga, ((0, 0), (0, p)))
        B = jnp.pad(B, ((0, 0), (0, p), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, p), (0, 0)))
    y = ssd_scan_kernel(xt, loga, B, C, chunk=chunk, interpret=interpret)
    return y[:, :L]


def ssd_scan_pallas(xt: jax.Array, loga: jax.Array, B: jax.Array,
                    C: jax.Array, chunk: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """Chunked SSD scan. xt: [BH, L, P]; loga: [BH, L]; B/C: [BH, L, N].

    ``interpret=None`` autodetects: interpret on CPU, compiled on TPU/GPU
    (``REPRO_PALLAS_INTERPRET`` overrides — see docs/OPERATIONS.md).
    """
    if interpret is None:
        interpret = default_interpret()
    return _ssd_scan_pallas(xt, loga, B, C, chunk, interpret)
