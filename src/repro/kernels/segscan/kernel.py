"""Pallas TPU kernel: SKUEUE batch position-assignment scan.

The paper's Stages 1-3 for one device's request array, as a two-phase
Blelloch scan tiled for VMEM:

  phase A (parallel over tiles): per-tile total (A,B,C) transform —
          a pure reduction, one (8,128) VPU tile at a time;
  phase B (parallel over tiles, given the exclusive tile-prefix carries):
          intra-tile Hillis-Steele scan in the min-plus semiring +
          position emission.

The inter-tile exclusive scan of the tiny per-tile carries happens in jnp
between the two pallas_calls (it is O(n/TILE) elements — negligible), which
mirrors the paper's anchor step: the carries ARE the aggregated batches.

Layout: requests are reshaped to [T, 8, 128] tiles; the scan order is the
row-major flattened order.  All arithmetic is int32 in VMEM; the MXU is not
involved (this is a VPU kernel).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

INF = 2 ** 30  # plain int: Pallas kernels need literals, not traced consts
TILE_ROWS = 8
TILE_LANES = 128
TILE = TILE_ROWS * TILE_LANES


def _compose(t1, t2):
    A1, B1, C1 = t1
    A2, B2, C2 = t2
    return (A1 + A2,
            jnp.minimum(jnp.minimum(B1 + A2, C1 + B2), INF),
            C1 + C2)


def _tile_transforms(is_enq, valid):
    e = jnp.logical_and(is_enq != 0, valid != 0).astype(jnp.int32)
    v = (valid != 0)
    A = jnp.where(v, 1 - e, 0)
    B = jnp.where(v, jnp.where(e > 0, INF, 1), INF)
    C = jnp.where(v, e, 0)
    return A, B, C


def _totals_kernel(is_enq_ref, valid_ref, out_ref):
    """Phase A: reduce one [8,128] tile to its total (A,B,C)."""
    A, B, C = _tile_transforms(is_enq_ref[...], valid_ref[...])
    flat = (A.reshape(-1), B.reshape(-1), C.reshape(-1))
    # log-step tree reduction over the flattened tile.  The min-plus compose
    # is non-commutative: pair ADJACENT elements (2i, 2i+1) at every level so
    # the reduction respects the left-to-right request order.
    n = TILE
    a, b, c = flat
    while n > 1:
        left = (a[0:n:2], b[0:n:2], c[0:n:2])
        right = (a[1:n:2], b[1:n:2], c[1:n:2])
        a, b, c = _compose(left, right)
        n //= 2
    out_ref[0, 0] = a[0]
    out_ref[0, 1] = b[0]
    out_ref[0, 2] = c[0]


def _scan_kernel(is_enq_ref, valid_ref, carry_ref, state_ref,
                 pos_ref, match_ref):
    """Phase B: intra-tile exclusive scan after the tile's carry."""
    A, B, C = _tile_transforms(is_enq_ref[...], valid_ref[...])
    a = A.reshape(-1)
    b = B.reshape(-1)
    c = C.reshape(-1)
    # Hillis-Steele inclusive scan over TILE elems (log2(TILE)=10 steps)
    shift = 1
    while shift < TILE:
        ap = jnp.concatenate([jnp.zeros((shift,), jnp.int32), a[:-shift]])
        bp = jnp.concatenate([jnp.full((shift,), INF, jnp.int32), b[:-shift]])
        cp = jnp.concatenate([jnp.zeros((shift,), jnp.int32), c[:-shift]])
        na, nb, nc = _compose((ap, bp, cp), (a, b, c))
        idx = lax.broadcasted_iota(jnp.int32, (TILE,), 0)
        keep = idx < shift
        a = jnp.where(keep, a, na)
        b = jnp.where(keep, b, nb)
        c = jnp.where(keep, c, nc)
        shift *= 2
    # exclusive = shift by one
    a_x = jnp.concatenate([jnp.zeros((1,), jnp.int32), a[:-1]])
    b_x = jnp.concatenate([jnp.full((1,), INF, jnp.int32), b[:-1]])
    c_x = jnp.concatenate([jnp.zeros((1,), jnp.int32), c[:-1]])
    # prepend the inter-tile carry and the initial anchor state
    ca = carry_ref[0, 0]
    cb = carry_ref[0, 1]
    cc = carry_ref[0, 2]
    a_x, b_x, c_x = _compose((ca, cb, cc), (a_x, b_x, c_x))
    first0 = state_ref[0, 0]
    last0 = state_ref[0, 1]
    f_i = jnp.minimum(first0 + a_x, last0 + b_x)
    l_i = last0 + c_x
    is_enq = (is_enq_ref[...].reshape(-1) != 0)
    vmask = (valid_ref[...].reshape(-1) != 0)
    pos = jnp.where(is_enq, l_i + 1,
                    jnp.where(f_i <= l_i, f_i, jnp.int32(-1)))
    pos = jnp.where(vmask, pos, jnp.int32(-1))
    pos_ref[...] = pos.reshape(1, TILE_ROWS, TILE_LANES)
    match_ref[...] = jnp.where(vmask, (pos >= 0), False).reshape(
        1, TILE_ROWS, TILE_LANES).astype(jnp.int32)


def queue_scan_kernel(is_enq: jax.Array, valid: jax.Array,
                      first: jax.Array, last: jax.Array,
                      interpret: bool = True):
    """n must be a multiple of 1024 (pad with valid=False).

    Returns (pos[n], matched[n], new_first, new_last)."""
    n = is_enq.shape[0]
    assert n % TILE == 0, f"pad request batch to a multiple of {TILE}"
    T = n // TILE
    e2 = is_enq.astype(jnp.int32).reshape(T, TILE_ROWS, TILE_LANES)
    v2 = valid.astype(jnp.int32).reshape(T, TILE_ROWS, TILE_LANES)

    # ---- phase A: per-tile totals ----
    totals = pl.pallas_call(
        _totals_kernel,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, TILE_ROWS, TILE_LANES), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, TILE_ROWS, TILE_LANES), lambda t: (t, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 3), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((T, 3), jnp.int32),
        interpret=interpret,
    )(e2, v2)

    # ---- inter-tile exclusive scan of carries (tiny; jnp) ----
    def comp(x, y):
        return jnp.stack(_compose((x[..., 0], x[..., 1], x[..., 2]),
                                  (y[..., 0], y[..., 1], y[..., 2])), -1)
    incl = lax.associative_scan(comp, totals, axis=0)
    ident = jnp.array([[0, INF, 0]], jnp.int32)
    excl = jnp.concatenate([ident, incl[:-1]], axis=0)
    tot = incl[-1]
    state = jnp.stack([first.astype(jnp.int32),
                       last.astype(jnp.int32)])[None]  # [1, 2]

    # ---- phase B: positions ----
    pos, match = pl.pallas_call(
        _scan_kernel,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, TILE_ROWS, TILE_LANES), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, TILE_ROWS, TILE_LANES), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, 3), lambda t: (t, 0)),
            pl.BlockSpec((1, 2), lambda t: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, TILE_ROWS, TILE_LANES), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, TILE_ROWS, TILE_LANES), lambda t: (t, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, TILE_ROWS, TILE_LANES), jnp.int32),
            jax.ShapeDtypeStruct((T, TILE_ROWS, TILE_LANES), jnp.int32),
        ],
        interpret=interpret,
    )(e2, v2, excl, state)

    new_first = jnp.minimum(first + tot[0], last + tot[1])
    new_last = last + tot[2]
    return (pos.reshape(n), match.reshape(n).astype(bool),
            new_first.astype(jnp.int32), new_last.astype(jnp.int32))
