"""Pallas TPU kernels: SKUEUE batch position-assignment sweeps.

The paper's Stages 1-3 for one device's request array, as a two-phase
Blelloch scan tiled for VMEM:

  phase A (parallel over tiles): per-tile total (A,B,C) transform —
          a pure reduction, one (8,128) VPU tile at a time;
  phase B (parallel over tiles, given the exclusive tile-prefix carries):
          intra-tile Hillis-Steele scan in the min-plus semiring +
          position emission.

The inter-tile exclusive scan of the tiny per-tile carries happens in jnp
between the two pallas_calls (it is O(n/TILE) elements — negligible), which
mirrors the paper's anchor step: the carries ARE the aggregated batches.

Three sweeps share the machinery (one per discipline family):

  * :func:`queue_scan_kernel`  — FIFO min-plus (ENQ/DEQ transforms);
  * :func:`stack_scan_kernel`  — LIFO max-plus (PUSH/POP on (last, ticket));
  * :func:`tiered_queue_scan_kernel` — the fused per-tier sweep: ONE
    pallas_call pair with grid (n_tiers, tiles) replacing n_tiers separate
    masked launches — this is the dispatch arithmetic of the priority
    (tier := SLA class) and Seap (tier := bucket) disciplines; the
    batch-DeleteMin epilogue stays prefix arithmetic on the tiny per-tier
    totals (``core.scan_queue.strict_batch_deletemin``) inside the same
    jitted program.

Layout: requests are reshaped to [T, 8, 128] tiles; the scan order is the
row-major flattened order.  All arithmetic is int32 in VMEM; the MXU is not
involved (these are VPU kernels).  ``interpret=None`` resolves through
``repro.kernels.default_interpret()`` (interpret on CPU, compiled on
TPU/GPU; env override ``REPRO_PALLAS_INTERPRET``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from ..backend import default_interpret

INF = 2 ** 30  # plain int: Pallas kernels need literals, not traced consts
TILE_ROWS = 8
TILE_LANES = 128
TILE = TILE_ROWS * TILE_LANES


# ------------------------------------------------- min-plus (queue) ---------
def _compose(t1, t2):
    A1, B1, C1 = t1
    A2, B2, C2 = t2
    return (A1 + A2,
            jnp.minimum(jnp.minimum(B1 + A2, C1 + B2), INF),
            C1 + C2)


def _tile_transforms(is_enq, valid):
    e = jnp.logical_and(is_enq != 0, valid != 0).astype(jnp.int32)
    v = (valid != 0)
    A = jnp.where(v, 1 - e, 0)
    B = jnp.where(v, jnp.where(e > 0, INF, 1), INF)
    C = jnp.where(v, e, 0)
    return A, B, C


# ------------------------------------------------- max-plus (stack) ---------
def _stack_compose(t1, t2):
    a1, b1, d1 = t1
    a2, b2, d2 = t2
    return (a1 + a2,
            jnp.maximum(jnp.maximum(b1 + a2, b2), -INF),
            d1 + d2)


def _stack_tile_transforms(is_push, valid):
    e = jnp.logical_and(is_push != 0, valid != 0).astype(jnp.int32)
    v = (valid != 0)
    a = jnp.where(v, 2 * e - 1, 0)
    b = jnp.where(v, jnp.where(e > 0, -INF, 0), -INF)
    d = jnp.where(v, e, 0)
    return a, b, d


# ---------------------------------------------------- shared scan bodies ----
def _tree_reduce(compose, flat):
    """Log-step tree reduction over a flattened tile.  The tropical compose
    is non-commutative: pair ADJACENT elements (2i, 2i+1) at every level so
    the reduction respects the left-to-right request order."""
    n = TILE
    a, b, c = flat
    while n > 1:
        left = (a[0:n:2], b[0:n:2], c[0:n:2])
        right = (a[1:n:2], b[1:n:2], c[1:n:2])
        a, b, c = compose(left, right)
        n //= 2
    return a, b, c


def _hillis_steele_exclusive(compose, fills, tr):
    """Intra-tile exclusive scan (log2(TILE) Hillis-Steele steps + shift)."""
    a, b, c = tr
    f_a, f_b, f_c = fills
    shift = 1
    while shift < TILE:
        ap = jnp.concatenate([jnp.full((shift,), f_a, jnp.int32), a[:-shift]])
        bp = jnp.concatenate([jnp.full((shift,), f_b, jnp.int32), b[:-shift]])
        cp = jnp.concatenate([jnp.full((shift,), f_c, jnp.int32), c[:-shift]])
        na, nb, nc = compose((ap, bp, cp), (a, b, c))
        idx = lax.broadcasted_iota(jnp.int32, (TILE,), 0)
        keep = idx < shift
        a = jnp.where(keep, a, na)
        b = jnp.where(keep, b, nb)
        c = jnp.where(keep, c, nc)
        shift *= 2
    a_x = jnp.concatenate([jnp.full((1,), f_a, jnp.int32), a[:-1]])
    b_x = jnp.concatenate([jnp.full((1,), f_b, jnp.int32), b[:-1]])
    c_x = jnp.concatenate([jnp.full((1,), f_c, jnp.int32), c[:-1]])
    return a_x, b_x, c_x


def _totals_kernel(is_enq_ref, valid_ref, out_ref):
    """Phase A: reduce one [8,128] tile to its total (A,B,C)."""
    A, B, C = _tile_transforms(is_enq_ref[...], valid_ref[...])
    a, b, c = _tree_reduce(
        _compose, (A.reshape(-1), B.reshape(-1), C.reshape(-1)))
    out_ref[0, 0] = a[0]
    out_ref[0, 1] = b[0]
    out_ref[0, 2] = c[0]


def _scan_kernel(is_enq_ref, valid_ref, carry_ref, state_ref,
                 pos_ref, match_ref):
    """Phase B: intra-tile exclusive scan after the tile's carry."""
    A, B, C = _tile_transforms(is_enq_ref[...], valid_ref[...])
    a_x, b_x, c_x = _hillis_steele_exclusive(
        _compose, (0, INF, 0),
        (A.reshape(-1), B.reshape(-1), C.reshape(-1)))
    # prepend the inter-tile carry and the initial anchor state
    ca = carry_ref[0, 0]
    cb = carry_ref[0, 1]
    cc = carry_ref[0, 2]
    a_x, b_x, c_x = _compose((ca, cb, cc), (a_x, b_x, c_x))
    first0 = state_ref[0, 0]
    last0 = state_ref[0, 1]
    f_i = jnp.minimum(first0 + a_x, last0 + b_x)
    l_i = last0 + c_x
    is_enq = (is_enq_ref[...].reshape(-1) != 0)
    vmask = (valid_ref[...].reshape(-1) != 0)
    pos = jnp.where(is_enq, l_i + 1,
                    jnp.where(f_i <= l_i, f_i, jnp.int32(-1)))
    pos = jnp.where(vmask, pos, jnp.int32(-1))
    pos_ref[...] = pos.reshape(1, TILE_ROWS, TILE_LANES)
    match_ref[...] = jnp.where(vmask, (pos >= 0), False).reshape(
        1, TILE_ROWS, TILE_LANES).astype(jnp.int32)


def _stack_totals_kernel(is_push_ref, valid_ref, out_ref):
    """Phase A (max-plus): reduce one tile to its total (a, b, dt)."""
    a, b, d = _stack_tile_transforms(is_push_ref[...], valid_ref[...])
    a, b, d = _tree_reduce(
        _stack_compose, (a.reshape(-1), b.reshape(-1), d.reshape(-1)))
    out_ref[0, 0] = a[0]
    out_ref[0, 1] = b[0]
    out_ref[0, 2] = d[0]


def _stack_scan_kernel(is_push_ref, valid_ref, carry_ref, state_ref,
                       pos_ref, tick_ref):
    """Phase B (max-plus): positions + tickets after the tile's carry."""
    a, b, d = _stack_tile_transforms(is_push_ref[...], valid_ref[...])
    a_x, b_x, d_x = _hillis_steele_exclusive(
        _stack_compose, (0, -INF, 0),
        (a.reshape(-1), b.reshape(-1), d.reshape(-1)))
    ca = carry_ref[0, 0]
    cb = carry_ref[0, 1]
    cd = carry_ref[0, 2]
    a_x, b_x, d_x = _stack_compose((ca, cb, cd), (a_x, b_x, d_x))
    last0 = state_ref[0, 0]
    tick0 = state_ref[0, 1]
    l_i = jnp.maximum(last0 + a_x, b_x)
    t_i = tick0 + d_x
    is_push = (is_push_ref[...].reshape(-1) != 0)
    vmask = (valid_ref[...].reshape(-1) != 0)
    pos = jnp.where(is_push, l_i + 1,
                    jnp.where(l_i >= 1, l_i, jnp.int32(-1)))
    pos = jnp.where(vmask, pos, jnp.int32(-1))
    tick = jnp.where(is_push, t_i + 1, t_i)
    pos_ref[...] = pos.reshape(1, TILE_ROWS, TILE_LANES)
    tick_ref[...] = tick.reshape(1, TILE_ROWS, TILE_LANES)


def _tiered_totals_kernel(tier_ref, enq_ref, out_ref):
    """Phase A over grid (tier, tile): totals of THIS tier's enqueue mask."""
    p = pl.program_id(0)
    mask = jnp.logical_and(enq_ref[...] != 0,
                           tier_ref[...] == p).astype(jnp.int32)
    A, B, C = _tile_transforms(mask, mask)
    a, b, c = _tree_reduce(
        _compose, (A.reshape(-1), B.reshape(-1), C.reshape(-1)))
    out_ref[0, 0, 0] = a[0]
    out_ref[0, 0, 1] = b[0]
    out_ref[0, 0, 2] = c[0]


def _tiered_scan_kernel(tier_ref, enq_ref, carry_ref, state_ref, pos_ref):
    """Phase B over grid (tier, tile): per-tier enqueue positions."""
    p = pl.program_id(0)
    mask32 = jnp.logical_and(enq_ref[...] != 0,
                             tier_ref[...] == p).astype(jnp.int32)
    A, B, C = _tile_transforms(mask32, mask32)
    a_x, b_x, c_x = _hillis_steele_exclusive(
        _compose, (0, INF, 0),
        (A.reshape(-1), B.reshape(-1), C.reshape(-1)))
    ca = carry_ref[0, 0, 0]
    cb = carry_ref[0, 0, 1]
    cc = carry_ref[0, 0, 2]
    a_x, b_x, c_x = _compose((ca, cb, cc), (a_x, b_x, c_x))
    last0 = state_ref[0, 1]
    l_i = last0 + c_x
    mask = (mask32.reshape(-1) != 0)
    pos_ref[...] = jnp.where(mask, l_i + 1, jnp.int32(-1)).reshape(
        1, 1, TILE_ROWS, TILE_LANES)


# -------------------------------------------------------- entry points ------
def _carry_scan(compose, totals, ident_row, axis=0):
    """Inter-tile exclusive scan of the tiny per-tile carries (jnp)."""
    def comp(x, y):
        return jnp.stack(compose((x[..., 0], x[..., 1], x[..., 2]),
                                 (y[..., 0], y[..., 1], y[..., 2])), -1)
    incl = lax.associative_scan(comp, totals, axis=axis)
    ident = jnp.broadcast_to(
        jnp.asarray(ident_row, jnp.int32),
        totals.shape[:axis] + (1,) + totals.shape[axis + 1:])
    excl = lax.concatenate(
        [ident, lax.slice_in_dim(incl, 0, totals.shape[axis] - 1, axis=axis)],
        axis)
    tot = lax.index_in_dim(incl, totals.shape[axis] - 1, axis=axis,
                           keepdims=False)
    return excl, tot


def queue_scan_kernel(is_enq: jax.Array, valid: jax.Array,
                      first: jax.Array, last: jax.Array,
                      interpret: bool | None = None):
    """n must be a multiple of 1024 (pad with valid=False).

    Returns (pos[n], matched[n], new_first, new_last)."""
    if interpret is None:
        interpret = default_interpret()
    n = is_enq.shape[0]
    assert n % TILE == 0, f"pad request batch to a multiple of {TILE}"
    T = n // TILE
    e2 = is_enq.astype(jnp.int32).reshape(T, TILE_ROWS, TILE_LANES)
    v2 = valid.astype(jnp.int32).reshape(T, TILE_ROWS, TILE_LANES)

    # ---- phase A: per-tile totals ----
    totals = pl.pallas_call(
        _totals_kernel,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, TILE_ROWS, TILE_LANES), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, TILE_ROWS, TILE_LANES), lambda t: (t, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 3), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((T, 3), jnp.int32),
        interpret=interpret,
    )(e2, v2)

    # ---- inter-tile exclusive scan of carries (tiny; jnp) ----
    excl, tot = _carry_scan(_compose, totals, [0, INF, 0])
    state = jnp.stack([first.astype(jnp.int32),
                       last.astype(jnp.int32)])[None]  # [1, 2]

    # ---- phase B: positions ----
    pos, match = pl.pallas_call(
        _scan_kernel,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, TILE_ROWS, TILE_LANES), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, TILE_ROWS, TILE_LANES), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, 3), lambda t: (t, 0)),
            pl.BlockSpec((1, 2), lambda t: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, TILE_ROWS, TILE_LANES), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, TILE_ROWS, TILE_LANES), lambda t: (t, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, TILE_ROWS, TILE_LANES), jnp.int32),
            jax.ShapeDtypeStruct((T, TILE_ROWS, TILE_LANES), jnp.int32),
        ],
        interpret=interpret,
    )(e2, v2, excl, state)

    new_first = jnp.minimum(first + tot[0], last + tot[1])
    new_last = last + tot[2]
    return (pos.reshape(n), match.reshape(n).astype(bool),
            new_first.astype(jnp.int32), new_last.astype(jnp.int32))


def stack_scan_kernel(is_push: jax.Array, valid: jax.Array,
                      last: jax.Array, ticket: jax.Array,
                      interpret: bool | None = None):
    """Max-plus LIFO sweep.  n must be a multiple of 1024.

    Returns (pos[n], tick[n], new_last, new_ticket) with the exact
    semantics of ``core.scan_queue.stack_scan``: for pushes ``tick`` is
    the element's unique ticket, for pops the max-ticket bound."""
    if interpret is None:
        interpret = default_interpret()
    n = is_push.shape[0]
    assert n % TILE == 0, f"pad request batch to a multiple of {TILE}"
    T = n // TILE
    e2 = is_push.astype(jnp.int32).reshape(T, TILE_ROWS, TILE_LANES)
    v2 = valid.astype(jnp.int32).reshape(T, TILE_ROWS, TILE_LANES)

    totals = pl.pallas_call(
        _stack_totals_kernel,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, TILE_ROWS, TILE_LANES), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, TILE_ROWS, TILE_LANES), lambda t: (t, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 3), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((T, 3), jnp.int32),
        interpret=interpret,
    )(e2, v2)

    excl, tot = _carry_scan(_stack_compose, totals, [0, -INF, 0])
    state = jnp.stack([last.astype(jnp.int32),
                       ticket.astype(jnp.int32)])[None]  # [1, 2]

    pos, tick = pl.pallas_call(
        _stack_scan_kernel,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, TILE_ROWS, TILE_LANES), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, TILE_ROWS, TILE_LANES), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, 3), lambda t: (t, 0)),
            pl.BlockSpec((1, 2), lambda t: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, TILE_ROWS, TILE_LANES), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, TILE_ROWS, TILE_LANES), lambda t: (t, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, TILE_ROWS, TILE_LANES), jnp.int32),
            jax.ShapeDtypeStruct((T, TILE_ROWS, TILE_LANES), jnp.int32),
        ],
        interpret=interpret,
    )(e2, v2, excl, state)

    new_last = jnp.maximum(last + tot[0], tot[1])
    new_ticket = ticket + tot[2]
    return (pos.reshape(n), tick.reshape(n),
            new_last.astype(jnp.int32), new_ticket.astype(jnp.int32))


def tiered_queue_scan_kernel(tier: jax.Array, enq: jax.Array,
                             firsts: jax.Array, lasts: jax.Array,
                             n_tiers: int,
                             interpret: bool | None = None):
    """The fused per-tier enqueue sweep: grid (n_tiers, tiles), ONE
    pallas_call pair total — versus n_tiers separate masked launches.

    tier: [n] int32 (the element's tier/bucket; dequeues may carry any
    value — gate with ``enq``); enq: [n] bool, the masked enqueue ops.
    n must be a multiple of 1024.  Returns (pos_all [n_tiers, n] int32
    with -1 off-tier, new_lasts [n_tiers]); firsts are unchanged by an
    enqueue-only sweep."""
    if interpret is None:
        interpret = default_interpret()
    P_ = n_tiers
    n = enq.shape[0]
    assert n % TILE == 0, f"pad request batch to a multiple of {TILE}"
    T = n // TILE
    t2 = tier.astype(jnp.int32).reshape(T, TILE_ROWS, TILE_LANES)
    e2 = enq.astype(jnp.int32).reshape(T, TILE_ROWS, TILE_LANES)

    totals = pl.pallas_call(
        _tiered_totals_kernel,
        grid=(P_, T),
        in_specs=[
            pl.BlockSpec((1, TILE_ROWS, TILE_LANES), lambda p, t: (t, 0, 0)),
            pl.BlockSpec((1, TILE_ROWS, TILE_LANES), lambda p, t: (t, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 3), lambda p, t: (p, t, 0)),
        out_shape=jax.ShapeDtypeStruct((P_, T, 3), jnp.int32),
        interpret=interpret,
    )(t2, e2)

    excl, tot = _carry_scan(_compose, totals, [0, INF, 0], axis=1)
    state = jnp.stack([firsts.astype(jnp.int32),
                       lasts.astype(jnp.int32)], axis=-1)  # [P, 2]

    pos_all = pl.pallas_call(
        _tiered_scan_kernel,
        grid=(P_, T),
        in_specs=[
            pl.BlockSpec((1, TILE_ROWS, TILE_LANES), lambda p, t: (t, 0, 0)),
            pl.BlockSpec((1, TILE_ROWS, TILE_LANES), lambda p, t: (t, 0, 0)),
            pl.BlockSpec((1, 1, 3), lambda p, t: (p, t, 0)),
            pl.BlockSpec((1, 2), lambda p, t: (p, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, TILE_ROWS, TILE_LANES),
                               lambda p, t: (p, t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((P_, T, TILE_ROWS, TILE_LANES),
                                       jnp.int32),
        interpret=interpret,
    )(t2, e2, excl, state)

    new_lasts = lasts + tot[:, 2]
    return pos_all.reshape(P_, n), new_lasts.astype(jnp.int32)
