from .ops import (make_tier_scan, priority_queue_scan_pallas,
                  queue_scan_pallas, stack_scan_pallas,
                  tiered_queue_scan_pallas)
from .ref import queue_scan_ref

__all__ = ["make_tier_scan", "priority_queue_scan_pallas",
           "queue_scan_pallas", "queue_scan_ref", "stack_scan_pallas",
           "tiered_queue_scan_pallas"]
