from .ops import queue_scan_pallas
from .ref import queue_scan_ref

__all__ = ["queue_scan_pallas", "queue_scan_ref"]
