from .ops import priority_queue_scan_pallas, queue_scan_pallas
from .ref import queue_scan_ref

__all__ = ["priority_queue_scan_pallas", "queue_scan_pallas",
           "queue_scan_ref"]
