"""Pure-jnp oracle for the SKUEUE batch scan kernel.

Delegates to the framework implementation (itself hypothesis-validated
against the paper's Stage-2/3 interval machinery in tests/test_scan_queue.py)
so the kernel is checked against the exact protocol semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.scan_queue import QueueState, queue_scan


def queue_scan_ref(is_enq: jax.Array, valid: jax.Array, first: jax.Array,
                   last: jax.Array):
    """Returns (positions[n] int32 with ⊥=-1, matched[n] bool,
    new_first, new_last)."""
    pos, matched, new = queue_scan(
        is_enq.astype(bool), QueueState(first.astype(jnp.int32),
                                        last.astype(jnp.int32)),
        valid=valid.astype(bool))
    return pos, matched, new.first, new.last
