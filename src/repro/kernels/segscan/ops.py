"""Jit'd public wrapper for the segscan kernel (auto-padding, dtypes)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import TILE, queue_scan_kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def queue_scan_pallas(is_enq: jax.Array, valid: jax.Array,
                      first: jax.Array, last: jax.Array,
                      interpret: bool = True):
    """Position assignment for a request batch (SKUEUE Stages 1-3).

    is_enq/valid: [n] bool.  Returns (pos[n] int32 ⊥=-1, matched[n] bool,
    new_first, new_last).  n is padded internally to a multiple of 1024.
    """
    n = is_enq.shape[0]
    pad = (-n) % TILE
    if pad:
        is_enq = jnp.concatenate([is_enq, jnp.zeros((pad,), is_enq.dtype)])
        valid = jnp.concatenate([valid, jnp.zeros((pad,), valid.dtype)])
    pos, matched, nf, nl = queue_scan_kernel(
        is_enq, valid, jnp.asarray(first), jnp.asarray(last),
        interpret=interpret)
    return pos[:n], matched[:n], nf, nl


@functools.partial(jax.jit, static_argnames=("n_prios", "interpret"))
def priority_queue_scan_pallas(is_enq: jax.Array, prio: jax.Array,
                               valid: jax.Array, firsts: jax.Array,
                               lasts: jax.Array, n_prios: int,
                               interpret: bool = True):
    """P-tier priority position assignment (strict mode) on the pallas path.

    The per-tier enqueue scans — the O(n) part — run through
    :func:`queue_scan_pallas` (one masked kernel invocation per tier; P is
    a small static constant), and the wave's dequeues are then resolved
    highest-priority-first by the batch-drain prefix arithmetic of
    ``core.scan_queue.priority_queue_scan`` on the tiny per-tier totals.

    is_enq/valid: [n] bool; prio: [n] int32; firsts/lasts: [n_prios] int32.
    Returns (tier [n] int32 (-1 unmatched), pos [n] int32 (⊥ = -1),
    matched [n] bool, new_firsts, new_lasts).
    """
    from ...core.scan_queue import strict_batch_deletemin
    enq = is_enq & valid
    deq = (~is_enq) & valid
    tier = jnp.full(is_enq.shape, -1, jnp.int32)
    pos = jnp.full(is_enq.shape, -1, jnp.int32)
    new_lasts = []
    for p in range(n_prios):
        mask = enq & (prio == p)
        pos_p, _, _, nl_p = queue_scan_pallas(mask, mask, firsts[p],
                                              lasts[p], interpret=interpret)
        tier = jnp.where(mask, p, tier)
        pos = jnp.where(mask, pos_p, pos)
        new_lasts.append(nl_p)
    new_lasts = jnp.stack(new_lasts)
    avail = new_lasts - firsts + 1
    # the dequeue resolution is the SAME batch-DeleteMin prefix arithmetic
    # the core scan uses — one copy, shared (PR 4)
    t_c, pos_d, d_matched, taken = strict_batch_deletemin(
        deq, avail, firsts, n_prios)
    tier = jnp.where(d_matched, t_c, tier)
    pos = jnp.where(d_matched, pos_d, pos)
    matched = enq | d_matched
    return tier, pos, matched, firsts + taken, new_lasts
