"""Jit'd public wrappers for the segscan kernels (auto-padding, dtypes).

``interpret=None`` (the default everywhere) resolves through
``repro.kernels.default_interpret()``: interpret mode on CPU, compiled on
TPU/GPU, overridable with ``REPRO_PALLAS_INTERPRET`` (docs/OPERATIONS.md).
``core/scan_queue`` stays the pure-jnp differential oracle for every
function here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..backend import default_interpret
from .kernel import (TILE, queue_scan_kernel, stack_scan_kernel,
                     tiered_queue_scan_kernel)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _queue_scan_pallas(is_enq, valid, first, last, interpret):
    n = is_enq.shape[0]
    pad = (-n) % TILE
    if pad:
        is_enq = jnp.concatenate([is_enq, jnp.zeros((pad,), is_enq.dtype)])
        valid = jnp.concatenate([valid, jnp.zeros((pad,), valid.dtype)])
    pos, matched, nf, nl = queue_scan_kernel(
        is_enq, valid, jnp.asarray(first), jnp.asarray(last),
        interpret=interpret)
    return pos[:n], matched[:n], nf, nl


def queue_scan_pallas(is_enq: jax.Array, valid: jax.Array,
                      first: jax.Array, last: jax.Array,
                      interpret: bool | None = None):
    """Position assignment for a request batch (SKUEUE Stages 1-3).

    is_enq/valid: [n] bool.  Returns (pos[n] int32 ⊥=-1, matched[n] bool,
    new_first, new_last).  n is padded internally to a multiple of 1024.
    """
    if interpret is None:
        interpret = default_interpret()
    return _queue_scan_pallas(is_enq, valid, first, last, interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _stack_scan_pallas(is_push, valid, last, ticket, interpret):
    n = is_push.shape[0]
    pad = (-n) % TILE
    if pad:
        is_push = jnp.concatenate([is_push, jnp.zeros((pad,), is_push.dtype)])
        valid = jnp.concatenate([valid, jnp.zeros((pad,), valid.dtype)])
    pos, tick, nl, nt = stack_scan_kernel(
        is_push, valid, jnp.asarray(last), jnp.asarray(ticket),
        interpret=interpret)
    pos, tick = pos[:n], tick[:n]
    return pos, tick, pos != -1, nl, nt


def stack_scan_pallas(is_push: jax.Array, valid: jax.Array,
                      last: jax.Array, ticket: jax.Array,
                      interpret: bool | None = None):
    """Max-plus LIFO position assignment (the stack analogue, Sec. VI).

    is_push/valid: [n] bool; last/ticket: int32 scalars.  Returns
    (pos[n] int32 ⊥=-1, tick[n] int32, matched[n] bool, new_last,
    new_ticket) — bit-identical to ``core.scan_queue.stack_scan``.
    """
    if interpret is None:
        interpret = default_interpret()
    return _stack_scan_pallas(is_push, valid, last, ticket, interpret)


@functools.partial(jax.jit, static_argnames=("n_tiers", "interpret"))
def _tiered_queue_scan_pallas(enq, tier, firsts, lasts, n_tiers, interpret):
    n = enq.shape[0]
    pad = (-n) % TILE
    if pad:
        enq = jnp.concatenate([enq, jnp.zeros((pad,), enq.dtype)])
        tier = jnp.concatenate([tier, jnp.zeros((pad,), tier.dtype)])
    pos_all, new_lasts = tiered_queue_scan_kernel(
        tier, enq, firsts, lasts, n_tiers, interpret=interpret)
    t_c = jnp.clip(tier[:n].astype(jnp.int32), 0, n_tiers - 1)
    pos = jnp.take_along_axis(pos_all[:, :n], t_c[None, :], axis=0)[0]
    return jnp.where(enq[:n] != 0, pos, jnp.int32(-1)), new_lasts


def tiered_queue_scan_pallas(enq: jax.Array, tier: jax.Array,
                             firsts: jax.Array, lasts: jax.Array,
                             n_tiers: int,
                             interpret: bool | None = None):
    """Fused per-tier enqueue sweep: ONE kernel pair over grid
    (n_tiers, tiles), replacing n_tiers separate masked launches.

    enq: [n] bool (the wave's valid enqueues); tier: [n] int32 (tier or
    Seap bucket per op; out-of-range tiers assign no position).  Returns
    (pos[n] int32 ⊥=-1, new_lasts[n_tiers]); an enqueue-only sweep never
    moves ``firsts``.  This is the ``tier_scan`` hook consumed by
    ``core.scan_queue.priority_queue_scan`` / ``seap_queue_scan``.
    """
    if interpret is None:
        interpret = default_interpret()
    return _tiered_queue_scan_pallas(enq, tier, firsts, lasts, n_tiers,
                                     interpret)


def make_tier_scan(n_tiers: int, interpret: bool | None = None):
    """Bind :func:`tiered_queue_scan_pallas` to the 4-arg ``tier_scan``
    hook signature the core scans accept."""
    def tier_scan(enq, tier, firsts, lasts):
        return tiered_queue_scan_pallas(enq, tier, firsts, lasts,
                                        n_tiers=n_tiers, interpret=interpret)
    return tier_scan


def priority_queue_scan_pallas(is_enq: jax.Array, prio: jax.Array,
                               valid: jax.Array, firsts: jax.Array,
                               lasts: jax.Array, n_prios: int,
                               interpret: bool | None = None):
    """P-tier priority position assignment (strict mode) on the pallas path.

    The per-tier enqueue scans — the O(n) part — are ONE fused
    :func:`tiered_queue_scan_pallas` sweep (grid (P, tiles); PR 9 — this
    used to be P separate masked kernel launches), and the wave's
    dequeues are then resolved highest-priority-first by the batch-drain
    prefix arithmetic of ``core.scan_queue.strict_batch_deletemin`` on
    the tiny per-tier totals, fused into the same jitted program.

    is_enq/valid: [n] bool; prio: [n] int32; firsts/lasts: [n_prios] int32.
    Returns (tier [n] int32 (-1 unmatched), pos [n] int32 (⊥ = -1),
    matched [n] bool, new_firsts, new_lasts).
    """
    from ...core.scan_queue import strict_batch_deletemin
    if interpret is None:
        interpret = default_interpret()
    enq = is_enq & valid
    deq = (~is_enq) & valid
    pos_e, new_lasts = tiered_queue_scan_pallas(
        enq, prio, firsts, lasts, n_tiers=n_prios, interpret=interpret)
    tier = jnp.where(enq & (pos_e >= 0), prio.astype(jnp.int32), -1)
    pos = jnp.where(enq, pos_e, jnp.int32(-1))
    avail = new_lasts - firsts + 1
    # the dequeue resolution is the SAME batch-DeleteMin prefix arithmetic
    # the core scan uses — one copy, shared (PR 4)
    t_c, pos_d, d_matched, taken = strict_batch_deletemin(
        deq, avail, firsts, n_prios)
    tier = jnp.where(d_matched, t_c, tier)
    pos = jnp.where(d_matched, pos_d, pos)
    matched = enq | d_matched
    return tier, pos, matched, firsts + taken, new_lasts
