"""Jit'd public wrapper for the segscan kernel (auto-padding, dtypes)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import TILE, queue_scan_kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def queue_scan_pallas(is_enq: jax.Array, valid: jax.Array,
                      first: jax.Array, last: jax.Array,
                      interpret: bool = True):
    """Position assignment for a request batch (SKUEUE Stages 1-3).

    is_enq/valid: [n] bool.  Returns (pos[n] int32 ⊥=-1, matched[n] bool,
    new_first, new_last).  n is padded internally to a multiple of 1024.
    """
    n = is_enq.shape[0]
    pad = (-n) % TILE
    if pad:
        is_enq = jnp.concatenate([is_enq, jnp.zeros((pad,), is_enq.dtype)])
        valid = jnp.concatenate([valid, jnp.zeros((pad,), valid.dtype)])
    pos, matched, nf, nl = queue_scan_kernel(
        is_enq, valid, jnp.asarray(first), jnp.asarray(last),
        interpret=interpret)
    return pos[:n], matched[:n], nf, nl
