"""Pallas execution-mode selection shared by every kernel package.

Every ``kernels/*/ops.py`` wrapper takes ``interpret: bool | None = None``
and resolves ``None`` through :func:`default_interpret`:

  * on CPU (the CI mesh, laptops) pallas has no compiled lowering worth
    using — kernels run in interpret mode, which is plain traced jax and
    therefore exact but slow;
  * on TPU/GPU the kernels compile for real and ``interpret=False`` is
    the right default.

The env var ``REPRO_PALLAS_INTERPRET`` overrides the autodetect in both
directions (``1``/``true`` forces interpret mode everywhere, ``0``/
``false`` forces compiled mode even on CPU — useful for debugging a
lowering, and for CI legs that want to pin one mode).  See
docs/OPERATIONS.md ("Pallas execution mode").

:func:`use_fused_dispatch` is the wave-path gate built on the same
detection: the disciplines route their dispatch arithmetic (per-tier
masked min-plus scans, the max-plus stack scan) through the fused
``kernels/segscan`` pallas sweep ONLY where that sweep actually compiles
— on CPU the ``core/scan_queue`` jnp path is both the oracle and the
fastest implementation, so interpret-mode pallas is never put on the hot
path implicitly.
"""
from __future__ import annotations

import os

_TRUE = frozenset({"1", "true", "yes", "on"})
_FALSE = frozenset({"0", "false", "no", "off"})


def default_interpret() -> bool:
    """Pallas ``interpret`` default: True iff running on CPU.

    ``REPRO_PALLAS_INTERPRET=1|0`` overrides the backend autodetect.
    Read at trace time — flipping the env var mid-process only affects
    traces that have not been cached yet.
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "").strip().lower()
    if env in _TRUE:
        return True
    if env in _FALSE:
        return False
    import jax
    return jax.default_backend() == "cpu"


def use_fused_dispatch() -> bool:
    """True when wave dispatch should ride the compiled pallas sweep.

    Follows :func:`default_interpret` inverted: compiled backends get the
    fused kernel, CPU keeps the ``core/scan_queue`` jnp path (which would
    otherwise run the pallas sweep in interpret mode — strictly slower
    than the code it replaces).  ``REPRO_PALLAS_INTERPRET=0`` therefore
    also force-enables fused dispatch on CPU; the differential tests
    instead pin ``fused_dispatch=True`` per queue instance, which runs
    the sweep in interpret mode inside the wave — slow, but bit-exact.
    """
    return not default_interpret()
