"""Mamba-2 block (SSD, arXiv:2405.21060) — chunked jnp path + Pallas option.

Block: in_proj -> [z | xBC | dt]; short causal depthwise conv on xBC; SSD
scan over heads; gated RMSNorm(y, z); out_proj.  The SSD scan itself is the
chunked block decomposition (same math as kernels/ssd_scan; that kernel is
the TPU fast path, this jnp version is what the dry-run lowers).

Decode is O(1): the recurrent state [H, N, P] plus a (K-1)-deep conv tail
replace the KV cache entirely — this is why mamba2/zamba2 run long_500k.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .costing import scan as cscan
from .layers import _dense_init, rms_norm


def init_mamba2(key, cfg):
    d = cfg.d_model
    di = cfg.d_inner
    H = cfg.ssm_heads
    N = cfg.ssm_state
    G = cfg.ssm_groups
    K = cfg.conv_kernel
    conv_dim = di + 2 * G * N
    proj_out = 2 * di + 2 * G * N + H   # z, x, B, C, dt
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["in_proj"], a["in_proj"] = _dense_init(ks[0], (d, proj_out),
                                             ("embed", "ssm_inner"))
    p["conv_w"], a["conv_w"] = _dense_init(ks[1], (K, conv_dim),
                                           (None, "ssm_inner"), scale=0.5)
    p["A_log"], a["A_log"] = (jnp.zeros((H,), jnp.float32), (None,))
    p["D"], a["D"] = (jnp.ones((H,), jnp.float32), (None,))
    p["dt_bias"], a["dt_bias"] = (jnp.zeros((H,), jnp.float32), (None,))
    p["norm_w"], a["norm_w"] = (jnp.ones((di,), jnp.bfloat16), ("ssm_inner",))
    p["out_proj"], a["out_proj"] = _dense_init(ks[2], (di, d),
                                               ("ssm_inner", "embed"))
    return p, a


def _ssd_chunked(xt, loga, B, C, chunk=128):
    """xt: [b, L, H, P]; loga: [b, L, H]; B/C: [b, L, G, N] (G=1 broadcast).
    Chunked scan over L with lax.scan across chunks."""
    b, L, H, P = xt.shape
    N = B.shape[-1]
    Q = min(chunk, L)
    while L % Q:
        Q //= 2
    nc = L // Q
    xt_c = xt.reshape(b, nc, Q, H, P).astype(jnp.float32)
    la_c = loga.reshape(b, nc, Q, H).astype(jnp.float32)
    B_c = B.reshape(b, nc, Q, -1, N).astype(jnp.float32)
    C_c = C.reshape(b, nc, Q, -1, N).astype(jnp.float32)

    def chunk_step(S, inp):
        xq, lq, bq, cq = inp            # [b,Q,H,P], [b,Q,H], [b,Q,G,N]
        l = jnp.cumsum(lq, axis=1)       # [b,Q,H]
        bqh = jnp.broadcast_to(bq[:, :, :1], (b, Q, 1, N))[:, :, 0]
        cqh = jnp.broadcast_to(cq[:, :, :1], (b, Q, 1, N))[:, :, 0]
        # inter-chunk
        y_inter = jnp.einsum("bqn,bhnp,bqh->bqhp", cqh, S, jnp.exp(l))
        # intra-chunk
        scores = jnp.einsum("bqn,btn->bqt", cqh, bqh)
        dec = jnp.exp(l[:, :, None] - l[:, None])        # [b,q,t,H]
        ii = jnp.arange(Q)
        mask = (ii[:, None] >= ii[None, :])[None, :, :, None]
        w = scores[..., None] * jnp.where(mask, dec, 0.0)
        y_intra = jnp.einsum("bqth,bthp->bqhp", w, xq)
        # state update
        ltot = l[:, -1]                                   # [b,H]
        bdec = jnp.einsum("btn,bth->bthn", bqh,
                          jnp.exp(ltot[:, None] - l))
        S_new = jnp.exp(ltot)[:, :, None, None] * S + \
            jnp.einsum("bthn,bthp->bhnp", bdec, xq)
        return S_new, y_inter + y_intra

    S0 = jnp.zeros((b, H, N, P), jnp.float32)
    inp = (xt_c.transpose(1, 0, 2, 3, 4), la_c.transpose(1, 0, 2, 3),
           B_c.transpose(1, 0, 2, 3, 4), C_c.transpose(1, 0, 2, 3, 4))
    S_fin, y = cscan(chunk_step, S0, inp)
    y = y.transpose(1, 0, 2, 3, 4).reshape(b, L, H, P)
    return y.astype(xt.dtype), S_fin


def _split_proj(cfg, zxbcdt):
    di, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di: di + di + 2 * G * N]
    dt = zxbcdt[..., -H:]
    return z, xBC, dt


def mamba2_block(p, x, cfg, state: Optional[dict] = None):
    """x: [B, S, d].  Returns (y, new_state | None).

    state (decode): {"ssm": [B,H,N,P] f32, "conv": [B,K-1,conv_dim]}."""
    Bsz, S, d = x.shape
    di, H, N, G, K = (cfg.d_inner, cfg.ssm_heads, cfg.ssm_state,
                      cfg.ssm_groups, cfg.conv_kernel)
    P = cfg.ssm_headdim
    zxbcdt = x @ p["in_proj"]
    z, xBC, dt = _split_proj(cfg, zxbcdt)

    new_state = None
    if state is None:
        # causal depthwise conv over sequence
        pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
        conv = sum(pad[:, i: i + S] * p["conv_w"][i].astype(x.dtype)[None, None]
                   for i in range(K))
        xBC = jax.nn.silu(conv)
    else:
        tail = state["conv"]                      # [B, K-1, conv_dim]
        win = jnp.concatenate([tail, xBC], axis=1)  # [B, K, conv] (S==1)
        conv = jnp.einsum("bkc,kc->bc", win.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32))[:, None]
        xBC = jax.nn.silu(conv.astype(x.dtype))
        new_conv = win[:, 1:]

    xpart = xBC[..., :di].reshape(Bsz, S, H, P)
    Bmat = xBC[..., di: di + G * N].reshape(Bsz, S, G, N)
    Cmat = xBC[..., di + G * N:].reshape(Bsz, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None])  # [B,S,H]
    loga = -jnp.exp(p["A_log"])[None, None] * dt
    xt = xpart.astype(jnp.float32) * dt[..., None]

    if state is None:
        y, _ = _ssd_chunked(xt, loga, Bmat, Cmat)
    else:
        S_prev = state["ssm"]                      # [B,H,N,P]
        b1 = Bmat[:, 0, 0]                         # [B,N]  (G=1)
        c1 = Cmat[:, 0, 0]
        a1 = jnp.exp(loga[:, 0])                   # [B,H]
        S_new = a1[:, :, None, None] * S_prev + \
            jnp.einsum("bn,bhp->bhnp", b1.astype(jnp.float32), xt[:, 0])
        y = jnp.einsum("bn,bhnp->bhp", c1.astype(jnp.float32), S_new)[:, None]
        new_state = {"ssm": S_new, "conv": new_conv}
        y = y.astype(x.dtype)

    y = y + p["D"].astype(x.dtype)[None, None, :, None] * xpart
    y = y.reshape(Bsz, S, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(x.dtype)), p["norm_w"],
                 cfg.norm_eps)
    return (y @ p["out_proj"]).astype(x.dtype), new_state


def init_mamba_state(cfg, batch, dtype=jnp.bfloat16):
    H, N, P = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_headdim
    conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "ssm": jnp.zeros((batch, H, N, P), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim), dtype),
    }
