"""Whisper-style encoder-decoder (audio frontend stubbed).

Encoder: bidirectional attention over precomputed frame embeddings
(``input_specs`` supplies [B, enc_seq, d] — the conv stem is a stub per the
assignment).  Decoder: causal self-attention + cross-attention to the
encoder output.  Sinusoidal positions, scan-over-layers, remat.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..sharding import constraint
from .costing import scan as cscan
from . import layers as L


def _init_enc_block(key, cfg):
    ks = jax.random.split(key, 2)
    p, a = {}, {}
    p["ln1"], a["ln1"] = L._ones_init((cfg.d_model,), ("embed",))
    p["attn"], a["attn"] = L.init_attention(ks[0], cfg)
    p["ln2"], a["ln2"] = L._ones_init((cfg.d_model,), ("embed",))
    p["mlp"], a["mlp"] = L.init_mlp(ks[1], cfg)
    return p, a


def _init_dec_block(key, cfg):
    ks = jax.random.split(key, 3)
    p, a = {}, {}
    p["ln1"], a["ln1"] = L._ones_init((cfg.d_model,), ("embed",))
    p["self_attn"], a["self_attn"] = L.init_attention(ks[0], cfg)
    p["lnx"], a["lnx"] = L._ones_init((cfg.d_model,), ("embed",))
    p["cross_attn"], a["cross_attn"] = L.init_attention(ks[1], cfg)
    p["ln2"], a["ln2"] = L._ones_init((cfg.d_model,), ("embed",))
    p["mlp"], a["mlp"] = L.init_mlp(ks[2], cfg)
    return p, a


def init_encdec(key, cfg):
    from .transformer import _stack_init
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {}
    a: Dict[str, Any] = {}
    p["embed"], a["embed"] = L._dense_init(
        ks[0], (cfg.vocab, cfg.d_model), ("vocab", "embed"), scale=0.02)
    p["enc_layers"], a["enc_layers"] = _stack_init(
        _init_enc_block, ks[1], cfg.enc_layers, cfg)
    p["enc_ln"], a["enc_ln"] = L._ones_init((cfg.d_model,), ("embed",))
    p["dec_layers"], a["dec_layers"] = _stack_init(
        _init_dec_block, ks[2], cfg.n_layers, cfg)
    p["final_ln"], a["final_ln"] = L._ones_init((cfg.d_model,), ("embed",))
    p["unembed"], a["unembed"] = L._dense_init(
        ks[3], (cfg.d_model, cfg.vocab), ("embed", "vocab"), scale=0.02)
    return p, a


def encode(params, cfg, frames, remat=True):
    """frames: [B, T, d] stub embeddings -> encoder states [B, T, d]."""
    B, T, d = frames.shape
    h = frames.astype(jnp.bfloat16) + L.sinusoidal_pos(T, d)[None]
    h = constraint(h, ("batch", None, None))
    positions = jnp.arange(T)

    def body(hh, lp):
        hh = constraint(hh, ("batch", "seq", None))
        x = L.rms_norm(hh, lp["ln1"], cfg.norm_eps)
        o, _ = L.attention(lp["attn"], x, cfg, positions, causal=False)
        hh = hh + o
        x = L.rms_norm(hh, lp["ln2"], cfg.norm_eps)
        return hh + L.mlp(lp["mlp"], x), None

    body_fn = jax.checkpoint(body) if remat else body
    h, _ = cscan(body_fn, h, params["enc_layers"])
    return L.rms_norm(h, params["enc_ln"], cfg.norm_eps)


def _cross_kv(lp, cfg, enc_out):
    B, T, _ = enc_out.shape
    KV, hd = cfg.n_kv_heads, cfg.hd
    k = (enc_out @ lp["cross_attn"]["wk"]).reshape(B, T, KV, hd)
    v = (enc_out @ lp["cross_attn"]["wv"]).reshape(B, T, KV, hd)
    return k, v


def decode(params, cfg, tokens, enc_out, cache=None, cache_index=None,
           remat=True):
    """tokens: [B, S]; enc_out: [B, T, d].  Returns (h, new_cache)."""
    h = params["embed"].astype(jnp.bfloat16)[tokens]
    h = constraint(h, ("batch", None, None))
    B, S, d = h.shape
    base = cache_index if cache_index is not None else 0
    positions = base + jnp.arange(S)

    def body(hh, xs):
        if cache is None:
            lp = xs
            hh = constraint(hh, ("batch", "seq", None))
            x = L.rms_norm(hh, lp["ln1"], cfg.norm_eps)
            o, _ = L.attention(lp["self_attn"], x, cfg, positions,
                               causal=True)
            hh = hh + o
            x = L.rms_norm(hh, lp["lnx"], cfg.norm_eps)
            o, _ = L.attention(lp["cross_attn"], x, cfg, positions,
                               cross_kv=_cross_kv(lp, cfg, enc_out))
            hh = hh + o
            x = L.rms_norm(hh, lp["ln2"], cfg.norm_eps)
            return hh + L.mlp(lp["mlp"], x), None
        lp, kc, vc = xs
        x = L.rms_norm(hh, lp["ln1"], cfg.norm_eps)
        o, nc = L.attention(lp["self_attn"], x, cfg, positions, causal=True,
                            cache={"k": kc, "v": vc},
                            cache_index=cache_index)
        hh = hh + o
        x = L.rms_norm(hh, lp["lnx"], cfg.norm_eps)
        o, _ = L.attention(lp["cross_attn"], x, cfg, positions,
                           cross_kv=_cross_kv(lp, cfg, enc_out))
        hh = hh + o
        x = L.rms_norm(hh, lp["ln2"], cfg.norm_eps)
        return hh + L.mlp(lp["mlp"], x), (nc["k"], nc["v"])

    body_fn = jax.checkpoint(body) if (remat and cache is None) else body
    if cache is None:
        h, _ = cscan(body_fn, h, params["dec_layers"])
        new_cache = None
    else:
        h, (nk, nv) = cscan(body_fn, h,
                               (params["dec_layers"], cache["k"], cache["v"]))
        new_cache = {"k": nk, "v": nv}
    return L.rms_norm(h, params["final_ln"], cfg.norm_eps), new_cache


def encdec_loss(params, cfg, batch, remat=True):
    enc_out = encode(params, cfg, batch["frames"], remat=remat)
    h, _ = decode(params, cfg, batch["tokens"], enc_out, remat=remat)
    return L.chunked_xent(h, params["unembed"].astype(jnp.bfloat16),
                          batch["targets"], batch.get("valid"))


def encdec_init_cache(cfg, batch, max_seq, dtype=jnp.bfloat16):
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, max_seq, cfg.hd)
    axes = ("layer", "batch", "kv", None, "kv_hd")
    return ({"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)},
            {"k": axes, "v": axes})


def encdec_decode_step(params, cfg, cache, tokens, cache_index, enc_out):
    h, new_cache = decode(params, cfg, tokens, enc_out, cache=cache,
                          cache_index=cache_index, remat=False)
    logits = (h[:, -1] @ params["unembed"].astype(jnp.bfloat16)
              ).astype(jnp.float32)
    return logits, new_cache
