"""Costing mode: unroll every internal loop so ``compiled.cost_analysis()``
is exact (XLA costs while-loop bodies ONCE, regardless of trip count — see
EXPERIMENTS.md §Roofline methodology).

Usage: ``with costing_mode(): lower(...)`` — model scans (layers,
microbatches, loss chunks, SSD chunks, attention q-chunks) switch to
unrolled forms.  Costing lowers reduced-depth variants (L=2 and L=4) and
extrapolates linearly in L, which is exact because layers are identical."""
from __future__ import annotations

import contextlib

_UNROLL = False


def unrolling() -> bool:
    return _UNROLL


@contextlib.contextmanager
def costing_mode():
    global _UNROLL
    prev = _UNROLL
    _UNROLL = True
    try:
        yield
    finally:
        _UNROLL = prev


def scan(f, init, xs, length=None):
    """lax.scan that fully unrolls in costing mode."""
    from jax import lax
    if not _UNROLL:
        return lax.scan(f, init, xs, length=length)
    import jax
    import jax.numpy as jnp
    n = length if xs is None else jax.tree.leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(n):
        xi = None if xs is None else jax.tree.map(lambda a: a[i], xs)
        carry, y = f(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def xmap(f, xs):
    """lax.map that fully unrolls in costing mode."""
    from jax import lax
    if not _UNROLL:
        return lax.map(f, xs)
    import jax
    import jax.numpy as jnp
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = [f(jax.tree.map(lambda a: a[i], xs)) for i in range(n)]
    return jax.tree.map(lambda *a: jnp.stack(a), *ys)
