"""Mixture-of-Experts FFN — GShard-style per-row capacity dispatch.

Dispatch/combine are dense one-hot einsums (no scatter): for each batch row,
each token's top-k choices get a rank within their expert (exclusive cumsum
over the row); ranks beyond the per-row capacity ``C = S*k/E * factor`` drop
(classic capacity dropping).  The [B,S,E,C] dispatch tensor contracts tokens
into per-expert buffers and back — MXU-friendly, and GSPMD shards it exactly
like any other matmul (dispatch overhead ~E*C*d/(k*3*d*ff) ≈ 5% of expert
flops at mixtral scale).

This is the SKUEUE Stage-4 dataflow with experts as DHT shards: hashed-
destination dispatch, bounded per-destination capacity, combine on return
(DESIGN.md §2).  Sharding: ``moe_ep=True`` shards experts over "model"
(granite-moe: 32/16); otherwise d_ff shards over "model" and experts
replicate (mixtral: 8 experts < 16 shards).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..sharding import constraint
from .layers import _dense_init


def init_moe(key, cfg):
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    ex = "expert" if cfg.moe_ep else "expert_rep"
    p, a = {}, {}
    p["router"], a["router"] = _dense_init(ks[0], (d, E), ("embed", None),
                                           dtype=jnp.float32)
    p["w1"], a["w1"] = _dense_init(ks[1], (E, d, f), (ex, "embed", "ff"))
    p["w3"], a["w3"] = _dense_init(ks[2], (E, d, f), (ex, "embed", "ff"))
    p["w2"], a["w2"] = _dense_init(ks[3], (E, f, d), (ex, "ff", "embed"))
    return p, a


def moe_ffn(p, x, cfg, capacity_factor: float = None):
    """x: [B, S, d] -> (y [B, S, d], aux_loss scalar)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    capacity_factor = capacity_factor or cfg.capacity_factor
    C = int(max(1, round(S * K / E * capacity_factor)))

    logits = (x.astype(jnp.float32) @ p["router"])             # [B, S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = lax.top_k(probs, K)                           # [B, S, K]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style)
    me = probs.mean(axis=(0, 1))                               # [E]
    onehot_e = jax.nn.one_hot(idx, E, dtype=jnp.float32)       # [B, S, K, E]
    ce = onehot_e.mean(axis=(0, 1, 2))
    aux = E * jnp.sum(me * ce)

    # rank of each (token, choice) within its expert, per row
    flat = onehot_e.reshape(B, S * K, E)
    ranks = jnp.cumsum(flat, axis=1) - flat                    # exclusive
    pos = jnp.einsum("bte,bte->bt", ranks, flat).reshape(B, S, K)
    keep = (pos < C).astype(jnp.float32)
    onehot_c = jax.nn.one_hot(pos.astype(jnp.int32), C,
                              dtype=jnp.float32)               # [B, S, K, C]
    disp = jnp.einsum("bske,bskc->bsec", onehot_e * keep[..., None],
                      onehot_c).astype(x.dtype)                # [B, S, E, C]
    comb = jnp.einsum("bske,bskc,bsk->bsec", onehot_e, onehot_c,
                      gates * keep).astype(jnp.float32)

    ex = "expert" if cfg.moe_ep else None
    xe = jnp.einsum("bsec,bsd->becd", disp, x)                 # [B, E, C, d]
    xe = constraint(xe, ("batch", ex, None, None))
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, p["w1"])) * \
        jnp.einsum("becd,edf->becf", xe, p["w3"])
    h = constraint(h, ("batch", ex, None, "ff" if not cfg.moe_ep else None))
    ye = jnp.einsum("becf,efd->becd", h, p["w2"])
    ye = constraint(ye, ("batch", ex, None, None))
    y = jnp.einsum("bsec,becd->bsd", comb, ye.astype(jnp.float32))
    return y.astype(x.dtype), aux
