"""Decoder-only LM covering the dense / moe / ssm / hybrid / vlm families.

Layers are STACKED on a leading axis and executed with ``lax.scan`` (small
HLO, fast multi-pod compiles — the MaxText approach).  The scan body is
``jax.checkpoint``-wrapped (full remat by default).  Hybrid (Zamba2-style)
models run the mamba scan in segments of ``attn_every`` layers with ONE
shared attention+FFN block applied between segments.

All functions are pure over (params, inputs); logical-axis trees parallel
the param trees for sharding (repro.sharding.specs).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..sharding import constraint
from .costing import scan as cscan
from . import layers as L
from .moe import init_moe, moe_ffn
from .ssm import init_mamba2, init_mamba_state, mamba2_block


# ------------------------------------------------------------------ init ---
def _stack_init(fn, key, n, *args):
    """vmap a per-layer init over n layer keys -> stacked params + axes."""
    keys = jax.random.split(key, n)
    p0, a0 = fn(keys[0], *args)
    stacked = jax.vmap(lambda k: fn(k, *args)[0])(keys)
    axes = jax.tree.map(lambda ax: ("layer",) + ax, a0,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))
    return stacked, axes


def _init_block(key, cfg):
    """One transformer block (attn + ffn/moe/mamba per family)."""
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {}
    a: Dict[str, Any] = {}
    if cfg.family in ("dense", "moe", "vlm"):
        p["ln1"], a["ln1"] = L._ones_init((cfg.d_model,), ("embed",))
        p["attn"], a["attn"] = L.init_attention(ks[0], cfg)
        p["ln2"], a["ln2"] = L._ones_init((cfg.d_model,), ("embed",))
        if cfg.family == "moe":
            p["moe"], a["moe"] = init_moe(ks[1], cfg)
        else:
            p["mlp"], a["mlp"] = L.init_mlp(ks[1], cfg)
    elif cfg.family in ("ssm", "hybrid"):
        p["ln1"], a["ln1"] = L._ones_init((cfg.d_model,), ("embed",))
        p["mamba"], a["mamba"] = init_mamba2(ks[0], cfg)
    else:
        raise ValueError(cfg.family)
    return p, a


def init_lm(key, cfg):
    ks = jax.random.split(key, 5)
    p: Dict[str, Any] = {}
    a: Dict[str, Any] = {}
    p["embed"], a["embed"] = L._dense_init(
        ks[0], (cfg.vocab, cfg.d_model), ("vocab", "embed"), scale=0.02)
    p["layers"], a["layers"] = _stack_init(_init_block, ks[1],
                                           cfg.n_layers, cfg)
    p["final_ln"], a["final_ln"] = L._ones_init((cfg.d_model,), ("embed",))
    if not cfg.tie_embeddings:
        p["unembed"], a["unembed"] = L._dense_init(
            ks[2], (cfg.d_model, cfg.vocab), ("embed", "vocab"), scale=0.02)
    if cfg.family == "hybrid":
        sp, sa = {}, {}
        sp["ln1"], sa["ln1"] = L._ones_init((cfg.d_model,), ("embed",))
        sp["attn"], sa["attn"] = L.init_attention(ks[3], cfg)
        sp["ln2"], sa["ln2"] = L._ones_init((cfg.d_model,), ("embed",))
        sp["mlp"], sa["mlp"] = L.init_mlp(ks[4], cfg)
        p["shared"], a["shared"] = sp, sa
    return p, a


# --------------------------------------------------------------- forward ---
def _attn_block(p, h, cfg, positions, cache=None, cache_index=None):
    x = L.rms_norm(h, p["ln1"], cfg.norm_eps)
    attn_out, new_cache = L.attention(
        p["attn"], x, cfg, positions, causal=True, window=cfg.window,
        cache=cache, cache_index=cache_index)
    h = h + attn_out
    x = L.rms_norm(h, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y, aux = moe_ffn(p["moe"], x, cfg)
    else:
        y, aux = L.mlp(p["mlp"], x), jnp.float32(0)
    return h + y, aux, new_cache


def _mamba_layer(p, h, cfg, state=None):
    x = L.rms_norm(h, p["ln1"], cfg.norm_eps)
    y, new_state = mamba2_block(p["mamba"], x, cfg, state=state)
    return h + y, new_state


def forward(params, cfg, tokens, vision_embeds=None, cache=None,
            cache_index=None, remat=True):
    """tokens: [B, S] int32.  vision_embeds: [B, n_vis, d] (vlm prefill).
    cache: per-family decode cache (see init_cache).  Returns
    (hidden [B, S_total, d], aux_loss, new_cache)."""
    h = params["embed"].astype(jnp.bfloat16)[tokens]
    if vision_embeds is not None:
        h = jnp.concatenate([vision_embeds.astype(h.dtype), h], axis=1)
    h = constraint(h, ("batch", None, None))
    B, S, _ = h.shape
    base = cache_index if cache_index is not None else 0
    positions = base + jnp.arange(S)

    aux_total = jnp.float32(0)
    new_cache: Dict[str, Any] = {}

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, xs):
            hh, aux = carry
            if cache is None:
                # sequence parallelism: the carry (== the remat stack saved
                # for backward) stays seq-sharded over "model"
                hh = constraint(hh, ("batch", "seq", None))
                lp = xs
                hh, a, _ = _attn_block(lp, hh, cfg, positions)
                return (hh, aux + a), None
            lp, kc, vc = xs
            hh, a, nc = _attn_block(lp, hh, cfg, positions,
                                    cache={"k": kc, "v": vc},
                                    cache_index=cache_index)
            return (hh, aux + a), (nc["k"], nc["v"])
        body_fn = jax.checkpoint(body) if (remat and cache is None) else body
        if cache is None:
            (h, aux_total), _ = cscan(body_fn, (h, aux_total),
                                         params["layers"])
        else:
            (h, aux_total), (nk, nv) = cscan(
                body_fn, (h, aux_total),
                (params["layers"], cache["k"], cache["v"]))
            new_cache = {"k": nk, "v": nv}

    elif cfg.family == "ssm":
        def body(carry, xs):
            hh = carry
            if cache is None:
                hh = constraint(hh, ("batch", "seq", None))
                hh, _ = _mamba_layer(xs, hh, cfg)
                return hh, None
            lp, ssm_s, conv_s = xs
            hh, ns = _mamba_layer(lp, hh, cfg,
                                  state={"ssm": ssm_s, "conv": conv_s})
            return hh, (ns["ssm"], ns["conv"])
        body_fn = jax.checkpoint(body) if (remat and cache is None) else body
        if cache is None:
            h, _ = cscan(body_fn, h, params["layers"])
        else:
            h, (nssm, nconv) = cscan(
                body_fn, h, (params["layers"], cache["ssm"], cache["conv"]))
            new_cache = {"ssm": nssm, "conv": nconv}

    elif cfg.family == "hybrid":
        # segments of attn_every mamba layers + the shared attn block
        k = cfg.attn_every
        n_seg = (cfg.n_layers + k - 1) // k
        seg_caches = []
        for s in range(n_seg):
            lo, hi = s * k, min((s + 1) * k, cfg.n_layers)
            seg = jax.tree.map(lambda x: x[lo:hi], params["layers"])
            if cache is None:
                def mbody(hh, lp):
                    hh = constraint(hh, ("batch", "seq", None))
                    hh, _ = _mamba_layer(lp, hh, cfg)
                    return hh, None
                mb = jax.checkpoint(mbody) if remat else mbody
                h, _ = cscan(mb, h, seg)
            else:
                def mbody_c(hh, xs):
                    lp, ssm_s, conv_s = xs
                    hh, ns = _mamba_layer(lp, hh, cfg,
                                          state={"ssm": ssm_s,
                                                 "conv": conv_s})
                    return hh, (ns["ssm"], ns["conv"])
                h, (nssm, nconv) = cscan(
                    mbody_c, h,
                    (seg, cache["ssm"][lo:hi], cache["conv"][lo:hi]))
                new_cache.setdefault("ssm", []).append(nssm)
                new_cache.setdefault("conv", []).append(nconv)
            if hi == (s + 1) * k:  # full segment -> shared attention block
                if cache is None:
                    h, a, _ = _attn_block(params["shared"], h, cfg, positions)
                    aux_total = aux_total + a
                else:
                    kc = cache["shared_k"][s]
                    vc = cache["shared_v"][s]
                    h, a, nc = _attn_block(
                        params["shared"], h, cfg, positions,
                        cache={"k": kc, "v": vc}, cache_index=cache_index)
                    seg_caches.append(nc)
        if cache is not None:
            new_cache["ssm"] = jnp.concatenate(new_cache["ssm"], 0)
            new_cache["conv"] = jnp.concatenate(new_cache["conv"], 0)
            if seg_caches:
                new_cache["shared_k"] = jnp.stack(
                    [c["k"] for c in seg_caches])
                new_cache["shared_v"] = jnp.stack(
                    [c["v"] for c in seg_caches])
    else:
        raise ValueError(cfg.family)

    h = L.rms_norm(h, params["final_ln"], cfg.norm_eps)
    return h, aux_total, (new_cache if cache is not None else None)


# ------------------------------------------------------------------ loss ---
def lm_loss(params, cfg, batch, remat=True):
    """batch: tokens [B,S], targets [B,S] (+ vision_embeds for vlm)."""
    ve = batch.get("vision_embeds")
    h, aux, _ = forward(params, cfg, batch["tokens"], vision_embeds=ve,
                        remat=remat)
    if ve is not None:
        h = h[:, ve.shape[1]:]  # loss on text positions only
    w = (params["embed"].T if cfg.tie_embeddings
         else params["unembed"]).astype(jnp.bfloat16)
    nll = L.chunked_xent(h, w, batch["targets"], batch.get("valid"))
    return nll + 0.01 * aux


# ----------------------------------------------------------------- cache ---
def init_cache(cfg, batch, max_seq, dtype=jnp.bfloat16):
    """Decode cache + its logical axes (for sharding).

    Sliding-window attention caps the cache at the window size: decode only
    ever reads the last ``window`` keys (the long_500k enabler for SWA)."""
    eff = min(max_seq, cfg.window) if cfg.window else max_seq
    if cfg.family in ("dense", "moe", "vlm"):
        shape = (cfg.n_layers, batch, cfg.n_kv_heads, eff, cfg.hd)
        # resolution order: kv-heads shard when divisible; else kv_seq (off
        # by default — flash-decoding split-K, enable via rules override);
        # else head_dim (split-D decode with per-layer logit all-reduce)
        axes = ("layer", "batch", "kv", "kv_seq", "kv_hd")
        return ({"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)},
                {"k": axes, "v": axes})
    if cfg.family == "ssm":
        st = init_mamba_state(cfg, batch, dtype)
        shapes = jax.tree.map(
            lambda x: jnp.zeros((cfg.n_layers,) + x.shape, x.dtype), st)
        return ({"ssm": shapes["ssm"], "conv": shapes["conv"]},
                {"ssm": ("layer", "batch", "ssm_inner", None, None),
                 "conv": ("layer", "batch", None, "ssm_inner")})
    if cfg.family == "hybrid":
        st = init_mamba_state(cfg, batch, dtype)
        n_seg = cfg.n_layers // cfg.attn_every
        kshape = (n_seg, batch, cfg.n_kv_heads, eff, cfg.hd)
        return ({
            "ssm": jnp.zeros((cfg.n_layers,) + st["ssm"].shape,
                             st["ssm"].dtype),
            "conv": jnp.zeros((cfg.n_layers,) + st["conv"].shape,
                              st["conv"].dtype),
            "shared_k": jnp.zeros(kshape, dtype),
            "shared_v": jnp.zeros(kshape, dtype),
        }, {
            "ssm": ("layer", "batch", "ssm_inner", None, None),
            "conv": ("layer", "batch", None, "ssm_inner"),
            "shared_k": ("layer", "batch", "kv", None, "kv_hd"),
            "shared_v": ("layer", "batch", "kv", None, "kv_hd"),
        })
    raise ValueError(cfg.family)


def decode_step(params, cfg, cache, tokens, cache_index):
    """One decode step. tokens: [B, 1].  Returns (logits [B, V], cache)."""
    h, _, new_cache = forward(params, cfg, tokens, cache=cache,
                              cache_index=cache_index, remat=False)
    w = (params["embed"].T if cfg.tie_embeddings
         else params["unembed"]).astype(jnp.bfloat16)
    logits = (h[:, -1] @ w).astype(jnp.float32)
    return logits, new_cache
