"""Model facade: one uniform interface over every assigned architecture.

``build_model(cfg)`` returns a :class:`Model` exposing

  init_params(rng)          real parameters (smoke tests, examples)
  abstract_params()         ShapeDtypeStructs via eval_shape (dry-run)
  param_axes()              logical-axis tree parallel to params
  loss_fn(params, batch)    training loss
  decode_fn(params, cache, tokens, idx, [enc_out])   one serve step
  init_cache(batch, seq)    decode cache (+ axes); abstract_cache for dry-run
  input_specs(shape_name)   ShapeDtypeStruct stand-ins for every input
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs import SHAPES, ArchConfig
from . import encdec as ED
from . import transformer as TF


@dataclass
class Model:
    cfg: ArchConfig

    # ----------------------------------------------------------- params ----
    def init_params(self, rng) -> Tuple[Dict, Dict]:
        if self.cfg.family == "encdec":
            return ED.init_encdec(rng, self.cfg)
        return TF.init_lm(rng, self.cfg)

    def abstract_params(self):
        """(ShapeDtypeStruct tree, logical-axes tree) — zero allocation.

        The axes tree is static python data; capture it by side effect while
        eval_shape traces the parameter shapes."""
        cap = {}

        def f(k):
            p, a = self.init_params(k)
            cap["axes"] = a
            return p

        p = jax.eval_shape(f, jax.random.key(0))
        return p, cap["axes"]

    # ------------------------------------------------------------- loss ----
    def loss_fn(self, params, batch, remat=True):
        if self.cfg.family == "encdec":
            return ED.encdec_loss(params, self.cfg, batch, remat=remat)
        return TF.lm_loss(params, self.cfg, batch, remat=remat)

    # ------------------------------------------------------------ decode ---
    def init_cache(self, batch, max_seq, dtype=jnp.bfloat16):
        if self.cfg.family == "encdec":
            return ED.encdec_init_cache(self.cfg, batch, max_seq, dtype)
        return TF.init_cache(self.cfg, batch, max_seq, dtype)

    def abstract_cache(self, batch, max_seq, dtype=jnp.bfloat16):
        cap = {}

        def f():
            c, a = self.init_cache(batch, max_seq, dtype)
            cap["axes"] = a
            return c

        c = jax.eval_shape(f)
        return c, cap["axes"]

    def decode_fn(self, params, cache, tokens, cache_index, enc_out=None):
        if self.cfg.family == "encdec":
            return ED.encdec_decode_step(params, self.cfg, cache, tokens,
                                         cache_index, enc_out)
        return TF.decode_step(params, self.cfg, cache, tokens, cache_index)

    # ------------------------------------------------------- input specs ---
    def input_specs(self, shape_name: str, dtype=jnp.bfloat16
                    ) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input of a cell.

    For train/prefill: the token batch (+ stub modality embeddings).
    For decode: one new token per sequence (the KV cache/SSM state is a
    separate argument, see launch.dryrun)."""
        seq, gb, kind = SHAPES[shape_name]
        cfg = self.cfg
        i32 = jnp.int32
        if kind in ("train", "prefill"):
            specs = {}
            if cfg.family == "encdec":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (gb, cfg.enc_seq, cfg.d_model), dtype)
                specs["tokens"] = jax.ShapeDtypeStruct((gb, seq), i32)
            elif cfg.family == "vlm":
                text = seq - cfg.n_vision_tokens
                specs["vision_embeds"] = jax.ShapeDtypeStruct(
                    (gb, cfg.n_vision_tokens, cfg.d_model), dtype)
                specs["tokens"] = jax.ShapeDtypeStruct((gb, text), i32)
            else:
                specs["tokens"] = jax.ShapeDtypeStruct((gb, seq), i32)
            if kind == "train":
                tshape = specs["tokens"].shape
                specs["targets"] = jax.ShapeDtypeStruct(tshape, i32)
            return specs
        # decode: one token per sequence
        specs = {"tokens": jax.ShapeDtypeStruct((gb, 1), i32)}
        if cfg.family == "encdec":
            specs["enc_out"] = jax.ShapeDtypeStruct(
                (gb, cfg.enc_seq, cfg.d_model), dtype)
        return specs

    def batch_axes(self, shape_name: str) -> Dict[str, tuple]:
        """Logical axes for input_specs entries."""
        specs = self.input_specs(shape_name)
        out = {}
        for k, v in specs.items():
            out[k] = ("batch",) + (None,) * (len(v.shape) - 1)
        return out


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg=cfg)
