"""Core layer primitives (pure functions over param pytrees).

Every ``init_*`` returns ``(params, logical_axes)`` with identical tree
structure; logical axis names are mapped to mesh axes by
``repro.sharding.specs``.  Compute defaults to the pure-jnp path (used by the
multi-pod dry-run: XLA fuses it); the Pallas kernels are switched in with
``use_pallas=True`` on real TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .costing import xmap

NEG_INF = -1e30


# ---------------------------------------------------------------- inits ----
def _dense_init(key, shape, axes, scale=None, dtype=jnp.bfloat16):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, dtype) * scale, axes)


def _zeros_init(shape, axes, dtype=jnp.bfloat16):
    return (jnp.zeros(shape, dtype), axes)


def _ones_init(shape, axes, dtype=jnp.bfloat16):
    return (jnp.ones(shape, dtype), axes)


# ----------------------------------------------------------------- norm ----
def rms_norm(x, w, eps=1e-5):
    # square in input dtype, accumulate in f32: avoids a full-tensor f32
    # convert of the residual stream (XLA hoists that out of the layer loop,
    # materializing the whole remat stack in f32 — 2x activation memory)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
    wb = w.reshape((1,) * (x.ndim - w.ndim) + w.shape)
    return (x * lax.rsqrt(var + eps).astype(x.dtype)) * wb


# ----------------------------------------------------------------- rope ----
def rope(x, positions, theta=1e6):
    """x: [..., S, H, hd]; positions: [..., S] int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # broadcast positions [..., S] against freqs -> [..., S, 1, half]
    pos = positions[..., :, None, None].astype(jnp.float32)
    ang = pos * freqs.reshape((1,) * (pos.ndim - 1) + (half,))
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos.astype(x.dtype).reshape((1,) * (x.ndim - cos.ndim) + cos.shape)
    sin = sin.astype(x.dtype).reshape((1,) * (x.ndim - sin.ndim) + sin.shape)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


def sinusoidal_pos(S, d, dtype=jnp.bfloat16):
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-9.21034 / d))
    pe = jnp.zeros((S, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div[None, :]))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div[None, : (d - d // 2)]))
    return pe.astype(dtype)


# ------------------------------------------------------------- attention ---
def init_attention(key, cfg, d_model=None):
    d = d_model or cfg.d_model
    hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["wq"], a["wq"] = _dense_init(ks[0], (d, H * hd), ("embed", "heads"))
    p["wk"], a["wk"] = _dense_init(ks[1], (d, KV * hd), ("embed", "kv"))
    p["wv"], a["wv"] = _dense_init(ks[2], (d, KV * hd), ("embed", "kv"))
    p["wo"], a["wo"] = _dense_init(ks[3], (H * hd, d), ("heads", "embed"))
    return p, a


def attention(p, x, cfg, positions, causal=True, window=None,
              cache=None, cache_index=None, cross_kv=None):
    """x: [B, S, d].  Returns (out [B, S, d], new_cache | None).

    cache: dict(k=[B, KV, Smax, hd], v=...) for decode; cache_index: current
    length (tokens already in cache).  cross_kv: precomputed (k, v) for
    cross-attention (ignores cache/causal)."""
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    if cross_kv is None:
        k = (x @ p["wk"]).reshape(B, S, KV, hd)
        v = (x @ p["wv"]).reshape(B, S, KV, hd)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    else:
        k, v = cross_kv  # [B, Skv, KV, hd]

    new_cache = None
    if cache is not None and cross_kv is None:
        # decode: RING-buffer cache of size eff (== window for SWA models —
        # decode never reads past the window, so long_500k SWA decode keeps
        # an O(window) cache).  slot(pos) = pos % eff.
        eff = cache["k"].shape[2]
        slot = cache_index % eff
        kc = lax.dynamic_update_slice(
            cache["k"], k.transpose(0, 2, 1, 3).astype(cache["k"].dtype),
            (0, 0, slot, 0))
        vc = lax.dynamic_update_slice(
            cache["v"], v.transpose(0, 2, 1, 3).astype(cache["v"].dtype),
            (0, 0, slot, 0))
        new_cache = {"k": kc, "v": vc}
        kk = kc.transpose(0, 2, 1, 3)  # [B, eff, KV, hd]
        vv = vc.transpose(0, 2, 1, 3)
        j = jnp.arange(eff)
        # true position held by slot j (largest p <= cache_index, p≡j mod eff)
        kv_positions = j + ((cache_index - j) // eff) * eff
    else:
        kk, vv = k, v
        kv_positions = jnp.arange(kk.shape[1])

    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    masked = cross_kv is None
    out = _sdpa_chunked(qg, kk, vv, positions, kv_positions,
                        causal=causal and masked, window=window if masked
                        else None, masked=masked)
    out = out.reshape(B, S, H * hd)
    return out @ p["wo"], new_cache


def _sdpa_chunked(qg, kk, vv, positions, kv_positions, causal, window,
                  masked=True, chunk=512):
    """Query-chunked attention: never materializes the full [S, T] score
    matrix (jnp flash; the Pallas kernel replaces this on real TPU).

    qg: [B, S, KV, G, hd]; kk/vv: [B, T, KV, hd]."""
    B, S, KV, G, hd = qg.shape
    scale = hd ** -0.5
    kf = kk.astype(jnp.float32)
    vf = vv.astype(jnp.float32)
    tpos = kv_positions

    def block(args):
        qc, spos_c = args                      # [B, c, KV, G, hd], [c]
        s = jnp.einsum("bskgd,btkd->bkgst", qc.astype(jnp.float32),
                       kf) * scale
        if masked:
            m = tpos[None, :] >= 0
            if causal:
                m &= tpos[None, :] <= spos_c[:, None]
            if window is not None:
                m &= tpos[None, :] > spos_c[:, None] - window
            s = jnp.where(m[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bkgst,btkd->bskgd", p, vf)

    if S <= chunk:
        return block((qg, positions)).astype(qg.dtype)
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    n = S // chunk
    qs = qg.reshape(B, n, chunk, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    ps = positions.reshape(n, chunk)
    out = xmap(block, (qs, ps))
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(
        B, S, KV, G, hd).astype(qg.dtype)


# ----------------------------------------------------------------- mlp -----
def init_mlp(key, cfg, d_model=None, d_ff=None):
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p, a = {}, {}
    p["w1"], a["w1"] = _dense_init(ks[0], (d, f), ("embed", "ff"))
    p["w3"], a["w3"] = _dense_init(ks[1], (d, f), ("embed", "ff"))
    p["w2"], a["w2"] = _dense_init(ks[2], (f, d), ("ff", "embed"))
    return p, a


def mlp(p, x):
    return (jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])) @ p["w2"]


# ------------------------------------------------------------- lm head -----
def chunked_xent(h, w_unembed, targets, valid=None, chunk=512):
    """Cross-entropy without materializing [B, S, V]: scan over seq chunks.

    h: [B, S, d]; w_unembed: [d, V]; targets: [B, S] int32.
    Returns mean nll over valid positions."""
    B, S, d = h.shape
    n = max(1, S // chunk)
    while S % n:
        n -= 1
    c = S // n
    hc = h.reshape(B, n, c, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, n, c).transpose(1, 0, 2)
    vc = (valid.reshape(B, n, c).transpose(1, 0, 2)
          if valid is not None else jnp.ones_like(tc, bool))

    @jax.checkpoint  # never save per-chunk logits for backward: recompute
    def chunk_loss(args):
        hh, tt, vv = args
        logits = (hh @ w_unembed).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tt[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * vv
        return nll.sum(), vv.sum()

    losses, counts = xmap(chunk_loss, (hc, tc, vc))
    return losses.sum() / jnp.maximum(counts.sum(), 1)
