"""Version compatibility shims for the jax API surface this repo targets.

The codebase is written against the modern spellings (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh(..., axis_types=...)``).  Older jaxlibs (e.g.
0.4.x) expose the same functionality as ``jax.experimental.shard_map`` with
``check_rep`` and a ``make_mesh`` without ``axis_types``.  Everything in the
repo goes through these two wrappers so a single file owns the skew.
"""
from __future__ import annotations

import jax
from jax import lax


def axis_size(axis_name) -> int:
    """Static named-axis size inside shard_map, on any jax version."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)  # special-cased to the static size


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off, on any jax version."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` requesting Auto axis types where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(axis_type.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)
