"""Continuous-batching serving engine fed by the SKUEUE request queue.

Requests arrive at any host and are enqueued into the distributed queue
(payload = request id); the engine dequeues in the queue's sequentially-
consistent FIFO order — cross-host fairness is Definition 1, not a
scheduler heuristic.  Decode runs vmapped over the
slot set with per-slot positions; finished slots are refilled from the queue each step
(continuous batching).  Prompt ingestion is teacher-forced through the
decode path (slot-local), which shares one compiled step for prefill and
decode at engine scale; the 32k-prefill fast path is the dedicated
``prefill`` lowering exercised by the dry-run.

Queue traffic rides the multi-wave API (PR 1): ``submit`` stages arrivals
host-side, and each engine step flushes staged enqueues *and* the free-slot
dequeues as ONE fused queue wave (``run_waves``), chunked across
K waves in a single device dispatch when a submission burst exceeds one
wave's capacity.  The engine mirrors the queue size host-side
(enqueues flushed minus dequeues granted), so ``run_until_drained`` never
synchronizes on device state between steps.

Elastic membership (PR 2): the request queue is an
:class:`~repro.dqueue.ElasticDeviceQueue`, so the engine can JOIN/LEAVE
queue shards at runtime — :meth:`resize` drains staged submissions into the
queue, re-materializes it onto the new shard count (every queued request id
survives, FIFO order intact), and resumes bursts on the new mesh.  This is
the elastic-serving story: scale the admission fabric with traffic, shed a
failed shard without dropping queued work.

Unified wave engine (PR 4): every queue flavor the engine can ride — FIFO,
priority-tiered, elastic — is now one
:class:`~repro.dqueue.WaveEngine` under a discipline plug-in, and the
chunked multi-wave bursts ``_queue_wave`` stages are software-pipelined by
default (wave k's dispatch overlaps wave k-1's store rewrite; one fused
``all_to_all`` per wave in steady state).  ``ServeEngine(pipelined=False)``
forwards the engine's sequential burst schedule for differential testing;
results are identical either way.

Priority tiers (PR 3): ``ServeEngine(priorities=P)`` swaps the admission
fabric for an :class:`~repro.dqueue.ElasticDevicePriorityQueue` —
``submit(reqs, prio=...)`` stages requests into SLA tiers (0 = interactive,
higher = batch), each step's fused wave admits higher tiers first (the
queue's highest-priority-first wave resolution, NOT a host scheduler
heuristic), and per-tier queue waits are tracked so mixed-load tail-latency
separation is measurable (``tier_wait_stats``).  ``relaxation=k`` forwards
Skeap's bounded tier-relaxation knob to the queue.

Deadline scheduling (PR 5): ``ServeEngine(deadline=True)`` swaps the
admission fabric for an :class:`~repro.dqueue.ElasticDeviceSeapQueue` —
the Seap arbitrary-key discipline with key = the request's deadline step,
so each step's fused wave admits **earliest-deadline-first** (EDF, at the
bucket granularity of the Seap directory), and ``deadline_stats`` reports
the miss rate.  Queue overflow is no longer an assert anywhere on this
path: the elastic wrappers raise
:class:`~repro.dqueue.QueueOverflowError` with per-tier/bucket occupancy,
and :meth:`resize` raises :class:`~repro.dqueue.ServeInvariantError`
instead of a stripped-under-``-O`` bare assert when its enqueue-only
drain wave misbehaves.

Backpressure (PR 8): ``ServeEngine(admission=...)`` installs an admission
policy (``"shed"`` / ``"defer"`` / ``"degrade"``, see
:mod:`repro.serve.admission`) that :meth:`submit` consults against the
queue's zero-cost pre-wave pressure API before staging anything — a full
window rejects with a structured, retryable
:class:`~repro.serve.AdmissionRejected` instead of overwriting live data
mid-wave; deferred requests wait in a bounded host-side spill buffer that
drains ahead of new arrivals on every refill.  ``autoscale=`` wires a
:class:`~repro.serve.HysteresisController` that turns sustained pressure
above its high watermark into ``resize(n + k)`` (and sustained idleness
into a shrink) over the PR 2 one-collective migration — the system's
first closed feedback loop.  ``docs/BACKPRESSURE.md`` is the design doc.

Observability (PR 7): ``ServeEngine(telemetry=True)`` turns on Wavescope —
each fused queue wave also writes one row of admission/occupancy counters
into a device-side metrics ring (pure arithmetic on values the wave already
materializes; zero extra collectives), drained host-side at burst
boundaries into the queue's flight recorder.  :meth:`metrics` returns the
structured snapshot (export via ``repro.obs.to_json`` /
``to_prometheus``), ``submit``/refill/resize emit ``repro.obs.trace``
spans, and overflow/invariant errors carry the last-K wave trajectory.
"""
from __future__ import annotations

import bisect
import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..dqueue import (ElasticDeviceQueue, ElasticDevicePriorityQueue,
                      ElasticDeviceSeapQueue, ServeInvariantError)
from ..obs.trace import span
from .admission import AdmissionRejected, PressureSignal, resolve_policy


@dataclasses.dataclass
class Request:
    """One serving request and its lifecycle bookkeeping.

    Attributes:
      rid: caller-chosen unique request id (rides the queue as payload).
      prompt: prompt token ids, teacher-forced through the decode path.
      max_new: tokens to generate after the prompt.
      prio: SLA tier on ``priorities > 1`` engines (0 = most urgent; the
        degrade admission policy may raise this).
      deadline: absolute engine step to start by on EDF engines (the
        degrade policy may extend it); -1 = unset.
      out: generated token ids (filled by the engine).
      done: True once ``max_new`` tokens (or ``max_seq``) were produced.
      enqueue_step: step the request was accepted (staged or deferred).
      start_step: step it won a decode slot; -1 while queued.
      finish_step: step it completed; -1 while running.
    """

    rid: int
    prompt: List[int]
    max_new: int = 8
    prio: int = 0
    deadline: int = -1            # absolute engine step to start by (EDF)
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    enqueue_step: int = -1
    start_step: int = -1
    finish_step: int = -1


class ServeEngine:
    """Continuous-batching serving engine over the SKUEUE device queue.

    See the module docstring for the architecture.  Constructor args:

    Args:
      model / params / mesh: the decode model, its parameters, and the
        jax mesh whose ``"data"`` axis sizes the queue's shard count.
      max_slots: concurrent decode slots (continuous-batching width).
      max_seq: per-slot sequence capacity.
      queue_cap: per-shard ring capacity of the request queue.
      priorities: > 1 swaps in the priority queue with that many SLA
        tiers (exclusive with ``deadline``).
      relaxation: Skeap bounded tier-relaxation knob (tiers only).
      deadline: True swaps in the Seap queue for EDF admission.
      n_buckets / deadline_horizon: Seap directory shape (EDF only).
      pipelined: software-pipelined multi-wave bursts (default).
      telemetry: enable Wavescope device metrics + flight recorder.
      flight_k: flight-recorder depth.
      admission: None, a policy name ("shed" / "defer" / "degrade"), or
        an :class:`~repro.serve.admission.AdmissionPolicy` — consulted by
        :meth:`submit` before staging (PR 8).
      spill_cap: bound of the defer policy's host-side spill buffer.
      autoscale: a :class:`~repro.serve.HysteresisController` driving
        :meth:`resize` from sustained pressure (PR 8); its
        ``max_shards`` defaults to the queue's device-pool size.
      runtime: a :class:`~repro.runtime.Runtime` handle (PR 10).  The
        queue's shard pool, placement, and host staging all go through
        it; when omitted, one is derived from ``mesh`` (a bare Mesh is
        adopted into a transparent ``LocalRuntime``).  ``mesh`` itself
        may also BE a runtime, in which case the engine's mesh is the
        runtime's current mesh.

    Raises:
      ValueError: incompatible discipline flags or unknown policy name.
    """

    def __init__(self, model, params, mesh, *, max_slots: int = 4,
                 max_seq: int = 64, queue_cap: int = 256,
                 priorities: int = 1, relaxation: int = 0,
                 deadline: bool = False, n_buckets: int = 8,
                 deadline_horizon: int = 64, pipelined: bool = True,
                 telemetry: bool = False, flight_k: int = 16,
                 admission=None, spill_cap: int = 64,
                 autoscale=None, runtime=None):
        from ..runtime import Runtime
        if runtime is None and isinstance(mesh, Runtime):
            runtime, mesh = mesh, None
        if runtime is not None and mesh is None:
            mesh = runtime.mesh()
        self.runtime = runtime
        n_shards = (runtime.n_shards if runtime is not None
                    else mesh.shape["data"])
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.mesh = mesh
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.priorities = priorities
        self.deadline = deadline
        self.telemetry = bool(telemetry)
        if deadline and priorities > 1:
            raise ValueError("deadline=True (EDF via the Seap queue) and "
                             "priorities > 1 (SLA tiers) are exclusive "
                             "admission disciplines")
        if deadline:
            # seed the directory on a step grid over the deadline horizon
            # (a cold directory serves near-FIFO until splits zoom in);
            # the split/merge rule then rolls the refined window forward
            # as past buckets drain and future ones fill.  Splits trigger
            # at roughly one refill's worth of waiting requests.
            grid = max(1, deadline_horizon // n_buckets)
            self.queue = ElasticDeviceSeapQueue(
                n_shards, n_buckets=n_buckets, cap=queue_cap,
                payload_width=2, ops_per_shard=max(8, 2 * max_slots),
                split_occupancy=max(1, 2 * max_slots),
                seed_bounds=[i * grid for i in range(1, n_buckets)],
                pipelined=pipelined, metrics=telemetry,
                flight_k=flight_k, runtime=runtime)
        elif priorities > 1:
            self.queue = ElasticDevicePriorityQueue(
                n_shards, n_prios=priorities,
                relaxation=relaxation, cap=queue_cap, payload_width=2,
                ops_per_shard=max(8, 2 * max_slots), pipelined=pipelined,
                metrics=telemetry, flight_k=flight_k, runtime=runtime)
        else:
            self.queue = ElasticDeviceQueue(n_shards,
                                            cap=queue_cap, payload_width=2,
                                            ops_per_shard=max(8, 2 * max_slots),
                                            pipelined=pipelined,
                                            metrics=telemetry,
                                            flight_k=flight_k,
                                            runtime=runtime)
        self.requests: Dict[int, Request] = {}
        self.slots: List[Optional[int]] = [None] * max_slots
        self.slot_pos = np.zeros(max_slots, np.int64)
        self.cache, _ = model.init_cache(max_slots, max_seq)
        self.step_no = 0
        self._staged: List[int] = []   # rids submitted but not yet flushed
        self._host_qsize = 0           # host mirror of the device queue size
        # vmap over slots: each slot decodes at ITS OWN position (cache leaves
        # have batch on axis 1: [layers, B, ...]); re-add the unit batch dim
        # the model expects inside the map
        def _one(p, c, t, i):
            c = jax.tree.map(lambda x: x[:, None], c)
            lg, nc = model.decode_fn(p, c, t[None], i)
            nc = jax.tree.map(lambda x: x[:, 0], nc)
            return lg[0], nc

        self._decode = jax.jit(jax.vmap(
            _one, in_axes=(None, 1, 0, 0), out_axes=(0, 1)))
        self.stats = {"served": 0, "queue_waits": [],
                      "queue_waits_by_prio": {p: [] for
                                              p in range(priorities)},
                      "deadline_lateness": []}
        # ---- backpressure control plane (PR 8) ----
        self.admission = resolve_policy(admission)
        self.spill_cap = int(spill_cap)
        self._spill: deque = deque()   # deferred Requests, oldest first
        self.autoscale = autoscale
        if autoscale is not None and autoscale.cfg.max_shards is None:
            autoscale.cfg.max_shards = self.queue.pool_size
        self._overloaded = False       # shed/defer seen since last tick
        self._in_autoscale = False     # resize() call is the controller's
        self.admission_stats = {"offered": 0, "admitted": 0, "shed": 0,
                                "deferred": 0, "degraded": 0,
                                "spill_peak": 0, "decide_us": []}

    # ---------------------------------------------------------- frontend ---
    def submit(self, reqs: List[Request], prio: Optional[int] = None,
               deadline: Optional[int] = None):
        """Stage arrivals for the distributed queue.

        They enter the queue on the next engine step, fused with that step's
        refill dequeues; oversized bursts are chunked across as many queue
        waves as needed (all inside one ``run_waves`` dispatch), so a submit
        can exceed ``n_shards * L`` requests without overflowing a wave.

        With ``priorities > 1``, ``prio`` (or each request's ``.prio``
        field) selects the SLA tier: 0 is served ahead of 1, etc.

        With ``deadline=True`` on the engine, ``deadline`` (steps from
        now) or each request's ``.deadline`` field (an absolute engine
        step) sets the EDF key — requests with earlier deadlines are
        admitted first, bucket-granular.

        With an admission policy installed (``admission=``), the batch is
        first decided against the queue's live pressure (PR 8): what fits
        is staged, the defer policy spills the rest host-side, and
        anything rejected raises — AFTER the fitting part was staged.

        Raises:
          ValueError: bad tier / missing deadline.
          AdmissionRejected: the policy rejected part of the batch (or
            the spill buffer was full); ``err.shed`` holds the untouched,
            resubmittable requests.
        """
        with span("serve:submit", cat="serve", n=len(reqs),
                  step=self.step_no):
            self._submit(reqs, prio, deadline)

    def _submit(self, reqs: List[Request], prio: Optional[int],
                deadline: Optional[int]):
        for r in reqs:
            if prio is not None:
                r.prio = prio
            if not 0 <= r.prio < self.priorities:
                raise ValueError(f"request {r.rid} prio {r.prio} outside "
                                 f"[0, {self.priorities})")
            if self.deadline:
                if deadline is not None:
                    r.deadline = self.step_no + deadline
                if r.deadline < 0:
                    raise ValueError(f"request {r.rid} needs a deadline "
                                     "(engine runs EDF admission)")
        if self.admission is None:
            for r in reqs:
                self._accept(r, stage=True)
            return
        t0 = time.perf_counter()
        sig = self._pressure_signal()
        dec = self.admission.decide(list(reqs), sig)
        st = self.admission_stats
        st["decide_us"].append((time.perf_counter() - t0) * 1e6)
        st["offered"] += len(reqs)
        st["admitted"] += len(dec.admit)
        st["deferred"] += len(dec.defer)
        st["degraded"] += dec.degraded
        for r in dec.admit:
            self._accept(r, stage=True)
        for r in dec.defer:
            self._accept(r, stage=False)
            self._spill.append(r)
        st["spill_peak"] = max(st["spill_peak"], len(self._spill))
        if dec.shed or dec.defer or dec.degraded:
            self._overloaded = True
            self.queue.recorder.record({
                "event": "admission", "step": self.step_no,
                "policy": self.admission.name, "shed": len(dec.shed),
                "deferred": len(dec.defer), "degraded": dec.degraded,
                "occ": list(sig.occupancy)})
        if dec.shed:
            st["shed"] += len(dec.shed)
            backlog = len(dec.shed) + len(self._spill)
            raise AdmissionRejected(
                self.admission.name,
                "spill-overflow" if dec.spill_overflow else "shed",
                dec.shed, admitted=len(dec.admit),
                deferred=len(dec.defer), degraded=dec.degraded,
                pressure=sig.snapshot(),
                retry_after=-(-backlog // max(1, self.max_slots)))

    def _accept(self, r: Request, *, stage: bool):
        """Register an admitted request; stage it for the next flush (or
        leave it to the spill buffer when ``stage`` is False)."""
        self.requests[r.rid] = r
        r.enqueue_step = self.step_no
        if stage:
            self._staged.append(r.rid)

    # ------------------------------------------------------- backpressure ---
    def _pressure_signal(self) -> PressureSignal:
        """Snapshot the queue + host pressure for an admission decision.

        Occupancy and the Seap directory come from the elastic wrapper's
        pre-wave pressure API — replicated host reads, no collective and
        no wave dispatch; staged/spill counts are pure host bookkeeping."""
        q = self.queue
        occ = q.occupancy()
        staged = [0] * len(occ)
        window_order = None
        window_lo = None
        if self.deadline:
            entries = q.directory()       # (lo, bucket) in key order
            los = [lo for lo, _ in entries]
            ids = [b for _, b in entries]
            window_order = ids
            window_lo = {b: lo for lo, b in entries}

            def window_of(r, _los=los, _ids=ids):
                return _ids[max(0, bisect.bisect_right(_los,
                                                       r.deadline) - 1)]
        elif self.priorities > 1:
            def window_of(r):
                return r.prio
        else:
            def window_of(r):
                return 0
        for rid in self._staged:
            staged[window_of(self.requests[rid])] += 1
        late = self.stats["deadline_lateness"][-128:]
        p99 = (float(np.percentile(np.asarray(late, np.float64), 99))
               if late else 0.0)
        return PressureSignal(
            capacity=q.window_capacity(), occupancy=occ, staged=staged,
            spill=len(self._spill), spill_cap=self.spill_cap,
            step=self.step_no,
            mode=("edf" if self.deadline
                  else "tiers" if self.priorities > 1 else "fifo"),
            lateness_p99=p99, drain_per_step=self.max_slots,
            window_of=window_of, window_order=window_order,
            window_lo=window_lo)

    def _drain_spill(self):
        """Re-offer deferred requests ahead of new arrivals, as far as the
        current headroom allows (oldest first; the rest keep waiting)."""
        if not self._spill:
            return
        sig = self._pressure_signal()
        keep: deque = deque()
        front: List[int] = []
        while self._spill:
            r = self._spill.popleft()
            w = sig.window_of(r)
            if sig.headroom(w) > 0:
                sig.take(w)
                front.append(r.rid)
            else:
                keep.append(r)
        self._spill = keep
        self._staged = front + self._staged

    def _autoscale_tick(self):
        """One controller observation; executes the resize it decides.

        Utilization feeds the hottest window's occupancy PLUS everything
        still host-side (staged + spilled), so load a policy absorbed
        before the device saw it still registers as pressure."""
        q = self.queue
        cap = q.window_capacity()
        occ = q.occupancy()
        backlog = max(occ, default=0) + len(self._staged) + len(self._spill)
        util = backlog / cap if cap else 1.0
        target = self.autoscale.observe(util, q.n_shards,
                                        overloaded=self._overloaded)
        self._overloaded = False
        if target is None or target == q.n_shards:
            return
        with span("serve:autoscale", cat="serve", step=self.step_no,
                  target=target):
            self._in_autoscale = True
            try:
                self.resize(target)
            finally:
                self._in_autoscale = False
        self.autoscale.notify_resize(target)
        q.recorder.record({"event": "autoscale", "step": self.step_no,
                           "n_shards": target, "occ": occ})

    def _queue_wave(self, enq_rids: List[int], n_deq: int) -> List[int]:
        """Run enqueues + dequeues as chunked fused waves; returns granted
        request ids.  Wave width tracks the queue's CURRENT shard count —
        and, within it, the burst's occupancy bucket: a refill that fits a
        single wave rides the narrowest envelope of the queue's bucket
        ladder that holds it (PR 9), shrinking both all_to_all payloads.
        Oversized bursts chunk at the full width as before."""
        n_ops = len(enq_rids) + n_deq
        if n_ops == 0:
            return []
        n_full = self.queue.n_shards * self.queue.L
        if n_ops <= n_full:
            # the admission layer knows the staged count: pick the
            # smallest bucket that fits (each width is a cached program)
            n = self.queue.n_shards * self.queue.pick_width(n_ops)
        else:
            n = n_full
        n_waves = -(-n_ops // n)  # ceil: chunk oversized bursts
        # pad the wave count to a power of two (extra waves are all-invalid
        # no-ops) so fluctuating burst sizes only ever compile the scanned
        # program for O(log K) distinct shapes
        n_waves = 1 << (n_waves - 1).bit_length()
        is_enq = np.zeros((n_waves, n), bool)
        valid = np.zeros((n_waves, n), bool)
        prio = np.zeros((n_waves, n), np.int32)
        payload = np.zeros((n_waves, n, 2), np.int32)
        for j, rid in enumerate(enq_rids):
            k, i = divmod(j, n)
            is_enq[k, i] = valid[k, i] = True
            prio[k, i] = (self.requests[rid].deadline if self.deadline
                          else self.requests[rid].prio)
            payload[k, i, 0] = rid
        for m in range(n_deq):
            k, i = divmod(len(enq_rids) + m, n)
            valid[k, i] = True  # dequeue request
        # overflow is raised by the elastic wrapper as QueueOverflowError
        # (with per-tier/bucket occupancy) — no bare assert on this path
        if self.deadline or self.priorities > 1:
            _, _, _, dv, dok, _, _ = self.queue.run_waves(
                jnp.array(is_enq), jnp.array(valid), jnp.array(prio),
                jnp.array(payload))
        else:
            _, _, dv, dok, _ = self.queue.run_waves(
                jnp.array(is_enq), jnp.array(valid), jnp.array(payload))
        to_host = self.queue.runtime.to_host
        dv = to_host(dv).reshape(n_waves * n, 2)
        dok = to_host(dok).reshape(n_waves * n)
        got = [int(dv[j, 0]) for j in range(n_waves * n) if dok[j]]
        self._host_qsize += len(enq_rids) - len(got)
        return got

    def _flush_and_refill(self):
        """ONE fused queue dispatch: staged enqueues + free-slot dequeues.
        Deferred (spilled) requests drain first, ahead of new arrivals."""
        self._drain_spill()
        free = [i for i, s in enumerate(self.slots) if s is None]
        enq_rids, self._staged = self._staged, []
        with span("serve:refill", cat="serve", step=self.step_no,
                  enq=len(enq_rids), free=len(free)):
            got = self._queue_wave(enq_rids, len(free))
        for slot, rid in zip(free, got):
            r = self.requests[rid]
            r.start_step = self.step_no
            self.stats["queue_waits"].append(r.start_step - r.enqueue_step)
            self.stats["queue_waits_by_prio"][r.prio].append(
                r.start_step - r.enqueue_step)
            if self.deadline and r.deadline >= 0:
                self.stats["deadline_lateness"].append(
                    r.start_step - r.deadline)
            self.slots[slot] = rid
            self.slot_pos[slot] = 0

    def _pending_by_prio(self) -> Dict[int, int]:
        """Submitted-but-not-yet-admitted request count per tier — the
        starvation the wait stats exist to expose."""
        pending = {p: 0 for p in range(self.priorities)}
        for r in self.requests.values():
            if r.start_step < 0 and not r.done:
                pending[r.prio] += 1
        return pending

    def tier_wait_stats(self) -> Dict[int, dict]:
        """Per-tier admission latency (engine steps from submit to slot):
        count / mean / p50 / p99 plus the tier's ``pending`` (submitted,
        never admitted) count — the mixed-load separation the priority
        fabric exists to provide.  EVERY configured tier gets a row: a
        starved tier shows ``{"n": 0, "pending": k}`` instead of being
        silently omitted (which hid exactly the starvation this report
        exists to surface)."""
        pending = self._pending_by_prio()
        out = {}
        for p in range(self.priorities):
            waits = self.stats["queue_waits_by_prio"].get(p, [])
            row = {"n": len(waits), "pending": pending[p]}
            if waits:
                w = np.asarray(waits, np.float64)
                row.update(mean=float(w.mean()),
                           p50=float(np.percentile(w, 50)),
                           p99=float(np.percentile(w, 99)))
            out[p] = row
        return out

    def deadline_stats(self) -> dict:
        """EDF admission outcome (``deadline=True`` engines): admissions,
        misses (started after the deadline step), miss rate, lateness
        percentiles, and the still-pending count."""
        late = np.asarray(self.stats["deadline_lateness"], np.float64)
        missed = int((late > 0).sum()) if late.size else 0
        out = {"n": int(late.size), "missed": missed,
               "miss_rate": missed / late.size if late.size else 0.0,
               "pending": sum(self._pending_by_prio().values())}
        if late.size:
            out.update(lateness_mean=float(late.mean()),
                       lateness_p99=float(np.percentile(late, 99)),
                       lateness_max=float(late.max()))
        return out

    # ----------------------------------------------------------- elastic ---
    def resize(self, n_shards: int) -> dict:
        """Live JOIN/LEAVE of queue shards between engine steps.

        Drains staged submissions into the device queue (so the migration
        wave carries them too), re-materializes the queue onto ``n_shards``
        shards, and resumes — queued request ids and FIFO admission order
        are preserved exactly.  Returns the migration stats dict."""
        enq_rids, self._staged = self._staged, []
        got = self._queue_wave(enq_rids, 0)
        if got:
            # an enqueue-only drain wave granted dequeues: the host-side
            # queue mirror and the device queue have diverged (was a bare
            # assert, invisible under ``python -O``)
            raise ServeInvariantError(
                "resize drain wave granted requests from an enqueue-only "
                "wave", granted_rids=got, staged=len(enq_rids),
                n_shards_from=self.queue.n_shards, n_shards_to=n_shards,
                host_qsize=self._host_qsize, step=self.step_no,
                trajectory=self.queue.trajectory())
        stats = self.queue.resize(n_shards)
        if self.autoscale is not None and not self._in_autoscale:
            # a resize the controller did NOT decide (operator or fault
            # layer): reset its counters so it re-learns the new shape
            self.autoscale.notify_resize(n_shards, external=True)
        return stats

    # ------------------------------------------------------ observability ---
    def metrics(self) -> dict:
        """Structured Wavescope snapshot of the serving fabric — feed it to
        :func:`repro.obs.to_json` / :func:`repro.obs.to_prometheus`.

        Always-on scalars come from host bookkeeping (served count, slot
        utilization, queue-depth mirror, admission-wait percentiles,
        per-tier / deadline stats where configured).  With
        ``telemetry=True`` the snapshot additionally drains the device-side
        metrics ring into the flight recorder and attaches the recent wave
        summaries under ``"waves"`` — no extra collectives, the drain is a
        burst-boundary host read."""
        q = self.queue
        occ = q.occupancy()
        snap = {
            "step": self.step_no,
            "served": self.stats["served"],
            "slots": {"active": sum(s is not None for s in self.slots),
                      "max": self.max_slots},
            "staged": len(self._staged),
            "queue": {
                "kind": q._kind,
                "n_shards": q.n_shards,
                "depth": self._host_qsize,
                "window_capacity": q.window_capacity(),
                "occupancy": occ,
                "headroom": q.window_capacity() - max(occ, default=0),
                "migrations": len(q.migrations),
            },
        }
        if self.admission is not None:
            st = self.admission_stats
            ac = {"policy": self.admission.name,
                  "offered": st["offered"], "admitted": st["admitted"],
                  "shed": st["shed"], "deferred": st["deferred"],
                  "degraded": st["degraded"],
                  "spill": len(self._spill), "spill_cap": self.spill_cap,
                  "spill_peak": st["spill_peak"]}
            if st["decide_us"]:
                d = np.asarray(st["decide_us"], np.float64)
                ac.update(decide_us_mean=float(d.mean()),
                          decide_us_p99=float(np.percentile(d, 99)))
            snap["admission_control"] = ac
        if self.autoscale is not None:
            snap["autoscale"] = self.autoscale.snapshot()
        waits = self.stats["queue_waits"]
        adm = {"n": len(waits)}
        if waits:
            w = np.asarray(waits, np.float64)
            adm.update(mean=float(w.mean()),
                       p50=float(np.percentile(w, 50)),
                       p99=float(np.percentile(w, 99)))
        snap["admission"] = adm
        if self.priorities > 1:
            snap["tiers"] = self.tier_wait_stats()
        if self.deadline:
            snap["deadline"] = self.deadline_stats()
        if self.telemetry:
            q._drain_telemetry()
            snap["waves"] = q.trajectory()
        return snap

    # ------------------------------------------------------------ decode ---
    def step(self):
        """One engine step: flush+refill in one fused wave, advance slots.
        With ``autoscale=`` set, also runs one controller tick (which may
        execute a resize migration between the wave and the decode)."""
        self.step_no += 1
        self._flush_and_refill()
        if self.autoscale is not None:
            self._autoscale_tick()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return
        toks = np.zeros((self.max_slots, 1), np.int32)
        for i in active:
            r = self.requests[self.slots[i]]
            p = int(self.slot_pos[i])
            if p < len(r.prompt):
                toks[i, 0] = r.prompt[p]
            else:
                toks[i, 0] = r.out[-1] if r.out else r.prompt[-1]
        # ONE vmapped decode: every slot advances at its own position
        idxs = jnp.array(self.slot_pos, jnp.int32)
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.array(toks), idxs)
        lg = np.asarray(logits, np.float32).reshape(self.max_slots, -1)
        for i in active:
            r = self.requests[self.slots[i]]
            self.slot_pos[i] += 1
            if self.slot_pos[i] >= len(r.prompt):
                nxt = int(lg[i].argmax())
                r.out.append(nxt)
                if (len(r.out) >= r.max_new
                        or self.slot_pos[i] >= self.max_seq - 1):
                    r.done = True
                    r.finish_step = self.step_no
                    self.stats["served"] += 1
                    self.slots[i] = None

    def run_until_drained(self, max_steps: int = 1000):
        """Drive steps until everything is served.  Drain detection uses the
        host-side queue-size mirror — no device synchronization per step."""
        for _ in range(max_steps):
            self.step()
            if (all(r.done for r in self.requests.values())
                    and not self._staged and not self._spill
                    and self._host_qsize == 0):
                return True
        return False
