"""Continuous-batching serving engine fed by the SKUEUE request queue.

Requests arrive at any host and are enqueued into the distributed queue
(payload = request id); the engine dequeues in the queue's sequentially-
consistent FIFO order — cross-host fairness is Definition 1, not a
scheduler heuristic.  Decode runs vmapped over the
slot set with per-slot positions; finished slots are refilled from the queue each step
(continuous batching).  Prompt ingestion is teacher-forced through the
decode path (slot-local), which shares one compiled step for prefill and
decode at engine scale; the 32k-prefill fast path is the dedicated
``prefill`` lowering exercised by the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..dqueue import DeviceQueue


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 8
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    enqueue_step: int = -1
    start_step: int = -1
    finish_step: int = -1


class ServeEngine:
    def __init__(self, model, params, mesh, *, max_slots: int = 4,
                 max_seq: int = 64, queue_cap: int = 256):
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.mesh = mesh
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.queue = DeviceQueue(mesh, "data", cap=queue_cap,
                                 payload_width=2,
                                 ops_per_shard=max(8, 2 * max_slots))
        self.qstate = self.queue.init_state()
        self.requests: Dict[int, Request] = {}
        self.slots: List[Optional[int]] = [None] * max_slots
        self.slot_pos = np.zeros(max_slots, np.int64)
        self.cache, _ = model.init_cache(max_slots, max_seq)
        self.step_no = 0
        # vmap over slots: each slot decodes at ITS OWN position (cache leaves
        # have batch on axis 1: [layers, B, ...]); re-add the unit batch dim
        # the model expects inside the map
        def _one(p, c, t, i):
            c = jax.tree.map(lambda x: x[:, None], c)
            lg, nc = model.decode_fn(p, c, t[None], i)
            nc = jax.tree.map(lambda x: x[:, 0], nc)
            return lg[0], nc

        self._decode = jax.jit(jax.vmap(
            _one, in_axes=(None, 1, 0, 0), out_axes=(0, 1)))
        self.stats = {"served": 0, "queue_waits": []}

    # ---------------------------------------------------------- frontend ---
    def submit(self, reqs: List[Request]):
        """Enqueue arrivals into the distributed FIFO (one step batch)."""
        n = self.queue.n_shards * self.queue.L
        is_enq = np.zeros(n, bool)
        valid = np.zeros(n, bool)
        payload = np.zeros((n, 2), np.int32)
        for i, r in enumerate(reqs):
            self.requests[r.rid] = r
            r.enqueue_step = self.step_no
            is_enq[i] = valid[i] = True
            payload[i, 0] = r.rid
        self._qstep(is_enq, valid, payload)

    def _qstep(self, is_enq, valid, payload):
        self.qstate, pos, matched, dv, dok, ovf = self.queue.step(
            self.qstate, jnp.array(is_enq), jnp.array(valid),
            jnp.array(payload))
        assert not bool(ovf)
        return np.asarray(dv), np.asarray(dok)

    def _refill(self):
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free:
            return
        n = self.queue.n_shards * self.queue.L
        is_enq = np.zeros(n, bool)
        valid = np.zeros(n, bool)
        payload = np.zeros((n, 2), np.int32)
        for k in range(min(len(free), n)):
            valid[k] = True  # dequeue request
        dv, dok = self._qstep(is_enq, valid, payload)
        got = [int(dv[k, 0]) for k in range(n) if dok[k]]
        for slot, rid in zip(free, got):
            r = self.requests[rid]
            r.start_step = self.step_no
            self.stats["queue_waits"].append(r.start_step - r.enqueue_step)
            self.slots[slot] = rid
            self.slot_pos[slot] = 0

    # ------------------------------------------------------------ decode ---
    def step(self):
        """One engine step: refill free slots, advance every active slot."""
        self.step_no += 1
        self._refill()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return
        toks = np.zeros((self.max_slots, 1), np.int32)
        for i in active:
            r = self.requests[self.slots[i]]
            p = int(self.slot_pos[i])
            if p < len(r.prompt):
                toks[i, 0] = r.prompt[p]
            else:
                toks[i, 0] = r.out[-1] if r.out else r.prompt[-1]
        # ONE vmapped decode: every slot advances at its own position
        idxs = jnp.array(self.slot_pos, jnp.int32)
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.array(toks), idxs)
        lg = np.asarray(logits, np.float32).reshape(self.max_slots, -1)
        for i in active:
            r = self.requests[self.slots[i]]
            self.slot_pos[i] += 1
            if self.slot_pos[i] >= len(r.prompt):
                nxt = int(lg[i].argmax())
                r.out.append(nxt)
                if (len(r.out) >= r.max_new
                        or self.slot_pos[i] >= self.max_seq - 1):
                    r.done = True
                    r.finish_step = self.step_no
                    self.stats["served"] += 1
                    self.slots[i] = None

    def run_until_drained(self, max_steps: int = 1000):
        for _ in range(max_steps):
            self.step()
            if all(r.done for r in self.requests.values()) and \
                    int(self.qstate.size) == 0:
                return True
        return False
