"""Hysteresis autoscaling for the elastic queue fabric.

The paper's JOIN/LEAVE exist so the queue "can be used in highly dynamic
environments"; PR 2 made them a one-collective migration wave.  Until now
every caller of ``grow()`` / ``shrink()`` was a human (tests, fault
injection).  This module is the missing controller: it watches the same
zero-cost pressure signal admission uses (occupancy + staged + spill over
window capacity) and turns *sustained* load above a high watermark into
``resize(n + k)`` and *sustained* idleness below a low watermark into a
shrink — never reacting to a single spike, never flapping.

The controller itself is pure host arithmetic with no jax dependency, so
its hysteresis behavior (the flap guard) is unit-testable without a mesh;
:class:`~repro.serve.ServeEngine` wires it to real ``resize`` calls (one
migration wave each, per PR 2) when constructed with ``autoscale=``.

Coexistence with fault handling: ``fault.elastic_queue_policy`` accepts
the same controller and reports its failure-LEAVE (and regrow-JOIN)
resizes via :meth:`HysteresisController.notify_resize`, which resets the
patience counters and starts the cooldown — so the controller neither
fights the fault layer (instantly re-growing a shard that was shrunk away
because it *died*) nor double-counts the membership change as its own.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class ControllerConfig:
    """Watermarks and hysteresis knobs for :class:`HysteresisController`.

    Attributes:
      high_watermark: utilization (hottest-window pressure / capacity)
        above which a tick counts toward growing.
      low_watermark: utilization below which a tick counts toward
        shrinking.
      high_patience: consecutive above-watermark ticks required before a
        grow fires (spike rejection).
      low_patience: consecutive below-watermark ticks required before a
        shrink fires (kept higher than ``high_patience`` by default:
        growing late loses data, shrinking late only wastes devices).
      cooldown: ticks after ANY resize (including external/fault ones)
        during which the controller only observes — the flap guard that
        keeps a square-wave load from toggling grow/shrink every burst.
      grow_k: shards added per grow decision.
      shrink_k: shards removed per shrink decision.
      min_shards: never shrink below this.
      max_shards: never grow above this (the engine defaults it to the
        queue's device-pool size).
    """

    high_watermark: float = 0.75
    low_watermark: float = 0.25
    high_patience: int = 2
    low_patience: int = 8
    cooldown: int = 4
    grow_k: int = 1
    shrink_k: int = 1
    min_shards: int = 1
    max_shards: Optional[int] = None


class HysteresisController:
    """Sustained-pressure → resize decisions, with a flap guard.

    Call :meth:`observe` once per engine step with the current
    utilization; it returns a target shard count when (and only when) a
    resize should happen now.  Whoever executes the resize — the engine,
    or the fault layer doing a failure-LEAVE — reports it back via
    :meth:`notify_resize` so counters reset and the cooldown starts.

    Args:
      config: a :class:`ControllerConfig`; keyword overrides may be
        passed directly instead (``HysteresisController(cooldown=8)``).
      runtime: an optional :class:`~repro.runtime.Runtime` (PR 10).  When
        given and ``max_shards`` is unset, the ceiling defaults to the
        runtime's LIVE pool size — quarantined (failed) devices do not
        count, so the controller never decides to grow onto dead
        hardware.  The controller stays pure host arithmetic: the
        runtime is consulted once here, never on the observe path.

    Raises:
      ValueError: watermarks out of order or patience/cooldown negative.
    """

    def __init__(self, config: Optional[ControllerConfig] = None, *,
                 runtime=None, **kw):
        self.cfg = config or ControllerConfig(**kw)
        if runtime is not None and self.cfg.max_shards is None:
            self.cfg.max_shards = runtime.pool_size
        c = self.cfg
        if not 0.0 <= c.low_watermark < c.high_watermark:
            raise ValueError(
                f"need 0 <= low_watermark < high_watermark, got "
                f"{c.low_watermark} / {c.high_watermark}")
        if min(c.high_patience, c.low_patience, c.cooldown) < 0:
            raise ValueError("patience/cooldown must be >= 0")
        self._above = 0          # consecutive ticks above high watermark
        self._below = 0          # consecutive ticks below low watermark
        self._cooldown = 0       # ticks left before decisions resume
        self.stats = {"ticks": 0, "grows": 0, "shrinks": 0,
                      "suppressed_cooldown": 0, "external_resizes": 0}
        self.last_decision = "none"

    # ----------------------------------------------------------- inputs ---
    def observe(self, utilization: float, n_shards: int, *,
                overloaded: bool = False) -> Optional[int]:
        """One controller tick.

        Args:
          utilization: hottest-window pressure over window capacity
            (occupancy + staged + spilled, so shed/deferred load still
            registers as pressure even though it never hit the device).
          n_shards: the queue's current shard count.
          overloaded: force this tick to count as above-watermark — the
            engine sets it when the admission policy had to shed/defer
            this step, which is overload by definition even if the
            post-shed occupancy looks calm.

        Returns:
          A target shard count to ``resize`` to right now, or None.
          The caller MUST report the resize back via
          :meth:`notify_resize` once done.
        """
        self.stats["ticks"] += 1
        if self._cooldown > 0:
            self._cooldown -= 1
            if utilization >= self.cfg.high_watermark or overloaded:
                self.stats["suppressed_cooldown"] += 1
            return None
        if utilization >= self.cfg.high_watermark or overloaded:
            self._above += 1
            self._below = 0
        elif utilization <= self.cfg.low_watermark:
            self._below += 1
            self._above = 0
        else:
            self._above = self._below = 0
        c = self.cfg
        if self._above >= max(1, c.high_patience):
            hi = c.max_shards if c.max_shards is not None else n_shards
            target = min(hi, n_shards + c.grow_k)
            if target > n_shards:
                self.stats["grows"] += 1
                self.last_decision = f"grow->{target}"
                return target
            self._above = 0  # at the ceiling: nothing to do, stop counting
        if self._below >= max(1, c.low_patience):
            target = max(c.min_shards, n_shards - c.shrink_k)
            if target < n_shards:
                self.stats["shrinks"] += 1
                self.last_decision = f"shrink->{target}"
                return target
            self._below = 0  # at the floor
        return None

    def notify_resize(self, n_shards: int, *, external: bool = False) -> None:
        """Report a completed membership change (ours or anyone's).

        Resets both patience counters and starts the cooldown, so the
        controller re-learns the post-migration pressure before deciding
        again.  The fault layer calls this with ``external=True`` after a
        failure-LEAVE/regrow so the controller does not fight it.

        Args:
          n_shards: the shard count now in effect.
          external: the resize was NOT this controller's decision.
        """
        del n_shards  # the next observe() receives the live count anyway
        self._above = self._below = 0
        self._cooldown = self.cfg.cooldown
        if external:
            self.stats["external_resizes"] += 1
            self.last_decision = "external"

    # ------------------------------------------------------------ output ---
    def snapshot(self) -> dict:
        """Metrics-ready state: counters, watermarks, pending patience."""
        c = self.cfg
        return {"ticks": self.stats["ticks"], "grows": self.stats["grows"],
                "shrinks": self.stats["shrinks"],
                "suppressed_cooldown": self.stats["suppressed_cooldown"],
                "external_resizes": self.stats["external_resizes"],
                "last_decision": self.last_decision,
                "above_streak": self._above, "below_streak": self._below,
                "cooldown_left": self._cooldown,
                "high_watermark": c.high_watermark,
                "low_watermark": c.low_watermark}
