"""Admission control for the serving fabric: act BEFORE the wave loses data.

PR 5 made overflow a structured :class:`~repro.dqueue.QueueOverflowError`
and documented that it means *data loss* — by the time the replicated flag
reaches the host, a wrapped-around enqueue has already overwritten a live
head slot.  PR 7 gave the host a zero-cost view of the pressure that causes
it (per-window occupancy/headroom).  This module closes the loop: pluggable
policies that :meth:`repro.serve.ServeEngine.submit` consults against the
live occupancy vector *before* staging, so a full window rejects with a
structured, retryable :class:`AdmissionRejected` at the submit edge instead
of corrupting the queue mid-wave.

The decision inputs ride :class:`PressureSignal` — a host-side snapshot
built from the elastic wrappers' pre-wave pressure API
(``occupancy()`` / ``headroom()``; replicated scalars the last burst
already materialized, NO device round-trip) plus the engine's own staged
and spill bookkeeping, so admission adds no collectives and no dispatches
to the wave pipeline.

Three policies ship (``docs/BACKPRESSURE.md`` is the design doc):

``shed`` (:class:`ShedPolicy`)
    Reject what does not fit.  Within a contended window the *least
    urgent* requests are shed first — lowest tier (highest ``prio``
    number), then latest deadline, then latest arrival; on EDF engines
    requests whose deadline is already unmeetable (past, after shifting
    by the observed lateness p99) are shed before any request that can
    still make it.
``defer`` (:class:`DeferPolicy`)
    Hold what does not fit in a bounded host-side spill buffer; the
    engine re-offers spilled requests to the queue on every subsequent
    step as headroom frees up (oldest first, ahead of newer arrivals).
    A full spill buffer rejects the excess with a structured
    ``kind="spill-overflow"`` error — never a silent drop.
``degrade`` (:class:`DegradePolicy`)
    Trade SLA for admission: downgrade the request's tier (or extend its
    deadline into a less-loaded Seap bucket) until it fits, falling back
    to shed/defer when every alternative window is also full.

All three guarantee the invariant that matters: **no admitted request is
ever lost to overflow** — ``QueueOverflowError`` with a policy installed
is a bug, not an operational event.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence


@dataclasses.dataclass
class PressureSignal:
    """Host-side pressure snapshot an admission decision runs against.

    Built by the engine from the queue's pre-wave pressure API plus host
    bookkeeping; mutated in place (via :meth:`take`) as a decision
    reserves slots, so one signal stays consistent across a whole batch.

    Attributes:
      capacity: elements ONE window holds (per tier/bucket).
      occupancy: committed device occupancy per window (post last burst).
      staged: host-staged (submitted, not yet flushed) count per window.
      spill: current defer-buffer depth (requests already accepted but
        held host-side).
      spill_cap: defer-buffer bound.
      step: current engine step (EDF "now").
      mode: admission discipline — "fifo", "tiers", or "edf".
      lateness_p99: recent EDF lateness p99 in steps (0.0 when unknown);
        shifts the horizon behind which a deadline counts as doomed.
      drain_per_step: rough service-rate hint (engine slots) used for
        the retry-after estimate.
      window_of: maps a request to its window index (tier, Seap bucket,
        or 0 for FIFO).
      window_order: active window ids in *key* order (EDF bucket ids are
        not sorted by deadline range; tiers/FIFO leave this None for
        natural order) — the degrade policy walks "later" windows along
        this order.
      window_lo: window id → lowest key the window covers (EDF only);
        the deadline a degraded request is extended to.
    """

    capacity: int
    occupancy: List[int]
    staged: List[int]
    spill: int
    spill_cap: int
    step: int
    mode: str
    lateness_p99: float
    drain_per_step: int
    window_of: Callable
    window_order: Optional[List[int]] = None
    window_lo: Optional[dict] = None

    @property
    def n_windows(self) -> int:
        """Number of store windows (tiers / buckets; 1 for FIFO)."""
        return len(self.occupancy)

    def predicted(self, w: int) -> int:
        """Window ``w``'s occupancy once everything staged flushes."""
        return self.occupancy[w] + self.staged[w]

    def headroom(self, w: int) -> int:
        """Slots left in window ``w`` before an enqueue would wrap."""
        return self.capacity - self.predicted(w)

    def take(self, w: int) -> None:
        """Reserve one slot in window ``w`` (an admit/degrade decision)."""
        self.staged[w] += 1

    def deadline_for_window(self, req, w: int) -> int:
        """The extended (never shortened) deadline that lands ``req`` in
        EDF bucket ``w`` — the bucket's lowest covered key."""
        lo = (self.window_lo or {}).get(w, 0)
        return max(getattr(req, "deadline", 0), int(lo))

    def doomed(self, req) -> bool:
        """True when ``req``'s deadline is already unmeetable: it falls
        behind "now" shifted by the observed admission lateness p99."""
        if self.mode != "edf" or getattr(req, "deadline", -1) < 0:
            return False
        return req.deadline <= self.step + max(0.0, self.lateness_p99)

    def snapshot(self) -> dict:
        """Plain-dict copy for error payloads and metrics."""
        return {"capacity": self.capacity,
                "occupancy": list(self.occupancy),
                "staged": list(self.staged),
                "headroom": [self.headroom(w)
                             for w in range(self.n_windows)],
                "spill": self.spill, "spill_cap": self.spill_cap,
                "step": self.step, "mode": self.mode,
                "lateness_p99": self.lateness_p99}


class AdmissionRejected(RuntimeError):
    """A submit batch did not fully fit — and was refused *safely*.

    Raised by :meth:`repro.serve.ServeEngine.submit` after the fitting
    part of the batch has been staged/deferred: everything in
    :attr:`shed` was NOT registered with the engine and NOT staged, so
    the queue is untouched by it and the error is retryable —
    resubmit ``err.shed`` (optionally after ``err.retry_after`` steps)
    and nothing is double-admitted.

    Attributes:
      policy: name of the deciding policy ("shed" / "defer" / "degrade").
      kind: "shed" (policy rejected) or "spill-overflow" (defer buffer
        was full — the bounded buffer refused, it did not silently drop).
      shed: the rejected Request objects, in arrival order.
      admitted: how many of the batch WERE staged for the queue.
      deferred: how many went to the spill buffer instead.
      degraded: how many were admitted at a downgraded tier / extended
        deadline.
      pressure: :meth:`PressureSignal.snapshot` at decision time.
      retry_after: suggested steps to wait before resubmitting (excess
        over capacity divided by the engine's drain rate; >= 1).
    """

    def __init__(self, policy: str, kind: str, shed: Sequence, *,
                 admitted: int, deferred: int, degraded: int,
                 pressure: dict, retry_after: int = 1):
        self.policy = policy
        self.kind = kind
        self.shed = list(shed)
        self.admitted = int(admitted)
        self.deferred = int(deferred)
        self.degraded = int(degraded)
        self.pressure = dict(pressure)
        self.retry_after = max(1, int(retry_after))
        super().__init__(
            f"admission policy '{policy}' rejected {len(self.shed)} "
            f"request(s) [{kind}]: admitted={admitted} "
            f"deferred={deferred} degraded={degraded} against headroom "
            f"{pressure.get('headroom')} (capacity "
            f"{pressure.get('capacity')}); rejected requests were never "
            f"staged — resubmit after ~{self.retry_after} step(s)")


@dataclasses.dataclass
class AdmissionDecision:
    """What a policy decided for one submit batch (arrival order kept).

    ``spill_overflow`` counts sheds that happened only because the defer
    buffer was full — they surface as ``kind="spill-overflow"``.
    """

    admit: list
    shed: list
    defer: list
    degraded: int = 0
    spill_overflow: int = 0


def _urgency(req, sig: PressureSignal) -> tuple:
    """Sort key: most urgent first.  Lower tier number wins, then (EDF)
    meetable-before-doomed, then earlier deadline."""
    dl = getattr(req, "deadline", -1)
    return (getattr(req, "prio", 0), sig.doomed(req),
            dl if dl >= 0 else 0)


class AdmissionPolicy:
    """Base class: split a submit batch into admit / shed / defer.

    Subclasses override :meth:`overflow` to say what happens to the
    requests that do not fit their window; the shared :meth:`decide`
    walks the batch per window, keeps arrival order for everything that
    fits, and hands the *least urgent* overflow to :meth:`overflow`
    (lowest tier first, then latest deadline, then latest arrival — and
    on EDF engines, already-doomed deadlines are first in line).
    """

    name = "admit-all"

    def decide(self, reqs: Sequence, sig: PressureSignal) -> AdmissionDecision:
        """Decide the batch against ``sig`` (mutates its staged counts).

        Args:
          reqs: Request objects in arrival order.
          sig: live :class:`PressureSignal` for the engine's queue.

        Returns:
          An :class:`AdmissionDecision`; ``admit`` preserves the arrival
          order of the admitted subset.
        """
        order = {id(r): i for i, r in enumerate(reqs)}
        by_window: dict = {}
        for r in reqs:
            by_window.setdefault(sig.window_of(r), []).append(r)
        dec = AdmissionDecision([], [], [])
        for w, group in by_window.items():
            # most urgent first; stable, so arrival order breaks ties
            ranked = sorted(group, key=lambda r: _urgency(r, sig))
            room = max(0, sig.headroom(w))
            for r in ranked[:room]:
                sig.take(w)
                dec.admit.append(r)
            if len(ranked) > room:
                self.overflow(ranked[room:], w, sig, dec)
        dec.admit.sort(key=lambda r: order[id(r)])
        dec.shed.sort(key=lambda r: order[id(r)])
        dec.defer.sort(key=lambda r: order[id(r)])
        return dec

    def overflow(self, rest: list, w: int, sig: PressureSignal,
                 dec: AdmissionDecision) -> None:
        """Handle ``rest`` (least-urgent first would be ``reversed``):
        requests window ``w`` has no headroom for.  Base admits them
        anyway (admit-all — the pre-PR-8 behavior, will overflow)."""
        for r in rest:
            sig.take(w)
            dec.admit.append(r)


class ShedPolicy(AdmissionPolicy):
    """Reject what does not fit; never buffer, never lose queue data.

    Guarantees zero ``QueueOverflowError`` and bounded memory; the cost
    is that rejected work is the caller's to retry (the
    :class:`AdmissionRejected` it triggers carries the victims and a
    retry hint).  Victim order per contended window: lowest tier /
    doomed-deadline / latest deadline / latest arrival first.
    """

    name = "shed"

    def overflow(self, rest, w, sig, dec):
        """Shed every request the window has no headroom for."""
        dec.shed.extend(rest)


class DeferPolicy(AdmissionPolicy):
    """Hold what does not fit in the engine's bounded spill buffer.

    Deferred requests are accepted (registered, counted as pending) but
    wait host-side; the engine re-offers them ahead of newer arrivals on
    every subsequent step as headroom frees.  When the spill buffer
    itself is full the excess is rejected with
    ``AdmissionRejected(kind="spill-overflow")`` — bounded means
    *refuse*, not *drop*.
    """

    name = "defer"

    def overflow(self, rest, w, sig, dec):
        """Defer into spill space; excess past ``spill_cap`` is shed."""
        room = max(0, sig.spill_cap - sig.spill - len(dec.defer))
        # most urgent of the overflow get the spill space
        dec.defer.extend(rest[:room])
        dec.shed.extend(rest[room:])
        dec.spill_overflow += len(rest[room:])


class DegradePolicy(AdmissionPolicy):
    """Admit at a worse SLA instead of rejecting.

    On a tiered engine an overflowing request is retried one tier down
    (``prio + 1`` … lowest) until a window with headroom takes it; on an
    EDF engine its deadline is extended to the next Seap bucket with
    headroom.  When every alternative is full too, falls back to
    ``fallback`` ("shed" or "defer").  FIFO engines have a single
    window, so degrade always falls back there.

    Args:
      fallback: "shed" (default) or "defer" — what to do when no window
        can take the request even degraded.
    """

    name = "degrade"

    def __init__(self, fallback: str = "shed"):
        if fallback not in ("shed", "defer"):
            raise ValueError(f"fallback must be 'shed' or 'defer', "
                             f"got {fallback!r}")
        self._fb = ShedPolicy() if fallback == "shed" else DeferPolicy()

    def overflow(self, rest, w, sig, dec):
        """Retarget each overflow request to a less-loaded window."""
        for r in rest:
            w2 = self._retarget(r, w, sig)
            if w2 is None:
                self._fb.overflow([r], w, sig, dec)
            else:
                sig.take(w2)
                dec.degraded += 1
                dec.admit.append(r)

    def _retarget(self, r, w: int, sig: PressureSignal) -> Optional[int]:
        """First window after ``w`` (in key order) with headroom, mutating
        the request's tier/deadline to land there; None when full."""
        order = sig.window_order or list(range(sig.n_windows))
        try:
            at = order.index(w)
        except ValueError:
            return None
        for w2 in order[at + 1:]:
            if sig.headroom(w2) > 0:
                if sig.mode == "tiers":
                    r.prio = w2
                elif sig.mode == "edf":
                    r.deadline = sig.deadline_for_window(r, w2)
                return w2
        return None


_POLICIES = {"shed": ShedPolicy, "defer": DeferPolicy,
             "degrade": DegradePolicy}


def resolve_policy(spec) -> Optional[AdmissionPolicy]:
    """Normalize an ``admission=`` engine argument into a policy.

    Args:
      spec: None (admission off), a policy name ("shed" / "defer" /
        "degrade"), or an :class:`AdmissionPolicy` instance.

    Returns:
      The policy instance, or None.

    Raises:
      ValueError: unknown policy name.
    """
    if spec is None or isinstance(spec, AdmissionPolicy):
        return spec
    if isinstance(spec, str):
        if spec not in _POLICIES:
            raise ValueError(f"unknown admission policy {spec!r}; "
                             f"known: {sorted(_POLICIES)}")
        return _POLICIES[spec]()
    raise ValueError(f"admission= takes None, a name, or an "
                     f"AdmissionPolicy, got {type(spec).__name__}")
