from ..dqueue import QueueOverflowError, ServeInvariantError
from .engine import Request, ServeEngine

__all__ = ["QueueOverflowError", "Request", "ServeEngine",
           "ServeInvariantError"]
