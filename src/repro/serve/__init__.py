"""Serving layer: continuous batching fed by the SKUEUE device queue.

:class:`ServeEngine` is the entry point; PR 8 added the backpressure
control plane — admission policies (:mod:`repro.serve.admission`) and the
:class:`HysteresisController` autoscaler (:mod:`repro.serve.controller`).
See ``docs/BACKPRESSURE.md``.
"""
from ..dqueue import QueueOverflowError, ServeInvariantError
from .admission import (AdmissionPolicy, AdmissionRejected, DeferPolicy,
                        DegradePolicy, PressureSignal, ShedPolicy,
                        resolve_policy)
from .controller import ControllerConfig, HysteresisController
from .engine import Request, ServeEngine

__all__ = ["AdmissionPolicy", "AdmissionRejected", "ControllerConfig",
           "DeferPolicy", "DegradePolicy", "HysteresisController",
           "PressureSignal", "QueueOverflowError", "Request",
           "ServeEngine", "ShedPolicy", "ServeInvariantError",
           "resolve_policy"]
