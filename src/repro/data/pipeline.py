"""Deterministic data pipeline with a sequentially-consistent global order.

Sample content is a pure function of the global sample index (splitmix), so
any worker can materialize any sample.  The *order* in which samples are
consumed is the SKUEUE dequeue order: a producer enqueues sample indices,
DP workers dequeue — Definition 1 guarantees the global consumption order
is a single FIFO regardless of worker count or timing.  Consequences:

  * elastic determinism: resizing the worker fleet mid-run cannot reorder
    or drop samples (the queue state is the cursor);
  * restart determinism: the queue cursor (first/last) is checkpointed with
    the model, so a restarted run replays the identical stream.

On-device batches come from ``synthetic_tokens`` here (a corpus-backed
loader would swap in at the ``sample_index -> tokens`` seam).
"""
from __future__ import annotations


import numpy as np

from ..core.hashing import splitmix64


def synthetic_tokens(sample_idx: np.ndarray, seq_len: int,
                     vocab: int) -> np.ndarray:
    """Pure function of (sample_idx, t): a hash-driven random walk with
    small steps, so next-token prediction is learnable (p(next|cur) is
    concentrated) while remaining stateless and reproducible."""
    idx = np.asarray(sample_idx, np.uint64)[:, None]
    t = np.arange(seq_len, dtype=np.uint64)[None, :]
    with np.errstate(over="ignore"):
        h = splitmix64(idx * np.uint64(0x9E3779B97F4A7C15) + t)
        start = splitmix64(idx) % np.uint64(vocab)
    steps = (h % np.uint64(3)).astype(np.int64)  # walk steps in {0,1,2}
    walk = (start.astype(np.int64) + np.cumsum(steps, axis=1))
    return (walk % vocab).astype(np.int32)


class GlobalOrderPipeline:
    """Host-side view of the queue-ordered stream for one worker.

    The queue semantics collapse to an interval handout when the producer
    enqueues 0..N monotonically: dequeue order IS index order (that is
    exactly Definition 1's guarantee — validated against the protocol in
    tests/test_data_pipeline.py)."""

    def __init__(self, seq_len: int, vocab: int, global_batch: int,
                 start_index: int = 0):
        self.seq_len = seq_len
        self.vocab = vocab
        self.global_batch = global_batch
        self.cursor = start_index  # == queue `first`

    def state(self) -> dict:
        return {"cursor": self.cursor}

    def restore(self, state: dict):
        self.cursor = int(state["cursor"])

    def next_batch(self, n_workers: int = 1, worker: int = 0):
        """Global batch, sliced for this worker. Advances the cursor."""
        idx = np.arange(self.cursor, self.cursor + self.global_batch)
        self.cursor += self.global_batch
        per = self.global_batch // n_workers
        mine = idx[worker * per:(worker + 1) * per]
        toks = synthetic_tokens(mine, self.seq_len + 1, self.vocab)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:],
                "sample_indices": mine}

    def batch_at_step(self, step: int, n_workers: int = 1, worker: int = 0):
        """Pure function of step — restart/elastic determinism by construction."""
        base = step * self.global_batch
        idx = np.arange(base, base + self.global_batch)
        per = self.global_batch // n_workers
        mine = idx[worker * per:(worker + 1) * per]
        toks = synthetic_tokens(mine, self.seq_len + 1, self.vocab)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:],
                "sample_indices": mine}
