from .pipeline import GlobalOrderPipeline, synthetic_tokens

__all__ = ["GlobalOrderPipeline", "synthetic_tokens"]
