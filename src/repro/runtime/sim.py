"""SimRuntime: LocalRuntime + a declarative wire-latency model +
scheduled failure injection.

The container has no real wire, but the cost models this repo ships —
the ~1-collective migration bound (BENCH_PR2), the pipelined K+1-launch
burst schedule, the backpressure/autoscale loop — are all *stated in
collective launches and bytes moved*, which means they can be priced
under any latency regime by pure arithmetic: count the launches the
wave stack actually performed, multiply by a modeled per-launch /
per-byte cost.  SimRuntime does exactly that, accumulating a simulated
wire clock next to the real one, and additionally raises scheduled
:class:`~repro.fault.failures.ShardFailure`\\ s keyed by **stable
device id** so churn experiments compose with the fault layer.

Latency-model schema (see docs/RUNTIME.md)::

    LatencyModel(
        base_us=100.0,          # per collective launch, microseconds
        per_mib_us=8.0,         # per MiB on the wire, microseconds
        per_collective={        # optional per-kind overrides
            "all_to_all": {"base_us": 120.0},
            "all_reduce": {"base_us": 40.0, "per_mib_us": 2.0},
        })

Charging rules (pinned by ``tests/test_runtime.py``):

* a K-wave burst charges ``K + 1`` all_to_all launches when pipelined
  (the engine's fused request_k ‖ reply_{k-1} schedule) and ``2 K``
  sequential, each carrying the ``n_shards * width`` request envelope
  of ``4 * (2 + W)`` bytes per op row (slot ‖ tag ‖ payload columns);
* a migration wave charges 1 all_to_all carrying ``stats["bytes_moved"]``
  plus 2 scalar all_reduce launches (the lost-element pmax and the
  moved-count psum), and annotates the migration stats dict with the
  charged ``sim_s``.

Everything is host arithmetic at burst boundaries — the device programs
are untouched, so results stay bit-identical to LocalRuntime.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from .local import LocalRuntime

_MIB = float(1 << 20)


@dataclasses.dataclass
class LatencyModel:
    """Per-collective wire cost: ``base_us`` per launch plus
    ``per_mib_us`` per MiB moved, with optional per-kind overrides."""

    base_us: float = 0.0
    per_mib_us: float = 0.0
    per_collective: Dict[str, dict] = dataclasses.field(
        default_factory=dict)

    def latency_s(self, kind: str, nbytes: int = 0) -> float:
        """Modeled seconds for ONE ``kind`` launch of ``nbytes``."""
        o = self.per_collective.get(kind, {})
        base = float(o.get("base_us", self.base_us))
        per_mib = float(o.get("per_mib_us", self.per_mib_us))
        return (base + per_mib * (nbytes / _MIB)) * 1e-6


class SimRuntime(LocalRuntime):
    """LocalRuntime with a simulated wire.

    Args:
      latency: the :class:`LatencyModel` (default: a free wire).
      fail_at: ``{step: device_id}`` schedule — :meth:`maybe_fail`
        raises a :class:`~repro.fault.failures.ShardFailure` carrying
        the stable ``device_id`` the first time each step is reached
        (the fault layer calls it once per step).
      devices / axis_name: as for LocalRuntime.
    """

    kind = "sim"

    def __init__(self, latency: Optional[LatencyModel] = None,
                 devices=None, axis_name: str = "data",
                 fail_at: Optional[Dict[int, int]] = None):
        super().__init__(devices=devices, axis_name=axis_name)
        self.latency = latency or LatencyModel()
        self.fail_at = dict(fail_at or {})
        self._fired: set = set()
        self.sim_time_s = 0.0
        self.counts: Dict[str, int] = {}
        self.bytes_by_kind: Dict[str, int] = {}

    # ------------------------------------------------------- charging ------
    def collective_latency(self, kind: str, nbytes: int = 0) -> float:
        return self.latency.latency_s(kind, nbytes)

    def charge(self, kind: str, launches: int, nbytes_each: int = 0
               ) -> float:
        """Charge ``launches`` collectives of ``nbytes_each`` to the sim
        clock; returns the seconds added."""
        dt = launches * self.latency.latency_s(kind, nbytes_each)
        self.sim_time_s += dt
        self.counts[kind] = self.counts.get(kind, 0) + int(launches)
        self.bytes_by_kind[kind] = (self.bytes_by_kind.get(kind, 0)
                                    + int(launches) * int(nbytes_each))
        return dt

    @staticmethod
    def burst_launches(n_waves: int, pipelined: bool) -> int:
        """all_to_all launches a K-wave burst performs: K+1 pipelined
        (request_k ‖ reply_{k-1} fuse), 2K sequential."""
        return n_waves + 1 if pipelined else 2 * n_waves

    @staticmethod
    def wave_envelope_bytes(n_shards: int, width: int,
                            payload_width: int) -> int:
        """Bytes one wave's request envelope puts on the wire:
        ``n_shards * width`` op rows of ``slot ‖ tag ‖ payload`` int32
        columns."""
        return n_shards * width * 4 * (2 + payload_width)

    def on_burst(self, kind: str, n_waves: int, n_shards: int, *,
                 width: int, payload_width: int,
                 pipelined: bool = True) -> None:
        self.charge("all_to_all",
                    self.burst_launches(n_waves, pipelined),
                    self.wave_envelope_bytes(n_shards, width,
                                             payload_width))

    def on_migration(self, stats: dict) -> None:
        dt = self.charge("all_to_all", 1, int(stats.get("bytes_moved", 0)))
        dt += self.charge("all_reduce", 2, 4)
        stats["sim_s"] = dt

    # ------------------------------------------------------- failures ------
    def maybe_fail(self, step: int) -> None:
        step = int(step)
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            from ..fault.failures import ShardFailure
            raise ShardFailure(None, step,
                               device_id=int(self.fail_at[step]))

    def snapshot(self) -> dict:
        snap = super().snapshot()
        snap.update(sim_time_s=self.sim_time_s,
                    collectives=dict(self.counts),
                    bytes_by_kind=dict(self.bytes_by_kind),
                    latency=dataclasses.asdict(self.latency))
        return snap
