"""The pluggable mesh-runtime layer (PR 10): one seam between the wave
stack and physical devices.  See :mod:`repro.runtime.base` for the
contract and docs/RUNTIME.md for launch recipes."""
from .base import (ProcessRole, Runtime, as_runtime, build_mesh,
                   select_devices)
from .distributed import DistributedRuntime
from .launcher import ProcResult, find_free_port, launch_localhost
from .local import LocalRuntime
from .sim import LatencyModel, SimRuntime

__all__ = [
    "Runtime", "ProcessRole", "as_runtime", "build_mesh",
    "select_devices", "LocalRuntime", "SimRuntime", "LatencyModel",
    "DistributedRuntime", "launch_localhost", "find_free_port",
    "ProcResult",
]
