"""The Runtime contract: who owns devices, meshes, and the wire.

Before PR 10 every layer of the wave stack touched jax device state
directly — ``jax.devices()`` in the elastic wrappers, ``jax.sharding.
Mesh`` construction in ``launch/mesh.py``, ad-hoc ``device_put`` staging
in the migration path — so the protocol could only ever run on the
one-process XLA mesh it was developed on, while the paper defines it for
the *asynchronous message-passing model* (processes that join, leave,
and exchange messages over a wire).  :class:`Runtime` is the one seam
between the two: everything above it (``WaveEngine``, the disciplines,
the elastic wrappers, ``ServeEngine``, the fault layer, Wavescope)
speaks in *stable device ids* and runtime-built meshes, and the three
implementations decide what a shard physically is:

* :class:`~repro.runtime.local.LocalRuntime` — today's single-process
  path (absorbs ``launch/mesh.make_elastic_mesh``); host staging is
  ``np.asarray``, placement is a no-op, ``sync`` is a no-op.
* :class:`~repro.runtime.distributed.DistributedRuntime` — a
  ``jax.distributed.initialize`` multi-controller over localhost TCP:
  a shard is a *process*, LEAVE means a process dropping out of the
  live set, and the packed-migration wave is a real cross-process
  reshard.  Host staging is a ``process_allgather``; op placement is an
  explicit global ``device_put``.
* :class:`~repro.runtime.sim.SimRuntime` — LocalRuntime plus a
  declarative per-collective latency model and scheduled
  ``ShardFailure`` injection, so migration/backpressure cost models can
  be measured under microseconds-to-milliseconds wire regimes without
  hardware.

Stable identity
---------------
A device's ``.id`` is its stable identity for the lifetime of the
runtime (for ``DistributedRuntime`` it is the global jax device id, so
it also encodes the owning process).  Every membership operation above
the runtime — failure attribution, quarantine, reshard — is keyed by
these ids, never by mesh index: a mesh index is only stable while the
membership never changes, which is exactly the assumption elasticity
breaks (the PR 10 failure-rekey bugfix).

Failure quarantine
------------------
``mark_failed(device_id)`` removes a device from :meth:`Runtime.pool`
permanently.  The elastic wrappers draw JOIN capacity from ``pool()``,
so a quarantined device can never be handed back out by a later
``grow`` — the regression the resurrection test pins down.
"""
from __future__ import annotations

from typing import Dict, List, NamedTuple, Optional, Sequence

import numpy as np

import jax


class ProcessRole(NamedTuple):
    """This process's place in the runtime: ``index`` of ``count``
    processes; ``coordinator`` is True exactly for process 0 (the one
    that should write artifacts / drive single-writer side effects)."""
    index: int
    count: int
    coordinator: bool


def select_devices(devs: Sequence, n_shards: int, exclude=()) -> list:
    """Subset selection for a one-axis elastic mesh: drop ``exclude``,
    then take the first ``n_shards`` of what survives.

    ``exclude`` entries may be device objects or bare device ids.
    Raises with the offending device named when the exclusion makes
    ``n_shards`` unsatisfiable — the caller excluded a *specific* failed
    device, so the error must say which exclusion broke the build
    instead of a bare count mismatch.
    """
    devs = list(devs)
    excl_ids = {d if isinstance(d, int) else d.id for d in exclude}
    live = [d for d in devs if d.id not in excl_ids]
    if not 1 <= n_shards <= len(live):
        hit = sorted(i for i in excl_ids if any(d.id == i for d in devs))
        if hit:
            raise ValueError(
                f"cannot build a {n_shards}-shard mesh: excluding "
                f"device id(s) {hit} leaves only {len(live)} of "
                f"{len(devs)} devices")
        raise ValueError(
            f"cannot build a {n_shards}-shard mesh from {len(live)} "
            f"devices")
    return live[:n_shards]


def build_mesh(devices: Sequence, axis_name: str):
    """A one-axis ``jax.sharding.Mesh`` over an explicit device list
    (unlike ``jax.make_mesh`` this never consults global device state,
    so it can build over fewer devices than the process owns)."""
    arr = np.empty((len(devices),), dtype=object)
    for i, d in enumerate(devices):
        arr[i] = d
    return jax.sharding.Mesh(arr, (axis_name,))


class Runtime:
    """Base contract + shared machinery (mesh cache, id bookkeeping,
    failure quarantine).  Subclasses supply the device pool and the
    host/wire data plane."""

    kind: str = "base"

    def __init__(self, axis_name: str = "data"):
        self.axis_name = axis_name
        self._failed: set = set()
        self._mesh_cache: Dict[tuple, object] = {}

    # ------------------------------------------------------- topology ------
    def all_devices(self) -> list:
        """Every device this runtime was built over, failed included,
        in stable order.  Subclasses must implement."""
        raise NotImplementedError

    def pool(self) -> list:
        """Live (non-quarantined) devices, in stable order.  JOIN
        capacity is drawn from here — a device marked failed never
        reappears."""
        return [d for d in self.all_devices() if d.id not in self._failed]

    @property
    def pool_size(self) -> int:
        """Number of live devices (the hard upper bound on shards)."""
        return len(self.pool())

    @property
    def n_shards(self) -> int:
        """Default shard count: one shard per live device."""
        return self.pool_size

    @property
    def process_role(self) -> ProcessRole:
        """This process's (index, count, coordinator) role."""
        return ProcessRole(0, 1, True)

    def device_ids(self, devices=None) -> List[int]:
        """Stable ids for ``devices`` (default: the live pool)."""
        return [d.id for d in (self.pool() if devices is None else devices)]

    def reshard_devices(self, live_ids: Sequence[int]) -> list:
        """Map stable device ids back to device objects, in the given
        order — the id->device half of a reshard.  Raises when an id is
        unknown or quarantined (resharding onto a failed device is the
        resurrection bug this layer exists to prevent)."""
        by_id = {d.id: d for d in self.all_devices()}
        out = []
        for i in live_ids:
            i = int(i)
            if i not in by_id:
                raise ValueError(f"unknown device id {i} (known: "
                                 f"{sorted(by_id)})")
            if i in self._failed:
                raise ValueError(f"device id {i} is quarantined (failed) "
                                 f"— cannot reshard onto it")
            out.append(by_id[i])
        return out

    def mesh(self, devices=None, *, n_shards: Optional[int] = None,
             exclude=()):
        """A cached one-axis mesh.

        With ``devices`` the mesh spans exactly that list (the elastic
        wrappers pass their active set).  Otherwise the subset is
        selected from the live pool: ``exclude`` first, then the first
        ``n_shards`` survivors (default: all).  Identical device sets
        return the identical Mesh object, so jit executable caches keyed
        on the mesh stay warm across membership bounces."""
        if devices is None:
            pool = self.pool()
            devices = select_devices(
                pool, len(pool) if n_shards is None else n_shards, exclude)
        key = tuple(d.id for d in devices)
        if key not in self._mesh_cache:
            self._mesh_cache[key] = build_mesh(devices, self.axis_name)
        return self._mesh_cache[key]

    # ------------------------------------------------------- liveness ------
    def mark_failed(self, device_id: int) -> None:
        """Quarantine a device by stable id: it leaves :meth:`pool`
        permanently, so JOIN can never resurrect state onto it."""
        self._failed.add(int(device_id))

    @property
    def failed_ids(self) -> frozenset:
        """Stable ids of every quarantined device."""
        return frozenset(self._failed)

    # ----------------------------------------------------- data plane ------
    def to_host(self, x) -> np.ndarray:
        """Materialize a (possibly sharded) global array on this host.
        Subclasses override when local addressability is partial."""
        return np.asarray(x)

    def put(self, x, sharding):
        """Place a host array under an explicit sharding."""
        return jax.device_put(x, sharding)

    def place(self, x, mesh, lead: int = 0):
        """Stage one wave-op array onto ``mesh`` (sharded on
        ``axis_name`` after ``lead`` unsharded leading dims).  The local
        runtimes keep this a zero-cost ``jnp.asarray`` so the
        single-process wave path is bit-identical to the pre-runtime
        code; the distributed runtime must build a global array."""
        import jax.numpy as jnp
        return jnp.asarray(x)

    def sync(self) -> None:
        """Barrier across every process in the runtime (no-op when
        there is only one)."""

    # ------------------------------------------------ injection hooks ------
    def collective_latency(self, kind: str, nbytes: int = 0) -> float:
        """Modeled seconds one ``kind`` collective of ``nbytes`` costs
        (0 everywhere except SimRuntime)."""
        return 0.0

    def on_burst(self, kind: str, n_waves: int, n_shards: int, *,
                 width: int, payload_width: int,
                 pipelined: bool = True) -> None:
        """Burst-boundary notification from the elastic drivers: a
        K-wave burst was dispatched.  No-op except under SimRuntime,
        which charges the modeled all_to_all launches."""

    def on_migration(self, stats: dict) -> None:
        """Migration-wave notification (the PR 2 reshard); SimRuntime
        charges the wire model and annotates ``stats`` in place."""

    def maybe_fail(self, step: int) -> None:
        """Scheduled-failure hook (SimRuntime raises ``ShardFailure``
        here); the fault layer calls it once per step."""

    def snapshot(self) -> dict:
        """Metrics-ready description of this runtime."""
        role = self.process_role
        return {"kind": self.kind, "axis_name": self.axis_name,
                "pool_size": self.pool_size,
                "failed_ids": sorted(self._failed),
                "process_index": role.index,
                "process_count": role.count}


def as_runtime(mesh_or_runtime, axis_name: str = "data", runtime=None):
    """Normalize a constructor's mesh-or-runtime first argument.

    Returns ``(runtime, mesh, axis_name)``.  A Runtime yields its own
    default mesh; a bare Mesh is adopted into a fresh LocalRuntime over
    exactly its devices — the SAME Mesh object is returned, so jit
    caches keyed on mesh identity are unaffected by the wrapping.  An
    explicit ``runtime`` pins the owning runtime while keeping the
    caller's mesh (the elastic wrappers hand their subset mesh down to
    the fixed-mesh inner queues this way)."""
    from .local import LocalRuntime
    if runtime is not None:
        mesh = mesh_or_runtime
        if mesh is None or isinstance(mesh, Runtime):
            mesh = runtime.mesh()
        return runtime, mesh, runtime.axis_name
    if isinstance(mesh_or_runtime, Runtime):
        rt = mesh_or_runtime
        return rt, rt.mesh(), rt.axis_name
    mesh = mesh_or_runtime
    rt = LocalRuntime(devices=list(mesh.devices.flat), axis_name=axis_name)
    rt.adopt_mesh(mesh)
    return rt, mesh, axis_name
