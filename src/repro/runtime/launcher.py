"""Localhost multi-process launcher for DistributedRuntime.

Spawns ``n_procs`` python subprocesses, each forced to
``devs_per_proc`` CPU devices, wired to one coordinator port via the
``REPRO_RT_*`` environment (which ``DistributedRuntime.from_env``
consumes).  This is how the multiprocess CI leg, the distributed
differential test, and the BENCH_PR10 wire measurement all run 2
processes x 4 CPU devices on one machine.

The child is an ordinary python program: a script path, or inline code
via ``code=``.  Its first jax-touching line should be
``DistributedRuntime.from_env()`` (device-count forcing only works
before jax initializes, which is why it must ride the child's
environment rather than a jax call).
"""
from __future__ import annotations

import os
import socket
import subprocess
import sys
from typing import Dict, List, NamedTuple, Optional, Sequence

from .distributed import ENV_COORD, ENV_NPROCS, ENV_PID


def find_free_port(host: str = "127.0.0.1") -> int:
    """An OS-assigned free TCP port (released immediately; the race
    window is acceptable for localhost test launches)."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind((host, 0))
        return s.getsockname()[1]


class ProcResult(NamedTuple):
    """One child's outcome."""
    process_id: int
    returncode: int
    stdout: str
    stderr: str


def _child_env(pid: int, n_procs: int, devs_per_proc: int, coord: str,
               extra_env: Optional[Dict[str, str]]) -> Dict[str, str]:
    env = dict(os.environ)
    env.update({
        ENV_COORD: coord,
        ENV_NPROCS: str(n_procs),
        ENV_PID: str(pid),
        "JAX_PLATFORMS": "cpu",
        "XLA_FLAGS": f"--xla_force_host_platform_device_count="
                     f"{devs_per_proc}",
    })
    if extra_env:
        env.update(extra_env)
    return env


def launch_localhost(script: Optional[str] = None, *,
                     code: Optional[str] = None,
                     args: Sequence[str] = (),
                     n_procs: int = 2, devs_per_proc: int = 4,
                     timeout: float = 600.0,
                     extra_env: Optional[Dict[str, str]] = None,
                     check: bool = True) -> List[ProcResult]:
    """Run ``n_procs`` copies of a python program as one jax world.

    Args:
      script: path to a python file to run (mutually exclusive with
        ``code``); ``code`` runs inline via ``python -c``.
      args: extra argv passed to every child.
      n_procs / devs_per_proc: world shape (total shards =
        ``n_procs * devs_per_proc``).
      timeout: per-child wait in seconds (the world hangs if any child
        dies before ``initialize`` — the timeout is the backstop).
      extra_env: additional environment for every child.
      check: raise ``RuntimeError`` (with the failing child's stderr)
        on any nonzero exit.

    Returns:
      One :class:`ProcResult` per process, in process-id order.
    """
    if (script is None) == (code is None):
        raise ValueError("pass exactly one of script= or code=")
    coord = f"127.0.0.1:{find_free_port()}"
    cmd = [sys.executable]
    cmd += ["-c", code] if code is not None else [script]
    cmd += list(args)
    procs = []
    for pid in range(n_procs):
        procs.append(subprocess.Popen(
            cmd, env=_child_env(pid, n_procs, devs_per_proc, coord,
                                extra_env),
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True))
    results: List[ProcResult] = []
    try:
        for pid, p in enumerate(procs):
            out, err = p.communicate(timeout=timeout)
            results.append(ProcResult(pid, p.returncode, out, err))
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
                p.communicate()
    if check:
        for r in results:
            if r.returncode != 0:
                raise RuntimeError(
                    f"distributed child {r.process_id}/{n_procs} exited "
                    f"{r.returncode}\n--- stdout ---\n{r.stdout}\n"
                    f"--- stderr ---\n{r.stderr}")
    return results
