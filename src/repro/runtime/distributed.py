"""DistributedRuntime: multi-controller jax over localhost TCP.

``jax.distributed.initialize`` turns N CPU processes into one jax
runtime: every process sees the *global* device list, collectives run
over a real socket (the gloo CPU collectives implementation), and a
``shard_map`` program over a cross-process mesh is a genuine
message-passing execution of the paper's protocol — a shard is a
process, LEAVE is a process dropping out of the live mesh, and the
PR 2 packed-migration wave is a real cross-process reshard.

What changes relative to LocalRuntime (and is encapsulated here so the
wave stack above does not care):

* **op staging** — a host numpy array is only *locally* addressable;
  :meth:`place` builds the global array with an explicit ``device_put``
  under the wave's NamedSharding (every process passes the same host
  values, which the single-controller-per-process model requires);
* **host reads** — ``np.asarray`` works only on fully-replicated
  arrays; :meth:`to_host` falls back to a tiled ``process_allgather``
  for sharded ones (the migration path's store staging);
* **barriers** — :meth:`sync` is a real cross-process barrier
  (``multihost_utils.sync_global_devices``).

Launch recipe (see also :mod:`repro.runtime.launcher` and
docs/RUNTIME.md): every process must force the same per-process device
count *before* jax initializes, then::

    rt = DistributedRuntime.initialize(
        coordinator="127.0.0.1:9911", num_processes=2, process_id=pid)

or export ``REPRO_RT_COORD`` / ``REPRO_RT_NPROCS`` / ``REPRO_RT_PID``
and call :meth:`DistributedRuntime.from_env`.
"""
from __future__ import annotations

import os

import numpy as np

import jax

from .base import ProcessRole, Runtime

ENV_COORD = "REPRO_RT_COORD"
ENV_NPROCS = "REPRO_RT_NPROCS"
ENV_PID = "REPRO_RT_PID"


class DistributedRuntime(Runtime):
    """Runtime over an already-initialized ``jax.distributed`` world:
    the pool is the *global* device list (every process's devices), and
    the data plane is cross-process."""

    kind = "distributed"

    def __init__(self, axis_name: str = "data"):
        super().__init__(axis_name)
        if jax.process_count() < 2:
            raise RuntimeError(
                "DistributedRuntime needs an initialized multi-process "
                "jax world (jax.process_count() >= 2) — call "
                "DistributedRuntime.initialize(...) first, or use "
                "LocalRuntime for the single-process path")
        self._devices = list(jax.devices())

    # ---------------------------------------------------------- launch -----
    @classmethod
    def initialize(cls, coordinator: str, num_processes: int,
                   process_id: int, axis_name: str = "data"
                   ) -> "DistributedRuntime":
        """Join the multi-controller world and build the runtime.

        Selects the gloo CPU collectives implementation (the only one
        that works over plain TCP sockets on CPU), then blocks in
        ``jax.distributed.initialize`` until all ``num_processes``
        processes have connected to ``coordinator`` (``host:port``;
        process 0 hosts it).  Must run before any other jax device use
        in the process."""
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
        jax.distributed.initialize(coordinator_address=coordinator,
                                   num_processes=num_processes,
                                   process_id=process_id)
        return cls(axis_name=axis_name)

    @classmethod
    def from_env(cls, axis_name: str = "data") -> "DistributedRuntime":
        """:meth:`initialize` from the launcher's environment variables
        (``REPRO_RT_COORD`` / ``REPRO_RT_NPROCS`` / ``REPRO_RT_PID``)."""
        try:
            coord = os.environ[ENV_COORD]
            nprocs = int(os.environ[ENV_NPROCS])
            pid = int(os.environ[ENV_PID])
        except KeyError as e:
            raise RuntimeError(
                f"DistributedRuntime.from_env: {e.args[0]} is not set — "
                "launch via repro.runtime.launcher or export "
                f"{ENV_COORD}/{ENV_NPROCS}/{ENV_PID}") from None
        return cls.initialize(coord, nprocs, pid, axis_name=axis_name)

    # -------------------------------------------------------- topology -----
    def all_devices(self) -> list:
        return list(self._devices)

    @property
    def process_role(self) -> ProcessRole:
        idx = jax.process_index()
        return ProcessRole(idx, jax.process_count(), idx == 0)

    def local_devices(self) -> list:
        """The devices THIS process owns (addressable subset of the
        pool)."""
        return [d for d in self._devices
                if d.process_index == jax.process_index()]

    # ------------------------------------------------------ data plane -----
    def to_host(self, x) -> np.ndarray:
        if getattr(x, "is_fully_replicated", True):
            return np.asarray(x)
        from jax.experimental import multihost_utils
        return np.asarray(multihost_utils.process_allgather(x, tiled=True))

    def put(self, x, sharding):
        # a committed single-device jax array cannot be re-placed onto a
        # sharding spanning other processes — stage through host numpy
        return jax.device_put(np.asarray(x), sharding)

    def place(self, x, mesh, lead: int = 0):
        from jax.sharding import NamedSharding, PartitionSpec as P
        spec = P(*((None,) * lead + (self.axis_name,)))
        return jax.device_put(np.asarray(x), NamedSharding(mesh, spec))

    def sync(self) -> None:
        from jax.experimental import multihost_utils
        multihost_utils.sync_global_devices("repro.runtime.sync")
