"""LocalRuntime: the single-process mesh runtime (the pre-PR 10 path).

One process owns every device; a shard is a device.  This runtime is
deliberately *transparent*: ``place`` is a plain ``jnp.asarray``,
``to_host`` is ``np.asarray``, ``sync`` is a no-op — so the wave stack
running over a LocalRuntime executes the exact same operations as the
pre-runtime code, and the existing differential oracles, HLO budgets,
and recompile guards pass unchanged (the behavior-preservation proof
the PR 10 refactor rests on).
"""
from __future__ import annotations

import jax

from .base import Runtime


class LocalRuntime(Runtime):
    """Single-process runtime over an explicit device pool (default:
    every device the process owns)."""

    kind = "local"

    def __init__(self, devices=None, axis_name: str = "data"):
        super().__init__(axis_name)
        self._devices = (list(devices) if devices is not None
                         else list(jax.devices()))
        if not self._devices:
            raise ValueError("LocalRuntime needs at least one device")

    def all_devices(self) -> list:
        return list(self._devices)

    def adopt_mesh(self, mesh) -> None:
        """Seed the mesh cache with a caller-built Mesh object so code
        that already holds a mesh (the fixed-mesh structures' back-compat
        constructors) keeps its exact object identity — jit executable
        caches key on it."""
        devs = list(mesh.devices.flat)
        self._mesh_cache[tuple(d.id for d in devs)] = mesh
