"""whisper-small [audio]: 12L enc + 12L dec, d_model=768 12H d_ff=3072
vocab=51865 — encoder-decoder; conv frontend is a STUB (input_specs provides
precomputed frame embeddings).  Source: Whisper [arXiv:2212.04356]."""
from . import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="encdec",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab=51865, enc_layers=12, enc_seq=1500,
)
