"""mamba2-130m [ssm]: 24L d_model=768, attn-free, vocab=50280, state=128.
Source: SSD / Mamba-2 [arXiv:2405.21060]."""
from . import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=50280, ssm_state=128, ssm_headdim=64, ssm_expand=2,
)
