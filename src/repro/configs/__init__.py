"""Architecture configs: one module per assigned architecture.

Each config is an :class:`ArchConfig`; ``get_config(name)`` resolves by id.
``SHAPES`` defines the assigned input-shape set (same for every LM arch).
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, replace
from typing import Optional

ARCH_IDS = [
    "mamba2_130m", "zamba2_1p2b", "whisper_small", "granite_moe_1b",
    "mixtral_8x22b", "mistral_large_123b", "granite_3_8b", "llama3_8b",
    "internlm2_20b", "llava_next_34b",
]

# shape name -> (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_ep: bool = False         # expert-parallel (vs tensor-parallel experts)
    capacity_factor: float = 1.25
    # --- SSM (Mamba-2) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    conv_kernel: int = 4
    # --- hybrid (Zamba2-style shared attention block) ---
    attn_every: int = 0          # 0 = no interleaved attention
    # --- attention ---
    window: Optional[int] = None  # sliding-window attention
    rope_theta: float = 1e6
    # --- encoder-decoder (Whisper) ---
    enc_layers: int = 0
    enc_seq: int = 1500
    # --- VLM ---
    n_vision_tokens: int = 0
    # --- misc ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.n_heads))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / sliding-window)."""
        return self.family in ("ssm", "hybrid") or self.window is not None

    def reduced(self, **over) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 2), d_model=128,
            n_heads=4, n_kv_heads=min(self.n_kv_heads, 2) or 2,
            d_ff=256, vocab=512, head_dim=32,
        )
        if self.n_experts:
            # dropless at smoke scale so decode == prefill is exact
            small.update(n_experts=4, top_k=min(self.top_k, 2),
                         capacity_factor=8.0)
        if self.ssm_state:
            small.update(ssm_state=16, ssm_headdim=16)
        if self.attn_every:
            small.update(attn_every=2, n_layers=4)
        if self.enc_layers:
            small.update(enc_layers=2, enc_seq=16)
        if self.n_vision_tokens:
            small.update(n_vision_tokens=8)
        if self.window:
            small.update(window=32)
        small.update(over)
        return replace(self, **small)


def get_config(name: str) -> ArchConfig:
    key = name.replace("-", "_").replace(".", "p")
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(f".{key}", __package__)
    return mod.CONFIG


def shape_applicable(cfg: ArchConfig, shape: str) -> bool:
    """long_500k only for sub-quadratic archs (DESIGN.md §5)."""
    if shape == "long_500k":
        return cfg.sub_quadratic
    return True
