"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling; the vision tower is a STUB (input_specs
provides precomputed patch embeddings, 2880 tokens = 5 anyres tiles x 576).
Source: hf:llava-hf/llava-v1.6 family."""
from . import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20480,
    vocab=64000, n_vision_tokens=2880,
)
