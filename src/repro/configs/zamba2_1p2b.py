"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention blocks.
Source: Zamba2 [arXiv:2411.15242]."""
from . import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=32000, ssm_state=64, ssm_headdim=64, ssm_expand=2,
    attn_every=6,  # shared transformer block applied every 6 mamba layers
)
