"""Fault-tolerance runtime: failure injection + restart-from-checkpoint.

At fleet scale a node failure kills the whole SPMD step; recovery is
checkpoint-restart (possibly on a resized slice — the elastic path through
``checkpoint.restore_sharded``).  ``run_with_restarts`` is that control
loop, made testable: a :class:`FailureInjector` raises ``SimulatedFailure``
at chosen steps, and the loop restores from the last committed checkpoint
and continues.  Determinism: the data pipeline is indexed by global step,
so a restarted run replays identical batches (asserted in tests)."""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Optional

from ..checkpoint import latest_step, load_checkpoint, save_checkpoint


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    fail_at_steps: tuple = ()
    fired: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(f"injected node failure at step {step}")


def run_with_restarts(*, init_state: Callable[[], tuple],
                      step_fn: Callable[[tuple, int], tuple],
                      n_steps: int, ckpt_dir, ckpt_every: int = 10,
                      injector: Optional[FailureInjector] = None,
                      max_restarts: int = 10, log: Callable = print):
    """Run ``step_fn(state, step) -> state`` for n_steps with checkpointing.

    On failure: reload the latest checkpoint and resume from its step.
    Returns (state, metrics: dict with restart/step accounting)."""
    restarts = 0
    metrics = {"restarts": 0, "steps_replayed": 0, "steps_run": 0}
    while True:
        start = latest_step(ckpt_dir)
        state = init_state()
        step0 = 0
        if start is not None:
            host, manifest = load_checkpoint(ckpt_dir, start, state)
            state = host
            step0 = int(manifest["step"])
            log(f"[fault] restored step {step0}")
        try:
            for step in range(step0, n_steps):
                if injector is not None:
                    injector.maybe_fail(step)
                state = step_fn(state, step)
                metrics["steps_run"] += 1
                if (step + 1) % ckpt_every == 0 or step + 1 == n_steps:
                    save_checkpoint(ckpt_dir, step + 1, state)
            metrics["restarts"] = restarts
            return state, metrics
        except SimulatedFailure as e:
            restarts += 1
            log(f"[fault] {e}; restarting ({restarts})")
            if restarts > max_restarts:
                raise
