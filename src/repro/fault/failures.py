"""Fault-tolerance runtime: failure injection, restart-from-checkpoint, and
elastic shrink-on-failure.

At fleet scale a node failure kills the whole SPMD step.  Two recovery
policies are provided, composable in one control loop:

* **checkpoint-restart** (the classic): reload the last committed
  checkpoint and replay.  Works for any failure, costs replayed steps.
  ``checkpoint.restore_sharded`` makes the restart elastic at the training
  level — the reload may land on a resized slice.
* **shrink-on-failure** (the paper's LEAVE, PR 2): when the failure
  identifies a dead shard (:class:`ShardFailure`) and the caller supplies an
  :class:`ElasticPolicy`, the loop issues a LEAVE of that shard (state is
  re-materialized onto the surviving mesh — e.g.
  ``dqueue.ElasticDeviceQueue.shrink``) and retries the *same* step on the
  smaller fleet: zero steps replayed, no checkpoint round-trip.  After
  ``regrow_after`` consecutive healthy steps the policy's ``regrow`` hook
  JOINs replacement capacity back in.

Determinism: the data pipeline is indexed by global step, so a restarted
run replays identical batches (asserted in tests)."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

from ..checkpoint import latest_step, load_checkpoint, save_checkpoint
from ..obs.trace import span


class SimulatedFailure(RuntimeError):
    pass


class ShardFailure(SimulatedFailure):
    """A failure attributable to one shard — eligible for LEAVE instead of
    restart when an :class:`ElasticPolicy` is installed.

    ``shard`` is a MESH INDEX — only stable while the membership never
    changes, which is exactly the assumption elasticity breaks.  Failures
    attributed by hardware (a dead process, a SimRuntime schedule) carry
    ``device_id`` instead: the stable runtime identity (PR 10), immune to
    the index shift a prior LEAVE causes."""

    def __init__(self, shard: Optional[int], step: int,
                 device_id: Optional[int] = None):
        who = (f"device id {device_id}" if device_id is not None
               else f"shard {shard}")
        super().__init__(f"injected failure of {who} at step {step}")
        self.shard = shard
        self.step = step
        self.device_id = device_id


@dataclasses.dataclass
class FailureInjector:
    """Raises at chosen steps: ``fail_at_steps`` raise plain
    :class:`SimulatedFailure` (whole-job crash); ``shard_fail_at`` maps
    step -> shard MESH INDEX and ``device_fail_at`` maps step -> stable
    DEVICE ID, both raising :class:`ShardFailure` (attributable).  Prefer
    ``device_fail_at`` whenever more than one failure can occur: mesh
    indices shift after every LEAVE, device ids never do."""

    fail_at_steps: tuple = ()
    shard_fail_at: Dict[int, int] = dataclasses.field(default_factory=dict)
    device_fail_at: Dict[int, int] = dataclasses.field(default_factory=dict)
    fired: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.device_fail_at and ("dev", step) not in self.fired:
            self.fired.add(("dev", step))
            raise ShardFailure(None, step,
                               device_id=self.device_fail_at[step])
        if step in self.shard_fail_at and ("shard", step) not in self.fired:
            self.fired.add(("shard", step))
            raise ShardFailure(self.shard_fail_at[step], step)
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(f"injected node failure at step {step}")


@dataclasses.dataclass
class ElasticPolicy:
    """Shrink-on-failure / regrow-on-recovery hooks for
    :func:`run_with_restarts`.

    ``shrink(state, dead_shard) -> state`` issues the LEAVE (the state
    carrier decides what that means — for an ``ElasticDeviceQueue``-backed
    state it is ``queue.shrink([dead_shard])``).  ``regrow(state) -> state``
    JOINs one replacement shard; it fires after ``regrow_after`` consecutive
    healthy steps while capacity is degraded (0 disables regrowing).

    ``shrink_by_device(state, device_id) -> state`` is the PR 10 stable-id
    LEAVE: it receives the runtime device id from a
    :class:`ShardFailure` carrying one, and should quarantine the device
    so a later regrow-JOIN cannot resurrect state onto dead hardware."""

    shrink: Callable[[object, int], object]
    regrow: Optional[Callable[[object], object]] = None
    regrow_after: int = 0
    shrink_by_device: Optional[Callable[[object, int], object]] = None


def elastic_queue_policy(queue, regrow_after: int = 0,
                         controller=None) -> ElasticPolicy:
    """An :class:`ElasticPolicy` wired to any elastic queue wrapper
    (``ElasticDeviceQueue`` / ``ElasticDeviceStack`` /
    ``ElasticDevicePriorityQueue`` — all WaveEngine disciplines share the
    same membership surface, so one policy covers every flavor): a
    :class:`ShardFailure` LEAVEs the dead shard out of the queue fabric,
    and recovery JOINs one replacement shard back after ``regrow_after``
    healthy steps.  The training/serving state passes through untouched —
    the queue re-materializes itself.

    Args:
      queue: the elastic wrapper whose membership the policy drives.
      regrow_after: consecutive healthy steps before a replacement JOIN
        (0 disables regrowing).
      controller: an optional
        :class:`~repro.serve.HysteresisController` sharing this queue
        (the PR 8 autoscaler).  Every failure-LEAVE and regrow-JOIN is
        reported to it as an *external* resize, which resets its
        patience counters and starts its cooldown — so the autoscaler
        does not immediately JOIN back a shard the fault layer removed
        because it died, and does not count the fault layer's membership
        changes as its own decisions.
    """
    def _notify():
        if controller is not None:
            controller.notify_resize(queue.n_shards, external=True)

    def _shrink_dev(state, device_id):
        # stable-id LEAVE (PR 10): quarantine the dead device in the
        # queue's runtime so the regrow-JOIN below can never resurrect
        # state onto it — the pre-PR 10 resurrection bug
        queue.shrink_devices([device_id], quarantine=True)
        _notify()
        return state

    def _shrink(state, shard):
        # a bare mesh index is resolved to the CURRENT shard->device map
        # before the LEAVE mutates it, then handled on the stable-id path
        return _shrink_dev(state, queue.device_ids[shard])

    def _regrow(state):
        queue.grow(1)
        _notify()
        return state

    return ElasticPolicy(
        shrink=_shrink,
        regrow=_regrow if regrow_after > 0 else None,
        regrow_after=regrow_after,
        shrink_by_device=_shrink_dev)


def run_with_restarts(*, init_state: Callable[[], tuple],
                      step_fn: Callable[[tuple, int], tuple],
                      n_steps: int, ckpt_dir, ckpt_every: int = 10,
                      injector: Optional[FailureInjector] = None,
                      elastic: Optional[ElasticPolicy] = None,
                      max_restarts: int = 10, log: Callable = print):
    """Run ``step_fn(state, step) -> state`` for n_steps with checkpointing.

    On a :class:`ShardFailure` with an ``elastic`` policy: LEAVE the dead
    shard and retry the same step on the shrunk fleet (no replay).  On any
    other failure (or without a policy): reload the latest checkpoint and
    resume from its step.  Returns (state, metrics with restart/LEAVE/JOIN
    accounting)."""
    restarts = 0
    metrics = {"restarts": 0, "steps_replayed": 0, "steps_run": 0,
               "leaves": 0, "joins": 0}
    # LEAVEd-but-not-regrown capacity survives checkpoint restarts: the
    # elastic state (e.g. a shrunk ElasticDeviceQueue captured by the
    # policy hooks) lives outside the checkpointed tree, so forgetting the
    # deficit on restart would permanently disable regrow.
    degraded = 0
    while True:
        start = latest_step(ckpt_dir)
        state = init_state()
        step0 = 0
        if start is not None:
            with span("checkpoint:restore", cat="checkpoint", step=start):
                host, manifest = load_checkpoint(ckpt_dir, start, state)
            state = host
            step0 = int(manifest["step"])
            log(f"[fault] restored step {step0}")
        try:
            step = step0
            healthy = 0    # consecutive failure-free steps
            while step < n_steps:
                try:
                    if injector is not None:
                        injector.maybe_fail(step)
                    state = step_fn(state, step)
                except ShardFailure as e:
                    if elastic is None:
                        raise
                    log(f"[fault] {e}; LEAVE instead of restart")
                    dev = getattr(e, "device_id", None)
                    with span("fault:leave", cat="membership",
                              shard=e.shard, device=dev, step=step):
                        if dev is not None \
                                and elastic.shrink_by_device is not None:
                            state = elastic.shrink_by_device(state, dev)
                        elif dev is not None:
                            raise ValueError(
                                f"ShardFailure carries device_id={dev} but "
                                "the ElasticPolicy has no shrink_by_device "
                                "hook — use fault.elastic_queue_policy or "
                                "supply one") from e
                        else:
                            state = elastic.shrink(state, e.shard)
                    metrics["leaves"] += 1
                    degraded += 1
                    healthy = 0
                    continue  # retry the SAME step on the smaller fleet
                metrics["steps_run"] += 1
                step += 1
                healthy += 1
                if step % ckpt_every == 0 or step == n_steps:
                    with span("checkpoint:save", cat="checkpoint",
                              step=step):
                        save_checkpoint(ckpt_dir, step, state)
                if (elastic is not None and degraded > 0
                        and elastic.regrow is not None
                        and elastic.regrow_after > 0
                        and healthy >= elastic.regrow_after):
                    log("[fault] recovered; JOIN of a replacement shard")
                    with span("fault:join", cat="membership", step=step):
                        state = elastic.regrow(state)
                    metrics["joins"] += 1
                    degraded -= 1
                    healthy = 0
            metrics["restarts"] = restarts
            return state, metrics
        except SimulatedFailure as e:
            restarts += 1
            log(f"[fault] {e}; restarting ({restarts})")
            if restarts > max_restarts:
                raise
