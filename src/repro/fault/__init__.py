from .failures import FailureInjector, run_with_restarts

__all__ = ["FailureInjector", "run_with_restarts"]
