from .failures import (ElasticPolicy, FailureInjector, ShardFailure,
                       SimulatedFailure, run_with_restarts)

__all__ = ["ElasticPolicy", "FailureInjector", "ShardFailure",
           "SimulatedFailure", "run_with_restarts"]
