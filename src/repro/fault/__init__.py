from .failures import (ElasticPolicy, FailureInjector, ShardFailure,
                       SimulatedFailure, elastic_queue_policy,
                       run_with_restarts)

__all__ = ["ElasticPolicy", "FailureInjector", "ShardFailure",
           "SimulatedFailure", "elastic_queue_policy", "run_with_restarts"]
