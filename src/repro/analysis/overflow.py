"""Rule family 4 — int32-overflow lint over the tropical-semiring jaxprs.

The scan-queue arithmetic lives on int32 with ``INF = 2**30`` as tropical
+infinity and the Seap directory carrying genuinely full-range keys
(``key_lo``/``key_hi`` start at +-2^31).  The invariant that keeps this
sound is *structural*: every add/sub touching an extreme value must be
immediately clamped (``min``/``max``), selected around (``where`` with an
explicit extreme guard), or be one of two blessed idioms —

* the overflow-free midpoint ``(a & b) + ((a ^ b) >> 1)``;
* ``associative_scan``'s interleave, which adds two *disjointly*
  zero-interior-padded arrays (one operand is always the 0 padding).

The lint inlines nested ``pjit`` calls (``jnp.where`` & friends trace as
sub-jaxprs) into one flat equation list, runs a forward taint pass and
reports:

  V1 ``both-extreme-add``: add/sub/mul with *both* operands reachable
     from extreme values (wraps regardless of downstream guards);
  V2 ``unclamped-extreme-add``: add/sub with one tainted operand whose
     result never reaches a clamp (min/max/clamp/reduce_min/reduce_max)
     or a ``select_n`` guard.
"""
from __future__ import annotations

from typing import Any, Dict, List, NamedTuple, Sequence, Tuple

import numpy as np

from .report import Violation

TAINT_BOUND = 2 ** 30

# ops whose output is index-like / boolean — never extreme-valued
_UNTAINT_OUT = frozenset({
    "eq", "ne", "lt", "le", "gt", "ge", "argmax", "argmin", "iota",
    "reduce_and", "reduce_or", "sign", "is_finite",
})
# ops that merely move values around: taint and guard-search pass through
_PASS_THROUGH = frozenset({
    "reshape", "broadcast_in_dim", "concatenate", "slice", "squeeze",
    "transpose", "convert_element_type", "pad", "gather", "dynamic_slice",
    "dynamic_update_slice", "rev", "expand_dims", "copy", "stop_gradient",
    "scatter",
})
# consuming one of these bounds the result again (or explicitly branches
# on the extreme case): the add is considered guarded
_GUARDS = frozenset({
    "min", "max", "clamp", "select_n", "reduce_min", "reduce_max",
})
_ARITH = frozenset({"add", "sub", "mul"})
_INLINE_PRIMS = frozenset({"pjit", "closed_call", "core_call", "remat",
                           "checkpoint", "custom_jvp_call",
                           "custom_vjp_call"})


class _FakeLit:
    """Stand-in literal for a sub-jaxpr const, so taint can read its
    value the same way it reads a jax Literal."""
    __slots__ = ("val",)

    def __init__(self, val: Any) -> None:
        self.val = val


class _FlatEqn(NamedTuple):
    prim: str
    invars: Tuple[Any, ...]   # Var | Literal | _FakeLit, pjit-resolved
    outvars: Tuple[Any, ...]
    params: Dict[str, Any]
    eqn: Any                  # original JaxprEqn (for messages)


def _is_int(aval) -> bool:
    dt = getattr(aval, "dtype", None)
    return dt is not None and np.issubdtype(dt, np.integer)


def _const_tainted(val) -> bool:
    try:
        arr = np.asarray(val)
    except Exception:
        return False
    if arr.size == 0 or not np.issubdtype(arr.dtype, np.integer):
        return False
    return bool(np.abs(arr.astype(np.int64)).max() >= TAINT_BOUND)


def _flatten_into(jaxpr, consts: Sequence, sub: Dict[int, Any],
                  out: List[_FlatEqn]) -> Dict[int, Any]:
    """Inline every pjit-like call into one flat equation list, rewriting
    operand references through the call boundary."""
    env = dict(sub)
    for cv, c in zip(jaxpr.constvars, consts):
        env[id(cv)] = _FakeLit(c)

    def res(atom):
        return env.get(id(atom), atom)

    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        cj = None
        if name in _INLINE_PRIMS:
            cj = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
        if cj is not None:
            inner = cj.jaxpr if hasattr(cj, "jaxpr") else cj
            iconsts = (cj.consts if hasattr(cj, "consts")
                       else [None] * len(inner.constvars))
            isub = {id(iv): res(pv)
                    for iv, pv in zip(inner.invars, eqn.invars)}
            ienv = _flatten_into(inner, iconsts, isub, out)
            for pov, iov in zip(eqn.outvars, inner.outvars):
                env[id(pov)] = ienv.get(id(iov), iov)
        else:
            out.append(_FlatEqn(name, tuple(res(v) for v in eqn.invars),
                                tuple(eqn.outvars), dict(eqn.params), eqn))
    return env


def _fmt(fe: _FlatEqn) -> str:
    s = str(fe.eqn)
    return s if len(s) <= 200 else s[:197] + "..."


def _is_zero_interleave_pad(fe: "_FlatEqn | None") -> bool:
    """``pad(x, 0)`` with interior padding — associative_scan's
    interleave operand (its support is disjoint from its partner's)."""
    if fe is None or fe.prim != "pad":
        return False
    cfg = fe.params.get("padding_config", ())
    if not any(len(d) >= 3 and d[2] >= 1 for d in cfg):
        return False
    if len(fe.invars) < 2:
        return False
    pv = fe.invars[1]
    if not hasattr(pv, "val"):
        return False
    try:
        return bool((np.asarray(pv.val) == 0).all())
    except Exception:
        return False


class _Lint:
    def __init__(self, flat: List[_FlatEqn], invars, taint_in,
                 outvars, program: str) -> None:
        self.flat = flat
        self.program = program
        self.taint: Dict[int, bool] = {
            id(v): bool(t) for v, t in zip(invars, taint_in)}
        self.producer: Dict[int, _FlatEqn] = {}
        self.consumers: Dict[int, List[_FlatEqn]] = {}
        self.out_ids = {id(v) for v in outvars}

    def get(self, atom) -> bool:
        if hasattr(atom, "val"):
            return _const_tainted(atom.val)
        return self.taint.get(id(atom), False)

    # ---------------------------------------------------- forward pass ---
    def propagate(self) -> List[Tuple[_FlatEqn, List[bool]]]:
        arith: List[Tuple[_FlatEqn, List[bool]]] = []
        for fe in self.flat:
            in_t = [self.get(v) for v in fe.invars]
            for v in fe.invars:
                if not hasattr(v, "val"):
                    self.consumers.setdefault(id(v), []).append(fe)
            if fe.prim == "sort":
                # operands are co-sorted: output i is a permutation of
                # operand i (argsort's index output stays index-like)
                out_t = list(in_t[:len(fe.outvars)])
                out_t += [False] * (len(fe.outvars) - len(out_t))
            elif fe.prim in _UNTAINT_OUT:
                out_t = [False] * len(fe.outvars)
            else:
                out_t = [any(in_t)] * len(fe.outvars)
            for var, t in zip(fe.outvars, out_t):
                self.taint[id(var)] = t
                self.producer[id(var)] = fe
            if fe.prim in _ARITH and any(in_t):
                arith.append((fe, in_t))
        return arith

    # ------------------------------------------------- blessed idioms ---
    def _is_midpoint_idiom(self, fe: _FlatEqn) -> bool:
        if fe.prim != "add" or len(fe.invars) != 2:
            return False

        def prod(atom):
            return self.producer.get(id(atom))

        def inputs(e: _FlatEqn):
            return frozenset(id(v) for v in e.invars
                             if not hasattr(v, "val"))

        for x, y in ((fe.invars[0], fe.invars[1]),
                     (fe.invars[1], fe.invars[0])):
            px, py = prod(x), prod(y)
            if px is None or py is None or px.prim != "and":
                continue
            if py.prim not in ("shift_right_arithmetic",
                               "shift_right_logical"):
                continue
            pxor = prod(py.invars[0])
            if pxor is not None and pxor.prim == "xor" \
                    and inputs(px) == inputs(pxor):
                return True
        return False

    def _is_interleave(self, fe: _FlatEqn) -> bool:
        return all(_is_zero_interleave_pad(self.producer.get(id(v)))
                   for v in fe.invars if not hasattr(v, "val")) \
            and len(fe.invars) == 2 and not any(
                hasattr(v, "val") for v in fe.invars)

    # ----------------------------------------------------- guard search ---
    def guarded(self, var, depth: int = 8) -> bool:
        seen = set()
        frontier = [id(var)]
        for _ in range(depth):
            nxt: List[int] = []
            for vid in frontier:
                if vid in seen:
                    continue
                seen.add(vid)
                if vid in self.out_ids:
                    return False        # escapes the program unclamped
                for fe in self.consumers.get(vid, []):
                    if fe.prim in _GUARDS:
                        return True
                    if fe.prim in _PASS_THROUGH:
                        nxt.extend(id(v) for v in fe.outvars)
            if not nxt:
                break
            frontier = nxt
        return False

    # -------------------------------------------------------- verdicts ---
    def check(self) -> List[Violation]:
        out: List[Violation] = []
        for fe, in_t in self.propagate():
            ov = fe.outvars[0]
            if not _is_int(getattr(ov, "aval", None)):
                continue
            if sum(bool(t) for t in in_t) >= 2:
                if self._is_midpoint_idiom(fe) or self._is_interleave(fe):
                    continue
                out.append(Violation(
                    "int32_overflow", self.program,
                    f"{fe.prim} with BOTH operands reachable from "
                    f"int32-extreme values (can wrap regardless of "
                    f"downstream guards): {_fmt(fe)}",
                    {"kind": "both-extreme-add", "eqn": _fmt(fe)}))
            elif not self.guarded(ov):
                out.append(Violation(
                    "int32_overflow", self.program,
                    f"{fe.prim} on an int32-extreme operand whose result "
                    f"is never clamped (min/max/clamp) or selected around "
                    f"(where): {_fmt(fe)}",
                    {"kind": "unclamped-extreme-add", "eqn": _fmt(fe)}))
        return out


def lint_jaxpr(fn, avals: Sequence, *, program: str,
               tainted_args: Sequence[int] = ()) -> List[Violation]:
    """Trace ``fn(*avals)``, inline nested pjit calls, and lint the flat
    jaxpr.  ``tainted_args`` are flat positional indices whose values are
    full-range int32 (keys, directory boundaries)."""
    import jax

    closed = jax.make_jaxpr(fn)(*avals)
    flat: List[_FlatEqn] = []
    env = _flatten_into(closed.jaxpr, closed.consts, {}, flat)
    outvars = [env.get(id(v), v) for v in closed.jaxpr.outvars]
    taint_in = [i in set(tainted_args)
                for i in range(len(closed.jaxpr.invars))]
    lint = _Lint(flat, closed.jaxpr.invars, taint_in, outvars, program)
    return lint.check()


def check_int32_overflow() -> "tuple[List[Violation], Dict[str, Any]]":
    """Lint the full core/scan_queue.py surface the wave path traces."""
    import functools

    import jax
    import jax.numpy as jnp

    from ..core import scan_queue as sq

    n, P_, B_ = 16, 3, 4
    i32 = jnp.int32
    vec = lambda k, dt=i32: jax.ShapeDtypeStruct((k,), dt)
    sc = jax.ShapeDtypeStruct((), i32)

    def queue_entry(e, first, last, v):
        return sq.queue_scan(e, sq.QueueState(first, last), v)

    def stack_entry(e, last, ticket, v):
        return sq.stack_scan(e, sq.StackState(last, ticket), v)

    entries = [
        ("core/scan_queue.py:queue_scan", queue_entry,
         (vec(n, jnp.bool_), sc, sc, vec(n, jnp.bool_)), ()),
        ("core/scan_queue.py:stack_scan", stack_entry,
         (vec(n, jnp.bool_), sc, sc, vec(n, jnp.bool_)), ()),
        ("core/scan_queue.py:strict_batch_deletemin",
         functools.partial(sq.strict_batch_deletemin, n_prios=P_),
         (vec(n, jnp.bool_), vec(P_), vec(P_)), ()),
        ("core/scan_queue.py:priority_queue_scan",
         functools.partial(sq.priority_queue_scan, n_prios=P_),
         (vec(n, jnp.bool_), vec(n), vec(n, jnp.bool_), vec(P_), vec(P_)),
         ()),
        ("core/scan_queue.py:seap_bucket_lookup", sq.seap_bucket_lookup,
         (vec(n), vec(B_), vec(B_, jnp.bool_)), (0, 1)),
        ("core/scan_queue.py:seap_queue_scan",
         functools.partial(sq.seap_queue_scan, n_buckets=B_,
                           split_occupancy=6),
         (vec(n, jnp.bool_), vec(n), vec(n, jnp.bool_), vec(B_), vec(B_),
          vec(B_), vec(B_, jnp.bool_), sc, sc),
         (1, 5, 7, 8)),   # key, lo, key_lo, key_hi are full-range int32
    ]
    violations: List[Violation] = []
    info: Dict[str, Any] = {"entries": []}
    for name, fn, avals, tainted in entries:
        vs = lint_jaxpr(fn, avals, program=name, tainted_args=tainted)
        violations.extend(vs)
        info["entries"].append({"program": name, "violations": len(vs)})
    return violations, info
