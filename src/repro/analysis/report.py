"""Violation record and JSON report assembly shared by all rule families."""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List


@dataclass(frozen=True)
class Violation:
    """One broken contract, attributable to a rule family and a program
    (a jitted entry point, a jaxpr function, or a source file)."""
    rule: str        # "collective_budget" | "donation" | "recompile_guard"
                     # | "int32_overflow" | "repo_ast"
    program: str     # e.g. "queue.step", "core/scan_queue.py:seap_queue_scan"
    message: str
    detail: Dict[str, Any] = field(default_factory=dict)

    def __str__(self) -> str:
        return f"[{self.rule}] {self.program}: {self.message}"


RULE_FAMILIES = ("collective_budget", "donation", "recompile_guard",
                 "int32_overflow", "repo_ast")


def build_report(violations: List[Violation],
                 programs: Dict[str, Dict[str, Any]],
                 info: Dict[str, Any]) -> Dict[str, Any]:
    by_rule: Dict[str, List[dict]] = {r: [] for r in RULE_FAMILIES}
    for v in violations:
        by_rule.setdefault(v.rule, []).append(asdict(v))
    return {
        "tool": "wavecheck",
        "passed": not violations,
        "n_violations": len(violations),
        "violations": [asdict(v) for v in violations],
        "rules": {r: {"violations": vs, "n": len(vs)}
                  for r, vs in by_rule.items()},
        "programs": programs,
        **info,
    }


def to_json(report: Dict[str, Any]) -> str:
    return json.dumps(report, indent=2, sort_keys=False, default=str)
