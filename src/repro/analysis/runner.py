"""Run every wavecheck rule family and assemble the JSON report."""
from __future__ import annotations

from typing import Any, Dict, List, Optional

from .astlint import lint_paths
from .budgets import check_budget
from .donation import check_donation
from .hlo import collective_counts, compiled_text, input_output_aliases
from .overflow import check_int32_overflow
from .recompile import check_recompile_guard
from .report import Violation, build_report
from .programs import build_migration_programs, build_programs


def run_all(*, n_shards: Optional[int] = None,
            skip_recompile: bool = False) -> Dict[str, Any]:
    import jax

    from ..compat import make_mesh

    n_dev = len(jax.devices())
    p = n_shards or min(8, n_dev)
    mesh = make_mesh((p,), ("data",))

    violations: List[Violation] = []
    programs: Dict[str, Dict[str, Any]] = {}

    # rule families 1+2: one compile per program serves both checks
    specs = build_programs(mesh) + build_migration_programs()
    for spec in specs:
        text = compiled_text(spec.jitted, spec.args)
        violations.extend(check_budget(spec.name, text, spec.budget))
        violations.extend(check_donation(
            spec.name, text, spec.donated_leaves, spec.donated_params))
        programs[spec.name] = {
            "collectives": collective_counts(text),
            "aliases": len(input_output_aliases(text)),
            "donated_leaves": spec.donated_leaves,
            **spec.meta,
        }

    # rule family 3: membership / burst-length bounce must not recompile
    recompile_info: Dict[str, Any] = {}
    if not skip_recompile:
        vs, recompile_info = check_recompile_guard()
        violations.extend(vs)

    # rule family 4: int32-overflow taint lint over core/scan_queue.py
    vs, overflow_info = check_int32_overflow()
    violations.extend(vs)

    # rule family 5: repo AST lint over the device-path modules
    vs, ast_info = lint_paths()
    violations.extend(vs)

    return build_report(violations, programs, {
        "n_devices": n_dev,
        "n_shards": p,
        "jax_version": jax.__version__,
        "recompile_guard": recompile_info,
        "int32_overflow": overflow_info,
        "repo_ast": ast_info,
    })
