"""wavecheck: a static invariant analyzer for the Skueue device wave path.

Five rule families over the jaxpr / compiled-HLO artifacts we already
lower (no runtime instrumentation beyond jax's own compile events):

1. ``budgets``   — per-Discipline collective budgets (all_to_all /
                   all_gather / ppermute / all_reduce counts) checked by a
                   structured HLO op walk over every jitted entry point.
2. ``donation``  — every ``donate_argnums`` buffer must have received an
                   input-output alias in the compiled module (a silently
                   dropped donation = one full state copy per wave).
3. ``recompile`` — a compilation-event tracker asserting the elastic
                   mesh/program caches prevent recompiles when bouncing
                   between shard counts and burst lengths.
4. ``overflow``  — an int32-overflow taint lint over the jaxprs of the
                   ``core/scan_queue.py`` tropical-semiring arithmetic and
                   the Seap midpoint / ``key_lo`` / ``key_hi`` math.
5. ``astlint``   — a repo AST lint: no ``int()``/``float()`` on traced
                   values, no ``.block_until_ready()`` inside burst loops,
                   no bare ``assert`` in device-path modules.

CLI: ``python -m repro.analysis --all`` (JSON report, non-zero exit on any
violation); ``--selftest`` runs the mutation self-test (a deliberately
broken Discipline must trip >= 3 independent rules).

This module is imported lazily so ``python -m repro.analysis`` can pin
``XLA_FLAGS`` device forcing *before* jax loads.
"""
from typing import Any

__all__ = [
    "HloOp", "HloProgram", "parse_hlo", "collective_counts",
    "count_all_to_all", "compiled_text", "input_output_aliases",
    "Violation", "CollectiveBudget", "check_budget", "check_donation",
    "CompilationTracker", "check_int32_overflow", "lint_paths", "run_all",
]

_LAZY = {
    "HloOp": "hlo", "HloProgram": "hlo", "parse_hlo": "hlo",
    "collective_counts": "hlo", "count_all_to_all": "hlo",
    "compiled_text": "hlo", "input_output_aliases": "hlo",
    "Violation": "report",
    "CollectiveBudget": "budgets", "check_budget": "budgets",
    "check_donation": "donation",
    "CompilationTracker": "recompile",
    "check_int32_overflow": "overflow",
    "lint_paths": "astlint",
    "run_all": "runner",
}


def __getattr__(name: str) -> Any:
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(f".{_LAZY[name]}", __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
