"""Rule family 5 — repo AST lint for the device wave path.

Three structural rules over the device-path modules (``dqueue/*``,
``core/scan_queue.py``, ``serve/engine.py``):

* ``no-bare-assert``      — ``assert`` is stripped under ``python -O`` and
  cannot act on traced values; the PR 5 migration replaced every one with
  a structured error (``QueueOverflowError`` / ``ServeInvariantError``).
  This rule locks that in: no ``assert`` statements at all.
* ``no-traced-cast``      — ``int()`` / ``float()`` inside *device scope*
  (a function traced by jit / shard_map / lax control flow, or a
  Discipline wave method) forces a concretization error at best and a
  silent host sync at worst.
* ``no-block-in-burst``   — ``.block_until_ready()`` inside a ``for`` /
  ``while`` loop serializes the wave pipeline the engine exists to
  overlap.
* ``no-host-callback-in-wave`` — host-effect escapes (``jax.debug.print``,
  ``debug.callback`` / ``io_callback`` / ``pure_callback``,
  ``block_until_ready``, ``device_get``) inside *device scope*.  The wave
  is collective-budgeted, donated-in-place code; a host callback inserts
  an unbudgeted device→host sync per wave.  Telemetry reads device state
  ONLY via the sanctioned Wavescope drain (``repro.obs.device.drain`` /
  ``WaveEngine.drain_metrics`` at burst boundaries), which is exempt.

PR 10 adds a fifth rule with its own (wider) module scope:

* ``no-direct-mesh`` — ``jax.devices()`` / ``jax.sharding.Mesh(...)`` /
  ``make_mesh`` and friends anywhere in ``dqueue/``, ``serve/``,
  ``fault/``, or ``obs/``.  Device topology is owned by the
  :class:`repro.runtime.Runtime` seam — a layer that constructs its own
  mesh pins the stack to the one-process XLA world and breaks the
  distributed/simulated runtimes.  ``repro.runtime`` itself and
  ``launch/mesh.py`` (the seam and its public helper) are the only
  places allowed to touch global device state.  This rule is NOT in
  :data:`DEFAULT_RULES` (``lint_source`` behavior is unchanged);
  ``lint_paths`` applies it over :data:`MESH_SCOPE_MODULES`.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, Iterable, List, Sequence, Set, Tuple

from .report import Violation

# callables whose function-valued arguments are traced on device
_TRACING_CALLEES = frozenset({
    "shard_map", "jit", "pjit", "scan", "associative_scan", "fori_loop",
    "while_loop", "cond", "switch", "vmap", "pmap", "checkpoint", "remat",
    "custom_jvp", "custom_vjp", "grad", "value_and_grad", "map",
})
# Discipline / WaveEngine methods that run inside the traced wave
_DEVICE_METHODS = frozenset({
    "split", "merge", "dispatch", "commit", "zero_outs", "zero_aux",
    "_wave", "_multi_sequential", "_multi_pipelined", "_pack_request",
    "_extract_reply", "_out_specs", "_metric_row", "occupancy",
})
_CASTS = frozenset({"int", "float"})
# host-effect escapes forbidden inside the traced wave ("print" catches
# both the builtin and jax.debug.print; "callback" catches debug.callback)
_HOST_CALLBACKS = frozenset({
    "print", "callback", "debug_callback", "io_callback", "pure_callback",
    "block_until_ready", "device_get",
})
# the sanctioned Wavescope drain API: the ONE device->host telemetry read,
# at burst boundaries only — exempt from no-host-callback-in-wave
_OBS_DRAIN_API = frozenset({"drain", "drain_metrics", "_drain_telemetry"})

DEFAULT_MODULES = (
    "src/repro/dqueue",
    "src/repro/core/scan_queue.py",
    "src/repro/serve/engine.py",
)

# the four original structural rules; lint_source runs exactly these
# unless told otherwise, so PR <10 callers see identical behavior
DEFAULT_RULES = frozenset({
    "no-bare-assert", "no-traced-cast", "no-block-in-burst",
    "no-host-callback-in-wave",
})

# where the no-direct-mesh rule applies: every layer above the runtime
# seam (the acceptance surface of the PR 10 refactor)
MESH_SCOPE_MODULES = (
    "src/repro/dqueue",
    "src/repro/serve",
    "src/repro/fault",
    "src/repro/obs",
)

# direct device-topology constructions the runtime seam owns: the
# builders ("Mesh", "make_mesh", launch helpers) and the global device
# enumerations ("devices" catches jax.devices / jax.local_devices)
_MESH_CALLS = frozenset({
    "Mesh", "make_mesh", "make_elastic_mesh", "make_host_mesh",
    "make_production_mesh", "devices", "local_devices", "device_count",
})


def _callee_tail(func: ast.expr) -> str:
    """'jax.lax.scan' -> 'scan', 'shard_map' -> 'shard_map'."""
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return ""


def _func_arg_names(call: ast.Call) -> Iterable[str]:
    for a in list(call.args) + [kw.value for kw in call.keywords]:
        if isinstance(a, ast.Name):
            yield a.id
        elif isinstance(a, ast.Attribute):
            yield a.attr


class _DeviceScopeFinder(ast.NodeVisitor):
    """Names of functions that end up traced on device."""

    def __init__(self) -> None:
        self.rooted: Set[str] = set()

    def visit_Call(self, node: ast.Call) -> None:
        if _callee_tail(node.func) in _TRACING_CALLEES:
            self.rooted.update(_func_arg_names(node))
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        for dec in node.decorator_list:
            tail = (_callee_tail(dec.func) if isinstance(dec, ast.Call)
                    else _callee_tail(dec))
            if tail in _TRACING_CALLEES:
                self.rooted.add(node.name)
        self.generic_visit(node)


class _ModuleLinter(ast.NodeVisitor):
    def __init__(self, path: str, tree: ast.Module,
                 rules: "Iterable[str] | None" = None) -> None:
        self.path = path
        self.rules = frozenset(DEFAULT_RULES if rules is None else rules)
        self.violations: List[Violation] = []
        finder = _DeviceScopeFinder()
        finder.visit(tree)
        self._rooted = finder.rooted
        self._scope: List[Tuple[str, bool]] = []   # (name, is_device)
        self._loops = 0

    # ------------------------------------------------------ scope track ---
    def _enter_fn(self, node) -> None:
        parent_device = bool(self._scope) and self._scope[-1][1]
        device = (parent_device or node.name in self._rooted
                  or node.name in _DEVICE_METHODS)
        self._scope.append((node.name, device))

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._enter_fn(node)
        self.generic_visit(node)
        self._scope.pop()

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

    def _in_device_scope(self) -> bool:
        return bool(self._scope) and self._scope[-1][1]

    # ------------------------------------------------------------ rules ---
    def visit_Assert(self, node: ast.Assert) -> None:
        if "no-bare-assert" not in self.rules:
            self.generic_visit(node)
            return
        self.violations.append(Violation(
            "repo_ast", f"{self.path}:{node.lineno}",
            "bare assert in a device-path module — raise a structured "
            "error (QueueOverflowError / ServeInvariantError) instead",
            {"check": "no-bare-assert", "line": node.lineno}))
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        tail = _callee_tail(node.func)
        if tail in _MESH_CALLS and "no-direct-mesh" in self.rules:
            self.violations.append(Violation(
                "repo_ast", f"{self.path}:{node.lineno}",
                f"direct device-topology call '{tail}(...)' above the "
                "runtime seam — meshes and device pools are owned by "
                "repro.runtime.Runtime (mesh()/pool()/reshard_devices); "
                "constructing them here pins the layer to the "
                "one-process XLA world",
                {"check": "no-direct-mesh", "line": node.lineno,
                 "callee": tail}))
        if "no-traced-cast" in self.rules and tail in _CASTS \
                and self._in_device_scope() \
                and isinstance(node.func, ast.Name):
            fn = ".".join(n for n, _ in self._scope)
            self.violations.append(Violation(
                "repo_ast", f"{self.path}:{node.lineno}",
                f"{tail}() on a traced value inside device scope "
                f"'{fn}' — concretizes the trace / syncs the host",
                {"check": "no-traced-cast", "line": node.lineno,
                 "scope": fn}))
        if "no-host-callback-in-wave" in self.rules \
                and tail in _HOST_CALLBACKS and self._in_device_scope() \
                and self._scope[-1][0] not in _OBS_DRAIN_API:
            fn = ".".join(n for n, _ in self._scope)
            self.violations.append(Violation(
                "repo_ast", f"{self.path}:{node.lineno}",
                f"host callback '{tail}' inside device scope '{fn}' — "
                "an unbudgeted device->host sync per wave; telemetry "
                "must ride the Wavescope metrics ring and drain at "
                "burst boundaries (repro.obs.device.drain)",
                {"check": "no-host-callback-in-wave", "line": node.lineno,
                 "scope": fn}))
        if "no-block-in-burst" in self.rules \
                and tail == "block_until_ready" and self._loops > 0:
            self.violations.append(Violation(
                "repo_ast", f"{self.path}:{node.lineno}",
                ".block_until_ready() inside a burst loop serializes "
                "the wave pipeline — hoist it after the loop",
                {"check": "no-block-in-burst", "line": node.lineno}))
        self.generic_visit(node)

    def visit_For(self, node: ast.For) -> None:
        self._loops += 1
        self.generic_visit(node)
        self._loops -= 1

    def visit_While(self, node: ast.While) -> None:
        self._loops += 1
        self.generic_visit(node)
        self._loops -= 1


def lint_source(src: str, path: str = "<string>",
                rules: "Iterable[str] | None" = None) -> List[Violation]:
    tree = ast.parse(src)
    linter = _ModuleLinter(path, tree, rules=rules)
    linter.visit(tree)
    return linter.violations


def _expand(root: str, modules: Sequence[str]) -> List[str]:
    out: List[str] = []
    for m in modules:
        p = os.path.join(root, m)
        if os.path.isdir(p):
            out.extend(sorted(
                os.path.join(p, f) for f in os.listdir(p)
                if f.endswith(".py")))
        elif os.path.isfile(p):
            out.append(p)
    return out


def _repo_root() -> str:
    # .../src/repro/analysis/astlint.py -> repo root is 3 dirs above src
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.abspath(os.path.join(here, "..", "..", ".."))


def lint_paths(modules: Sequence[str] = DEFAULT_MODULES,
               root: "str | None" = None
               ) -> "tuple[List[Violation], Dict[str, object]]":
    """Lint the wave-path modules.

    Files under ``modules`` get :data:`DEFAULT_RULES`; files under
    :data:`MESH_SCOPE_MODULES` additionally get ``no-direct-mesh``
    (rule sets union where the scopes overlap), so the whole layer
    above the runtime seam is checked for direct topology access even
    though only the device-path subset runs the structural rules."""
    root = root or _repo_root()
    per_file: Dict[str, Set[str]] = {}
    for f in _expand(root, modules):
        per_file.setdefault(f, set()).update(DEFAULT_RULES)
    for f in _expand(root, MESH_SCOPE_MODULES):
        per_file.setdefault(f, set()).add("no-direct-mesh")
    violations: List[Violation] = []
    for f in sorted(per_file):
        with open(f, "r", encoding="utf-8") as fh:
            src = fh.read()
        rel = os.path.relpath(f, root)
        violations.extend(lint_source(src, rel, rules=per_file[f]))
    return violations, {"files_checked": [os.path.relpath(f, root)
                                          for f in sorted(per_file)]}
