"""Rule family 2 — donation / aliasing.

``wave_engine`` jits every entry with ``donate_argnums=(0,)`` (the state
pytree) and the elastic migration donates the two store arrays.  If XLA
cannot honor a donation it silently falls back to a copy — for the wave
path that is one full state copy *per wave*, visible only as a warning.
This rule asserts each donated leaf actually received an input-output
alias in the compiled module header.
"""
from __future__ import annotations

from typing import List, Sequence, Union

from .hlo import HloProgram, input_output_aliases, parse_hlo
from .report import Violation


def check_donation(program_name: str,
                   program: Union[HloProgram, str],
                   expected_donated_leaves: int,
                   donated_params: Union[Sequence[int], None] = None
                   ) -> List[Violation]:
    """``expected_donated_leaves``: number of flattened array leaves in the
    donated arguments (every one must alias an output).  When
    ``donated_params`` is given, additionally require each alias to point
    at one of those flat parameter numbers."""
    if isinstance(program, str):
        program = parse_hlo(program)
    aliases = input_output_aliases(program)
    out: List[Violation] = []
    if len(aliases) < expected_donated_leaves:
        out.append(Violation(
            "donation", program_name,
            f"{expected_donated_leaves} donated leaves but only "
            f"{len(aliases)} input-output aliases in the compiled module "
            f"— dropped donations copy state every wave",
            {"expected": expected_donated_leaves, "got": len(aliases),
             "aliases": [tuple(a) for a in aliases]}))
    if donated_params is not None:
        allowed = set(int(p) for p in donated_params)
        for a in aliases:
            if a.param not in allowed:
                out.append(Violation(
                    "donation", program_name,
                    f"alias onto parameter {a.param} which was not "
                    f"declared donated {sorted(allowed)}",
                    {"alias": tuple(a)}))
    return out
