"""Mutation self-test: a deliberately broken Discipline (and three
deliberately broken idioms) must trip the analyzer.

If wavecheck cannot catch a Discipline that leaks an extra collective,
drops its donation, busts the jit cache, wraps int32, and casts traced
values — it cannot catch the regressions it exists to block.  The
acceptance bar is >= 3 independent rule families tripped; this module
breaks all five on purpose and reports which fired.
"""
from __future__ import annotations

import textwrap
from typing import Any, Dict

from .astlint import lint_source
from .budgets import check_budget
from .donation import check_donation
from .hlo import compiled_text
from .overflow import lint_jaxpr
from .recompile import CompilationTracker

# device-scope sins, linted from source (kept as a string so the repo
# lint over src/ stays clean)
_BAD_SRC = textwrap.dedent("""
    import jax, jax.numpy as jnp
    from jax import lax

    def broken_body(state, x):
        k = int(x[0])                       # cast on a traced value
        assert k >= 0, "traced assert"      # stripped under -O
        return state + k, x

    def broken_burst(state, xs):
        out = lax.scan(broken_body, state, xs)
        for _ in range(4):
            out[0].block_until_ready()      # sync inside the burst loop
        return out
""")


def _broken_engine(mesh):
    """FIFO discipline leaking ONE extra all_to_all per wave, fed by
    runtime data so XLA cannot fold it away."""
    import jax.numpy as jnp
    from jax import lax

    from ..dqueue.device_queue import FifoDiscipline
    from ..dqueue.wave_engine import WaveEngine

    class _BrokenFifoDiscipline(FifoDiscipline):
        def dispatch(self, carry, ops):
            d = super().dispatch(carry, ops)
            buf = jnp.tile(d.payload[:1, :1], (self.n_shards, 1))
            leak = lax.all_to_all(buf, self.axis, 0, 0)
            owner = jnp.where(leak[0, 0] > jnp.int32(2 ** 30),
                              d.owner - 1, d.owner)
            return d._replace(owner=owner)

    p = mesh.devices.size
    disc = _BrokenFifoDiscipline("data", p, 16, 2)
    return WaveEngine(mesh, "data", disc, pipelined=False)


def run_selftest() -> Dict[str, Any]:
    import jax
    import jax.numpy as jnp

    from ..compat import make_mesh
    from ..dqueue import DeviceQueue
    from .programs import _wave_budget

    p = min(8, len(jax.devices()))
    mesh = make_mesh((p,), ("data",))
    L = 2
    n = p * L
    dq = DeviceQueue(mesh, "data", cap=16, payload_width=2,
                     ops_per_shard=L)
    args = (dq.init_state(), jnp.zeros(n, bool), jnp.zeros(n, bool),
            jnp.zeros((n, 2), jnp.int32))

    tripped: Dict[str, Any] = {}

    # 1. collective budget — the leaked third all_to_all must be counted
    eng = _broken_engine(mesh)
    vs = check_budget("mutation:leaky-fifo.step",
                      compiled_text(eng._step, args),
                      _wave_budget("queue", p, pipelined=False, burst=False))
    tripped["collective_budget"] = [str(v) for v in vs]

    # 2. donation — re-jit the step without donate_argnums: the outer
    # module must show zero input-output aliases
    undonated = jax.jit(lambda s, e, v, pw: dq._step(s, e, v, pw))
    vs = check_donation("mutation:undonated.step",
                        compiled_text(undonated, args),
                        expected_donated_leaves=4)
    tripped["donation"] = [str(v) for v in vs]

    # 3. recompile guard — a fresh jit per wave defeats every cache: the
    # second pass must still observe backend compiles
    def cacheless_burst():
        for _ in range(2):
            f = jax.jit(lambda x: x + 1)      # new jit object every wave
            f(jnp.zeros((4,), jnp.int32)).block_until_ready()

    with CompilationTracker():
        cacheless_burst()
    with CompilationTracker() as second:
        cacheless_burst()
    tripped["recompile_guard"] = (
        [f"{second.count} recompiles on an identical second burst"]
        if second.count > 0 else [])

    # 4. int32-overflow lint — naive midpoint and unclamped INF growth
    INF = jnp.int32(2 ** 30)
    sc = jax.ShapeDtypeStruct((), jnp.int32)
    vs = lint_jaxpr(lambda lo, hi: (lo + hi) // 2, (sc, sc),
                    program="mutation:naive_midpoint",
                    tainted_args=(0, 1))
    vs += lint_jaxpr(lambda b: b + INF, (sc,),
                     program="mutation:inf_growth")
    tripped["int32_overflow"] = [str(v) for v in vs]

    # 5. repo AST lint — the three device-scope sins
    vs = lint_source(_BAD_SRC, "mutation:bad_module")
    tripped["repo_ast"] = [str(v) for v in vs]

    fired = sorted(r for r, v in tripped.items() if v)
    return {
        "tripped_rules": fired,
        "n_tripped": len(fired),
        "required": 3,
        "passed": len(fired) >= 3,
        "details": tripped,
    }
