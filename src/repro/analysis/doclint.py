"""doclint: keep the docs tree honest (dead links + rotting snippets).

Two checks over markdown files, both import-light (stdlib only — the CI
docs job and the tier-1 test both run them; jax is only needed when a
checked snippet itself imports it):

1. **Link check** — every relative link and ``#anchor`` in ``docs/*.md``
   and ``README.md`` must resolve: the target file exists inside the
   repo, and when the link carries an anchor the target heading exists
   (GitHub's heading→anchor slug rules).  External ``http(s)://`` /
   ``mailto:`` links and paths escaping the repo (e.g. the CI badge's
   site-relative URL) are skipped.
2. **Doctest extraction** — fenced ````python`` blocks containing
   ``>>>`` prompts are collected per file and executed with
   :mod:`doctest` (one shared namespace per file, in block order), so a
   quickstart in ``docs/ARCHITECTURE.md`` breaks CI the moment the API
   it shows drifts.

CLI::

    python -m repro.analysis.doclint README.md docs --doctest docs/ARCHITECTURE.md

Exit status 1 on any dead link/anchor or failing doctest.
"""
from __future__ import annotations

import argparse
import doctest
import re
import sys
from pathlib import Path
from typing import Iterable, List, Tuple

_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING_RE = re.compile(r"^(#{1,6})\s+(.*)$")
_FENCE_RE = re.compile(r"^(```|~~~)")
_PY_BLOCK_RE = re.compile(r"```python[^\n]*\n(.*?)```", re.S)


def slugify(heading: str) -> str:
    """GitHub's heading→anchor slug: demote to lowercase, strip markup
    and punctuation (keeping word chars, hyphens, spaces), then replace
    spaces with hyphens.

    Args:
      heading: the heading text (without the leading ``#`` marks).

    Returns:
      The anchor slug (no leading ``#``).
    """
    text = re.sub(r"`([^`]*)`", r"\1", heading)            # code spans
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)    # links → text
    # asterisks never reach a GitHub anchor; bare underscores are word
    # chars and DO survive (BENCH_PR*.json -> bench_prjson)
    text = re.sub(r"\*", "", text)
    text = text.strip().lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def anchors_of(md_path: Path) -> set:
    """All anchor slugs a markdown file exposes (fenced blocks skipped;
    GitHub-style ``-1``/``-2`` suffixes for duplicate headings)."""
    seen: dict = {}
    out = set()
    in_fence = False
    for line in md_path.read_text().splitlines():
        if _FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = _HEADING_RE.match(line)
        if not m:
            continue
        slug = slugify(m.group(2))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        out.add(slug if n == 0 else f"{slug}-{n}")
    return out


def iter_links(md_path: Path) -> Iterable[str]:
    """Yield every inline link target in a markdown file, fenced code
    blocks excluded."""
    in_fence = False
    for line in md_path.read_text().splitlines():
        if _FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in _LINK_RE.finditer(line):
            yield m.group(1)


def check_links(md_files: List[Path], repo_root: Path) -> List[str]:
    """Resolve every relative link/anchor in ``md_files``.

    Args:
      md_files: the markdown files to lint.
      repo_root: links resolving outside this directory are skipped
        (site-relative badge URLs etc.).

    Returns:
      Human-readable failure strings (empty = clean).
    """
    failures = []
    for md in md_files:
        for target in iter_links(md):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # http:, mailto:
                continue
            path_part, _, anchor = target.partition("#")
            if not path_part:                               # in-page #anchor
                if anchor and anchor not in anchors_of(md):
                    failures.append(f"{md}: dead in-page anchor #{anchor}")
                continue
            dest = (md.parent / path_part).resolve()
            try:
                dest.relative_to(repo_root.resolve())
            except ValueError:
                continue                                    # escapes repo
            if not dest.exists():
                failures.append(f"{md}: dead link {target}")
                continue
            if anchor and dest.suffix == ".md":
                if anchor not in anchors_of(dest):
                    failures.append(
                        f"{md}: dead anchor {target} "
                        f"(no heading slugs to '{anchor}' in {dest.name})")
    return failures


def run_doctests(md_path: Path) -> Tuple[int, int]:
    """Execute the ``>>>`` snippets of one markdown file.

    All ``python`` fenced blocks containing doctest prompts are joined
    (in order, sharing one namespace) and run.

    Returns:
      ``(failed, attempted)`` example counts; ``(0, 0)`` when the file
      has no doctest blocks.
    """
    blocks = [b for b in _PY_BLOCK_RE.findall(md_path.read_text())
              if ">>>" in b]
    if not blocks:
        return 0, 0
    src = "\n".join(blocks)
    test = doctest.DocTestParser().get_doctest(
        src, {}, md_path.name, str(md_path), 0)
    runner = doctest.DocTestRunner(optionflags=doctest.ELLIPSIS)
    runner.run(test)
    res = runner.summarize(verbose=False)
    return res.failed, res.attempted


def collect(paths: List[str]) -> List[Path]:
    """Expand file/dir arguments into a sorted list of ``*.md`` files."""
    out = []
    for p in paths:
        pp = Path(p)
        if pp.is_dir():
            out.extend(sorted(pp.rglob("*.md")))
        else:
            out.append(pp)
    return out


def main(argv=None) -> int:
    """CLI entry point; returns the process exit status."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.doclint", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("paths", nargs="+",
                    help="markdown files and/or directories to link-check")
    ap.add_argument("--doctest", action="append", default=[],
                    metavar="MD", help="also run the >>> snippets of this "
                    "markdown file (repeatable)")
    ap.add_argument("--root", default=".",
                    help="repo root; links escaping it are skipped")
    args = ap.parse_args(argv)

    md_files = collect(args.paths)
    failures = check_links(md_files, Path(args.root))
    for f in failures:
        print(f"doclint: {f}", file=sys.stderr)
    print(f"doclint: {len(md_files)} file(s), "
          f"{len(failures)} dead link(s)/anchor(s)")
    rc = 1 if failures else 0
    for md in args.doctest:
        failed, attempted = run_doctests(Path(md))
        print(f"doclint: {md}: {attempted} doctest example(s), "
              f"{failed} failed")
        if failed:
            rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
