"""The jitted entry-point registry: every device wave program the repo
ships, with its declared collective budget and donation contract.

Budgets are *declared* here (not inferred) — adding a Discipline means
adding its row.  The numbers encode the paper's wave contract:

* every fused wave = exactly 2 all_to_all (request + reply); the
  pipelined burst fuses ``request_k ‖ reply_{k-1}`` so its static count
  stays <= 2 for any K;
* FIFO runs the min-plus hypercube scan: <= 3*(ceil(log2 P)+1)
  collective-permutes (3 carries per ppermute round) and <= 3
  all_gathers for the replicated carries;
* LIFO adds one all_gather for tickets plus <= 2 all_reduce (the pmax
  ticket fold; the pipelined epilogue adds the second);
* priority / Seap keep one all_gather (replicated tier/bucket serve);
* Wavescope telemetry (PR 7) is budget-NEUTRAL: the ``[obs]`` variants
  lower the metrics-on entry points against the SAME budgets as their
  metrics-off twins — a telemetry implementation that added a collective
  (or broke the ``(state, metrics)`` donation) fails wavecheck statically;
* occupancy buckets (PR 9) are budget-NEUTRAL too: the ``[compact]``
  variants lower the same step / pipelined-burst entry points at every
  narrower envelope width of the bucket ladder against IDENTICAL budgets
  — compaction shrinks the all_to_all payloads, never the collective
  structure;
* the elastic migration wave is exactly 1 all_to_all + <= 2 all_reduce
  (lost-element pmax + moved-count psum);
* the legacy (pre-fusion) queue step is pinned at exactly 5 all_to_all —
  the seed baseline the fused path is measured against.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .budgets import CollectiveBudget


@dataclass
class ProgramSpec:
    """One compiled entry point under analysis."""
    name: str
    jitted: Any                      # lowerable: has .lower(*args)
    args: Tuple[Any, ...]
    budget: CollectiveBudget
    donated_leaves: int              # flat array leaves that MUST alias
    donated_params: Optional[Sequence[int]] = None
    meta: Dict[str, Any] = field(default_factory=dict)


def _ppermute_bound(p: int) -> int:
    return 3 * (math.ceil(math.log2(max(p, 2))) + 1)


def _wave_budget(kind: str, p: int, *, pipelined: bool,
                 burst: bool) -> CollectiveBudget:
    """Collective budget for one {step | burst} wave program."""
    a2a = {"max": {"all-to-all": 2}} if (pipelined and burst) \
        else {"exact": {"all-to-all": 2}}
    caps: Dict[str, int] = {}
    if kind == "queue":
        caps.update({"all-gather": 3,
                     "collective-permute": _ppermute_bound(p)})
    elif kind == "stack":
        caps.update({"all-gather": 1, "all-reduce": 2})
    elif kind in ("priority", "seap"):
        caps.update({"all-gather": 1})
    else:
        raise ValueError(f"no declared budget for discipline {kind!r}")
    merged = dict(a2a)
    merged.setdefault("max", {})
    merged["max"] = {**caps, **merged.get("max", {})}
    return CollectiveBudget(exact=merged.get("exact", {}),
                            max=merged["max"])


LEGACY_QUEUE_STEP = CollectiveBudget(
    exact={"all-to-all": 5},
    max={"all-gather": 3, "collective-permute": 64, "all-reduce": 2})

MIGRATION_BUDGET = CollectiveBudget(
    exact={"all-to-all": 1}, max={"all-reduce": 2})


def _n_leaves(tree) -> int:
    import jax
    return len(jax.tree.leaves(tree))


def build_programs(mesh, *, L: int = 2, K: int = 3, cap: int = 16,
                   W: int = 2, n_prios: int = 3, n_buckets: int = 4
                   ) -> List[ProgramSpec]:
    import jax.numpy as jnp

    from ..dqueue import (DevicePriorityQueue, DeviceQueue, DeviceSeapQueue,
                          DeviceStack)

    p = mesh.devices.size
    n = p * L
    zb = lambda *s: jnp.zeros(s, bool)
    zi = lambda *s: jnp.zeros(s, jnp.int32)

    def wave_args(q, kind: str, burst: bool, width: int = L):
        lead = (K,) if burst else ()
        nw = p * width
        args: List[Any] = [q.init_state(), zb(*lead, nw), zb(*lead, nw)]
        if kind == "priority":
            args.append(zi(*lead, nw))
        if kind == "seap":
            args.append(zi(*lead, nw))
        args.append(zi(*lead, nw, W))
        return tuple(args)

    kinds = [
        ("queue", lambda pipe, obs=False: DeviceQueue(
            mesh, "data", cap=cap, payload_width=W, ops_per_shard=L,
            pipelined=pipe, metrics=obs)),
        ("stack", lambda pipe, obs=False: DeviceStack(
            mesh, "data", cap=cap, payload_width=W, ops_per_shard=L,
            slot_depth=4, pipelined=pipe, metrics=obs)),
        ("priority", lambda pipe, obs=False: DevicePriorityQueue(
            mesh, "data", n_prios=n_prios, cap=cap, payload_width=W,
            ops_per_shard=L, pipelined=pipe, metrics=obs)),
        ("seap", lambda pipe, obs=False: DeviceSeapQueue(
            mesh, "data", n_buckets=n_buckets, cap=cap, payload_width=W,
            ops_per_shard=L, pipelined=pipe, metrics=obs)),
    ]

    specs: List[ProgramSpec] = []
    for kind, make in kinds:
        seq, pipe = make(False), make(True)
        leaves = _n_leaves(seq.init_state())
        specs.append(ProgramSpec(
            f"{kind}.step", seq._step, wave_args(seq, kind, burst=False),
            _wave_budget(kind, p, pipelined=False, burst=False),
            donated_leaves=leaves, meta={"discipline": kind}))
        specs.append(ProgramSpec(
            f"{kind}.run_waves[seq]", seq._run_waves,
            wave_args(seq, kind, burst=True),
            _wave_budget(kind, p, pipelined=False, burst=True),
            donated_leaves=leaves, meta={"discipline": kind}))
        specs.append(ProgramSpec(
            f"{kind}.run_waves[pipe]", pipe._run_waves,
            wave_args(pipe, kind, burst=True),
            _wave_budget(kind, p, pipelined=True, burst=True),
            donated_leaves=leaves, meta={"discipline": kind}))
        # Wavescope telemetry-on twins: args[0] becomes the donated
        # (state, metrics-ring) tuple (+2 aliased leaves: count, rows);
        # budgets are IDENTICAL — telemetry must add zero collectives
        obs = make(True, obs=True)
        for nm, fn, burst, pipelined in (
                ("step[obs]", obs._step, False, False),
                ("run_waves[pipe,obs]", obs._run_waves, True, True)):
            a = wave_args(obs, kind, burst=burst)
            a = ((a[0], obs.engine.init_metrics_state()),) + a[1:]
            specs.append(ProgramSpec(
                f"{kind}.{nm}", fn, a,
                _wave_budget(kind, p, pipelined=pipelined, burst=burst),
                donated_leaves=leaves + 2,
                meta={"discipline": kind, "telemetry": True}))
        # occupancy-bucket twins (PR 9): the SAME entry points lowered at
        # every narrower envelope width of the bucket ladder, pinned
        # against IDENTICAL budgets — compaction must shrink the wire
        # payloads, never change the collective structure
        from ..dqueue.wave_engine import bucket_ladder
        for w in bucket_ladder(L)[:-1]:
            specs.append(ProgramSpec(
                f"{kind}.step[compact:w{w}]", seq._step,
                wave_args(seq, kind, burst=False, width=w),
                _wave_budget(kind, p, pipelined=False, burst=False),
                donated_leaves=leaves,
                meta={"discipline": kind, "compact": True, "width": w}))
            specs.append(ProgramSpec(
                f"{kind}.run_waves[pipe,compact:w{w}]", pipe._run_waves,
                wave_args(pipe, kind, burst=True, width=w),
                _wave_budget(kind, p, pipelined=True, burst=True),
                donated_leaves=leaves,
                meta={"discipline": kind, "compact": True, "width": w}))

    legacy = DeviceQueue(mesh, "data", cap=cap, payload_width=W,
                         ops_per_shard=L, fused=False)
    specs.append(ProgramSpec(
        "queue-legacy.step", legacy._step,
        wave_args(legacy, "queue", burst=False), LEGACY_QUEUE_STEP,
        donated_leaves=_n_leaves(legacy.init_state()),
        meta={"discipline": "queue", "legacy": True}))
    # runtime-constructed twins (PR 10): the SAME entry points built
    # through a Runtime handle instead of a bare mesh, pinned against
    # IDENTICAL budgets — the runtime seam must add zero collectives and
    # leave the donation contract untouched
    from ..runtime import LocalRuntime
    rt = LocalRuntime(devices=list(mesh.devices.flat))
    rt_seq = DeviceQueue(rt, cap=cap, payload_width=W, ops_per_shard=L,
                         pipelined=False)
    rt_pipe = DeviceQueue(rt, cap=cap, payload_width=W, ops_per_shard=L,
                          pipelined=True)
    rt_leaves = _n_leaves(rt_seq.init_state())
    specs.append(ProgramSpec(
        "queue.step[runtime]", rt_seq._step,
        wave_args(rt_seq, "queue", burst=False),
        _wave_budget("queue", p, pipelined=False, burst=False),
        donated_leaves=rt_leaves,
        meta={"discipline": "queue", "runtime": True}))
    specs.append(ProgramSpec(
        "queue.run_waves[pipe,runtime]", rt_pipe._run_waves,
        wave_args(rt_pipe, "queue", burst=True),
        _wave_budget("queue", p, pipelined=True, burst=True),
        donated_leaves=rt_leaves,
        meta={"discipline": "queue", "runtime": True}))
    return specs


def build_migration_programs(*, cap: int = 16, W: int = 2, L: int = 2,
                             n_prios: int = 3, n_buckets: int = 4
                             ) -> List[ProgramSpec]:
    """The elastic migration wave for all four disciplines, lowered on
    the current elastic mesh as a shrink-shaped reshard (P -> P-2)."""
    from ..dqueue import (ElasticDevicePriorityQueue, ElasticDeviceQueue,
                          ElasticDeviceSeapQueue, ElasticDeviceStack)
    from ..runtime import LocalRuntime

    n_dev = LocalRuntime().pool_size
    P0 = min(4, n_dev)
    if P0 < 3:
        return []
    kinds = [
        ("queue", lambda: ElasticDeviceQueue(
            P0, cap=cap, payload_width=W, ops_per_shard=L)),
        ("stack", lambda: ElasticDeviceStack(
            P0, cap=cap, payload_width=W, ops_per_shard=L, slot_depth=4)),
        ("priority", lambda: ElasticDevicePriorityQueue(
            P0, n_prios=n_prios, cap=cap, payload_width=W,
            ops_per_shard=L)),
        ("seap", lambda: ElasticDeviceSeapQueue(
            P0, n_buckets=n_buckets, cap=cap, payload_width=W,
            ops_per_shard=L)),
    ]
    specs: List[ProgramSpec] = []
    for kind, make in kinds:
        eq = make()
        entry = eq._migration_for(eq.mesh, P0, P0 - 2)[0]
        args = eq._unpack(eq.state)
        specs.append(ProgramSpec(
            f"{kind}.migration", entry, tuple(args), MIGRATION_BUDGET,
            donated_leaves=2, donated_params=(2, 3),
            meta={"discipline": kind, "P_from": P0, "P_to": P0 - 2}))
    return specs
