"""CLI: ``python -m repro.analysis --all``.

Runs every wavecheck rule family on a forced multi-device CPU mesh and
prints (or writes) the JSON report.  Exit status is 0 iff no rule
violated.  ``--selftest`` runs the mutation self-test instead and fails
unless >= 3 rule families catch the deliberately broken Discipline.

Device forcing happens here, BEFORE jax is imported — the analysis
package itself stays jax-free at import time for exactly this reason.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _force_devices(n: int) -> None:
    if "jax" in sys.modules:     # too late to force; use what we have
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = \
            f"{flags} --xla_force_host_platform_device_count={n}".strip()
        os.environ.setdefault("JAX_PLATFORMS", "cpu")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="wavecheck: static invariant analyzer for the device "
                    "wave path")
    ap.add_argument("--all", action="store_true",
                    help="run every rule family (default)")
    ap.add_argument("--selftest", action="store_true",
                    help="run the mutation self-test instead")
    ap.add_argument("--json", metavar="PATH",
                    help="also write the JSON report to PATH")
    ap.add_argument("--devices", type=int, default=8,
                    help="forced CPU device count (default 8; ignored if "
                         "jax is already imported)")
    ap.add_argument("--skip-recompile", action="store_true",
                    help="skip the recompile-guard family (fastest)")
    args = ap.parse_args(argv)

    _force_devices(args.devices)

    if args.selftest:
        from .selftest import run_selftest
        report = run_selftest()
    else:
        from .runner import run_all
        report = run_all(skip_recompile=args.skip_recompile)

    text = json.dumps(report, indent=2, sort_keys=True)
    if args.json:
        with open(args.json, "w", encoding="utf-8") as fh:
            fh.write(text + "\n")
    print(text)
    ok = bool(report.get("passed"))
    if args.selftest:
        print(f"wavecheck selftest: {report['n_tripped']}/5 rule families "
              f"tripped (need >= {report['required']}) -> "
              f"{'OK' if ok else 'FAIL'}", file=sys.stderr)
    else:
        print(f"wavecheck: {report['n_violations']} violations across "
              f"{len(report['programs'])} programs -> "
              f"{'OK' if ok else 'FAIL'}", file=sys.stderr)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
