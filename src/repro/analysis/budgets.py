"""Rule family 1 — collective budgets.

A Discipline declares, per program shape (step / sequential burst /
pipelined burst / migration), how many of each collective its compiled
wave may contain.  The check runs the structured HLO op walk and compares:

* ``exact``  — opcode must appear exactly N times (the two-phase wave
               contract: request + reply = 2 all_to_all),
* ``max``    — opcode may appear at most N times (e.g. the hypercube
               ppermute ladder is bounded by 3*(ceil(log2 P)+1)),
* anything else in the collective domain must be absent.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Union

from .hlo import HloProgram, collective_counts
from .report import Violation


@dataclass(frozen=True)
class CollectiveBudget:
    """Declared collective budget for one compiled program."""
    exact: Dict[str, int] = field(default_factory=dict)
    max: Dict[str, int] = field(default_factory=dict)

    def merged_keys(self):
        return set(self.exact) | set(self.max)


def check_budget(program_name: str,
                 program: Union[HloProgram, str],
                 budget: CollectiveBudget) -> List[Violation]:
    counts = collective_counts(program)
    out: List[Violation] = []
    for opcode, want in budget.exact.items():
        got = counts.get(opcode, 0)
        if got != want:
            out.append(Violation(
                "collective_budget", program_name,
                f"{opcode}: expected exactly {want}, compiled module "
                f"has {got}",
                {"opcode": opcode, "expected": want, "got": got}))
    for opcode, cap in budget.max.items():
        got = counts.get(opcode, 0)
        if got > cap:
            out.append(Violation(
                "collective_budget", program_name,
                f"{opcode}: budget allows at most {cap}, compiled module "
                f"has {got}",
                {"opcode": opcode, "max": cap, "got": got}))
    for opcode, got in sorted(counts.items()):
        if got and opcode not in budget.merged_keys():
            out.append(Violation(
                "collective_budget", program_name,
                f"{opcode}: {got} undeclared collective(s) — extend the "
                f"budget or remove the op",
                {"opcode": opcode, "got": got}))
    return out
