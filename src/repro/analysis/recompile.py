"""Rule family 3 — recompile guard.

``jax.monitoring`` emits ``/jax/core/compile/backend_compile_duration``
once per *real* backend compile and stays silent on cache hits — exactly
the observable we need to assert the elastic layer's mesh / inner-engine /
migration caches (PR 2) prevent recompilation when membership bounces
between shard counts, that the burst-length jit cache holds when K
bounces, and that bouncing across the occupancy-bucket envelope ladder
(PR 9) re-uses the per-width executables instead of recompiling.

The scenario runs every bounce twice: the first pass is allowed (and
expected) to compile; the second identical pass must compile *nothing*.
"""
from __future__ import annotations

from typing import Any, Dict, List

import numpy as np

from .report import Violation

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"


class CompilationTracker:
    """Counts backend compiles inside a ``with`` block.

    jax.monitoring listeners cannot be individually unregistered, so one
    process-wide listener is installed on first use and fans out to the
    stack of active trackers.
    """
    _installed = False
    _active: List["CompilationTracker"] = []

    def __init__(self) -> None:
        self.count = 0
        self.events: List[float] = []

    @classmethod
    def _on_event(cls, event: str, duration: float, **kw: Any) -> None:
        if event == _COMPILE_EVENT:
            for t in cls._active:
                t.count += 1
                t.events.append(duration)

    @classmethod
    def _ensure_listener(cls) -> None:
        if not cls._installed:
            from jax import monitoring
            monitoring.register_event_duration_secs_listener(cls._on_event)
            cls._installed = True

    def __enter__(self) -> "CompilationTracker":
        self._ensure_listener()
        CompilationTracker._active.append(self)
        return self

    def __exit__(self, *exc: Any) -> None:
        CompilationTracker._active.remove(self)


def _bounce(eq, K_a: int, K_b: int, grow_by: int) -> None:
    """One full membership + burst-length + bucket-width bounce on an
    elastic queue: step, burst K_a, burst K_b, the occupancy-bucket
    ladder, grow, step, shrink back.  The PR 9 envelope buckets are pure
    jit shape keys, so bouncing across widths must hit the same
    per-shape executable cache the K bounce exercises."""
    import jax.numpy as jnp

    P0 = eq.n_shards

    def drive_step(w=None):
        n = eq.n_shards * (eq.L if w is None else w)
        eq.step(jnp.zeros(n, bool), jnp.zeros(n, bool),
                jnp.zeros((n, eq.W), jnp.int32))

    def drive_waves(K: int):
        n = eq.n_shards * eq.L
        eq.run_waves(jnp.zeros((K, n), bool), jnp.zeros((K, n), bool),
                     jnp.zeros((K, n, eq.W), jnp.int32))

    def drive_ladder():
        for w in eq.bucket_widths():      # narrow -> full envelope
            drive_step(w)
        for w in reversed(eq.bucket_widths()):   # bounce back down
            drive_step(w)

    drive_step()
    drive_waves(K_a)
    drive_waves(K_b)
    drive_waves(K_a)                      # K bounce back: cached jit shape
    drive_ladder()                        # width bounce: cached jit shapes
    eq.grow(grow_by)
    drive_step()
    drive_waves(K_a)
    drive_ladder()                        # ladder on the grown membership
    eq.shrink(list(range(P0, P0 + grow_by)))
    drive_step()


def check_recompile_guard() -> "tuple[List[Violation], Dict[str, Any]]":
    """Warm one bounce (compiles allowed), then repeat it and require the
    compilation counter to stay at zero."""
    import jax

    from ..dqueue import ElasticDeviceQueue

    n_dev = len(jax.devices())
    if n_dev < 3:
        return [], {"skipped": f"needs >= 3 devices, have {n_dev}"}
    grow_by = 1 if n_dev < 6 else 2
    P0 = min(4, n_dev - grow_by)

    eq = ElasticDeviceQueue(P0, cap=16, payload_width=2, ops_per_shard=2)
    with CompilationTracker() as warm:
        _bounce(eq, K_a=2, K_b=3, grow_by=grow_by)
    with CompilationTracker() as second:
        _bounce(eq, K_a=2, K_b=3, grow_by=grow_by)

    info: Dict[str, Any] = {
        "warm_compiles": warm.count,
        "second_bounce_compiles": second.count,
        "P0": P0, "grow_by": grow_by,
    }
    out: List[Violation] = []
    if warm.count == 0:
        out.append(Violation(
            "recompile_guard", "elastic.bounce",
            "tracker observed no compiles on the cold bounce — the "
            "compile-event hook is broken, guard is vacuous", dict(info)))
    if second.count != 0:
        out.append(Violation(
            "recompile_guard", "elastic.bounce",
            f"{second.count} recompilation(s) on an identical second "
            f"membership/burst bounce — a mesh/program cache is leaking",
            dict(info)))
    # sanity: the caches must actually be populated, not bypassed
    if not eq._inner_cache or not eq._mig_cache or not eq._mesh_cache:
        out.append(Violation(
            "recompile_guard", "elastic.bounce",
            "elastic caches empty after a bounce — cache keying bypassed",
            {"inner": len(eq._inner_cache), "mig": len(eq._mig_cache),
             "mesh": len(eq._mesh_cache)}))
    moved = sum(int(np.asarray(m["moved"])) for m in eq.migrations)
    info["migrations"] = len(eq.migrations)
    info["moved_total"] = moved
    return out, info
