"""Structured HLO walk: per-line op parse + input-output alias table.

This replaces the four copy-pasted ``re.findall(r"all-to-all...")``
counters: instead of substring-matching anywhere in the module text, each
instruction line is parsed into ``(var, shape, opcode)`` — so operand
references, metadata ``op_name`` strings and comments can never be
miscounted, and async ``-start``/``-done`` pairs collapse to one op.
"""
from __future__ import annotations

import re
from typing import Dict, List, NamedTuple, Sequence, Tuple, Union


class HloOp(NamedTuple):
    var: str       # "%all-to-all.1" (or "" when unparsable)
    shape: str     # "s32[8,4]{1,0}" or "(s32[4]{0}, s32[4]{0})"
    opcode: str    # normalized: "all-to-all-start" -> "all-to-all"
    line_no: int   # 1-based line in the module text


class HloAlias(NamedTuple):
    output_index: str  # tuple index of the aliased output, e.g. "0" or "1,2"
    param: int         # parameter number it aliases
    param_index: str   # tuple index within the parameter (usually "")
    kind: str          # "may-alias" | "must-alias"


class HloProgram(NamedTuple):
    ops: Tuple[HloOp, ...]
    aliases: Tuple[HloAlias, ...]


# Collective opcodes the budget rule understands.
COLLECTIVE_OPS = frozenset({
    "all-to-all", "all-gather", "all-reduce", "reduce-scatter",
    "collective-permute", "collective-broadcast", "all-gather-done",
})

_ALIAS_ENTRY = re.compile(
    r"\{\s*([\d,\s]*)\}:\s*\(\s*(\d+)\s*,\s*\{([\d,\s]*)\}"
    r"(?:\s*,\s*([a-z-]+))?\s*\)")
_SHAPE_TOKEN = re.compile(r"\S+")
_OPCODE = re.compile(r"([A-Za-z][\w-]*)\(")


def _balanced_brace_span(line: str, marker: str) -> str:
    """Contents of the ``{...}`` (nested braces balanced) right after
    ``marker`` in ``line``; "" when the marker is absent."""
    at = line.find(marker)
    if at < 0:
        return ""
    i = line.find("{", at)
    if i < 0:
        return ""
    depth = 0
    for j in range(i, len(line)):
        depth += line[j] == "{"
        depth -= line[j] == "}"
        if depth == 0:
            return line[i + 1:j]
    return ""


def _parse_rhs(rhs: str) -> Union[Tuple[str, str], None]:
    """Parse ``<shape> <opcode>(...)`` — the RHS of one instruction."""
    rhs = rhs.lstrip()
    if rhs.startswith("("):           # tuple shape: balanced-paren scan
        depth, i = 0, 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        shape, rest = rhs[:i + 1], rhs[i + 1:]
    else:
        m = _SHAPE_TOKEN.match(rhs)
        if not m:
            return None
        shape, rest = m.group(0), rhs[m.end():]
    m = _OPCODE.match(rest.lstrip())
    if not m:
        return None
    return shape, m.group(1)


def normalize_opcode(opcode: str) -> Union[str, None]:
    """Collapse async pairs: ``*-start`` is the op, ``*-done`` is dropped
    (returns None).  Plain opcodes pass through."""
    if opcode.endswith("-done") or opcode.endswith("-update"):
        return None
    if opcode.endswith("-start"):
        return opcode[:-len("-start")]
    return opcode


def parse_hlo(text: str) -> HloProgram:
    """Walk compiled HLO text line by line into structured ops + aliases."""
    ops: List[HloOp] = []
    aliases: List[HloAlias] = []
    for line_no, line in enumerate(text.splitlines(), start=1):
        s = line.strip()
        if not s:
            continue
        if s.startswith("HloModule"):
            span = _balanced_brace_span(s, "input_output_alias=")
            for om in _ALIAS_ENTRY.finditer(span):
                aliases.append(HloAlias(
                    output_index=om.group(1).replace(" ", ""),
                    param=int(om.group(2)),
                    param_index=om.group(3).replace(" ", ""),
                    kind=om.group(4) or "may-alias"))
            continue
        # instruction lines: "[ROOT] %var = <shape> <opcode>(...)"
        eq = s.find(" = ")
        if eq < 0:
            continue
        lhs = s[:eq].strip()
        if lhs.startswith("ROOT "):
            lhs = lhs[5:].strip()
        if not lhs.startswith("%") and not re.match(r"^[\w.-]+$", lhs):
            continue
        parsed = _parse_rhs(s[eq + 3:])
        if parsed is None:
            continue
        shape, opcode = parsed
        norm = normalize_opcode(opcode)
        if norm is None:
            continue
        ops.append(HloOp(var=lhs, shape=shape, opcode=norm, line_no=line_no))
    return HloProgram(ops=tuple(ops), aliases=tuple(aliases))


def op_counts(program: Union[HloProgram, str]) -> Dict[str, int]:
    if isinstance(program, str):
        program = parse_hlo(program)
    counts: Dict[str, int] = {}
    for op in program.ops:
        counts[op.opcode] = counts.get(op.opcode, 0) + 1
    return counts


def collective_counts(program: Union[HloProgram, str]) -> Dict[str, int]:
    """Counts restricted to cross-device collectives (budget domain)."""
    return {k: v for k, v in op_counts(program).items()
            if k in COLLECTIVE_OPS}


def input_output_aliases(program: Union[HloProgram, str]
                         ) -> Tuple[HloAlias, ...]:
    if isinstance(program, str):
        program = parse_hlo(program)
    return program.aliases


def compiled_text(jitted, args: Sequence) -> str:
    """Lower + compile a jitted callable and return its HLO text."""
    return jitted.lower(*args).compile().as_text()


def count_op(program: Union[HloProgram, str], opcode: str) -> int:
    return op_counts(program).get(opcode, 0)


def count_all_to_all(jitted, args: Sequence) -> int:
    """Drop-in replacement for the four regex counters in the tier-1
    tests: number of all-to-all ops (async pairs counted once) in the
    compiled module of ``jitted(*args)``."""
    return count_op(compiled_text(jitted, tuple(args)), "all-to-all")
