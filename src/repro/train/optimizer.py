"""AdamW with global-norm clipping and cosine schedule (pure JAX).

Optimizer state (m, v in f32) shards exactly like its parameter (ZeRO:
the FSDP axis in the param spec shards the moments too).  Params are stored
bf16; updates are computed in f32 and cast back.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    m: dict
    v: dict
    step: jax.Array


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(m=zeros,
                      v=jax.tree.map(jnp.copy, zeros),
                      step=jnp.zeros((), jnp.int32))


def cosine_lr(step, base_lr=3e-4, warmup=100, total=10_000, min_frac=0.1):
    warm = base_lr * (step + 1) / warmup
    t = jnp.clip((step - warmup) / jnp.maximum(1, total - warmup), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup, warm, cos).astype(jnp.float32)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(params, grads, state: AdamWState, lr,
                 b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
                 clip_norm=1.0):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, AdamWState(m=new_m, v=new_v, step=step), gnorm
