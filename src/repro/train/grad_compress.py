"""Gradient compression for cross-pod (DCN) reduction: int8 quantization
with error feedback.

At 2+ pods the gradient all-reduce crosses the slow inter-pod links.  The
standard distributed-optimization trick: reduce-scatter in full precision
inside the pod (fast ICI), quantize the pod-local partial sums to int8 with
a per-block scale, all-reduce the int8 payload across pods (4x fewer DCN
bytes than bf16), dequantize, and carry the quantization residual into the
next step (error feedback keeps the compression unbiased over time).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 1024


def _quant_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequant(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_grads(grads, residual):
    """Quantize grads+residual; returns (payload, new_residual).

    payload is a pytree of (int8 blocks, f32 scales) leaf-pairs ready for
    the cross-pod all-reduce; residual carries the error feedback."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = (jax.tree.leaves(residual) if residual is not None
              else [jnp.zeros(g.shape, jnp.float32) for g in flat_g])
    payload, new_res = [], []
    for g, r in zip(flat_g, flat_r):
        x = g.astype(jnp.float32) + r
        q, s = _quant_int8(x)
        deq = _dequant(q, s, g.shape)
        payload.append((q, s))
        new_res.append(x - deq)
    return (jax.tree.unflatten(treedef, payload),
            jax.tree.unflatten(treedef, new_res))


def decompress_grads(payload, shapes):
    flat_p = jax.tree.leaves(payload,
                             is_leaf=lambda x: isinstance(x, tuple))
    flat_s, treedef = jax.tree.flatten(shapes)
    out = [_dequant(q, s, g.shape) for (q, s), g in zip(flat_p, flat_s)]
    return jax.tree.unflatten(treedef, out)


def compression_ratio(grads) -> float:
    """Bytes(int8+scales) / bytes(bf16) — reported in EXPERIMENTS.md."""
    total_in = sum(g.size * 2 for g in jax.tree.leaves(grads))
    total_out = sum(g.size * 1 + (g.size // BLOCK + 1) * 4
                    for g in jax.tree.leaves(grads))
    return total_out / total_in
