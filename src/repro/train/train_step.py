"""Sharded train step: loss + grad + AdamW, with gradient-accumulation
microbatching (the activation-memory lever at 100B+ scale) and optional
int8+error-feedback gradient compression for the cross-pod axis.

Under pjit the data-parallel gradient reduction is inserted by GSPMD from
the shardings (reduce-scatter onto the FSDP axis + all-reduce across pods);
compute/comm overlap comes from XLA's latency-hiding scheduler — see
EXPERIMENTS.md §Perf for the measured collective schedule.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models.costing import scan as cscan
from .optimizer import AdamWState, adamw_update, cosine_lr


def make_train_step(model, *, num_microbatches: int = 1,
                    base_lr: float = 3e-4, total_steps: int = 10_000,
                    remat: bool = True):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    batch leaves have leading dim = global_batch; with microbatching they are
    reshaped to [M, gb/M, ...] and grads accumulate over a lax.scan (f32)."""

    def loss_fn(params, mb):
        return model.loss_fn(params, mb, remat=remat)

    def grads_of(params, batch):
        if num_microbatches == 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        M = num_microbatches

        def resplit(x):
            return x.reshape((M, x.shape[0] // M) + x.shape[1:])

        mbs = jax.tree.map(resplit, batch)

        def acc_step(acc, mb):
            loss_acc, g_acc = acc
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32) / M, g_acc, g)
            return (loss_acc + loss / M, g_acc), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (loss, grads), _ = cscan(acc_step, (jnp.float32(0), zeros), mbs)
        return loss, grads

    def train_step(params, opt_state: AdamWState, batch):
        loss, grads = grads_of(params, batch)
        lr = cosine_lr(opt_state.step, base_lr=base_lr, total=total_steps)
        params, opt_state, gnorm = adamw_update(params, grads, opt_state, lr)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm,
                   "lr": lr}
        return params, opt_state, metrics

    return train_step
