"""Device-side Wavescope metrics: the donated ring buffer the wave fills.

The telemetry contract is **zero extra collectives**: every field of a
wave's metrics row is pure arithmetic on values the wave already
materializes at dispatch time — the op masks, the :class:`~..dqueue.
wave_engine.Dispatch` routing decisions, and the (replicated) interval
carry.  Per-shard counters are summed on the HOST at drain time (each
shard's row holds its local count; the global count is the sum over the
sharded ring's leading axis), so nothing about telemetry touches the
wire.  The ring rides the engine's donated state tuple through
``lax.scan`` bursts and is drained only at burst boundaries via
:meth:`~..dqueue.wave_engine.WaveEngine.drain_metrics` — the ONE
sanctioned device→host telemetry read (see the ``no-host-callback-in-
wave`` AST lint rule).

Row layout (all int32)::

    seq ‖ puts ‖ gets ‖ valid ‖ bottom ‖ aux ‖ headroom ‖ width ‖
    occ[n_windows]

* ``seq``      replicated wave sequence number (monotone across bursts);
* ``puts``     PER-SHARD admitted enqueues this wave (sum at drain);
* ``gets``     PER-SHARD admitted dequeues this wave (sum at drain);
* ``valid``    PER-SHARD valid ops offered this wave (sum at drain);
* ``bottom``   PER-SHARD valid ops that got the ⊥ reply, i.e. were not
               routed (sum at drain);
* ``aux``      the discipline's replicated per-wave extra — ``n_relaxed``
               for the priority discipline, ``n_active`` (directory size,
               whose deltas are the split/merge signal) for Seap, 0
               otherwise;
* ``headroom`` replicated free-slot count across every tier/bucket
               window after the wave's reservations;
* ``width``    replicated per-shard envelope width the wave rode — the
               occupancy bucket (PR 9); a constant baked into each
               bucket's trace, so it costs nothing at run time;
* ``occ[w]``   replicated post-dispatch occupancy of window ``w`` (the
               FIFO/LIFO interval, each priority tier, each Seap bucket).

This module is imported by ``wave_engine`` — it must not import anything
from ``repro.dqueue`` (and it does not).
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

# replicated-vs-per-shard split of the fixed row head (occ tail follows)
METRIC_HEAD = ("seq", "puts", "gets", "valid", "bottom", "aux", "headroom",
               "width")
N_HEAD = len(METRIC_HEAD)
_ADDITIVE = frozenset({"puts", "gets", "valid", "bottom"})


class MetricsState(NamedTuple):
    """The donated telemetry ring carried through the wave path.

    ``count`` is the replicated total number of waves ever recorded (the
    next row's ``seq``); ``rows`` is ``[n_shards, ring, N_HEAD +
    n_windows]`` int32 sharded on the leading axis — inside shard_map
    each shard sees its local ``[1, ring, M]`` block.
    """
    count: jax.Array
    rows: jax.Array


def row_width(n_windows: int) -> int:
    return N_HEAD + int(n_windows)


def init_metrics_state(n_shards: int, ring: int, n_windows: int,
                       mesh=None, axis_name: str | None = None,
                       runtime=None):
    """A zeroed ring.  With ``mesh``/``axis_name`` the buffers are placed
    explicitly (count replicated, rows sharded) so donation works from
    the first burst; with ``runtime`` (PR 10) the placement goes through
    the runtime handle's data plane instead of a raw ``device_put``."""
    count = jnp.int32(0)
    rows = jnp.zeros((n_shards, ring, row_width(n_windows)), jnp.int32)
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        put = runtime.put if runtime is not None else jax.device_put
        count = put(count, NamedSharding(mesh, P()))
        rows = put(rows, NamedSharding(mesh, P(axis_name)))
    return MetricsState(count, rows)


def record_row(m: MetricsState, row: jax.Array) -> MetricsState:
    """Append one wave's ``[M]`` row at ring index ``count % ring``.

    Runs INSIDE shard_map on the local ``[1, ring, M]`` view; pure
    ``dynamic_update_slice`` arithmetic — no collective, no host
    callback."""
    ring = m.rows.shape[1]
    idx = jnp.mod(m.count, ring)
    rows = lax.dynamic_update_slice(
        m.rows, row.astype(jnp.int32)[None, None, :], (0, idx, 0))
    return MetricsState(m.count + 1, rows)


def drain(m: MetricsState) -> list:
    """HOST-side drain at a burst boundary: materialize the ring, order
    rows chronologically, and combine the shard dimension (per-shard
    counters summed, replicated fields read off shard 0).

    Returns a list of wave-summary dicts, oldest first; ``occ`` is the
    per-window occupancy list."""
    count = int(np.asarray(m.count))
    rows = np.asarray(m.rows)              # [n_shards, ring, M]
    ring = rows.shape[1]
    n_valid = min(count, ring)
    if n_valid == 0:
        return []
    order = [(count - k) % ring for k in range(n_valid, 0, -1)]
    summed = rows.sum(axis=0)              # per-shard counters
    rep = rows[0]                          # replicated fields
    out = []
    for i in order:
        d = {name: int((summed if name in _ADDITIVE else rep)[i, j])
             for j, name in enumerate(METRIC_HEAD)}
        d["occ"] = [int(v) for v in rep[i, N_HEAD:]]
        out.append(d)
    return out
