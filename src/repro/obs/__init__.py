"""Wavescope: observability for the Skueue wave runtime.

Four layers, one package:

1. ``obs.device``   — the donated device-side metrics ring the
   :class:`~repro.dqueue.wave_engine.WaveEngine` fills with ZERO extra
   collectives (every row field is arithmetic on values the wave already
   materializes); drained to host only at burst boundaries.
2. ``obs.trace``    — wall-clock timers (alpa style) and a span API with
   ``jax.profiler`` annotations and Chrome-trace/perfetto JSON export.
3. ``obs.recorder`` — the flight recorder: the last K wave summaries,
   attached to :class:`~repro.dqueue.errors.QueueOverflowError` as the
   occupancy trajectory that led to the failure.
4. ``obs.export``   — JSON / Prometheus-text emitters for
   :meth:`~repro.serve.engine.ServeEngine.metrics` snapshots.

CLI: ``python -m repro.obs --smoke`` (forced multi-device CPU smoke run
printing a live snapshot; ``--trace out.json`` also writes a perfetto
trace).  Imported lazily so the CLI can pin ``XLA_FLAGS`` device forcing
*before* jax loads.
"""
from typing import Any

__all__ = [
    "METRIC_HEAD", "MetricsState", "init_metrics_state", "record_row",
    "drain", "row_width",
    "Timer", "Timers", "timers", "Tracer", "tracer", "span",
    "FlightRecorder",
    "to_json", "to_prometheus",
]

_LAZY = {
    "METRIC_HEAD": "device", "MetricsState": "device",
    "init_metrics_state": "device", "record_row": "device",
    "drain": "device", "row_width": "device",
    "Timer": "trace", "Timers": "trace", "timers": "trace",
    "Tracer": "trace", "tracer": "trace", "span": "trace",
    "FlightRecorder": "recorder",
    "to_json": "export", "to_prometheus": "export",
}


def __getattr__(name: str) -> Any:
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(f".{_LAZY[name]}", __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
