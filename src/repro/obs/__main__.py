"""CLI: ``python -m repro.obs --smoke``.

Wavescope's operational entry point: run a short telemetry-on wave burst
on a forced multi-device CPU mesh, print the live metrics snapshot (JSON
by default, Prometheus text with ``--format prom``), and optionally
export the host-trace spans as a Chrome/perfetto trace
(``--trace PATH``).  Exit status is 0 iff the smoke burst ran, the
drained wave summaries are self-consistent, and telemetry added zero
collectives to the wave program.

Device forcing happens here, BEFORE jax is imported — the obs package
stays jax-free at import time for exactly this reason.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _force_devices(n: int) -> None:
    if "jax" in sys.modules:     # too late to force; use what we have
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = \
            f"{flags} --xla_force_host_platform_device_count={n}".strip()
        os.environ.setdefault("JAX_PLATFORMS", "cpu")


def _smoke(n_devices: int, waves: int) -> dict:
    """Telemetry-on burst on an elastic FIFO queue; returns the snapshot
    report {ok, collectives_{on,off}, waves, prometheus_lines, ...}."""
    import numpy as np

    from ..analysis import count_all_to_all
    from ..dqueue import DeviceQueue, ElasticDeviceQueue
    from ..runtime import LocalRuntime
    from .export import to_prometheus
    from .trace import span, tracer

    q = ElasticDeviceQueue(n_devices, cap=256, payload_width=2,
                           ops_per_shard=8, metrics=True,
                           flight_k=max(16, waves))
    n = q.n_shards * q.L
    rng = np.random.default_rng(0)
    with span("obs:smoke", cat="cli", waves=waves):
        for k in range(waves):
            is_enq = rng.random(n) < 0.6
            valid = rng.random(n) < 0.9
            payload = rng.integers(0, 1 << 20, (n, 2)).astype(np.int32)
            q.step(is_enq, valid, payload)
    rows = q.trajectory()
    ok = bool(rows) and [r["seq"] for r in rows] == sorted(
        {r["seq"] for r in rows})
    # telemetry must not add collectives: lower both flavors and count
    mesh = LocalRuntime().mesh(n_shards=q.n_shards)
    args_np = (np.zeros(n, bool), np.zeros(n, bool),
               np.zeros((n, 2), np.int32))
    c = {}
    for tag, on in (("off", False), ("on", True)):
        dq = DeviceQueue(mesh, "data", cap=256, payload_width=2,
                         ops_per_shard=8, metrics=on)
        st = dq.init_state()
        st = (st, dq.engine._mstate) if on else st
        c[tag] = count_all_to_all(dq._step, (st,) + args_np)
    snapshot = {
        "smoke": {"n_devices": q.n_shards, "waves": waves,
                  "queue_size": q.size},
        "collectives": {"telemetry_off": c["off"], "telemetry_on": c["on"],
                        "added": c["on"] - c["off"]},
        "wave_summaries": rows,
        "spans": len(tracer.events()),
    }
    snapshot["ok"] = ok and c["on"] == c["off"]
    snapshot["prometheus"] = to_prometheus(
        {k: v for k, v in snapshot.items()
         if k in ("smoke", "collectives")}, prefix="repro_obs")
    return snapshot


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Wavescope: telemetry for the device wave path")
    ap.add_argument("--smoke", action="store_true",
                    help="run a telemetry-on burst and print the snapshot "
                         "(default)")
    ap.add_argument("--devices", type=int, default=8,
                    help="forced CPU device count (default 8; ignored if "
                         "jax is already imported)")
    ap.add_argument("--waves", type=int, default=6,
                    help="waves in the smoke burst (default 6)")
    ap.add_argument("--format", choices=("json", "prom"), default="json",
                    help="snapshot output format (default json)")
    ap.add_argument("--json", metavar="PATH",
                    help="also write the JSON snapshot to PATH")
    ap.add_argument("--trace", metavar="PATH",
                    help="export the host spans as a Chrome/perfetto "
                         "trace JSON to PATH")
    args = ap.parse_args(argv)

    _force_devices(args.devices)

    report = _smoke(args.devices, args.waves)

    from .export import to_json
    text = to_json(report)
    if args.json:
        with open(args.json, "w", encoding="utf-8") as fh:
            fh.write(text + "\n")
    print(report["prometheus"] if args.format == "prom" else text)
    if args.trace:
        from .trace import tracer
        tracer.export_chrome_trace(args.trace)
        print(f"wrote {len(tracer.events())} spans to {args.trace}",
              file=sys.stderr)
    added = report["collectives"]["added"]
    print(f"wavescope smoke: {len(report['wave_summaries'])} wave "
          f"summaries, +{added} collectives with telemetry on -> "
          f"{'OK' if report['ok'] else 'FAIL'}", file=sys.stderr)
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
