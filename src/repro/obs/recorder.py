"""The flight recorder: a bounded host-side ring of wave summaries.

Elastic queue wrappers (and :class:`~repro.dqueue.work_queue.WorkQueue` /
:class:`~repro.serve.engine.ServeEngine`) drain the device metrics ring
at every burst boundary into one of these; when a wave overflows, the
recorder's trajectory — the last K wave summaries, i.e. the occupancy
pressure ramp that led to the failure — is attached to the raised
:class:`~repro.dqueue.errors.QueueOverflowError` /
:class:`~repro.serve.engine.ServeInvariantError` so the post-mortem no
longer starts from "this was data loss" but from the 16-wave history
that caused it.
"""
from __future__ import annotations

from collections import deque
from typing import Iterable, Optional


class FlightRecorder:
    """Keep the last ``k`` wave-summary dicts (see
    :func:`repro.obs.device.drain` for the row schema)."""

    def __init__(self, k: int = 16):
        if k < 1:
            raise ValueError("flight recorder needs k >= 1")
        self.k = k
        self._ring: deque = deque(maxlen=k)

    def record(self, summary: dict) -> None:
        self._ring.append(dict(summary))

    def extend(self, summaries: Iterable[dict]) -> None:
        for s in summaries:
            self.record(s)

    def trajectory(self) -> list:
        """Oldest-first copy of the recorded summaries."""
        return [dict(s) for s in self._ring]

    def last(self) -> Optional[dict]:
        return dict(self._ring[-1]) if self._ring else None

    def clear(self) -> None:
        self._ring.clear()

    def __len__(self) -> int:
        return len(self._ring)
