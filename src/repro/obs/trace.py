"""Host tracing: wall-clock timers (alpa style) + a span API with
Chrome-trace/perfetto export and optional ``jax.profiler`` annotations.

Instrumented sites (wave bursts, migrations, checkpoint save/restore,
ServeEngine submit/refill) call :func:`span` — a context manager that
records a wall-clock interval into the module-level :data:`tracer` and,
when jax is importable, also opens a ``jax.profiler.TraceAnnotation`` so
the same names show up in an XLA profile.  ``python -m repro.obs
--trace out.json`` (or :meth:`Tracer.export_chrome_trace` directly)
writes the recorded spans in the Chrome trace-event format that
``chrome://tracing`` and https://ui.perfetto.dev load natively.

This module stays jax-free at import time (the CLI forces the device
count before jax loads); jax is only touched lazily inside spans.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Optional


# ------------------------------------------------------------- timers ------
class Timer:
    """Cumulative wall-clock timer (the alpa ``timers("x")`` idiom):
    ``start()``/``stop()`` append one cost per interval; ``elapsed``
    aggregates."""

    def __init__(self, name: str):
        self.name = name
        self.costs: list = []
        self._start: Optional[float] = None

    def start(self, sync_fn=None):
        if sync_fn is not None:
            sync_fn()
        self._start = time.perf_counter()
        return self

    def stop(self, sync_fn=None):
        if self._start is None:
            raise RuntimeError(f"timer {self.name!r} stopped before start")
        if sync_fn is not None:
            sync_fn()
        self.costs.append(time.perf_counter() - self._start)
        self._start = None
        return self

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()
        return False

    @property
    def count(self) -> int:
        return len(self.costs)

    def reset(self):
        self.costs = []
        self._start = None

    def elapsed(self, mode: str = "sum") -> float:
        if not self.costs:
            return 0.0
        if mode == "sum":
            return sum(self.costs)
        if mode == "mean":
            return sum(self.costs) / len(self.costs)
        if mode == "min":
            return min(self.costs)
        if mode == "max":
            return max(self.costs)
        if mode == "last":
            return self.costs[-1]
        raise ValueError(f"unknown elapsed mode {mode!r}")


class Timers:
    """Name → :class:`Timer` registry; ``timers("x").start()``."""

    def __init__(self):
        self._timers: dict = {}

    def __call__(self, name: str) -> Timer:
        if name not in self._timers:
            self._timers[name] = Timer(name)
        return self._timers[name]

    def __contains__(self, name: str) -> bool:
        return name in self._timers

    def names(self) -> list:
        return sorted(self._timers)

    def report(self) -> dict:
        return {n: {"n": len(t.costs), "sum_s": t.elapsed("sum"),
                    "mean_s": t.elapsed("mean")}
                for n, t in sorted(self._timers.items())}


timers = Timers()


# -------------------------------------------------------------- tracer -----
def _profiler_annotation(name: str):
    """A ``jax.profiler.TraceAnnotation`` when jax is around, else a
    no-op — imported lazily so the CLI can force devices first."""
    try:
        import jax.profiler
        return jax.profiler.TraceAnnotation(name)
    except Exception:  # pragma: no cover - jax always present in CI
        from contextlib import nullcontext
        return nullcontext()


class Tracer:
    """Bounded span recorder with Chrome-trace export.

    Spans nest naturally (the trace viewer stacks same-thread ``X``
    events by time containment).  The event ring is bounded so an
    always-on tracer cannot grow without bound.
    """

    def __init__(self, max_events: int = 65536, annotate: bool = True):
        self._events: deque = deque(maxlen=max_events)
        self.annotate = annotate
        self._t0 = time.perf_counter()

    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    @contextmanager
    def span(self, name: str, cat: str = "repro", **args):
        ann = _profiler_annotation(name) if self.annotate else None
        ts = self._now_us()
        if ann is not None:
            ann.__enter__()
        try:
            yield self
        finally:
            if ann is not None:
                ann.__exit__(None, None, None)
            self._events.append({
                "name": name, "cat": cat, "ph": "X", "ts": ts,
                "dur": self._now_us() - ts, "pid": os.getpid(),
                "tid": threading.get_ident() % (1 << 31),
                "args": {k: _jsonable(v) for k, v in args.items()},
            })

    def events(self) -> list:
        return list(self._events)

    def clear(self):
        self._events.clear()

    def export_chrome_trace(self, path) -> str:
        """Write the recorded spans as Chrome trace-event JSON (loads in
        chrome://tracing and ui.perfetto.dev); returns the path."""
        doc = {"traceEvents": self.events(), "displayTimeUnit": "ms"}
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(doc, fh)
        return str(path)


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    try:
        return int(v)
    except Exception:
        return str(v)


tracer = Tracer()


@contextmanager
def span(name: str, cat: str = "repro", **args):
    """Record a span on the module-level :data:`tracer` (the instrumented
    wave/migration/checkpoint/serve sites all funnel through here)."""
    with tracer.span(name, cat, **args):
        yield tracer
