"""Metrics exposition: JSON and Prometheus-text emitters.

Both take the structured snapshot dicts produced by
:meth:`~repro.serve.engine.ServeEngine.metrics` (or any nested dict of
numbers / lists / sub-dicts) and are pure host-side formatting — no jax
import, so the CLI stays free to force devices first.

Flattening convention for the Prometheus text format: nested dict keys
extend the metric name with ``_``; list entries and all-digit dict keys
become an ``index="i"`` label (per-tier / per-window gauges); non-numeric
leaves are dropped.
"""
from __future__ import annotations

import json
import re

_NAME_OK = re.compile(r"[^a-zA-Z0-9_]")


def to_json(metrics: dict, *, indent: int = 2) -> str:
    return json.dumps(metrics, indent=indent, sort_keys=True, default=str)


def _sanitize(name: str) -> str:
    return _NAME_OK.sub("_", str(name))


def _fmt(name: str, labels: dict, value) -> str:
    if isinstance(value, bool):
        value = int(value)
    lab = ("{" + ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
           + "}") if labels else ""
    return f"{name}{lab} {value}"


def to_prometheus(metrics: dict, prefix: str = "repro") -> str:
    """Render a nested metrics snapshot as Prometheus text exposition."""
    lines: list = []

    def walk(name: str, labels: dict, v) -> None:
        if isinstance(v, dict):
            for k in sorted(v, key=str):
                ks = str(k)
                if ks.lstrip("-").isdigit():
                    walk(name, {**labels, "index": ks}, v[k])
                else:
                    walk(f"{name}_{_sanitize(ks)}", labels, v[k])
        elif isinstance(v, (list, tuple)):
            for i, item in enumerate(v):
                walk(name, {**labels, "index": str(i)}, item)
        elif isinstance(v, (int, float, bool)):
            lines.append(_fmt(name, labels, v))

    walk(_sanitize(prefix), {}, metrics)
    return "\n".join(lines) + ("\n" if lines else "")
