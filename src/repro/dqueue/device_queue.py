"""Device-resident distributed queue: SKUEUE Stage 4 as all_to_all dispatch.

The element store is sharded across a mesh axis: position ``p`` lives on
shard ``p % n_shards`` at slot ``(p // n_shards) % cap`` — a dense sharded
ring buffer.  Because SKUEUE positions are *dense consecutive integers*,
round-robin placement is **perfectly** fair (a strict improvement over the
paper's consistent hashing, which is fair only in expectation — recorded as
a beyond-paper adaptation in DESIGN.md §6; a hashed-owner mode computed by
``kernels/hash_route`` exists for fidelity benchmarking).

One ``step`` call = one paper "wave": position assignment via the
associative scan (Stages 1-3) + PUT/GET dispatch via ``lax.all_to_all``
(Stage 4).  PUTs apply before GETs inside the step, which resolves the
paper's GET-outruns-PUT asynchrony *by construction*; FIFO consistency
guarantees a matched GET's element is present (enqueued this step or
earlier).

Payloads are fixed-width int32 vectors (token ids / request descriptors);
the serving engine keeps richer request metadata host-side keyed by payload.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..core.scan_queue import (BOTTOM, QueueState, StackState, queue_scan,
                               sharded_queue_scan, stack_scan)


class DeviceQueueState(NamedTuple):
    first: jax.Array          # replicated int32
    last: jax.Array           # replicated int32
    store_vals: jax.Array     # [n_shards(sharded), cap+1, W] int32
    store_full: jax.Array     # [n_shards(sharded), cap+1] bool

    @property
    def size(self) -> jax.Array:
        return self.last - self.first + 1


def _build_send(owner, col_payload, active, n_shards, sentinel):
    """Scatter local ops into a [n_shards, L, ...] send buffer by owner row."""
    L = owner.shape[0]
    rows = jnp.arange(n_shards, dtype=jnp.int32)[:, None]
    hit = (rows == owner[None, :]) & active[None, :]
    if col_payload.ndim == 1:
        return jnp.where(hit, col_payload[None, :], sentinel)
    return jnp.where(hit[..., None], col_payload[None, :, :], sentinel)


class DeviceQueue:
    """Distributed FIFO over one mesh axis.

    Args:
      mesh: jax Mesh; axis_name: the shard axis; cap: slots per shard;
      payload_width: int32 words per element.
    """

    def __init__(self, mesh, axis_name: str = "data", cap: int = 1024,
                 payload_width: int = 4, ops_per_shard: int = 64):
        self.mesh = mesh
        self.axis = axis_name
        self.n_shards = mesh.shape[axis_name]
        self.cap = cap
        self.W = payload_width
        self.L = ops_per_shard
        self._step = self._build_step()

    def init_state(self) -> DeviceQueueState:
        n, cap, W = self.n_shards, self.cap, self.W
        sharding = jax.sharding.NamedSharding(self.mesh, P(self.axis))
        rep = jax.sharding.NamedSharding(self.mesh, P())
        return DeviceQueueState(
            first=jax.device_put(jnp.int32(0), rep),
            last=jax.device_put(jnp.int32(-1), rep),
            store_vals=jax.device_put(
                jnp.zeros((n, cap + 1, W), jnp.int32), sharding),
            store_full=jax.device_put(
                jnp.zeros((n, cap + 1), bool), sharding),
        )

    # ------------------------------------------------------------ step -----
    def _build_step(self):
        axis, n_shards, cap, W = self.axis, self.n_shards, self.cap, self.W

        def body(state: DeviceQueueState, is_enq, valid, payload):
            # ---- stages 1-3: position assignment by associative scan ----
            qs = QueueState(state.first, state.last)
            pos, matched, new_qs = sharded_queue_scan(
                is_enq, qs, axis, valid_local=valid)
            owner = jnp.where(matched, pos % n_shards, -1).astype(jnp.int32)
            slot = jnp.where(matched, (pos // n_shards) % cap, cap)
            slot = slot.astype(jnp.int32)

            # ---- stage 4a: PUT dispatch (enqueues) ----
            put_active = matched & is_enq
            send_slot = _build_send(owner, slot, put_active, n_shards,
                                    jnp.int32(cap))
            send_vals = _build_send(owner, payload, put_active, n_shards,
                                    jnp.int32(0))
            recv_slot = lax.all_to_all(send_slot, axis, 0, 0, tiled=True)
            recv_vals = lax.all_to_all(send_vals, axis, 0, 0, tiled=True)
            flat_slot = recv_slot.reshape(-1)
            flat_vals = recv_vals.reshape(-1, W)
            sv = state.store_vals[0]   # local shard view inside shard_map
            sf = state.store_full[0]
            sv = sv.at[flat_slot].set(flat_vals)     # cap row is the junk row
            sf = sf.at[flat_slot].set(True)
            sf = sf.at[cap].set(False)

            # ---- stage 4b: GET dispatch (dequeues) ----
            get_active = matched & (~is_enq)
            gsend = _build_send(owner, slot, get_active, n_shards,
                                jnp.int32(cap))
            grecv = lax.all_to_all(gsend, axis, 0, 0, tiled=True)
            res_vals = sv[grecv]                      # [n_shards, L, W]
            res_ok = sf[grecv] & (grecv < cap)
            sf = sf.at[grecv.reshape(-1)].set(False)  # remove on read
            sf = sf.at[cap].set(False)
            back_vals = lax.all_to_all(res_vals, axis, 0, 0, tiled=True)
            back_ok = lax.all_to_all(res_ok, axis, 0, 0, tiled=True)
            # local op j's reply sits at [owner[j], j]
            j = jnp.arange(owner.shape[0])
            own_row = jnp.clip(owner, 0, n_shards - 1)
            deq_vals = jnp.where(get_active[:, None],
                                 back_vals[own_row, j], jnp.int32(0))
            deq_ok = get_active & back_ok[own_row, j]

            overflow = (new_qs.last - new_qs.first + 1) > n_shards * cap
            return (DeviceQueueState(new_qs.first, new_qs.last,
                                     sv[None], sf[None]),
                    pos, matched, deq_vals, deq_ok, overflow)

        state_specs = DeviceQueueState(P(), P(), P(self.axis), P(self.axis))

        @jax.jit
        @functools.partial(
            jax.shard_map, mesh=self.mesh,
            in_specs=(state_specs, P(self.axis), P(self.axis),
                      P(self.axis)),
            out_specs=(state_specs, P(self.axis), P(self.axis),
                       P(self.axis), P(self.axis), P()),
            check_vma=False)
        def step(state, is_enq, valid, payload):
            return body(state, is_enq, valid, payload)

        return step

    def step(self, state: DeviceQueueState, is_enq: jax.Array,
             valid: jax.Array, payload: jax.Array):
        """Process one global batch.

        is_enq/valid: [n_shards * L] bool; payload: [n_shards * L, W] int32.
        Returns (new_state, positions, matched, deq_vals, deq_ok, overflow).
        """
        return self._step(state, is_enq, valid, payload)


class DeviceStack:
    """Distributed LIFO (paper Sec. VI) over one mesh axis.

    Positions are reused, so each store slot keeps a small (ticket, payload)
    set of depth ``slot_depth``; the monotone ticket bound makes concurrent
    pops conflict-free (each pop takes the unique max ticket <= its bound).
    """

    def __init__(self, mesh, axis_name: str = "data", cap: int = 1024,
                 payload_width: int = 4, ops_per_shard: int = 64,
                 slot_depth: int = 4):
        self.mesh = mesh
        self.axis = axis_name
        self.n_shards = mesh.shape[axis_name]
        self.cap = cap
        self.W = payload_width
        self.L = ops_per_shard
        self.D = slot_depth
        self._step = self._build_step()

    def init_state(self):
        n, cap, W, D = self.n_shards, self.cap, self.W, self.D
        sharding = jax.sharding.NamedSharding(self.mesh, P(self.axis))
        rep = jax.sharding.NamedSharding(self.mesh, P())
        return {
            "last": jax.device_put(jnp.int32(0), rep),
            "ticket": jax.device_put(jnp.int32(0), rep),
            "vals": jax.device_put(jnp.zeros((n, cap + 1, D, W), jnp.int32),
                                   sharding),
            "ticks": jax.device_put(jnp.full((n, cap + 1, D), -1, jnp.int32),
                                    sharding),
        }

    def _build_step(self):
        axis, n_shards, cap, W, D = (self.axis, self.n_shards, self.cap,
                                     self.W, self.D)

        def body(state, is_push, valid, payload):
            ss = StackState(state["last"], state["ticket"])
            # global order over shards: reuse the queue hypercube by running
            # the scan on the concatenated view via all_gather of transforms.
            # (stack_scan is cheap: carries are 3 ints)
            is_push_g = lax.all_gather(is_push, axis, tiled=True)
            valid_g = lax.all_gather(valid, axis, tiled=True)
            pos_g, tick_g, matched_g, new_ss = stack_scan(
                is_push_g, ss, valid=valid_g)
            i0 = lax.axis_index(axis) * is_push.shape[0]
            pos = lax.dynamic_slice_in_dim(pos_g, i0, is_push.shape[0])
            tick = lax.dynamic_slice_in_dim(tick_g, i0, is_push.shape[0])
            matched = lax.dynamic_slice_in_dim(matched_g, i0,
                                               is_push.shape[0])

            owner = jnp.where(matched, pos % n_shards, -1).astype(jnp.int32)
            slot = jnp.where(matched, (pos // n_shards) % cap,
                             cap).astype(jnp.int32)

            sv = state["vals"][0]    # [cap+1, D, W]
            stk = state["ticks"][0]  # [cap+1, D]

            # ---- PUSH dispatch ----
            a_push = matched & is_push
            s_slot = _build_send(owner, slot, a_push, n_shards, jnp.int32(cap))
            s_tick = _build_send(owner, tick, a_push, n_shards, jnp.int32(-1))
            s_vals = _build_send(owner, payload, a_push, n_shards,
                                 jnp.int32(0))
            r_slot = lax.all_to_all(s_slot, axis, 0, 0, tiled=True).reshape(-1)
            r_tick = lax.all_to_all(s_tick, axis, 0, 0, tiled=True).reshape(-1)
            r_vals = lax.all_to_all(s_vals, axis, 0, 0,
                                    tiled=True).reshape(-1, W)
            # insert each arriving element into the first free depth entry
            # of its slot; arrivals to one slot in one step get distinct
            # entries via rank-within-slot.
            order = jnp.argsort(r_slot)  # group same-slot arrivals
            rs, rt, rv = r_slot[order], r_tick[order], r_vals[order]
            same = jnp.concatenate([jnp.array([False]), rs[1:] == rs[:-1]])
            idx = jnp.arange(rs.shape[0], dtype=jnp.int32)
            run_start = lax.associative_scan(
                jnp.maximum, jnp.where(same, -1, idx))
            rank = idx - run_start  # 0,1,2,... within each same-slot run
            free = (stk[rs] < 0).astype(jnp.int32)      # [Nr, D]
            base_free = jnp.cumsum(free, axis=1) - free  # rank of each free
            want = rank[:, None]
            pick = (stk[rs] < 0) & (base_free == want)
            depth_idx = jnp.argmax(pick, axis=1)
            ok_ins = pick.any(axis=1) & (rt >= 0) & (rs < cap)
            stk = stk.at[jnp.where(ok_ins, rs, cap),
                         jnp.where(ok_ins, depth_idx, D - 1)].set(
                             jnp.where(ok_ins, rt, stk[cap, D - 1]))
            sv = sv.at[jnp.where(ok_ins, rs, cap),
                       jnp.where(ok_ins, depth_idx, D - 1)].set(
                           jnp.where(ok_ins[:, None], rv, sv[cap, D - 1]))
            slot_overflow = ((rt >= 0) & (rs < cap) & ~ok_ins).any()
            slot_overflow = lax.pmax(slot_overflow.astype(jnp.int32),
                                     axis) > 0  # replicated flag

            # ---- POP dispatch: take max ticket <= bound at the slot ----
            a_pop = matched & (~is_push)
            g_slot = _build_send(owner, slot, a_pop, n_shards, jnp.int32(cap))
            g_bound = _build_send(owner, tick, a_pop, n_shards, jnp.int32(-1))
            q_slot = lax.all_to_all(g_slot, axis, 0, 0, tiled=True)
            q_bound = lax.all_to_all(g_bound, axis, 0, 0, tiled=True)
            cand = stk[q_slot]                                   # [n,L,D]
            eligible = (cand >= 0) & (cand <= q_bound[..., None])
            best = jnp.where(eligible, cand, -1).max(axis=-1)    # [n,L]
            got = best >= 0
            d_pick = jnp.argmax(jnp.where(eligible, cand, -1), axis=-1)
            res_vals = sv[q_slot, d_pick]
            # remove the picked entries (unique per pop: tickets are unique)
            stk = stk.at[jnp.where(got, q_slot, cap),
                         jnp.where(got, d_pick, D - 1)].set(
                             jnp.where(got, -1, stk[cap, D - 1]))
            back_vals = lax.all_to_all(res_vals, axis, 0, 0, tiled=True)
            back_ok = lax.all_to_all(got, axis, 0, 0, tiled=True)
            j = jnp.arange(owner.shape[0])
            own_row = jnp.clip(owner, 0, n_shards - 1)
            pop_vals = jnp.where(a_pop[:, None],
                                 back_vals[own_row, j], jnp.int32(0))
            pop_ok = a_pop & back_ok[own_row, j]

            new_state = {"last": new_ss.last, "ticket": new_ss.ticket,
                         "vals": sv[None], "ticks": stk[None]}
            return new_state, pos, matched, pop_vals, pop_ok, slot_overflow

        specs = {"last": P(), "ticket": P(), "vals": P(self.axis),
                 "ticks": P(self.axis)}

        @jax.jit
        @functools.partial(
            jax.shard_map, mesh=self.mesh,
            in_specs=(specs, P(self.axis), P(self.axis), P(self.axis)),
            out_specs=(specs, P(self.axis), P(self.axis), P(self.axis),
                       P(self.axis), P()),
            check_vma=False)
        def step(state, is_push, valid, payload):
            return body(state, is_push, valid, payload)

        return step

    def step(self, state, is_push, valid, payload):
        return self._step(state, is_push, valid, payload)
