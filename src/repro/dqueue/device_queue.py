"""Device-resident distributed queue/stack: disciplines over the WaveEngine.

The element store is sharded across a mesh axis: position ``p`` lives on
shard ``p % n_shards`` at slot ``(p // n_shards) % cap`` — a dense sharded
ring buffer.  Because SKUEUE positions are *dense consecutive integers*,
round-robin placement is **perfectly** fair (a strict improvement over the
paper's consistent hashing, which is fair only in expectation — recorded as
a beyond-paper adaptation in DESIGN.md §6; a hashed-owner mode computed by
``kernels/hash_route`` exists for fidelity benchmarking).

One ``step`` call = one paper "wave": position assignment via the
associative scan (Stages 1-3) + PUT/GET dispatch via ``lax.all_to_all``
(Stage 4).  PUTs apply before GETs inside the step, which resolves the
paper's GET-outruns-PUT asynchrony *by construction*; FIFO consistency
guarantees a matched GET's element is present (enqueued this step or
earlier).

As of PR 4 the wave body itself — packed two-collective Stage-4 layout,
capacity check, store rewrite, multi-wave ``lax.scan`` driver, and the
pipelined burst schedule — lives ONCE in
:class:`~.wave_engine.WaveEngine`; this module defines only what is
FIFO/LIFO-specific:

* :class:`FifoDiscipline` — positions from the min-plus hypercube scan
  (``core.scan_queue.sharded_queue_scan``), the shared dense-ring commit,
  and the post-enqueue-peak capacity check;
* :class:`LifoDiscipline` — positions/tickets from the max-plus stack
  scan over one packed descriptor ``all_gather``, plus the (slot, depth)
  ticket-set commit that makes concurrent pops conflict-free (each pop
  takes the unique max ticket <= its bound).

``run_waves`` executes K waves inside one device dispatch; with
``pipelined=True`` (default) wave k's dispatch overlaps wave k-1's store
rewrite and the request/reply collectives fuse to ONE ``all_to_all`` per
wave in steady state (see the engine docstring) — bit-identical results,
``pipelined=False`` keeps the sequential schedule for differential tests.

The seed five-collective Stage 4 is preserved as ``DeviceQueue(fused=
False)`` so benchmarks and differential tests can compare against it.
Payloads are fixed-width int32 vectors (token ids / request descriptors);
the serving engine keeps richer request metadata host-side keyed by payload.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..core.scan_queue import (QueueState, StackState, sharded_queue_scan,
                               stack_scan)
from ..kernels.backend import use_fused_dispatch
from .wave_engine import (TAG_GET, TAG_INACTIVE, TAG_PUT, Discipline,
                          Dispatch, WaveEngine, build_send,
                          post_enqueue_peak_overflow, ring_commit)


class DeviceQueueState(NamedTuple):
    """FIFO queue state: replicated ``[first, last]`` live window plus the
    per-shard ring store (``store_vals`` ``[n_shards, cap+1, W]`` sharded,
    ``store_full`` occupancy bits; the extra slot is the junk row)."""

    first: jax.Array          # replicated int32
    last: jax.Array           # replicated int32
    store_vals: jax.Array     # [n_shards(sharded), cap+1, W] int32
    store_full: jax.Array     # [n_shards(sharded), cap+1] bool

    @property
    def size(self) -> jax.Array:
        """Live element count (``last - first + 1``), as a traced scalar."""
        return self.last - self.first + 1


# ------------------------------------------------------------ FIFO ---------
class FifoDiscipline(Discipline):
    """SKUEUE FIFO order: min-plus hypercube scan + dense-ring commit."""

    n_ops = 3           # (is_enq, valid, payload)
    n_disp_outs = 2     # (pos, matched)

    def __init__(self, axis: str, n_shards: int, cap: int, W: int):
        self.axis = axis
        self.n_shards = n_shards
        self.cap = cap
        self.W = W
        self.junk = cap
        self.n_windows = 1
        self.window_capacity = n_shards * cap
        self.state_specs = DeviceQueueState(P(), P(), P(axis), P(axis))

    def split(self, state):
        """Split state into its (replicated carry, sharded store) halves."""
        return (state.first, state.last), (state.store_vals,
                                           state.store_full)

    def merge(self, carry, store):
        """Reassemble the full state from (carry, store) halves."""
        return DeviceQueueState(carry[0], carry[1], store[0], store[1])

    def dispatch(self, carry, ops) -> Dispatch:
        """Stages 1-3: assign positions and build the routed Dispatch."""
        is_enq, valid, payload = ops
        pos, matched, new_qs = sharded_queue_scan(
            is_enq, QueueState(carry[0], carry[1]), self.axis,
            valid_local=valid)
        owner = jnp.where(matched, pos % self.n_shards, -1).astype(jnp.int32)
        slot = jnp.where(matched, (pos // self.n_shards) % self.cap,
                         self.cap).astype(jnp.int32)
        tag = jnp.where(matched & is_enq, TAG_PUT,
                        jnp.where(matched & ~is_enq, TAG_GET, TAG_INACTIVE))
        ovf = post_enqueue_peak_overflow(carry[0], new_qs.last,
                                         self.n_shards * self.cap)
        return Dispatch(owner, slot, tag, (), payload, matched,
                        matched & ~is_enq, (pos, matched),
                        (new_qs.first, new_qs.last), ovf, ())

    def commit(self, store, recv):
        """Stage 4: apply this shard's routed requests to its store."""
        return ring_commit(store, recv, self.junk, self.W)

    def zero_outs(self, L: int) -> tuple:
        """All-invalid per-op dispatch outputs (padding waves)."""
        return (jnp.full((L,), -1, jnp.int32), jnp.zeros((L,), bool))

    def occupancy(self, carry):
        """Per-window occupancy vector from the carry (traced)."""
        return jnp.reshape(carry[1] - carry[0] + 1, (1,))


class DeviceQueue:
    """Distributed FIFO over one mesh axis.

    Args:
      mesh: jax Mesh; axis_name: the shard axis; cap: slots per shard;
      payload_width: int32 words per element; ops_per_shard: wave width L;
      fused: two-collective fused Stage 4 via the WaveEngine (default) vs.
        the five-collective seed path (kept for benchmarking and
        differential tests);
      pipelined: multi-wave bursts overlap wave k's dispatch with wave
        k-1's store rewrite (one fused all_to_all per wave); False keeps
        the sequential burst schedule.  Results are identical either way.
        Only meaningful with ``fused=True`` — the seed path is always
        sequential, and ``self.pipelined`` reports False there.
    """

    def __init__(self, mesh, axis_name: str = "data", cap: int = 1024,
                 payload_width: int = 4, ops_per_shard: int = 64,
                 fused: bool = True, pipelined: bool = True,
                 metrics: bool = False, metrics_ring: int = 64,
                 runtime=None):
        from ..runtime import as_runtime
        self.runtime, mesh, axis_name = as_runtime(mesh, axis_name,
                                                   runtime=runtime)
        self.mesh = mesh
        self.axis = axis_name
        self.n_shards = mesh.shape[axis_name]
        self.cap = cap
        self.W = payload_width
        self.L = ops_per_shard
        self.fused = fused
        self.pipelined = pipelined and fused  # the seed path is sequential
        self.metrics = metrics
        self._state_specs = DeviceQueueState(P(), P(), P(self.axis),
                                             P(self.axis))
        if fused:
            self.engine = WaveEngine(
                mesh, axis_name,
                FifoDiscipline(axis_name, self.n_shards, cap, payload_width),
                pipelined=pipelined, metrics=metrics,
                metrics_ring=metrics_ring, runtime=self.runtime)
            self._step = self.engine._step
            self._run_waves = self.engine._run_waves
        else:
            if metrics:
                raise ValueError("Wavescope metrics need the fused engine "
                                 "path (fused=True)")
            self.engine = None
            self._step = self._build_legacy_step()
            self._run_waves = self._build_legacy_run_waves()

    def init_state(self) -> DeviceQueueState:
        """Freshly sharded empty state on this structure's mesh (placed
        through the runtime handle's data plane)."""
        n, cap, W = self.n_shards, self.cap, self.W
        put = self.runtime.put
        sharding = jax.sharding.NamedSharding(self.mesh, P(self.axis))
        rep = jax.sharding.NamedSharding(self.mesh, P())
        return DeviceQueueState(
            first=put(jnp.int32(0), rep),
            last=put(jnp.int32(-1), rep),
            store_vals=put(jnp.zeros((n, cap + 1, W), jnp.int32), sharding),
            store_full=put(jnp.zeros((n, cap + 1), bool), sharding),
        )

    # ------------------------------------------------------------ step -----
    def step(self, state: DeviceQueueState, is_enq: jax.Array,
             valid: jax.Array, payload: jax.Array):
        """Process one global batch.  The state argument is DONATED.

        is_enq/valid: [n_shards * L] bool; payload: [n_shards * L, W] int32.
        Returns (new_state, positions, matched, deq_vals, deq_ok, overflow).
        """
        if self.engine is not None:
            return self.engine.step(state, is_enq, valid, payload)
        return self._step(state, is_enq, valid, payload)

    def run_waves(self, state: DeviceQueueState, is_enq: jax.Array,
                  valid: jax.Array, payload: jax.Array):
        """Execute K pre-staged waves in ONE device dispatch (lax.scan).

        The state argument is DONATED.  is_enq/valid: [K, n_shards * L] bool;
        payload: [K, n_shards * L, W] int32.  Wave k's global order follows
        wave k-1's.  Returns (new_state, positions [K, n], matched [K, n],
        deq_vals [K, n, W], deq_ok [K, n], overflow [K]) with no host
        synchronization between waves.
        """
        if self.engine is not None:
            return self.engine.run_waves(state, is_enq, valid, payload)
        return self._run_waves(state, is_enq, valid, payload)

    def drain_metrics(self, *, reset: bool = False) -> list:
        """Burst-boundary Wavescope drain (empty when metrics are off)."""
        return self.engine.drain_metrics(reset=reset) if self.engine else []

    # ------------------------------------------- legacy five-collective ----
    def _legacy_wave(self, state: DeviceQueueState, is_enq, valid, payload):
        """The seed five-collective wave (benchmark/differential baseline)."""
        axis, n_shards, cap, W = self.axis, self.n_shards, self.cap, self.W
        qs = QueueState(state.first, state.last)
        pos, matched, new_qs = sharded_queue_scan(
            is_enq, qs, axis, valid_local=valid)
        owner = jnp.where(matched, pos % n_shards, -1).astype(jnp.int32)
        slot = jnp.where(matched, (pos // n_shards) % cap,
                         cap).astype(jnp.int32)

        # ---- stage 4a: PUT dispatch (enqueues) ----
        put_active = matched & is_enq
        send_slot = build_send(owner, slot, put_active, n_shards,
                               jnp.int32(cap))
        send_vals = build_send(owner, payload, put_active, n_shards,
                               jnp.int32(0))
        recv_slot = lax.all_to_all(send_slot, axis, 0, 0, tiled=True)
        recv_vals = lax.all_to_all(send_vals, axis, 0, 0, tiled=True)
        flat_slot = recv_slot.reshape(-1)
        flat_vals = recv_vals.reshape(-1, W)
        sv = state.store_vals[0]
        sf = state.store_full[0]
        sv = sv.at[flat_slot].set(flat_vals)     # cap row is the junk row
        sf = sf.at[flat_slot].set(True)
        sf = sf.at[cap].set(False)

        # ---- stage 4b: GET dispatch (dequeues) ----
        get_active = matched & (~is_enq)
        gsend = build_send(owner, slot, get_active, n_shards,
                           jnp.int32(cap))
        grecv = lax.all_to_all(gsend, axis, 0, 0, tiled=True)
        res_vals = sv[grecv]                      # [n_shards, L, W]
        res_ok = sf[grecv] & (grecv < cap)
        sf = sf.at[grecv.reshape(-1)].set(False)  # remove on read
        sf = sf.at[cap].set(False)
        back_vals = lax.all_to_all(res_vals, axis, 0, 0, tiled=True)
        back_ok = lax.all_to_all(res_ok, axis, 0, 0, tiled=True)
        j = jnp.arange(owner.shape[0])
        own_row = jnp.clip(owner, 0, n_shards - 1)
        deq_vals = jnp.where(get_active[:, None],
                             back_vals[own_row, j], jnp.int32(0))
        deq_ok = get_active & back_ok[own_row, j]

        overflow = post_enqueue_peak_overflow(state.first, new_qs.last,
                                              n_shards * cap)
        return (DeviceQueueState(new_qs.first, new_qs.last, sv[None],
                                 sf[None]),
                pos, matched, deq_vals, deq_ok, overflow)

    def _build_legacy_step(self):
        state_specs = self._state_specs
        wrapped = shard_map(
            self._legacy_wave, mesh=self.mesh,
            in_specs=(state_specs, P(self.axis), P(self.axis), P(self.axis)),
            out_specs=(state_specs, P(self.axis), P(self.axis), P(self.axis),
                       P(self.axis), P()))
        return jax.jit(wrapped, donate_argnums=(0,))

    def _build_legacy_run_waves(self):
        state_specs = self._state_specs

        def multi(state, is_enq, valid, payload):
            def wave(st, xs):
                e, v, p = xs
                st2, pos, matched, dv, dok, ovf = self._legacy_wave(
                    st, e, v, p)
                return st2, (pos, matched, dv, dok, ovf)
            st, (pos, matched, dv, dok, ovf) = lax.scan(
                wave, state, (is_enq, valid, payload))
            return st, pos, matched, dv, dok, ovf

        wrapped = shard_map(
            multi, mesh=self.mesh,
            in_specs=(state_specs, P(None, self.axis), P(None, self.axis),
                      P(None, self.axis)),
            out_specs=(state_specs, P(None, self.axis), P(None, self.axis),
                       P(None, self.axis), P(None, self.axis), P(None)))
        return jax.jit(wrapped, donate_argnums=(0,))


# ------------------------------------------------------------ LIFO ---------
class LifoDiscipline(Discipline):
    """Stack order (paper Sec. VI): max-plus ticket scan + (slot, depth)
    ticket-set commit.

    Positions are reused, so each store slot keeps a small (ticket,
    payload) set of depth ``D``; the monotone ticket bound makes
    concurrent pops conflict-free (each pop takes the unique max ticket
    <= its bound)."""

    n_ops = 3           # (is_push, valid, payload)
    n_disp_outs = 2     # (pos, matched)
    extra_fill = (-1,)  # the ticket/bound request column

    TAG_PUSH = TAG_PUT
    TAG_POP = TAG_GET

    def __init__(self, axis: str, n_shards: int, cap: int, W: int, D: int,
                 fused_dispatch: bool | None = None):
        self.axis = axis
        self.n_shards = n_shards
        self.cap = cap
        self.W = W
        self.D = D
        self.junk = cap
        self.n_windows = 1
        self.window_capacity = n_shards * cap * D
        # route the replicated max-plus scan through the compiled pallas
        # sweep on TPU/GPU; the jnp stack_scan stays the CPU path AND the
        # differential oracle (None = backend autodetect, PR 9)
        self.fused_dispatch = (use_fused_dispatch() if fused_dispatch is None
                               else bool(fused_dispatch))
        self.state_specs = {"last": P(), "ticket": P(), "vals": P(axis),
                            "ticks": P(axis)}

    def split(self, state):
        """Split state into its (replicated carry, sharded store) halves."""
        return (state["last"], state["ticket"]), (state["vals"],
                                                  state["ticks"])

    def merge(self, carry, store):
        """Reassemble the full state from (carry, store) halves."""
        return {"last": carry[0], "ticket": carry[1],
                "vals": store[0], "ticks": store[1]}

    def dispatch(self, carry, ops) -> Dispatch:
        """Stages 1-3: assign positions and build the routed Dispatch."""
        is_push, valid, payload = ops
        n_shards, cap = self.n_shards, self.cap
        # global order over shards: one packed descriptor all_gather, then
        # the replicated max-plus scan (its carries are 3 ints — cheap)
        code = (is_push.astype(jnp.int32) * 2 + valid.astype(jnp.int32))
        g = lax.all_gather(code, self.axis, tiled=True)
        if self.fused_dispatch:
            from ..kernels.segscan import stack_scan_pallas
            pos_g, tick_g, matched_g, nl, nt = stack_scan_pallas(
                (g & 2) > 0, (g & 1) > 0, carry[0], carry[1])
            new_ss = StackState(nl, nt)
        else:
            pos_g, tick_g, matched_g, new_ss = stack_scan(
                (g & 2) > 0, StackState(carry[0], carry[1]),
                valid=(g & 1) > 0)
        L = is_push.shape[0]
        i0 = lax.axis_index(self.axis) * L
        pos = lax.dynamic_slice_in_dim(pos_g, i0, L)
        tick = lax.dynamic_slice_in_dim(tick_g, i0, L)
        matched = lax.dynamic_slice_in_dim(matched_g, i0, L)

        owner = jnp.where(matched, pos % n_shards, -1).astype(jnp.int32)
        slot = jnp.where(matched, (pos // n_shards) % cap,
                         cap).astype(jnp.int32)
        tag = jnp.where(matched & is_push, self.TAG_PUSH,
                        jnp.where(matched & ~is_push, self.TAG_POP,
                                  TAG_INACTIVE))
        return Dispatch(owner, slot, tag, (tick,), payload, matched,
                        matched & ~is_push, (pos, matched),
                        (new_ss.last, new_ss.ticket),
                        jnp.zeros((), bool), ())   # capacity is commit-time

    def commit(self, store, recv):
        """Stage 4: apply this shard's routed requests to its store."""
        cap, W, D = self.cap, self.W, self.D
        sv = store[0][0]     # [cap+1, D, W]
        stk = store[1][0]    # [cap+1, D]
        r_all_slot, r_tb, r_tag = recv[..., 0], recv[..., 1], recv[..., 2]
        r_all_vals = recv[..., 3:]

        # ---- PUSH inserts ----
        is_push_r = r_tag == self.TAG_PUSH
        r_slot = jnp.where(is_push_r, r_all_slot, cap).reshape(-1)
        r_tick = jnp.where(is_push_r, r_tb, -1).reshape(-1)
        r_vals = r_all_vals.reshape(-1, W)
        # insert each arriving element into the first free depth entry
        # of its slot; arrivals to one slot in one step get distinct
        # entries via rank-within-slot.
        order = jnp.argsort(r_slot)  # group same-slot arrivals
        rs, rt, rv = r_slot[order], r_tick[order], r_vals[order]
        same = jnp.concatenate([jnp.array([False]), rs[1:] == rs[:-1]])
        idx = jnp.arange(rs.shape[0], dtype=jnp.int32)
        run_start = lax.associative_scan(
            jnp.maximum, jnp.where(same, -1, idx))
        rank = idx - run_start  # 0,1,2,... within each same-slot run
        free = (stk[rs] < 0).astype(jnp.int32)      # [Nr, D]
        base_free = jnp.cumsum(free, axis=1) - free  # rank of each free
        want = rank[:, None]
        pick = (stk[rs] < 0) & (base_free == want)
        depth_idx = jnp.argmax(pick, axis=1)
        ok_ins = pick.any(axis=1) & (rt >= 0) & (rs < cap)
        stk = stk.at[jnp.where(ok_ins, rs, cap),
                     jnp.where(ok_ins, depth_idx, D - 1)].set(
                         jnp.where(ok_ins, rt, stk[cap, D - 1]))
        sv = sv.at[jnp.where(ok_ins, rs, cap),
                   jnp.where(ok_ins, depth_idx, D - 1)].set(
                       jnp.where(ok_ins[:, None], rv, sv[cap, D - 1]))
        slot_overflow = ((rt >= 0) & (rs < cap) & ~ok_ins).any()
        slot_overflow = lax.pmax(slot_overflow.astype(jnp.int32),
                                 self.axis) > 0  # replicated flag

        # ---- POP picks: take max ticket <= bound at the slot ----
        is_pop_r = r_tag == self.TAG_POP
        q_slot = jnp.where(is_pop_r, r_all_slot, cap)        # [n, L]
        q_bound = jnp.where(is_pop_r, r_tb, -1)
        cand = stk[q_slot]                                   # [n,L,D]
        eligible = (cand >= 0) & (cand <= q_bound[..., None])
        best = jnp.where(eligible, cand, -1).max(axis=-1)    # [n,L]
        got = best >= 0
        d_pick = jnp.argmax(jnp.where(eligible, cand, -1), axis=-1)
        res_vals = sv[q_slot, d_pick]
        # remove the picked entries (unique per pop: tickets are unique)
        stk = stk.at[jnp.where(got, q_slot, cap),
                     jnp.where(got, d_pick, D - 1)].set(
                         jnp.where(got, -1, stk[cap, D - 1]))
        reply = jnp.concatenate(
            [got.astype(jnp.int32)[..., None], res_vals], axis=-1)
        return (sv[None], stk[None]), reply, slot_overflow

    def zero_outs(self, L: int) -> tuple:
        """All-invalid per-op dispatch outputs (padding waves)."""
        return (jnp.full((L,), -1, jnp.int32), jnp.zeros((L,), bool))

    def occupancy(self, carry):
        """Per-window occupancy vector from the carry (traced)."""
        # stack positions start at 1: the live window is [1, last]
        return jnp.reshape(carry[0], (1,))


class DeviceStack:
    """Distributed LIFO (paper Sec. VI) over one mesh axis.

    Stage 4 uses the same fused two-collective layout as
    :class:`DeviceQueue` (request packs ``slot ‖ ticket/bound ‖ tag ‖
    payload``; reply packs ``ok ‖ value``) via the shared WaveEngine, and
    the jitted entry points donate the stack state.  ``run_waves`` is the
    engine's multi-wave driver — pipelined by default.
    """

    TAG_PUSH = LifoDiscipline.TAG_PUSH
    TAG_POP = LifoDiscipline.TAG_POP

    def __init__(self, mesh, axis_name: str = "data", cap: int = 1024,
                 payload_width: int = 4, ops_per_shard: int = 64,
                 slot_depth: int = 4, pipelined: bool = True,
                 metrics: bool = False, metrics_ring: int = 64,
                 fused_dispatch: bool | None = None, runtime=None):
        from ..runtime import as_runtime
        self.runtime, mesh, axis_name = as_runtime(mesh, axis_name,
                                                   runtime=runtime)
        self.mesh = mesh
        self.axis = axis_name
        self.n_shards = mesh.shape[axis_name]
        self.cap = cap
        self.W = payload_width
        self.L = ops_per_shard
        self.D = slot_depth
        self.pipelined = pipelined
        self.metrics = metrics
        self.engine = WaveEngine(
            mesh, axis_name,
            LifoDiscipline(axis_name, self.n_shards, cap, payload_width,
                           slot_depth, fused_dispatch=fused_dispatch),
            pipelined=pipelined, metrics=metrics, metrics_ring=metrics_ring,
            runtime=self.runtime)
        self._step = self.engine._step
        self._run_waves = self.engine._run_waves

    def init_state(self):
        """Freshly sharded empty state on this structure's mesh (placed
        through the runtime handle's data plane)."""
        n, cap, W, D = self.n_shards, self.cap, self.W, self.D
        put = self.runtime.put
        sharding = jax.sharding.NamedSharding(self.mesh, P(self.axis))
        rep = jax.sharding.NamedSharding(self.mesh, P())
        return {
            "last": put(jnp.int32(0), rep),
            "ticket": put(jnp.int32(0), rep),
            "vals": put(jnp.zeros((n, cap + 1, D, W), jnp.int32), sharding),
            "ticks": put(jnp.full((n, cap + 1, D), -1, jnp.int32), sharding),
        }

    def step(self, state, is_push, valid, payload):
        """One wave; the state argument is DONATED."""
        return self.engine.step(state, is_push, valid, payload)

    def run_waves(self, state, is_push, valid, payload):
        """K pushes/pops waves in one lax.scan dispatch (state DONATED)."""
        return self.engine.run_waves(state, is_push, valid, payload)

    def drain_metrics(self, *, reset: bool = False) -> list:
        """Burst-boundary Wavescope drain (empty when metrics are off)."""
        return self.engine.drain_metrics(reset=reset)
