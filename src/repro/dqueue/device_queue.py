"""Device-resident distributed queue: SKUEUE Stage 4 as fused all_to_all waves.

The element store is sharded across a mesh axis: position ``p`` lives on
shard ``p % n_shards`` at slot ``(p // n_shards) % cap`` — a dense sharded
ring buffer.  Because SKUEUE positions are *dense consecutive integers*,
round-robin placement is **perfectly** fair (a strict improvement over the
paper's consistent hashing, which is fair only in expectation — recorded as
a beyond-paper adaptation in DESIGN.md §6; a hashed-owner mode computed by
``kernels/hash_route`` exists for fidelity benchmarking).

One ``step`` call = one paper "wave": position assignment via the
associative scan (Stages 1-3) + PUT/GET dispatch via ``lax.all_to_all``
(Stage 4).  PUTs apply before GETs inside the step, which resolves the
paper's GET-outruns-PUT asynchrony *by construction*; FIFO consistency
guarantees a matched GET's element is present (enqueued this step or
earlier).

Fused-collective layout (PR 1)
------------------------------
Stage 4 costs exactly **two** ``all_to_all`` collectives per wave:

* *request* direction — PUT and GET traffic share one int32 send buffer of
  shape ``[n_shards, L, 2 + W]``; each op column packs
  ``slot ‖ tag ‖ payload`` where ``tag`` is 0 = inactive, 1 = PUT,
  2 = GET (payload words are don't-care for GETs).  Inactive entries carry
  ``slot = cap``, the junk row every shard reserves past its ring.
* *reply* direction — one ``[n_shards, L, 1 + W]`` buffer packing
  ``ok ‖ value`` for GET responses (PUT entries reply with ``ok = 0``).

The seed implementation issued five collectives per wave (PUT slot, PUT
vals, GET slot, GET reply vals, GET reply ok); that path is preserved as
``fused=False`` so benchmarks and differential tests can compare against it.

Buffer donation and multi-wave scan driver
------------------------------------------
The jitted ``step``/``run_waves`` entry points donate the queue state
(``donate_argnums=(0,)``), so the ``[n_shards, cap+1, W]`` store is updated
in place instead of being copied every wave — callers must treat the
passed-in state as consumed (every driver in this repo replaces it).

``run_waves`` executes K waves inside one ``lax.scan`` over pre-staged
``[K, n, ...]`` op batches and returns all K results at once: no host
round-trip between waves, one device dispatch per K-wave burst.  Wave k's
global order follows wave k-1's, so a [K, n] staging is exactly K
back-to-back waves of the sequential queue semantics.

Payloads are fixed-width int32 vectors (token ids / request descriptors);
the serving engine keeps richer request metadata host-side keyed by payload.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..core.scan_queue import (QueueState, StackState, sharded_queue_scan,
                               stack_scan)

TAG_INACTIVE = 0
TAG_PUT = 1
TAG_GET = 2


class DeviceQueueState(NamedTuple):
    first: jax.Array          # replicated int32
    last: jax.Array           # replicated int32
    store_vals: jax.Array     # [n_shards(sharded), cap+1, W] int32
    store_full: jax.Array     # [n_shards(sharded), cap+1] bool

    @property
    def size(self) -> jax.Array:
        return self.last - self.first + 1


def _build_send(owner, col_payload, active, n_shards, sentinel):
    """Scatter local ops into a [n_shards, L, ...] send buffer by owner row."""
    rows = jnp.arange(n_shards, dtype=jnp.int32)[:, None]
    hit = (rows == owner[None, :]) & active[None, :]
    if col_payload.ndim == 1:
        return jnp.where(hit, col_payload[None, :], sentinel)
    return jnp.where(hit[..., None], col_payload[None, :, :], sentinel)


def _build_send_packed(owner, cols, active, n_shards, fill):
    """Fused scatter: cols [L, C] into a [n_shards, L, C] send buffer; rows
    not owned by a shard carry the ``fill`` [C] sentinel column."""
    rows = jnp.arange(n_shards, dtype=jnp.int32)[:, None]
    hit = (rows == owner[None, :]) & active[None, :]
    return jnp.where(hit[..., None], cols[None, :, :], fill[None, None, :])


class DeviceQueue:
    """Distributed FIFO over one mesh axis.

    Args:
      mesh: jax Mesh; axis_name: the shard axis; cap: slots per shard;
      payload_width: int32 words per element; ops_per_shard: wave width L;
      fused: two-collective fused Stage 4 (default) vs. the five-collective
        seed path (kept for benchmarking and differential tests).
    """

    def __init__(self, mesh, axis_name: str = "data", cap: int = 1024,
                 payload_width: int = 4, ops_per_shard: int = 64,
                 fused: bool = True):
        self.mesh = mesh
        self.axis = axis_name
        self.n_shards = mesh.shape[axis_name]
        self.cap = cap
        self.W = payload_width
        self.L = ops_per_shard
        self.fused = fused
        self._state_specs = DeviceQueueState(P(), P(), P(self.axis),
                                             P(self.axis))
        self._step = self._build_step()
        self._run_waves = self._build_run_waves()

    def init_state(self) -> DeviceQueueState:
        n, cap, W = self.n_shards, self.cap, self.W
        sharding = jax.sharding.NamedSharding(self.mesh, P(self.axis))
        rep = jax.sharding.NamedSharding(self.mesh, P())
        return DeviceQueueState(
            first=jax.device_put(jnp.int32(0), rep),
            last=jax.device_put(jnp.int32(-1), rep),
            store_vals=jax.device_put(
                jnp.zeros((n, cap + 1, W), jnp.int32), sharding),
            store_full=jax.device_put(
                jnp.zeros((n, cap + 1), bool), sharding),
        )

    # ------------------------------------------------------- wave bodies ---
    def _assign(self, state: DeviceQueueState, is_enq, valid):
        """Stages 1-3: position assignment by associative scan."""
        qs = QueueState(state.first, state.last)
        pos, matched, new_qs = sharded_queue_scan(
            is_enq, qs, self.axis, valid_local=valid)
        owner = jnp.where(matched, pos % self.n_shards, -1).astype(jnp.int32)
        slot = jnp.where(matched, (pos // self.n_shards) % self.cap, self.cap)
        return pos, matched, new_qs, owner, slot.astype(jnp.int32)

    def _fused_wave(self, state: DeviceQueueState, is_enq, valid, payload):
        """One wave, two collectives: packed request + packed reply."""
        axis, n_shards, cap, W = self.axis, self.n_shards, self.cap, self.W
        pos, matched, new_qs, owner, slot = self._assign(state, is_enq, valid)

        # ---- stage 4 request: slot ‖ tag ‖ payload in ONE all_to_all ----
        tag = jnp.where(matched & is_enq, TAG_PUT,
                        jnp.where(matched & ~is_enq, TAG_GET, TAG_INACTIVE))
        cols = jnp.concatenate(
            [slot[:, None], tag.astype(jnp.int32)[:, None], payload], axis=1)
        fill = jnp.concatenate(
            [jnp.full((2,), cap, jnp.int32).at[1].set(TAG_INACTIVE),
             jnp.zeros((W,), jnp.int32)])
        send = _build_send_packed(owner, cols, matched, n_shards, fill)
        recv = lax.all_to_all(send, axis, 0, 0, tiled=True)  # [n, L, 2+W]
        r_slot, r_tag, r_vals = recv[..., 0], recv[..., 1], recv[..., 2:]

        # ---- apply PUTs (before GETs: same-wave ENQ visible to DEQ) ----
        sv = state.store_vals[0]   # local shard view inside shard_map
        sf = state.store_full[0]
        put_slot = jnp.where(r_tag == TAG_PUT, r_slot, cap).reshape(-1)
        sv = sv.at[put_slot].set(r_vals.reshape(-1, W))  # cap row is junk
        sf = sf.at[put_slot].set(True)
        sf = sf.at[cap].set(False)

        # ---- serve GETs and build the packed reply ----
        is_get = r_tag == TAG_GET
        get_slot = jnp.where(is_get, r_slot, cap)        # [n, L]
        res_vals = sv[get_slot]                          # [n, L, W]
        res_ok = is_get & sf[get_slot] & (get_slot < cap)
        sf = sf.at[get_slot.reshape(-1)].set(False)      # remove on read
        sf = sf.at[cap].set(False)
        reply = jnp.concatenate(
            [res_ok.astype(jnp.int32)[..., None], res_vals], axis=-1)
        back = lax.all_to_all(reply, axis, 0, 0, tiled=True)  # [n, L, 1+W]

        # local op j's reply sits at [owner[j], j]
        j = jnp.arange(owner.shape[0])
        own_row = jnp.clip(owner, 0, n_shards - 1)
        want_get = matched & (~is_enq)
        deq_vals = jnp.where(want_get[:, None],
                             back[own_row, j, 1:], jnp.int32(0))
        deq_ok = want_get & (back[own_row, j, 0] > 0)

        # peak size is post-enqueue (PUTs apply before GETs): same-wave
        # dequeues shrinking the size back under cap do not undo a head
        # slot the wrapped-around enqueue already overwrote.  Only
        # enqueues move ``last``, so new_qs.last - state.first is that peak.
        overflow = (new_qs.last - state.first + 1) > n_shards * cap
        return (DeviceQueueState(new_qs.first, new_qs.last, sv[None],
                                 sf[None]),
                pos, matched, deq_vals, deq_ok, overflow)

    def _legacy_wave(self, state: DeviceQueueState, is_enq, valid, payload):
        """The seed five-collective wave (benchmark/differential baseline)."""
        axis, n_shards, cap, W = self.axis, self.n_shards, self.cap, self.W
        pos, matched, new_qs, owner, slot = self._assign(state, is_enq, valid)

        # ---- stage 4a: PUT dispatch (enqueues) ----
        put_active = matched & is_enq
        send_slot = _build_send(owner, slot, put_active, n_shards,
                                jnp.int32(cap))
        send_vals = _build_send(owner, payload, put_active, n_shards,
                                jnp.int32(0))
        recv_slot = lax.all_to_all(send_slot, axis, 0, 0, tiled=True)
        recv_vals = lax.all_to_all(send_vals, axis, 0, 0, tiled=True)
        flat_slot = recv_slot.reshape(-1)
        flat_vals = recv_vals.reshape(-1, W)
        sv = state.store_vals[0]
        sf = state.store_full[0]
        sv = sv.at[flat_slot].set(flat_vals)     # cap row is the junk row
        sf = sf.at[flat_slot].set(True)
        sf = sf.at[cap].set(False)

        # ---- stage 4b: GET dispatch (dequeues) ----
        get_active = matched & (~is_enq)
        gsend = _build_send(owner, slot, get_active, n_shards,
                            jnp.int32(cap))
        grecv = lax.all_to_all(gsend, axis, 0, 0, tiled=True)
        res_vals = sv[grecv]                      # [n_shards, L, W]
        res_ok = sf[grecv] & (grecv < cap)
        sf = sf.at[grecv.reshape(-1)].set(False)  # remove on read
        sf = sf.at[cap].set(False)
        back_vals = lax.all_to_all(res_vals, axis, 0, 0, tiled=True)
        back_ok = lax.all_to_all(res_ok, axis, 0, 0, tiled=True)
        j = jnp.arange(owner.shape[0])
        own_row = jnp.clip(owner, 0, n_shards - 1)
        deq_vals = jnp.where(get_active[:, None],
                             back_vals[own_row, j], jnp.int32(0))
        deq_ok = get_active & back_ok[own_row, j]

        overflow = (new_qs.last - state.first + 1) > n_shards * cap
        return (DeviceQueueState(new_qs.first, new_qs.last, sv[None],
                                 sf[None]),
                pos, matched, deq_vals, deq_ok, overflow)

    def _wave_body(self):
        return self._fused_wave if self.fused else self._legacy_wave

    # ------------------------------------------------------------ step -----
    def _build_step(self):
        body = self._wave_body()
        state_specs = self._state_specs
        wrapped = shard_map(
            body, mesh=self.mesh,
            in_specs=(state_specs, P(self.axis), P(self.axis), P(self.axis)),
            out_specs=(state_specs, P(self.axis), P(self.axis), P(self.axis),
                       P(self.axis), P()))
        return jax.jit(wrapped, donate_argnums=(0,))

    def step(self, state: DeviceQueueState, is_enq: jax.Array,
             valid: jax.Array, payload: jax.Array):
        """Process one global batch.  The state argument is DONATED.

        is_enq/valid: [n_shards * L] bool; payload: [n_shards * L, W] int32.
        Returns (new_state, positions, matched, deq_vals, deq_ok, overflow).
        """
        return self._step(state, is_enq, valid, payload)

    # ------------------------------------------------------- multi-wave ----
    def _build_run_waves(self):
        body = self._wave_body()
        state_specs = self._state_specs

        def multi(state, is_enq, valid, payload):
            # local shapes: is_enq/valid [K, L]; payload [K, L, W]
            def wave(st, xs):
                e, v, p = xs
                st2, pos, matched, dv, dok, ovf = body(st, e, v, p)
                return st2, (pos, matched, dv, dok, ovf)
            st, (pos, matched, dv, dok, ovf) = lax.scan(
                wave, state, (is_enq, valid, payload))
            return st, pos, matched, dv, dok, ovf

        wrapped = shard_map(
            multi, mesh=self.mesh,
            in_specs=(state_specs, P(None, self.axis), P(None, self.axis),
                      P(None, self.axis)),
            out_specs=(state_specs, P(None, self.axis), P(None, self.axis),
                       P(None, self.axis), P(None, self.axis), P(None)))
        return jax.jit(wrapped, donate_argnums=(0,))

    def run_waves(self, state: DeviceQueueState, is_enq: jax.Array,
                  valid: jax.Array, payload: jax.Array):
        """Execute K pre-staged waves in ONE device dispatch (lax.scan).

        The state argument is DONATED.  is_enq/valid: [K, n_shards * L] bool;
        payload: [K, n_shards * L, W] int32.  Wave k's global order follows
        wave k-1's.  Returns (new_state, positions [K, n], matched [K, n],
        deq_vals [K, n, W], deq_ok [K, n], overflow [K]) with no host
        synchronization between waves.
        """
        return self._run_waves(state, is_enq, valid, payload)


class DeviceStack:
    """Distributed LIFO (paper Sec. VI) over one mesh axis.

    Positions are reused, so each store slot keeps a small (ticket, payload)
    set of depth ``slot_depth``; the monotone ticket bound makes concurrent
    pops conflict-free (each pop takes the unique max ticket <= its bound).

    Stage 4 uses the same fused two-collective layout as :class:`DeviceQueue`
    (request buffer packs ``slot ‖ ticket/bound ‖ tag ‖ payload``; reply
    packs ``ok ‖ value``), replacing the seed's seven collectives per wave,
    and the jitted entry points donate the stack state.  ``run_waves``
    mirrors the queue's multi-wave lax.scan driver.
    """

    TAG_PUSH = 1
    TAG_POP = 2

    def __init__(self, mesh, axis_name: str = "data", cap: int = 1024,
                 payload_width: int = 4, ops_per_shard: int = 64,
                 slot_depth: int = 4):
        self.mesh = mesh
        self.axis = axis_name
        self.n_shards = mesh.shape[axis_name]
        self.cap = cap
        self.W = payload_width
        self.L = ops_per_shard
        self.D = slot_depth
        self._specs = {"last": P(), "ticket": P(), "vals": P(self.axis),
                       "ticks": P(self.axis)}
        self._step = self._build_step()
        self._run_waves = self._build_run_waves()

    def init_state(self):
        n, cap, W, D = self.n_shards, self.cap, self.W, self.D
        sharding = jax.sharding.NamedSharding(self.mesh, P(self.axis))
        rep = jax.sharding.NamedSharding(self.mesh, P())
        return {
            "last": jax.device_put(jnp.int32(0), rep),
            "ticket": jax.device_put(jnp.int32(0), rep),
            "vals": jax.device_put(jnp.zeros((n, cap + 1, D, W), jnp.int32),
                                   sharding),
            "ticks": jax.device_put(jnp.full((n, cap + 1, D), -1, jnp.int32),
                                    sharding),
        }

    def _wave(self, state, is_push, valid, payload):
        axis, n_shards, cap, W, D = (self.axis, self.n_shards, self.cap,
                                     self.W, self.D)
        ss = StackState(state["last"], state["ticket"])
        # global order over shards: reuse the queue hypercube by running
        # the scan on the concatenated view via all_gather of transforms.
        # (stack_scan is cheap: carries are 3 ints)
        is_push_g = lax.all_gather(is_push, axis, tiled=True)
        valid_g = lax.all_gather(valid, axis, tiled=True)
        pos_g, tick_g, matched_g, new_ss = stack_scan(
            is_push_g, ss, valid=valid_g)
        i0 = lax.axis_index(axis) * is_push.shape[0]
        pos = lax.dynamic_slice_in_dim(pos_g, i0, is_push.shape[0])
        tick = lax.dynamic_slice_in_dim(tick_g, i0, is_push.shape[0])
        matched = lax.dynamic_slice_in_dim(matched_g, i0,
                                           is_push.shape[0])

        owner = jnp.where(matched, pos % n_shards, -1).astype(jnp.int32)
        slot = jnp.where(matched, (pos // n_shards) % cap,
                         cap).astype(jnp.int32)

        sv = state["vals"][0]    # [cap+1, D, W]
        stk = state["ticks"][0]  # [cap+1, D]

        # ---- fused request: slot ‖ ticket/bound ‖ tag ‖ payload ----
        tag = jnp.where(matched & is_push, self.TAG_PUSH,
                        jnp.where(matched & ~is_push, self.TAG_POP,
                                  TAG_INACTIVE))
        cols = jnp.concatenate(
            [slot[:, None], tick[:, None], tag.astype(jnp.int32)[:, None],
             payload], axis=1)
        fill = jnp.concatenate(
            [jnp.array([cap, -1, TAG_INACTIVE], jnp.int32),
             jnp.zeros((W,), jnp.int32)])
        send = _build_send_packed(owner, cols, matched, n_shards, fill)
        recv = lax.all_to_all(send, axis, 0, 0, tiled=True)  # [n, L, 3+W]
        r_all_slot, r_tb, r_tag = recv[..., 0], recv[..., 1], recv[..., 2]
        r_all_vals = recv[..., 3:]

        # ---- PUSH inserts ----
        is_push_r = r_tag == self.TAG_PUSH
        r_slot = jnp.where(is_push_r, r_all_slot, cap).reshape(-1)
        r_tick = jnp.where(is_push_r, r_tb, -1).reshape(-1)
        r_vals = r_all_vals.reshape(-1, W)
        # insert each arriving element into the first free depth entry
        # of its slot; arrivals to one slot in one step get distinct
        # entries via rank-within-slot.
        order = jnp.argsort(r_slot)  # group same-slot arrivals
        rs, rt, rv = r_slot[order], r_tick[order], r_vals[order]
        same = jnp.concatenate([jnp.array([False]), rs[1:] == rs[:-1]])
        idx = jnp.arange(rs.shape[0], dtype=jnp.int32)
        run_start = lax.associative_scan(
            jnp.maximum, jnp.where(same, -1, idx))
        rank = idx - run_start  # 0,1,2,... within each same-slot run
        free = (stk[rs] < 0).astype(jnp.int32)      # [Nr, D]
        base_free = jnp.cumsum(free, axis=1) - free  # rank of each free
        want = rank[:, None]
        pick = (stk[rs] < 0) & (base_free == want)
        depth_idx = jnp.argmax(pick, axis=1)
        ok_ins = pick.any(axis=1) & (rt >= 0) & (rs < cap)
        stk = stk.at[jnp.where(ok_ins, rs, cap),
                     jnp.where(ok_ins, depth_idx, D - 1)].set(
                         jnp.where(ok_ins, rt, stk[cap, D - 1]))
        sv = sv.at[jnp.where(ok_ins, rs, cap),
                   jnp.where(ok_ins, depth_idx, D - 1)].set(
                       jnp.where(ok_ins[:, None], rv, sv[cap, D - 1]))
        slot_overflow = ((rt >= 0) & (rs < cap) & ~ok_ins).any()
        slot_overflow = lax.pmax(slot_overflow.astype(jnp.int32),
                                 axis) > 0  # replicated flag

        # ---- POP picks: take max ticket <= bound at the slot ----
        is_pop_r = r_tag == self.TAG_POP
        q_slot = jnp.where(is_pop_r, r_all_slot, cap)        # [n, L]
        q_bound = jnp.where(is_pop_r, r_tb, -1)
        cand = stk[q_slot]                                   # [n,L,D]
        eligible = (cand >= 0) & (cand <= q_bound[..., None])
        best = jnp.where(eligible, cand, -1).max(axis=-1)    # [n,L]
        got = best >= 0
        d_pick = jnp.argmax(jnp.where(eligible, cand, -1), axis=-1)
        res_vals = sv[q_slot, d_pick]
        # remove the picked entries (unique per pop: tickets are unique)
        stk = stk.at[jnp.where(got, q_slot, cap),
                     jnp.where(got, d_pick, D - 1)].set(
                         jnp.where(got, -1, stk[cap, D - 1]))
        reply = jnp.concatenate(
            [got.astype(jnp.int32)[..., None], res_vals], axis=-1)
        back = lax.all_to_all(reply, axis, 0, 0, tiled=True)
        j = jnp.arange(owner.shape[0])
        own_row = jnp.clip(owner, 0, n_shards - 1)
        a_pop = matched & (~is_push)
        pop_vals = jnp.where(a_pop[:, None],
                             back[own_row, j, 1:], jnp.int32(0))
        pop_ok = a_pop & (back[own_row, j, 0] > 0)

        new_state = {"last": new_ss.last, "ticket": new_ss.ticket,
                     "vals": sv[None], "ticks": stk[None]}
        return new_state, pos, matched, pop_vals, pop_ok, slot_overflow

    def _build_step(self):
        wrapped = shard_map(
            self._wave, mesh=self.mesh,
            in_specs=(self._specs, P(self.axis), P(self.axis), P(self.axis)),
            out_specs=(self._specs, P(self.axis), P(self.axis), P(self.axis),
                       P(self.axis), P()))
        return jax.jit(wrapped, donate_argnums=(0,))

    def step(self, state, is_push, valid, payload):
        """One wave; the state argument is DONATED."""
        return self._step(state, is_push, valid, payload)

    def _build_run_waves(self):
        def multi(state, is_push, valid, payload):
            def wave(st, xs):
                e, v, p = xs
                st2, pos, matched, pv, pok, ovf = self._wave(st, e, v, p)
                return st2, (pos, matched, pv, pok, ovf)
            st, (pos, matched, pv, pok, ovf) = lax.scan(
                wave, state, (is_push, valid, payload))
            return st, pos, matched, pv, pok, ovf

        wrapped = shard_map(
            multi, mesh=self.mesh,
            in_specs=(self._specs, P(None, self.axis), P(None, self.axis),
                      P(None, self.axis)),
            out_specs=(self._specs, P(None, self.axis), P(None, self.axis),
                       P(None, self.axis), P(None, self.axis), P(None)))
        return jax.jit(wrapped, donate_argnums=(0,))

    def run_waves(self, state, is_push, valid, payload):
        """K pushes/pops waves in one lax.scan dispatch (state DONATED)."""
        return self._run_waves(state, is_push, valid, payload)
