"""Structured errors for the device-queue and serving paths.

Before PR 5, capacity overflow was a replicated device bool that every
caller terminated in a bare ``assert`` (ServeEngine, WorkQueue, the
benchmarks) — so production overflows died with no occupancy context, or
worse, sailed through under ``python -O``.  The device wave cannot raise
(it is jitted shard_map code; the flag is an output), so the host-side
owners of queue state — the elastic wrappers, WorkQueue, ServeEngine —
convert the flag into :class:`QueueOverflowError` here, carrying the
per-tier/bucket occupancy a shed/defer admission policy needs.

PR 8 closed that loop: :mod:`repro.serve.admission` consults the elastic
wrappers' zero-cost pressure API (``occupancy()`` / ``headroom()`` /
``pressure()``) BEFORE staging, so a full window rejects with a
structured, retryable ``AdmissionRejected`` at submit time instead of
raising this error mid-wave.  Seeing :class:`QueueOverflowError` with an
admission policy installed is therefore a bug report, not an operational
event — see ``docs/BACKPRESSURE.md`` for the residual loss windows.
"""
from __future__ import annotations

from typing import Optional, Sequence


class QueueOverflowError(RuntimeError):
    """A wave's post-enqueue peak exceeded the store capacity.

    This is a DATA-LOSS signal, not flow control: by the time the flag
    reaches the host, the flagged wave has already executed and a
    wrapped-around enqueue has overwritten a live head slot, so the
    structure's contents are no longer trustworthy (recover from a
    checkpoint, or drop and rebuild the queue).  Admission policies that
    want to shed/defer BEFORE capacity is violated should act on the
    occupancy this error carries — at submit time, not by catching this
    and continuing (a ServeEngine whose flush burst overflowed has also
    lost any dequeue grants that burst produced).

    Attributes:
      kind: the structure ("queue" / "stack" / "pqueue" / "squeue" /
        "workqueue").
      capacity: elements one window holds (per tier/bucket for the
        priority and Seap queues, total for FIFO, ``slots * depth`` for
        the stack).
      occupancy: occupancy per window AFTER the step/burst completed
        (one entry for FIFO/stack; per tier for the priority queue; per
        bucket for Seap).  The flagged wave exceeded ``capacity`` at its
        post-enqueue peak (see ``wave_engine.post_enqueue_peak_overflow``)
        — in a multi-wave burst, waves after the flagged one still ran
        and may have drained the window below what this vector shows.
      wave: index of the first overflowing wave within a multi-wave
        burst, or None for a single ``step``.
      trajectory: the Wavescope flight-recorder trajectory — the last K
        wave-summary dicts (see ``repro.obs.device.drain`` for the
        schema) leading up to and including the failing burst, i.e. the
        occupancy pressure ramp that caused the overflow.  Empty when the
        owner ran without telemetry.
    """

    def __init__(self, kind: str, capacity: int,
                 occupancy: Sequence[int], *,
                 wave: Optional[int] = None, detail: str = "",
                 trajectory: Optional[Sequence[dict]] = None):
        self.kind = kind
        self.capacity = int(capacity)
        self.occupancy = [int(x) for x in occupancy]
        self.wave = wave
        self.trajectory = [dict(t) for t in (trajectory or [])]
        msg = (f"{kind} overflow (queue contents no longer trustworthy): "
               f"post-burst occupancy {self.occupancy} against per-window "
               f"capacity {self.capacity}")
        if wave is not None:
            msg += f" (first overflowing wave {wave})"
        if detail:
            msg += f"; {detail}"
        if self.trajectory:
            ramp = [sum(t.get("occ", [])) for t in self.trajectory]
            msg += (f"; flight recorder: {len(self.trajectory)}-wave "
                    f"occupancy ramp {ramp}")
        super().__init__(msg)

    @property
    def headroom(self) -> list:
        """Free slots per window at the post-burst snapshot
        (``capacity - occupancy``; negative entries mark the windows that
        wrapped).  The same vector the elastic wrappers' pre-wave
        ``headroom()`` API would have reported — an admission policy
        acting on it at submit time prevents this error entirely."""
        return [self.capacity - o for o in self.occupancy]


class ServeInvariantError(RuntimeError):
    """A ServeEngine internal invariant was violated (state corruption —
    not a capacity or input error).  Carries a ``context`` dict with the
    engine state that witnessed the violation and, when the engine runs
    with telemetry, the flight-recorder ``trajectory`` of the last K wave
    summaries leading up to it."""

    def __init__(self, message: str, *,
                 trajectory: Optional[Sequence[dict]] = None, **context):
        self.context = dict(context)
        self.trajectory = [dict(t) for t in (trajectory or [])]
        if context:
            message += " [" + ", ".join(
                f"{k}={v!r}" for k, v in context.items()) + "]"
        if self.trajectory:
            message += (f" [flight recorder: last {len(self.trajectory)} "
                        f"wave summaries attached]")
        super().__init__(message)
