from .device_queue import DeviceQueue, DeviceQueueState, DeviceStack
from .work_queue import WorkQueue

__all__ = ["DeviceQueue", "DeviceQueueState", "DeviceStack", "WorkQueue"]
