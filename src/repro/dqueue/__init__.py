from .device_queue import DeviceQueue, DeviceQueueState, DeviceStack
from .elastic import ElasticDeviceQueue, ElasticDeviceStack
from .priority_queue import (DevicePriorityQueue, ElasticDevicePriorityQueue,
                             PriorityQueueState)
from .work_queue import WorkQueue

__all__ = ["DeviceQueue", "DeviceQueueState", "DeviceStack",
           "DevicePriorityQueue", "ElasticDeviceQueue",
           "ElasticDevicePriorityQueue", "ElasticDeviceStack",
           "PriorityQueueState", "WorkQueue"]
