from .device_queue import (DeviceQueue, DeviceQueueState, DeviceStack,
                           FifoDiscipline, LifoDiscipline)
from .elastic import ElasticDeviceQueue, ElasticDeviceStack
from .priority_queue import (DevicePriorityQueue, ElasticDevicePriorityQueue,
                             PriorityDiscipline, PriorityQueueState)
from .wave_engine import (Discipline, WaveEngine,
                          post_enqueue_peak_overflow)
from .work_queue import WorkQueue

__all__ = ["DeviceQueue", "DeviceQueueState", "DeviceStack",
           "DevicePriorityQueue", "Discipline", "ElasticDeviceQueue",
           "ElasticDevicePriorityQueue", "ElasticDeviceStack",
           "FifoDiscipline", "LifoDiscipline", "PriorityDiscipline",
           "PriorityQueueState", "WaveEngine", "WorkQueue",
           "post_enqueue_peak_overflow"]
