from .device_queue import (DeviceQueue, DeviceQueueState, DeviceStack,
                           FifoDiscipline, LifoDiscipline)
from .elastic import ElasticDeviceQueue, ElasticDeviceStack
from .errors import QueueOverflowError, ServeInvariantError
from .priority_queue import (DevicePriorityQueue, ElasticDevicePriorityQueue,
                             PriorityDiscipline, PriorityQueueState)
from .seap_queue import (DeviceSeapQueue, ElasticDeviceSeapQueue,
                         SeapDiscipline, SeapQueueState)
from .wave_engine import (Discipline, WaveEngine,
                          post_enqueue_peak_overflow)
from .work_queue import WorkQueue

__all__ = ["DeviceQueue", "DeviceQueueState", "DeviceStack",
           "DevicePriorityQueue", "DeviceSeapQueue", "Discipline",
           "ElasticDeviceQueue", "ElasticDevicePriorityQueue",
           "ElasticDeviceSeapQueue", "ElasticDeviceStack",
           "FifoDiscipline", "LifoDiscipline", "PriorityDiscipline",
           "PriorityQueueState", "QueueOverflowError", "SeapDiscipline",
           "SeapQueueState", "ServeInvariantError", "WaveEngine",
           "WorkQueue", "post_enqueue_peak_overflow"]
