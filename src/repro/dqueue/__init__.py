"""SKUEUE device path: the wave protocol as fused jax collectives.

One :class:`WaveEngine` drives every discipline — FIFO
(:class:`DeviceQueue`), LIFO (:class:`DeviceStack`), P-tier priority
(:class:`DevicePriorityQueue`), arbitrary-key Seap
(:class:`DeviceSeapQueue`) — at two fused ``all_to_all`` collectives per
wave (one per wave in pipelined bursts).  The ``Elastic*`` wrappers add
runtime JOIN/LEAVE membership, checkpointing, the structured
:class:`QueueOverflowError` on capacity violation, and the zero-cost
pre-wave pressure API (``occupancy()`` / ``headroom()`` / ``pressure()``)
that the PR 8 admission control plane decides on.  See
``docs/ARCHITECTURE.md``.
"""
from .device_queue import (DeviceQueue, DeviceQueueState, DeviceStack,
                           FifoDiscipline, LifoDiscipline)
from .elastic import ElasticDeviceQueue, ElasticDeviceStack
from .errors import QueueOverflowError, ServeInvariantError
from .priority_queue import (DevicePriorityQueue, ElasticDevicePriorityQueue,
                             PriorityDiscipline, PriorityQueueState)
from .seap_queue import (DeviceSeapQueue, ElasticDeviceSeapQueue,
                         SeapDiscipline, SeapQueueState)
from .wave_engine import (Discipline, WaveEngine,
                          post_enqueue_peak_overflow)
from .work_queue import WorkQueue

__all__ = ["DeviceQueue", "DeviceQueueState", "DeviceStack",
           "DevicePriorityQueue", "DeviceSeapQueue", "Discipline",
           "ElasticDeviceQueue", "ElasticDevicePriorityQueue",
           "ElasticDeviceSeapQueue", "ElasticDeviceStack",
           "FifoDiscipline", "LifoDiscipline", "PriorityDiscipline",
           "PriorityQueueState", "QueueOverflowError", "SeapDiscipline",
           "SeapQueueState", "ServeInvariantError", "WaveEngine",
           "WorkQueue", "post_enqueue_peak_overflow"]
