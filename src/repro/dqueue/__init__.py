from .device_queue import DeviceQueue, DeviceQueueState, DeviceStack
from .elastic import ElasticDeviceQueue, ElasticDeviceStack
from .work_queue import WorkQueue

__all__ = ["DeviceQueue", "DeviceQueueState", "DeviceStack",
           "ElasticDeviceQueue", "ElasticDeviceStack", "WorkQueue"]
