"""Device-resident arbitrary-priority queue: Seap on the fused wave path.

Seap (arXiv:1805.03472, second half) generalizes Skeap's constant-priority
tiers to **arbitrary priority keys** by running a distributed search
structure over the tier set.  On the unified
:class:`~.wave_engine.WaveEngine` that search tree collapses to a
**two-level bucket directory** — the fourth discipline plug-in rather than
a fourth wave body:

* the sharded ring store gains one round-robin slot window per *bucket id*
  (exactly the priority queue's tier windows: bucket ``b``'s position ``q``
  lives on shard ``q % n_shards`` at slot ``b * cap + (q // n_shards) %
  cap``), so Stage 4 stays the packed TWO-collective layout (ONE per wave
  in the pipelined burst) — the slot already encodes the bucket;
* a replicated **boundary table** ``(lo[B], active[B])`` maps keys to
  buckets by predecessor lookup (``core.scan_queue.seap_bucket_lookup``);
  op descriptors (key ‖ 2 flag bits) ride one tiny ``all_gather``, after
  which assignment is fully replicated;
* enqueues get per-bucket FIFO positions from B masked min-plus scans;
  dequeues are Skeap's batch-DeleteMin over the directory sorted by
  boundary (``strict_batch_deletemin`` over the permuted availability);
* the directory is **rebalanced in-wave** by a cheap split/merge rule —
  halve an over-full bucket's key range (clamped to the observed min/max
  enqueued keys) into a free id, recycling an empty bucket's id on
  demand when none is free — pure replicated arithmetic that never moves
  elements.  Priority order is therefore *bucket-granular*: inversions
  are bounded by the key-range width a bucket had when the element
  entered, FIFO always holds inside a bucket, and under drifting keys
  (deadlines) the refined window rolls with the live range.
  ``core.seap.SeapOracle`` implements the identical semantics
  independently and is the differential reference.

:class:`ElasticDeviceSeapQueue` adds the PR 2 membership story: grow /
shrink re-materializes every bucket window with ONE packed migration
all_to_all (the boundary table is replicated and passes through
untouched), and checkpoint manifests record the bucket layout so cold
starts can reshard.  Host-raised :class:`~.errors.QueueOverflowError`
replaces the PR 1-4 replicated-bool-plus-assert overflow contract.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..core.scan_queue import seap_queue_scan
from ..core.seap import INT32_MAX, INT32_MIN, check_seed_bounds
from ..kernels.backend import use_fused_dispatch
from .elastic import _MultiWindowElastic
from .wave_engine import (Discipline, Dispatch, TAG_GET, TAG_INACTIVE,
                          TAG_PUT, WaveEngine,
                          post_enqueue_peak_overflow, ring_commit)


class SeapQueueState(NamedTuple):
    """Seap queue state: per-bucket replicated intervals, the replicated
    bucket directory (``lo``/``active`` boundary table plus observed key
    range), and the sharded ring store (one slot window per bucket)."""

    firsts: jax.Array         # [B] replicated int32 (per-bucket interval)
    lasts: jax.Array          # [B] replicated int32
    lo: jax.Array             # [B] replicated int32 bucket key boundaries
    active: jax.Array         # [B] replicated bool directory membership
    key_lo: jax.Array         # [] replicated int32: min key ever enqueued
    key_hi: jax.Array         # [] replicated int32: max key ever enqueued
    store_vals: jax.Array     # [n_shards(sharded), B*cap + 1, W] int32
    store_full: jax.Array     # [n_shards(sharded), B*cap + 1] bool

    @property
    def sizes(self) -> jax.Array:
        """Per-bucket occupancy vector ``[B]`` (traced)."""
        return self.lasts - self.firsts + 1


class SeapDiscipline(Discipline):
    """Seap arbitrary-key order: bucket-directory lookup + B masked
    min-plus scans + boundary-ordered batch-DeleteMin, over the shared
    dense-ring store, with the in-wave split/merge directory rebalance."""

    n_ops = 4           # (is_enq, valid, key, payload)
    n_disp_outs = 3     # (bucket, pos, matched)
    n_aux = 1           # n_active (directory size after the rebalance)

    def __init__(self, axis: str, n_shards: int, n_buckets: int, cap: int,
                 W: int, split_occupancy: int,
                 fused_dispatch: bool | None = None):
        self.axis = axis
        self.n_shards = n_shards
        self.n_buckets = n_buckets
        self.cap = cap
        self.W = W
        self.split_occupancy = split_occupancy
        self.junk = n_buckets * cap
        self.n_windows = n_buckets
        self.window_capacity = n_shards * cap
        # on compiled backends the B masked min-plus scans collapse to ONE
        # pallas sweep (grid = buckets x tiles); the jnp loop stays the
        # CPU path AND the differential oracle (None = autodetect, PR 9)
        if fused_dispatch is None:
            fused_dispatch = use_fused_dispatch()
        self.fused_dispatch = bool(fused_dispatch)
        if self.fused_dispatch:
            from ..kernels.segscan import make_tier_scan
            self._tier_scan = make_tier_scan(n_buckets)
        else:
            self._tier_scan = None
        self.state_specs = SeapQueueState(P(), P(), P(), P(), P(), P(),
                                          P(axis), P(axis))

    def split(self, state):
        """Split state into its (replicated carry, sharded store) halves."""
        return ((state.firsts, state.lasts, state.lo, state.active,
                 state.key_lo, state.key_hi),
                (state.store_vals, state.store_full))

    def merge(self, carry, store):
        """Reassemble the full state from (carry, store) halves."""
        return SeapQueueState(*carry, store[0], store[1])

    def dispatch(self, carry, ops) -> Dispatch:
        """Stages 1-3: assign positions and build the routed Dispatch."""
        is_enq, valid, key, payload = ops
        firsts, lasts, lo, active, key_lo, key_hi = carry
        n_shards, cap = self.n_shards, self.cap
        L = is_enq.shape[0]

        # ---- gather op descriptors (key ‖ flags) and assign replicated:
        #      every shard runs the same directory lookup + scans ----
        code = is_enq.astype(jnp.int32) * 2 + valid.astype(jnp.int32)
        desc = jnp.stack([code, key.astype(jnp.int32)], axis=1)    # [L, 2]
        g = lax.all_gather(desc, self.axis, tiled=True)   # [n_shards*L, 2]
        (bucket_g, pos_g, matched_g, new_firsts, new_lasts, new_lo,
         new_active, new_key_lo, new_key_hi, n_active) = seap_queue_scan(
            (g[:, 0] & 2) > 0, g[:, 1], (g[:, 0] & 1) > 0,
            firsts, lasts, lo, active, key_lo, key_hi,
            n_buckets=self.n_buckets, split_occupancy=self.split_occupancy,
            tier_scan=self._tier_scan)

        i0 = lax.axis_index(self.axis) * L
        bucket = lax.dynamic_slice_in_dim(bucket_g, i0, L)
        pos = lax.dynamic_slice_in_dim(pos_g, i0, L)
        matched = lax.dynamic_slice_in_dim(matched_g, i0, L)

        owner = jnp.where(matched, pos % n_shards, -1).astype(jnp.int32)
        slot = jnp.where(matched, bucket * cap + (pos // n_shards) % cap,
                         self.junk).astype(jnp.int32)
        tag = jnp.where(matched & is_enq, TAG_PUT,
                        jnp.where(matched & ~is_enq, TAG_GET, TAG_INACTIVE))
        # capacity holds per bucket (each bucket owns its own slot window)
        ovf = post_enqueue_peak_overflow(firsts, new_lasts, n_shards * cap)
        return Dispatch(owner, slot, tag, (), payload, matched,
                        matched & ~is_enq, (bucket, pos, matched),
                        (new_firsts, new_lasts, new_lo, new_active,
                         new_key_lo, new_key_hi), ovf, (n_active,))

    def commit(self, store, recv):
        """Stage 4: apply this shard's routed requests to its store."""
        return ring_commit(store, recv, self.junk, self.W)

    def zero_outs(self, L: int) -> tuple:
        """All-invalid per-op dispatch outputs (padding waves)."""
        return (jnp.full((L,), -1, jnp.int32),
                jnp.full((L,), -1, jnp.int32), jnp.zeros((L,), bool))

    def zero_aux(self) -> tuple:
        """Zeroed auxiliary per-wave outputs (padding waves)."""
        return (jnp.int32(0),)

    def occupancy(self, carry):
        """Per-window occupancy vector from the carry (traced)."""
        return carry[1] - carry[0] + 1


def default_split_occupancy(n_shards: int, cap: int) -> int:
    """Split a bucket when it passes 3/4 of its window (leaves headroom
    for the wave in flight while the upper half diverts to the new id)."""
    return max(1, (3 * n_shards * cap) // 4)


class DeviceSeapQueue:
    """Distributed arbitrary-priority queue over one mesh axis.

    Args:
      mesh/axis_name: the shard axis; n_buckets: directory capacity B
        (bucket ids, each owning a slot window); cap: slots per shard PER
        BUCKET; payload_width: int32 words per element; ops_per_shard:
        wave width L;
      split_occupancy: occupancy above which a bucket's key range is
        halved into a free id (default: 3/4 of the bucket window) —
        must match the :class:`~repro.core.seap.SeapOracle` threshold in
        differential runs;
      seed_bounds: optional warm-start boundaries for the directory
        (strictly increasing ints; see
        :func:`repro.core.seap.check_seed_bounds`) — without them every
        key starts in the root bucket and ordering only refines as
        splits zoom in;
      pipelined: multi-wave bursts use the engine's software-pipelined
        schedule (False = sequential; results identical).
    """

    def __init__(self, mesh, axis_name: str = "data", n_buckets: int = 8,
                 cap: int = 1024, payload_width: int = 4,
                 ops_per_shard: int = 64,
                 split_occupancy: Optional[int] = None,
                 seed_bounds=None, pipelined: bool = True,
                 metrics: bool = False, metrics_ring: int = 64,
                 fused_dispatch: bool | None = None, runtime=None):
        if n_buckets < 1:
            raise ValueError("need at least one bucket")
        from ..runtime import as_runtime
        self.runtime, mesh, axis_name = as_runtime(mesh, axis_name,
                                                   runtime=runtime)
        self.mesh = mesh
        self.axis = axis_name
        self.n_shards = mesh.shape[axis_name]
        self.n_buckets = n_buckets
        self.cap = cap
        self.W = payload_width
        self.L = ops_per_shard
        if split_occupancy is None:
            split_occupancy = default_split_occupancy(self.n_shards, cap)
        if split_occupancy < 1:
            raise ValueError("split_occupancy must be >= 1")
        self.split_occupancy = split_occupancy
        self.seed_bounds = check_seed_bounds(seed_bounds, n_buckets)
        self.pipelined = pipelined
        self.metrics = metrics
        self.engine = WaveEngine(
            mesh, axis_name,
            SeapDiscipline(axis_name, self.n_shards, n_buckets, cap,
                           payload_width, split_occupancy,
                           fused_dispatch=fused_dispatch),
            pipelined=pipelined, metrics=metrics, metrics_ring=metrics_ring,
            runtime=self.runtime)
        self._step = self.engine._step
        self._run_waves = self.engine._run_waves

    def init_state(self) -> SeapQueueState:
        """Freshly sharded empty state on this structure's mesh."""
        n, cap, W, B = self.n_shards, self.cap, self.W, self.n_buckets
        sharding = jax.sharding.NamedSharding(self.mesh, P(self.axis))
        rep = jax.sharding.NamedSharding(self.mesh, P())
        lo = np.full((B,), INT32_MAX, np.int32)
        lo[0] = INT32_MIN
        active = np.zeros((B,), bool)
        active[0] = True
        ns = len(self.seed_bounds)
        lo[1:1 + ns] = self.seed_bounds
        active[1:1 + ns] = True
        put = self.runtime.put
        return SeapQueueState(
            firsts=put(jnp.zeros((B,), jnp.int32), rep),
            lasts=put(jnp.full((B,), -1, jnp.int32), rep),
            lo=put(jnp.asarray(lo), rep),
            active=put(jnp.asarray(active), rep),
            key_lo=put(jnp.int32(INT32_MAX), rep),
            key_hi=put(jnp.int32(INT32_MIN), rep),
            store_vals=put(
                jnp.zeros((n, B * cap + 1, W), jnp.int32), sharding),
            store_full=put(
                jnp.zeros((n, B * cap + 1), bool), sharding),
        )

    def step(self, state: SeapQueueState, is_enq, valid, key, payload):
        """Process one global wave.  The state argument is DONATED.

        is_enq/valid: [n_shards * L] bool; key: [n_shards * L] int32
        priority keys (any int32; smaller = more urgent; ignored for
        dequeues); payload: [n_shards * L, W].  Returns (new_state,
        bucket, pos, matched, deq_vals, deq_ok, overflow, n_active) —
        bucket/pos are -1/⊥ for unmatched ops, ``n_active`` is the
        directory size after the wave's rebalance.
        """
        return self.engine.step(state, is_enq, valid, key, payload)

    def run_waves(self, state: SeapQueueState, is_enq, valid, key, payload):
        """K pre-staged waves in ONE lax.scan dispatch (state DONATED).

        Shapes: is_enq/valid/key [K, n_shards * L]; payload [K, ..., W].
        """
        return self.engine.run_waves(state, is_enq, valid, key, payload)

    def drain_metrics(self, *, reset: bool = False) -> list:
        """Burst-boundary Wavescope drain (empty when metrics are off)."""
        return self.engine.drain_metrics(reset=reset)


class ElasticDeviceSeapQueue(_MultiWindowElastic):
    """Arbitrary-priority queue whose shard count is a runtime variable.

    ``grow`` / ``shrink`` / ``resize`` re-materialize every bucket window
    onto the new mesh with one packed migration all_to_all (the PR 2 wave
    vectorized over windows via the shared
    :class:`~.elastic._MultiWindowElastic` machinery); the replicated
    boundary table rides around the migration untouched, and checkpoint
    manifests record the bucket layout so cold starts can reshard."""

    _kind = "squeue"
    _pad_fill = (0, False)
    _sharded_keys = frozenset({"store_vals", "store_full"})

    @property
    def _n_windows(self) -> int:
        return self.n_buckets

    def __init__(self, n_shards: int, *, n_buckets: int = 8,
                 split_occupancy: Optional[int] = None,
                 seed_bounds=None, axis_name: str = "data", cap: int = 1024,
                 payload_width: int = 4, ops_per_shard: int = 64,
                 devices=None, runtime=None, hlo_stats: bool = False,
                 pipelined: bool = True, metrics: bool = False,
                 metrics_ring: int = 64, flight_k: int = 16):
        self.n_buckets = n_buckets
        if split_occupancy is None:
            split_occupancy = default_split_occupancy(n_shards, cap)
        self.split_occupancy = split_occupancy
        self.seed_bounds = check_seed_bounds(seed_bounds, n_buckets)
        super().__init__(n_shards, axis_name=axis_name, cap=cap,
                         payload_width=payload_width,
                         ops_per_shard=ops_per_shard, devices=devices,
                         runtime=runtime,
                         hlo_stats=hlo_stats, pipelined=pipelined,
                         metrics=metrics, metrics_ring=metrics_ring,
                         flight_k=flight_k)

    def _make_inner(self, mesh):
        return DeviceSeapQueue(mesh, self.axis, n_buckets=self.n_buckets,
                               cap=self.cap, payload_width=self.W,
                               ops_per_shard=self.L,
                               split_occupancy=self.split_occupancy,
                               seed_bounds=self.seed_bounds,
                               pipelined=self.pipelined,
                               metrics=self.metrics,
                               metrics_ring=self.metrics_ring,
                               runtime=self.runtime)

    # ------------------------------------------------------------ waves ----
    def step(self, is_enq, valid, key, payload):
        """One wave on the current mesh; state is threaded internally.
        Returns (bucket, pos, matched, deq_vals, deq_ok, overflow,
        n_active); raises :class:`~.errors.QueueOverflowError` when the
        wave overflowed a bucket window."""
        with self._burst_span(1):
            self.state, *out = self.inner.step(
                self.state, self._place(is_enq), self._place(valid),
                self._place(key), self._place(payload))
        self._check_overflow(out[5])
        return tuple(out)

    def run_waves(self, is_enq, valid, key, payload):
        """K pre-staged waves in one dispatch (shapes [K, n_shards * L]).
        Raises :class:`~.errors.QueueOverflowError` on bucket overflow."""
        is_enq = self._place(is_enq, lead=1)
        with self._burst_span(is_enq.shape[0]):
            self.state, *out = self.inner.run_waves(
                self.state, is_enq, self._place(valid, lead=1),
                self._place(key, lead=1), self._place(payload, lead=1))
        self._check_overflow(out[5])
        return tuple(out)

    @property
    def n_active(self) -> int:
        """Active buckets in the directory (host read, no dispatch)."""
        return int(np.asarray(self.state.active).sum())

    def directory(self) -> list:
        """Active (lo, bucket_id) entries in ascending key order."""
        lo = np.asarray(self.state.lo)
        act = np.asarray(self.state.active)
        return sorted((int(lo[b]), int(b))
                      for b in range(self.n_buckets) if act[b])

    # -------------------------------------------------------- migration ----
    def _unpack(self, state):
        # the replicated directory (boundary table + observed key range)
        # is not touched by the migration wave; stash it and re-attach on
        # the destination mesh in _pack
        self._mig_directory = tuple(
            self.runtime.to_host(x) for x in (state.lo, state.active,
                                              state.key_lo, state.key_hi))
        return state.firsts, state.lasts, state.store_vals, state.store_full

    def _pack(self, a, b, X, Y):
        rep = a.sharding                      # replicated on the final mesh
        lo_h, act_h, klo_h, khi_h = (self.runtime.put(x, rep)
                                     for x in self._mig_directory)
        return SeapQueueState(a, b, lo_h, act_h, klo_h, khi_h, X, Y)

    def _layout(self) -> dict:
        return {**super()._layout(), "B": self.n_buckets,
                "split": self.split_occupancy, "seed": self.seed_bounds}

    @classmethod
    def _layout_kwargs(cls, lay: dict) -> dict:
        # the live directory (lo/active) restores from the state dict;
        # the seed only shapes a fresh init_state
        return {**super()._layout_kwargs(lay), "n_buckets": lay["B"],
                "split_occupancy": lay["split"],
                "seed_bounds": lay.get("seed") or None}

    def _state_dict(self) -> dict:
        return {"firsts": self.state.firsts, "lasts": self.state.lasts,
                "lo": self.state.lo, "active": self.state.active,
                "key_lo": self.state.key_lo, "key_hi": self.state.key_hi,
                "store_vals": self.state.store_vals,
                "store_full": self.state.store_full}

    def _from_state_dict(self, d: dict):
        return SeapQueueState(d["firsts"], d["lasts"], d["lo"], d["active"],
                              d["key_lo"], d["key_hi"],
                              d["store_vals"], d["store_full"])
