"""The unified fused-wave engine: Stages 1-4 once, disciplines plug in.

Before this module, ``DeviceQueue``, ``DeviceStack`` and
``DevicePriorityQueue`` each carried a full copy of the fused wave body —
position assignment, the packed two-collective Stage-4 request/reply
layout, the post-enqueue-peak capacity check, and the store rewrite — so
every wave-level fix had to land three times (the PR 3 capacity bug did).
:class:`WaveEngine` owns that body once; the three structures are now thin
:class:`Discipline` plug-ins that only answer the questions that actually
differ between FIFO, LIFO and P-tier priority semantics:

* **dispatch** (Stages 1-3): assign each op of the wave a position, an
  owner shard and a store slot — FIFO via the min-plus hypercube scan,
  LIFO via the max-plus ticket scan, priority via P masked min-plus scans
  plus the batch-DeleteMin drain;
* **commit** (Stage-4 store rewrite): apply the received PUT/GET rows to
  the local store and build the packed ``ok ‖ value`` reply — the dense
  ring rewrite (queue/priority share :func:`ring_commit`) or the
  (slot, depth) ticket-set rewrite (stack).

Everything else — the ``slot ‖ extra ‖ tag ‖ payload`` request packing,
the collectives, reply extraction, the overflow surfacing, the multi-wave
``lax.scan`` driver — is engine code, written once.

Wave pipelining
---------------
``run_waves(pipelined=True)`` (the default) software-pipelines the burst:
the scan carry holds **both buffers** of a double-buffered wave — the
committed store *and* the in-flight request buffer of the previous wave —
so iteration k dispatches wave k (scans + request packing, which never
read the store) while committing wave k-1's store rewrite.  Because wave
k-1's reply becomes available exactly when wave k's request is packed,
the two ride ONE fused ``all_to_all`` (request columns of wave k ‖ reply
columns of wave k-1): a K-wave burst costs K+1 ``all_to_all`` launches
instead of 2K, and the dispatch collectives of wave k (ppermute hypercube
/ descriptor all_gather) overlap wave k-1's store scatter.  The schedule
is a pure reordering of the same integer operations, so results are
bit-identical to the sequential path — ``pipelined=False`` keeps the
one-wave-at-a-time schedule for differential testing.

    wave k:    dispatch_k ──┐                     ┌─> outputs k-1
                            ├─ ONE all_to_all ────┤
    wave k-1:  commit_{k-1}─┘   (req_k ‖ rep_k-1) └─> in-flight k

``step`` is always the sequential single wave (two collectives, the PR 1
contract, HLO-tested).

Occupancy buckets (PR 9)
------------------------
Every wave ships a ``[n_shards, width, C]`` request and a
``[n_shards, width, 1+W]`` reply through the two all_to_alls — padded to
the envelope width whether the burst staged 3 ops or 300.  The engine's
wave bodies are deliberately *width-agnostic*: every discipline derives
its per-wave length from the op arrays themselves, so lowering the same
jitted entry point at a narrower op width yields a program whose
collective operands shrink proportionally.  :func:`bucket_ladder` defines
the static ladder of envelope widths (L/4, L/2, L — deduplicated,
minimum 1) and :func:`pick_bucket_width` picks the smallest bucket that
fits a staged burst; the host-side drivers (``ElasticDeviceQueue`` and
friends via ``pick_width``, ``ServeEngine`` refill) stage their op
arrays at that width.  ``jax.jit`` keys its executable cache on the
abstract shapes, so each bucket compiles exactly once and bouncing
between widths never recompiles (the wavecheck recompile guard drives
the whole ladder to prove it); the ``[compact]`` ProgramSpecs in
``analysis/programs.py`` pin every bucket to the same ≤2-all_to_all
budget.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..obs.device import (MetricsState, drain as _drain_rows,
                          init_metrics_state, record_row)

TAG_INACTIVE = 0
TAG_PUT = 1
TAG_GET = 2


# ------------------------------------------------- occupancy buckets -------
def bucket_ladder(L: int) -> tuple:
    """The static ladder of per-shard envelope widths for full width
    ``L``: {L/4, L/2, L} deduplicated, ascending, floored at 1.  Small
    and static on purpose — each rung is one cached executable per entry
    point, and three rungs already cover the low-utilization regimes
    (≤25%, ≤50%) where compaction pays."""
    return tuple(sorted({max(1, L // 4), max(1, L // 2), L}))


def pick_bucket_width(L: int, n_shards: int, n_ops: int) -> int:
    """Smallest ladder width ``w`` with ``n_shards * w >= n_ops`` —
    the envelope a burst of ``n_ops`` staged ops rides.  Bursts larger
    than the full envelope return ``L`` (the multi-wave chunking above
    this call handles them)."""
    for w in bucket_ladder(L):
        if n_shards * w >= n_ops:
            return w
    return L


# ------------------------------------------------------ shared helpers -----
def post_enqueue_peak_overflow(first, new_last, capacity):
    """THE post-enqueue-peak capacity check (one copy; was fixed three
    times in PR 3 across the fused queue, the legacy queue, and the
    priority queue).

    A wave applies PUTs before GETs, so capacity must hold at the
    *post-enqueue peak*: a same-wave dequeue that shrinks the size back
    under ``capacity`` does NOT undo the head slot a wrapped-around
    enqueue already overwrote.  Only enqueues move ``last``, so
    ``new_last - first`` (with ``first`` from *before* the wave) is that
    peak.  Accepts scalars (queue) or per-tier ``[P]`` vectors (priority,
    where ``capacity`` is per tier); returns one replicated bool.
    """
    return jnp.any((new_last - first + 1) > capacity)


def build_send(owner, col_payload, active, n_shards, sentinel):
    """Scatter local ops into a [n_shards, L, ...] send buffer by owner
    row (one column per collective — the legacy five-collective path)."""
    rows = jnp.arange(n_shards, dtype=jnp.int32)[:, None]
    hit = (rows == owner[None, :]) & active[None, :]
    if col_payload.ndim == 1:
        return jnp.where(hit, col_payload[None, :], sentinel)
    return jnp.where(hit[..., None], col_payload[None, :, :], sentinel)


def build_send_packed(owner, cols, active, n_shards, fill):
    """Fused scatter: cols [L, C] into a [n_shards, L, C] send buffer;
    rows not owned by a shard carry the ``fill`` [C] sentinel column."""
    rows = jnp.arange(n_shards, dtype=jnp.int32)[:, None]
    hit = (rows == owner[None, :]) & active[None, :]
    return jnp.where(hit[..., None], cols[None, :, :], fill[None, None, :])


def ring_commit(store, recv, junk: int, W: int):
    """Stage-4 store rewrite for the dense sharded ring (queue AND
    priority queue — the tier window is already encoded in the slot).

    Applies PUTs before GETs (same-wave ENQ visible to DEQ), removes on
    read, and routes every inactive row to the ``junk`` slot.  Returns
    (new_store, packed ``ok ‖ value`` reply, commit-time overflow=False —
    ring capacity is a dispatch-time check, :func:`post_enqueue_peak_overflow`).
    """
    sv, sf = store[0][0], store[1][0]      # local shard views
    r_slot, r_tag, r_vals = recv[..., 0], recv[..., 1], recv[..., 2:]
    put_slot = jnp.where(r_tag == TAG_PUT, r_slot, junk).reshape(-1)
    sv = sv.at[put_slot].set(r_vals.reshape(-1, W))   # junk row eats
    sf = sf.at[put_slot].set(True)
    sf = sf.at[junk].set(False)
    is_get = r_tag == TAG_GET
    get_slot = jnp.where(is_get, r_slot, junk)        # [n, L]
    res_vals = sv[get_slot]                           # [n, L, W]
    res_ok = is_get & sf[get_slot] & (get_slot < junk)
    sf = sf.at[get_slot.reshape(-1)].set(False)       # remove on read
    sf = sf.at[junk].set(False)
    reply = jnp.concatenate(
        [res_ok.astype(jnp.int32)[..., None], res_vals], axis=-1)
    return (sv[None], sf[None]), reply, jnp.zeros((), bool)


# ------------------------------------------------- discipline contract -----
class Dispatch(NamedTuple):
    """What a discipline's Stages 1-3 hand to the engine for one wave."""
    owner: jax.Array        # [L] destination shard, -1 for unrouted ops
    slot: jax.Array         # [L] destination slot (junk when unrouted)
    tag: jax.Array          # [L] TAG_PUT / TAG_GET / TAG_INACTIVE
    extra: tuple            # extra request columns, each [L] int32
    payload: jax.Array      # [L, W] int32
    active: jax.Array       # [L] rows that travel (matched ops)
    wants_reply: jax.Array  # [L] ops whose reply is extracted (dequeues)
    outs: tuple             # dispatch-time per-op outputs (pos, matched, ...)
    carry: tuple            # updated interval carry
    overflow: jax.Array     # replicated bool (dispatch-time capacity check)
    aux: tuple              # replicated per-wave extras (e.g. n_relaxed)


class Discipline:
    """Position-assignment + store-rewrite plug-in for :class:`WaveEngine`.

    Subclasses define class attributes ``n_ops`` (op input arrays per
    wave), ``n_disp_outs`` (dispatch-time per-op outputs), ``n_aux``
    (replicated per-wave extras) and ``extra_fill`` (sentinel values for
    extra request columns), instance attributes ``W`` / ``junk`` /
    ``state_specs``, and the methods below.  All methods run *inside*
    shard_map on per-shard local views.
    """

    n_ops: int = 3
    n_disp_outs: int = 2
    n_aux: int = 0
    extra_fill: tuple = ()
    # Wavescope telemetry: number of interval windows (1 for FIFO/LIFO,
    # one per tier/bucket otherwise) and per-window element capacity —
    # instances set both; occupancy() reads the post-dispatch carry.
    n_windows: int = 1
    window_capacity: int = 0

    def split(self, state):
        """state -> (interval carry tuple, store tuple)."""
        raise NotImplementedError

    def merge(self, carry, store):
        """(carry, store) -> state (inverse of split)."""
        raise NotImplementedError

    def dispatch(self, carry, ops) -> Dispatch:
        """Stages 1-3: assign positions/owners/slots for one wave."""
        raise NotImplementedError

    def commit(self, store, recv):
        """Stage-4 rewrite: -> (store, reply [n, L, 1+W], commit_ovf)."""
        raise NotImplementedError

    def zero_outs(self, L: int) -> tuple:
        """Dtype-correct zeros for ``Dispatch.outs`` (pipeline priming)."""
        raise NotImplementedError

    def zero_aux(self) -> tuple:
        """Dtype-correct zeros for ``Dispatch.aux``."""
        return ()

    def occupancy(self, carry) -> jax.Array:
        """Replicated ``[n_windows]`` int32 occupancy vector computed
        from a (post-dispatch) interval carry — pure arithmetic, feeds
        the Wavescope metrics row."""
        raise NotImplementedError


# --------------------------------------------------------- the engine ------
class WaveEngine:
    """One fused wave body for every device structure.

    ``step`` runs one sequential wave (two collectives: packed request +
    packed reply).  ``run_waves`` executes K pre-staged waves in one
    ``lax.scan`` dispatch — software-pipelined by default (see module
    docstring), or the sequential schedule with ``pipelined=False``.
    Both jitted entry points donate the state argument.

    With ``metrics=True`` every wave additionally writes one Wavescope
    row (ops admitted per kind, ⊥ count, per-window occupancy, headroom,
    the discipline's aux signal) into a donated device-side ring carried
    through the burst — pure arithmetic on values the wave already
    materializes, ZERO extra collectives, identical queue outputs.  The
    jitted entry points then take/return ``(state, MetricsState)`` as the
    donated leading argument; the *public* ``step``/``run_waves`` keep
    the metrics-off signature by threading the engine-owned ring
    internally, and :meth:`drain_metrics` is the one sanctioned
    device→host telemetry read (burst boundaries only).
    """

    def __init__(self, mesh, axis_name: str, discipline: Discipline, *,
                 pipelined: bool = True, metrics: bool = False,
                 metrics_ring: int = 64, runtime=None):
        if runtime is None:
            # mesh may be a Runtime (PR 10) or a bare Mesh (adopted into
            # a transparent LocalRuntime — same object, same jit keys)
            from ..runtime import as_runtime
            runtime, mesh, axis_name = as_runtime(mesh, axis_name)
        self.runtime = runtime
        self.mesh = mesh
        self.axis = axis_name
        self.n_shards = mesh.shape[axis_name]
        self.disc = discipline
        self.pipelined = pipelined
        self.metrics = bool(metrics)
        self.metrics_ring = int(metrics_ring)
        self._mstate = self.init_metrics_state() if self.metrics else None
        self._seq0 = 0  # waves drained-and-reset before the current ring
        self._step = self._build_step()
        self._run_waves = self._build_run_waves()

    # --------------------------------------------------- request packing ---
    def _req_fill(self):
        d = self.disc
        return jnp.concatenate(
            [jnp.array([d.junk, *d.extra_fill, TAG_INACTIVE], jnp.int32),
             jnp.zeros((d.W,), jnp.int32)])

    def _pack_request(self, d: Dispatch):
        cols = jnp.concatenate(
            [d.slot[:, None]]
            + [e.astype(jnp.int32)[:, None] for e in d.extra]
            + [d.tag.astype(jnp.int32)[:, None], d.payload], axis=1)
        return build_send_packed(d.owner, cols, d.active, self.n_shards,
                                 self._req_fill())

    def _extract_reply(self, back, owner, wants_reply):
        """Local op j's reply sits at [owner[j], j] of the reply buffer."""
        j = jnp.arange(owner.shape[0])
        own_row = jnp.clip(owner, 0, self.n_shards - 1)
        vals = jnp.where(wants_reply[:, None],
                         back[own_row, j, 1:], jnp.int32(0))
        ok = wants_reply & (back[own_row, j, 0] > 0)
        return vals, ok

    # ---------------------------------------------------------- metrics ----
    def _metric_row(self, d: Dispatch, ops, seq):
        """One Wavescope row from values wave ``seq`` already
        materialized at dispatch time — per-shard op counters plus the
        replicated occupancy/headroom gauges.  No collective, no host
        callback (see ``obs.device`` for the row schema)."""
        disc = self.disc
        valid = ops[1]
        puts = jnp.sum(((d.tag == TAG_PUT) & d.active).astype(jnp.int32))
        gets = jnp.sum(((d.tag == TAG_GET) & d.active).astype(jnp.int32))
        offered = jnp.sum(valid.astype(jnp.int32))
        bottom = jnp.sum((valid & ~d.active).astype(jnp.int32))
        occ = disc.occupancy(d.carry).astype(jnp.int32)
        headroom = (jnp.int32(disc.n_windows * disc.window_capacity)
                    - jnp.sum(occ))
        aux = (d.aux[0].astype(jnp.int32) if d.aux else jnp.int32(0))
        # the wave's per-shard envelope width — static per trace, so each
        # occupancy bucket stamps its rows with the width it rode (PR 9)
        width = jnp.int32(valid.shape[0])
        head = jnp.stack([seq.astype(jnp.int32), puts, gets, offered,
                          bottom, aux, headroom, width])
        return jnp.concatenate([head, occ])

    # ------------------------------------------------------- wave bodies ---
    def _wave(self, state, ops, m: MetricsState | None = None):
        """One sequential wave: dispatch -> request a2a -> commit ->
        reply a2a -> extract.  Exactly two all_to_all collectives —
        with or without the metrics row (``m`` threads the Wavescope
        ring; telemetry is dispatch-time arithmetic only)."""
        disc = self.disc
        carry, store = disc.split(state)
        d = disc.dispatch(carry, ops)
        if m is not None:
            m = record_row(m, self._metric_row(d, ops, m.count))
        recv = lax.all_to_all(self._pack_request(d), self.axis, 0, 0,
                              tiled=True)
        store, reply, c_ovf = disc.commit(store, recv)
        back = lax.all_to_all(reply, self.axis, 0, 0, tiled=True)
        dv, dok = self._extract_reply(back, d.owner, d.wants_reply)
        ovf = jnp.logical_or(d.overflow, c_ovf)
        merged = disc.merge(d.carry, store)
        outs = d.outs + (dv, dok, ovf) + d.aux
        if m is None:
            return merged, outs
        return (merged, m), outs

    def _multi_sequential(self, state, ops, m: MetricsState | None = None):
        if m is None:
            st, outs = lax.scan(self._wave, state, ops)
            return (st,) + outs

        def wave_m(sm, xs):
            return self._wave(sm[0], xs, sm[1])

        sm, outs = lax.scan(wave_m, (state, m), ops)
        return (sm,) + outs

    def _multi_pipelined(self, state, ops, m: MetricsState | None = None):
        """K waves, software-pipelined: iteration k dispatches wave k and
        commits wave k-1; ONE fused all_to_all carries wave k's request
        columns alongside wave k-1's reply columns.  Outputs are all
        emitted at commit time (one iteration later than dispatch), so the
        stacked scan outputs are shifted by one and the last wave drains
        through a reply-only epilogue collective."""
        disc = self.disc
        n, L = self.n_shards, ops[0].shape[1]
        C_req = 2 + len(disc.extra_fill) + disc.W
        carry0, store0 = disc.split(state)
        prime = {
            # an all-sentinel in-flight buffer commits as a no-op
            "recv": jnp.tile(self._req_fill()[None, None, :], (n, L, 1)),
            "owner": jnp.full((L,), -1, jnp.int32),
            "wants": jnp.zeros((L,), bool),
            "outs": disc.zero_outs(L),
            "ovf": jnp.zeros((), bool),
            "aux": disc.zero_aux(),
        }

        def body(c, xs):
            if m is None:
                carry, store, infl = c
                mm = None
            else:
                carry, store, infl, mm = c
            d = disc.dispatch(carry, xs)                  # wave k
            if mm is not None:
                mm = record_row(mm, self._metric_row(d, xs, mm.count))
            store, reply, c_ovf = disc.commit(store, infl["recv"])  # k-1
            fused = jnp.concatenate([self._pack_request(d), reply], axis=-1)
            out = lax.all_to_all(fused, self.axis, 0, 0, tiled=True)
            dv, dok = self._extract_reply(out[..., C_req:], infl["owner"],
                                          infl["wants"])
            emitted = (infl["outs"]
                       + (dv, dok, jnp.logical_or(infl["ovf"], c_ovf))
                       + infl["aux"])
            infl = {"recv": out[..., :C_req], "owner": d.owner,
                    "wants": d.wants_reply, "outs": d.outs,
                    "ovf": jnp.asarray(d.overflow), "aux": d.aux}
            nc = ((d.carry, store, infl) if m is None
                  else (d.carry, store, infl, mm))
            return nc, emitted

        init = ((carry0, store0, prime) if m is None
                else (carry0, store0, prime, m))
        final, stacked = lax.scan(body, init, ops)
        if m is None:
            carry, store, infl = final
        else:
            carry, store, infl, m = final
        # epilogue: commit the last in-flight wave, reply-only collective
        store, reply, c_ovf = disc.commit(store, infl["recv"])
        back = lax.all_to_all(reply, self.axis, 0, 0, tiled=True)
        dv, dok = self._extract_reply(back, infl["owner"], infl["wants"])
        last = (infl["outs"]
                + (dv, dok, jnp.logical_or(infl["ovf"], c_ovf))
                + infl["aux"])
        # drop the priming wave's garbage row, append the drained last wave
        outs = tuple(jnp.concatenate([s[1:], l[None]], axis=0)
                     for s, l in zip(stacked, last))
        merged = disc.merge(carry, store)
        if m is None:
            return (merged,) + outs
        return ((merged, m),) + outs

    # ---------------------------------------------------- jitted wrappers --
    def _m_specs(self):
        return MetricsState(P(), P(self.axis))

    def _out_specs(self, multi: bool = False):
        d = self.disc
        op = P(None, self.axis) if multi else P(self.axis)
        rep = P(None) if multi else P()
        st = ((d.state_specs, self._m_specs()) if self.metrics
              else d.state_specs)
        return ((st,) + (op,) * (d.n_disp_outs + 2)
                + (rep,) * (1 + d.n_aux))

    def _build_step(self):
        if self.metrics:
            def fn(sm, *ops):
                smm, outs = self._wave(sm[0], ops, sm[1])
                return (smm,) + outs
        else:
            def fn(state, *ops):
                st, outs = self._wave(state, ops)
                return (st,) + outs
        in_state = ((self.disc.state_specs, self._m_specs())
                    if self.metrics else self.disc.state_specs)
        wrapped = shard_map(
            fn, mesh=self.mesh,
            in_specs=(in_state,) + (P(self.axis),) * self.disc.n_ops,
            out_specs=self._out_specs())
        return jax.jit(wrapped, donate_argnums=(0,))

    def _build_run_waves(self):
        body = (self._multi_pipelined if self.pipelined
                else self._multi_sequential)

        if self.metrics:
            def fn(sm, *ops):
                return body(sm[0], ops, sm[1])
        else:
            def fn(state, *ops):
                return body(state, ops)
        in_state = ((self.disc.state_specs, self._m_specs())
                    if self.metrics else self.disc.state_specs)
        wrapped = shard_map(
            fn, mesh=self.mesh,
            in_specs=(in_state,) + (P(None, self.axis),) * self.disc.n_ops,
            out_specs=self._out_specs(multi=True))
        return jax.jit(wrapped, donate_argnums=(0,))

    def step(self, state, *ops):
        """One wave.  The state argument is DONATED.  With metrics on,
        the engine-owned telemetry ring rides the donated tuple
        internally — same external signature either way."""
        if not self.metrics:
            return self._step(state, *ops)
        out = self._step((state, self._mstate), *ops)
        st, self._mstate = out[0]
        return (st,) + tuple(out[1:])

    def run_waves(self, state, *ops):
        """K pre-staged waves in ONE device dispatch (state DONATED)."""
        if not self.metrics:
            return self._run_waves(state, *ops)
        out = self._run_waves((state, self._mstate), *ops)
        st, self._mstate = out[0]
        return (st,) + tuple(out[1:])

    # ----------------------------------------------------- metrics drain ---
    def init_metrics_state(self) -> MetricsState:
        """A zeroed Wavescope ring placed on this engine's mesh (the
        placement itself rides the runtime handle)."""
        return init_metrics_state(self.n_shards, self.metrics_ring,
                                  self.disc.n_windows, self.mesh, self.axis,
                                  runtime=self.runtime)

    def drain_metrics(self, *, reset: bool = False) -> list:
        """Drain the telemetry ring to host wave-summary dicts (oldest
        first).  THE sanctioned burst-boundary device→host telemetry
        read; with ``reset=True`` the ring restarts empty (the wave
        sequence number keeps running)."""
        if not self.metrics:
            return []
        rows = _drain_rows(self._mstate)
        for r in rows:
            r["seq"] += self._seq0
        if reset:
            self._seq0 += int(jnp.asarray(self._mstate.count))
            self._mstate = self.init_metrics_state()
        return rows


# -------------------------------------------------- migration machinery ----
def dest_rank(owner: jax.Array, live: jax.Array, n_mesh: int) -> jax.Array:
    """Exclusive rank of each live entry among earlier entries with the
    same destination — its row in the packed per-destination send buffer."""
    ids = jnp.arange(n_mesh, dtype=jnp.int32)
    oh = ((owner[:, None] == ids[None, :]) & live[:, None]).astype(jnp.int32)
    excl = jnp.cumsum(oh, axis=0) - oh
    return excl[jnp.arange(owner.shape[0]), jnp.clip(owner, 0, n_mesh - 1)]


def fanout_bound(P_old: int, P_new: int, cap: int) -> int:
    """Max elements one source shard can owe one destination shard.

    Live positions occupy a window of at most ``min(P_old, P_new) * cap``
    consecutive integers (old occupancy and new capacity both bound it);
    positions on shard ``s`` (mod P_old) owned by ``d`` (mod P_new) recur
    with stride ``lcm(P_old, P_new)``."""
    window = min(P_old, P_new) * cap
    per_pair = -(-window // math.lcm(P_old, P_new))
    return min(cap, per_pair + 1)  # +1 alignment slack


def recover_positions(s, t, first, P_old: int, cap: int):
    """Invert the round-robin layout: the position slot ``t`` on shard
    ``s`` holds is the unique ``p = s + P_old*j`` with ``j ≡ t (mod cap)``
    and ``p`` in the live window starting at ``first`` (unique because a
    live window spans at most ``P_old * cap`` positions)."""
    j_lo = -((s - first) // P_old)
    j = j_lo + jnp.mod(t - j_lo, cap)
    return s + P_old * j


def migrate_packed(axis: str, n_mesh: int, M: int, live, owner, cols, fill):
    """The ONE packed migration all_to_all every elastic structure uses:
    scatter ``cols`` rows (column 0 = destination slot / junk sentinel)
    into rank-within-destination rows, exchange, and return the received
    rows flattened.  Also returns (moved count, fanout-overflow flag)."""
    rank = dest_rank(owner, live, n_mesh)
    lost = lax.pmax(
        (live & (rank >= M)).any().astype(jnp.int32), axis) > 0
    buf = jnp.tile(fill[None, None, :], (n_mesh, M + 1, 1))
    d_i = jnp.where(live, owner, 0)
    r_i = jnp.where(live, jnp.minimum(rank, M), M)
    buf = buf.at[d_i, r_i].set(
        jnp.where(live[:, None], cols, fill[None, :]))
    recv = lax.all_to_all(buf[:, :M], axis, 0, 0, tiled=True)
    moved = lax.psum(jnp.sum(live.astype(jnp.int32)), axis)
    return recv.reshape(-1, cols.shape[1]), moved, lost


def rewrite_ring_store(rows, junk: int, W: int):
    """Rebuild a dense ring store from received ``new_slot ‖ payload``
    migration rows (sentinel rows land on — and are wiped from — the junk
    row)."""
    rs = rows[:, 0]
    nsv = jnp.zeros((junk + 1, W), jnp.int32).at[rs].set(rows[:, 1:])
    nsv = nsv.at[junk].set(0)
    nsf = jnp.zeros((junk + 1,), bool).at[rs].set(True)
    nsf = nsf.at[junk].set(False)
    return nsv[None], nsf[None]
