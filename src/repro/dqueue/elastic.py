"""Elastic membership for the device path: live JOIN/LEAVE resharding.

The paper's distinguishing feature over prior distributed queues is dynamic
membership — JOIN and LEAVE processed under sequential consistency (Sec. IV).
In this repo that capability lived only in the host-side ``Skueue`` protocol
simulator; the fused ``DeviceQueue``/``DeviceStack`` hot path (PR 1) assumed
a fixed shard set for its entire lifetime.  This module makes the mesh shape
a *runtime variable*: :class:`ElasticDeviceQueue` and
:class:`ElasticDeviceStack` wrap the fixed-mesh implementations and support
``grow(k)`` / ``shrink(ids)`` / ``resize(n)`` between wave bursts,
re-materializing the sharded element store from a P-shard layout onto a
P±k-shard mesh while preserving FIFO (resp. LIFO) order and every in-flight
element.

The migration wave
------------------
Between bursts the store is quiescent, and — because SKUEUE positions are
dense integers and the device layout is round-robin (position ``p`` on shard
``p % P`` at slot ``(p // P) % cap``) — the set of live positions is exactly
the interval ``[first, last]``.  Each shard can therefore *recover* the
position held by any of its occupied slots without scanning: slot ``t`` on
shard ``s`` holds the unique ``p = s + P*j`` with ``j ≡ t (mod cap)`` and
``p ∈ [first, last]`` (unique because the live window spans at most
``P * cap`` positions).  One jitted shard_map wave then

1. recomputes each live element's owner under the *new* shard count
   (``p % P'`` — the device path's perfectly-fair specialization of the
   paper's consistent hashing; the paper-faithful hashed owner distribution
   for the same live set is reported via ``kernels/hash_route`` in the
   migration stats),
2. scatters ``new_slot ‖ payload`` columns into a packed per-destination
   send buffer (``wave_engine.migrate_packed``, the engine's packed-send
   idiom with rank-within-destination rows), moves everything with ONE
   ``lax.all_to_all``, and
3. rewrites the receiving shards' stores; ``first``/``last`` (queue) and
   ``last``/``ticket`` (stack) interval bookkeeping pass through unchanged —
   membership changes never disturb the position order, which is the whole
   point of the paper's Sec. IV design.

The migration mesh is the *larger* of the two shard sets: a grow pads the
old store with empty shards and routes on the new mesh; a shrink routes on
the old mesh (every new owner is a surviving shard) and then drops the
now-empty rows.  Crossing between meshes of different device counts is a
host-staged ``device_put`` in this single-process container (a real
deployment would stream shard state device-to-device); the part that scales
with queue *contents* — owner routing, packing, the all_to_all, the store
rewrite — runs jitted on device and is what ``benchmarks/micro.py --pr2``
measures.

Failure semantics: ``shrink`` is the paper's *graceful* LEAVE — the leaving
shard participates in its own migration wave (like the leaving node handing
its interval to its predecessor before departing).  A hard crash is outside
the LEAVE protocol's model there too; its recovery path here is the
checkpoint cold start (:meth:`save` / :meth:`restore` via
``checkpoint.restore_sharded``), and ``fault.run_with_restarts`` composes
both: LEAVE the dead shard and keep running, restore from checkpoint only
when elasticity cannot help.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..obs.recorder import FlightRecorder
from ..obs.trace import span
from .device_queue import DeviceQueue, DeviceQueueState, DeviceStack
from .errors import QueueOverflowError
from .wave_engine import (fanout_bound, migrate_packed, recover_positions,
                          rewrite_ring_store)

HASH_BALANCE_MAX_SIZE = 1 << 16  # skip the fidelity report for huge queues


def _mesh_key(devices) -> tuple:
    return tuple(d.id for d in devices)


class _ElasticBase:
    """Shared machinery: device bookkeeping, mesh/inner/migration caches,
    the resize driver, migration stats, and checkpoint save/restore."""

    _kind: str  # "queue" | "stack"

    def __init__(self, n_shards: int, *, axis_name: str = "data",
                 cap: int = 1024, payload_width: int = 4,
                 ops_per_shard: int = 64, devices=None, runtime=None,
                 hlo_stats: bool = False, pipelined: bool = True,
                 metrics: bool = False, metrics_ring: int = 64,
                 flight_k: int = 16):
        from ..runtime import LocalRuntime
        if runtime is not None:
            if devices is not None:
                raise ValueError("pass devices= OR runtime=, not both "
                                 "(the runtime owns the device pool)")
            self.runtime = runtime
            axis_name = runtime.axis_name
        else:
            self.runtime = LocalRuntime(devices=devices,
                                        axis_name=axis_name)
        if not 1 <= n_shards <= self.runtime.pool_size:
            raise ValueError(f"n_shards={n_shards} outside the device pool "
                             f"of {self.runtime.pool_size}")
        self.axis = axis_name
        self.cap = cap
        self.W = payload_width
        self.L = ops_per_shard
        self.pipelined = pipelined
        self.metrics = bool(metrics)
        self.metrics_ring = int(metrics_ring)
        self.recorder = FlightRecorder(flight_k)
        self._hlo_stats = hlo_stats
        self._active = list(self.runtime.pool()[:n_shards])
        self._mesh_cache: Dict[tuple, jax.sharding.Mesh] = {}
        self._inner_cache: Dict[tuple, object] = {}
        self._mig_cache: Dict[tuple, list] = {}
        self.inner = self._get_inner(self._mesh_for(self._active))
        self.state = self.inner.init_state()
        self.migrations: List[dict] = []

    # ------------------------------------------------------------ caches ---
    def _mesh_for(self, devices) -> jax.sharding.Mesh:
        # the runtime is the one mesh builder; the local mirror keeps the
        # cache inspectable (the wavecheck recompile guard asserts on it)
        key = _mesh_key(devices)
        if key not in self._mesh_cache:
            self._mesh_cache[key] = self.runtime.mesh(list(devices))
        return self._mesh_cache[key]

    def _get_inner(self, mesh):
        """Fixed-mesh DeviceQueue/DeviceStack per mesh, cached so that
        bouncing between shard counts (grow 4→8, shrink 8→4, grow again)
        never recompiles the wave programs."""
        key = _mesh_key(mesh.devices.flat)
        if key not in self._inner_cache:
            self._inner_cache[key] = self._make_inner(mesh)
        return self._inner_cache[key]

    def _migration_for(self, mesh, P_old: int, P_new: int):
        key = (_mesh_key(mesh.devices.flat), P_old, P_new)
        if key not in self._mig_cache:
            fn = self._build_migration(mesh, P_old, P_new)
            self._mig_cache[key] = [fn, None]  # [jitted, collective count]
        return self._mig_cache[key]

    # ---------------------------------------------------------- overflow ---
    def _wave_capacity(self) -> int:
        """Elements one store window holds (per tier/bucket where tiered)."""
        return self.n_shards * self.cap

    def _occupancies(self) -> list:
        """Post-wave occupancy per window (subclasses with tier/bucket
        windows override with the per-window vector)."""
        return [self.size]

    _overflow_detail: str = ""

    def _drain_telemetry(self) -> list:
        """Burst-boundary Wavescope drain into the flight recorder (the
        one sanctioned device→host telemetry read; no-op with metrics
        off).  Returns the freshly drained wave summaries."""
        eng = getattr(self.inner, "engine", None)
        if not self.metrics or eng is None or not eng.metrics:
            return []
        rows = eng.drain_metrics(reset=True)
        self.recorder.extend(rows)
        return rows

    def trajectory(self) -> list:
        """The flight recorder's last-K wave summaries, oldest first."""
        return self.recorder.trajectory()

    # ------------------------------------------------------ pressure API ---
    def window_capacity(self) -> int:
        """Elements ONE store window holds under the current membership.

        ``n_shards * cap`` for FIFO (per tier/bucket for the tiered
        structures; times ``slot_depth`` for the stack).  A host-side
        constant of the current shard count — admission policies
        (:mod:`repro.serve.admission`) compare it against
        :meth:`occupancy` without any device work.

        Returns:
            Per-window element capacity as a host int.
        """
        return self._wave_capacity()

    def occupancy(self) -> List[int]:
        """Committed post-burst occupancy per window, as host ints.

        Reads only the replicated interval bookkeeping (``first``/``last``
        scalars) the last wave already materialized — a tiny device→host
        scalar copy with NO collective and NO wave dispatch, so pre-wave
        admission decisions cannot perturb the wave pipeline.

        Returns:
            One entry per window: ``[size]`` for FIFO/LIFO, a per-tier
            vector for the priority queue, per-bucket for the Seap queue.
        """
        # ``_occupancies`` builds on the ``size``/``sizes`` host properties,
        # which already return concrete Python ints — no cast needed here
        # (and ``occupancy`` doubles as a Discipline *device* method name,
        # so wavecheck's no-traced-cast rule watches this scope).
        return list(self._occupancies())

    def headroom(self) -> List[int]:
        """Free slots per window before the next enqueue overwrites data.

        ``window_capacity() - occupancy()`` per window; enqueueing into a
        window with zero headroom is exactly the wrap-around that raises
        :class:`~.errors.QueueOverflowError` mid-wave.  Same zero-cost
        host read as :meth:`occupancy`.

        Returns:
            One int per window (negative only after an overflow already
            corrupted the window).
        """
        cap = self._wave_capacity()
        return [cap - o for o in self.occupancy()]

    def pressure(self) -> dict:
        """One-call snapshot for host-side admission/autoscale decisions.

        Returns:
            Dict with ``capacity`` (per-window int), ``occupancy`` /
            ``headroom`` (per-window vectors), ``n_windows``,
            ``n_shards``, ``pool_size``, and ``utilization`` — the
            hottest window's ``occupancy / capacity`` as a float in
            ``[0, 1]`` (above 1 only after an overflow already happened).
        """
        cap = self._wave_capacity()
        occ = self.occupancy()
        return {
            "capacity": cap,
            "occupancy": occ,
            "headroom": [cap - o for o in occ],
            "n_windows": len(occ),
            "n_shards": self.n_shards,
            "pool_size": self.pool_size,
            "utilization": (max(occ) / cap) if cap else 1.0,
        }

    # ------------------------------------------------- occupancy buckets ---
    def bucket_widths(self) -> tuple:
        """The occupancy-bucket envelope ladder for this queue (PR 9).

        Ascending per-shard wave widths ``{L/4, L/2, L}`` (deduplicated,
        floored at 1).  Every width is a separately cached wave program —
        same discipline, same ≤2-all_to_all budget, smaller request/reply
        columns on the wire.  A host-side constant of ``L``; no device
        work.

        Returns:
            Tuple of ints, ascending, ending in ``L``.
        """
        from .wave_engine import bucket_ladder
        return bucket_ladder(self.L)

    def pick_width(self, n_ops: int) -> int:
        """Smallest ladder width whose global wave fits ``n_ops`` (PR 9).

        The burst driver's envelope choice: the narrowest ``w`` with
        ``n_shards * w >= n_ops``, falling back to the full ``L`` when
        even the widest bucket cannot hold the burst in one wave.  Pure
        host arithmetic on the current membership.

        Args:
            n_ops: Valid ops staged for the next wave (global count).

        Returns:
            A width from :meth:`bucket_widths`.
        """
        from .wave_engine import pick_bucket_width
        return pick_bucket_width(self.L, self.n_shards, n_ops)

    def _burst_span(self, K: int):
        """Span wrapping one multi-wave burst dispatch.  Also the
        runtime's burst-boundary latency hook (SimRuntime charges the
        modeled K+1 pipelined / 2K sequential all_to_all launches;
        no-op everywhere else)."""
        self.runtime.on_burst(self._kind, int(K), self.n_shards,
                              width=self.L, payload_width=self.W,
                              pipelined=self.pipelined)
        return span(f"{self._kind}:burst", cat="wave", K=int(K),
                    n_shards=self.n_shards)

    def _place(self, x, lead: int = 0):
        """Stage one op array onto the active mesh via the runtime
        (``jnp.asarray`` under LocalRuntime — bit-identical to the
        pre-runtime path; an explicit global device_put under
        DistributedRuntime)."""
        return self.runtime.place(x, self.mesh, lead)

    def _check_overflow(self, ovf) -> None:
        """Drain telemetry, then host-raise the wave's replicated
        overflow flag as a structured
        :class:`~.errors.QueueOverflowError` (was a bare assert in every
        caller before PR 5) carrying the flight-recorder trajectory.
        ``ovf`` is a scalar bool (``step``) or a [K] vector
        (``run_waves``); this runs once per step/burst, so the recorder
        sees every wave even when nothing overflowed."""
        self._drain_telemetry()
        o = self.runtime.to_host(ovf)   # replicated scalar/[K] — cheap
        if not bool(o.any()):
            return
        wave = int(np.flatnonzero(o)[0]) if o.ndim >= 1 else None
        raise QueueOverflowError(self._kind, self._wave_capacity(),
                                 self._occupancies(), wave=wave,
                                 detail=self._overflow_detail,
                                 trajectory=self.recorder.trajectory())

    # -------------------------------------------------------- membership ---
    @property
    def n_shards(self) -> int:
        """Current number of active shards (the runtime-variable P)."""
        return len(self._active)

    @property
    def _pool(self) -> list:
        """The runtime's live device pool (failed devices excluded)."""
        return self.runtime.pool()

    @property
    def pool_size(self) -> int:
        """Total live devices available to this queue (active + spare);
        the hard upper bound :meth:`grow` can reach.  Quarantined
        (failed) devices do not count."""
        return self.runtime.pool_size

    @property
    def mesh(self):
        """The active shards' jax mesh (changes identity across resizes)."""
        return self.inner.mesh

    @property
    def devices(self) -> list:
        """The active shard devices, in shard-index order."""
        return list(self._active)

    @property
    def device_ids(self) -> list:
        """Stable device ids of the active shards, in shard-index order
        — the membership key failure attribution uses (mesh indices are
        only stable while membership never changes)."""
        return [d.id for d in self._active]

    def grow(self, k: int = 1) -> dict:
        """JOIN: add ``k`` shards from the device pool (P → P + k).

        Spares come from the runtime's *live* pool, so a device the
        fault layer quarantined (``shrink_devices(..., quarantine=
        True)``) is never handed back out — a LEAVE of a dead shard
        followed by a regrow cannot resurrect state on it."""
        if k < 1:
            raise ValueError("grow(k) needs k >= 1")
        active_ids = set(self.device_ids)
        spare = [d for d in self.runtime.pool() if d.id not in active_ids]
        if len(spare) < k:
            raise ValueError(f"cannot grow by {k}: only {len(spare)} spare "
                             f"devices in the pool")
        return self._rematerialize(self._active + spare[:k], kind="grow")

    def shrink(self, ids: Sequence[int]) -> dict:
        """Graceful LEAVE of the shards with indices ``ids`` (P → P - |ids|).

        The leaving shards participate in the migration wave (their elements
        are routed out before they drop from the mesh), mirroring the
        paper's LEAVE where the departing node hands its interval over
        before disconnecting."""
        ids = sorted(set(int(i) for i in ids))
        if not ids:
            raise ValueError("shrink(ids) needs at least one shard id")
        if ids[0] < 0 or ids[-1] >= self.n_shards:
            raise ValueError(f"shard ids {ids} out of range "
                             f"[0, {self.n_shards})")
        if len(ids) >= self.n_shards:
            raise ValueError("cannot shrink to zero shards")
        survivors = [d for i, d in enumerate(self._active) if i not in ids]
        return self._rematerialize(survivors, kind="shrink")

    def shrink_devices(self, dev_ids: Sequence[int], *,
                       quarantine: bool = False) -> dict:
        """Graceful LEAVE keyed by **stable device id** instead of mesh
        index (the PR 10 failure-rekey surface).

        Args:
          dev_ids: stable ids of the leaving devices (must be active).
          quarantine: additionally mark them failed in the runtime, so
            a later :meth:`grow` can never pick them again — the fault
            layer sets this for failure-LEAVEs (a dead device must not
            rejoin), and leaves it False for capacity scaling (the
            autoscaler may legitimately re-JOIN a healthy device).

        Returns:
          The migration stats dict, like :meth:`shrink`.
        """
        ids = [int(i) for i in dev_ids]
        mine = self.device_ids
        missing = [i for i in ids if i not in mine]
        if missing:
            raise ValueError(f"device id(s) {missing} are not active "
                             f"shards (active ids: {mine})")
        stats = self.shrink([mine.index(i) for i in ids])
        if quarantine:
            for i in ids:
                self.runtime.mark_failed(i)
        return stats

    def resize(self, n_new: int) -> dict:
        """Reshape to ``n_new`` shards (grow or shrink as needed)."""
        if n_new == self.n_shards:
            return {"kind": "noop", "P_from": self.n_shards,
                    "P_to": n_new, "moved": 0}
        if n_new > self.n_shards:
            return self.grow(n_new - self.n_shards)
        return self.shrink(range(n_new, self.n_shards))

    # ----------------------------------------------------- rematerialize ---
    def _rematerialize(self, new_active: list, kind: str) -> dict:
        P_old, P_new = self.n_shards, len(new_active)
        need = self._live_span()
        if need > P_new * self.cap:
            raise ValueError(
                f"cannot reshard to {P_new} shards: {need} live elements "
                f"exceed the new capacity {P_new} * {self.cap}")
        with span(f"migration:{kind}", cat="membership", kind=self._kind,
                  P_from=P_old, P_to=P_new):
            return self._rematerialize_traced(new_active, kind, P_old,
                                              P_new)

    def _rematerialize_traced(self, new_active: list, kind: str,
                              P_old: int, P_new: int) -> dict:
        t_total = time.perf_counter()
        a, b, X, Y = self._unpack(self.state)

        rt = self.runtime
        if P_new > P_old:
            # grow: pad empty shards, route on the NEW mesh.  Crossing
            # between meshes of different device sets is host-staged
            # through the runtime (np.asarray locally; a process_allgather
            # + global device_put under DistributedRuntime).
            mig_mesh = self._mesh_for(new_active)
            shard = NamedSharding(mig_mesh, P(self.axis))
            rep = NamedSharding(mig_mesh, P())
            fx, fy = self._pad_fill
            Xh, Yh = rt.to_host(X), rt.to_host(Y)
            pad = P_new - P_old
            Xh = np.concatenate(
                [Xh, np.full((pad,) + Xh.shape[1:], fx, Xh.dtype)])
            Yh = np.concatenate(
                [Yh, np.full((pad,) + Yh.shape[1:], fy, Yh.dtype)])
            a = rt.put(rt.to_host(a), rep)
            b = rt.put(rt.to_host(b), rep)
            X, Y = rt.put(Xh, shard), rt.put(Yh, shard)
        else:
            # shrink: route on the OLD mesh (owners are surviving shards)
            mig_mesh = self.mesh

        entry = self._migration_for(mig_mesh, P_old, P_new)
        if self._hlo_stats and entry[1] is None:
            entry[1] = self._count_all_to_all(entry[0], (a, b, X, Y))
        t_wave = time.perf_counter()
        a, b, X, Y, moved, lost = entry[0](a, b, X, Y)
        jax.block_until_ready(Y)
        t_wave = time.perf_counter() - t_wave
        if bool(rt.to_host(lost)):
            raise RuntimeError("migration fanout overflow — internal bound "
                               "violated, elements would have been dropped")

        if P_new < P_old:
            # drop the emptied rows, land on the smaller mesh
            new_mesh = self._mesh_for(new_active)
            shard = NamedSharding(new_mesh, P(self.axis))
            rep = NamedSharding(new_mesh, P())
            a = rt.put(rt.to_host(a), rep)
            b = rt.put(rt.to_host(b), rep)
            X = rt.put(rt.to_host(X)[:P_new], shard)
            Y = rt.put(rt.to_host(Y)[:P_new], shard)

        self.state = self._pack(a, b, X, Y)
        self._active = list(new_active)
        self.inner = self._get_inner(self._mesh_for(new_active))
        n_moved = int(rt.to_host(moved))
        stats = {
            "kind": kind, "P_from": P_old, "P_to": P_new,
            "moved": n_moved,
            "bytes_moved": n_moved * self._entry_bytes,
            "wave_s": t_wave,
            "total_s": time.perf_counter() - t_total,
            "collectives": entry[1],
        }
        hb = self._hash_balance(P_new)
        if hb is not None:
            stats["hash_balance"] = hb
        rt.on_migration(stats)   # SimRuntime charges the wire model here
        self.migrations.append(stats)
        return stats

    @staticmethod
    def _count_all_to_all(jitted, args) -> int:
        from ..analysis import count_all_to_all
        return count_all_to_all(jitted, args)

    def _hash_balance(self, P_new: int) -> Optional[dict]:
        """Paper-fidelity report: what the consistent-hashing layer
        (``kernels/hash_route``) would assign each shard for the SAME live
        position set that round-robin just re-placed perfectly evenly."""
        lo, hi = self._live_window()
        size = hi - lo + 1
        if size <= 0 or size > HASH_BALANCE_MAX_SIZE:
            return None
        from ..kernels.hash_route import hash_route_ref
        pos = jnp.arange(lo, hi + 1, dtype=jnp.int32)
        _, counts = hash_route_ref(pos, jnp.ones((size,), bool), P_new)
        counts = np.asarray(counts)
        return {"n": size, "max": int(counts.max()),
                "min": int(counts.min()),
                "roundrobin_max": -(-size // P_new)}

    # ------------------------------------------------------- checkpoints ---
    def _layout(self) -> dict:
        return {"kind": self._kind, "n_shards": self.n_shards,
                "cap": self.cap, "W": self.W, "L": self.L}

    @classmethod
    def _layout_kwargs(cls, lay: dict) -> dict:
        return {"cap": lay["cap"], "payload_width": lay["W"],
                "ops_per_shard": lay["L"]}

    def save(self, ckpt_dir, step: int):
        """Checkpoint the queue state (layout recorded in the manifest)."""
        from ..checkpoint import save_checkpoint
        with span("checkpoint:save", cat="checkpoint", kind=self._kind,
                  step=step):
            return save_checkpoint(ckpt_dir, step, self._state_dict(),
                                   meta={"layout": self._layout()})

    @classmethod
    def restore(cls, ckpt_dir, step: Optional[int] = None, *,
                n_shards: Optional[int] = None, devices=None,
                runtime=None, **kw):
        """Cold-start analogue of the live migration: rebuild from a
        checkpoint written under a possibly different shard count, via
        ``checkpoint.restore_sharded`` + one migration wave.

        Requires ``max(saved, target)`` shards' worth of devices (the
        migration mesh is the larger of the two layouts).  ``runtime``
        selects the mesh runtime the restored queue lives on (mutually
        exclusive with ``devices``, like the constructor)."""
        from ..checkpoint import latest_step, restore_sharded
        if step is None:
            step = latest_step(ckpt_dir)
        manifest = json.loads(
            (Path(ckpt_dir) / f"step_{step}" / "manifest.json").read_text())
        lay = manifest["meta"]["layout"]
        if lay["kind"] != cls._kind:
            raise ValueError(f"checkpoint holds a {lay['kind']}, "
                             f"not a {cls._kind}")
        inst = cls(lay["n_shards"], devices=devices, runtime=runtime,
                   **cls._layout_kwargs(lay), **kw)
        shard = NamedSharding(inst.mesh, P(inst.axis))
        rep = NamedSharding(inst.mesh, P())
        shardings = {k: (shard if k in cls._sharded_keys else rep)
                     for k in inst._state_dict()}
        placed, _ = restore_sharded(ckpt_dir, step, inst._state_dict(),
                                    shardings)
        inst.state = inst._from_state_dict(placed)
        if n_shards is not None and n_shards != lay["n_shards"]:
            inst.resize(n_shards)
        return inst

    # ------------------------------------------------- subclass contract ---
    _pad_fill: tuple  # fill values for (X, Y) padding rows
    _sharded_keys: frozenset = frozenset()  # state-dict keys on the axis

    def _make_inner(self, mesh):
        raise NotImplementedError

    def _build_migration(self, mesh, P_old, P_new):
        raise NotImplementedError

    def _unpack(self, state):
        raise NotImplementedError

    def _pack(self, a, b, X, Y):
        raise NotImplementedError

    def _live_span(self) -> int:
        raise NotImplementedError

    def _live_window(self) -> tuple:
        raise NotImplementedError

    def _state_dict(self) -> dict:
        raise NotImplementedError

    def _from_state_dict(self, d: dict):
        raise NotImplementedError

    @property
    def _entry_bytes(self) -> int:
        raise NotImplementedError


class _MultiWindowElastic(_ElasticBase):
    """Shared elastic machinery for structures whose ring store is split
    into ``_n_windows`` round-robin slot windows over one ``[first, last]``
    interval each — priority tiers (window = tier) and Seap buckets
    (window = bucket).  State must expose ``firsts``/``lasts`` ``[W]``
    vectors; the migration wave recovers every window's positions and
    moves all windows with ONE packed all_to_all (the PR 2 wave
    vectorized over windows).  Lives here ONCE so a migration fix cannot
    need landing per discipline (the PR 3 'patched three times' lesson)."""

    @property
    def _n_windows(self) -> int:
        raise NotImplementedError

    @property
    def sizes(self) -> list:
        """Per-window occupancy vector (one host int per tier/bucket)."""
        f = np.asarray(self.state.firsts)
        l = np.asarray(self.state.lasts)
        return [int(x) for x in (l - f + 1)]

    @property
    def size(self) -> int:
        """Total live elements across every window."""
        return sum(self.sizes)

    def _occupancies(self) -> list:
        return self.sizes

    def _live_span(self) -> int:
        # capacity check is per window (each owns its own slot range)
        return max([0] + self.sizes)

    def _hash_balance(self, P_new: int):
        """Combined consistent-hashing fidelity report over every
        window's live range (positions in different windows hash
        independently)."""
        f = np.asarray(self.state.firsts)
        l = np.asarray(self.state.lasts)
        pos = np.concatenate([np.arange(lo, hi + 1)
                              for lo, hi in zip(f, l)] or [np.zeros(0)])
        if pos.size == 0 or pos.size > HASH_BALANCE_MAX_SIZE:
            return None
        from ..kernels.hash_route import hash_route_ref
        _, counts = hash_route_ref(jnp.asarray(pos, jnp.int32),
                                   jnp.ones((pos.size,), bool), P_new)
        counts = np.asarray(counts)
        return {"n": int(pos.size), "max": int(counts.max()),
                "min": int(counts.min()),
                "roundrobin_max": -(-int(pos.size) // P_new)}

    @property
    def _entry_bytes(self) -> int:
        return 4 * (1 + self.W)  # slot ‖ payload columns

    def _build_migration(self, mesh, P_old: int, P_new: int):
        axis, cap, W = self.axis, self.cap, self.W
        n_win = self._n_windows
        n_mesh = mesh.shape[axis]
        M = min(n_win * cap, n_win * fanout_bound(P_old, P_new, cap))
        junk = n_win * cap

        def body(firsts, lasts, sv, sf):
            s = lax.axis_index(axis).astype(jnp.int32)
            u = jnp.arange(junk, dtype=jnp.int32)
            win = u // cap
            # recover the window-local position each occupied slot holds
            # (unique in the window's live range; PR 2 invariant per
            # window)
            p = recover_positions(s, u % cap, firsts[win], P_old, cap)
            live = sf[0, :junk] & (p >= firsts[win]) & (p <= lasts[win])
            owner = jnp.mod(p, P_new).astype(jnp.int32)
            slot_new = (win * cap + jnp.mod(p // P_new, cap)).astype(
                jnp.int32)
            cols = jnp.concatenate([slot_new[:, None], sv[0, :junk]], axis=1)
            fill = jnp.zeros((1 + W,), jnp.int32).at[0].set(junk)
            rows, moved, lost = migrate_packed(axis, n_mesh, M, live, owner,
                                               cols, fill)
            nsv, nsf = rewrite_ring_store(rows, junk, W)
            return firsts, lasts, nsv, nsf, moved, lost

        specs = (P(), P(), P(axis), P(axis))
        wrapped = shard_map(body, mesh=mesh, in_specs=specs,
                            out_specs=specs + (P(), P()))
        return jax.jit(wrapped, donate_argnums=(2, 3))


class ElasticDeviceQueue(_ElasticBase):
    """Distributed FIFO whose shard count is a runtime variable.

    Owns its state (the inner ``DeviceQueue``'s donated-state discipline is
    internal): ``step``/``run_waves`` mirror :class:`DeviceQueue` minus the
    state argument, and ``grow``/``shrink``/``resize`` re-materialize the
    store between bursts.  See the module docstring for the mechanism."""

    _kind = "queue"
    _pad_fill = (0, False)
    _sharded_keys = frozenset({"store_vals", "store_full"})

    def __init__(self, n_shards: int, *, axis_name: str = "data",
                 cap: int = 1024, payload_width: int = 4,
                 ops_per_shard: int = 64, fused: bool = True,
                 devices=None, runtime=None, hlo_stats: bool = False,
                 pipelined: bool = True, metrics: bool = False,
                 metrics_ring: int = 64, flight_k: int = 16):
        self.fused = fused
        super().__init__(n_shards, axis_name=axis_name, cap=cap,
                         payload_width=payload_width,
                         ops_per_shard=ops_per_shard, devices=devices,
                         runtime=runtime,
                         hlo_stats=hlo_stats, pipelined=pipelined,
                         metrics=metrics, metrics_ring=metrics_ring,
                         flight_k=flight_k)

    def _make_inner(self, mesh):
        return DeviceQueue(mesh, self.axis, cap=self.cap,
                           payload_width=self.W, ops_per_shard=self.L,
                           fused=self.fused, pipelined=self.pipelined,
                           metrics=self.metrics and self.fused,
                           metrics_ring=self.metrics_ring,
                           runtime=self.runtime)

    # ------------------------------------------------------------ waves ----
    def step(self, is_enq, valid, payload):
        """One wave on the current mesh; state is threaded internally.
        Returns (positions, matched, deq_vals, deq_ok, overflow); raises
        :class:`~.errors.QueueOverflowError` when the wave overflowed."""
        with self._burst_span(1):
            self.state, pos, m, dv, dok, ovf = self.inner.step(
                self.state, self._place(is_enq), self._place(valid),
                self._place(payload))
        self._check_overflow(ovf)
        return pos, m, dv, dok, ovf

    def run_waves(self, is_enq, valid, payload):
        """K pre-staged waves in one dispatch (shapes [K, n_shards * L]).
        Raises :class:`~.errors.QueueOverflowError` on overflow."""
        is_enq = self._place(is_enq, lead=1)
        with self._burst_span(is_enq.shape[0]):
            self.state, pos, m, dv, dok, ovf = self.inner.run_waves(
                self.state, is_enq, self._place(valid, lead=1),
                self._place(payload, lead=1))
        self._check_overflow(ovf)
        return pos, m, dv, dok, ovf

    @property
    def size(self) -> int:
        """Live elements in the FIFO window (``last - first + 1``)."""
        return int(self.state.last) - int(self.state.first) + 1

    # -------------------------------------------------------- migration ----
    def _unpack(self, state):
        return state.first, state.last, state.store_vals, state.store_full

    def _pack(self, a, b, X, Y):
        return DeviceQueueState(a, b, X, Y)

    def _live_window(self):
        return int(self.state.first), int(self.state.last)

    def _live_span(self) -> int:
        lo, hi = self._live_window()
        return max(0, hi - lo + 1)

    @property
    def _entry_bytes(self) -> int:
        return 4 * (1 + self.W)  # slot ‖ payload columns

    def _state_dict(self) -> dict:
        return {"first": self.state.first, "last": self.state.last,
                "store_vals": self.state.store_vals,
                "store_full": self.state.store_full}

    def _from_state_dict(self, d: dict):
        return DeviceQueueState(d["first"], d["last"], d["store_vals"],
                                d["store_full"])

    def _build_migration(self, mesh, P_old: int, P_new: int):
        axis, cap, W = self.axis, self.cap, self.W
        n_mesh = mesh.shape[axis]
        M = fanout_bound(P_old, P_new, cap)

        def body(first, last, sv, sf):
            s = lax.axis_index(axis).astype(jnp.int32)
            t = jnp.arange(cap, dtype=jnp.int32)
            # recover the position each occupied slot holds (unique in the
            # live window [first, last]; see module docstring)
            p = recover_positions(s, t, first, P_old, cap)
            live = sf[0, :cap] & (p >= first) & (p <= last)
            owner = jnp.mod(p, P_new).astype(jnp.int32)
            slot_new = jnp.mod(p // P_new, cap).astype(jnp.int32)
            # ---- packed request: new_slot ‖ payload, one all_to_all ----
            cols = jnp.concatenate([slot_new[:, None], sv[0, :cap]], axis=1)
            fill = jnp.zeros((1 + W,), jnp.int32).at[0].set(cap)
            rows, moved, lost = migrate_packed(axis, n_mesh, M, live, owner,
                                               cols, fill)
            nsv, nsf = rewrite_ring_store(rows, cap, W)
            return first, last, nsv, nsf, moved, lost

        specs = (P(), P(), P(axis), P(axis))
        wrapped = shard_map(body, mesh=mesh, in_specs=specs,
                            out_specs=specs + (P(), P()))
        return jax.jit(wrapped, donate_argnums=(2, 3))


class ElasticDeviceStack(_ElasticBase):
    """Distributed LIFO with runtime-variable shard count.

    Migration flattens the (slot, depth) entry set; an entry's position is
    recovered from its slot exactly as for the queue (live window
    ``[1, last]``), and its depth index travels with it — distinct positions
    land on distinct new slots, so (new_slot, depth) addressing is
    collision-free on the receiving side."""

    _kind = "stack"
    _pad_fill = (0, -1)  # vals pad 0, tickets pad -1 (= empty)
    _sharded_keys = frozenset({"vals", "ticks"})

    def __init__(self, n_shards: int, *, axis_name: str = "data",
                 cap: int = 1024, payload_width: int = 4,
                 ops_per_shard: int = 64, slot_depth: int = 4,
                 devices=None, runtime=None, hlo_stats: bool = False,
                 pipelined: bool = True, metrics: bool = False,
                 metrics_ring: int = 64, flight_k: int = 16):
        self.D = slot_depth
        super().__init__(n_shards, axis_name=axis_name, cap=cap,
                         payload_width=payload_width,
                         ops_per_shard=ops_per_shard, devices=devices,
                         runtime=runtime,
                         hlo_stats=hlo_stats, pipelined=pipelined,
                         metrics=metrics, metrics_ring=metrics_ring,
                         flight_k=flight_k)

    def _make_inner(self, mesh):
        return DeviceStack(mesh, self.axis, cap=self.cap,
                           payload_width=self.W, ops_per_shard=self.L,
                           slot_depth=self.D, pipelined=self.pipelined,
                           metrics=self.metrics,
                           metrics_ring=self.metrics_ring,
                           runtime=self.runtime)

    _overflow_detail = ("a store slot's depth-D ticket set was exhausted "
                        "at commit time")

    def _wave_capacity(self) -> int:
        return self.n_shards * self.cap * self.D

    # ------------------------------------------------------------ waves ----
    def step(self, is_push, valid, payload):
        """One wave on the current mesh; state is threaded internally.
        Returns (positions, matched, pop_vals, pop_ok, overflow); raises
        :class:`~.errors.QueueOverflowError` when the wave overflowed."""
        with self._burst_span(1):
            self.state, pos, m, pv, pok, ovf = self.inner.step(
                self.state, self._place(is_push), self._place(valid),
                self._place(payload))
        self._check_overflow(ovf)
        return pos, m, pv, pok, ovf

    def run_waves(self, is_push, valid, payload):
        """K pre-staged waves in one dispatch (shapes [K, n_shards * L]).
        Raises :class:`~.errors.QueueOverflowError` on overflow."""
        is_push = self._place(is_push, lead=1)
        with self._burst_span(is_push.shape[0]):
            self.state, pos, m, pv, pok, ovf = self.inner.run_waves(
                self.state, is_push, self._place(valid, lead=1),
                self._place(payload, lead=1))
        self._check_overflow(ovf)
        return pos, m, pv, pok, ovf

    @property
    def size(self) -> int:
        """Live elements on the stack (positions start at 1)."""
        return int(self.state["last"])

    # -------------------------------------------------------- migration ----
    def _unpack(self, state):
        return state["last"], state["ticket"], state["vals"], state["ticks"]

    def _pack(self, a, b, X, Y):
        return {"last": a, "ticket": b, "vals": X, "ticks": Y}

    def _live_window(self):
        return 1, int(self.state["last"])

    def _live_span(self) -> int:
        return int(self.state["last"])

    @property
    def _entry_bytes(self) -> int:
        return 4 * (3 + self.W)  # slot ‖ depth ‖ ticket ‖ payload

    def _layout(self) -> dict:
        return {**super()._layout(), "D": self.D}

    @classmethod
    def _layout_kwargs(cls, lay: dict) -> dict:
        return {**super()._layout_kwargs(lay), "slot_depth": lay["D"]}

    def _state_dict(self) -> dict:
        return dict(self.state)

    def _from_state_dict(self, d: dict):
        return {"last": d["last"], "ticket": d["ticket"],
                "vals": d["vals"], "ticks": d["ticks"]}

    def _build_migration(self, mesh, P_old: int, P_new: int):
        axis, cap, W, D = self.axis, self.cap, self.W, self.D
        n_mesh = mesh.shape[axis]
        M = min(cap * D, fanout_bound(P_old, P_new, cap) * D)

        def body(last, ticket, sv, stk):
            s = lax.axis_index(axis).astype(jnp.int32)
            t = jnp.arange(cap, dtype=jnp.int32)
            p = recover_positions(s, t, 1, P_old, cap)  # positions start at 1
            in_range = (p >= 1) & (p <= last)
            owner = jnp.mod(p, P_new).astype(jnp.int32)
            slot_new = jnp.mod(p // P_new, cap).astype(jnp.int32)
            ticks = stk[0, :cap]                             # [cap, D]
            live = ((ticks >= 0) & in_range[:, None]).reshape(-1)
            dep = jnp.tile(jnp.arange(D, dtype=jnp.int32), cap)
            # ---- packed request: slot ‖ depth ‖ ticket ‖ payload ----
            cols = jnp.concatenate(
                [jnp.repeat(slot_new, D)[:, None], dep[:, None],
                 ticks.reshape(-1)[:, None], sv[0, :cap].reshape(-1, W)],
                axis=1)
            fill = jnp.zeros((3 + W,), jnp.int32).at[0].set(cap).at[2].set(-1)
            rows, moved, lost = migrate_packed(
                axis, n_mesh, M, live, jnp.repeat(owner, D), cols, fill)
            rs, rd, rt = rows[:, 0], rows[:, 1], rows[:, 2]
            rv = rows[:, 3:]
            nstk = jnp.full((cap + 1, D), -1, jnp.int32).at[rs, rd].set(rt)
            nstk = nstk.at[cap].set(-1)
            nsv = jnp.zeros((cap + 1, D, W), jnp.int32).at[rs, rd].set(rv)
            nsv = nsv.at[cap].set(0)
            return last, ticket, nsv[None], nstk[None], moved, lost

        specs = (P(), P(), P(axis), P(axis))
        wrapped = shard_map(body, mesh=mesh, in_specs=specs,
                            out_specs=specs + (P(), P()))
        return jax.jit(wrapped, donate_argnums=(2, 3))
