"""Device-resident P-tier priority queue on the fused Stage-4 wave path.

Skeap (arXiv:1805.03472) extends SKUEUE's batch-aggregation protocol to
distributed priority queues; in the constant-priority regime the queue is
P independent SKUEUE position intervals tie-broken by tier.  This module is
that design as a :class:`~.wave_engine.WaveEngine` discipline: the sharded
ring store gains one round-robin slot *window per tier* — tier ``p``'s
position ``q`` lives on shard ``q % n_shards`` at slot
``p * cap + (q // n_shards) % cap`` — and Stage-4 dispatch stays TWO fused
``all_to_all`` collectives per wave (ONE per wave in the pipelined burst
schedule; the slot already encodes the tier window, so nothing else
changes on the wire).

Only the *dispatch* differs from FIFO (the commit is the shared dense-ring
rewrite, :func:`~.wave_engine.ring_commit`):

* op descriptors (enq/valid/prio: 5 bits per op) ride one tiny
  ``all_gather`` — the same trick the stack discipline uses — after which
  position assignment is fully replicated;
* enqueues get per-tier FIFO positions from P masked min-plus scans
  (``core.scan_queue.priority_queue_scan``, reusing the PR 1 transforms);
* the wave's dequeues are resolved highest-priority-first *inside the
  wave*: the d-th dequeue (wave order) takes the d-th element of the
  priority-ordered pool — Skeap's batch-DeleteMin assignment — via
  per-tier prefix sums, no sequential loop in strict mode;
* ``relaxation=k`` switches the resolution to a replicated in-wave scan
  that lets a dequeue take a *locally owned* lower-tier head (at most k
  tiers below the strictly-best one) instead of a remote best-tier head —
  bounded tier skew (never per-tier FIFO violation) traded for serves
  that avoid the cross-shard hop, after arXiv:2503.02164.

Differentially tested op-by-op against the host
:class:`repro.core.priority.PriorityOracle` (same wave semantics,
independent implementation).  :class:`ElasticDevicePriorityQueue` adds the
PR 2 membership story: grow/shrink re-materializes every tier window with
ONE packed migration all_to_all, and the per-tier layout (n_prios, cap,
relaxation) is recorded in checkpoint manifests for cold-start resharding.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..core.scan_queue import priority_queue_scan
from ..kernels.backend import use_fused_dispatch
from .elastic import _MultiWindowElastic
from .wave_engine import (Discipline, Dispatch, TAG_GET, TAG_INACTIVE,
                          TAG_PUT, WaveEngine,
                          post_enqueue_peak_overflow, ring_commit)


class PriorityQueueState(NamedTuple):
    """P-tier queue state: per-tier replicated ``[firsts, lasts]`` live
    windows plus the sharded ring store (one slot window per tier)."""

    firsts: jax.Array         # [P] replicated int32
    lasts: jax.Array          # [P] replicated int32
    store_vals: jax.Array     # [n_shards(sharded), P*cap + 1, W] int32
    store_full: jax.Array     # [n_shards(sharded), P*cap + 1] bool

    @property
    def sizes(self) -> jax.Array:
        """Per-tier occupancy vector ``[P]`` (traced)."""
        return self.lasts - self.firsts + 1


class PriorityDiscipline(Discipline):
    """Skeap constant-priority order: P masked min-plus scans + in-wave
    batch-DeleteMin dequeue resolution over the shared dense-ring store."""

    n_ops = 4           # (is_enq, valid, prio, payload)
    n_disp_outs = 3     # (tier, pos, matched)
    n_aux = 1           # n_relaxed

    def __init__(self, axis: str, n_shards: int, n_prios: int, cap: int,
                 W: int, relaxation: int,
                 fused_dispatch: bool | None = None):
        self.axis = axis
        self.n_shards = n_shards
        self.n_prios = n_prios
        self.cap = cap
        self.W = W
        self.relaxation = relaxation
        self.junk = n_prios * cap
        self.n_windows = n_prios
        self.window_capacity = n_shards * cap
        # on compiled backends the P masked min-plus scans collapse to ONE
        # pallas sweep (grid = tiers x tiles); the jnp loop stays the CPU
        # path AND the differential oracle (None = autodetect, PR 9)
        if fused_dispatch is None:
            fused_dispatch = use_fused_dispatch()
        self.fused_dispatch = bool(fused_dispatch)
        if self.fused_dispatch:
            from ..kernels.segscan import make_tier_scan
            self._tier_scan = make_tier_scan(n_prios)
        else:
            self._tier_scan = None
        self.state_specs = PriorityQueueState(P(), P(), P(axis), P(axis))

    def split(self, state):
        """Split state into its (replicated carry, sharded store) halves."""
        return (state.firsts, state.lasts), (state.store_vals,
                                             state.store_full)

    def merge(self, carry, store):
        """Reassemble the full state from (carry, store) halves."""
        return PriorityQueueState(carry[0], carry[1], store[0], store[1])

    def dispatch(self, carry, ops) -> Dispatch:
        """Stages 1-3: assign positions and build the routed Dispatch."""
        is_enq, valid, prio, payload = ops
        firsts, lasts = carry
        n_shards, cap, P_ = self.n_shards, self.cap, self.n_prios
        L = is_enq.shape[0]

        # ---- gather the op descriptors (5ish bits/op) and assign
        #      replicated: every shard runs the same per-tier scans ----
        code = (prio.astype(jnp.int32) * 4
                + is_enq.astype(jnp.int32) * 2 + valid.astype(jnp.int32))
        g = lax.all_gather(code, self.axis, tiled=True)     # [n_shards * L]
        shard_of = (jnp.arange(g.shape[0], dtype=jnp.int32) // L)
        tier_g, pos_g, matched_g, new_firsts, new_lasts, n_relaxed = (
            priority_queue_scan(
                (g & 2) > 0, g >> 2, (g & 1) > 0, firsts, lasts,
                n_prios=P_, relaxation=self.relaxation,
                shard_of=shard_of, n_shards=n_shards,
                tier_scan=self._tier_scan))

        i0 = lax.axis_index(self.axis) * L
        tier = lax.dynamic_slice_in_dim(tier_g, i0, L)
        pos = lax.dynamic_slice_in_dim(pos_g, i0, L)
        matched = lax.dynamic_slice_in_dim(matched_g, i0, L)

        owner = jnp.where(matched, pos % n_shards, -1).astype(jnp.int32)
        slot = jnp.where(matched, tier * cap + (pos // n_shards) % cap,
                         self.junk).astype(jnp.int32)
        tag = jnp.where(matched & is_enq, TAG_PUT,
                        jnp.where(matched & ~is_enq, TAG_GET, TAG_INACTIVE))
        # capacity holds per tier (each tier owns its own slot window)
        ovf = post_enqueue_peak_overflow(firsts, new_lasts, n_shards * cap)
        return Dispatch(owner, slot, tag, (), payload, matched,
                        matched & ~is_enq, (tier, pos, matched),
                        (new_firsts, new_lasts), ovf, (n_relaxed,))

    def commit(self, store, recv):
        """Stage 4: apply this shard's routed requests to its store."""
        return ring_commit(store, recv, self.junk, self.W)

    def zero_outs(self, L: int) -> tuple:
        """All-invalid per-op dispatch outputs (padding waves)."""
        return (jnp.full((L,), -1, jnp.int32),
                jnp.full((L,), -1, jnp.int32), jnp.zeros((L,), bool))

    def zero_aux(self) -> tuple:
        """Zeroed auxiliary per-wave outputs (padding waves)."""
        return (jnp.int32(0),)

    def occupancy(self, carry):
        """Per-window occupancy vector from the carry (traced)."""
        return carry[1] - carry[0] + 1


class DevicePriorityQueue:
    """Distributed constant-priority queue over one mesh axis.

    Args:
      mesh/axis_name: the shard axis; n_prios: number of priority tiers P
        (0 = most urgent); cap: slots per shard PER TIER; payload_width:
        int32 words per element; ops_per_shard: wave width L;
      relaxation: 0 = strict priority order; k > 0 allows a dequeue to be
        served from a locally-owned head up to k tiers below the best
        non-empty tier (see module docstring);
      pipelined: multi-wave bursts use the engine's software-pipelined
        schedule (False = sequential; results identical).
    """

    def __init__(self, mesh, axis_name: str = "data", n_prios: int = 2,
                 cap: int = 1024, payload_width: int = 4,
                 ops_per_shard: int = 64, relaxation: int = 0,
                 pipelined: bool = True, metrics: bool = False,
                 metrics_ring: int = 64,
                 fused_dispatch: bool | None = None, runtime=None):
        if n_prios < 1:
            raise ValueError("need at least one priority tier")
        from ..runtime import as_runtime
        self.runtime, mesh, axis_name = as_runtime(mesh, axis_name,
                                                   runtime=runtime)
        self.mesh = mesh
        self.axis = axis_name
        self.n_shards = mesh.shape[axis_name]
        self.n_prios = n_prios
        self.cap = cap
        self.W = payload_width
        self.L = ops_per_shard
        self.relaxation = relaxation
        self.pipelined = pipelined
        self.metrics = metrics
        self.engine = WaveEngine(
            mesh, axis_name,
            PriorityDiscipline(axis_name, self.n_shards, n_prios, cap,
                               payload_width, relaxation,
                               fused_dispatch=fused_dispatch),
            pipelined=pipelined, metrics=metrics, metrics_ring=metrics_ring,
            runtime=self.runtime)
        self._step = self.engine._step
        self._run_waves = self.engine._run_waves

    def init_state(self) -> PriorityQueueState:
        """Freshly sharded empty state on this structure's mesh."""
        n, cap, W, P_ = self.n_shards, self.cap, self.W, self.n_prios
        sharding = jax.sharding.NamedSharding(self.mesh, P(self.axis))
        rep = jax.sharding.NamedSharding(self.mesh, P())
        put = self.runtime.put
        return PriorityQueueState(
            firsts=put(jnp.zeros((P_,), jnp.int32), rep),
            lasts=put(jnp.full((P_,), -1, jnp.int32), rep),
            store_vals=put(
                jnp.zeros((n, P_ * cap + 1, W), jnp.int32), sharding),
            store_full=put(
                jnp.zeros((n, P_ * cap + 1), bool), sharding),
        )

    def step(self, state: PriorityQueueState, is_enq, valid, prio, payload):
        """Process one global wave.  The state argument is DONATED.

        is_enq/valid: [n_shards * L] bool; prio: [n_shards * L] int32 in
        [0, n_prios) (ignored for dequeues); payload: [n_shards * L, W].
        Returns (new_state, tier, pos, matched, deq_vals, deq_ok, overflow,
        n_relaxed) — tier/pos are -1/⊥ for unmatched ops.
        """
        return self.engine.step(state, is_enq, valid, prio, payload)

    def run_waves(self, state: PriorityQueueState, is_enq, valid, prio,
                  payload):
        """K pre-staged waves in ONE lax.scan dispatch (state DONATED).

        Shapes: is_enq/valid/prio [K, n_shards * L]; payload [K, ..., W].
        """
        return self.engine.run_waves(state, is_enq, valid, prio, payload)

    def drain_metrics(self, *, reset: bool = False) -> list:
        """Burst-boundary Wavescope drain (empty when metrics are off)."""
        return self.engine.drain_metrics(reset=reset)


class ElasticDevicePriorityQueue(_MultiWindowElastic):
    """P-tier priority queue whose shard count is a runtime variable.

    Owns its state like :class:`~.elastic.ElasticDeviceQueue`; ``grow`` /
    ``shrink`` / ``resize`` re-materialize every tier window onto the new
    mesh with one packed migration all_to_all (the PR 2 wave, vectorized
    over the P tier windows via the shared
    :class:`~.elastic._MultiWindowElastic` machinery), and checkpoint
    manifests record the per-tier layout so cold starts can reshard."""

    _kind = "pqueue"
    _pad_fill = (0, False)
    _sharded_keys = frozenset({"store_vals", "store_full"})

    @property
    def _n_windows(self) -> int:
        return self.n_prios

    def __init__(self, n_shards: int, *, n_prios: int = 2,
                 relaxation: int = 0, axis_name: str = "data",
                 cap: int = 1024, payload_width: int = 4,
                 ops_per_shard: int = 64, devices=None, runtime=None,
                 hlo_stats: bool = False, pipelined: bool = True,
                 metrics: bool = False, metrics_ring: int = 64,
                 flight_k: int = 16):
        self.n_prios = n_prios
        self.relaxation = relaxation
        super().__init__(n_shards, axis_name=axis_name, cap=cap,
                         payload_width=payload_width,
                         ops_per_shard=ops_per_shard, devices=devices,
                         runtime=runtime,
                         hlo_stats=hlo_stats, pipelined=pipelined,
                         metrics=metrics, metrics_ring=metrics_ring,
                         flight_k=flight_k)

    def _make_inner(self, mesh):
        return DevicePriorityQueue(mesh, self.axis, n_prios=self.n_prios,
                                   cap=self.cap, payload_width=self.W,
                                   ops_per_shard=self.L,
                                   relaxation=self.relaxation,
                                   pipelined=self.pipelined,
                                   metrics=self.metrics,
                                   metrics_ring=self.metrics_ring,
                                   runtime=self.runtime)

    # ------------------------------------------------------------ waves ----
    def step(self, is_enq, valid, prio, payload):
        """One wave on the current mesh; state is threaded internally.
        Returns (tier, pos, matched, deq_vals, deq_ok, overflow,
        n_relaxed); raises :class:`~.errors.QueueOverflowError` when the
        wave overflowed a tier window."""
        with self._burst_span(1):
            self.state, *out = self.inner.step(
                self.state, self._place(is_enq), self._place(valid),
                self._place(prio), self._place(payload))
        self._check_overflow(out[5])
        return tuple(out)

    def run_waves(self, is_enq, valid, prio, payload):
        """K pre-staged waves in one dispatch (shapes [K, n_shards * L]).
        Raises :class:`~.errors.QueueOverflowError` on tier overflow."""
        is_enq = self._place(is_enq, lead=1)
        with self._burst_span(is_enq.shape[0]):
            self.state, *out = self.inner.run_waves(
                self.state, is_enq, self._place(valid, lead=1),
                self._place(prio, lead=1), self._place(payload, lead=1))
        self._check_overflow(out[5])
        return tuple(out)

    # -------------------------------------------------------- migration ----
    def _unpack(self, state):
        return state.firsts, state.lasts, state.store_vals, state.store_full

    def _pack(self, a, b, X, Y):
        return PriorityQueueState(a, b, X, Y)

    def _layout(self) -> dict:
        return {**super()._layout(), "P": self.n_prios,
                "relaxation": self.relaxation}

    @classmethod
    def _layout_kwargs(cls, lay: dict) -> dict:
        return {**super()._layout_kwargs(lay), "n_prios": lay["P"],
                "relaxation": lay.get("relaxation", 0)}

    def _state_dict(self) -> dict:
        return {"firsts": self.state.firsts, "lasts": self.state.lasts,
                "store_vals": self.state.store_vals,
                "store_full": self.state.store_full}

    def _from_state_dict(self, d: dict):
        return PriorityQueueState(d["firsts"], d["lasts"], d["store_vals"],
                                  d["store_full"])
