"""Device-resident P-tier priority queue on the fused Stage-4 wave path.

Skeap (arXiv:1805.03472) extends SKUEUE's batch-aggregation protocol to
distributed priority queues; in the constant-priority regime the queue is
P independent SKUEUE position intervals tie-broken by tier.  This module is
that design on the PR 1 device path: the sharded ring store gains one
round-robin slot *window per tier* — tier ``p``'s position ``q`` lives on
shard ``q % n_shards`` at slot ``p * cap + (q // n_shards) % cap`` — and
Stage-4 dispatch stays TWO fused ``all_to_all`` collectives per wave (one
packed ``slot ‖ tag ‖ payload`` request, one ``ok ‖ value`` reply; the
slot already encodes the tier window, so nothing else changes on the wire).

Op descriptors (enq/valid/prio: 5 bits per op) ride one tiny ``all_gather``
— the same trick :class:`~.device_queue.DeviceStack` uses for its global
scan — after which position assignment is fully replicated:

* enqueues get per-tier FIFO positions from P masked min-plus scans
  (``core.scan_queue.priority_queue_scan``, reusing the PR 1 transforms);
* the wave's dequeues are resolved highest-priority-first *inside the
  wave*: the d-th dequeue (wave order) takes the d-th element of the
  priority-ordered pool — Skeap's batch-DeleteMin assignment — via
  per-tier prefix sums, no sequential loop in strict mode;
* ``relaxation=k`` switches the resolution to a replicated in-wave scan
  that lets a dequeue take a *locally owned* lower-tier head (at most k
  tiers below the strictly-best one) instead of a remote best-tier head —
  bounded tier skew (never per-tier FIFO violation) traded for serves
  that avoid the cross-shard hop, after arXiv:2503.02164.

Differentially tested op-by-op against the host
:class:`repro.core.priority.PriorityOracle` (same wave semantics,
independent implementation).  :class:`ElasticDevicePriorityQueue` adds the
PR 2 membership story: grow/shrink re-materializes every tier window with
ONE packed migration all_to_all, and the per-tier layout (n_prios, cap,
relaxation) is recorded in checkpoint manifests for cold-start resharding.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..core.scan_queue import priority_queue_scan
from .device_queue import TAG_GET, TAG_INACTIVE, TAG_PUT, _build_send_packed
from .elastic import _ElasticBase, _dest_rank, _fanout_bound

HASH_BALANCE_MAX_SIZE = 1 << 16


class PriorityQueueState(NamedTuple):
    firsts: jax.Array         # [P] replicated int32
    lasts: jax.Array          # [P] replicated int32
    store_vals: jax.Array     # [n_shards(sharded), P*cap + 1, W] int32
    store_full: jax.Array     # [n_shards(sharded), P*cap + 1] bool

    @property
    def sizes(self) -> jax.Array:
        return self.lasts - self.firsts + 1


class DevicePriorityQueue:
    """Distributed constant-priority queue over one mesh axis.

    Args:
      mesh/axis_name: the shard axis; n_prios: number of priority tiers P
        (0 = most urgent); cap: slots per shard PER TIER; payload_width:
        int32 words per element; ops_per_shard: wave width L;
      relaxation: 0 = strict priority order; k > 0 allows a dequeue to be
        served from a locally-owned head up to k tiers below the best
        non-empty tier (see module docstring).
    """

    def __init__(self, mesh, axis_name: str = "data", n_prios: int = 2,
                 cap: int = 1024, payload_width: int = 4,
                 ops_per_shard: int = 64, relaxation: int = 0):
        if n_prios < 1:
            raise ValueError("need at least one priority tier")
        self.mesh = mesh
        self.axis = axis_name
        self.n_shards = mesh.shape[axis_name]
        self.n_prios = n_prios
        self.cap = cap
        self.W = payload_width
        self.L = ops_per_shard
        self.relaxation = relaxation
        self._state_specs = PriorityQueueState(P(), P(), P(self.axis),
                                               P(self.axis))
        self._step = self._build_step()
        self._run_waves = self._build_run_waves()

    def init_state(self) -> PriorityQueueState:
        n, cap, W, P_ = self.n_shards, self.cap, self.W, self.n_prios
        sharding = jax.sharding.NamedSharding(self.mesh, P(self.axis))
        rep = jax.sharding.NamedSharding(self.mesh, P())
        return PriorityQueueState(
            firsts=jax.device_put(jnp.zeros((P_,), jnp.int32), rep),
            lasts=jax.device_put(jnp.full((P_,), -1, jnp.int32), rep),
            store_vals=jax.device_put(
                jnp.zeros((n, P_ * cap + 1, W), jnp.int32), sharding),
            store_full=jax.device_put(
                jnp.zeros((n, P_ * cap + 1), bool), sharding),
        )

    # ------------------------------------------------------- wave body -----
    def _wave(self, state: PriorityQueueState, is_enq, valid, prio, payload):
        axis, n_shards, cap, W = self.axis, self.n_shards, self.cap, self.W
        P_, L = self.n_prios, is_enq.shape[0]
        junk = P_ * cap

        # ---- gather the op descriptors (5ish bits/op) and assign
        #      replicated: every shard runs the same per-tier scans ----
        code = (prio.astype(jnp.int32) * 4
                + is_enq.astype(jnp.int32) * 2 + valid.astype(jnp.int32))
        g = lax.all_gather(code, axis, tiled=True)          # [n_shards * L]
        g_valid = (g & 1) > 0
        g_enq = (g & 2) > 0
        g_prio = g >> 2
        n = g.shape[0]
        shard_of = (jnp.arange(n, dtype=jnp.int32) // L)
        tier_g, pos_g, matched_g, new_firsts, new_lasts, n_relaxed = (
            priority_queue_scan(
                g_enq, g_prio, g_valid, state.firsts, state.lasts,
                n_prios=P_, relaxation=self.relaxation,
                shard_of=shard_of, n_shards=n_shards))

        i0 = lax.axis_index(axis) * L
        tier = lax.dynamic_slice_in_dim(tier_g, i0, L)
        pos = lax.dynamic_slice_in_dim(pos_g, i0, L)
        matched = lax.dynamic_slice_in_dim(matched_g, i0, L)

        owner = jnp.where(matched, pos % n_shards, -1).astype(jnp.int32)
        slot = jnp.where(matched, tier * cap + (pos // n_shards) % cap,
                         junk).astype(jnp.int32)

        # ---- stage 4 request: slot ‖ tag ‖ payload in ONE all_to_all ----
        tag = jnp.where(matched & is_enq, TAG_PUT,
                        jnp.where(matched & ~is_enq, TAG_GET, TAG_INACTIVE))
        cols = jnp.concatenate(
            [slot[:, None], tag.astype(jnp.int32)[:, None], payload], axis=1)
        fill = jnp.concatenate(
            [jnp.full((2,), junk, jnp.int32).at[1].set(TAG_INACTIVE),
             jnp.zeros((W,), jnp.int32)])
        send = _build_send_packed(owner, cols, matched, n_shards, fill)
        recv = lax.all_to_all(send, axis, 0, 0, tiled=True)  # [n, L, 2+W]
        r_slot, r_tag, r_vals = recv[..., 0], recv[..., 1], recv[..., 2:]

        # ---- apply PUTs before GETs (same-wave ENQ visible to DEQ) ----
        sv = state.store_vals[0]
        sf = state.store_full[0]
        put_slot = jnp.where(r_tag == TAG_PUT, r_slot, junk).reshape(-1)
        sv = sv.at[put_slot].set(r_vals.reshape(-1, W))     # junk row eats
        sf = sf.at[put_slot].set(True)
        sf = sf.at[junk].set(False)

        # ---- serve GETs and build the packed reply ----
        is_get = r_tag == TAG_GET
        get_slot = jnp.where(is_get, r_slot, junk)          # [n, L]
        res_vals = sv[get_slot]
        res_ok = is_get & sf[get_slot] & (get_slot < junk)
        sf = sf.at[get_slot.reshape(-1)].set(False)         # remove on read
        sf = sf.at[junk].set(False)
        reply = jnp.concatenate(
            [res_ok.astype(jnp.int32)[..., None], res_vals], axis=-1)
        back = lax.all_to_all(reply, axis, 0, 0, tiled=True)

        j = jnp.arange(L)
        own_row = jnp.clip(owner, 0, n_shards - 1)
        want_get = matched & (~is_enq)
        deq_vals = jnp.where(want_get[:, None],
                             back[own_row, j, 1:], jnp.int32(0))
        deq_ok = want_get & (back[own_row, j, 0] > 0)

        # capacity must hold at the post-enqueue peak (PUTs apply before
        # GETs): a same-wave dequeue shrinking the size back under cap
        # does NOT undo the head slot its enqueue already overwrote
        overflow = ((new_lasts - state.firsts + 1) > n_shards * cap).any()
        new_state = PriorityQueueState(new_firsts, new_lasts, sv[None],
                                       sf[None])
        return (new_state, tier, pos, matched, deq_vals, deq_ok, overflow,
                n_relaxed)

    # ------------------------------------------------------------ step -----
    def _build_step(self):
        specs = self._state_specs
        wrapped = shard_map(
            self._wave, mesh=self.mesh,
            in_specs=(specs, P(self.axis), P(self.axis), P(self.axis),
                      P(self.axis)),
            out_specs=(specs, P(self.axis), P(self.axis), P(self.axis),
                       P(self.axis), P(self.axis), P(), P()))
        return jax.jit(wrapped, donate_argnums=(0,))

    def step(self, state: PriorityQueueState, is_enq, valid, prio, payload):
        """Process one global wave.  The state argument is DONATED.

        is_enq/valid: [n_shards * L] bool; prio: [n_shards * L] int32 in
        [0, n_prios) (ignored for dequeues); payload: [n_shards * L, W].
        Returns (new_state, tier, pos, matched, deq_vals, deq_ok, overflow,
        n_relaxed) — tier/pos are -1/⊥ for unmatched ops.
        """
        return self._step(state, is_enq, valid, prio, payload)

    # ------------------------------------------------------- multi-wave ----
    def _build_run_waves(self):
        specs = self._state_specs

        def multi(state, is_enq, valid, prio, payload):
            def wave(st, xs):
                e, v, pr, pw = xs
                st2, *out = self._wave(st, e, v, pr, pw)
                return st2, tuple(out)
            st, outs = lax.scan(wave, state, (is_enq, valid, prio, payload))
            return (st,) + outs

        wrapped = shard_map(
            multi, mesh=self.mesh,
            in_specs=(specs, P(None, self.axis), P(None, self.axis),
                      P(None, self.axis), P(None, self.axis)),
            out_specs=(specs, P(None, self.axis), P(None, self.axis),
                       P(None, self.axis), P(None, self.axis),
                       P(None, self.axis), P(None), P(None)))
        return jax.jit(wrapped, donate_argnums=(0,))

    def run_waves(self, state: PriorityQueueState, is_enq, valid, prio,
                  payload):
        """K pre-staged waves in ONE lax.scan dispatch (state DONATED).

        Shapes: is_enq/valid/prio [K, n_shards * L]; payload [K, ..., W].
        """
        return self._run_waves(state, is_enq, valid, prio, payload)


class ElasticDevicePriorityQueue(_ElasticBase):
    """P-tier priority queue whose shard count is a runtime variable.

    Owns its state like :class:`~.elastic.ElasticDeviceQueue`; ``grow`` /
    ``shrink`` / ``resize`` re-materialize every tier window onto the new
    mesh with one packed migration all_to_all (the PR 2 wave, vectorized
    over the P tier windows), and checkpoint manifests record the per-tier
    layout so cold starts can reshard."""

    _kind = "pqueue"
    _pad_fill = (0, False)
    _sharded_keys = frozenset({"store_vals", "store_full"})

    def __init__(self, n_shards: int, *, n_prios: int = 2,
                 relaxation: int = 0, axis_name: str = "data",
                 cap: int = 1024, payload_width: int = 4,
                 ops_per_shard: int = 64, devices=None,
                 hlo_stats: bool = False):
        self.n_prios = n_prios
        self.relaxation = relaxation
        super().__init__(n_shards, axis_name=axis_name, cap=cap,
                         payload_width=payload_width,
                         ops_per_shard=ops_per_shard, devices=devices,
                         hlo_stats=hlo_stats)

    def _make_inner(self, mesh):
        return DevicePriorityQueue(mesh, self.axis, n_prios=self.n_prios,
                                   cap=self.cap, payload_width=self.W,
                                   ops_per_shard=self.L,
                                   relaxation=self.relaxation)

    # ------------------------------------------------------------ waves ----
    def step(self, is_enq, valid, prio, payload):
        """One wave on the current mesh; state is threaded internally.
        Returns (tier, pos, matched, deq_vals, deq_ok, overflow,
        n_relaxed)."""
        self.state, *out = self.inner.step(
            self.state, jnp.asarray(is_enq), jnp.asarray(valid),
            jnp.asarray(prio), jnp.asarray(payload))
        return tuple(out)

    def run_waves(self, is_enq, valid, prio, payload):
        """K pre-staged waves in one dispatch (shapes [K, n_shards * L])."""
        self.state, *out = self.inner.run_waves(
            self.state, jnp.asarray(is_enq), jnp.asarray(valid),
            jnp.asarray(prio), jnp.asarray(payload))
        return tuple(out)

    @property
    def sizes(self) -> list:
        f = np.asarray(self.state.firsts)
        l = np.asarray(self.state.lasts)
        return [int(x) for x in (l - f + 1)]

    @property
    def size(self) -> int:
        return sum(self.sizes)

    # -------------------------------------------------------- migration ----
    def _unpack(self, state):
        return state.firsts, state.lasts, state.store_vals, state.store_full

    def _pack(self, a, b, X, Y):
        return PriorityQueueState(a, b, X, Y)

    def _live_span(self) -> int:
        # capacity check is per tier (each tier owns its own slot window)
        return max([0] + [l - f + 1
                          for f, l in zip(np.asarray(self.state.firsts),
                                          np.asarray(self.state.lasts))])

    def _hash_balance(self, P_new: int):
        """Combined consistent-hashing fidelity report over every tier's
        live window (positions from different tiers hash independently)."""
        f = np.asarray(self.state.firsts)
        l = np.asarray(self.state.lasts)
        pos = np.concatenate([np.arange(lo, hi + 1)
                              for lo, hi in zip(f, l)] or [np.zeros(0)])
        if pos.size == 0 or pos.size > HASH_BALANCE_MAX_SIZE:
            return None
        from ..kernels.hash_route import hash_route_ref
        _, counts = hash_route_ref(jnp.asarray(pos, jnp.int32),
                                   jnp.ones((pos.size,), bool), P_new)
        counts = np.asarray(counts)
        return {"n": int(pos.size), "max": int(counts.max()),
                "min": int(counts.min()),
                "roundrobin_max": -(-int(pos.size) // P_new)}

    @property
    def _entry_bytes(self) -> int:
        return 4 * (1 + self.W)  # slot ‖ payload columns

    def _layout(self) -> dict:
        return {**super()._layout(), "P": self.n_prios,
                "relaxation": self.relaxation}

    @classmethod
    def _layout_kwargs(cls, lay: dict) -> dict:
        return {**super()._layout_kwargs(lay), "n_prios": lay["P"],
                "relaxation": lay.get("relaxation", 0)}

    def _state_dict(self) -> dict:
        return {"firsts": self.state.firsts, "lasts": self.state.lasts,
                "store_vals": self.state.store_vals,
                "store_full": self.state.store_full}

    def _from_state_dict(self, d: dict):
        return PriorityQueueState(d["firsts"], d["lasts"], d["store_vals"],
                                  d["store_full"])

    def _build_migration(self, mesh, P_old: int, P_new: int):
        axis, cap, W, P_ = self.axis, self.cap, self.W, self.n_prios
        n_mesh = mesh.shape[axis]
        M = min(P_ * cap, P_ * _fanout_bound(P_old, P_new, cap))

        def body(firsts, lasts, sv, sf):
            s = lax.axis_index(axis).astype(jnp.int32)
            u = jnp.arange(P_ * cap, dtype=jnp.int32)
            tier = u // cap
            t = u % cap
            fp = firsts[tier]
            # recover the tier-local position each occupied slot holds
            # (unique in the tier's live window; PR 2 invariant per tier)
            j_lo = -((s - fp) // P_old)
            j = j_lo + jnp.mod(t - j_lo, cap)
            p = s + P_old * j
            live = sf[0, :P_ * cap] & (p >= fp) & (p <= lasts[tier])
            owner = jnp.mod(p, P_new).astype(jnp.int32)
            slot_new = (tier * cap + jnp.mod(p // P_new, cap)).astype(
                jnp.int32)
            rank = _dest_rank(owner, live, n_mesh)
            lost = lax.pmax(
                (live & (rank >= M)).any().astype(jnp.int32), axis) > 0
            # ---- packed request: new_slot ‖ payload, one all_to_all ----
            cols = jnp.concatenate([slot_new[:, None], sv[0, :P_ * cap]],
                                   axis=1)
            junk = P_ * cap
            fill = jnp.zeros((1 + W,), jnp.int32).at[0].set(junk)
            buf = jnp.zeros((n_mesh, M + 1, 1 + W), jnp.int32)
            buf = buf.at[:, :, 0].set(junk)
            d_i = jnp.where(live, owner, 0)
            r_i = jnp.where(live, jnp.minimum(rank, M), M)
            buf = buf.at[d_i, r_i].set(
                jnp.where(live[:, None], cols, fill[None, :]))
            recv = lax.all_to_all(buf[:, :M], axis, 0, 0, tiled=True)
            # ---- rewrite the local store under the NEW layout ----
            rs = recv[..., 0].reshape(-1)
            rv = recv[..., 1:].reshape(-1, W)
            nsv = jnp.zeros((junk + 1, W), jnp.int32).at[rs].set(rv)
            nsv = nsv.at[junk].set(0)
            nsf = jnp.zeros((junk + 1,), bool).at[rs].set(True)
            nsf = nsf.at[junk].set(False)
            moved = lax.psum(jnp.sum(live.astype(jnp.int32)), axis)
            return firsts, lasts, nsv[None], nsf[None], moved, lost

        specs = (P(), P(), P(axis), P(axis))
        wrapped = shard_map(body, mesh=mesh, in_specs=specs,
                            out_specs=specs + (P(), P()))
        return jax.jit(wrapped, donate_argnums=(2, 3))
