"""Work-stealing queue with lease-based straggler mitigation.

The paper's motivating application (Sec. I): FIFO work stealing.  Work items
enter the distributed queue; workers dequeue in sequentially-consistent FIFO
order.  For fault tolerance at fleet scale:

  * every dequeue is a *lease* — the item is re-enqueued if not acknowledged
    within ``lease_steps`` (handles dead or straggling workers);
  * duplicate completions are idempotent (first ack wins), which makes
    speculative "backup" execution of leased-but-slow items safe — the
    standard straggler-mitigation trick.

Runs host-side around a :class:`DeviceQueue` so the item payloads live
sharded on device, and the global FIFO order is the queue's order ≺.

Scheduling rides the multi-wave API (PR 1): :meth:`run_waves` stages a burst
of K scheduling steps as ``[K, n]`` op batches and executes them in ONE
``DeviceQueue.run_waves`` dispatch — no host round-trip between waves.
Since PR 4 that dispatch is the unified :class:`~.wave_engine.WaveEngine`
driver, software-pipelined by default (construct the backing queue with
``pipelined=False`` for the sequential burst schedule; grants are
identical either way, so the lease bookkeeping below is schedule-blind).
Leases held at burst start have fully predictable expiry times, so their
retries are pre-staged into exactly the wave where a per-step loop would
have re-enqueued them; leases *granted inside* the burst cannot be observed
until it returns, so they are re-checked at the next burst boundary.  A
lease granted at wave j expires only after ``lease_steps`` further steps,
so for bursts of ``K <= lease_steps + 1`` waves the burst schedule is
*exactly* the per-step schedule.  :meth:`run_waves` ENFORCES that bound by
chunking longer horizons into consecutive sub-bursts of at most
``lease_steps + 1`` waves (each chunk boundary re-checks leases, so the
chunked schedule equals the per-step schedule for any K).  :meth:`step` is
the K=1 special case and matches the seed per-step behavior bit for bit.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from ..obs.recorder import FlightRecorder
from ..obs.trace import span
from .device_queue import DeviceQueue
from .errors import QueueOverflowError


@dataclass
class _Lease:
    item: np.ndarray
    issued_step: int
    worker: int


class WorkQueue:
    """Lease-based work-stealing scheduler over a :class:`DeviceQueue`.

    Args:
      dq: the backing device queue (item payloads live sharded on it).
      lease_steps: steps before an unacknowledged dequeue is reissued.
      flight_k: flight-recorder depth for the telemetry trajectory.

    Raises:
      QueueOverflowError: on oversized submit batches ("work") or when
        the backing device queue overflows ("workqueue").
    """

    def __init__(self, dq: DeviceQueue, lease_steps: int = 8,
                 flight_k: int = 16):
        self.dq = dq
        self.state = dq.init_state()
        self.lease_steps = lease_steps
        self.step_no = 0
        self.leases: Dict[int, _Lease] = {}   # element-id -> lease
        self.completed: set = set()
        self.stats = {"reissued": 0, "duplicate_acks": 0, "items_done": 0}
        self._next_eid = 0
        self.recorder = FlightRecorder(flight_k)

    def _drain_telemetry(self) -> None:
        """Burst-boundary Wavescope drain (no-op unless the backing
        DeviceQueue was built with ``metrics=True``)."""
        eng = getattr(self.dq, "engine", None)
        if eng is not None and eng.metrics:
            self.recorder.extend(eng.drain_metrics(reset=True))

    def trajectory(self) -> list:
        """Flight-recorder trajectory (last K wave summaries)."""
        return self.recorder.trajectory()

    # -- one synchronous scheduling step ------------------------------------
    def step(self, submit: List[np.ndarray], want: List[int]
             ) -> List[Tuple[int, np.ndarray]]:
        """Submit new items and serve dequeue requests of `want[w]` items per
        worker.  Returns (worker, payload) grants.  Expired leases are
        re-enqueued ahead of new submissions (FIFO fairness for retries)."""
        return self.run_waves([submit], [want])[0]

    # -- a burst of K scheduling steps in one device dispatch ---------------
    def run_waves(self, submits: List[List[np.ndarray]],
                  wants: List[List[int]]
                  ) -> List[List[Tuple[int, np.ndarray]]]:
        """Execute ``K = len(submits)`` scheduling steps as one multi-wave
        queue dispatch.  ``submits[k]`` are the items entering at wave k and
        ``wants[k][w]`` the dequeue count for worker w at wave k.  Returns
        per-wave grant lists.  A pre-burst lease whose expiry falls at wave
        k is re-enqueued ahead of wave k's submissions, exactly as the
        per-step loop would have.

        Bursts longer than the lease horizon (``K > lease_steps + 1``) are
        chunked into consecutive sub-bursts: a lease granted inside a burst
        can only be observed at a burst boundary, so an unchunked oversized
        burst would silently defer its expiry retries.  Chunk boundaries
        re-check leases, making the chunked schedule identical to the
        per-step schedule for any K."""
        K = len(submits)
        if K != len(wants) or K < 1:
            raise ValueError(
                f"run_waves needs aligned non-empty burst lists: "
                f"{K} submit waves vs {len(wants)} want waves")
        H = self.lease_steps + 1
        if K > H:
            out: List[List[Tuple[int, np.ndarray]]] = []
            for i in range(0, K, H):
                out.extend(self.run_waves(submits[i:i + H], wants[i:i + H]))
            return out
        first_step = self.step_no + 1

        n = self.dq.n_shards * self.dq.L
        W = self.dq.W
        is_enq = np.zeros((K, n), bool)
        valid = np.zeros((K, n), bool)
        payload = np.zeros((K, n, W), np.int32)
        wave_meta: List[Tuple[int, List[int]]] = []
        for k in range(K):
            # pre-burst leases expiring at step first_step + k retry HERE
            step_k = first_step + k
            expired = [l for eid, l in self.leases.items()
                       if step_k - l.issued_step > self.lease_steps
                       and eid not in self.completed]
            retry_payloads = []
            for l in expired:
                self.stats["reissued"] += 1
                retry_payloads.append(l.item)
                self.leases.pop(int(l.item[0]), None)
            enq_items = retry_payloads + list(submits[k])
            n_deq = int(sum(wants[k]))
            if len(enq_items) + n_deq > n:
                raise QueueOverflowError(
                    "work", n, [len(enq_items) + n_deq], wave=k,
                    detail="batch larger than queue wave: shrink the "
                           "wave's submits/wants or raise ops_per_shard")
            for i, item in enumerate(enq_items):
                is_enq[k, i] = valid[k, i] = True
                payload[k, i, : len(item)] = item
            for t in range(n_deq):
                valid[k, len(enq_items) + t] = True
            wave_meta.append((len(enq_items), list(wants[k])))

        self.step_no += K
        with span("workqueue:burst", cat="wave", K=K,
                  leases=len(self.leases)):
            self.state, pos, matched, deq_vals, deq_ok, overflow = \
                self.dq.run_waves(self.state, jnp.array(is_enq),
                                  jnp.array(valid), jnp.array(payload))
        self._drain_telemetry()
        o = np.asarray(overflow)
        if bool(o.any()):
            size = (int(np.asarray(self.state.last))
                    - int(np.asarray(self.state.first)) + 1)
            raise QueueOverflowError(
                "workqueue", self.dq.n_shards * self.dq.cap, [size],
                wave=int(np.flatnonzero(o)[0]) if o.ndim >= 1 else None,
                detail=f"{len(self.leases)} leases outstanding, "
                       f"{self.stats['items_done']} items done",
                trajectory=self.recorder.trajectory())
        deq_vals = np.asarray(deq_vals)
        deq_ok = np.asarray(deq_ok)
        all_grants: List[List[Tuple[int, np.ndarray]]] = []
        for k, (n_enq, want) in enumerate(wave_meta):
            grants: List[Tuple[int, np.ndarray]] = []
            workers = [w for w, c in enumerate(want) for _ in range(c)]
            for t, w in enumerate(workers):
                i = n_enq + t
                if deq_ok[k, i]:
                    item = deq_vals[k, i]
                    eid = int(item[0])
                    self.leases[eid] = _Lease(item=item,
                                              issued_step=first_step + k,
                                              worker=w)
                    grants.append((w, item))
            all_grants.append(grants)
        return all_grants

    def make_item(self, data: List[int]) -> np.ndarray:
        """Items carry a unique id in word 0 (dedup across re-issues)."""
        eid = self._next_eid
        self._next_eid += 1
        item = np.zeros(self.dq.W, np.int32)
        item[0] = eid
        item[1: 1 + len(data)] = data
        return item

    def ack(self, item: np.ndarray) -> bool:
        """Worker completion. Returns True if this ack won (first)."""
        eid = int(item[0])
        if eid in self.completed:
            self.stats["duplicate_acks"] += 1
            return False
        self.completed.add(eid)
        self.leases.pop(eid, None)
        self.stats["items_done"] += 1
        return True

    @property
    def outstanding(self) -> int:
        """Leased-but-unacknowledged item count."""
        return len(self.leases)
