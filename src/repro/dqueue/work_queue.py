"""Work-stealing queue with lease-based straggler mitigation.

The paper's motivating application (Sec. I): FIFO work stealing.  Work items
enter the distributed queue; workers dequeue in sequentially-consistent FIFO
order.  For fault tolerance at fleet scale:

  * every dequeue is a *lease* — the item is re-enqueued if not acknowledged
    within ``lease_steps`` (handles dead or straggling workers);
  * duplicate completions are idempotent (first ack wins), which makes
    speculative "backup" execution of leased-but-slow items safe — the
    standard straggler-mitigation trick.

Runs host-side around a :class:`DeviceQueue` so the item payloads live
sharded on device, and the global FIFO order is the queue's order ≺.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from .device_queue import DeviceQueue


@dataclass
class _Lease:
    item: np.ndarray
    issued_step: int
    worker: int


class WorkQueue:
    def __init__(self, dq: DeviceQueue, lease_steps: int = 8):
        self.dq = dq
        self.state = dq.init_state()
        self.lease_steps = lease_steps
        self.step_no = 0
        self.leases: Dict[int, _Lease] = {}   # element-id -> lease
        self.completed: set = set()
        self.stats = {"reissued": 0, "duplicate_acks": 0, "items_done": 0}
        self._next_eid = 0

    # -- one synchronous scheduling step ------------------------------------
    def step(self, submit: List[np.ndarray], want: List[int]
             ) -> List[Tuple[int, np.ndarray]]:
        """Submit new items and serve dequeue requests of `want[w]` items per
        worker.  Returns (worker, payload) grants.  Expired leases are
        re-enqueued ahead of new submissions (FIFO fairness for retries)."""
        self.step_no += 1
        expired = [l for eid, l in self.leases.items()
                   if self.step_no - l.issued_step > self.lease_steps
                   and eid not in self.completed]
        for l in expired:
            self.stats["reissued"] += 1
        retry_payloads = [l.item for l in expired]
        for l in expired:
            eid = int(l.item[0])
            self.leases.pop(eid, None)

        n = self.dq.n_shards * self.dq.L
        W = self.dq.W
        enq_items = retry_payloads + list(submit)
        n_deq = int(sum(want))
        assert len(enq_items) + n_deq <= n, "batch larger than queue step"
        is_enq = np.zeros(n, bool)
        valid = np.zeros(n, bool)
        payload = np.zeros((n, W), np.int32)
        for i, item in enumerate(enq_items):
            is_enq[i] = True
            valid[i] = True
            payload[i, : len(item)] = item
        for k in range(n_deq):
            valid[len(enq_items) + k] = True
        self.state, pos, matched, deq_vals, deq_ok, overflow = self.dq.step(
            self.state, is_enq, valid, payload)
        assert not bool(overflow), "work queue overflow"
        deq_vals = np.asarray(deq_vals)
        deq_ok = np.asarray(deq_ok)
        grants: List[Tuple[int, np.ndarray]] = []
        workers = [w for w, k in enumerate(want) for _ in range(k)]
        for k in range(n_deq):
            i = len(enq_items) + k
            if deq_ok[i]:
                item = deq_vals[i]
                eid = int(item[0])
                self.leases[eid] = _Lease(item=item,
                                          issued_step=self.step_no,
                                          worker=workers[k])
                grants.append((workers[k], item))
        return grants

    def make_item(self, data: List[int]) -> np.ndarray:
        """Items carry a unique id in word 0 (dedup across re-issues)."""
        eid = self._next_eid
        self._next_eid += 1
        item = np.zeros(self.dq.W, np.int32)
        item[0] = eid
        item[1: 1 + len(data)] = data
        return item

    def ack(self, item: np.ndarray) -> bool:
        """Worker completion. Returns True if this ack won (first)."""
        eid = int(item[0])
        if eid in self.completed:
            self.stats["duplicate_acks"] += 1
            return False
        self.completed.add(eid)
        self.leases.pop(eid, None)
        self.stats["items_done"] += 1
        return True

    @property
    def outstanding(self) -> int:
        return len(self.leases)
