"""Logical-axis -> mesh-axis rules (DP / FSDP / TP / EP / SP).

Parameters and activations carry *logical* axis names (("embed", "ff"),
("batch", None, "embed"), ...).  ``AxisRules`` maps them onto the production
mesh.  The default rules implement:

  batch   -> ("pod", "data")     data parallelism (hierarchical across pods)
  embed   -> ("data",)           FSDP / ZeRO-3 weight sharding
  heads/kv/ff/vocab/ssm_inner/expert -> ("model",)   tensor/expert parallel
  kv_seq  -> ("model",)          decode KV-cache sequence (flash-decoding
                                 split-K) — used by the optimized specs
  expert_rep -> None             TP-MoE (experts replicated, d_ff sharded)

``constraint(x, names)`` applies ``lax.with_sharding_constraint`` when a
mesh is active (set via ``set_rules``) and is a no-op otherwise, so model
code stays pure and runs unsharded on CPU tests.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


@dataclass(frozen=True)
class AxisRules:
    rules: Tuple[Tuple[str, Tuple[str, ...]], ...] = (
        ("batch", ("pod", "data")),
        ("embed", ("data",)),
        ("heads", ("model",)),
        ("kv", ("model",)),
        ("kv_hd", ("model",)),  # decode-cache head_dim: the split-D fallback
        #                         when kv_heads doesn't divide the model axis
        ("ff", ("model",)),
        ("vocab", ("model",)),
        ("expert", ("model",)),
        ("expert_rep", ()),
        ("ssm_inner", ("model",)),
        ("kv_seq", ()),  # flash-decoding split-K: opt-in via override
        ("layer", ()),
        # Megatron-style sequence parallelism: the residual stream between
        # blocks (and therefore the remat stack the layer scan saves for
        # backward) shards its seq dim over "model"; GSPMD inserts the
        # all-gather at attention entry / reduce-scatter at block exit.
        ("seq", ("model",)),
    )

    def lookup(self, name: Optional[str], mesh_axes) -> Optional[Tuple[str, ...]]:
        if name is None:
            return None
        for k, axes in self.rules:
            if k == name:
                usable = tuple(a for a in axes if a in mesh_axes)
                return usable or None
        return None

    def override(self, **kw) -> "AxisRules":
        d = dict(self.rules)
        for k, v in kw.items():
            d[k] = tuple(v) if v else ()
        return AxisRules(rules=tuple(d.items()))


def logical_to_spec(logical: Sequence[Optional[str]], mesh: Mesh,
                    rules: Optional[AxisRules] = None,
                    shape: Optional[Sequence[int]] = None) -> P:
    """When ``shape`` is given, mappings whose axis product does not divide
    the dim are shrunk (drop axes left-to-right) or dropped — pjit input
    shardings require exact divisibility (e.g. vocab 50280 on a 16-way axis
    falls back to replicated; padding the table is the optimization, see
    EXPERIMENTS.md §Perf)."""
    rules = rules or AxisRules()
    names = set(mesh.axis_names)
    parts = []
    used = set()
    for i, ax in enumerate(logical):
        mapped = rules.lookup(ax, names)
        if mapped:
            # an axis may appear only once in a spec
            mapped = tuple(m for m in mapped if m not in used)
        if mapped and shape is not None:
            while mapped:
                prod = 1
                for m in mapped:
                    prod *= mesh.shape[m]
                if shape[i] % prod == 0:
                    break
                mapped = mapped[1:]  # drop the outermost axis and retry
            mapped = tuple(mapped)
        if mapped:
            used.update(mapped)
            parts.append(mapped if len(mapped) > 1 else mapped[0])
        else:
            parts.append(None)
    return P(*parts)


def shardings_for_tree(axes_tree, mesh: Mesh,
                       rules: Optional[AxisRules] = None,
                       shapes_tree=None):
    """Map a pytree of logical-axis tuples to NamedShardings.  Pass the
    matching params/ShapeDtypeStruct tree to enable divisibility fallback."""
    is_axes = lambda x: isinstance(x, tuple) and all(  # noqa: E731
        isinstance(e, (str, type(None))) for e in x)
    if shapes_tree is None:
        return jax.tree.map(
            lambda ax: NamedSharding(mesh, logical_to_spec(ax, mesh, rules)),
            axes_tree, is_leaf=is_axes)
    flat_a, treedef = jax.tree.flatten(axes_tree, is_leaf=is_axes)
    flat_s = jax.tree.leaves(shapes_tree)
    assert len(flat_a) == len(flat_s), "axes/shape trees must parallel"
    out = [NamedSharding(mesh, logical_to_spec(a, mesh, rules,
                                               shape=s.shape))
           for a, s in zip(flat_a, flat_s)]
    return jax.tree.unflatten(treedef, out)


def set_rules(mesh: Optional[Mesh], rules: Optional[AxisRules] = None):
    _state.mesh = mesh
    _state.rules = rules or AxisRules()


def current_rules():
    return (getattr(_state, "mesh", None), getattr(_state, "rules", None))


def constraint(x, logical: Sequence[Optional[str]]):
    """Sharding constraint by logical axes; no-op without an active mesh."""
    mesh = getattr(_state, "mesh", None)
    if mesh is None:
        return x
    rules = getattr(_state, "rules", None) or AxisRules()
    spec = logical_to_spec(logical, mesh, rules, shape=x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))
