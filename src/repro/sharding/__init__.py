from .specs import (AxisRules, constraint, current_rules, logical_to_spec,
                    set_rules, shardings_for_tree)

__all__ = ["AxisRules", "constraint", "current_rules", "logical_to_spec",
           "set_rules", "shardings_for_tree"]
