import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Exact per-cell cost model: XLA's cost_analysis counts while-loop bodies
ONCE, so the dry-run numbers undercount scanned layers/microbatches.  This
runner lowers a fully-UNROLLED variant at two reduced depths (L=2 and L=4 —
layers are identical, so cost is affine in L) and extrapolates:

    F(L) = F(L2) + (F(L4) - F(L2)) / (L4 - L2) * (L - L2)

Train cells are costed with num_microbatches=1 at the full global batch
(the accumulation loop is compute-identical).  Results land in
experiments/costing/ and are consumed by benchmarks/roofline.py.
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from ..models import build_model
from ..models.costing import costing_mode
from ..sharding import AxisRules, logical_to_spec, set_rules, shardings_for_tree
from ..train import adamw_init, make_train_step
from .hlo import collective_bytes
from .mesh import make_production_mesh

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "costing"


def _reduced_cfg(cfg, L):
    over = {"n_layers": L}
    if cfg.family == "hybrid":
        over["n_layers"] = L * cfg.attn_every  # whole segments
    if cfg.enc_layers:
        over["enc_layers"] = L
    return dataclasses.replace(cfg, **over), over.get("n_layers", L)


def _measure(cfg, shape, rules):
    mesh = make_production_mesh(multi_pod=False)
    set_rules(mesh, rules)
    model = build_model(cfg)
    seq, gb, kind = SHAPES[shape]
    params, p_axes = model.abstract_params()
    p_sh = shardings_for_tree(p_axes, mesh, rules, shapes_tree=params)
    in_specs = model.input_specs(shape)
    b_axes = model.batch_axes(shape)
    b_sh = {k: NamedSharding(mesh, logical_to_spec(
        b_axes[k], mesh, rules, shape=in_specs[k].shape)) for k in in_specs}
    with costing_mode():
        if kind == "train":
            step = make_train_step(model, num_microbatches=1)
            opt = jax.eval_shape(adamw_init, params)
            opt_sh = type(opt)(m=p_sh, v=p_sh, step=NamedSharding(mesh, P()))
            fn = jax.jit(step, in_shardings=(p_sh, opt_sh, b_sh),
                         out_shardings=(p_sh, opt_sh, None))
            comp = fn.lower(params, opt, in_specs).compile()
        elif kind == "prefill":
            def prefill(params, batch):
                if cfg.family == "encdec":
                    from ..models.encdec import decode as dfw, encode
                    enc = encode(params, cfg, batch["frames"], remat=False)
                    h, _ = dfw(params, cfg, batch["tokens"], enc, remat=False)
                else:
                    from ..models.transformer import forward
                    h, _, _ = forward(params, cfg, batch["tokens"],
                                      vision_embeds=batch.get("vision_embeds"),
                                      remat=False)
                w = (params["embed"].T if cfg.tie_embeddings
                     else params["unembed"]).astype(jnp.bfloat16)
                return (h[:, -1] @ w).astype(jnp.float32)
            fn = jax.jit(prefill, in_shardings=(p_sh, b_sh))
            comp = fn.lower(params, in_specs).compile()
        else:
            cache, c_axes = model.abstract_cache(gb, seq)
            c_sh = shardings_for_tree(c_axes, mesh, rules, shapes_tree=cache)
            extra = {k: v for k, v in in_specs.items() if k != "tokens"}
            extra_sh = {k: b_sh[k] for k in extra}

            def decode(params, cache, tokens, idx, extra):
                return model.decode_fn(params, cache, tokens, idx, **extra)
            fn = jax.jit(decode, in_shardings=(
                p_sh, c_sh, b_sh["tokens"], NamedSharding(mesh, P()),
                extra_sh))
            comp = fn.lower(params, cache, in_specs["tokens"],
                            jax.ShapeDtypeStruct((), jnp.int32),
                            extra).compile()
    ca = comp.cost_analysis()
    coll = collective_bytes(comp.as_text())
    return {"flops": ca.get("flops", 0.0),
            "bytes": ca.get("bytes accessed", 0.0),
            "coll": coll.get("total", 0)}


def cost_cell(arch, shape):
    cfg = get_config(arch)
    if not shape_applicable(cfg, shape):
        return {"skipped": True}
    rules = AxisRules()
    c2, l2 = _reduced_cfg(cfg, 2)
    c4, l4 = _reduced_cfg(cfg, 4)
    f2 = _measure(c2, shape, rules)
    f4 = _measure(c4, shape, rules)
    L = cfg.n_layers
    out = {"arch": arch, "shape": shape, "L2": l2, "L4": l4, "L": L}
    for k in ("flops", "bytes", "coll"):
        slope = (f4[k] - f2[k]) / (l4 - l2)
        out[k + "_per_layer"] = slope
        out[k + "_const"] = f2[k] - slope * l2
        out[k] = f2[k] + slope * (L - l2)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    for a in archs:
        for s in shapes:
            path = OUT_DIR / f"{a}__{s}.json"
            if path.exists() and not args.force:
                print(f"[cache] {a}/{s}")
                continue
            t0 = time.time()
            try:
                res = cost_cell(a, s)
                path.write_text(json.dumps(res, indent=1))
                if res.get("skipped"):
                    print(f"[skip ] {a}/{s}")
                else:
                    print(f"[ok   ] {a}/{s}: {res['flops']:.3g} flops/dev "
                          f"{res['coll']/2**20:.0f} MiB coll/dev "
                          f"({time.time()-t0:.0f}s)", flush=True)
            except Exception as e:  # noqa: BLE001
                print(f"[FAIL ] {a}/{s}: {e}")
                (OUT_DIR / f"{a}__{s}.FAILED.txt").write_text(
                    traceback.format_exc())


if __name__ == "__main__":
    main()
