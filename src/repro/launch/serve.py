"""Serving driver: continuous batching fed by the SKUEUE request queue.

  python -m repro.launch.serve --arch mamba2_130m --requests 12
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..launch.mesh import make_host_mesh
from ..models import build_model
from ..serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=6)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.key(0))
    mesh = make_host_mesh(n_data=len(jax.devices()))
    eng = ServeEngine(model, params, mesh, max_slots=args.slots, max_seq=32)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=list(rng.integers(0, cfg.vocab, 4)),
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    eng.submit(reqs[: len(reqs) // 2])
    for _ in range(3):
        eng.step()
    eng.submit(reqs[len(reqs) // 2:])
    ok = eng.run_until_drained()
    dt = time.time() - t0
    tok = sum(len(r.out) for r in reqs)
    print(f"served {eng.stats['served']}/{len(reqs)} requests, {tok} tokens "
          f"in {dt:.1f}s ({tok/dt:.1f} tok/s); drained={ok}")
    order = sorted(reqs, key=lambda r: r.start_step)
    fifo = all(order[i].enqueue_step <= order[i + 1].enqueue_step
               for i in range(len(order) - 1))
    print(f"queue FIFO admission order preserved: {fifo}")
    for r in reqs[:3]:
        print(f"  rid={r.rid} prompt={r.prompt} -> out={r.out}")


if __name__ == "__main__":
    main()
