"""Parse collective traffic out of compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` reports flops and bytes-accessed per device but
NOT collective bytes; we regex every collective op in ``compiled.as_text()``
and sum its output-shape bytes (per-device payload).  This feeds the third
roofline term (EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# matches e.g.  %ar = bf16[16,1024]{1,0} all-reduce(...)
#          or   %t = (f32[8,128], f32[8,128]) all-gather(...)
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind output bytes (per device), plus op counts."""
    out: Dict[str, int] = defaultdict(int)
    counts: Dict[str, int] = defaultdict(int)
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        # avoid double counting async start/done pairs: count "-start" ops and
        # plain ops; "-done" repeats the shape of its start
        window = hlo_text[m.start(): m.start() + 400]
        if f"{kind}-done(" in window.split("\n")[0]:
            continue
        out[kind] += _shape_bytes(shape_str)
        counts[kind] += 1
    result = dict(out)
    result["_counts"] = dict(counts)
    result["total"] = sum(v for k, v in out.items())
    return result
