import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we AOT-compile the real jitted step (train_step for train
shapes, prefill/decode for serving shapes) against ShapeDtypeStruct inputs
with full production shardings — no array is ever allocated.  The compiled
artifact yields:

  memory_analysis()   per-device bytes (proves the cell fits 16 GB HBM)
  cost_analysis()     per-device HLO flops + bytes accessed
  as_text()           post-SPMD HLO — collective bytes via launch.hlo

Results are cached as JSON under experiments/dryrun/ and consumed by
benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch llama3_8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from ..models import build_model
from ..sharding import AxisRules, logical_to_spec, set_rules, shardings_for_tree
from ..train import adamw_init, make_train_step
from .hlo import collective_bytes
from .mesh import make_production_mesh

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# microbatch count per (arch, shape): the activation-memory lever
MICROBATCH = {
    "default": {"train_4k": 8},
    "mamba2_130m": {"train_4k": 4},
    "whisper_small": {"train_4k": 4},
    "zamba2_1p2b": {"train_4k": 8},
    "granite_moe_1b": {"train_4k": 8},
    "mixtral_8x22b": {"train_4k": 16},
    "mistral_large_123b": {"train_4k": 16},
}


def _axes_leaf(x):
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None)))
                                        for e in x)


def cell_name(arch, shape, multi_pod, variant=""):
    pod = "pod2" if multi_pod else "pod1"
    v = f"_{variant}" if variant else ""
    return f"{arch}__{shape}__{pod}{v}"


def run_cell(arch: str, shape: str, multi_pod: bool = False,
             rules: AxisRules | None = None, variant: str = "",
             donate: bool = True, microbatch: int | None = None,
             cache_dtype=None, cfg_over: dict | None = None) -> dict:
    """variant / microbatch / cache_dtype / cfg_over support the §Perf
    hillclimb: lower the same cell under a changed configuration and diff
    the roofline terms."""
    cfg = get_config(arch)
    if cfg_over:
        import dataclasses
        cfg = dataclasses.replace(cfg, **cfg_over)
    if not shape_applicable(cfg, shape):
        return {"cell": cell_name(arch, shape, multi_pod, variant),
                "skipped": f"{arch} is not sub-quadratic; long_500k skipped "
                           "(DESIGN.md §5)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules or AxisRules()
    set_rules(mesh, rules)
    model = build_model(cfg)
    seq, gb, kind = SHAPES[shape]
    t0 = time.time()

    params, p_axes = model.abstract_params()
    p_sh = shardings_for_tree(p_axes, mesh, rules, shapes_tree=params)
    in_specs = model.input_specs(shape)
    b_axes = model.batch_axes(shape)
    b_sh = {k: NamedSharding(mesh, logical_to_spec(
        b_axes[k], mesh, rules, shape=in_specs[k].shape)) for k in in_specs}

    if kind == "train":
        mb = microbatch or MICROBATCH.get(
            arch, MICROBATCH["default"]).get(shape, 1)
        train_step = make_train_step(model, num_microbatches=mb)
        opt = jax.eval_shape(adamw_init, params)
        opt_sh = type(opt)(m=jax.tree.map(lambda s: s, p_sh),
                           v=jax.tree.map(lambda s: s, p_sh),
                           step=NamedSharding(mesh, P()))
        fn = jax.jit(train_step,
                     in_shardings=(p_sh, opt_sh, b_sh),
                     out_shardings=(p_sh, opt_sh, None),
                     donate_argnums=(0, 1) if donate else ())
        lowered = fn.lower(params, opt, in_specs)
    elif kind == "prefill":
        def prefill(params, batch):
            if cfg.family == "encdec":
                from ..models.encdec import decode as dec_fwd, encode
                enc = encode(params, cfg, batch["frames"], remat=False)
                h, _ = dec_fwd(params, cfg, batch["tokens"], enc, remat=False)
            else:
                from ..models.transformer import forward
                h, _, _ = forward(params, cfg, batch["tokens"],
                                  vision_embeds=batch.get("vision_embeds"),
                                  remat=False)
            w = (params["embed"].T if cfg.tie_embeddings
                 else params["unembed"]).astype(jnp.bfloat16)
            return (h[:, -1] @ w).astype(jnp.float32)
        fn = jax.jit(prefill, in_shardings=(p_sh, b_sh))
        lowered = fn.lower(params, in_specs)
    else:  # decode
        cache, c_axes = model.abstract_cache(
            gb, seq, dtype=cache_dtype or jnp.bfloat16)
        c_sh = shardings_for_tree(c_axes, mesh, rules, shapes_tree=cache)
        extra = {k: v for k, v in in_specs.items() if k != "tokens"}
        extra_sh = {k: b_sh[k] for k in extra}

        def decode(params, cache, tokens, idx, extra):
            return model.decode_fn(params, cache, tokens, idx, **extra)
        fn = jax.jit(decode,
                     in_shardings=(p_sh, c_sh, b_sh["tokens"],
                                   NamedSharding(mesh, P()), extra_sh),
                     donate_argnums=(1,) if donate else ())
        lowered = fn.lower(params, cache, in_specs["tokens"],
                           jax.ShapeDtypeStruct((), jnp.int32), extra)

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    widened = _bf16_widening_estimate(txt)
    n_chips = int(np.prod(list(mesh.shape.values())))
    result = {
        "cell": cell_name(arch, shape, multi_pod, variant),
        "arch": arch, "shape": shape, "kind": kind,
        "mesh": dict(mesh.shape), "chips": n_chips,
        "seq": seq, "global_batch": gb,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_per_device": (ma.argument_size_in_bytes
                                      + ma.output_size_in_bytes
                                      + ma.temp_size_in_bytes
                                      - ma.alias_size_in_bytes),
        },
        "cost": {"flops": ca.get("flops", 0.0),
                 "transcendentals": ca.get("transcendentals", 0.0),
                 "bytes_accessed": ca.get("bytes accessed", 0.0)},
        "collectives": coll,
    }
    # The CPU backend widens bf16 arithmetic to f32 and keeps f32 copies of
    # bf16 tensors across loop boundaries; a native-bf16 TPU backend would
    # not allocate those.  Report both raw and corrected peaks.
    result["memory"]["bf16_widening_bytes_est"] = widened
    result["memory"]["peak_bytes_tpu_corrected"] = max(
        0, result["memory"]["peak_bytes_per_device"] - widened)
    result["fits_hbm16"] = bool(
        result["memory"]["peak_bytes_per_device"] < 16e9)
    result["fits_hbm16_tpu_corrected"] = bool(
        result["memory"]["peak_bytes_tpu_corrected"] < 16e9)
    return result


def _bf16_widening_estimate(txt: str) -> int:
    """Bytes of f32 buffers that pair a same-shape bf16 buffer (the CPU
    backend's widening artifact).  Conservative: counts each dims-set once."""
    import re
    bf16 = set(re.findall(r"bf16\[([0-9,]+)\]", txt))
    f32 = set(re.findall(r"f32\[([0-9,]+)\]", txt))
    total = 0
    for dims in bf16 & f32:
        n = 1
        for d in dims.split(","):
            n *= int(d)
        if n * 4 > 64 * 2**20:  # only large buffers matter for the peak
            total += n * 4
    return total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-donate", action="store_true")
    args = ap.parse_args()

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for mp in meshes:
        for a in archs:
            for s in shapes:
                cells.append((a, s, mp))

    n_ok = n_skip = n_fail = 0
    for a, s, mp in cells:
        name = cell_name(a, s, mp)
        path = OUT_DIR / f"{name}.json"
        if path.exists() and not args.force:
            print(f"[cache] {name}")
            n_ok += 1
            continue
        print(f"[run  ] {name} ...", flush=True)
        try:
            res = run_cell(a, s, multi_pod=mp, donate=not args.no_donate)
            path.write_text(json.dumps(res, indent=1))
            if "skipped" in res:
                print(f"[skip ] {name}: {res['skipped']}")
                n_skip += 1
            else:
                mem = res["memory"]["peak_bytes_per_device"] / 2**30
                fl = res["cost"]["flops"]
                print(f"[ok   ] {name}: peak {mem:.2f} GiB/dev, "
                      f"{fl:.3g} flops/dev, "
                      f"coll {res['collectives'].get('total', 0)/2**20:.1f} "
                      f"MiB/dev, compile {res['compile_s']:.0f}s "
                      f"fits={res['fits_hbm16']}")
                n_ok += 1
        except Exception as e:  # noqa: BLE001 — report, continue sweep
            n_fail += 1
            print(f"[FAIL ] {name}: {e}")
            (OUT_DIR / f"{name}.FAILED.txt").write_text(
                traceback.format_exc())
    print(f"\ndry-run summary: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
