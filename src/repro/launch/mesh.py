"""Production mesh definitions.

A function, not a module-level constant: importing this module never touches
jax device state (device count is locked on first jax init, and the 512
placeholder devices are only forced by launch/dryrun.py)."""
from __future__ import annotations

import jax

from ..compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips single pod; (2,16,16) = 512 chips for two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over whatever devices exist (tests/examples)."""
    n = len(jax.devices())
    n_data = min(n_data, n)
    n_model = max(1, min(n_model, n // n_data))
    return make_mesh((n_data, n_model), ("data", "model"))


def make_elastic_mesh(n_shards: int, axis_name: str = "data", devices=None,
                      exclude=()):
    """One-axis mesh over an explicit device subset.

    The elastic JOIN/LEAVE path (``dqueue.elastic``) re-materializes queue
    state across meshes of *different* sizes, so unlike ``jax.make_mesh``
    this helper must be able to build a mesh over fewer devices than the
    process owns — and over a caller-chosen subset, so a LEAVE can exclude
    the precise device that failed.

    ``exclude`` (device objects or bare device ids) is dropped *before*
    the ``n_shards`` prefix is taken, so callers no longer have to
    pre-filter the pool to dodge a failed device; when the exclusion
    makes ``n_shards`` unsatisfiable the error names the offending
    device ids instead of a bare count mismatch.

    Since PR 10 the implementation lives in :mod:`repro.runtime` (the
    subset logic in ``select_devices``, construction in ``build_mesh``);
    this wrapper survives for callers outside the runtime-managed wave
    stack."""
    from ..runtime import build_mesh, select_devices

    devs = list(devices) if devices is not None else list(jax.devices())
    return build_mesh(select_devices(devs, n_shards, exclude), axis_name)
