"""Training driver: end-to-end loop with checkpoint/restart and the
queue-ordered data pipeline.

Full-scale use lowers the same train_step the dry-run compiles; on this CPU
container run reduced configs, e.g.:

  python -m repro.launch.train --arch llama3_8b --reduced --steps 50
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..data import GlobalOrderPipeline
from ..fault import FailureInjector, run_with_restarts
from ..models import build_model
from ..train import adamw_init, make_train_step


def train_loop(arch: str, *, reduced: bool = True, steps: int = 50,
               global_batch: int = 8, seq_len: int = 64,
               ckpt_dir=None, ckpt_every: int = 10,
               fail_at=(), log=print):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    pipe = GlobalOrderPipeline(seq_len, cfg.vocab, global_batch)
    train_step = jax.jit(make_train_step(model, num_microbatches=1,
                                         total_steps=steps))

    def init_state():
        params, _ = model.init_params(jax.random.key(0))
        return {"params": params, "opt": adamw_init(params)}

    losses = []

    def step_fn(state, step):
        batch = pipe.batch_at_step(step)
        batch = {k: jnp.asarray(v) for k, v in batch.items()
                 if k != "sample_indices"}
        if cfg.family == "encdec":
            rng = np.random.default_rng(step)
            batch["frames"] = jnp.asarray(
                rng.standard_normal((global_batch, cfg.enc_seq, cfg.d_model)),
                jnp.bfloat16)
        if cfg.family == "vlm":
            rng = np.random.default_rng(step)
            batch["vision_embeds"] = jnp.asarray(
                rng.standard_normal((global_batch, cfg.n_vision_tokens,
                                     cfg.d_model)), jnp.bfloat16)
        params, opt, metrics = train_step(state["params"], state["opt"],
                                          batch)
        loss = float(metrics["loss"])
        losses.append((step, loss))
        if step % 10 == 0:
            log(f"step {step:4d}  loss {loss:.4f}  "
                f"gnorm {float(metrics['grad_norm']):.3f}")
        return {"params": params, "opt": opt}

    if ckpt_dir is None:
        import tempfile
        ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    injector = FailureInjector(fail_at_steps=tuple(fail_at))
    state, metrics = run_with_restarts(
        init_state=init_state, step_fn=step_fn, n_steps=steps,
        ckpt_dir=ckpt_dir, ckpt_every=ckpt_every, injector=injector, log=log)
    return state, losses, metrics


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    t0 = time.time()
    _, losses, metrics = train_loop(
        args.arch, reduced=args.reduced, steps=args.steps,
        global_batch=args.global_batch, seq_len=args.seq_len,
        ckpt_dir=args.ckpt_dir)
    print(f"done in {time.time()-t0:.1f}s; "
          f"loss {losses[0][1]:.3f} -> {losses[-1][1]:.3f}; {metrics}")


if __name__ == "__main__":
    main()
