"""Sharded checkpointing with atomic commit and elastic restore.

Layout:  <dir>/step_<N>/manifest.json + one .npy per leaf (keypath-named).
Writes go to ``step_<N>.tmp`` and are renamed on completion — a crash
mid-save never corrupts the latest checkpoint (restart-safe).

``restore_sharded`` places each leaf with the shardings of the *current*
mesh, which may differ from the mesh that saved it — that is the elastic
JOIN/LEAVE path at the training level: consistent hashing moves the DHT's
keys, checkpoint-reshard moves the model's (DESIGN.md §6).  At fleet scale
each host writes its own shard files; on this single-host container the
full arrays are written once (the manifest format is host-count agnostic).
"""
from __future__ import annotations

import json
import os
import threading
from pathlib import Path
from typing import Optional

import jax
import numpy as np


def _key_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return "__".join(out) or "root"


def save_checkpoint(ckpt_dir, step: int, tree, meta: Optional[dict] = None,
                    blocking: bool = True):
    ckpt_dir = Path(ckpt_dir)
    tmp = ckpt_dir / f"step_{step}.tmp"
    final = ckpt_dir / f"step_{step}"
    if final.exists():
        return final
    tmp.mkdir(parents=True, exist_ok=True)
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"step": step, "meta": meta or {}, "leaves": []}
    host_arrays = []
    for path, leaf in leaves:
        name = _key_str(path)
        arr = np.asarray(jax.device_get(leaf))
        manifest["leaves"].append(
            {"key": name, "shape": list(arr.shape), "dtype": str(arr.dtype)})
        host_arrays.append((name, arr))

    def _write():
        for name, arr in host_arrays:
            # raw-byte storage: np.save cannot roundtrip ml_dtypes (bf16);
            # dtype/shape live in the manifest
            raw = np.ascontiguousarray(arr).view(np.uint8)
            np.save(tmp / f"{name}.npy", raw)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        os.replace(tmp, final)  # atomic commit

    if blocking:
        _write()
    else:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    return final


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")
             if not p.name.endswith(".tmp") and (p / "manifest.json").exists()]
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir, step: Optional[int], like_tree):
    """Load into the structure of ``like_tree`` (host numpy arrays)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    meta = {m["key"]: m for m in manifest["leaves"]}
    flat, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    out = []
    for path, leaf in flat:
        name = _key_str(path)
        raw = np.load(d / f"{name}.npy")
        info = meta[name]
        arr = raw.view(np.dtype(info["dtype"])).reshape(info["shape"])
        out.append(arr)
    return jax.tree.unflatten(treedef, out), manifest


def restore_sharded(ckpt_dir, step, like_tree, shardings):
    """Load + device_put with the current mesh's shardings — the elastic
    reshard path (works across different device counts / mesh shapes)."""
    host, manifest = load_checkpoint(ckpt_dir, step, like_tree)
    placed = jax.tree.map(
        lambda arr, sh: jax.device_put(arr, sh), host, shardings)
    return placed, manifest
