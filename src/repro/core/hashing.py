"""Deterministic pseudorandom hashing used across SKUEUE.

The paper assumes "a publicly known pseudorandom hash function" both for node
labels (LDB middle-node positions) and for the consistent-hashing DHT keys
``k(p)``.  We use splitmix64: cheap, stateless, vectorizable in numpy and in
JAX (uint32-pair variant for TPU, where uint64 is unavailable).
"""
from __future__ import annotations

import numpy as np

_GOLDEN = np.uint64(0x9E3779B97F4A7C15)
_M1 = np.uint64(0xBF58476D1CE4E5B9)
_M2 = np.uint64(0x94D049BB133111EB)
_MASK = np.uint64(0xFFFFFFFFFFFFFFFF)


def splitmix64(x) -> np.ndarray:
    """Vectorized splitmix64 finalizer. Accepts int or uint64 array."""
    z = (np.asarray(x, dtype=np.uint64) + _GOLDEN) & _MASK
    z = ((z ^ (z >> np.uint64(30))) * _M1) & _MASK
    z = ((z ^ (z >> np.uint64(27))) * _M2) & _MASK
    return z ^ (z >> np.uint64(31))


def hash01(x, salt: int = 0) -> np.ndarray:
    """Hash ints to floats uniform in [0, 1).  Deterministic."""
    with np.errstate(over="ignore"):
        z = splitmix64(np.asarray(x, dtype=np.uint64) ^ splitmix64(np.uint64(salt)))
    # 53-bit mantissa for an unbiased float64 in [0,1)
    return (z >> np.uint64(11)).astype(np.float64) * (1.0 / (1 << 53))


def position_key(pos, salt: int = 0xD47) -> np.ndarray:
    """DHT key k(p) in [0,1) for queue position p (paper Sec. II-B)."""
    return hash01(pos, salt=salt)
