"""TPU-native SKUEUE: the aggregation tree as an associative scan.

The paper's Stages 1-3 (aggregate batches up the tree, assign intervals at
the anchor, decompose down the tree) are a Blelloch exclusive prefix scan.
Queue-state evolution under a request sequence is associative in the
*min-plus (tropical) semiring*:

    a single request acts on anchor state (f, l) = (first, last) as
        ENQ:  f' = f,                 l' = l + 1
        DEQ:  f' = min(f + 1, l + 1), l' = l
    every composition stays in the 3-parameter family
        T(A,B,C):  f' = min(f + A, l + B),  l' = l + C
    with identity (0, +INF, 0) and composition
        T1 ; T2 = (A1+A2, min(B1+A2, C1+B2), C1+C2).          (associative)

Given the *exclusive* prefix state (f_i, l_i) of request i:
        ENQ  ->  position l_i + 1
        DEQ  ->  position f_i   if f_i <= l_i else ⊥

The stack variant (Sec. VI) is the max-plus analogue on (last, ticket):
        PUSH: l' = l + 1, t' = t + 1    POP: l' = max(l - 1, 0), t' = t
        family  l' = max(l + a, b);  composition (a1+a2, max(b1+a2, b2)).
        PUSH_i -> (pos l_i + 1, ticket t_i + 1)
        POP_i  -> (pos l_i, bound t_i)  if l_i >= 1 else ⊥

Consequences for TPU (DESIGN.md §2): the anchor is *virtual* (the carry is
replicated, no hot node), a batch of requests costs one O(log) scan instead
of O(log n) protocol rounds, and sequential consistency holds by
construction because the scan order IS the total order ≺.

Two distribution strategies are provided:
  * ``*_scan`` — ``jax.lax.associative_scan`` over the flat request array
    (GSPMD chooses the schedule; fine under pjit).
  * ``sharded_queue_scan`` — explicit shard_map: local scan per device +
    ``lax.ppermute`` hypercube scan over the device axis for the carries.
    This is the literal ICI analogue of the paper's O(log n) aggregation
    tree: ⌈log2 p⌉ permute rounds, constant bytes per round (Theorem 18's
    O(log n)-size batches collapse to an (A,B,C) carry).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..compat import axis_size, shard_map

INF = jnp.int32(2 ** 30)  # +infinity in the tropical semiring (no overflow:
#                           compositions add at most O(batch) to it once)
BOTTOM = jnp.int32(-1)


class QueueState(NamedTuple):
    """Replicated anchor state: occupied positions are [first, last]."""
    first: jax.Array  # int32 scalar
    last: jax.Array

    @staticmethod
    def empty() -> "QueueState":
        return QueueState(jnp.int32(0), jnp.int32(-1))

    @property
    def size(self) -> jax.Array:
        return self.last - self.first + 1


class StackState(NamedTuple):
    last: jax.Array    # top of stack; positions start at 1
    ticket: jax.Array  # monotone push counter

    @staticmethod
    def empty() -> "StackState":
        return StackState(jnp.int32(0), jnp.int32(0))


# ------------------------------------------------------------ queue scan ---
def queue_op_transforms(is_enq: jax.Array):
    """Per-request (A, B, C) transforms. is_enq: bool/int array."""
    e = is_enq.astype(jnp.int32)
    A = 1 - e                      # ENQ: 0, DEQ: 1
    B = jnp.where(e > 0, INF, 1)   # ENQ: inf, DEQ: 1
    C = e                          # ENQ: 1, DEQ: 0
    return A, B, C


def queue_compose(t1, t2):
    """(t1 then t2), elementwise; associative (used by associative_scan)."""
    A1, B1, C1 = t1
    A2, B2, C2 = t2
    return (A1 + A2,
            jnp.minimum(jnp.minimum(B1 + A2, C1 + B2), INF),
            C1 + C2)


def _exclusive(tr, fills=(0, INF, 0), axis=0):
    """Inclusive scan results -> exclusive (shift right, identity first)."""
    def shift(x, fill):
        pad = jnp.full_like(lax.slice_in_dim(x, 0, 1, axis=axis), fill)
        return lax.concatenate([pad, lax.slice_in_dim(x, 0, x.shape[axis] - 1,
                                                      axis=axis)], axis)
    return tuple(shift(x, f) for x, f in zip(tr, fills))


def queue_scan(is_enq: jax.Array, state: QueueState,
               valid: jax.Array | None = None
               ) -> Tuple[jax.Array, jax.Array, QueueState]:
    """Assign positions to a flat request batch (global order = array order).

    Args:
      is_enq: [n] bool — True for ENQUEUE, False for DEQUEUE.
      state:  incoming anchor state.
      valid:  [n] bool — padding mask (False entries are no-ops).
    Returns:
      positions [n] int32 (⊥ = -1 for unmatched dequeues; enqueue slots are
      the DHT positions to PUT into), matched mask, new state.
    """
    if valid is not None:
        # padded entries become identity transforms
        e = is_enq & valid
        tr = queue_op_transforms(e)
        A, B, C = tr
        A = jnp.where(valid, A, 0)
        B = jnp.where(valid, B, INF)
        C = jnp.where(valid, C, 0)
        tr = (A, B, C)
    else:
        tr = queue_op_transforms(is_enq)
    inc = lax.associative_scan(queue_compose, tr)
    Ax, Bx, Cx = _exclusive(inc)
    f_i = jnp.minimum(state.first + Ax, state.last + Bx)
    l_i = state.last + Cx
    pos = jnp.where(is_enq, l_i + 1, jnp.where(f_i <= l_i, f_i, BOTTOM))
    matched = pos != BOTTOM
    if valid is not None:
        pos = jnp.where(valid, pos, BOTTOM)
        matched = matched & valid
    # total transform = last element of the inclusive scan
    A_t, B_t, C_t = (x[-1] for x in inc)
    new = QueueState(jnp.minimum(state.first + A_t, state.last + B_t),
                     state.last + C_t)
    return pos, matched, new


# ------------------------------------------------------------ stack scan ---
def stack_op_transforms(is_push: jax.Array):
    p = is_push.astype(jnp.int32)
    a = 2 * p - 1                        # PUSH: +1, POP: -1
    b = jnp.where(p > 0, -INF, 0)        # POP clamps at 0
    dt = p                               # ticket increment
    return a, b, dt


def stack_compose(t1, t2):
    a1, b1, d1 = t1
    a2, b2, d2 = t2
    return (a1 + a2,
            jnp.maximum(jnp.maximum(b1 + a2, b2), -INF),
            d1 + d2)


def stack_scan(is_push: jax.Array, state: StackState,
               valid: jax.Array | None = None):
    """Returns (positions, tickets, matched, new_state).  For pushes the
    ticket is the element's unique ticket; for pops it is the bound t'."""
    tr = stack_op_transforms(is_push if valid is None else (is_push & valid))
    if valid is not None:
        a, b, d = tr
        a = jnp.where(valid, a, 0)
        b = jnp.where(valid, b, -INF)
        d = jnp.where(valid, d, 0)
        tr = (a, b, d)
    inc = lax.associative_scan(stack_compose, tr)
    a_x, b_x, d_x = _exclusive(inc, fills=(0, -INF, 0))
    l_i = jnp.maximum(state.last + a_x, b_x)
    t_i = state.ticket + d_x
    pos = jnp.where(is_push, l_i + 1, jnp.where(l_i >= 1, l_i, BOTTOM))
    tick = jnp.where(is_push, t_i + 1, t_i)
    matched = pos != BOTTOM
    if valid is not None:
        pos = jnp.where(valid, pos, BOTTOM)
        matched = matched & valid
    a_t, b_t, d_t = (x[-1] for x in inc)
    new = StackState(jnp.maximum(state.last + a_t, b_t), state.ticket + d_t)
    return pos, tick, matched, new


# -------------------------------------------------- priority-tier scan -----
def strict_batch_deletemin(deq: jax.Array, avail: jax.Array,
                           firsts: jax.Array, n_prios: int):
    """Skeap's strict batch-DeleteMin assignment as prefix arithmetic.

    The d-th dequeue of the wave (wave order) takes the d-th element of
    the priority-ordered pool: dequeue ranks index into the per-tier
    cumulative availability, no sequential loop.  Shared by
    :func:`priority_queue_scan` and the pallas path
    (``kernels.segscan.priority_queue_scan_pallas``).

    Args:
      deq: [n] bool — the wave's dequeue ops (global wave order);
      avail: [n_prios] int32 — per-tier sizes AFTER the wave's enqueues;
      firsts: [n_prios] int32 — per-tier head positions.
    Returns:
      (tier [n] int32 (clamped; gate with matched), pos [n] int32,
      matched [n] bool, taken [n_prios] int32 — heads consumed per tier).
    """
    d_in = deq.astype(jnp.int32)
    d_rank = jnp.cumsum(d_in) - d_in                # exclusive deq rank
    cum = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(avail)])
    t_d = (d_rank[:, None] >= cum[None, 1:]).sum(1).astype(jnp.int32)
    matched = deq & (t_d < n_prios)
    t_c = jnp.minimum(t_d, n_prios - 1)
    pos = firsts[t_c] + d_rank - cum[t_c]
    taken = jnp.clip(d_in.sum() - cum[:-1], 0, avail)
    return t_c, pos, matched, taken


def priority_queue_scan(is_enq: jax.Array, prio: jax.Array, valid: jax.Array,
                        firsts: jax.Array, lasts: jax.Array, *, n_prios: int,
                        relaxation: int = 0, shard_of: jax.Array | None = None,
                        n_shards: int | None = None, tier_scan=None):
    """Batch position assignment for the P-tier constant-priority queue
    (Skeap's constant-priority regime, arXiv:1805.03472).

    The queue is P independent SKUEUE position intervals, tie-broken by
    tier: each tier keeps its own dense ``[firsts[p], lasts[p]]`` window.
    One wave applies all enqueues before all dequeues (the PR 1 PUT-before-
    GET rule, lifted to tiers):

      * enqueues — per-tier FIFO positions via the min-plus transforms of
        :func:`queue_scan`, one masked scan per tier (P is a small static
        constant);
      * dequeues — resolved highest-priority-first *inside the wave*: the
        d-th dequeue (wave order) takes the d-th element of the priority-
        ordered pool, i.e. the wave's dequeue batch drains tier 0, then
        tier 1, ...  With ``relaxation=k`` a dequeue may instead take the
        head of a tier up to ``k`` below the currently-best non-empty tier
        when that lower head is *locally owned* (``head % n_shards ==
        shard_of[i]``) and the best tier's head is not — trading strict
        priority order (never per-tier FIFO, and never by more than k
        tiers) for a serve that avoids the cross-shard hop.

    Args:
      is_enq/valid: [n] bool (global wave order); prio: [n] int32 in
        [0, n_prios) (ignored for dequeues); firsts/lasts: [n_prios] int32.
      relaxation: static int k >= 0; 0 is the strict mode.
      shard_of/n_shards: issuing shard per op and shard count — required
        when relaxation > 0 (the locality rule needs owners).
      tier_scan: optional fused replacement for the per-tier enqueue
        loop, ``(enq, tier, firsts, lasts) -> (pos, new_lasts)`` —
        ``kernels.segscan.make_tier_scan`` provides the pallas sweep
        (PR 9); None keeps this jnp path, which remains the oracle.
    Returns:
      (tier [n] int32 (-1 unmatched), pos [n] int32 (⊥ = -1), matched [n]
      bool, new_firsts, new_lasts, n_relaxed) — ``n_relaxed`` counts the
      dequeues served from below the strictly-best tier (0 in strict mode).
    """
    P_ = n_prios
    enq = is_enq & valid
    deq = (~is_enq) & valid
    tier = jnp.full(is_enq.shape, -1, jnp.int32)
    pos = jnp.full(is_enq.shape, BOTTOM, jnp.int32)
    if tier_scan is not None:
        pos_e, new_lasts = tier_scan(enq, prio, firsts, lasts)
        tier = jnp.where(enq & (pos_e >= 0), prio.astype(jnp.int32), tier)
        pos = jnp.where(enq, pos_e, pos)
    else:
        new_lasts = []
        for p in range(P_):
            mask = enq & (prio == p)
            pos_p, _, st_p = queue_scan(
                mask, QueueState(firsts[p], lasts[p]), valid=mask)
            tier = jnp.where(mask, p, tier)
            pos = jnp.where(mask, pos_p, pos)
            new_lasts.append(st_p.last)
        new_lasts = jnp.stack(new_lasts)
    avail = new_lasts - firsts + 1                      # sizes after enqueues

    if relaxation == 0:
        # strict: pure per-tier prefix arithmetic, no sequential loop
        t_c, pos_d, d_matched, taken = strict_batch_deletemin(
            deq, avail, firsts, P_)
        tier = jnp.where(d_matched, t_c, tier)
        pos = jnp.where(d_matched, pos_d, pos)
        matched = enq | d_matched
        n_relaxed = jnp.int32(0)
    else:
        if shard_of is None or n_shards is None:
            raise ValueError("relaxation > 0 needs shard_of and n_shards")
        ar = jnp.arange(P_, dtype=jnp.int32)

        def step(taken, x):
            d_i, s_i = x
            sizes = avail - taken
            ne = sizes > 0
            pstar = jnp.argmax(ne).astype(jnp.int32)    # best non-empty tier
            heads = firsts + taken
            loc = (ne & (ar >= pstar) & (ar <= pstar + relaxation)
                   & (jnp.mod(heads, n_shards) == s_i))
            q = jnp.where(loc.any(), jnp.argmax(loc), pstar).astype(jnp.int32)
            m = d_i & ne.any()
            out = (jnp.where(m, q, -1), jnp.where(m, heads[q], BOTTOM),
                   m, m & (q != pstar))
            return taken + jnp.where(m, (ar == q).astype(jnp.int32), 0), out

        taken, (t_d, pos_d, m_d, rel) = lax.scan(
            step, jnp.zeros((P_,), jnp.int32),
            (deq, shard_of.astype(jnp.int32)))
        tier = jnp.where(m_d, t_d, tier)
        pos = jnp.where(m_d, pos_d, pos)
        matched = enq | m_d
        n_relaxed = rel.astype(jnp.int32).sum()

    return tier, pos, matched, firsts + taken, new_lasts, n_relaxed


# -------------------------------------------------- seap bucket scan -------
INT32_MIN = jnp.int32(-(2 ** 31))
INT32_MAX = jnp.int32(2 ** 31 - 1)


def seap_bucket_lookup(key: jax.Array, lo: jax.Array, active: jax.Array):
    """Predecessor lookup in the replicated bucket directory: for each key,
    the active bucket with the largest boundary ``lo <= key``.

    The root bucket (id 0) keeps ``lo == INT32_MIN`` and is always active,
    so every key has a home; active boundaries are distinct by the split
    rule, so the argmax is unique (and ties at ``INT32_MIN`` resolve to the
    root because argmax returns the first index).
    """
    eligible = active[None, :] & (lo[None, :] <= key[:, None])
    score = jnp.where(eligible, lo[None, :], INT32_MIN)
    return jnp.argmax(score, axis=1).astype(jnp.int32)


def seap_queue_scan(is_enq: jax.Array, key: jax.Array, valid: jax.Array,
                    firsts: jax.Array, lasts: jax.Array, lo: jax.Array,
                    active: jax.Array, key_lo: jax.Array,
                    key_hi: jax.Array, *, n_buckets: int,
                    split_occupancy: int, tier_scan=None):
    """Batch position assignment for the arbitrary-key Seap queue
    (arXiv:1805.03472's search structure collapsed to a two-level bucket
    directory; see ``core.seap.SeapOracle`` for the full semantics).

    One wave applies all enqueues before all dequeues, then rebalances:

      * enqueues — bucket from :func:`seap_bucket_lookup`, then per-bucket
        FIFO positions via B masked min-plus scans (the
        :func:`priority_queue_scan` machinery with tier := bucket);
      * dequeues — Skeap's :func:`strict_batch_deletemin` over the bucket
        directory sorted by boundary: the d-th dequeue of the wave takes
        the d-th element of the boundary-ordered pool, FIFO inside each
        bucket;
      * rebalance — at most one split per wave (halve the fullest bucket
        whose occupancy exceeds ``split_occupancy`` into the lowest free
        id), preceded by at most one *on-demand* merge (recycle the
        lowest-id active empty non-root bucket) when the split wants an
        id and none is free.  The split midpoint is clamped to the
        *observed* key range ``[key_lo, key_hi]`` (running min/max of
        enqueued keys — the paper's search structure is built over
        inserted keys, not the int32 universe), so the zoom lands in the
        live range immediately instead of halving down from
        ``INT32_MAX`` geometrically.  Pure replicated arithmetic — no
        collectives, and no element ever moves between windows.

    Args:
      is_enq/valid: [n] bool (global wave order); key: [n] int32 (ignored
        for dequeues); firsts/lasts/lo: [n_buckets] int32; active:
        [n_buckets] bool; key_lo/key_hi: replicated int32 scalars, the
        min/max key ever enqueued (INT32_MAX/INT32_MIN while empty).
    Returns:
      (bucket [n] int32 (-1 unmatched), pos [n] int32 (⊥ = -1), matched
      [n] bool, new_firsts, new_lasts, new_lo, new_active, new_key_lo,
      new_key_hi, n_active) — ``n_active`` is the replicated directory
      size after the rebalance.
    """
    B = n_buckets
    enq = is_enq & valid
    deq = (~is_enq) & valid
    bucket_e = seap_bucket_lookup(key, lo, active)
    bucket = jnp.full(is_enq.shape, -1, jnp.int32)
    pos = jnp.full(is_enq.shape, BOTTOM, jnp.int32)
    if tier_scan is not None:
        # fused per-bucket sweep (tier := bucket), same hook as the
        # priority scan — kernels.segscan.make_tier_scan (PR 9)
        pos_e, new_lasts = tier_scan(enq, bucket_e, firsts, lasts)
        bucket = jnp.where(enq & (pos_e >= 0), bucket_e, bucket)
        pos = jnp.where(enq, pos_e, pos)
    else:
        new_lasts = []
        for b in range(B):
            mask = enq & (bucket_e == b)
            pos_b, _, st_b = queue_scan(
                mask, QueueState(firsts[b], lasts[b]), valid=mask)
            bucket = jnp.where(mask, b, bucket)
            pos = jnp.where(mask, pos_b, pos)
            new_lasts.append(st_b.last)
        new_lasts = jnp.stack(new_lasts)
    avail = new_lasts - firsts + 1               # sizes after enqueues

    # dequeues: batch-DeleteMin over the directory in boundary order
    # (inactive buckets sort last and are empty, so they are never taken)
    order = jnp.argsort(jnp.where(active, lo, INT32_MAX))
    t_s, pos_d, d_matched, taken_s = strict_batch_deletemin(
        deq, avail[order], firsts[order], B)
    taken = jnp.zeros((B,), jnp.int32).at[order].set(taken_s)
    bucket = jnp.where(d_matched, order[t_s], bucket)
    pos = jnp.where(d_matched, pos_d, pos)
    matched = enq | d_matched
    new_firsts = firsts + taken

    # running observed key range (enqueued keys only)
    enq_keys_min = jnp.min(jnp.where(enq, key, INT32_MAX))
    enq_keys_max = jnp.max(jnp.where(enq, key, INT32_MIN))
    new_key_lo = jnp.minimum(key_lo, enq_keys_min)
    new_key_hi = jnp.maximum(key_hi, enq_keys_max)

    # ---- rebalance: merge-on-demand then split, replicated arithmetic
    # only.  An empty bucket is harmless future structure, so its id is
    # recycled (merged away) ONLY when a split wants an id and none is
    # free — merging eagerly would dismantle the directory between
    # bursts, exactly when the next crunch needs it refined. ----
    sizes = new_lasts - new_firsts + 1
    ids = jnp.arange(B, dtype=jnp.int32)
    occ = jnp.where(active, sizes, -1)
    over = occ > split_occupancy
    cand = active & (sizes == 0) & (lo != INT32_MIN)
    need = over.any() & ~(~active).any()          # want to split, no free id
    active = jnp.where((ids == jnp.argmax(cand)) & need & cand.any(),
                       False, active)
    free = ~active
    b_s = jnp.argmax(jnp.where(over, occ, -1))   # fullest; ties -> lowest id
    hi = jnp.min(jnp.where(active & (lo > lo[b_s]), lo, INT32_MAX))
    # clamp the halving to the observed key range (saturating +/-1 at the
    # int32 edges); a triggered split implies the bucket is non-empty, so
    # new_key_hi >= lo[b_s] and the clamped range is non-degenerate
    lo_eff = jnp.maximum(
        lo[b_s], jnp.where(new_key_lo == INT32_MIN, INT32_MIN,
                           new_key_lo - 1))
    hi_eff = jnp.minimum(
        hi, jnp.where(new_key_hi == INT32_MAX, INT32_MAX, new_key_hi + 1))
    # overflow-free floor((lo_eff + hi_eff) / 2); the split is valid only
    # when the midpoint lands strictly inside the bucket's (lo, hi) range
    mid = (lo_eff & hi_eff) + ((lo_eff ^ hi_eff) >> 1)
    do_split = over.any() & free.any() & (mid > lo[b_s]) & (mid < hi)
    b_f = jnp.argmax(free)                       # lowest free id
    new_lo = jnp.where((ids == b_f) & do_split, mid, lo)
    new_active = active | ((ids == b_f) & do_split)
    n_active = jnp.sum(new_active.astype(jnp.int32))
    return (bucket, pos, matched, new_firsts, new_lasts, new_lo,
            new_active, new_key_lo, new_key_hi, n_active)


# ------------------------------------------------- shard_map distribution ---
def sharded_queue_scan(is_enq_local: jax.Array, state: QueueState,
                       axis_name: str,
                       valid_local: jax.Array | None = None):
    """shard_map body: per-device local request arrays; returns local
    positions + matched + the (replicated) new state.

    Three phases, mirroring the paper exactly:
      1. local "batch aggregation": an associative scan on-device,
      2. "anchor assignment": an O(log p) ppermute hypercube scan of the
         per-device total transforms (constant bytes per hop),
      3. "interval decomposition": apply the device-prefix carry locally.
    """
    e = is_enq_local if valid_local is None else (is_enq_local & valid_local)
    tr = queue_op_transforms(e)
    if valid_local is not None:
        A, B, C = tr
        tr = (jnp.where(valid_local, A, 0),
              jnp.where(valid_local, B, INF),
              jnp.where(valid_local, C, 0))
    inc = lax.associative_scan(queue_compose, tr)                    # phase 1
    total = tuple(x[-1] for x in inc)

    # phase 2: exclusive hypercube scan of device totals
    p = axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    incl = total
    shift = 1
    while shift < p:
        perm = [(i, i + shift) for i in range(p - shift)]
        moved = tuple(lax.ppermute(c, axis_name, perm) for c in incl)
        cand = queue_compose(moved, incl)
        use = idx >= shift
        incl = tuple(jnp.where(use, cn, cu) for cn, cu in zip(cand, incl))
        shift *= 2
    # device-exclusive carry = shift by one device
    perm1 = [(i, i + 1) for i in range(p - 1)]
    moved1 = tuple(lax.ppermute(c, axis_name, perm1) for c in incl)
    dev_excl = tuple(jnp.where(idx == 0, fill, m)
                     for m, fill in zip(moved1, (0, INF, 0)))

    # phase 3: local exclusive prefixes composed after the device carry
    Ax, Bx, Cx = _exclusive(inc)
    Ad, Bd, Cd = dev_excl
    A, B, C = queue_compose((Ad, Bd, Cd), (Ax, Bx, Cx))
    f_i = jnp.minimum(state.first + A, state.last + B)
    l_i = state.last + C
    pos = jnp.where(is_enq_local, l_i + 1,
                    jnp.where(f_i <= l_i, f_i, BOTTOM))
    matched = pos != BOTTOM
    if valid_local is not None:
        pos = jnp.where(valid_local, pos, BOTTOM)
        matched = matched & valid_local
    # new replicated state: all-devices total = inclusive scan at last device
    # (broadcast via a tiny all_gather of the 3 scalar carries)
    A_t, B_t, C_t = (
        lax.all_gather(c, axis_name)[p - 1] if p > 1 else c for c in incl)
    new = QueueState(jnp.minimum(state.first + A_t, state.last + B_t),
                     state.last + C_t)
    return pos, matched, new


def make_sharded_queue_scan(mesh, axis_name: str = "data"):
    """jit-compiled shard_map wrapper over ``mesh[axis_name]``."""
    spec = P(axis_name)
    rep = P()

    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(spec, rep, spec), out_specs=(spec, spec, rep))
    def run(is_enq, state, valid):       # new state is value-replicated by
        # the final all_gather broadcast
        pos, matched, new = sharded_queue_scan(
            is_enq, QueueState(*state), axis_name, valid_local=valid)
        return pos, matched, tuple(new)

    def call(is_enq: jax.Array, state: QueueState, valid: jax.Array):
        pos, matched, new = run(is_enq, tuple(state), valid)
        return pos, matched, QueueState(*new)

    return call
