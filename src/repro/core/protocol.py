"""SKUEUE protocol engine (paper Sections III, IV, VI) — faithful implementation.

One implementation of the message-passing protocol, driven by two schedulers:

* ``run_async``  — adversarial asynchronous delivery (arbitrary finite delays,
  non-FIFO channels).  Used by the hypothesis property tests to validate
  sequential consistency (Definition 1 / Theorems 14 & 21).
* ``run_rounds`` — the standard synchronous model used for the paper's
  runtime analysis and evaluation (Figures 2/3/4): messages sent in round i
  arrive in round i+1; every node fires TIMEOUT each round.

Fidelity notes (cf. DESIGN.md §6):
- Stages 1–4 follow Algorithms 1–2 exactly: empty batch waves, memorized
  sub-batch combination order, dequeue clamping, and — stack — the stage-4
  completion barrier, monotone tickets and local push/pop combining.
- DHT PUT/GET are delivered with a transit delay equal to the LDB De Bruijn
  route length (Lemma 3) instead of hop-by-hop forwarding; GETs that outrun
  their PUT wait at the owner exactly as in the paper; messages that land on
  a node that no longer owns the key are forwarded (Sec. IV).
- JOIN/LEAVE (Sec. IV) are lazy: responsible nodes buffer joiners/leavers and
  report counts ``B.j``/``B.l`` in their batches; the anchor raises the
  update flag on the next serve wave; nodes freeze after that wave's stage 4,
  integrate the nodes they are responsible for, and ack up the OLD tree; the
  anchor (possibly handing off to a new leftmost node) broadcasts resume down
  the NEW tree.  Simplifications vs. the paper, documented in DESIGN.md §6:
  data moves at integration (not at join-accept); a leaving node is merged
  into its predecessor (interval-equivalent to the paper's replacement node);
  busy leavers are deferred to the next update phase (subsumes the paper's
  leave-prioritisation rule); the message-drain acknowledgment machinery is
  replaced by arrival-time forwarding, which is equivalent under reliable
  channels.
- The anchor's virtual counter ``c`` (Section V) is materialized by carrying
  an *order interval* alongside each position interval, decomposed with the
  same leading-slice rule; this yields ``value(op)`` for every request, i.e.
  the total order ``≺`` that the consistency checker replays.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import batch as B
from .hashing import position_key
from .intervals import (AnchorState, BOTTOM, assign_queue, assign_stack,
                        decompose_queue, decompose_stack, positions_queue,
                        positions_stack)
from .ring import DynamicRing

ENQ, DEQ = "enq", "deq"


@dataclass
class Request:
    rid: int
    kind: str              # "enq" | "deq"  (also used for push/pop)
    node: int              # issuing virtual node (stable id)
    elem: Optional[int]    # element id for enqueues
    t_issue: int = 0       # round (sync) / event step (async)
    t_done: int = -1
    pos: Optional[int] = None
    order: Optional[int] = None   # value(op) — the protocol's total order
    result: Optional[int] = None  # dequeue: element id, or BOTTOM for ⊥
    done: bool = False


class Skueue:
    """A full SKUEUE instance, initially over ``n`` processes (3n nodes)."""

    def __init__(self, n: int, mode: str = "queue", seed: int = 0,
                 salt: int = 0, local_combining: bool = True):
        assert mode in ("queue", "stack")
        self.mode = mode
        self.ring = DynamicRing.build(n, salt=salt)
        self.rng = np.random.default_rng(seed)
        self.next_pid = n
        # --- per-node protocol state (lists grow with joins) ---
        M = len(self.ring.labels)
        self.W_own_reqs: List[List[int]] = [[] for _ in range(M)]
        self.W_child: List[Dict[int, List[int]]] = [dict() for _ in range(M)]
        self.B_own_reqs: List[List[int]] = [[] for _ in range(M)]
        self.B_child: List[Dict[int, List[int]]] = [dict() for _ in range(M)]
        self.B_child_order: List[List[int]] = [[] for _ in range(M)]
        self.busy: List[bool] = [False] * M
        self.frozen: List[bool] = [False] * M
        self.stage4_open: List[int] = [0] * M
        # --- DHT state (keyed by position; key k(p) only selects the owner) --
        self.store: List[Dict[int, object]] = [dict() for _ in range(M)]
        self.pending_get: List[Dict[int, List[int]]] = [dict() for _ in range(M)]
        self.pending_pop: List[List[Tuple[int, int, int]]] = [[] for _ in range(M)]
        # --- membership (Sec. IV) ---
        self.pending_joins: List[List[int]] = [[] for _ in range(M)]
        self.pending_leaves: List[List[int]] = [[] for _ in range(M)]
        self.leaving: List[bool] = [False] * M
        self.j_report: List[int] = [0] * M      # B.j since last batch
        self.l_report: List[int] = [0] * M      # B.l since last batch
        self.p_old: List[int] = [-2] * M        # serve-time parent in update phase
        self.agg_parent: List[int] = [-1] * M   # parent the last aggregate went to
        self.C_old: List[List[int]] = [[] for _ in range(M)]
        self.acks_got: List[int] = [0] * M
        self.integ_done: List[int] = [0] * M    # integrated count to report
        self.fwd_to: List[int] = [-1] * M       # post-leave forwarding pointer
        self.update_active = False
        self.pending_membership = 0             # anchor's known-uncompleted count
        self.update_phases = 0
        # --- anchor ---
        # queue: occupied = [first, last], empty at (0, -1).
        # stack: positions start at 1, empty at last=0 (paper Sec. VI).
        self.anchor_state = AnchorState(first=0, last=(-1 if mode == "queue" else 0))
        self.anchor_id = self.ring.anchor
        self.order_counter = 0   # the paper's virtual counter c
        # --- requests & messages ---
        self.requests: List[Request] = []
        self.local_combining = local_combining and mode == "stack"
        self.now = 0
        self.msgs_heap: List[Tuple[int, int, int, tuple]] = []  # (due, seq, dst, msg)
        self._seq = 0
        self.stats_batch_max_runs = 0
        self.total_msgs = 0

    # ---------------------------------------------------------- node state --
    def _grow_state(self) -> None:
        M = len(self.ring.labels)
        while len(self.busy) < M:
            self.W_own_reqs.append([])
            self.W_child.append(dict())
            self.B_own_reqs.append([])
            self.B_child.append(dict())
            self.B_child_order.append([])
            self.busy.append(False)
            self.frozen.append(True)   # new nodes wait for resume
            self.stage4_open.append(0)
            self.store.append(dict())
            self.pending_get.append(dict())
            self.pending_pop.append([])
            self.pending_joins.append([])
            self.pending_leaves.append([])
            self.leaving.append(False)
            self.j_report.append(0)
            self.l_report.append(0)
            self.p_old.append(-2)
            self.agg_parent.append(-1)
            self.C_old.append([])
            self.acks_got.append(0)
            self.integ_done.append(0)
            self.fwd_to.append(-1)

    # ------------------------------------------------------------- inject --
    def inject(self, node: int, kind: str, elem: Optional[int] = None) -> int:
        assert self.ring.active[node], "cannot inject at an inactive node"
        rid = len(self.requests)
        if kind == ENQ and elem is None:
            elem = rid  # unique element id (paper: elements unique w.l.o.g.)
        req = Request(rid=rid, kind=kind, node=node, elem=elem, t_issue=self.now)
        self.requests.append(req)
        own = self.W_own_reqs[node]
        if self.local_combining and kind == DEQ and own:
            # Stack local pairing (Sec. VI): a pop answers the latest
            # still-buffered local push.
            prev = self.requests[own[-1]]
            if prev.kind == ENQ:
                own.pop()
                prev.done, prev.t_done, prev.order = True, self.now, -1
                req.done, req.t_done, req.result, req.order = (
                    True, self.now, prev.elem, -1)
                return rid
        own.append(rid)
        return rid

    # -------------------------------------------------------- membership ---
    def request_join(self, pid: Optional[int] = None) -> List[int]:
        """A new process joins: three virtual nodes, each routed (Lemma 3) to
        its responsible node.  Returns the new virtual node ids."""
        if pid is None:
            pid = self.next_pid
        self.next_pid = max(self.next_pid, pid + 1)
        trio = self.ring.add_process(pid, activate=False)
        self._grow_state()
        for nid in trio:
            key = self.ring.labels[nid]
            owner = self.ring.owner_of_scalar(key)
            delay = 1 + self.ring.route_hops_scalar(owner, key)
            self._send(owner, ("join", nid), delay=delay)
        return list(trio)

    def request_leave(self, pid: int) -> None:
        """Process ``pid`` wants to leave: LEAVE() for its three nodes."""
        trios = [nid for nid, p in enumerate(self.ring.proc)
                 if p == pid and self.ring.active[nid]]
        for nid in trios:
            u = self.ring.pred(nid)
            self._send(u, ("leave", nid), delay=1)

    # ----------------------------------------------------------- messaging --
    def _send(self, dst: int, msg: tuple, delay: int = 1) -> None:
        self._seq += 1
        self.total_msgs += 1
        heapq.heappush(self.msgs_heap, (self.now + delay, self._seq, dst, msg))

    # ------------------------------------------------------------ TIMEOUT --
    def timeout(self, v: int) -> None:
        """Algorithm 1: if B=(0) and W has sub-batches from all children
        (and, stack, all stage-4 ops acked) -> B <- W, send AGGREGATE."""
        if (self.busy[v] or self.frozen[v] or self.stage4_open[v] > 0
                or not self.ring.active[v]):
            return
        kids = self.ring.children(v)
        if any(c not in self.W_child[v] for c in kids):
            return
        self.B_own_reqs[v] = self.W_own_reqs[v]
        self.W_own_reqs[v] = []
        # consume required children plus any orphaned sub-batches forwarded by
        # ex-children after a membership change (they must not be lost)
        take = list(kids) + [c for c in self.W_child[v] if c not in kids]
        self.B_child[v] = {c: self.W_child[v].pop(c) for c in take}
        self.B_child_order[v] = take
        self.busy[v] = True
        j, l = self.j_report[v], self.l_report[v]
        self.j_report[v] = 0
        self.l_report[v] = 0
        runs, jt, lt = self._combined_runs(v, j, l)
        self.stats_batch_max_runs = max(self.stats_batch_max_runs, len(runs))
        if v == self.anchor_id:
            self.agg_parent[v] = -1
            self.pending_membership += jt + lt
            self._assign_and_serve(v, runs)
        else:
            p = self.ring.parent(v)
            self.agg_parent[v] = p  # the OLD-tree parent for update-phase acks
            self._send(p, ("aggregate", v, runs, jt, lt))

    def _runs_of(self, rids: List[int]) -> List[int]:
        runs = B.empty()
        for rid in rids:
            B.append_op(runs, self.requests[rid].kind == ENQ)
        return runs

    def _combined_runs(self, v: int, j: int, l: int):
        parts = [self._runs_of(self.B_own_reqs[v])]
        jt, lt = j, l
        for c in self.B_child_order[v]:
            runs_c, j_c, l_c = self.B_child[v][c]
            parts.append(runs_c)
            jt += j_c
            lt += l_c
        return B.combine_many(parts), jt, lt

    # -------------------------------------------------------- stages 2 + 3 --
    def _assign_and_serve(self, v: int, runs: List[int]) -> None:
        """Stage 2 at the anchor, then recursive SERVE (Algorithm 2)."""
        norm = list(runs)
        if self.mode == "queue":
            ivs = assign_queue(self.anchor_state, norm)
        else:
            ivs = assign_stack(self.anchor_state, norm)
        orders = []
        c = self.order_counter
        for op in norm:
            orders.append((c + 1, c + int(op)))
            c += int(op)
        self.order_counter = c
        flag = self.pending_membership > 0
        if flag:
            self.update_active = True
            self.update_phases += 1
        self._serve(v, ivs, orders, flag)

    def _serve(self, v: int, ivs, orders, flag: bool) -> None:
        own_runs = self._runs_of(self.B_own_reqs[v])
        parts = [own_runs] + [self.B_child[v][c][0] for c in self.B_child_order[v]]
        if self.mode == "queue":
            sub = decompose_queue(ivs, parts)
        else:
            sub = decompose_stack(ivs, parts)
        sub_orders = decompose_queue(orders, parts)
        for i, c in enumerate(self.B_child_order[v]):
            self._send(c, ("serve", sub[i + 1], sub_orders[i + 1], flag))
        self._stage4(v, sub[0], sub_orders[0], own_runs)
        # return to stage 1 (or enter the update phase)
        self.B_own_reqs[v] = []
        self.B_child[v] = {}
        kids_served = self.B_child_order[v]
        self.B_child_order[v] = []
        if flag:
            self.frozen[v] = True
            # acks travel up the OLD aggregation tree (paper Sec. IV-A):
            # the parent this wave's aggregate was sent to, not the current one
            self.p_old[v] = -1 if v == self.anchor_id else self.agg_parent[v]
            self.C_old[v] = list(kids_served)
            self.acks_got[v] = 0
            self.integ_done[v] = 0
            self._integrate(v)
            self._maybe_ack(v)
        if self.stage4_open[v] == 0 and not self.frozen[v]:
            self.busy[v] = False
        elif self.stage4_open[v] == 0 and self.frozen[v]:
            self.busy[v] = False  # wave is complete; freeze blocks the next one

    # ------------------------------------------------------------ stage 4 --
    def _stage4(self, v: int, run_info, run_orders, own_runs) -> None:
        rids = self.B_own_reqs[v]
        if self.mode == "queue":
            pos = positions_queue(run_info, own_runs)
            pt = [(p, 0) for p in pos]
        else:
            pt = positions_stack(run_info, own_runs)
        ordvals: List[int] = []
        for i, op in enumerate(own_runs):
            x, _y = run_orders[i]
            ordvals += [x + j for j in range(int(op))]
        assert len(pt) == len(rids) == len(ordvals)
        for rid, (p, t), val in zip(rids, pt, ordvals):
            req = self.requests[rid]
            req.pos, req.order = (None if p == BOTTOM else p), val
            if p == BOTTOM:  # unmatched dequeue: returns ⊥ immediately
                req.result, req.done, req.t_done = BOTTOM, True, self.now
                continue
            key = float(position_key(p))
            owner = self.ring.owner_of_scalar(key)
            delay = 1 + self.ring.route_hops_scalar(v, key)
            if req.kind == ENQ:
                self._send(owner, ("put", p, t, req.elem, rid, v), delay=delay)
                if self.mode == "stack":
                    self.stage4_open[v] += 1
            else:
                self._send(owner, ("get", p, t, rid, v), delay=delay)
                if self.mode == "stack":
                    self.stage4_open[v] += 1

    # ------------------------------------------------- update phase helpers --
    def _integrate(self, v: int) -> None:
        """Integrate all joiners/leavers this node is responsible for."""
        # Activate joiners right-to-left so that at each activation the new
        # node's key interval still lives on ``v`` (paper: chain introduction
        # v_1 < ... < v_k between u and succ(u)).
        for nid in sorted(self.pending_joins[v],
                          key=lambda i: -self.ring.labels[i]):
            self.ring.activate(nid)
            self.frozen[nid] = True
            succ = self.ring.succ(nid)
            self._move_interval(v, nid, self.ring.labels[nid],
                                self.ring.labels[succ] if succ != nid else None)
            self.integ_done[v] += 1
        self.pending_joins[v] = []
        # LEAVE (paper Sec. IV-B): the process emulating the left neighbour
        # creates a replacement v' with the same label, connections, DHT data
        # and responsibilities.  The virtual node therefore PERSISTS on the
        # ring — only its emulating process changes.  In the engine this is a
        # process re-assignment; the state handover that a real deployment
        # would stream over the network is atomic here (DESIGN.md §6).
        if self.leaving[v]:
            # leave-prioritisation (paper Sec. IV-B): a responsible node that
            # is itself leaving postpones replacing its neighbours until it
            # has been replaced — there is always a leftmost leaving node, so
            # this converges phase by phase.
            pass
        else:
            for nid in self.pending_leaves[v]:
                self.ring.proc[nid] = self.ring.proc[v]
                self.leaving[nid] = False
                self.integ_done[v] += 1
            self.pending_leaves[v] = []
        if self.mode == "stack":
            self._drain_pops(v)
        else:
            self._drain_gets(v)

    def _move_interval(self, src: int, dst: int, lo: float,
                       hi: Optional[float]) -> None:
        """Move stored elements + waiting GETs/POPs with key in [lo, hi)."""
        def mine(p: int) -> bool:
            k = float(position_key(p))
            if hi is None:
                return True
            if lo <= hi:
                return lo <= k < hi
            return k >= lo or k < hi  # wrap-around interval
        moved = [p for p in self.store[src] if mine(p)]
        for p in moved:
            self.store[dst][p] = self.store[src].pop(p)
        movedg = [p for p in self.pending_get[src] if mine(p)]
        for p in movedg:
            self.pending_get[dst][p] = self.pending_get[src].pop(p)
        keep, move = [], []
        for rec in self.pending_pop[src]:
            (move if mine(rec[0]) else keep).append(rec)
        self.pending_pop[src] = keep
        self.pending_pop[dst].extend(move)
        # re-match waiters that now share a node with their element
        if self.mode == "stack":
            self._drain_pops(dst)
        else:
            self._drain_gets(dst)

    def _maybe_ack(self, v: int) -> None:
        if not self.frozen[v] or self.p_old[v] == -2:
            return
        if self.acks_got[v] < len(self.C_old[v]):
            return
        if v == self.anchor_id:
            self._finish_update(v)
        else:
            self._send(self.p_old[v], ("uack", self.integ_done[v]))
            self.p_old[v] = -2
            # stay frozen until resume

    def _finish_update(self, old_anchor: int) -> None:
        total = self.integ_done[old_anchor]
        # integration counts reported by the subtree arrived via uack already.
        # May go negative: a node can integrate joiners/leavers it accepted
        # after its last batch report — the report arrives later as a credit.
        self.pending_membership -= total
        new_anchor = self.ring.anchor
        if new_anchor != old_anchor:
            # anchor handoff (Sec. IV-A): transfer [first,last] (+ticket, c)
            self._send(new_anchor, ("anchor_handoff",
                                    self.anchor_state.first,
                                    self.anchor_state.last,
                                    self.anchor_state.ticket,
                                    self.order_counter), delay=1)
        else:
            self._resume_from(new_anchor)
        self.p_old[old_anchor] = -2

    def _resume_from(self, v: int) -> None:
        self.update_active = False
        self.frozen[v] = False
        self.p_old[v] = -2
        for c in self.ring.children(v):
            self._send(c, ("resume",))

    # ----------------------------------------------------- message handler --
    def handle(self, dst: int, msg: tuple) -> None:
        kind = msg[0]
        if kind == "aggregate":
            _, child, runs, j, l = msg
            if not self.ring.active[dst] and self.fwd_to[dst] >= 0:
                self._send(self.fwd_to[dst], msg, delay=1)
                return
            assert child not in self.W_child[dst], "child double-send in a wave"
            self.W_child[dst][child] = (runs, j, l)
        elif kind == "serve":
            _, ivs, orders, flag = msg
            self._serve(dst, ivs, orders, flag)
        elif kind == "put":
            _, p, t, elem, rid, src = msg
            owner = self._current_owner(dst, p)
            if owner != dst:
                self._send(owner, msg, delay=1)
                return
            if self.mode == "queue":
                self.store[dst][p] = elem
                req = self.requests[rid]
                req.done, req.t_done = True, self.now
                waiters = self.pending_get[dst].pop(p, [])
                for wrid in waiters:
                    self._answer_get(dst, p, wrid)
            else:
                self.store[dst].setdefault(p, {})[t] = elem  # type: ignore
                self._send(src, ("ack_put", rid), delay=1)
                req = self.requests[rid]
                req.done, req.t_done = True, self.now
                self._drain_pops(dst)
        elif kind == "get":
            _, p, t, rid, src = msg
            owner = self._current_owner(dst, p)
            if owner != dst:
                self._send(owner, msg, delay=1)
                return
            if self.mode == "queue":
                if p in self.store[dst]:
                    self._answer_get(dst, p, rid)
                else:  # GET outran PUT: wait at the owner (paper Stage 4)
                    self.pending_get[dst].setdefault(p, []).append(rid)
            else:
                self.pending_pop[dst].append((p, t, rid))
                self._drain_pops(dst)
        elif kind == "elem":
            _, rid, elem = msg
            req = self.requests[rid]
            req.result, req.done, req.t_done = elem, True, self.now
            if self.mode == "stack":
                self._close_stage4(req.node)
        elif kind == "ack_put":
            _, rid = msg
            self._close_stage4(self.requests[rid].node)
        elif kind == "join":
            _, nid = msg
            if not self.ring.active[dst] and self.fwd_to[dst] >= 0:
                self._send(self.fwd_to[dst], msg, delay=1)
                return
            owner = self.ring.owner_of_scalar(self.ring.labels[nid])
            if owner != dst:  # responsibility moved meanwhile
                self._send(owner, msg, delay=1)
                return
            self.pending_joins[dst].append(nid)
            self.j_report[dst] += 1
        elif kind == "leave":
            _, nid = msg
            if not self.ring.active[dst] and self.fwd_to[dst] >= 0:
                self._send(self.fwd_to[dst], msg, delay=1)
                return
            if self.ring.pred(nid) != dst and self.ring.active[nid]:
                self._send(self.ring.pred(nid), msg, delay=1)
                return
            if not self.ring.active[nid] or self.leaving[nid]:
                return  # already gone / duplicate request
            self.leaving[nid] = True
            self.pending_leaves[dst].append(nid)
            self.l_report[dst] += 1
        elif kind == "uack":
            _, integrated = msg
            self.acks_got[dst] += 1
            self.integ_done[dst] += integrated
            self._maybe_ack(dst)
        elif kind == "anchor_handoff":
            _, first, last, ticket, c = msg
            self.anchor_state = AnchorState(first=first, last=last, ticket=ticket)
            self.order_counter = c
            old = self.anchor_id
            self.anchor_id = dst
            # the old anchor may still hold unreported membership counts
            self._resume_from(dst)
            if old != dst:
                self.frozen[old] = False
        elif kind == "resume":
            self.frozen[dst] = False
            self.p_old[dst] = -2
            for c in self.ring.children(dst):
                self._send(c, ("resume",))
        else:  # pragma: no cover
            raise ValueError(f"unknown message {kind}")

    def _current_owner(self, dst: int, p: int) -> int:
        if not self.ring.active[dst]:
            return self.fwd_to[dst] if self.fwd_to[dst] >= 0 else dst
        key = float(position_key(p))
        owner = self.ring.owner_of_scalar(key)
        return owner

    def _close_stage4(self, v: int) -> None:
        self.stage4_open[v] -= 1
        if self.stage4_open[v] == 0:
            self.busy[v] = False

    def _answer_get(self, owner: int, p: int, rid: int) -> None:
        elem = self.store[owner].pop(p)
        req = self.requests[rid]
        self._send(req.node, ("elem", rid, elem), delay=1)

    def _drain_gets(self, owner: int) -> None:
        """Queue: answer waiting GETs whose element has arrived/migrated."""
        ready = [p for p in self.pending_get[owner] if p in self.store[owner]]
        for p in ready:
            waiters = self.pending_get[owner].pop(p)
            for wrid in waiters:
                if p in self.store[owner]:
                    self._answer_get(owner, p, wrid)
                else:  # more waiters than elements cannot happen (unique pos)
                    self.pending_get[owner].setdefault(p, []).append(wrid)

    def _drain_pops(self, owner: int) -> None:
        """Stack: serve pending pops whose element (max ticket <= t') is here."""
        out = []
        for (p, t, rid) in self.pending_pop[owner]:
            slot: Dict[int, int] = self.store[owner].get(p, {})  # type: ignore
            cand = [tk for tk in slot if tk <= t]
            if cand:
                tk = max(cand)
                elem = slot.pop(tk)
                req = self.requests[rid]
                self._send(req.node, ("elem", rid, elem), delay=1)
            else:
                out.append((p, t, rid))
        self.pending_pop[owner] = out

    # ----------------------------------------------------------- schedulers --
    def run_rounds(self, n_rounds: int, inject_fn=None, drain: bool = True,
                   max_extra: int = 200_000) -> None:
        """Synchronous model: each round = deliver all due messages, fire
        TIMEOUT at every active node, optionally inject new requests."""
        for _ in range(n_rounds):
            self.now += 1
            if inject_fn is not None:
                inject_fn(self, self.now)
            self._deliver_due()
            self._fire_timeouts()
        if drain:
            # NOTE: empty batch waves circulate forever (that is the protocol's
            # steady state) so we drain on *request* completion, not the heap.
            extra = 0
            while self._any_ready() and extra < max_extra:
                self.now += 1
                extra += 1
                self._deliver_due()
                self._fire_timeouts()
            assert not self._any_ready(), "drain exceeded max_extra rounds"

    def _deliver_due(self) -> None:
        while self.msgs_heap and self.msgs_heap[0][0] <= self.now:
            _, _, dst, msg = heapq.heappop(self.msgs_heap)
            self.handle(dst, msg)

    def _fire_timeouts(self) -> None:
        for nid in self.ring.node_ids():
            self.timeout(nid)

    def _any_ready(self) -> bool:
        return any(not r.done for r in self.requests)

    def run_async(self, max_steps: int = 2_000_000,
                  timeout_prob: float = 0.5) -> bool:
        """Adversarial asynchronous scheduler: at each step either deliver a
        uniformly random in-flight message (arbitrary reordering) or fire
        TIMEOUT at a random node.  Returns True when all requests finished."""
        rng = self.rng
        for _ in range(max_steps):
            self.now += 1
            if not self._any_ready():
                return True
            pend = len(self.msgs_heap)
            nids = self.ring.node_ids()
            if pend > 0 and (rng.random() > timeout_prob
                             or pend > 4 * len(nids)):
                k = int(rng.integers(pend))
                self.msgs_heap[k], self.msgs_heap[-1] = (
                    self.msgs_heap[-1], self.msgs_heap[k])
                _, _, dst, msg = self.msgs_heap.pop()
                heapq.heapify(self.msgs_heap)
                self.handle(dst, msg)
            else:
                self.timeout(nids[int(rng.integers(len(nids)))])
        return not self._any_ready()

    # ------------------------------------------------------------- checks ---
    def check_dht_placement(self) -> None:
        """Every stored element AND parked pending request lives at its
        consistent-hashing owner.  (The seed version carried a dead guard —
        ``if not self.store[nid]`` inside the loop over that dict's own keys,
        which can never fire — and only checked the store.)"""
        for nid in range(len(self.store)):
            for p in self.store[nid]:
                owner = self.ring.owner_of_scalar(float(position_key(p)))
                assert owner == nid, (
                    f"element at pos {p} stored on {nid}, owner is {owner}")
            for p in self.pending_get[nid]:
                owner = self.ring.owner_of_scalar(float(position_key(p)))
                assert owner == nid, (
                    f"pending GET for pos {p} parked on {nid}, "
                    f"owner is {owner}")
            for (p, _t, _rid) in self.pending_pop[nid]:
                owner = self.ring.owner_of_scalar(float(position_key(p)))
                assert owner == nid, (
                    f"pending POP for pos {p} parked on {nid}, "
                    f"owner is {owner}")

    def queue_size(self) -> int:
        return self.anchor_state.size if self.mode == "queue" else self.anchor_state.last
