"""Dynamic LDB ring with stable node ids (supports JOIN/LEAVE, paper Sec. IV).

The static :class:`~repro.core.ldb.LDB` uses sorted indices; membership
changes would invalidate them.  Here every virtual node has a *stable id*;
the sorted cycle, aggregation-tree parent/children and DHT ownership are
recomputed against the current active set (cached, invalidated on change).
Semantics (parent/children rules, ownership, De Bruijn routing) are identical
to ``LDB`` — ``tests/test_ldb.py`` cross-checks them on static membership.
"""
from __future__ import annotations

from bisect import bisect_right, insort
from typing import Dict, List, Tuple

import numpy as np

from .hashing import hash01

LEFT, MIDDLE, RIGHT = 0, 1, 2


class DynamicRing:
    def __init__(self, salt: int = 0):
        self.salt = salt
        self.labels: List[float] = []   # by node id
        self.kind: List[int] = []
        self.proc: List[int] = []
        self.active: List[bool] = []
        self.co: List[Tuple[int, int, int]] = []  # (l,m,r) ids per node id
        self._sorted: List[Tuple[float, int]] = []  # active (label, id), sorted
        self._parent: Dict[int, int] = {}
        self._children: Dict[int, List[int]] = {}
        self._dirty = True

    # ------------------------------------------------------------ build ----
    @staticmethod
    def build(n: int, salt: int = 0) -> "DynamicRing":
        r = DynamicRing(salt=salt)
        for pid in range(n):
            r.add_process(pid, activate=True)
        return r

    def _label_of_proc(self, pid: int) -> float:
        m = float(hash01(np.uint64(pid), salt=self.salt))
        # nudge collisions deterministically (labels must be unique)
        while any(abs(m - l) < 1e-15 for l in self.labels):
            m = float(np.nextafter(m, 1.0))
        return m

    def add_process(self, pid: int, activate: bool) -> Tuple[int, int, int]:
        """Create the three virtual nodes l(v), m(v), r(v) for a process."""
        m = self._label_of_proc(pid)
        ids = []
        for kind, lab in ((LEFT, m / 2.0), (MIDDLE, m), (RIGHT, (m + 1.0) / 2.0)):
            nid = len(self.labels)
            self.labels.append(lab)
            self.kind.append(kind)
            self.proc.append(pid)
            self.active.append(False)
            self.co.append((-1, -1, -1))
            ids.append(nid)
        trio = (ids[0], ids[1], ids[2])
        for nid in ids:
            self.co[nid] = trio
        if activate:
            for nid in ids:
                self.activate(nid)
        return trio

    def activate(self, nid: int) -> None:
        if not self.active[nid]:
            self.active[nid] = True
            insort(self._sorted, (self.labels[nid], nid))
            self._dirty = True

    def deactivate(self, nid: int) -> None:
        if self.active[nid]:
            self.active[nid] = False
            self._sorted.remove((self.labels[nid], nid))
            self._dirty = True

    # -------------------------------------------------------- topology -----
    @property
    def size(self) -> int:
        return len(self._sorted)

    def node_ids(self) -> List[int]:
        return [nid for _, nid in self._sorted]

    def _rebuild(self) -> None:
        if not self._dirty:
            return
        self._parent.clear()
        self._children.clear()
        order = self._sorted
        N = len(order)
        pos = {nid: i for i, (_, nid) in enumerate(order)}
        for i, (_, nid) in enumerate(order):
            k = self.kind[nid]
            l_id, m_id, _r_id = self.co[nid]
            if k == MIDDLE and self.active[l_id]:
                p = l_id
            elif k == RIGHT and self.active[m_id]:
                p = m_id
            else:  # LEFT, or co-node inactive: fall back to pred (label decreases)
                p = order[(i - 1) % N][1] if i > 0 else -1
            if i == 0:
                p = -1  # the leftmost active node is the anchor
            self._parent[nid] = p
            if p >= 0:
                self._children.setdefault(p, []).append(nid)
        self._pos = pos
        self._dirty = False

    @property
    def anchor(self) -> int:
        self._rebuild()
        return self._sorted[0][1]

    def parent(self, nid: int) -> int:
        self._rebuild()
        return self._parent[nid]

    def children(self, nid: int) -> List[int]:
        self._rebuild()
        return self._children.get(nid, [])

    def pred(self, nid: int) -> int:
        self._rebuild()
        i = self._pos[nid]
        return self._sorted[(i - 1) % self.size][1]

    def succ(self, nid: int) -> int:
        self._rebuild()
        i = self._pos[nid]
        return self._sorted[(i + 1) % self.size][1]

    def depth(self, nid: int) -> int:
        self._rebuild()
        d = 0
        while self._parent[nid] >= 0:
            nid = self._parent[nid]
            d += 1
        return d

    def max_depth(self) -> int:
        return max(self.depth(nid) for _, nid in self._sorted)

    # ---------------------------------------------------------- routing ----
    def owner_of_scalar(self, key: float) -> int:
        """Active node v with v <= key < succ(v) (consistent hashing)."""
        j = bisect_right(self._sorted, (key, float("inf"))) - 1
        return self._sorted[j][1] if j >= 0 else self._sorted[-1][1]

    def route_hops_scalar(self, src: int, key: float) -> int:
        """Continuous-discrete De Bruijn descent (Lemma 3), hop count."""
        N = max(2, self.size)
        nbits = max(1, int(np.ceil(np.log2(N))))
        cur = self.labels[src]
        t = float(key)
        bits = []
        for _ in range(nbits):
            t *= 2.0
            b = int(t)
            bits.append(b)
            t -= b
        for i in range(nbits - 1, -1, -1):
            cur = (cur + bits[i]) / 2.0
        snapped = self.owner_of_scalar(cur)
        tgt = self.owner_of_scalar(key)
        self._rebuild()
        a, b2 = self._pos[snapped], self._pos[tgt]
        dist = abs(a - b2)
        dist = min(dist, self.size - dist)
        return nbits + dist

    # ------------------------------------------------------------ checks ---
    def check_tree(self) -> None:
        self._rebuild()
        anchor = self.anchor
        for _, nid in self._sorted:
            p = self._parent[nid]
            if nid == anchor:
                assert p == -1
            else:
                assert p >= 0 and self.labels[p] < self.labels[nid]
        n_edges = sum(len(c) for c in self._children.values())
        assert n_edges == self.size - 1
