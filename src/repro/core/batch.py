"""Batch algebra (paper Definition 5).

A batch is a run-length encoding of an alternating sequence of ENQUEUE and
DEQUEUE requests: ``B = (op_1, ..., op_k)`` where odd 1-based indices count
enqueues and even indices count dequeues.  We store batches as python lists /
int64 numpy arrays with 0-based indexing, so ``runs[i]`` is an enqueue run
when ``i`` is even and a dequeue run when ``i`` is odd.  ``[0]`` is the empty
batch. For the stack variant batches collapse to ``(pops, pushes)``
(Theorem 20) — handled by the caller combining locally.

JOIN/LEAVE extensions (Section IV) ride along as scalar counters ``j``/``l``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Sequence

import numpy as np

ENQ = 0  # run parity for enqueues (0-based even index)
DEQ = 1


def empty() -> List[int]:
    return [0]


def is_empty(runs: Sequence[int]) -> bool:
    return len(runs) == 0 or all(r == 0 for r in runs)


def append_op(runs: List[int], is_enq: bool) -> None:
    """Record one locally-generated request (paper Sec. III-A), in place."""
    if not runs:
        runs.append(0)
    parity = (len(runs) - 1) % 2  # parity of the last run
    want = ENQ if is_enq else DEQ
    if parity == want:
        runs[-1] += 1
    else:
        runs.append(1)


def combine(a: Sequence[int], b: Sequence[int]) -> List[int]:
    """Elementwise sum with zero padding (paper Sec. III-A)."""
    m = max(len(a), len(b))
    out = [0] * m
    for i, v in enumerate(a):
        out[i] += int(v)
    for i, v in enumerate(b):
        out[i] += int(v)
    return out if out else [0]


def combine_many(parts: Sequence[Sequence[int]]) -> List[int]:
    out: List[int] = [0]
    for p in parts:
        out = combine(out, p)
    return out


def totals(runs: Sequence[int]) -> tuple:
    """(#enqueues, #dequeues) represented by the batch."""
    e = sum(int(v) for i, v in enumerate(runs) if i % 2 == ENQ)
    d = sum(int(v) for i, v in enumerate(runs) if i % 2 == DEQ)
    return e, d


def as_array(runs: Sequence[int], width: int) -> np.ndarray:
    """Fixed-width int64 padding, for the vectorized simulator."""
    out = np.zeros(width, dtype=np.int64)
    r = np.asarray(list(runs), dtype=np.int64)
    if len(r) > width:
        raise ValueError(f"batch has {len(r)} runs > width {width}")
    out[: len(r)] = r
    return out


@dataclass
class BatchMsg:
    """A batch in flight, with join/leave counters (Sec. IV)."""

    runs: List[int] = field(default_factory=empty)
    joins: int = 0   # B.j
    leaves: int = 0  # B.l

    def combined_with(self, other: "BatchMsg") -> "BatchMsg":
        return BatchMsg(
            runs=combine(self.runs, other.runs),
            joins=self.joins + other.joins,
            leaves=self.leaves + other.leaves,
        )

    @property
    def empty(self) -> bool:
        return is_empty(self.runs) and self.joins == 0 and self.leaves == 0
