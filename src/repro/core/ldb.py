"""Linearized De Bruijn network (paper Definition 2) + aggregation tree.

Each process ``v`` emulates three virtual nodes: left ``l(v)=m/2``, middle
``m(v)=hash01(v.id)`` and right ``r(v)=(m+1)/2``.  Virtual nodes are arranged
on a sorted cycle; linear edges connect consecutive labels, virtual edges
connect co-located nodes.  The aggregation tree (Sec. III-B) is derived
purely from local information:

  parent(middle) = l(v); parent(left) = pred; parent(right) = m(v)

so every parent hop strictly decreases the label and the global minimum (the
*anchor*) is the root.  Routing (Lemma 3) follows the continuous-discrete
De Bruijn rule ``z -> (z + b)/2`` which this class simulates hop-by-hop,
vectorized over many concurrent messages.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from .hashing import hash01

LEFT, MIDDLE, RIGHT = 0, 1, 2


@dataclass
class LDB:
    """Static LDB instance over ``n`` processes (ids 0..n-1 by default)."""

    n: int                      # number of processes
    labels: np.ndarray          # [3n] label of virtual node, sorted ascending
    kind: np.ndarray            # [3n] LEFT/MIDDLE/RIGHT
    proc: np.ndarray            # [3n] emulating process id
    co: np.ndarray              # [3n, 3] sorted-index of (l, m, r) of same proc
    parent: np.ndarray          # [3n] sorted-index of tree parent, -1 at anchor
    children: np.ndarray        # [3n, 2] sorted-indices, -1 padded
    n_children: np.ndarray      # [3n]
    anchor: int                 # sorted index of the leftmost node
    depth: np.ndarray           # [3n] distance to anchor along parent edges

    @property
    def size(self) -> int:
        return 3 * self.n

    # -- construction ------------------------------------------------------
    @staticmethod
    def build(n: int, proc_ids: Optional[np.ndarray] = None, salt: int = 0) -> "LDB":
        if n < 1:
            raise ValueError("need at least one process")
        ids = np.arange(n, dtype=np.uint64) if proc_ids is None else np.asarray(proc_ids, np.uint64)
        m = hash01(ids, salt=salt)
        # Perturb ties deterministically (labels must be unique).
        order = np.argsort(m, kind="stable")
        m_sorted = m[order]
        dup = np.concatenate([[False], np.diff(m_sorted) == 0])
        if dup.any():
            m_sorted = m_sorted + np.cumsum(dup) * 1e-15
            m[order] = m_sorted
        labels = np.concatenate([m / 2.0, m, (m + 1.0) / 2.0])
        kinds = np.concatenate([
            np.full(n, LEFT), np.full(n, MIDDLE), np.full(n, RIGHT)
        ]).astype(np.int8)
        procs = np.concatenate([np.arange(n)] * 3).astype(np.int64)
        srt = np.argsort(labels, kind="stable")
        labels, kinds, procs = labels[srt], kinds[srt], procs[srt]
        N = 3 * n
        # position of each original virtual node in the sorted order
        pos_of_orig = np.empty(N, dtype=np.int64)
        pos_of_orig[srt] = np.arange(N)
        co = np.stack([
            pos_of_orig[0 * n + np.arange(n)],   # l(v)
            pos_of_orig[1 * n + np.arange(n)],   # m(v)
            pos_of_orig[2 * n + np.arange(n)],   # r(v)
        ], axis=1)  # [n,3] by process id
        co_by_node = co[procs]  # [N,3]

        idx = np.arange(N)
        pred = (idx - 1) % N
        # parent rule (Sec. III-B)
        parent = np.where(
            kinds == MIDDLE, co_by_node[:, 0],
            np.where(kinds == LEFT, pred, co_by_node[:, 1]),
        ).astype(np.int64)
        anchor = 0  # sorted order => index 0 is the leftmost node
        parent[anchor] = -1
        # children: derived (and must mirror the parent rule exactly)
        children = np.full((N, 2), -1, dtype=np.int64)
        nch = np.zeros(N, dtype=np.int64)
        for v in range(N):
            p = parent[v]
            if p >= 0:
                children[p, nch[p]] = v
                nch[p] += 1
        # depth by pointer chasing in waves (labels strictly decrease => acyclic)
        depth = np.full(N, -1, dtype=np.int64)
        depth[anchor] = 0
        frontier = [anchor]
        d = 0
        while frontier:
            d += 1
            nxt = []
            for v in frontier:
                for c in children[v]:
                    if c >= 0:
                        depth[c] = d
                        nxt.append(int(c))
            frontier = nxt
        assert (depth >= 0).all(), "aggregation tree must span all nodes"
        return LDB(n=n, labels=labels, kind=kinds, proc=procs, co=co,
                   parent=parent, children=children, n_children=nch,
                   anchor=anchor, depth=depth)

    # -- DHT ownership ------------------------------------------------------
    def owner_of(self, keys: np.ndarray) -> np.ndarray:
        """Sorted-index of the node v with v <= k < succ(v) (consistent hashing)."""
        keys = np.asarray(keys, dtype=np.float64)
        j = np.searchsorted(self.labels, keys, side="right") - 1
        return np.where(j < 0, self.size - 1, j)  # wrap: pred of min = max node

    # -- De Bruijn routing (Lemma 3), vectorized ----------------------------
    def route_hops(self, src: np.ndarray, keys: np.ndarray,
                   rng: Optional[np.random.Generator] = None) -> np.ndarray:
        """Number of LDB hops for each message from node ``src[i]`` to the
        owner of ``keys[i]``: simulates the continuous-discrete De Bruijn
        descent ``z -> (z+b)/2`` (one virtual hop + O(1) expected linear hops
        per bit) followed by the final linear walk.  Returns int64 hops.
        """
        src = np.asarray(src, dtype=np.int64)
        keys = np.asarray(keys, dtype=np.float64)
        nbits = max(1, int(np.ceil(np.log2(max(2, self.size)))))
        cur = self.labels[src].copy()
        hops = np.zeros(len(src), dtype=np.int64)
        # extract target bits: keys = 0.b1 b2 b3 ...
        t = keys.copy()
        bits = []
        for _ in range(nbits):
            t = t * 2.0
            b = np.floor(t)
            bits.append(b)
            t -= b
        for i in range(nbits - 1, -1, -1):
            # De Bruijn hop toward prefix of target: z -> (z + b_i)/2
            cur = (cur + bits[i]) / 2.0
            # one virtual hop + expected O(1) linear hops to snap to the node
            # nearest the continuous point (distance ~ spacing of labels)
            hops += 1
        snapped = self.owner_of(cur)
        # final linear walk from snapped node to the key owner
        tgt = self.owner_of(keys)
        dist = np.abs(snapped - tgt)
        dist = np.minimum(dist, self.size - dist)  # cycle distance
        hops += dist
        return hops

    # -- scalar fast paths (hot in the event simulator) ----------------------
    def owner_of_scalar(self, key: float) -> int:
        j = int(np.searchsorted(self.labels, key, side="right")) - 1
        return self.size - 1 if j < 0 else j

    def route_hops_scalar(self, src: int, key: float) -> int:
        """Scalar version of :meth:`route_hops` (pure python, ~10x faster
        than the vectorized path for single messages)."""
        nbits = max(1, int(np.ceil(np.log2(max(2, self.size)))))
        cur = float(self.labels[src])
        t = float(key)
        bits = []
        for _ in range(nbits):
            t *= 2.0
            b = int(t)
            bits.append(b)
            t -= b
        for i in range(nbits - 1, -1, -1):
            cur = (cur + bits[i]) / 2.0
        snapped = self.owner_of_scalar(cur)
        tgt = self.owner_of_scalar(key)
        dist = abs(snapped - tgt)
        dist = min(dist, self.size - dist)
        return nbits + dist

    # -- invariant checks (used by tests) -----------------------------------
    def check_tree(self) -> None:
        N = self.size
        assert self.parent[self.anchor] == -1
        par = self.parent
        lab = self.labels
        mask = np.arange(N) != self.anchor
        assert (lab[par[mask]] < lab[mask]).all(), "parent labels must decrease"
        # children lists mirror parents
        for v in range(N):
            for c in self.children[v]:
                if c >= 0:
                    assert par[c] == v
        assert int(self.n_children.sum()) == N - 1
