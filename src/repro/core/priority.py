"""Host-side P-tier priority-queue oracle (Skeap's constant-priority regime).

The reference the device implementation is differentially tested against:
P independent SKUEUE position intervals — one ``[first_p, last_p]`` dense
window plus a position-keyed element store per tier — tie-broken by tier.
Wave semantics match ``core.scan_queue.priority_queue_scan`` exactly (and
are implemented independently of it, in plain Python over dicts, so the two
can disagree):

* all of a wave's enqueues apply before its dequeues (the PR 1
  PUT-before-GET rule lifted to tiers);
* the wave's dequeues drain the priority-ordered pool highest tier first,
  in wave order — the d-th dequeue gets the d-th best element (exactly the
  Skeap batch-DeleteMin assignment);
* with ``relaxation=k`` a dequeue issued at shard ``s`` may take the head
  of a tier up to ``k`` below the currently-best non-empty tier when that
  lower head is local (``head % n_shards == s``) and no better candidate
  head is — per-tier FIFO is never violated and the tier skew is bounded
  by k (arXiv:2503.02164's bounded-relaxation idea, specialized to tiers).

Sequential consistency across waves is by construction: each wave's
linearization is (enqueues in wave order, then dequeues in wave order),
and waves append to one total order.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

BOTTOM = -1
ENQ, DEQ = "enq", "deq"


@dataclass
class OpRecord:
    """Per-op oracle verdict: tier/pos are -1 for unmatched dequeues."""
    tier: int
    pos: int
    matched: bool
    value: Optional[int] = None   # dequeues only: the element taken
    relaxed: bool = False         # served from below the strictly-best tier


class PriorityOracle:
    """Sequentially consistent P-tier priority queue over integer elements.

    ``wave(ops, n_shards=...)`` consumes one wave of operations —
    ``(kind, prio, elem, shard)`` tuples (or None for padding) in global
    wave order — and returns one :class:`OpRecord` per op.
    """

    def __init__(self, n_prios: int, relaxation: int = 0):
        if n_prios < 1:
            raise ValueError("need at least one priority tier")
        self.P = n_prios
        self.k = relaxation
        self.firsts = [0] * n_prios
        self.lasts = [-1] * n_prios
        self.store: List[dict] = [dict() for _ in range(n_prios)]

    # ------------------------------------------------------------ queries --
    @property
    def sizes(self) -> List[int]:
        return [l - f + 1 for f, l in zip(self.firsts, self.lasts)]

    @property
    def size(self) -> int:
        return sum(self.sizes)

    # ------------------------------------------------------------- waves ---
    def wave(self, ops: Sequence[Optional[Tuple]], n_shards: int = 1
             ) -> List[OpRecord]:
        recs: List[Optional[OpRecord]] = [None] * len(ops)
        # ---- enqueues first (per-tier FIFO append) ----
        for i, op in enumerate(ops):
            if op is None:
                recs[i] = OpRecord(-1, BOTTOM, False)
                continue
            kind, prio, elem, _shard = op
            if kind == ENQ:
                if not 0 <= prio < self.P:
                    raise ValueError(f"priority {prio} outside [0, {self.P})")
                self.lasts[prio] += 1
                self.store[prio][self.lasts[prio]] = elem
                recs[i] = OpRecord(prio, self.lasts[prio], True)
        # ---- dequeues drain highest-priority-first, in wave order ----
        taken = [0] * self.P
        for i, op in enumerate(ops):
            if op is None or op[0] != DEQ:
                continue
            shard = op[3]
            sizes = [self.lasts[p] - self.firsts[p] + 1 - taken[p]
                     for p in range(self.P)]
            nonempty = [p for p in range(self.P) if sizes[p] > 0]
            if not nonempty:
                recs[i] = OpRecord(-1, BOTTOM, False)
                continue
            pstar = nonempty[0]
            q = pstar
            if self.k > 0:
                for cand in range(pstar, min(pstar + self.k, self.P - 1) + 1):
                    if (sizes[cand] > 0 and
                            (self.firsts[cand] + taken[cand]) % n_shards
                            == shard):
                        q = cand
                        break
            pos = self.firsts[q] + taken[q]
            taken[q] += 1
            recs[i] = OpRecord(q, pos, True, value=self.store[q].pop(pos),
                               relaxed=(q != pstar))
        for p in range(self.P):
            self.firsts[p] += taken[p]
        return recs
