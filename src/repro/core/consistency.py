"""Sequential-consistency checker (paper Definition 1, Theorems 14/21).

Strategy: the protocol materializes ``value(op)`` (the paper's Section-V
virtual-counter order ``≺``) for every processed request.  We *replay* all
requests in increasing ``value`` order against a reference sequential
queue/stack and demand that every request's protocol result is identical to
the reference result.  Replay equality implies Definition-1 properties 1–3
(FIFO matching, no skipped elements, no crossing matchings); property 4
(per-source program order embeds into ``≺``) is checked directly.

Locally-combined stack pairs (Sec. VI local pairing, ``order == -1``) are
net-zero on the stack and provably placeable adjacently anywhere consistent
with program order; they are validated pairwise instead of replayed.
"""
from __future__ import annotations

from collections import deque
from typing import List

from .intervals import BOTTOM
from .protocol import Skueue


class ConsistencyViolation(AssertionError):
    pass


def check_sequential_consistency(sk: Skueue) -> dict:
    reqs = [r for r in sk.requests if r.done]
    if any(not r.done for r in sk.requests):
        raise ConsistencyViolation("unfinished requests — run to quiescence first")

    paired = [r for r in reqs if r.order == -1]
    global_reqs = [r for r in reqs if r.order != -1]

    # locally-combined pairs: pop must return the locally paired push's element
    pops = [r for r in paired if r.kind == "deq"]
    pushes = {r.elem: r for r in paired if r.kind == "enq"}
    for p in pops:
        if p.result not in pushes:
            raise ConsistencyViolation(f"local pair mismatch for request {p.rid}")

    # uniqueness of the order values
    orders = [r.order for r in global_reqs]
    if len(set(orders)) != len(orders):
        raise ConsistencyViolation("value(op) not unique")

    # property 4: per-source program order embeds into ≺
    by_node: dict = {}
    for r in sk.requests:  # use full issue sequence, in issue order (rid order)
        by_node.setdefault(r.node, []).append(r)
    for node, seq in by_node.items():
        vals = [r.order for r in seq if r.order is not None and r.order != -1]
        if any(b <= a for a, b in zip(vals, vals[1:])):
            raise ConsistencyViolation(f"program order violated at node {node}")

    # properties 1-3 via replay
    global_reqs.sort(key=lambda r: r.order)
    if sk.mode == "queue":
        ref: deque = deque()
        for r in global_reqs:
            if r.kind == "enq":
                ref.append(r.elem)
            else:
                expect = ref.popleft() if ref else BOTTOM
                if r.result != expect:
                    raise ConsistencyViolation(
                        f"queue replay mismatch at rid={r.rid}: "
                        f"protocol={r.result} reference={expect}")
    else:
        ref_stack: List[int] = []
        for r in global_reqs:
            if r.kind == "enq":
                ref_stack.append(r.elem)
            else:
                expect = ref_stack.pop() if ref_stack else BOTTOM
                if r.result != expect:
                    raise ConsistencyViolation(
                        f"stack replay mismatch at rid={r.rid}: "
                        f"protocol={r.result} reference={expect}")

    return {
        "n_requests": len(reqs),
        "n_locally_paired": len(paired),
        "max_batch_runs": sk.stats_batch_max_runs,
        "total_msgs": sk.total_msgs,
    }
