"""Stages 2+3: anchor position-interval assignment and tree decomposition.

Queue (Sec. III-D/E): the anchor keeps ``(first, last)`` with the invariant
``first <= last + 1``; the occupied positions are ``[first, last]``.  For a
combined batch ``(op_1, ..., op_k)``:

  enqueue run i: interval [last+1, last+op_i];            last += op_i
  dequeue run i: interval [first, min(first+op_i-1,last)]; first = min(first+op_i, last+1)

Decomposition hands each sub-batch (in combination order) the leading slice
of the run interval; dequeue runs clamp at y (⊥ beyond).

Stack (Sec. VI): anchor keeps ``(last, ticket)``; pushes get
``([last+1, last+op], tickets ticket+1..)``; pops take from the TOP:
``[max(1, last-op+1), last]`` served in descending position order, each pop
also carrying the ticket bound ``t' = ticket`` at assignment time.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

BOTTOM = -1  # ⊥ position for unmatched dequeues / pops


@dataclass
class AnchorState:
    first: int = 0   # queue head position
    last: int = -1   # queue tail position (first > last  <=>  empty)
    ticket: int = 0  # stack only: monotone push counter

    @property
    def size(self) -> int:
        return self.last - self.first + 1


Interval = Tuple[int, int]  # inclusive [x, y]; empty iff x > y


# ----------------------------------------------------------------- queue ---
def assign_queue(state: AnchorState, runs: Sequence[int]) -> List[Interval]:
    """Stage 2 at the anchor. Mutates ``state``; returns per-run intervals."""
    out: List[Interval] = []
    for i, op in enumerate(runs):
        op = int(op)
        if i % 2 == 0:  # enqueue run
            out.append((state.last + 1, state.last + op))
            state.last += op
        else:           # dequeue run
            y = min(state.first + op - 1, state.last)
            out.append((state.first, y))
            state.first = min(state.first + op, state.last + 1)
    return out


def decompose_queue(intervals: Sequence[Interval],
                    parts: Sequence[Sequence[int]]) -> List[List[Interval]]:
    """Stage 3 at one tree node: split run intervals across sub-batches.

    ``parts`` are the memorized sub-batches in combination order (own ops
    first, then each child).  Returns per-part run-interval lists aligned
    with each part's runs.
    """
    cursors = [list(iv) for iv in intervals]  # mutable [x, y]
    out: List[List[Interval]] = []
    for part in parts:
        sub: List[Interval] = []
        for i, op in enumerate(part):
            op = int(op)
            if i >= len(cursors):
                if op:
                    raise ValueError("sub-batch longer than combined batch")
                sub.append((0, -1))
                continue
            x, y = cursors[i]
            if i % 2 == 0:  # enqueue: leading slice, never clamped
                sub.append((x, x + op - 1))
                cursors[i][0] = x + op
            else:           # dequeue: clamp at y; beyond y means ⊥
                hi = min(x + op - 1, y)
                sub.append((x, hi))
                cursors[i][0] = min(x + op, y + 1)
        out.append(sub)
    return out


def positions_queue(run_intervals: Sequence[Interval],
                    runs: Sequence[int]) -> List[int]:
    """Per-request positions for a leaf part (local op order). ⊥ = BOTTOM."""
    pos: List[int] = []
    for i, op in enumerate(runs):
        x, y = run_intervals[i]
        for j in range(int(op)):
            p = x + j
            if i % 2 == 0:
                pos.append(p)
            else:
                pos.append(p if p <= y else BOTTOM)
    return pos


# ----------------------------------------------------------------- stack ---
def assign_stack(state: AnchorState, runs: Sequence[int]) -> List[Tuple[Interval, int]]:
    """Stage 2 for the stack. Runs alternate PUSH (even) / POP (odd).

    Returns per-run ``((x, y), ticket_info)``: for pushes the tickets are
    ``ticket+1 .. ticket+op`` base-aligned with positions; for pops the
    single ticket *bound* t' (paper: remove element with max ticket <= t').
    """
    out: List[Tuple[Interval, int]] = []
    for i, op in enumerate(runs):
        op = int(op)
        if i % 2 == 0:  # push run
            out.append(((state.last + 1, state.last + op), state.ticket + 1))
            state.last += op
            state.ticket += op
        else:           # pop run: take from the top, descending
            x = max(1, state.last - op + 1) if state.last >= 1 else 1
            y = state.last
            out.append(((x, y), state.ticket))
            state.last = max(0, state.last - op)
    return out


def decompose_stack(run_info: Sequence[Tuple[Interval, int]],
                    parts: Sequence[Sequence[int]]) -> List[List[Tuple[Interval, int]]]:
    """Stage 3 for the stack. Pops consume the TOP of the interval first."""
    cursors = [[iv[0], iv[1]] for iv, _ in run_info]
    tickets = [t for _, t in run_info]
    out: List[List[Tuple[Interval, int]]] = []
    for part in parts:
        sub: List[Tuple[Interval, int]] = []
        for i, op in enumerate(part):
            op = int(op)
            if i >= len(cursors):
                sub.append(((0, -1), 0))
                continue
            x, y = cursors[i]
            if i % 2 == 0:  # push: leading slice; ticket base shifts with x
                base = tickets[i] + (x - run_info[i][0][0])
                sub.append(((x, x + op - 1), base))
                cursors[i][0] = x + op
            else:           # pop: trailing (top) slice, descending
                lo = max(x, y - op + 1)
                sub.append(((lo, y), tickets[i]))
                cursors[i][1] = max(y - op, x - 1)
        out.append(sub)
    return out


def positions_stack(run_info: Sequence[Tuple[Interval, int]],
                    runs: Sequence[int]) -> List[Tuple[int, int]]:
    """Per-request (position, ticket) for a leaf part.  For pushes ticket is
    the unique element ticket; for pops it is the bound t'.  ⊥ = BOTTOM pos."""
    out: List[Tuple[int, int]] = []
    for i, op in enumerate(runs):
        (x, y), t = run_info[i]
        if i % 2 == 0:
            for j in range(int(op)):
                out.append((x + j, t + j))
        else:
            # pops are served top-first: y, y-1, ...
            for j in range(int(op)):
                p = y - j
                out.append((p, t) if p >= x and p >= 1 else (BOTTOM, t))
    return out
