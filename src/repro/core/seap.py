"""Host-side arbitrary-priority queue oracle (Seap's bucket-directory regime).

Seap (arXiv:1805.03472, second half) extends Skeap from constant priority
tiers to **arbitrary priority keys** by maintaining a distributed search
structure over the tier set.  On the fused device path that search tree
collapses to a **two-level bucket directory**: B bucket ids, each owning a
fixed slot window of the sharded ring store, plus a replicated table of
lower key boundaries.  A key is served by the active bucket with the
largest boundary ``lo <= key`` (predecessor lookup); dequeues drain buckets
in ascending boundary order, FIFO within a bucket (the batch-DeleteMin
assignment over the directory).  The directory is rebalanced by a cheap
in-wave split/merge rule — no element ever moves between windows:

* **split**: when an active bucket's occupancy exceeds ``split_occupancy``
  and a free bucket id exists, the fullest such bucket's key range is
  halved — at the floor average of its range *clamped to the observed
  (min, max) enqueued keys*, so refinement lands among live keys instead
  of bisecting the int32 universe — and the upper half is assigned to the
  lowest free id; at most one per wave, and only when the midpoint falls
  strictly inside the range;
* **merge (on demand)**: when a split wants an id and none is free, the
  lowest-id active *empty* non-root bucket is deactivated (its key range
  folds into its predecessor) and its id recycled; at most one per wave.
  Empty buckets are otherwise left alone — they are harmless future
  structure, and eagerly dismantling them would leave the directory
  coarse exactly when the next burst needs it refined.

Existing elements never move, so a split leaves the old bucket's already-
stored upper-half keys ahead of the new bucket — priority order is
therefore **bucket-granular**: inversions are bounded by the width of the
key range a bucket held when the element entered, and within a bucket
FIFO always holds.  This is the documented relaxation of the exact Seap
DeleteMin, traded for waves that stay two collectives and a rebalance that
is pure replicated arithmetic.

This class is the reference the device implementation
(``repro.dqueue.DeviceSeapQueue``) is differentially tested against: the
same wave semantics — all of a wave's enqueues apply before its dequeues,
then the rebalance — implemented independently in plain Python over
key-sorted bucket dicts, so the two can disagree.  Sequential consistency
across waves is by construction: each wave's linearization is (enqueues in
wave order, then dequeues in wave order), and waves append to one total
order.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

BOTTOM = -1
INT32_MIN = -(2 ** 31)
INT32_MAX = 2 ** 31 - 1
ENQ, DEQ = "enq", "deq"


def check_seed_bounds(seed_bounds, n_buckets: int) -> list:
    """Validate a warm-start boundary list for the bucket directory.

    The directory starts as just the root (every key in one bucket) and
    only refines as occupancy forces splits, so a cold start serves in
    near-FIFO order until the split rule has zoomed in.  Seeding plants
    boundaries over the expected key range up front — the in-wave
    split/merge rule then *rolls* the refined window as the key
    distribution drifts (drained buckets merge away, over-full ones
    split).  Bounds must be strictly increasing, above ``INT32_MIN``
    (the root's boundary), and fit in the non-root bucket ids.
    """
    seeds = [int(s) for s in (seed_bounds or [])]
    if len(seeds) > n_buckets - 1:
        raise ValueError(f"{len(seeds)} seed bounds need at least "
                         f"{len(seeds) + 1} buckets, have {n_buckets}")
    if any(b <= a for a, b in zip(seeds, seeds[1:])):
        raise ValueError(f"seed bounds must be strictly increasing: {seeds}")
    if seeds and not INT32_MIN < seeds[0] <= INT32_MAX:
        raise ValueError(f"seed bounds must lie in (INT32_MIN, INT32_MAX]: "
                         f"{seeds}")
    return seeds


@dataclass
class SeapOpRecord:
    """Per-op oracle verdict: bucket/pos are -1 for unmatched dequeues."""
    bucket: int
    pos: int
    matched: bool
    value: Optional[int] = None   # dequeues only: the element taken
    key: Optional[int] = None     # dequeues only: the key of that element


class SeapOracle:
    """Sequentially consistent bucket-directory priority queue over int32
    keys.  ``wave(ops)`` consumes one wave of operations — ``(kind, key,
    elem)`` tuples (or None for padding) in global wave order — and returns
    one :class:`SeapOpRecord` per op.  ``split_occupancy`` must equal the
    device queue's threshold for differential runs.
    """

    def __init__(self, n_buckets: int, split_occupancy: int,
                 seed_bounds: Optional[Sequence[int]] = None):
        if n_buckets < 1:
            raise ValueError("need at least one bucket")
        self.B = n_buckets
        self.split_occupancy = split_occupancy
        self.lo = [INT32_MAX] * n_buckets
        self.lo[0] = INT32_MIN               # bucket 0 is the root
        self.active = [False] * n_buckets
        self.active[0] = True
        for i, s in enumerate(check_seed_bounds(seed_bounds, n_buckets)):
            self.lo[1 + i] = s
            self.active[1 + i] = True
        self.firsts = [0] * n_buckets
        self.lasts = [-1] * n_buckets
        self.store: List[dict] = [dict() for _ in range(n_buckets)]
        self.keys: List[dict] = [dict() for _ in range(n_buckets)]
        self.key_lo = INT32_MAX       # observed key range (empty so far)
        self.key_hi = INT32_MIN
        self.n_splits = 0
        self.n_merges = 0

    # ------------------------------------------------------------ queries --
    @property
    def sizes(self) -> List[int]:
        return [l - f + 1 for f, l in zip(self.firsts, self.lasts)]

    @property
    def size(self) -> int:
        return sum(self.sizes)

    @property
    def n_active(self) -> int:
        return sum(self.active)

    def directory(self) -> List[Tuple[int, int]]:
        """Active (lo, bucket_id) entries in ascending key order."""
        return sorted((self.lo[b], b)
                      for b in range(self.B) if self.active[b])

    def _bucket_of(self, key: int) -> int:
        """Predecessor lookup: active bucket with the largest lo <= key."""
        best, best_lo = 0, INT32_MIN
        for b in range(self.B):
            if self.active[b] and self.lo[b] <= key and self.lo[b] >= best_lo:
                # distinct active boundaries -> >= only ties at the root
                best, best_lo = b, self.lo[b]
        return best

    # ------------------------------------------------------------- waves ---
    def wave(self, ops: Sequence[Optional[Tuple]]) -> List[SeapOpRecord]:
        recs: List[Optional[SeapOpRecord]] = [None] * len(ops)
        # ---- enqueues first (bucket lookup + per-bucket FIFO append) ----
        for i, op in enumerate(ops):
            if op is None:
                recs[i] = SeapOpRecord(-1, BOTTOM, False)
                continue
            kind, key, elem = op
            if kind == ENQ:
                if not INT32_MIN <= key <= INT32_MAX:
                    raise ValueError(f"key {key} outside int32")
                b = self._bucket_of(key)
                self.lasts[b] += 1
                self.store[b][self.lasts[b]] = elem
                self.keys[b][self.lasts[b]] = key
                self.key_lo = min(self.key_lo, key)
                self.key_hi = max(self.key_hi, key)
                recs[i] = SeapOpRecord(b, self.lasts[b], True)
        # ---- dequeues drain buckets in boundary order, FIFO inside ----
        order = [b for _, b in self.directory()]
        taken = [0] * self.B
        for i, op in enumerate(ops):
            if op is None or op[0] != DEQ:
                continue
            b = next((q for q in order
                      if self.lasts[q] - self.firsts[q] + 1 - taken[q] > 0),
                     None)
            if b is None:
                recs[i] = SeapOpRecord(-1, BOTTOM, False)
                continue
            pos = self.firsts[b] + taken[b]
            taken[b] += 1
            recs[i] = SeapOpRecord(b, pos, True,
                                   value=self.store[b].pop(pos),
                                   key=self.keys[b].pop(pos))
        for b in range(self.B):
            self.firsts[b] += taken[b]
        self._rebalance()
        return recs

    # --------------------------------------------------------- rebalance ---
    def _rebalance(self):
        """The in-wave split/merge rule (must mirror the device exactly)."""
        sizes = self.sizes
        over = [self.active[b] and sizes[b] > self.split_occupancy
                for b in range(self.B)]
        # merge-on-demand: an empty bucket's id is recycled only when a
        # split wants an id and none is free (empty buckets are harmless
        # future structure; eager merging would dismantle the directory
        # between bursts); lowest-id candidate, at most one per wave
        if any(over) and all(self.active):
            for b in range(self.B):
                if (self.active[b] and sizes[b] == 0
                        and self.lo[b] != INT32_MIN):
                    self.active[b] = False
                    self.n_merges += 1
                    break
        # split: fullest over-threshold bucket into the lowest free id;
        # the halving is clamped to the OBSERVED key range so the zoom
        # lands among live keys instead of descending from INT32_MAX
        if any(over) and not all(self.active):
            b_s = max(range(self.B),
                      key=lambda b: (sizes[b] if over[b] else -1, -b))
            hi = min([self.lo[b] for b in range(self.B)
                      if self.active[b] and self.lo[b] > self.lo[b_s]],
                     default=INT32_MAX)
            lo_eff = max(self.lo[b_s],
                         self.key_lo - 1 if self.key_lo > INT32_MIN
                         else INT32_MIN)
            hi_eff = min(hi, self.key_hi + 1 if self.key_hi < INT32_MAX
                         else INT32_MAX)
            mid = (lo_eff + hi_eff) // 2         # floor average, no overflow
            if self.lo[b_s] < mid < hi:
                b_f = self.active.index(False)
                self.lo[b_f] = mid
                self.active[b_f] = True
                self.n_splits += 1
