"""Priority-tiered serving: SLA tiers through the device priority queue.

Three demos of the PR 3 subsystem (Skeap's constant-priority regime on the
fused wave path):

  §1 raw ``DevicePriorityQueue``: a batch flood then interactive arrivals —
     the wave serves tier 0 first, sequential consistency intact;
  §2 ``ServeEngine(priorities=2)``: mixed LM traffic, per-tier admission
     latency from ``tier_wait_stats()``;
  §3 ``relaxation=k``: the bounded tier-relaxation knob — dequeues take a
     locally-owned lower-tier head instead of a remote best-tier head, and
     the wave reports how many did.

Run:  PYTHONPATH=src python examples/priority_serving.py
(re-run under XLA_FLAGS=--xla_force_host_platform_device_count=4 to see the
multi-shard layout; works on any device count.)
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.compat import make_mesh
from repro.dqueue import DevicePriorityQueue


def section_1_priority_wave():
    print("== §1 priority wave: interactive ahead of a batch flood ==")
    n_dev = len(jax.devices())
    mesh = make_mesh((n_dev,), ("data",))
    # the wave must fit the 12-element flood on ANY device count
    q = DevicePriorityQueue(mesh, "data", n_prios=2, cap=64,
                            payload_width=1,
                            ops_per_shard=max(8, -(-12 // n_dev)))
    n = q.n_shards * q.L
    state = q.init_state()

    # wave 1: flood tier 1 (batch) with 12 elements
    e = np.zeros(n, bool)
    v = np.zeros(n, bool)
    pr = np.ones(n, np.int32)
    pw = np.zeros((n, 1), np.int32)
    e[:12] = v[:12] = True
    pw[:12, 0] = 1000 + np.arange(12)
    state, *_ = q.step(state, jnp.array(e), jnp.array(v), jnp.array(pr),
                       jnp.array(pw))

    # wave 2: 3 interactive arrivals + 6 dequeues in ONE fused wave
    e = np.zeros(n, bool)
    v = np.zeros(n, bool)
    pr = np.zeros(n, np.int32)
    pw = np.zeros((n, 1), np.int32)
    e[:3] = v[:3] = True
    pw[:3, 0] = 1 + np.arange(3)       # interactive ids 1..3
    v[3:9] = True                      # 6 dequeues
    state, tier, pos, m, dv, dok, ovf, _ = q.step(
        state, jnp.array(e), jnp.array(v), jnp.array(pr), jnp.array(pw))
    served = [(int(t), int(val[0])) for t, ok, val in
              zip(np.asarray(tier)[3:9], np.asarray(dok)[3:9],
                  np.asarray(dv)[3:9]) if ok]
    print(f"   6 dequeues served (tier, id): {served}")
    print(f"   -> the 3 same-wave interactive arrivals went first, then "
          f"batch FIFO order\n")


def section_2_engine_tiers():
    print("== §2 ServeEngine(priorities=2): per-tier admission latency ==")
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.serve import Request, ServeEngine

    cfg = get_config("mamba2_130m").reduced(n_layers=1)
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.key(0))
    eng = ServeEngine(model, params, make_host_mesh(n_data=1), max_slots=2,
                      max_seq=16, priorities=2)
    batch = [Request(rid=i, prompt=[7, 8], max_new=2, prio=1)
             for i in range(8)]
    inter = [Request(rid=100 + i, prompt=[5, 6], max_new=2, prio=0)
             for i in range(3)]
    eng.submit(batch)      # batch flood staged first
    eng.submit(inter)      # interactive arrives after — still admitted first
    assert eng.run_until_drained(max_steps=400)
    for p, st in sorted(eng.tier_wait_stats().items()):
        name = "interactive" if p == 0 else "batch"
        print(f"   tier {p} ({name:11s}): n={st['n']} mean={st['mean']:.1f} "
              f"p50={st['p50']:.1f} p99={st['p99']:.1f} steps")
    print()


def section_3_relaxation():
    print("== §3 relaxation=k: locally-served lower-tier dequeues ==")
    n_dev = len(jax.devices())
    mesh = make_mesh((n_dev,), ("data",))
    rng = np.random.default_rng(0)
    for k in (0, 1):
        q = DevicePriorityQueue(mesh, "data", n_prios=2, cap=64,
                                payload_width=1, ops_per_shard=8,
                                relaxation=k)
        n = q.n_shards * q.L
        state = q.init_state()
        relaxed = 0
        for _ in range(8):
            e = rng.random(n) < 0.55
            v = rng.random(n) < 0.9
            pr = rng.integers(0, 2, n).astype(np.int32)
            pw = rng.integers(0, 1000, (n, 1)).astype(np.int32)
            state, *out = q.step(state, jnp.array(e), jnp.array(v),
                                 jnp.array(pr), jnp.array(pw))
            relaxed += int(out[-1])
        print(f"   relaxation={k}: {relaxed} dequeues served from a "
              f"locally-owned lower-tier head")
    print("   (k=0 is strict priority order; k=1 trades bounded tier skew "
          "for locality)")


if __name__ == "__main__":
    section_1_priority_wave()
    section_2_engine_tiers()
    section_3_relaxation()
