"""Elastic scaling, both layers of the system:

1. the PAPER's JOIN/LEAVE: processes enter/leave the running queue overlay
   mid-traffic (update phases, anchor handoff, DHT data movement), with
   sequential consistency preserved throughout;
2. the FRAMEWORK's elastic path: a checkpoint written under one device
   layout restored under another (consistent-hash analogue for model state).

Run:  PYTHONPATH=src python examples/elastic_scaling.py
"""
import tempfile

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.consistency import check_sequential_consistency
from repro.core.protocol import DEQ, ENQ, Skueue


def main():
    # --- 1. protocol-level churn -------------------------------------------
    sk = Skueue(6, mode="queue", seed=1)
    rng = np.random.default_rng(2)

    def inject(s, rnd):
        nids = s.ring.node_ids()
        if rnd % 2 == 0 and rnd <= 120:
            s.inject(nids[int(rng.integers(len(nids)))],
                     ENQ if rng.random() < 0.6 else DEQ)
        if rnd == 10:
            print("  round 10: process 6 JOINs")
            s.request_join()
        if rnd == 30:
            print("  round 30: process 7 JOINs")
            s.request_join()
        if rnd == 50:
            print("  round 50: process 2 LEAVEs")
            s.request_leave(2)

    sk.run_rounds(220, inject_fn=inject)
    stats = check_sequential_consistency(sk)
    sk.check_dht_placement()
    procs = sorted(set(sk.ring.proc[n] for n in sk.ring.node_ids()))
    print(f"[protocol] consistent through churn: {stats['n_requests']} reqs, "
          f"{sk.update_phases} update phases, processes now {procs}")

    # --- 2. framework-level elastic reshard ---------------------------------
    from repro.checkpoint import restore_sharded, save_checkpoint
    x = jnp.arange(128.0).reshape(8, 16)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"w": x})
        from repro.compat import make_mesh
        mesh = make_mesh((1,), ("data",))
        from jax.sharding import NamedSharding, PartitionSpec as P
        sh = {"w": NamedSharding(mesh, P("data", None))}
        restored, _ = restore_sharded(d, 1, {"w": x}, sh)
        ok = bool(jnp.all(restored["w"] == x))
    print(f"[elastic]  checkpoint resharded onto a different mesh: ok={ok}")


if __name__ == "__main__":
    main()
