"""Elastic scaling, all three layers of the system:

1. the PAPER's JOIN/LEAVE: processes enter/leave the running queue overlay
   mid-traffic (update phases, anchor handoff, DHT data movement), with
   sequential consistency preserved throughout;
2. the FRAMEWORK's elastic path: a checkpoint written under one device
   layout restored under another (consistent-hash analogue for model state);
3. the DEVICE path's JOIN/LEAVE (PR 2): an ``ElasticDeviceQueue`` grows and
   shrinks its shard mesh mid-traffic — one packed all_to_all migration
   wave per membership change, FIFO order and every in-flight element
   preserved.

Run:  PYTHONPATH=src python examples/elastic_scaling.py
"""
import os
import tempfile

# section 3 needs a multi-device mesh; force CPU devices before jax inits
os.environ.setdefault("JAX_PLATFORMS", "cpu")
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.consistency import check_sequential_consistency
from repro.core.protocol import DEQ, ENQ, Skueue


def main():
    # --- 1. protocol-level churn -------------------------------------------
    sk = Skueue(6, mode="queue", seed=1)
    rng = np.random.default_rng(2)

    def inject(s, rnd):
        nids = s.ring.node_ids()
        if rnd % 2 == 0 and rnd <= 120:
            s.inject(nids[int(rng.integers(len(nids)))],
                     ENQ if rng.random() < 0.6 else DEQ)
        if rnd == 10:
            print("  round 10: process 6 JOINs")
            s.request_join()
        if rnd == 30:
            print("  round 30: process 7 JOINs")
            s.request_join()
        if rnd == 50:
            print("  round 50: process 2 LEAVEs")
            s.request_leave(2)

    sk.run_rounds(220, inject_fn=inject)
    stats = check_sequential_consistency(sk)
    sk.check_dht_placement()
    procs = sorted(set(sk.ring.proc[n] for n in sk.ring.node_ids()))
    print(f"[protocol] consistent through churn: {stats['n_requests']} reqs, "
          f"{sk.update_phases} update phases, processes now {procs}")

    # --- 2. framework-level elastic reshard ---------------------------------
    from repro.checkpoint import restore_sharded, save_checkpoint
    x = jnp.arange(128.0).reshape(8, 16)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"w": x})
        from repro.compat import make_mesh
        mesh = make_mesh((1,), ("data",))
        from jax.sharding import NamedSharding, PartitionSpec as P
        sh = {"w": NamedSharding(mesh, P("data", None))}
        restored, _ = restore_sharded(d, 1, {"w": x}, sh)
        ok = bool(jnp.all(restored["w"] == x))
    print(f"[elastic]  checkpoint resharded onto a different mesh: ok={ok}")

    # --- 3. device-path live resharding (PR 2) ------------------------------
    if len(jax.devices()) < 4:
        print("[device]   skipped (needs >= 4 devices)")
        return
    from repro.dqueue import ElasticDeviceQueue
    eq = ElasticDeviceQueue(2, cap=64, payload_width=2, ops_per_shard=8,
                            hlo_stats=True)
    sent, got = 0, []

    def traffic(n_enq, n_deq):
        """One wave at the queue's CURRENT width (it changes under us)."""
        nonlocal sent
        n = eq.n_shards * eq.L
        e = np.zeros(n, bool)
        v = np.zeros(n, bool)
        pw = np.zeros((n, 2), np.int32)
        n_enq, n_deq = min(n_enq, n), min(n_deq, n - n_enq)
        e[:n_enq] = v[:n_enq] = True
        pw[:n_enq, 0] = np.arange(sent, sent + n_enq)
        v[n_enq:n_enq + n_deq] = True
        sent += n_enq
        _, _, dv, dok, _ = eq.step(e, v, pw)
        dv, dok = np.asarray(dv), np.asarray(dok)
        got.extend(int(dv[i, 0]) for i in range(n) if dok[i])

    traffic(16, 0)                      # load up on 2 shards
    traffic(16, 4)
    s = eq.grow(2)                      # JOIN: 2 -> 4 shards, live
    print(f"[device]   grow  {s['P_from']}->{s['P_to']}: moved {s['moved']} "
          f"elems in {s['collectives']} collective(s), "
          f"{s['wave_s'] * 1e3:.1f} ms wave")
    traffic(16, 8)                      # keep the traffic flowing
    s = eq.shrink([1])                  # LEAVE of shard 1: 4 -> 3 shards
    print(f"[device]   LEAVE {s['P_from']}->{s['P_to']}: moved {s['moved']} "
          f"elems in {s['collectives']} collective(s), "
          f"{s['wave_s'] * 1e3:.1f} ms wave")
    while len(got) < sent:              # drain on the resized mesh
        traffic(0, eq.n_shards * eq.L)
    assert got == list(range(sent)), "FIFO broken by resharding!"
    print(f"[device]   {sent} elements dequeued in exact FIFO order through "
          f"grow+LEAVE; final mesh {eq.n_shards} shards")


if __name__ == "__main__":
    main()
