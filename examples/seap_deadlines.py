"""Arbitrary priorities and deadline scheduling: the Seap discipline.

Three demos of the PR 5 subsystem (Seap's arbitrary-key regime on the
fused wave path, arXiv:1805.03472 second half):

  §1 raw ``DeviceSeapQueue``: int32 keys, served smallest-key-first at
     bucket granularity — watch the directory split as one key range
     fills and merge as it drains;
  §2 the bucket directory as a *rolling window*: deadline-like monotone
     keys — drained past buckets merge away while the future range
     splits, so the refinement follows the live keys;
  §3 ``ServeEngine(deadline=True)``: earliest-deadline-first LM admission
     with miss-rate reporting from ``deadline_stats()``.

Run:  PYTHONPATH=src python examples/seap_deadlines.py
(re-run under XLA_FLAGS=--xla_force_host_platform_device_count=4 to see
the multi-shard layout; works on any device count.)
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.compat import make_mesh
from repro.dqueue import DeviceSeapQueue


def section_1_arbitrary_keys():
    print("== §1 arbitrary keys: smallest key served first ==")
    n_dev = len(jax.devices())
    mesh = make_mesh((n_dev,), ("data",))
    # directory seeded at 0 and 256: keys < 0 / [0, 256) / >= 256
    q = DeviceSeapQueue(mesh, "data", n_buckets=4, cap=64, payload_width=1,
                        ops_per_shard=max(8, -(-12 // n_dev)),
                        seed_bounds=[0, 256])
    n = q.n_shards * q.L
    state = q.init_state()

    # wave 1: enqueue 12 elements with scattered keys
    keys = np.array([700, -3, 250, 9, 512, -88, 31, 400, 5, 123, 777, -1])
    e = np.zeros(n, bool)
    v = np.zeros(n, bool)
    ky = np.zeros(n, np.int32)
    pw = np.zeros((n, 1), np.int32)
    e[:12] = v[:12] = True
    ky[:12] = keys
    pw[:12, 0] = keys          # payload = key, to see the serve order
    state, *_ = q.step(state, jnp.array(e), jnp.array(v),
                       jnp.array(ky), jnp.array(pw))
    print(f"  enqueued keys (arrival order): {keys.tolist()}")

    # wave 2: 12 dequeues drain the directory in boundary order
    e = np.zeros(n, bool)
    v = np.zeros(n, bool)
    v[:12] = True
    state, _, _, _, dv, dok, _, _ = q.step(state, jnp.array(e),
                                           jnp.array(v), jnp.array(ky),
                                           jnp.array(pw))
    dv, dok = np.asarray(dv), np.asarray(dok)
    served = [int(dv[i, 0]) for i in range(n) if dok[i]]
    print(f"  served order:                  {served}")
    print("  (buckets [<0 | 0..255 | >=256] in key order; FIFO inside a "
          "bucket)")


def section_2_rolling_window():
    print("== §2 deadline-like keys: the directory rolls forward ==")
    n_dev = len(jax.devices())
    mesh = make_mesh((n_dev,), ("data",))
    q = DeviceSeapQueue(mesh, "data", n_buckets=4, cap=64, payload_width=1,
                        ops_per_shard=max(16, -(-16 // n_dev)),
                        split_occupancy=6, seed_bounds=[8, 16, 24])
    n = q.n_shards * q.L
    state = q.init_state()
    t = 0
    for epoch in range(6):
        # keys advance with time: enqueue 8 near-future deadlines, serve 6
        e = np.zeros(n, bool)
        v = np.zeros(n, bool)
        ky = np.zeros(n, np.int32)
        pw = np.zeros((n, 1), np.int32)
        e[:8] = v[:8] = True
        ky[:8] = t + np.array([2, 3, 5, 7, 9, 12, 16, 20])
        v[8:14] = True
        state, *_ = q.step(state, jnp.array(e), jnp.array(v),
                           jnp.array(ky), jnp.array(pw))
        lo, act = np.asarray(state.lo), np.asarray(state.active)
        bounds = sorted(int(b) for b, a in zip(lo, act) if a
                        and int(b) > -(2 ** 31))
        print(f"  t={t:3d}: boundaries above the root: {bounds}")
        t += 8
    print("  (splits of the loaded future range recycle the ids of "
          "drained past buckets)")


def section_3_edf_serving():
    print("== §3 ServeEngine(deadline=True): EDF admission ==")
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.serve import Request, ServeEngine

    cfg = get_config("mamba2_130m").reduced(n_layers=1)
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.key(0))
    eng = ServeEngine(model, params, make_host_mesh(n_data=1), max_slots=2,
                      max_seq=16, deadline=True)
    batch = [Request(rid=i, prompt=[1, 2], max_new=2) for i in range(6)]
    urgent = [Request(rid=100 + i, prompt=[3, 4], max_new=2)
              for i in range(3)]
    eng.submit(batch, deadline=50)    # generous deadlines, staged first
    eng.submit(urgent, deadline=4)    # tight deadlines, arrive later
    eng.run_until_drained(max_steps=200)
    print(f"  urgent start steps: {[r.start_step for r in urgent]}")
    print(f"  batch  start steps: {[r.start_step for r in batch]}")
    print(f"  deadline_stats: {eng.deadline_stats()}")


if __name__ == "__main__":
    section_1_arbitrary_keys()
    section_2_rolling_window()
    section_3_edf_serving()
