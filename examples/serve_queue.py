"""End-to-end serving driver: a small LM served with batched requests that
flow through the SKUEUE distributed request queue (continuous batching).

This is the paper's use case as a production feature: cross-host FIFO
admission is the queue's sequential consistency, not a scheduler heuristic.

Run:  PYTHONPATH=src python examples/serve_queue.py [--arch llama3_8b]
"""
import sys

sys.argv = [sys.argv[0]] + (sys.argv[1:] or ["--arch", "llama3_8b",
                                             "--requests", "10"])
from repro.launch.serve import main  # noqa: E402

if __name__ == "__main__":
    main()
