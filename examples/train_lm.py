"""Train a reduced llama-family model for a few hundred steps on CPU with
the full production loop: queue-ordered deterministic data pipeline, AdamW,
checkpointing every 25 steps, and an injected node failure at step 60 that
the run recovers from (restart-from-checkpoint, identical trajectory).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import tempfile

from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="llama3_8b")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt:
        state, losses, metrics = train_loop(
            args.arch, reduced=True, steps=args.steps, global_batch=8,
            seq_len=64, ckpt_dir=ckpt, ckpt_every=25,
            fail_at=(min(60, args.steps // 2),))
    first, last = losses[0][1], losses[-1][1]
    print(f"\ntrained {args.steps} steps with 1 injected failure: "
          f"loss {first:.3f} -> {last:.3f}; {metrics}")
    assert last < first, "loss must decrease"


if __name__ == "__main__":
    main()
