"""Wavescope observability (PR 7): watch the queue without slowing it.

1. device metrics — every wave leaves one summary row in a donated
   device-side ring (ZERO extra collectives); drained at burst ends,
2. host tracing — span API + Chrome-trace/perfetto export,
3. flight recorder — an overflow arrives with the occupancy trajectory
   that led to it,
4. exposition — ServeEngine.metrics() -> JSON / Prometheus text.

Run:  PYTHONPATH=src python examples/observability.py
"""
import numpy as np

import jax

from repro.obs import span, timers, to_prometheus, tracer


def section_device_metrics():
    """§1 every wave records one metrics row, free of collectives."""
    from repro.dqueue import ElasticDeviceQueue

    q = ElasticDeviceQueue(len(jax.devices()), cap=256, payload_width=2,
                           ops_per_shard=8, metrics=True)
    n = q.n_shards * 8
    rng = np.random.default_rng(0)
    K = 6
    is_enq = rng.random((K, n)) < 0.7
    valid = rng.random((K, n)) < 0.8
    payload = rng.integers(0, 99, (K, n, 2)).astype(np.int32)
    with timers("burst"):
        q.run_waves(is_enq, valid, payload)
    rows = q.trajectory()   # drained into the flight recorder at burst end
    print(f"[device]   {len(rows)} wave rows drained after one "
          f"{timers('burst').elapsed('last') * 1e3:.1f} ms burst:")
    for r in rows[:3]:
        print(f"           wave {r['seq']}: +{r['puts']} puts "
              f"-{r['gets']} gets  occ={r['occ']}  "
              f"headroom={r['headroom']}")
    return q


def section_tracing(tmp="wavescope_trace.json"):
    """§2 spans nest, annotate jax profiles, and export a perfetto trace."""
    with span("example:outer", cat="demo", note=1):
        with span("example:inner", cat="demo"):
            pass
    path = tracer.export_chrome_trace(tmp)
    names = [e["name"] for e in tracer.events()]
    print(f"[trace]    {len(names)} spans recorded "
          f"(incl. {[n for n in names if n.endswith('burst')][:1]}); "
          f"open {path} in ui.perfetto.dev")


def section_flight_recorder():
    """§3 an overflow carries the occupancy ramp that caused it."""
    from repro.dqueue import ElasticDeviceQueue, QueueOverflowError

    q = ElasticDeviceQueue(1, cap=8, payload_width=1, ops_per_shard=4,
                           metrics=True)
    e = np.array([True, True, True, False])       # net +2 per wave
    v = np.array([True, True, True, True])
    pw = np.ones((4, 1), np.int32)
    try:
        for _ in range(8):
            q.step(e, v, pw)
    except QueueOverflowError as err:
        ramp = [r["occ"][0] for r in err.trajectory]
        print(f"[recorder] overflow at cap=8; flight recorder replays the "
              f"occupancy ramp {ramp}")


def section_serve_metrics():
    """§4 ServeEngine.metrics() -> Prometheus text exposition."""
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.serve import Request, ServeEngine

    cfg = get_config("mamba2_130m").reduced(n_layers=1)
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.key(0))
    eng = ServeEngine(model, params, make_host_mesh(n_data=1), max_slots=2,
                      max_seq=16, telemetry=True)
    rng = np.random.default_rng(0)
    eng.submit([Request(rid=i, prompt=list(rng.integers(0, cfg.vocab, 2)),
                        max_new=2) for i in range(3)])
    eng.run_until_drained(max_steps=100)
    snap = eng.metrics()
    prom = to_prometheus(snap)
    print(f"[serve]    served={snap['served']} over {len(snap['waves'])} "
          "queue waves; Prometheus exposition (excerpt):")
    for line in prom.splitlines():
        if line.startswith(("repro_served", "repro_queue_depth",
                            "repro_queue_occupancy")):
            print(f"           {line}")


def main():
    section_device_metrics()
    section_tracing()
    section_flight_recorder()
    section_serve_metrics()


if __name__ == "__main__":
    main()
