"""Quickstart: the SKUEUE distributed queue in 60 seconds.

1. paper-faithful protocol on the LDB overlay (async message passing),
2. the TPU-native associative-scan queue (identical semantics, one step),
3. the sharded device queue (Stage 4 as all_to_all).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core.consistency import check_sequential_consistency
from repro.core.protocol import DEQ, ENQ, Skueue
from repro.core.scan_queue import QueueState, queue_scan


def main():
    # --- 1. the protocol, as published -------------------------------------
    sk = Skueue(n=8, mode="queue", seed=0)
    rng = np.random.default_rng(0)
    nids = sk.ring.node_ids()
    for _ in range(40):
        sk.inject(nids[int(rng.integers(len(nids)))],
                  ENQ if rng.random() < 0.6 else DEQ)
    sk.run_async()  # adversarial asynchronous delivery
    stats = check_sequential_consistency(sk)
    print(f"[protocol] {stats['n_requests']} requests sequentially "
          f"consistent under async delivery; {stats['total_msgs']} messages")

    # --- 2. the same queue as ONE associative scan (the TPU form) ----------
    is_enq = jnp.array(rng.random(1000) < 0.6)
    pos, matched, state = queue_scan(is_enq, QueueState.empty())
    print(f"[scan]     1000 requests assigned in one O(log n) scan; "
          f"queue size now {int(state.size)}; "
          f"{int(matched.sum())} matched")

    # --- 3. sharded element store (Stage 4 as all_to_all) ------------------
    from repro.dqueue import DeviceQueue
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(n_data=len(jax.devices()))
    dq = DeviceQueue(mesh, "data", cap=256, payload_width=2, ops_per_shard=32)
    st = dq.init_state()
    n = dq.n_shards * dq.L
    is_enq = np.zeros(n, bool)
    valid = np.zeros(n, bool)
    payload = np.zeros((n, 2), np.int32)
    for i in range(10):         # enqueue 10 elements...
        is_enq[i] = valid[i] = True
        payload[i] = (i, i * i)
    for i in range(10, 15):     # ...and dequeue 5, in the same wave
        valid[i] = True
    st, pos, matched, dv, dok, _ = dq.step(
        st, jnp.array(is_enq), jnp.array(valid), jnp.array(payload))
    got = [tuple(map(int, dv[i])) for i in range(n) if dok[i]]
    print(f"[device]   dequeued {got} (FIFO), {int(st.size)} left in store")


if __name__ == "__main__":
    main()
