"""TPU-native scan queue: associativity, equivalence with the sequential
reference AND with the paper protocol's interval machinery."""
import numpy as np
from _hyp import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import batch as B
from repro.core.intervals import AnchorState, BOTTOM as IV_BOTTOM
from repro.core.intervals import assign_queue, positions_queue
from repro.core.scan_queue import (QueueState, StackState, queue_compose,
                                   queue_op_transforms, queue_scan,
                                   stack_compose, stack_op_transforms,
                                   stack_scan)


def _apply(tr, f, l):
    A, B_, C = tr
    return min(f + A, l + B_), l + C


@given(st.lists(st.booleans(), min_size=3, max_size=30),
       st.integers(0, 5), st.integers(-1, 20))
@settings(max_examples=60, deadline=None)
def test_queue_operator_associative(ops, cut, last0):
    """(t1;t2);t3 == t1;(t2;t3) and composition == sequential application."""
    e = jnp.array(ops)
    A, B_, C = queue_op_transforms(e)
    ts = [(int(A[i]), int(B_[i]), int(C[i])) for i in range(len(ops))]
    def comp(t1, t2):
        return tuple(int(x) for x in queue_compose(
            tuple(map(jnp.int32, t1)), tuple(map(jnp.int32, t2))))
    total_lr = ts[0]
    for t in ts[1:]:
        total_lr = comp(total_lr, t)
    # arbitrary re-association at `cut`
    k = max(1, min(len(ts) - 1, cut + 1))
    left = ts[0]
    for t in ts[1:k]:
        left = comp(left, t)
    right = ts[k]
    for t in ts[k + 1:]:
        right = comp(right, t)
    assert comp(left, right) == total_lr
    # composed transform == op-by-op state evolution
    f, l = 0, last0
    for op in ops:
        if op:
            l += 1
        else:
            f = min(f + 1, l + 1)
    ff, ll = _apply(total_lr, 0, last0)
    assert (min(ff, l + 10**9), ll) == (f, l) or (ff, ll) == (f, l)


@given(st.lists(st.booleans(), min_size=1, max_size=64),
       st.integers(0, 8))
@settings(max_examples=60, deadline=None)
def test_queue_scan_matches_sequential(ops, pre):
    """Scan positions == one-by-one sequential queue semantics."""
    is_enq = jnp.array(ops)
    state = QueueState(jnp.int32(0), jnp.int32(pre - 1))
    pos, matched, new = queue_scan(is_enq, state)
    pos = np.asarray(pos)
    f, l = 0, pre - 1
    for i, op in enumerate(ops):
        if op:
            l += 1
            assert pos[i] == l
        else:
            if f <= l:
                assert pos[i] == f and matched[i]
                f += 1
            else:
                assert pos[i] == -1 and not matched[i]
    assert (int(new.first), int(new.last)) == (f, l)


@given(st.lists(st.booleans(), min_size=1, max_size=48), st.integers(0, 6))
@settings(max_examples=40, deadline=None)
def test_scan_equals_paper_intervals(ops, pre):
    """THE bridge theorem: the associative scan assigns exactly the same
    positions as the paper's Stage-2/3 interval machinery (single batch)."""
    runs = B.empty()
    for op in ops:
        B.append_op(runs, op)
    anchor = AnchorState(first=0, last=pre - 1)
    ivs = assign_queue(anchor, runs)
    paper_pos = positions_queue(ivs, runs)
    paper_pos = [(-1 if p == IV_BOTTOM else p) for p in paper_pos]

    pos, matched, new = queue_scan(
        jnp.array(ops), QueueState(jnp.int32(0), jnp.int32(pre - 1)))
    assert list(np.asarray(pos)) == paper_pos
    assert (int(new.first), int(new.last)) == (anchor.first, anchor.last)


def test_queue_scan_padding_identity():
    is_enq = jnp.array([True, False, True, False])
    valid = jnp.array([True, False, False, True])
    state = QueueState(jnp.int32(0), jnp.int32(-1))
    pos, matched, new = queue_scan(is_enq, state, valid=valid)
    # effective sequence: ENQ, DEQ -> positions 0, 0
    assert list(np.asarray(pos)) == [0, -1, -1, 0]
    assert int(new.size) == 0


@given(st.lists(st.booleans(), min_size=1, max_size=48))
@settings(max_examples=40, deadline=None)
def test_stack_scan_matches_sequential(ops):
    is_push = jnp.array(ops)
    pos, tick, matched, new = stack_scan(is_push, StackState.empty())
    pos, tick = np.asarray(pos), np.asarray(tick)
    ref = []  # list of (pos, ticket)
    t = 0
    for i, op in enumerate(ops):
        if op:
            t += 1
            ref.append((len(ref) + 1, t))
            assert (pos[i], tick[i]) == ref[-1]
        else:
            if ref:
                rp, rt = ref.pop()
                assert pos[i] == rp and tick[i] >= rt
            else:
                assert pos[i] == -1 and not matched[i]
    assert int(new.last) == len(ref) and int(new.ticket) == t


@given(st.lists(st.booleans(), min_size=2, max_size=24), st.integers(1, 20))
@settings(max_examples=40, deadline=None)
def test_stack_operator_associative(ops, cut):
    a, b, d = stack_op_transforms(jnp.array(ops))
    ts = [(int(a[i]), int(b[i]), int(d[i])) for i in range(len(ops))]
    def comp(t1, t2):
        return tuple(int(x) for x in stack_compose(
            tuple(map(jnp.int32, t1)), tuple(map(jnp.int32, t2))))
    k = 1 + cut % (len(ts) - 1)
    left = ts[0]
    for t in ts[1:k]:
        left = comp(left, t)
    right = ts[k]
    for t in ts[k + 1:]:
        right = comp(right, t)
    seq = ts[0]
    for t in ts[1:]:
        seq = comp(seq, t)
    assert comp(left, right) == seq


# ---------------------------------------------------- multi-device paths ---
from multidev import run_multidev  # noqa: E402

SHARDED_EQUIV = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.scan_queue import QueueState, queue_scan, make_sharded_queue_scan
from repro.compat import make_mesh
mesh = make_mesh((8,), ("data",))
run = make_sharded_queue_scan(mesh, "data")
rng = np.random.default_rng(0)
state = QueueState(jnp.int32(0), jnp.int32(-1))
state_flat = QueueState(jnp.int32(0), jnp.int32(-1))
for it in range(5):
    is_enq = jnp.array(rng.random(64) < 0.6)
    valid = jnp.array(rng.random(64) < 0.9)
    p1, m1, state = run(is_enq, state, valid)
    p2, m2, state_flat = queue_scan(is_enq, state_flat, valid=valid)
    assert (np.asarray(p1) == np.asarray(p2)).all(), (p1, p2)
    assert (np.asarray(m1) == np.asarray(m2)).all()
    assert int(state.first) == int(state_flat.first)
    assert int(state.last) == int(state_flat.last)
print("OK sharded == flat", int(state.first), int(state.last))
"""


def test_sharded_scan_equals_flat_8dev():
    """The ppermute-hypercube path == flat associative_scan on 8 devices."""
    out = run_multidev(SHARDED_EQUIV, n_dev=8)
    assert "OK sharded == flat" in out


DEVICE_QUEUE = r"""
import numpy as np, jax, jax.numpy as jnp
from collections import deque
from repro.dqueue import DeviceQueue
from repro.compat import make_mesh
mesh = make_mesh((8,), ("data",))
dq = DeviceQueue(mesh, "data", cap=64, payload_width=2, ops_per_shard=8)
state = dq.init_state()
rng = np.random.default_rng(1)
ref = deque()
eid = 0
for it in range(12):
    n = dq.n_shards * dq.L
    is_enq = rng.random(n) < (0.7 if it < 8 else 0.2)
    valid = rng.random(n) < 0.8
    payload = np.zeros((n, 2), np.int32)
    for i in range(n):
        if is_enq[i] and valid[i]:
            payload[i, 0] = eid; payload[i, 1] = eid * 7; eid += 1
    state, pos, matched, dv, dok, ovf = dq.step(
        state, jnp.array(is_enq), jnp.array(valid), jnp.array(payload))
    assert not bool(ovf)
    dv, dok, matched = np.asarray(dv), np.asarray(dok), np.asarray(matched)
    # replay the same global order on a reference FIFO
    for i in range(n):
        if not valid[i]:
            assert not matched[i]
            continue
        if is_enq[i]:
            ref.append(tuple(payload[i]))
        else:
            if ref:
                exp = ref.popleft()
                assert matched[i] and dok[i], (it, i)
                assert tuple(dv[i]) == exp, (it, i, dv[i], exp)
            else:
                assert not matched[i]
    assert int(state.size) == len(ref)
print("OK device queue fifo", len(ref))
"""


def test_device_queue_fifo_8dev():
    out = run_multidev(DEVICE_QUEUE, n_dev=8)
    assert "OK device queue fifo" in out


DEVICE_STACK = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.dqueue import DeviceStack
from repro.compat import make_mesh
mesh = make_mesh((4,), ("data",))
ds = DeviceStack(mesh, "data", cap=64, payload_width=2, ops_per_shard=8,
                 slot_depth=6)
state = ds.init_state()
rng = np.random.default_rng(3)
ref = []
eid = 0
for it in range(12):
    n = ds.n_shards * ds.L
    is_push = rng.random(n) < (0.65 if it < 8 else 0.25)
    valid = rng.random(n) < 0.8
    payload = np.zeros((n, 2), np.int32)
    for i in range(n):
        if is_push[i] and valid[i]:
            payload[i, 0] = eid; payload[i, 1] = eid * 3 + 1; eid += 1
    state, pos, matched, pv, pok, ovf = ds.step(
        state, jnp.array(is_push), jnp.array(valid), jnp.array(payload))
    assert not bool(ovf), it
    pv, pok, matched = np.asarray(pv), np.asarray(pok), np.asarray(matched)
    for i in range(n):
        if not valid[i]:
            continue
        if is_push[i]:
            ref.append(tuple(payload[i]))
        else:
            if ref:
                exp = ref.pop()
                assert matched[i] and pok[i], (it, i)
                assert tuple(pv[i]) == exp, (it, i, pv[i], exp)
            else:
                assert not matched[i]
    assert int(state["last"]) == len(ref)
print("OK device stack lifo", len(ref))
"""


def test_device_stack_lifo_4dev():
    out = run_multidev(DEVICE_STACK, n_dev=4)
    assert "OK device stack lifo" in out


WORK_QUEUE = r"""
import numpy as np, jax
from repro.dqueue import DeviceQueue, WorkQueue
from repro.compat import make_mesh
mesh = make_mesh((4,), ("data",))
dq = DeviceQueue(mesh, "data", cap=128, payload_width=4, ops_per_shard=8)
wq = WorkQueue(dq, lease_steps=3)
items = [wq.make_item([i, i * i]) for i in range(20)]
done = set()
pending = list(items)
straggler_holds = {}
step = 0
while len(done) < 20 and step < 60:
    step += 1
    submit = pending[:5]; pending = pending[5:]
    grants = wq.step(submit, want=[2, 2, 2])  # 3 workers
    for w, item in grants:
        eid = int(item[0])
        if w == 2 and eid not in straggler_holds:
            straggler_holds[eid] = step  # worker 2 stalls on first receipt
            continue
        if wq.ack(item):
            done.add(eid)
assert len(done) == 20, (len(done), wq.stats)
assert wq.stats["reissued"] >= 1  # stragglers were re-issued
print("OK work queue", wq.stats)
"""


def test_work_queue_straggler_mitigation_4dev():
    out = run_multidev(WORK_QUEUE, n_dev=4)
    assert "OK work queue" in out
