"""PR 1 fused Stage-4 dispatch: collective count, multi-wave scan driver,
and cross-implementation equivalence (protocol sim == associative scan ==
device queue) on a forced multi-device CPU mesh."""
from multidev import run_multidev

COLLECTIVE_COUNT = r"""
import jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.dqueue import DeviceQueue, DeviceStack
from repro.analysis import count_all_to_all
mesh = make_mesh((8,), ("data",))
dq = DeviceQueue(mesh, "data", cap=32, payload_width=2, ops_per_shard=4)
n = dq.n_shards * dq.L
args = (dq.init_state(), jnp.zeros(n, bool), jnp.zeros(n, bool),
        jnp.zeros((n, 2), jnp.int32))
c_fused = count_all_to_all(dq._step, args)
assert c_fused <= 2, f"fused DeviceQueue.step has {c_fused} all-to-alls"
legacy = DeviceQueue(mesh, "data", cap=32, payload_width=2, ops_per_shard=4,
                     fused=False)
args = (legacy.init_state(), jnp.zeros(n, bool), jnp.zeros(n, bool),
        jnp.zeros((n, 2), jnp.int32))
c_legacy = count_all_to_all(legacy._step, args)
assert c_legacy == 5, f"seed baseline drifted: {c_legacy} all-to-alls"
ds = DeviceStack(mesh, "data", cap=32, payload_width=2, ops_per_shard=4)
args = (ds.init_state(), jnp.zeros(n, bool), jnp.zeros(n, bool),
        jnp.zeros((n, 2), jnp.int32))
c_stack = count_all_to_all(ds._step, args)
assert c_stack <= 2, f"fused DeviceStack.step has {c_stack} all-to-alls"
print("OK collectives", c_fused, c_legacy, c_stack)
"""


def test_step_compiles_to_two_all_to_alls_8dev():
    """Acceptance: fused DeviceQueue.step <= 2 all-to-all ops per wave."""
    out = run_multidev(COLLECTIVE_COUNT, n_dev=8)
    assert "OK collectives 2 5 2" in out


FUSED_EQUALS_LEGACY = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.dqueue import DeviceQueue
mesh = make_mesh((8,), ("data",))
kw = dict(cap=64, payload_width=2, ops_per_shard=8)
fused = DeviceQueue(mesh, "data", **kw)
legacy = DeviceQueue(mesh, "data", fused=False, **kw)
fs, ls = fused.init_state(), legacy.init_state()
rng = np.random.default_rng(11)
n = fused.n_shards * fused.L
for it in range(10):
    e = jnp.array(rng.random(n) < 0.6)
    v = jnp.array(rng.random(n) < 0.85)
    p = jnp.array(rng.integers(0, 1000, (n, 2)), jnp.int32)
    fs, fpos, fm, fdv, fdok, fovf = fused.step(fs, e, v, p)
    ls, lpos, lm, ldv, ldok, lovf = legacy.step(ls, e, v, p)
    assert (np.asarray(fpos) == np.asarray(lpos)).all(), it
    assert (np.asarray(fm) == np.asarray(lm)).all(), it
    assert (np.asarray(fdv) == np.asarray(ldv)).all(), it
    assert (np.asarray(fdok) == np.asarray(ldok)).all(), it
    assert bool(fovf) == bool(lovf)
assert int(fs.first) == int(ls.first) and int(fs.last) == int(ls.last)
assert (np.asarray(fs.store_full) == np.asarray(ls.store_full)).all()
print("OK fused == legacy")
"""


def test_fused_step_matches_seed_path_8dev():
    """The two-collective wave is bit-identical to the five-collective one."""
    out = run_multidev(FUSED_EQUALS_LEGACY, n_dev=8)
    assert "OK fused == legacy" in out


RUN_WAVES = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.dqueue import DeviceQueue
mesh = make_mesh((8,), ("data",))
dq = DeviceQueue(mesh, "data", cap=64, payload_width=2, ops_per_shard=8)
n = dq.n_shards * dq.L
K = 6
rng = np.random.default_rng(7)
E = rng.random((K, n)) < 0.6
V = rng.random((K, n)) < 0.9
PW = rng.integers(0, 99, (K, n, 2)).astype(np.int32)
sb = dq.init_state()
outs = []
for k in range(K):
    sb, pos, m, dv, dok, ovf = dq.step(sb, jnp.array(E[k]), jnp.array(V[k]),
                                       jnp.array(PW[k]))
    outs.append((np.asarray(pos), np.asarray(m), np.asarray(dv),
                 np.asarray(dok)))
sa, pos, m, dv, dok, ovf = dq.run_waves(dq.init_state(), jnp.array(E),
                                        jnp.array(V), jnp.array(PW))
pos, m, dv, dok = map(np.asarray, (pos, m, dv, dok))
for k in range(K):
    assert (pos[k] == outs[k][0]).all() and (m[k] == outs[k][1]).all(), k
    assert (dv[k] == outs[k][2]).all() and (dok[k] == outs[k][3]).all(), k
assert int(sa.first) == int(sb.first) and int(sa.last) == int(sb.last)
assert (np.asarray(sa.store_full) == np.asarray(sb.store_full)).all()
assert not np.asarray(ovf).any()
print("OK run_waves == K steps")
"""


def test_run_waves_equals_stepwise_8dev():
    """K waves in one lax.scan dispatch == K host-driven single waves."""
    out = run_multidev(RUN_WAVES, n_dev=8)
    assert "OK run_waves == K steps" in out


STACK_RUN_WAVES = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.dqueue import DeviceStack
mesh = make_mesh((4,), ("data",))
ds = DeviceStack(mesh, "data", cap=64, payload_width=2, ops_per_shard=8,
                 slot_depth=8)
n = ds.n_shards * ds.L
K = 5
rng = np.random.default_rng(13)
E = rng.random((K, n)) < 0.6
V = rng.random((K, n)) < 0.9
PW = rng.integers(0, 50, (K, n, 2)).astype(np.int32)
sb = ds.init_state()
outs = []
for k in range(K):
    sb, pos, m, pv, pok, ovf = ds.step(sb, jnp.array(E[k]), jnp.array(V[k]),
                                       jnp.array(PW[k]))
    outs.append((np.asarray(pos), np.asarray(m), np.asarray(pv),
                 np.asarray(pok)))
sa, pos, m, pv, pok, ovf = ds.run_waves(ds.init_state(), jnp.array(E),
                                        jnp.array(V), jnp.array(PW))
pos, m, pv, pok = map(np.asarray, (pos, m, pv, pok))
for k in range(K):
    assert (pos[k] == outs[k][0]).all() and (m[k] == outs[k][1]).all(), k
    assert (pv[k] == outs[k][2]).all() and (pok[k] == outs[k][3]).all(), k
assert int(sa["last"]) == int(sb["last"])
assert int(sa["ticket"]) == int(sb["ticket"])
print("OK stack run_waves == K steps")
"""


def test_stack_run_waves_equals_stepwise_4dev():
    out = run_multidev(STACK_RUN_WAVES, n_dev=4)
    assert "OK stack run_waves == K steps" in out


CROSS_IMPL = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core.protocol import DEQ, ENQ, Skueue
from repro.core.scan_queue import QueueState, queue_scan
from repro.dqueue import DeviceQueue

rng = np.random.default_rng(17)
ops = (rng.random(40) < 0.6).tolist()

# 1) paper protocol: all ops injected in order at ONE node, so the
#    protocol's total order == the trace order.
sk = Skueue(4, mode="queue", seed=0)
nid = sk.ring.node_ids()[0]
rids = [sk.inject(nid, ENQ if op else DEQ) for op in ops]
sk.run_rounds(200)
assert all(sk.requests[r].done for r in rids)
sk_pos = [-1 if sk.requests[r].pos is None else sk.requests[r].pos
          for r in rids]
sk_bot = [sk.requests[r].kind == DEQ and sk.requests[r].result == -1
          for r in rids]
sk_first, sk_last = sk.anchor_state.first, sk.anchor_state.last

# 2) flat associative scan
pos_s, matched_s, fin = queue_scan(jnp.array(ops),
                                   QueueState(jnp.int32(0), jnp.int32(-1)))
pos_s = np.asarray(pos_s).tolist()
bot_s = [(not op) and (p == -1) for op, p in zip(ops, pos_s)]

# 3) device queue via the multi-wave driver on 8 shards (trace order =
#    wave-major array order; trailing pad entries invalid)
mesh = make_mesh((8,), ("data",))
dq = DeviceQueue(mesh, "data", cap=16, payload_width=2, ops_per_shard=2)
n = dq.n_shards * dq.L
K = -(-len(ops) // n)
E = np.zeros((K, n), bool)
V = np.zeros((K, n), bool)
PW = np.zeros((K, n, 2), np.int32)
for j, op in enumerate(ops):
    k, i = divmod(j, n)
    E[k, i] = bool(op)
    V[k, i] = True
    PW[k, i, 0] = j  # element id = trace index
st, pos_d, m_d, dv, dok, ovf = dq.run_waves(dq.init_state(), jnp.array(E),
                                            jnp.array(V), jnp.array(PW))
assert not np.asarray(ovf).any()
pos_d = np.asarray(pos_d).reshape(-1)[:len(ops)].tolist()
m_d = np.asarray(m_d).reshape(-1)[:len(ops)]
bot_d = [(not op) and (not m) for op, m in zip(ops, m_d)]

assert sk_pos == pos_s == pos_d, (sk_pos, pos_s, pos_d)
assert sk_bot == bot_s == bot_d
assert (sk_first, sk_last) == (int(fin.first), int(fin.last)) \
    == (int(st.first), int(st.last))

# matched dequeues return the element enqueued at their position, in FIFO
# order, in all three implementations
enq_at = {p: j for j, (op, p) in enumerate(zip(ops, pos_s)) if op}
dv = np.asarray(dv).reshape(-1, 2)
dok = np.asarray(dok).reshape(-1)
for j, op in enumerate(ops):
    if op or pos_s[j] == -1:
        continue
    exp = enq_at[pos_s[j]]
    assert dok[j] and int(dv[j, 0]) == exp, (j, exp)
    # protocol: result is the elem id of that enqueue request
    assert sk.requests[rids[j]].result == sk.requests[rids[exp]].elem
print("OK cross-impl", sk_first, sk_last)
"""


def test_cross_implementation_equivalence_8dev():
    """Satellite: the same trace through Skueue.run_rounds, queue_scan, and
    DeviceQueue.run_waves yields identical positions, identical ⊥ results,
    and the same final (first, last)."""
    out = run_multidev(CROSS_IMPL, n_dev=8)
    assert "OK cross-impl" in out


def test_work_queue_burst_expiry_matches_per_step():
    """A pre-burst lease expiring at wave k of a run_waves burst is retried
    at wave k, exactly where a per-step loop would have re-enqueued it."""
    from repro.compat import make_mesh
    from repro.dqueue import DeviceQueue, WorkQueue
    mesh = make_mesh((1,), ("data",))
    dq = DeviceQueue(mesh, "data", cap=32, payload_width=4, ops_per_shard=8)
    wq = WorkQueue(dq, lease_steps=3)
    item = wq.make_item([7])
    grants = wq.step([item], [1])          # step 1: granted, never acked
    assert len(grants) == 1
    # steps 2-5 as one burst: the lease (issued step 1) expires at step 5
    # (5 - 1 > 3), so the retry must surface in wave index 3 of the burst
    bursts = wq.run_waves([[], [], [], []], [[1]] * 4)
    assert [len(g) for g in bursts] == [0, 0, 0, 1]
    assert int(bursts[3][0][1][0]) == int(item[0])
    assert wq.stats["reissued"] == 1


def test_work_queue_oversized_burst_chunks_to_per_step_schedule():
    """Regression (PR 2 satellite): a burst longer than the lease horizon
    (K > lease_steps + 1) used to be a docstring-only constraint; it is now
    chunked into sub-bursts whose schedule is EXACTLY the per-step one —
    including a lease granted inside the burst that also expires inside it
    (the case an unchunked burst would silently defer)."""
    from repro.compat import make_mesh
    from repro.dqueue import DeviceQueue, WorkQueue

    def build():
        mesh = make_mesh((1,), ("data",))
        dq = DeviceQueue(mesh, "data", cap=32, payload_width=4,
                         ops_per_shard=8)
        return WorkQueue(dq, lease_steps=2)

    K = 8  # >> lease_steps + 1 = 3
    wq_burst, wq_step = build(), build()
    submits = [[wq_burst.make_item([5])]] + [[] for _ in range(K - 1)]
    submits_ref = [[wq_step.make_item([5])]] + [[] for _ in range(K - 1)]
    wants = [[1]] * K  # one hungry worker every wave; grants never acked

    grants_burst = wq_burst.run_waves(submits, wants)
    grants_step = [wq_step.step(s, w) for s, w in zip(submits_ref, wants)]

    flat = [[(w, int(item[0])) for w, item in g] for g in grants_burst]
    flat_ref = [[(w, int(item[0])) for w, item in g] for g in grants_step]
    assert flat == flat_ref, (flat, flat_ref)
    # the item leases out, expires, and re-leases INSIDE the burst
    assert sum(len(g) for g in grants_burst) >= 2
    assert wq_burst.stats["reissued"] == wq_step.stats["reissued"] >= 1
    assert wq_burst.step_no == wq_step.step_no == K
