"""Hypothesis shim: the real library when installed, else a seeded fallback.

The tier-1 suite must collect and run green on a bare interpreter (jax +
pytest only).  When ``hypothesis`` is importable we re-export it untouched —
``pip install -r requirements-dev.txt`` gives the full property run with
shrinking.  Otherwise this module provides drop-in ``given`` / ``settings``
/ ``strategies`` that draw ``max_examples`` deterministic examples with
``np.random.default_rng`` seeded from the test name — no shrinking, but the
same assertions run over a stable example set either way.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # ---------------------------------- seeded fallback ---
    import inspect
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def draw(self, rng):
            return self._draw_fn(rng)

    class strategies:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(min_value
                                  + (max_value - min_value) * rng.random()))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                size = int(rng.integers(min_size, max_size + 1))
                return [elements.draw(rng) for _ in range(size)]
            return _Strategy(draw)

    def settings(max_examples=20, deadline=None, **_ignored):
        def decorate(fn):
            fn._hyp_max_examples = max_examples
            return fn
        return decorate

    def given(*arg_strategies, **kw_strategies):
        def decorate(fn):
            def wrapper():
                n_examples = getattr(fn, "_hyp_max_examples", 20)
                name_seed = zlib.crc32(fn.__name__.encode())
                for example in range(n_examples):
                    rng = np.random.default_rng((name_seed, example))
                    args = [s.draw(rng) for s in arg_strategies]
                    kwargs = {k: s.draw(rng)
                              for k, s in kw_strategies.items()}
                    try:
                        fn(*args, **kwargs)
                    except Exception:
                        print(f"falsifying example #{example}: "
                              f"args={args!r} kwargs={kwargs!r}")
                        raise
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            # zero-arg signature so pytest doesn't mistake the drawn
            # parameters for fixtures
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return decorate
