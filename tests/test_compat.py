"""Explicit branch coverage for src/repro/compat.py (the jax skew shims).

Each wrapper picks its branch by ``hasattr`` AT CALL TIME, so both branches
are testable on any installed jax: the new-API branch by installing a
recording stub of the modern symbol, the old-API branch by deleting it.
These are the code paths the CI ``jax-skew`` matrix runs for real on the
oldest-supported and latest jax pins; the unit tests here pin the branch
*selection* logic itself, on whatever version the runner has."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat


def _mesh1():
    return jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))


# ---------------------------------------------------------- shard_map ------
def test_shard_map_new_api_branch(monkeypatch):
    """With ``jax.shard_map`` present, compat must use it and pass
    ``check_vma=False`` (the modern spelling of check_rep)."""
    calls = {}

    def fake_shard_map(f, *, mesh, in_specs, out_specs, check_vma):
        calls["check_vma"] = check_vma
        from jax.experimental.shard_map import shard_map as real
        return real(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    check_rep=False)

    monkeypatch.setattr(jax, "shard_map", fake_shard_map, raising=False)
    fn = compat.shard_map(lambda x: x * 2, mesh=_mesh1(), in_specs=(P(),),
                          out_specs=P())
    out = fn(jnp.arange(4))
    assert calls == {"check_vma": False}
    assert (np.asarray(out) == 2 * np.arange(4)).all()


def test_shard_map_old_api_branch(monkeypatch):
    """Without ``jax.shard_map``, compat must fall back to
    ``jax.experimental.shard_map`` (the 0.4.x spelling)."""
    monkeypatch.delattr(jax, "shard_map", raising=False)
    assert not hasattr(jax, "shard_map")
    fn = compat.shard_map(lambda x: x + 1, mesh=_mesh1(), in_specs=(P(),),
                          out_specs=P())
    out = fn(jnp.arange(4))
    assert (np.asarray(out) == np.arange(4) + 1).all()


# ---------------------------------------------------------- axis_size ------
def test_axis_size_new_api_branch(monkeypatch):
    """With ``lax.axis_size`` present, compat must return its answer."""
    sentinel = jnp.int32(12345)
    monkeypatch.setattr(lax, "axis_size", lambda name: sentinel,
                        raising=False)
    assert int(compat.axis_size("data")) == 12345


def test_axis_size_old_api_branch(monkeypatch):
    """Without ``lax.axis_size``, compat must derive the size via psum
    (special-cased to the static axis size inside shard_map)."""
    monkeypatch.delattr(lax, "axis_size", raising=False)
    assert not hasattr(lax, "axis_size")

    def body(x):
        return x + compat.axis_size("data")

    fn = compat.shard_map(body, mesh=_mesh1(), in_specs=(P(),),
                          out_specs=P())
    out = fn(jnp.zeros((2,), jnp.int32))
    assert (np.asarray(out) == 1).all()  # one device on the axis


# ---------------------------------------------------------- make_mesh ------
def test_make_mesh_new_api_branch(monkeypatch):
    """With ``jax.sharding.AxisType`` present, compat must request Auto
    axis types for every axis."""
    class FakeAxisType:
        Auto = "auto-sentinel"

    calls = {}

    def fake_make_mesh(axis_shapes, axis_names, axis_types=None):
        calls["axis_types"] = axis_types
        return "mesh-sentinel"

    monkeypatch.setattr(jax.sharding, "AxisType", FakeAxisType,
                        raising=False)
    monkeypatch.setattr(jax, "make_mesh", fake_make_mesh)
    assert compat.make_mesh((1, 1), ("a", "b")) == "mesh-sentinel"
    assert calls == {"axis_types": ("auto-sentinel", "auto-sentinel")}


def test_make_mesh_old_api_branch(monkeypatch):
    """Without ``AxisType``, compat must call make_mesh WITHOUT the
    axis_types kwarg (0.4.x raises TypeError on it)."""
    monkeypatch.delattr(jax.sharding, "AxisType", raising=False)

    def strict_make_mesh(axis_shapes, axis_names):  # no axis_types accepted
        return ("mesh-sentinel", axis_shapes, axis_names)

    monkeypatch.setattr(jax, "make_mesh", strict_make_mesh)
    out = compat.make_mesh((1,), ("data",))
    assert out == ("mesh-sentinel", (1,), ("data",))


def test_make_mesh_builds_a_real_mesh():
    """End to end on the installed jax: a usable 1-device mesh."""
    mesh = compat.make_mesh((1,), ("data",))
    assert mesh.shape["data"] == 1


def test_shard_map_experimental_fallback_exists():
    """The repo's oldest-supported jax must ship the fallback module; if
    this import ever breaks, compat.shard_map's old-API branch is dead."""
    pytest.importorskip("jax.experimental.shard_map")
