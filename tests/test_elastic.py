"""PR 2 elastic membership: live JOIN/LEAVE resharding of the device path.

Differential tests: ElasticDeviceQueue / ElasticDeviceStack under a
grow+shrink schedule must produce the exact op-by-op results of the host
``Skueue`` protocol reference under the same trace with a JOIN/LEAVE
schedule — zero lost or reordered elements.  Plus integration: ServeEngine
live resize, fault shrink-on-failure, checkpoint cold-start reshard."""
from multidev import run_multidev

DIFFERENTIAL = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.consistency import check_sequential_consistency
from repro.core.protocol import DEQ, ENQ, Skueue
from repro.dqueue import ElasticDeviceQueue, ElasticDeviceStack

rng = np.random.default_rng(23)
N_OPS = 96
ops = (rng.random(N_OPS) < 0.6).tolist()
# membership schedule, keyed by trace index (applied between wave bursts on
# the device side, injected as JOIN/LEAVE on the protocol side)
SCHEDULE = {24: ("grow", 2), 48: ("shrink", [0, 4]), 72: ("grow", 1)}


def run_device(elastic, W):
    # Drive the op trace through an elastic wrapper, resizing at the
    # scheduled trace indices; payload word 0 = trace index.
    pos_l, bot_l, res_l = [], [], []
    cut = sorted(SCHEDULE) + [len(ops)]
    start = 0
    for end in cut:
        chunk = ops[start:end]
        if chunk:
            n = elastic.n_shards * elastic.L
            K = -(-len(chunk) // n)
            E = np.zeros((K, n), bool)
            V = np.zeros((K, n), bool)
            PW = np.zeros((K, n, W), np.int32)
            for j, op in enumerate(chunk):
                k, i = divmod(j, n)
                E[k, i] = bool(op)
                V[k, i] = True
                PW[k, i, 0] = start + j
            pos, m, dv, dok, ovf = elastic.run_waves(E, V, PW)
            assert not np.asarray(ovf).any()
            pos = np.asarray(pos).reshape(-1)[:len(chunk)]
            m = np.asarray(m).reshape(-1)[:len(chunk)]
            dv = np.asarray(dv).reshape(K * n, W)[:len(chunk)]
            dok = np.asarray(dok).reshape(-1)[:len(chunk)]
            for j, op in enumerate(chunk):
                pos_l.append(int(pos[j]))
                bot_l.append((not op) and not m[j])
                if (not op) and m[j]:
                    # matched dequeue/pop MUST find its element (none lost)
                    assert dok[j], f"matched op {start + j} lost its element"
                    res_l.append(int(dv[j, 0]))
                else:
                    res_l.append(None)
        if end in SCHEDULE:
            kind, arg = SCHEDULE[end]
            st = elastic.grow(arg) if kind == "grow" else elastic.shrink(arg)
            assert st["moved"] == elastic.size, (st, elastic.size)
        start = end
    return pos_l, bot_l, res_l


def run_protocol(mode):
    # Same trace through the paper protocol, one op injected per round at a
    # fixed node, JOIN/LEAVE requested at the scheduled trace indices.
    sk = Skueue(4, mode=mode, seed=0, local_combining=False)
    nid = sk.ring.node_ids()[0]
    rids = []

    def inject(s, rnd):
        i = rnd - 1
        if i < len(ops):
            rids.append(s.inject(nid, ENQ if ops[i] else DEQ))
        if i in SCHEDULE:
            kind, arg = SCHEDULE[i]
            if kind == "grow":
                for _ in range(arg):
                    s.request_join()
            else:
                # LEAVE processes that do not own the injection node
                keep = s.ring.proc[nid]
                alive = sorted({s.ring.proc[v] for v in s.ring.node_ids()})
                for pid in [p for p in alive if p != keep][:len(arg)]:
                    s.request_leave(pid)

    sk.run_rounds(len(ops) + 80, inject_fn=inject)
    assert all(sk.requests[r].done for r in rids)
    assert sk.update_phases >= 2, "membership schedule never took effect"
    check_sequential_consistency(sk)
    sk.check_dht_placement()
    pos_l = [-1 if sk.requests[r].pos is None else sk.requests[r].pos
             for r in rids]
    bot_l = [sk.requests[r].kind == DEQ and sk.requests[r].result == -1
             for r in rids]
    res_l = [sk.requests[r].result
             if sk.requests[r].kind == DEQ and sk.requests[r].result != -1
             else None for r in rids]
    return sk, pos_l, bot_l, res_l


# ------------------------------- queue mode --------------------------------
eq = ElasticDeviceQueue(4, cap=32, payload_width=2, ops_per_shard=4)
d_pos, d_bot, d_res = run_device(eq, 2)
sk, p_pos, p_bot, p_res = run_protocol("queue")
assert d_pos == p_pos, "positions diverged"
assert d_bot == p_bot, "unmatched-dequeue (bottom) sets diverged"
# protocol results are elem ids == trace index of the matching enqueue
assert d_res == p_res, "dequeue sequences diverged (lost/reordered!)"
assert (int(eq.state.first), int(eq.state.last)) == (
    sk.anchor_state.first, sk.anchor_state.last)
assert eq.n_shards == 5 and len(eq.migrations) == 3
print("OK elastic queue == Skueue through JOIN/LEAVE",
      sum(r is not None for r in d_res), "dequeues")

# ------------------------------- stack mode --------------------------------
es = ElasticDeviceStack(4, cap=32, payload_width=2, ops_per_shard=4,
                        slot_depth=8)
d_pos, d_bot, d_res = run_device(es, 2)
sk, p_pos, p_bot, p_res = run_protocol("stack")
assert d_pos == p_pos, "stack positions diverged"
assert d_bot == p_bot, "unmatched-pop (bottom) sets diverged"
assert d_res == p_res, "pop sequences diverged (lost/reordered!)"
assert int(es.state["last"]) == sk.anchor_state.last
assert int(es.state["ticket"]) == sk.anchor_state.ticket
print("OK elastic stack == Skueue through JOIN/LEAVE",
      sum(r is not None for r in d_res), "pops")

# --------------------- capacity guard + noop resize ------------------------
small = ElasticDeviceQueue(2, cap=4, payload_width=2, ops_per_shard=4)
e = np.ones(8, bool); pw = np.zeros((8, 2), np.int32)
small.step(e, e, pw)   # 8 live elements
try:
    small.shrink([0])  # 1 shard * cap 4 < 8 live -> must refuse
    raise SystemExit("shrink accepted an impossible capacity")
except ValueError:
    pass
assert small.resize(2)["kind"] == "noop"
print("OK capacity guard")
"""


def test_elastic_matches_protocol_reference_8dev():
    """Acceptance: grow (P->P+k) and shrink (P->P-k) under live traffic
    dequeue the exact sequence the host Skueue reference produces under the
    same JOIN/LEAVE schedule — both queue and stack modes."""
    out = run_multidev(DIFFERENTIAL, n_dev=8)
    assert "OK elastic queue == Skueue" in out
    assert "OK elastic stack == Skueue" in out
    assert "OK capacity guard" in out


INTEGRATION = r"""
import tempfile
import numpy as np, jax, jax.numpy as jnp

# ------------------ fault: shrink-on-failure / regrow-on-recovery ----------
from repro.dqueue import ElasticDeviceQueue
from repro.fault import ElasticPolicy, FailureInjector, run_with_restarts

q = ElasticDeviceQueue(4, cap=64, payload_width=2, ops_per_shard=4)
got = []

def step_fn(state, step):
    n = q.n_shards * q.L
    e = np.zeros(n, bool); v = np.zeros(n, bool)
    pw = np.zeros((n, 2), np.int32)
    e[:4] = v[:4] = True                      # 4 enqueues
    pw[:4, 0] = np.arange(step * 4, step * 4 + 4)
    v[4:7] = True                             # 3 dequeues (queue grows)
    _, _, dv, dok, _ = q.step(e, v, pw)
    dv, dok = np.asarray(dv), np.asarray(dok)
    got.extend(int(dv[i, 0]) for i in range(n) if dok[i])
    return {"done": np.int64(step + 1)}

policy = ElasticPolicy(
    shrink=lambda state, shard: (q.shrink([shard]), state)[1],
    regrow=lambda state: (q.grow(1), state)[1],
    regrow_after=2)
inj = FailureInjector(shard_fail_at={3: 1, 6: 0})
with tempfile.TemporaryDirectory() as d:
    state, metrics = run_with_restarts(
        init_state=lambda: {"done": np.int64(0)},
        step_fn=step_fn, n_steps=10, ckpt_dir=d, ckpt_every=100,
        injector=inj, elastic=policy, log=lambda *a: None)
assert metrics["leaves"] == 2, metrics
assert metrics["joins"] >= 1, metrics
assert metrics["restarts"] == 0, metrics          # zero checkpoint restarts
assert metrics["steps_run"] == 10, metrics        # zero replayed steps
# drain what's left; the full stream must come out in FIFO order
while q.size > 0:
    n = q.n_shards * q.L
    _, _, dv, dok, _ = q.step(np.zeros(n, bool), np.ones(n, bool),
                              np.zeros((n, 2), np.int32))
    dv, dok = np.asarray(dv), np.asarray(dok)
    got.extend(int(dv[i, 0]) for i in range(n) if dok[i])
assert got == list(range(40)), got
assert q.n_shards == 4 - 2 + metrics["joins"]
print("OK fault LEAVE/JOIN: no restarts, no replay, FIFO intact")

# ------------------ checkpoint cold-start reshard --------------------------
q2 = ElasticDeviceQueue(6, cap=16, payload_width=2, ops_per_shard=4)
n = q2.n_shards * q2.L
e = np.ones(n, bool); pw = np.zeros((n, 2), np.int32)
pw[:, 0] = np.arange(n)
q2.step(e, e, pw)
with tempfile.TemporaryDirectory() as d:
    q2.save(d, 11)
    q3 = ElasticDeviceQueue.restore(d, n_shards=3)   # cold start, resharded
assert q3.n_shards == 3 and q3.size == n
assert q3.migrations[-1]["kind"] == "shrink"
got = []
while len(got) < n:
    m = q3.n_shards * q3.L
    _, _, dv, dok, _ = q3.step(np.zeros(m, bool), np.ones(m, bool),
                               np.zeros((m, 2), np.int32))
    dv, dok = np.asarray(dv), np.asarray(dok)
    got.extend(int(dv[i, 0]) for i in range(m) if dok[i])
assert got == list(range(n))
print("OK checkpoint cold-start reshard 6 -> 3")

# ---- stack cold-start with non-default slot_depth (D in the manifest) -----
from repro.dqueue import ElasticDeviceStack
s1 = ElasticDeviceStack(2, cap=8, payload_width=2, ops_per_shard=4,
                        slot_depth=8)
n = s1.n_shards * s1.L
e = np.ones(n, bool)
pw = np.zeros((n, 2), np.int32)
pw[:, 0] = np.arange(n)
s1.step(e, e, pw)
with tempfile.TemporaryDirectory() as d:
    s1.save(d, 1)
    s2 = ElasticDeviceStack.restore(d, n_shards=3)
assert s2.D == 8 and s2.n_shards == 3 and s2.size == n
got = []
while len(got) < n:
    m = s2.n_shards * s2.L
    _, _, pv, pok, _ = s2.step(np.zeros(m, bool), np.ones(m, bool),
                               np.zeros((m, 2), np.int32))
    pv, pok = np.asarray(pv), np.asarray(pok)
    got.extend(int(pv[i, 0]) for i in range(m) if pok[i])
assert got == list(range(n - 1, -1, -1)), got
print("OK stack cold-start preserves slot_depth")
"""


def test_fault_leave_and_cold_start_8dev():
    """Satellite: failure => LEAVE of the dead shard instead of full
    restart (zero replayed steps); checkpoint restore_sharded is the
    cold-start analogue of the live migration."""
    out = run_multidev(INTEGRATION, n_dev=8)
    assert "OK fault LEAVE/JOIN" in out
    assert "OK checkpoint cold-start reshard" in out
    assert "OK stack cold-start preserves slot_depth" in out


def test_fault_regrow_deficit_survives_checkpoint_restart(tmp_path):
    """Regression: the LEAVEd-capacity deficit lives outside the
    checkpointed tree, so a plain-failure restart between a LEAVE and its
    regrow must not forget it — regrow still fires once healthy."""
    import numpy as np
    from repro.fault import (ElasticPolicy, FailureInjector,
                             run_with_restarts)
    events = []
    policy = ElasticPolicy(
        shrink=lambda st, shard: (events.append(("leave", shard)), st)[1],
        regrow=lambda st: (events.append(("join",)), st)[1],
        regrow_after=2)
    inj = FailureInjector(shard_fail_at={1: 0}, fail_at_steps=(2,))
    _, metrics = run_with_restarts(
        init_state=lambda: {"x": np.int64(0)},
        step_fn=lambda st, step: {"x": np.int64(step + 1)},
        n_steps=8, ckpt_dir=tmp_path, ckpt_every=100,
        injector=inj, elastic=policy, log=lambda *a: None)
    # step 1: ShardFailure => LEAVE; step 2: plain failure => restart from
    # scratch; the deficit survives and regrows after 2 healthy steps
    assert metrics["leaves"] == 1 and metrics["restarts"] == 1
    assert metrics["joins"] == 1, (metrics, events)
    assert events == [("leave", 0), ("join",)]


SERVE_RESIZE = r"""
import numpy as np, jax
from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.serve import Request, ServeEngine

cfg = get_config("mamba2_130m").reduced(n_layers=1)
model = build_model(cfg)
params, _ = model.init_params(jax.random.key(0))
mesh = make_host_mesh(n_data=2)
eng = ServeEngine(model, params, mesh, max_slots=2, max_seq=16)
rng = np.random.default_rng(3)
reqs = [Request(rid=i, prompt=list(rng.integers(0, cfg.vocab, 2)), max_new=2)
        for i in range(12)]
eng.submit(reqs[:8])
eng.step()                       # some admitted, some still queued on device
st = eng.resize(4)               # JOIN: queue fabric 2 -> 4 shards
assert st["P_to"] == 4 and eng.queue.n_shards == 4
eng.submit(reqs[8:])             # traffic keeps flowing on the wider mesh
eng.step()
st = eng.resize(1)               # LEAVE down to a single shard
assert st["P_to"] == 1
assert eng.run_until_drained(max_steps=400)
assert eng.stats["served"] == 12
starts = [r.start_step for r in reqs]
assert starts == sorted(starts), ("FIFO admission broken by resize", starts)
print("OK serve resize", [m["kind"] for m in eng.queue.migrations])
"""


def test_serve_engine_resize_8dev():
    """ServeEngine.resize: drain staged, reshard live, resume bursts —
    every request served, FIFO admission preserved across JOIN and LEAVE."""
    out = run_multidev(SERVE_RESIZE, n_dev=8)
    assert "OK serve resize" in out
