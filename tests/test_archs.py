"""Per-architecture smoke tests: REDUCED configs of the same family run one
forward + one train-grad step + one decode step on CPU, asserting shapes and
no NaNs.  Full configs are exercised only via the dry-run (no allocation)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model


def _smoke_batch(cfg, rng, B=2, S=32):
    batch = {}
    if cfg.family == "encdec":
        batch["frames"] = jnp.array(
            rng.standard_normal((B, cfg.enc_seq, cfg.d_model)), jnp.bfloat16)
        batch["tokens"] = jnp.array(
            rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
        batch["targets"] = jnp.array(
            rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    elif cfg.family == "vlm":
        text = S
        batch["vision_embeds"] = jnp.array(
            rng.standard_normal((B, cfg.n_vision_tokens, cfg.d_model)),
            jnp.bfloat16)
        batch["tokens"] = jnp.array(
            rng.integers(0, cfg.vocab, (B, text)), jnp.int32)
        batch["targets"] = jnp.array(
            rng.integers(0, cfg.vocab, (B, text)), jnp.int32)
    else:
        batch["tokens"] = jnp.array(
            rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
        batch["targets"] = jnp.array(
            rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    rng = np.random.default_rng(hash(arch) % (1 << 31))
    params, axes = model.init_params(jax.random.key(1))
    # axes tree must parallel the param tree
    flat_p = jax.tree.leaves(params)
    flat_a = jax.tree.leaves(
        axes, is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    assert len(flat_p) == len(flat_a)
    for p, a in zip(flat_p, flat_a):
        assert p.ndim == len(a), (p.shape, a)

    batch = _smoke_batch(cfg, rng)
    loss, grads = jax.value_and_grad(
        lambda pr: model.loss_fn(pr, batch))(params)
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    gnorm = jax.tree.reduce(
        lambda acc, g: acc + float(jnp.sum(jnp.square(g.astype(jnp.float32)))),
        grads, 0.0)
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: grad norm {gnorm}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_decode_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    rng = np.random.default_rng(hash(arch) % (1 << 30))
    params, _ = model.init_params(jax.random.key(2))
    B, S = 2, 16
    cache, cache_axes = model.init_cache(B, S)
    tok = jnp.array(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)
    kw = {}
    if cfg.family == "encdec":
        kw["enc_out"] = jnp.array(
            rng.standard_normal((B, cfg.enc_seq, cfg.d_model)), jnp.bfloat16)
    logits, cache = model.decode_fn(params, cache, tok, jnp.int32(0), **kw)
    logits2, cache = model.decode_fn(params, cache, tok, jnp.int32(1), **kw)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), arch


@pytest.mark.parametrize("arch", ["llama3_8b", "mixtral_8x22b", "mamba2_130m",
                                  "zamba2_1p2b"])
def test_decode_matches_prefill(arch):
    """Teacher-forced decode must reproduce the full-sequence forward."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    rng = np.random.default_rng(7)
    params, _ = model.init_params(jax.random.key(3))
    B, S = 1, 8
    toks = jnp.array(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    from repro.models import transformer as TF
    h_full, _, _ = TF.forward(params, cfg, toks, remat=False)
    w = params["unembed"].astype(jnp.bfloat16)
    logits_full = (h_full @ w).astype(jnp.float32)

    cache, _ = model.init_cache(B, S)
    outs = []
    for t in range(S):
        lg, cache = model.decode_fn(params, cache, toks[:, t: t + 1],
                                    jnp.int32(t))
        outs.append(lg)
    logits_step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_step),
                               np.asarray(logits_full),
                               rtol=0.15, atol=0.15)  # bf16 accumulation


def test_abstract_params_no_allocation():
    """Full-size configs must shape-infer without touching memory."""
    cfg = get_config("mistral_large_123b")
    model = build_model(cfg)
    p, axes = model.abstract_params()
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(p))
    assert 100e9 < n_params < 150e9, n_params / 1e9
    cache, c_axes = model.abstract_cache(128, 32768)
    n_cache = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(cache))
    assert n_cache > 1e9


def test_param_counts_sane():
    expect = {
        "mamba2_130m": (0.10e9, 0.20e9),
        "llama3_8b": (7e9, 9e9),
        "granite_3_8b": (7e9, 9.5e9),
        "internlm2_20b": (17e9, 23e9),
        "mixtral_8x22b": (120e9, 150e9),
        "mistral_large_123b": (110e9, 135e9),
        "llava_next_34b": (30e9, 38e9),
        "zamba2_1p2b": (1.0e9, 1.9e9),
        "whisper_small": (0.2e9, 0.5e9),
        "granite_moe_1b": (0.8e9, 1.6e9),
    }
    for arch, (lo, hi) in expect.items():
        model = build_model(get_config(arch))
        p, _ = model.abstract_params()
        n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(p))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
