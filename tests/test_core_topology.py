"""Topology tests: LDB (Definition 2), aggregation tree, DHT fairness."""
import numpy as np
import pytest

from repro.core.hashing import hash01, position_key
from repro.core.ldb import LDB, MIDDLE, RIGHT
from repro.core.ring import DynamicRing


@pytest.mark.parametrize("n", [1, 2, 3, 4, 7, 16, 33, 100, 257])
def test_ldb_tree_invariants(n):
    ldb = LDB.build(n, salt=n)
    ldb.check_tree()
    # every node has <= 2 children, right nodes have none
    assert (ldb.n_children <= 2).all()
    assert (ldb.n_children[ldb.kind == RIGHT] == 0).all()


@pytest.mark.parametrize("n", [4, 16, 64, 256, 1024, 4096])
def test_tree_height_logarithmic(n):
    """Corollary 6: aggregation tree height O(log n) w.h.p."""
    depths = [LDB.build(n, salt=s).depth.max() for s in range(3)]
    # empirical constant ~4-5 x log2(3n); assert a generous bound
    assert max(depths) <= 8 * np.log2(3 * n) + 8


def test_label_halving_structure():
    ldb = LDB.build(50, salt=1)
    # parent labels strictly decrease; middle's parent is exactly m/2
    mids = np.flatnonzero(ldb.kind == MIDDLE)
    for v in mids:
        p = ldb.parent[v]
        if p >= 0:
            assert abs(ldb.labels[p] - ldb.labels[v] / 2) < 1e-12


def test_ring_matches_static_ldb():
    """DynamicRing on static membership == LDB semantics."""
    n = 37
    ldb = LDB.build(n, salt=5)
    ring = DynamicRing.build(n, salt=5)
    ring.check_tree()
    assert ring.size == ldb.size
    # identical sorted label sequences
    ring_labels = [ring.labels[nid] for nid in ring.node_ids()]
    np.testing.assert_allclose(ring_labels, ldb.labels)
    # identical ownership for random keys
    keys = hash01(np.arange(200), salt=99)
    owners_ldb = ldb.owner_of(keys)
    for k, ow in zip(keys, owners_ldb):
        nid = ring.owner_of_scalar(float(k))
        assert abs(ring.labels[nid] - ldb.labels[ow]) < 1e-12


def test_routing_hops_logarithmic():
    """Lemma 3: O(log n) routing."""
    for n in (16, 256, 1024):
        ldb = LDB.build(n, salt=2)
        rng = np.random.default_rng(0)
        src = rng.integers(ldb.size, size=200)
        keys = rng.random(200)
        hops = ldb.route_hops(src, keys)
        assert hops.mean() <= 4 * np.log2(3 * n) + 4
        # scalar path agrees
        for i in range(10):
            assert hops[i] == ldb.route_hops_scalar(int(src[i]), float(keys[i]))


def test_consistent_hashing_fair():
    """Lemma 4 (fairness): keys spread evenly over nodes."""
    n = 64
    ldb = LDB.build(n, salt=3)
    keys = position_key(np.arange(20000))
    owners = ldb.owner_of(keys)
    counts = np.bincount(owners, minlength=ldb.size)
    # expectation ~104 per node; no node should be grossly overloaded
    assert counts.max() < 12 * keys.size / ldb.size
    assert counts.sum() == keys.size


def test_owner_interval_semantics():
    ldb = LDB.build(10, salt=7)
    # owner of exactly a node label is that node
    for i in (0, 5, 17):
        assert ldb.owner_of(np.array([ldb.labels[i]]))[0] == i
    # key below the minimum wraps to the max node
    assert ldb.owner_of(np.array([ldb.labels[0] / 2]))[0] == ldb.size - 1
