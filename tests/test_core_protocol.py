"""End-to-end protocol tests: sequential consistency (Theorems 14/21),
runtime scaling (Theorem 15), batch bounds (Theorems 18/20), membership
(Section IV) — under both synchronous and adversarial-async schedulers."""
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core.consistency import check_sequential_consistency
from repro.core.protocol import DEQ, ENQ, Skueue


def _inject_random(sk, n_reqs, p_enq, rng):
    nids = sk.ring.node_ids()
    for _ in range(n_reqs):
        sk.inject(nids[int(rng.integers(len(nids)))],
                  ENQ if rng.random() < p_enq else DEQ)


# ---------------------------------------------------------------- queue ----
@pytest.mark.parametrize("n,p_enq", [(3, 0.5), (8, 0.75), (8, 0.25), (16, 0.5)])
def test_queue_sync_consistent(n, p_enq):
    sk = Skueue(n, mode="queue", seed=n)
    rng = np.random.default_rng(n * 7 + 1)
    def inject(s, rnd):
        if rnd <= 40:
            _inject_random(s, 3, p_enq, rng)
    sk.run_rounds(80, inject_fn=inject)
    stats = check_sequential_consistency(sk)
    assert stats["n_requests"] == 120
    sk.check_dht_placement()


@given(seed=st.integers(0, 10_000), n=st.integers(2, 10),
       p_enq=st.floats(0.1, 0.9))
@settings(max_examples=25, deadline=None)
def test_queue_async_adversarial_consistent(seed, n, p_enq):
    """Definition 1 holds for every asynchronous schedule we can generate."""
    sk = Skueue(n, mode="queue", seed=seed)
    rng = np.random.default_rng(seed + 1)
    _inject_random(sk, 40, p_enq, rng)
    assert sk.run_async(max_steps=400_000)
    check_sequential_consistency(sk)


def test_queue_matches_fifo_when_single_process():
    """With one process the distributed queue == a classical queue."""
    sk = Skueue(1, mode="queue", seed=0)
    nid = sk.ring.node_ids()[0]
    pattern = [ENQ, ENQ, DEQ, ENQ, DEQ, DEQ, DEQ, ENQ, DEQ]
    for k in pattern:
        sk.inject(nid, k)
    sk.run_rounds(5)
    check_sequential_consistency(sk)  # replay IS the classical-queue check


def test_fifo_order_across_processes():
    """Elements injected in one quiesced wave leave in position order."""
    sk = Skueue(4, mode="queue", seed=2)
    nids = sk.ring.node_ids()
    for i in range(10):
        sk.inject(nids[i % len(nids)], ENQ)
    sk.run_rounds(100)
    for i in range(10):
        sk.inject(nids[(3 * i) % len(nids)], DEQ)
    sk.run_rounds(100)
    stats = check_sequential_consistency(sk)
    assert stats["n_requests"] == 20
    deqs = sorted((r.order, r.result) for r in sk.requests if r.kind == DEQ)
    enq_pos = {r.elem: r.pos for r in sk.requests if r.kind == ENQ}
    served = [enq_pos[res] for _, res in deqs]
    assert served == sorted(served), "FIFO: dequeues return ascending positions"


# ---------------------------------------------------------------- stack ----
@pytest.mark.parametrize("n,p_push", [(4, 0.5), (8, 0.7), (8, 0.3)])
def test_stack_sync_consistent(n, p_push):
    sk = Skueue(n, mode="stack", seed=n + 100)
    rng = np.random.default_rng(n * 13 + 1)
    def inject(s, rnd):
        if rnd <= 40:
            _inject_random(s, 3, p_push, rng)
    sk.run_rounds(100, inject_fn=inject)
    check_sequential_consistency(sk)


@given(seed=st.integers(0, 10_000), n=st.integers(2, 8),
       p=st.floats(0.2, 0.8))
@settings(max_examples=20, deadline=None)
def test_stack_async_adversarial_consistent(seed, n, p):
    sk = Skueue(n, mode="stack", seed=seed)
    rng = np.random.default_rng(seed + 3)
    _inject_random(sk, 30, p, rng)
    assert sk.run_async(max_steps=600_000)
    check_sequential_consistency(sk)


def test_stack_local_combining_fast_path():
    """Sec. VI: locally paired push/pop complete without any DHT traffic."""
    sk = Skueue(4, mode="stack", seed=7)
    nid = sk.ring.node_ids()[0]
    sk.inject(nid, ENQ)
    rid = sk.inject(nid, DEQ)
    req = sk.requests[rid]
    assert req.done and req.result == sk.requests[rid - 1].elem
    assert sk.total_msgs == 0  # answered before any message was sent


def test_stack_batches_constant_size():
    """Theorem 20: stack batches aggregate to at most (pop-run, push-run)."""
    sk = Skueue(6, mode="stack", seed=9)
    rng = np.random.default_rng(11)
    def inject(s, rnd):
        if rnd <= 60:
            _inject_random(s, 6, 0.5, rng)
    sk.run_rounds(120, inject_fn=inject)
    check_sequential_consistency(sk)
    assert sk.stats_batch_max_runs <= 3  # (maybe-empty push, pop, push)


# --------------------------------------------------------------- runtime ---
def test_latency_scales_logarithmically():
    """Theorem 15 / Figure 2: mean rounds/request grows ~ log n."""
    means = []
    for n in (4, 16, 64):
        sk = Skueue(n, mode="queue", seed=n)
        rng = np.random.default_rng(n)
        def inject(s, rnd):
            if rnd <= 30:
                _inject_random(s, 2, 0.5, rng)
        sk.run_rounds(60, inject_fn=inject)
        check_sequential_consistency(sk)
        lat = [r.t_done - r.t_issue for r in sk.requests]
        means.append(np.mean(lat))
    # monotone-ish growth, far from linear: 16x nodes << 16x latency
    assert means[2] < means[0] * 6
    assert means[2] / np.log2(64 * 3) < 3 * means[0] / np.log2(4 * 3) + 10


def test_queue_batch_size_logarithmic():
    """Theorem 18: queue batches stay O(log n) runs under 1 req/round/node."""
    n = 32
    sk = Skueue(n, mode="queue", seed=5)
    rng = np.random.default_rng(6)
    def inject(s, rnd):
        if rnd <= 60:
            nids = s.ring.node_ids()
            for nid in nids:
                s.inject(nid, ENQ if rng.random() < 0.5 else DEQ)
    sk.run_rounds(120, inject_fn=inject)
    check_sequential_consistency(sk)
    assert sk.stats_batch_max_runs <= 6 * np.log2(3 * n)


# ------------------------------------------------------------ membership ---
def test_join_leave_churn_queue():
    sk = Skueue(6, mode="queue", seed=17)
    rng = np.random.default_rng(19)
    def inject(s, rnd):
        nids = s.ring.node_ids()
        if rnd % 3 == 0 and rnd <= 150:
            s.inject(nids[int(rng.integers(len(nids)))],
                     ENQ if rng.random() < 0.6 else DEQ)
        if rnd == 10:
            s.request_join()
        if rnd == 20:
            s.request_join()
        if rnd == 35:
            s.request_leave(2)
        if rnd == 50:
            s.request_leave(0)
    sk.run_rounds(300, inject_fn=inject)
    check_sequential_consistency(sk)
    sk.check_dht_placement()
    procs = set(sk.ring.proc[n] for n in sk.ring.node_ids())
    assert procs == {1, 3, 4, 5, 6, 7}
    assert sk.pending_membership == 0
    assert sk.ring.size == 24  # 18 original + 6 joined virtual nodes


def test_anchor_process_leave_hands_off():
    sk = Skueue(5, mode="queue", seed=23)
    anchor_proc = sk.ring.proc[sk.ring.anchor]
    rng = np.random.default_rng(29)
    def inject(s, rnd):
        nids = s.ring.node_ids()
        if rnd % 2 == 0 and rnd <= 80:
            s.inject(nids[int(rng.integers(len(nids)))],
                     ENQ if rng.random() < 0.5 else DEQ)
        if rnd == 15:
            s.request_leave(anchor_proc)
    sk.run_rounds(250, inject_fn=inject)
    check_sequential_consistency(sk)
    sk.check_dht_placement()
    assert anchor_proc not in set(sk.ring.proc[n] for n in sk.ring.node_ids())
    assert sk.pending_membership == 0


def test_join_moves_dht_data_to_new_owner():
    sk = Skueue(4, mode="queue", seed=31)
    nids = sk.ring.node_ids()
    for i in range(30):
        sk.inject(nids[i % len(nids)], ENQ)
    sk.run_rounds(120)
    sk.check_dht_placement()
    stored_before = sum(len(s) for s in sk.store)
    assert stored_before == 30
    for _ in range(3):
        sk.request_join()
    sk.run_rounds(150)
    sk.check_dht_placement()  # data must have moved to the new owners
    assert sum(len(s) for s in sk.store) == 30
    # drain the queue through the grown system
    nids = sk.ring.node_ids()
    for i in range(30):
        sk.inject(nids[(7 * i) % len(nids)], DEQ)
    sk.run_rounds(200)
    check_sequential_consistency(sk)


def test_many_simultaneous_joins():
    """Theorem 17 flavour: a burst of joins integrates in few update phases."""
    sk = Skueue(8, mode="queue", seed=37)
    def inject(s, rnd):
        if rnd == 5:
            for _ in range(8):
                s.request_join()
    sk.run_rounds(200, inject_fn=inject)
    assert sk.ring.size == 3 * 16
    assert sk.pending_membership == 0
    assert sk.update_phases <= 6
