"""PR 9 compact waves: occupancy-adaptive envelopes.

Two satellites of the PR 9 acceptance bar:

* a hypothesis property test driving random op streams, random JOIN/LEAVE
  schedules AND a random per-wave envelope width (mixed across the bucket
  ladder) through all four disciplines — op-by-op parity against the host
  oracles, plus BIT-IDENTICAL parity (every per-op output and the final
  device state) with the same wave partition ridden at the full width;
* an HLO matrix test asserting each ladder width still lowers to the
  exact 2-all_to_all wave contract while the all_to_all operand shapes
  shrink STRICTLY monotonically with the envelope width — the compaction
  is real bytes off the wire, not a relabeling.
"""
import numpy as np

from _hyp import given, settings, strategies as st
from multidev import run_multidev

# --------------------------------------------------------------------------
# Property: mixed bucket widths == full width == host oracles.
#
# The op stream is partitioned into single-wave chunks; each chunk rides a
# randomly chosen ladder width that fits it (the compact run) and, in a
# twin queue, the full width L (the reference run) — the SAME wave
# partition, so the only difference is the envelope padding.  Membership
# events fire between chunks on both queues.
# --------------------------------------------------------------------------
MIXED_BUCKETS = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.priority import DEQ as PDEQ, ENQ as PENQ, PriorityOracle
from repro.core.seap import DEQ as SDEQ, ENQ as SENQ, SeapOracle
from repro.dqueue import (ElasticDeviceQueue, ElasticDeviceStack,
                          ElasticDevicePriorityQueue, ElasticDeviceSeapQueue)
from repro.dqueue.wave_engine import bucket_ladder

OPS = %(ops)r
PRIOS = %(prios)r
KEYS = %(keys)r
CHUNKS = %(chunks)r          # consecutive chunk sizes partitioning OPS
WIDTH_SEED = %(width_seed)d  # per-chunk ladder pick for the compact run
SCHEDULE = %(schedule)r      # chunk index -> ("grow", k) | ("shrink", ids)
P_ = %(n_prios)d
L = 4
B_ = 4
SPLIT_OCC = 6


def run_device(elastic, W, codes=None, compact=False):
    # drive the chunk schedule; compact=True rides mixed ladder widths
    wrng = np.random.default_rng(WIDTH_SEED)
    outs = []
    start = 0
    for ci, m in enumerate(CHUNKS):
        chunk = OPS[start:start + m]
        if compact:
            ladder = [w for w in elastic.bucket_widths()
                      if elastic.n_shards * w >= m]
            w = int(wrng.choice(ladder))
            assert w >= elastic.pick_width(m)
        else:
            w = elastic.L
        n = elastic.n_shards * w
        E = np.zeros(n, bool)
        V = np.zeros(n, bool)
        PR = np.zeros(n, np.int32)
        PW = np.zeros((n, W), np.int32)
        for j, op in enumerate(chunk):
            E[j] = bool(op)
            V[j] = True
            if codes is not None:
                PR[j] = codes[start + j]
            PW[j, 0] = start + j
        if codes is not None:
            tier, pos, mt, dv, dok, _ovf, _aux = elastic.step(E, V, PR, PW)
        else:
            pos, mt, dv, dok, _ovf = elastic.step(E, V, PW)
            tier = pos
        pos = np.asarray(pos)[:m]
        mt = np.asarray(mt)[:m]
        tier = np.asarray(tier)[:m]
        dv = np.asarray(dv)[:m]
        dok = np.asarray(dok)[:m]
        for j, op in enumerate(chunk):
            res = int(dv[j, 0]) if (not op) and mt[j] and dok[j] else None
            outs.append((int(pos[j]), bool(mt[j]), res, int(tier[j])))
        if ci in SCHEDULE:
            kind, arg = SCHEDULE[ci]
            if kind == "grow":
                elastic.grow(arg)
            else:
                elastic.shrink(arg)
        start += m
    return outs


def assert_twin(make):
    # compact run == full-width run, bit-identically (ops AND state)
    a = make()
    b = make()
    codes = {"queue": None, "stack": None,
             "pqueue": PRIOS, "squeue": KEYS}[a._kind]
    out_a = run_device(a, 2, codes=codes, compact=True)
    out_b = run_device(b, 2, codes=codes, compact=False)
    assert out_a == out_b, (a._kind, "per-op outputs differ across widths")
    sa, sb = a._state_dict(), b._state_dict()
    for k in sa:
        xa, xb = np.asarray(sa[k]), np.asarray(sb[k])
        if k in a._sharded_keys:
            # the store's trailing junk row is write-only scratch for the
            # wave's padding requests — more padding at wider envelopes
            # legitimately leaves different garbage there; every live and
            # stale data row must still match bit for bit
            xa, xb = xa[:, :-1], xb[:, :-1]
        assert np.array_equal(xa, xb), \
            (a._kind, k, "final device state differs across widths")
    return a, out_a


# ---- FIFO / LIFO: width-mixed == full width, plus op-by-op parity with
#      a direct sequentially-consistent host replay of the op stream
#      (positions are wave-partition independent for both orders) ----
q, fifo_out = assert_twin(lambda: ElasticDeviceQueue(
    4, cap=32, payload_width=2, ops_per_shard=L))
first, last, vals, ref = 0, -1, {}, []
for j, op in enumerate(OPS):
    if op:
        last += 1
        vals[last] = j
        ref.append((last, True, None))
    elif first <= last:
        ref.append((first, True, vals[first]))
        first += 1
    else:
        ref.append((-1, False, None))
assert [(d[0], d[1], d[2]) for d in fifo_out] == ref, "queue replay"
assert q.size == last - first + 1
print("OK mixed queue")

s, lifo_out = assert_twin(lambda: ElasticDeviceStack(
    4, cap=32, payload_width=2, ops_per_shard=L, slot_depth=8))
depth, stk, ref = 0, [], []
for j, op in enumerate(OPS):
    if op:
        depth += 1
        stk.append(j)
        ref.append((depth, True, None))
    elif depth >= 1:
        ref.append((depth, True, stk.pop()))
        depth -= 1
    else:
        ref.append((-1, False, None))
assert [(d[0], d[1], d[2]) for d in lifo_out] == ref, "stack replay"
assert s.size == depth
print("OK mixed stack")

# ---- priority: twin parity AND op-by-op host-oracle parity ----
pq, dev = assert_twin(lambda: ElasticDevicePriorityQueue(
    4, n_prios=P_, cap=32, payload_width=2, ops_per_shard=L))
oracle = PriorityOracle(P_)
recs = []
start = 0
shards = 4
for ci, m in enumerate(CHUNKS):
    wave = []
    for j in range(start, start + m):
        if OPS[j]:
            wave.append((PENQ, PRIOS[j], j, 0))
        else:
            wave.append((PDEQ, 0, None, 0))
    recs.extend(oracle.wave(wave, n_shards=shards))
    if ci in SCHEDULE:
        kind, arg = SCHEDULE[ci]
        shards += arg if kind == "grow" else -len(arg)
    start += m
assert len(recs) == len(dev) == len(OPS)
for j, (d, r) in enumerate(zip(dev, recs)):
    assert d[1] == r.matched, ("pqueue matched", j)
    assert d[0] == r.pos, ("pqueue pos", j)
    if r.matched:
        assert d[3] == r.tier, ("pqueue tier", j)
    if r.matched and r.value is not None:
        assert d[2] == r.value, ("pqueue value", j)
assert pq.sizes == oracle.sizes
print("OK mixed pqueue")

# ---- seap: twin parity AND op-by-op host-oracle parity ----
sq, dev = assert_twin(lambda: ElasticDeviceSeapQueue(
    4, n_buckets=B_, split_occupancy=SPLIT_OCC, cap=32, payload_width=2,
    ops_per_shard=L))
oracle = SeapOracle(B_, split_occupancy=SPLIT_OCC)
recs = []
start = 0
for ci, m in enumerate(CHUNKS):
    wave = []
    for j in range(start, start + m):
        if OPS[j]:
            wave.append((SENQ, KEYS[j], j))
        else:
            wave.append((SDEQ, 0, None))
    recs.extend(oracle.wave(wave))
    start += m
assert len(recs) == len(dev) == len(OPS)
for j, (d, r) in enumerate(zip(dev, recs)):
    assert d[1] == r.matched, ("seap matched", j)
    assert d[0] == r.pos, ("seap pos", j)
    if r.matched:
        assert d[3] == r.bucket, ("seap bucket", j)
    if r.matched and r.value is not None:
        assert d[2] == r.value, ("seap value", j)
assert sq.sizes == oracle.sizes
assert sq.directory() == oracle.directory()
print("OK mixed seap")
"""


@given(st.lists(st.booleans(), min_size=16, max_size=40),
       st.integers(0, 2 ** 31 - 1), st.integers(0, 2))
@settings(max_examples=2, deadline=None)
def test_mixed_bucket_widths_match_oracles_and_full_width_8dev(
        ops, seed, n_events):
    """PR 9 property: random op streams chunked into single waves riding
    RANDOM ladder widths, with JOIN/LEAVE between waves, are op-by-op
    equal to the host oracles and bit-identical (outputs and final state)
    to the identical wave partition ridden at the full envelope width."""
    rng = np.random.default_rng(seed)
    n_prios = int(rng.integers(2, 4))
    prios = [int(p) for p in rng.integers(0, n_prios, len(ops))]
    keys = [int(k) for k in rng.integers(-1000, 1000, len(ops))]
    # partition into chunks that always fit ONE wave at the minimum
    # membership the schedule can reach (2 shards x L=4)
    chunks = []
    left = len(ops)
    while left:
        m = int(rng.integers(1, min(8, left) + 1))
        chunks.append(m)
        left -= m
    schedule = {}
    shards = 4
    for idx in sorted(rng.choice(np.arange(len(chunks)),
                                 size=min(n_events, len(chunks)),
                                 replace=False).tolist()):
        if rng.random() < 0.5 and shards <= 6:
            k = int(rng.integers(1, min(2, 8 - shards) + 1))
            schedule[int(idx)] = ("grow", k)
            shards += k
        elif shards >= 3:
            m = int(rng.integers(1, min(2, shards - 2) + 1))
            ids = sorted(rng.choice(np.arange(shards), size=m,
                                    replace=False).tolist())
            schedule[int(idx)] = ("shrink", [int(i) for i in ids])
            shards -= m
    script = MIXED_BUCKETS % {
        "ops": [bool(o) for o in ops], "prios": prios, "keys": keys,
        "chunks": chunks, "width_seed": int(rng.integers(2 ** 31)),
        "schedule": schedule, "n_prios": n_prios}
    out = run_multidev(script, n_dev=8)
    for tag in ("queue", "stack", "pqueue", "seap"):
        assert f"OK mixed {tag}" in out


# --------------------------------------------------------------------------
# HLO matrix: every ladder width keeps the exact 2-all_to_all contract and
# the all_to_all operand shapes shrink strictly with the width.
# --------------------------------------------------------------------------
BUCKET_HLO = r"""
import re
import numpy as np, jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.dqueue import (DeviceQueue, DeviceStack, DevicePriorityQueue,
                          DeviceSeapQueue)
from repro.dqueue.wave_engine import bucket_ladder
from repro.analysis.hlo import compiled_text, parse_hlo

mesh = make_mesh((8,), ("data",))
L = 8
LADDER = bucket_ladder(L)
assert LADDER == (2, 4, 8), LADDER


def a2a_elems(fn, args):
    prog = parse_hlo(compiled_text(fn, args))
    a2a = [op for op in prog.ops if op.opcode == "all-to-all"]
    total = 0
    for op in a2a:
        for dims in re.findall(r"\[([\d,]*)\]", op.shape):
            total += int(np.prod([int(d) for d in dims.split(",") if d])
                         if dims else 1)
    return len(a2a), total


CASES = [
    ("queue", lambda: DeviceQueue(
        mesh, "data", cap=32, payload_width=2, ops_per_shard=L), 0),
    ("stack", lambda: DeviceStack(
        mesh, "data", cap=32, payload_width=2, ops_per_shard=L,
        slot_depth=8), 0),
    ("priority", lambda: DevicePriorityQueue(
        mesh, "data", n_prios=2, cap=32, payload_width=2,
        ops_per_shard=L), 2),
    ("seap", lambda: DeviceSeapQueue(
        mesh, "data", n_buckets=4, cap=32, payload_width=2,
        ops_per_shard=L), 50),
]
for name, make, kmax in CASES:
    q = make()
    sizes = []
    for w in LADDER:
        n = 8 * w
        args = [q.init_state(), jnp.zeros(n, bool), jnp.zeros(n, bool)]
        if kmax:
            args.append(jnp.zeros(n, jnp.int32))
        args.append(jnp.zeros((n, 2), jnp.int32))
        count, elems = a2a_elems(q._step, tuple(args))
        assert count == 2, (name, w, count)
        sizes.append(elems)
    assert sizes[0] < sizes[1] < sizes[2], (name, sizes)
    print(f"OK bucket-hlo {name}: a2a elems {sizes}")
"""


def test_bucket_hlo_matrix_two_a2a_and_strictly_smaller_shapes_8dev():
    """PR 9 HLO matrix: for every discipline and every ladder width the
    step program stays EXACTLY 2 all_to_all, and the total all_to_all
    operand element count strictly shrinks with the envelope width."""
    out = run_multidev(BUCKET_HLO, n_dev=8, timeout=900)
    for name in ("queue", "stack", "priority", "seap"):
        assert f"OK bucket-hlo {name}" in out


# --------------------------------------------------------------------------
# The perf regression gate (benchmarks/gate.py): pure-python logic units.
# The gate has no jax dependency; load it by path so the namespace-package
# layout of benchmarks/ doesn't matter under pytest.
# --------------------------------------------------------------------------
def _load_gate():
    import importlib.util
    import os
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "benchmarks", "gate.py")
    spec = importlib.util.spec_from_file_location("bench_gate", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _fake_bench(wps=100.0, speedup=1.7):
    rows = {}
    for occ in ("5%", "25%", "100%"):
        sp = speedup if occ != "100%" else 1.0
        rows[occ] = {"compact": {"waves_per_sec": wps * sp},
                     "full": {"waves_per_sec": wps},
                     "speedup_waves_per_sec": sp}
    return {"occupancy": {"disciplines": {"queue": dict(rows),
                                          "priority": dict(rows)}}}


def test_gate_passes_within_tolerance_and_fails_beyond():
    gate = _load_gate()
    base = gate.build_baseline(_fake_bench())
    assert gate.check(_fake_bench(), base) == []
    # a 20% dip is inside the 25% band
    assert gate.check(_fake_bench(wps=80.0), base) == []
    # a 30% dip on waves/sec trips every throughput floor it touches
    fails = gate.check(_fake_bench(wps=70.0), base)
    assert fails and all("below baseline" in f for f in fails)
    # a collapsed compact speedup trips the machine-portable ratio floor
    fails = gate.check(_fake_bench(speedup=1.1), base)
    assert any("below the committed floor" in f for f in fails)
    # missing metrics are failures, not silent skips
    fails = gate.check({"occupancy": {"disciplines": {}}}, base)
    assert fails and any("missing" in f for f in fails)


def test_gate_tracks_committed_baseline_schema():
    """The committed BENCH_BASELINE.json must cover exactly the tracked
    metrics (refreshed via ``--update``, never hand-edited)."""
    import json
    import os
    gate = _load_gate()
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_BASELINE.json")
    with open(path) as f:
        base = json.load(f)
    assert set(base["throughput"]) == set(gate.TRACKED_THROUGHPUT)
    assert set(base["ratio_floors"]) == set(gate.RATIO_FLOORS)
    assert all(v > 0 for v in base["throughput"].values())
