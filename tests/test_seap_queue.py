"""PR 5 Seap arbitrary-priority discipline: DeviceSeapQueue differential
vs. the host bucket-directory oracle (op-by-op, across grow+shrink, with
directory splits/merges exercised), HLO collective count, pipelined burst
equality, checkpoint cold-start, and the structured-overflow regression
(QueueOverflowError replaces every bare assert on the wave paths)."""
import numpy as np
import pytest

from multidev import run_multidev

DIFFERENTIAL = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.seap import DEQ, ENQ, SeapOracle
from repro.dqueue import ElasticDeviceSeapQueue

# randomized mixed enq/deq schedule with random int32 keys; migration
# schedule applied between waves (one grow, one shrink) — the oracle is
# membership-oblivious, so op-by-op equality proves migrations lose or
# reorder nothing, and a low split threshold forces directory rebalances.
for B, seeds in ((4, None), (8, [-500, 0, 500])):
    eq = ElasticDeviceSeapQueue(4, n_buckets=B, cap=32, payload_width=2,
                                ops_per_shard=4, split_occupancy=6,
                                seed_bounds=seeds)
    oracle = SeapOracle(B, split_occupancy=6, seed_bounds=seeds)
    rng = np.random.default_rng(1000 + B)
    for it in range(14):
        if it == 5:
            st = eq.grow(2)
            assert st["moved"] == eq.size == oracle.size, (st, it)
        if it == 10:
            st = eq.shrink([0, 3])
            assert st["moved"] == eq.size == oracle.size, (st, it)
        n = eq.n_shards * eq.L
        e = rng.random(n) < 0.55
        v = rng.random(n) < 0.9
        key = rng.integers(-1000, 1000, n).astype(np.int32)
        pw = np.zeros((n, 2), np.int32)
        pw[:, 0] = rng.integers(0, 1 << 20, n)
        bucket, pos, m, dv, dok, ovf, nact = eq.step(e, v, key, pw)
        assert not bool(np.asarray(ovf).any())
        ops = [None if not v[i] else
               ((ENQ, int(key[i]), int(pw[i, 0])) if e[i]
                else (DEQ, 0, None)) for i in range(n)]
        recs = oracle.wave(ops)
        bucket, pos, m, dv, dok = map(np.asarray,
                                      (bucket, pos, m, dv, dok))
        for i, r in enumerate(recs):
            assert bool(m[i]) == r.matched, (B, it, i)
            assert int(bucket[i]) == r.bucket, (B, it, i)
            assert int(pos[i]) == r.pos, (B, it, i)
            if r.matched and r.value is not None:
                # matched dequeue MUST find its element (none lost)
                assert bool(dok[i]), (B, it, i)
                assert int(dv[i, 0]) == r.value, (B, it, i)
        # the replicated directory evolves identically on both sides
        assert int(nact) == oracle.n_active, (B, it)
        assert eq.directory() == oracle.directory(), (B, it)
    assert eq.sizes == oracle.sizes, B
    assert oracle.n_splits > 0 and oracle.n_merges > 0, (
        B, oracle.n_splits, oracle.n_merges)
    print(f"OK seap B={B} seeded={seeds is not None} "
          f"splits={oracle.n_splits} merges={oracle.n_merges} "
          f"dir={len(oracle.directory())}")
"""


def test_seap_queue_matches_oracle_across_migrations_8dev():
    """Acceptance: DeviceSeapQueue matches the host bucket-directory
    oracle op-by-op on 8 CPU devices over random arbitrary-key schedules,
    including across one grow and one shrink migration, with directory
    splits AND merges actually exercised, cold and seeded."""
    out = run_multidev(DIFFERENTIAL, n_dev=8)
    assert "OK seap B=4 seeded=False" in out
    assert "OK seap B=8 seeded=True" in out


COLLECTIVES = r"""
import jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.dqueue import DeviceSeapQueue
from repro.analysis import count_all_to_all
mesh = make_mesh((8,), ("data",))
K, L = 6, 4
n = 8 * L
for B in (2, 8):
    for pipelined in (False, True):
        dq = DeviceSeapQueue(mesh, "data", n_buckets=B, cap=32,
                             payload_width=2, ops_per_shard=L,
                             pipelined=pipelined)
        args = (dq.init_state(), jnp.zeros(n, bool), jnp.zeros(n, bool),
                jnp.zeros(n, jnp.int32), jnp.zeros((n, 2), jnp.int32))
        c = count_all_to_all(dq._step, args)
        assert c <= 2, f"B={B}: {c} all-to-alls per wave"
        margs = (dq.init_state(), jnp.zeros((K, n), bool),
                 jnp.zeros((K, n), bool), jnp.zeros((K, n), jnp.int32),
                 jnp.zeros((K, n, 2), jnp.int32))
        cm = count_all_to_all(dq._run_waves, margs)
        assert cm <= 2, f"B={B} pipelined={pipelined}: {cm} in run_waves"
        print(f"OK seap collectives B={B} pipe={pipelined}: {c}/{cm}")
"""


def test_seap_wave_lowers_to_two_all_to_alls_8dev():
    """Acceptance: the Seap wave costs <= 2 all_to_all collectives per
    wave — the directory lookup, B masked scans, batch-DeleteMin and the
    split/merge rebalance are all replicated arithmetic on the wire-free
    side of the packed Stage-4 layout."""
    out = run_multidev(COLLECTIVES, n_dev=8)
    for B in (2, 8):
        assert f"OK seap collectives B={B} pipe=False: 2/2" in out
        assert f"OK seap collectives B={B} pipe=True:" in out


RUN_WAVES = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.dqueue import DeviceSeapQueue

mesh = make_mesh((8,), ("data",))
L, K = 4, 6
n = 8 * L
rng = np.random.default_rng(41)
E = rng.random((K, n)) < 0.6
V = rng.random((K, n)) < 0.9
KY = rng.integers(-99, 99, (K, n)).astype(np.int32)
PW = rng.integers(0, 99, (K, n, 2)).astype(np.int32)
make = lambda p: DeviceSeapQueue(mesh, "data", n_buckets=4, cap=64,
                                 payload_width=2, ops_per_shard=L,
                                 split_occupancy=5, pipelined=p)
seq, pipe = make(False), make(True)
sb = seq.init_state()
outs = []
for k in range(K):
    sb, *o = seq.step(sb, jnp.array(E[k]), jnp.array(V[k]),
                      jnp.array(KY[k]), jnp.array(PW[k]))
    outs.append([np.asarray(x) for x in o])
for mode, q in (("sequential", seq), ("pipelined", pipe)):
    sa, *oa = q.run_waves(q.init_state(), jnp.array(E), jnp.array(V),
                          jnp.array(KY), jnp.array(PW))
    oa = [np.asarray(x) for x in oa]
    for k in range(K):
        for a, b in zip(oa, outs[k]):
            assert (a[k] == b).all(), (mode, k)
    for a, b in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
        assert (np.asarray(a) == np.asarray(b)).all(), mode
print("OK seap run_waves == K steps (sequential AND pipelined)")
"""


def test_seap_run_waves_equals_stepwise_8dev():
    """The pipelined and sequential K-wave bursts are bit-identical to K
    host-driven steps — outputs, final state, AND the directory carry
    (the rebalance rides the scan carry correctly)."""
    out = run_multidev(RUN_WAVES, n_dev=8)
    assert "OK seap run_waves == K steps" in out


CHECKPOINT = r"""
import tempfile
import numpy as np
from repro.dqueue import ElasticDeviceSeapQueue

q = ElasticDeviceSeapQueue(6, n_buckets=4, cap=16, payload_width=2,
                           ops_per_shard=4, split_occupancy=8)
n = q.n_shards * q.L
rng = np.random.default_rng(3)
for _ in range(3):                      # force some directory refinement
    e = np.ones(n, bool)
    key = rng.integers(-1000, 1000, n).astype(np.int32)
    pw = np.zeros((n, 2), np.int32)
    pw[:, 0] = rng.integers(0, 1 << 20, n)
    q.step(e, e, key, pw)
assert q.n_active > 1, "no split happened; test is vacuous"
with tempfile.TemporaryDirectory() as d:
    q.save(d, 7)
    q2 = ElasticDeviceSeapQueue.restore(d, n_shards=3)
assert q2.n_shards == 3 and q2.n_buckets == 4
assert q2.split_occupancy == 8
assert q2.migrations[-1]["kind"] == "shrink"
assert q2.sizes == q.sizes and q2.size == 3 * n
# the bucket table survives the manifest round-trip + reshard
assert q2.directory() == q.directory()
# drain: every element survives, each bucket comes out in FIFO order
got = []
while q2.size > 0:
    m = q2.n_shards * q2.L
    b, _, _, dv, dok, _, _ = q2.step(np.zeros(m, bool), np.ones(m, bool),
                                     np.zeros(m, np.int32),
                                     np.zeros((m, 2), np.int32))
    b, dv, dok = np.asarray(b), np.asarray(dv), np.asarray(dok)
    got.extend((int(b[i]), int(dv[i, 0])) for i in range(m) if dok[i])
assert len(got) == 3 * n
print("OK seap checkpoint cold-start reshard 6 -> 3")
"""


def test_seap_checkpoint_cold_start_reshard_8dev():
    """Satellite integration: checkpoint manifests carry the bucket
    layout (B, split threshold, seed) and the state dict carries the live
    directory, so a cold start onto a different shard count restores the
    directory and loses no element."""
    out = run_multidev(CHECKPOINT, n_dev=8)
    assert "OK seap checkpoint cold-start reshard" in out


def test_seap_seed_bounds_validation():
    from repro.core.seap import SeapOracle
    from repro.dqueue import DeviceSeapQueue
    from repro.compat import make_mesh

    with pytest.raises(ValueError):
        SeapOracle(2, split_occupancy=4, seed_bounds=[1, 2])   # > B-1
    with pytest.raises(ValueError):
        SeapOracle(4, split_occupancy=4, seed_bounds=[5, 5])   # not strict
    with pytest.raises(ValueError):
        SeapOracle(4, split_occupancy=4, seed_bounds=[-(2 ** 31)])
    mesh = make_mesh((1,), ("data",))
    with pytest.raises(ValueError):
        DeviceSeapQueue(mesh, "data", n_buckets=2, seed_bounds=[3, 9])


# --------------------------------------------------------------------------
# Headline bugfix: overflow is no longer an assert.  A wrapped-around
# enqueue at exactly `capacity` (the `new_last - first + 1 > capacity`
# post-enqueue-peak boundary) must raise QueueOverflowError carrying the
# per-tier/bucket occupancy — scalar path, per-tier [P] vector path, and
# bucket path alike; run_waves reports the first overflowing wave index.
# --------------------------------------------------------------------------
def test_overflow_raises_structured_error_scalar_path():
    from repro.dqueue import ElasticDeviceQueue, QueueOverflowError

    q = ElasticDeviceQueue(1, cap=2, payload_width=1, ops_per_shard=4)
    n = q.n_shards * q.L
    one = np.ones((n, 1), np.int32)
    fill = np.array([True, True, False, False])
    q.step(fill, fill, one)                   # 2 live == capacity: fine
    e = np.array([True, False, False, False])
    v = np.array([True, True, False, False])  # 1 enq + 1 deq: peak = 3
    with pytest.raises(QueueOverflowError) as ei:
        q.step(e, v, one)
    ex = ei.value
    assert ex.kind == "queue" and ex.capacity == 2
    assert ex.occupancy == [2] and ex.wave is None
    assert "occupancy" in str(ex)


def test_overflow_raises_structured_error_per_tier_vector_path():
    from repro.dqueue import ElasticDevicePriorityQueue, QueueOverflowError

    q = ElasticDevicePriorityQueue(1, n_prios=3, cap=2, payload_width=1,
                                   ops_per_shard=4)
    n = q.n_shards * q.L
    one = np.ones((n, 1), np.int32)
    tier = np.full(n, 1, np.int32)
    fill = np.array([True, True, False, False])
    q.step(fill, fill, tier, one)             # tier 1 at exact capacity
    e = np.array([True, False, False, False])
    v = np.array([True, True, False, False])
    with pytest.raises(QueueOverflowError) as ei:
        q.step(e, v, tier, one)
    ex = ei.value
    assert ex.kind == "pqueue" and ex.capacity == 2
    assert len(ex.occupancy) == 3 and ex.occupancy[1] == 2, ex.occupancy


def test_overflow_raises_structured_error_bucket_path():
    from repro.dqueue import ElasticDeviceSeapQueue, QueueOverflowError

    q = ElasticDeviceSeapQueue(1, n_buckets=2, cap=2, payload_width=1,
                               ops_per_shard=4, split_occupancy=99)
    n = q.n_shards * q.L
    one = np.ones((n, 1), np.int32)
    key = np.zeros(n, np.int32)
    fill = np.array([True, True, False, False])
    q.step(fill, fill, key, one)
    e = np.array([True, False, False, False])
    v = np.array([True, True, False, False])
    with pytest.raises(QueueOverflowError) as ei:
        q.step(e, v, key, one)
    ex = ei.value
    assert ex.kind == "squeue" and len(ex.occupancy) == 2


def test_overflow_run_waves_reports_first_overflowing_wave():
    from repro.dqueue import ElasticDeviceQueue, QueueOverflowError

    q = ElasticDeviceQueue(1, cap=2, payload_width=1, ops_per_shard=4)
    K, n = 3, q.n_shards * q.L
    # wave 0 fills to capacity, wave 1 wraps around (enq+deq), wave 2 idle
    E = np.zeros((K, n), bool)
    V = np.zeros((K, n), bool)
    E[0, :2] = V[0, :2] = True
    E[1, 0] = V[1, 0] = True
    V[1, 1] = True
    with pytest.raises(QueueOverflowError) as ei:
        q.run_waves(E, V, np.ones((K, n, 1), np.int32))
    assert ei.value.wave == 1


def test_overflow_raises_in_work_queue():
    from repro.compat import make_mesh
    from repro.dqueue import DeviceQueue, QueueOverflowError, WorkQueue

    mesh = make_mesh((1,), ("data",))
    wq = WorkQueue(DeviceQueue(mesh, "data", cap=2, payload_width=4,
                               ops_per_shard=4), lease_steps=8)
    wq.step([wq.make_item([7]) for _ in range(2)], [0])   # exactly full
    with pytest.raises(QueueOverflowError) as ei:
        wq.step([wq.make_item([8])], [1])                 # wrap-around
    assert ei.value.kind == "workqueue" and "leases" in str(ei.value)


# ---------------------------------------------------------------------------
# int32-extreme coverage for the Seap split midpoint (the overflow-free
# (a & b) + ((a ^ b) >> 1) idiom that the wavecheck int32 lint certifies)
# ---------------------------------------------------------------------------
I32MIN, I32MAX = -(2 ** 31), 2 ** 31 - 1


def _scan_wave(st, is_enq, valid, keys, *, B, split_occupancy):
    """One seap_queue_scan wave against a (firsts, lasts, lo, active,
    key_lo, key_hi) directory tuple; returns (outputs, new directory)."""
    import jax.numpy as jnp

    from repro.core.scan_queue import seap_queue_scan

    out = seap_queue_scan(
        jnp.asarray(is_enq), jnp.asarray(keys, jnp.int32),
        jnp.asarray(valid), *st, n_buckets=B,
        split_occupancy=split_occupancy)
    return out[:3], tuple(out[3:9])


def _fresh_directory(B):
    import jax.numpy as jnp
    lo = np.full((B,), I32MAX, np.int32)
    lo[0] = I32MIN
    active = np.zeros((B,), bool)
    active[0] = True
    return (jnp.zeros((B,), jnp.int32), jnp.full((B,), -1, jnp.int32),
            jnp.asarray(lo), jnp.asarray(active), jnp.int32(I32MAX),
            jnp.int32(I32MIN))


def test_seap_midpoint_formula_matches_int64_floor_at_extremes():
    """(a & b) + ((a ^ b) >> 1) == floor((a + b) / 2) without ever leaving
    int32 — exhaustive over a grid of boundary-adjacent extreme pairs."""
    import jax.numpy as jnp

    edges = np.array([I32MIN, I32MIN + 1, I32MIN + 2, -3, -1, 0, 1, 3,
                      I32MAX - 2, I32MAX - 1, I32MAX], np.int64)
    rng = np.random.default_rng(7)
    rand = rng.integers(I32MIN, I32MAX, size=64, dtype=np.int64)
    vals = np.concatenate([edges, rand])
    a64, b64 = np.meshgrid(vals, vals)
    lo64 = np.minimum(a64, b64).ravel()          # scan uses lo_eff <= hi_eff
    hi64 = np.maximum(a64, b64).ravel()
    want = (lo64 + hi64) >> 1                    # exact int64 floor midpoint
    a = jnp.asarray(lo64.astype(np.int32))
    b = jnp.asarray(hi64.astype(np.int32))
    got = np.asarray((a & b) + ((a ^ b) >> 1), np.int64)
    np.testing.assert_array_equal(got, want)
    naive = np.asarray(a + b, np.int64) >> 1     # the bug the idiom avoids
    assert (naive != want).any(), "grid never overflows; test is vacuous"


@pytest.mark.parametrize("keys,expect_lo", [
    # cluster at INT32_MAX: lo_eff = key_lo-1, hi_eff = key_hi = I32MAX
    ([I32MAX, I32MAX - 1, I32MAX - 2], (I32MAX - 3 + I32MAX) >> 1),
    # cluster at INT32_MIN: lo_eff = I32MIN (saturated), hi_eff = key_hi+1
    ([I32MIN, I32MIN + 1, I32MIN + 2], (2 * I32MIN + 3) >> 1),
])
def test_seap_split_boundary_exact_at_int32_extremes(keys, expect_lo):
    """A split forced by keys hugging an int32 edge must place the new
    bucket boundary at the exact (clamped, observed-range) midpoint — a
    wrapping (lo + hi) // 2 would put it on the wrong side of zero."""
    st = _fresh_directory(4)
    (bucket, pos, matched), st2 = _scan_wave(
        st, [True] * len(keys) + [False], [True] * len(keys) + [False],
        keys + [0], B=4, split_occupancy=2)
    assert bool(np.asarray(matched)[: len(keys)].all())
    firsts, lasts, lo, active, key_lo, key_hi = st2
    active = np.asarray(active)
    lo = np.asarray(lo)
    assert active.sum() == 2, "occupancy 3 > 2 must split the root"
    new_b = int(np.flatnonzero(active)[1])
    assert int(lo[new_b]) == expect_lo
    assert int(np.asarray(key_lo)) == min(keys)
    assert int(np.asarray(key_hi)) == max(keys)


def test_seap_single_key_bucket_never_resplits():
    """All-identical keys at INT32_MAX: the first over-occupancy wave may
    split once (boundary I32MAX-1), after which the hot bucket's midpoint
    collapses onto its own lower boundary and further splits must be
    refused — saturating, not wrapping, at the int32 edge."""
    st = _fresh_directory(4)
    keys = [I32MAX] * 3
    _, st = _scan_wave(st, [True] * 3 + [False], [True] * 3 + [False],
                       keys + [0], B=4, split_occupancy=2)
    n_active_1 = int(np.asarray(st[3]).sum())
    # keep hammering the same key: occupancy keeps exceeding the threshold
    for _ in range(3):
        _, st = _scan_wave(st, [True] * 3 + [False],
                           [True] * 3 + [False], keys + [0],
                           B=4, split_occupancy=2)
        active = np.asarray(st[3])
        lo = np.asarray(st[2])
        assert int(active.sum()) == n_active_1, \
            "degenerate single-key bucket must not split again"
        assert lo[active].max() <= I32MAX and lo[active].min() == I32MIN
    # the directory still serves: drain three elements strictly matched
    (bucket, pos, matched), st = _scan_wave(
        st, [False] * 4, [True, True, True, False], [0] * 4,
        B=4, split_occupancy=2)
    assert bool(np.asarray(matched)[:3].all())


def test_seap_oracle_parity_at_int32_extremes():
    """SeapOracle and the device scan agree wave-by-wave on matched counts
    and directory size under an extreme-key schedule (both edges, splits
    and single-key hammering)."""
    from repro.core.seap import DEQ, ENQ, SeapOracle

    B, occ = 4, 2
    st = _fresh_directory(B)
    oracle = SeapOracle(B, split_occupancy=occ)
    waves = [
        [I32MAX, I32MAX - 1, I32MAX - 2],
        [I32MIN, I32MIN + 1, I32MIN + 2],
        [I32MAX] * 3,
        [I32MIN] * 3,
    ]
    total = 0
    for keys in waves:
        (bucket, pos, matched), st = _scan_wave(
            st, [True] * 3 + [False], [True] * 3 + [False], keys + [0],
            B=B, split_occupancy=occ)
        recs = oracle.wave([(ENQ, int(k), 0) for k in keys] + [None])
        dev_matched = int(np.asarray(matched).sum())
        orc_matched = sum(1 for r in recs if r.matched)
        assert dev_matched == orc_matched == 3, keys
        total += 3
        assert int(np.asarray(st[3]).sum()) == oracle.n_active, keys
    # drain everything; every dequeue must match on both sides
    drained = 0
    while drained < total:
        take = min(3, total - drained)
        valid = [True] * take + [False] * (4 - take)
        (bucket, pos, matched), st = _scan_wave(
            st, [False] * 4, valid, [0] * 4, B=B, split_occupancy=occ)
        recs = oracle.wave([(DEQ, 0, None)] * take + [None] * (4 - take))
        assert int(np.asarray(matched).sum()) == \
            sum(1 for r in recs if r.matched) == take
        drained += take
