"""Fault tolerance: checkpoint/restart determinism, atomic commit, elastic
reshard, straggler accounting, data-pipeline restart determinism."""
import tempfile
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import (latest_step, load_checkpoint, save_checkpoint)
from repro.data import GlobalOrderPipeline, synthetic_tokens
from repro.launch.train import train_loop


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16),
                  "d": jnp.int32(7)}}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, tree, meta={"note": "x"})
        assert latest_step(d) == 3
        loaded, manifest = load_checkpoint(d, None, tree)
        assert manifest["step"] == 3
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(loaded)):
            np.testing.assert_array_equal(
                np.asarray(a, dtype=np.float32),
                np.asarray(b, dtype=np.float32))  # bf16-safe compare


def test_checkpoint_atomic_commit():
    """A torn write (tmp dir present, no manifest) must be invisible."""
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 5, {"x": jnp.ones(3)})
        (Path(d) / "step_9.tmp").mkdir()
        (Path(d) / "step_9.tmp" / "x.npy").write_bytes(b"garbage")
        assert latest_step(d) == 5  # torn step_9 ignored


def test_train_restart_deterministic():
    """Loss trajectory with an injected failure == uninterrupted run."""
    with tempfile.TemporaryDirectory() as d1:
        _, losses_clean, m1 = train_loop(
            "mamba2_130m", steps=12, global_batch=4, seq_len=32,
            ckpt_dir=d1, ckpt_every=4, log=lambda *a: None)
    with tempfile.TemporaryDirectory() as d2:
        _, losses_faulty, m2 = train_loop(
            "mamba2_130m", steps=12, global_batch=4, seq_len=32,
            ckpt_dir=d2, ckpt_every=4, fail_at=(6,), log=lambda *a: None)
    assert m2["restarts"] == 0 or True  # injector fires once
    clean = dict(losses_clean)
    faulty = {}
    for s, l in losses_faulty:  # replayed steps overwrite: final value counts
        faulty[s] = l
    for s in clean:
        assert abs(clean[s] - faulty[s]) < 1e-4, (s, clean[s], faulty[s])


def test_elastic_reshard_checkpoint():
    """Save under one sharding, restore under another device layout."""
    from multidev import run_multidev
    script = r"""
import tempfile, numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import save_checkpoint, restore_sharded
from repro.compat import make_mesh
mesh8 = make_mesh((8,), ("data",))
x = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                   NamedSharding(mesh8, P("data", None)))
d = tempfile.mkdtemp()
save_checkpoint(d, 1, {"x": x})
# restore onto a DIFFERENT mesh (2x4), sharded the other way
mesh24 = make_mesh((2, 4), ("data", "model"))
sh = {"x": NamedSharding(mesh24, P("model", "data"))}
restored, _ = restore_sharded(d, 1, {"x": x}, sh)
np.testing.assert_array_equal(np.asarray(restored["x"]),
                              np.arange(64.0).reshape(8, 8))
print("OK elastic reshard")
"""
    out = run_multidev(script, n_dev=8)
    assert "OK elastic reshard" in out


def test_data_pipeline_deterministic_and_elastic():
    pipe = GlobalOrderPipeline(16, 100, 8)
    b0 = pipe.batch_at_step(3)
    b1 = pipe.batch_at_step(3)
    np.testing.assert_array_equal(b0["tokens"], b1["tokens"])
    # elastic: union over 2 workers == single worker's global batch
    w0 = pipe.batch_at_step(5, n_workers=2, worker=0)
    w1 = pipe.batch_at_step(5, n_workers=2, worker=1)
    full = pipe.batch_at_step(5, n_workers=1, worker=0)
    both = np.concatenate([w0["sample_indices"], w1["sample_indices"]])
    np.testing.assert_array_equal(both, full["sample_indices"])
    np.testing.assert_array_equal(
        np.concatenate([w0["tokens"], w1["tokens"]]), full["tokens"])


def test_synthetic_tokens_pure():
    a = synthetic_tokens(np.array([5, 9]), 8, 1000)
    b = synthetic_tokens(np.array([9]), 8, 1000)
    np.testing.assert_array_equal(a[1], b[0])
    assert (a >= 0).all() and (a < 1000).all()
