"""Docs-tree health (PR 8): doclint + the ruff-D docstring gate, locally.

Three layers:
- unit tests for doclint's GitHub-slug and markdown handling (the parts
  that silently rot: fenced blocks, duplicate headings, `*`/`_` slugs);
- the real doclint run over README.md + docs/ (dead links/anchors fail
  tier-1, not just the CI docs job) and the ARCHITECTURE.md doctest;
- a stdlib AST mirror of the ruff D1xx gate on the public API surface
  (src/repro/dqueue + src/repro/serve), so the docstring contract is
  enforced even where ruff is not installed.
"""
import ast
import doctest
from pathlib import Path

from repro.analysis.doclint import (anchors_of, check_links, collect,
                                    iter_links, run_doctests, slugify)

REPO = Path(__file__).resolve().parents[1]


# ------------------------------------------------------------- doclint ----

def test_slugify_github_rules():
    assert slugify("The wave lifecycle (Stages 1–4)") == \
        "the-wave-lifecycle-stages-14"
    assert slugify("Reading BENCH_PR*.json") == "reading-bench_prjson"
    assert slugify("`code` and [link](x.md) text") == "code-and-link-text"
    assert slugify("What's here") == "whats-here"


def test_anchors_skip_fences_and_suffix_duplicates(tmp_path):
    md = tmp_path / "t.md"
    md.write_text("# Top\n```\n# not a heading\n```\n## Dup\n## Dup\n")
    assert anchors_of(md) == {"top", "dup", "dup-1"}
    assert list(iter_links(md)) == []


def test_check_links_catches_dead_file_and_anchor(tmp_path):
    a = tmp_path / "a.md"
    b = tmp_path / "b.md"
    b.write_text("# Real heading\n")
    a.write_text("[ok](b.md#real-heading) [bad](b.md#nope) "
                 "[gone](c.md) [ext](https://example.com/x)\n")
    fails = check_links([a], tmp_path)
    assert len(fails) == 2
    assert any("dead anchor" in f for f in fails)
    assert any("dead link" in f for f in fails)


def test_doctest_extraction_runs_blocks(tmp_path):
    md = tmp_path / "d.md"
    md.write_text("```python\n>>> x = 2\n>>> x + 2\n4\n```\n"
                  "prose\n```python\n>>> x * 3\n6\n```\n")
    failed, attempted = run_doctests(md)
    assert (failed, attempted) == (0, 3)   # shared namespace across blocks
    md.write_text("```python\n>>> 1 + 1\n3\n```\n")
    failed, attempted = run_doctests(md)
    assert failed == 1


# ----------------------------------------------------- the real docs tree ----

def test_docs_tree_has_no_dead_links():
    md_files = collect([str(REPO / "README.md"), str(REPO / "docs")])
    assert len(md_files) >= 4                    # README + 3 docs
    fails = check_links(md_files, REPO)
    assert not fails, "\n".join(fails)


def test_architecture_doctest_passes():
    failed, attempted = run_doctests(REPO / "docs" / "ARCHITECTURE.md")
    assert attempted > 0, "ARCHITECTURE.md lost its doctest quickstart"
    assert failed == 0


# ------------------------------------------------- ruff D1xx gate mirror ----

def _missing_docstrings(pkg_root: Path) -> list:
    """Public names lacking docstrings, mirroring the enforced ruff rules:
    D100/D104 (module/package), D101/D106 (public class), D102/D103
    (public method/function).  Nested defs and _private names are out of
    scope, exactly as in ruff's D defaults."""
    out = []

    def scan(node, mod, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if (not child.name.startswith("_")
                        and ast.get_docstring(child) is None):
                    out.append(f"{mod}:{child.lineno} "
                               f"def {prefix}{child.name}")
                # nested defs are exempt: do not recurse into functions
            elif isinstance(child, ast.ClassDef):
                if (not child.name.startswith("_")
                        and ast.get_docstring(child) is None):
                    out.append(f"{mod}:{child.lineno} "
                               f"class {prefix}{child.name}")
                scan(child, mod, prefix + child.name + ".")

    for path in sorted(pkg_root.rglob("*.py")):
        mod = str(path.relative_to(REPO))
        tree = ast.parse(path.read_text())
        if ast.get_docstring(tree) is None:
            out.append(f"{mod}:1 module docstring")
        scan(tree, mod, "")
    return out


def test_public_api_docstrings_complete():
    """The docstring pass must not regress: every public module, class,
    method, and function in the API surface (dqueue + serve) carries a
    docstring — the same gate CI's ruff D1xx leg enforces."""
    missing = []
    for pkg in ("dqueue", "serve"):
        missing += _missing_docstrings(REPO / "src" / "repro" / pkg)
    assert not missing, "undocumented public API:\n  " + "\n  ".join(missing)


def test_doclint_module_self_documents():
    """doclint itself is runnable documentation: its CLI docstring must
    mention the exact invocation CI uses."""
    import repro.analysis.doclint as dl
    assert "python -m repro.analysis.doclint" in dl.__doc__
    assert doctest is not None  # stdlib only — no extra deps
