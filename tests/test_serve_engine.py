"""Serving engine: continuous batching through the SKUEUE request queue."""
import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.serve import Request, ServeEngine


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("mamba2_130m").reduced(n_layers=2)
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.key(0))
    mesh = make_host_mesh(n_data=1)
    return ServeEngine(model, params, mesh, max_slots=3, max_seq=24), cfg


def test_engine_serves_all_requests(engine):
    eng, cfg = engine
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=list(rng.integers(0, cfg.vocab, 3)),
                    max_new=4) for i in range(7)]
    eng.submit(reqs)
    assert eng.run_until_drained(max_steps=300)
    assert eng.stats["served"] == 7
    for r in reqs:
        assert r.done and len(r.out) == 4


def test_engine_fifo_admission(engine):
    eng, cfg = engine
    base = 100
    first = [Request(rid=base + i, prompt=[1, 2], max_new=2)
             for i in range(4)]
    second = [Request(rid=base + 10 + i, prompt=[3, 4], max_new=2)
              for i in range(4)]
    eng.submit(first)
    eng.step()
    eng.submit(second)
    assert eng.run_until_drained(max_steps=300)
    # every first-wave request starts no later than any second-wave request
    f_starts = [r.start_step for r in first]
    s_starts = [r.start_step for r in second]
    assert max(f_starts) <= min(s_starts), (f_starts, s_starts)


def test_engine_oversized_submit_chunks_across_waves(engine):
    """Regression: a submit burst larger than one queue wave (n_shards * L
    requests) used to index out of bounds; it must now be chunked across
    multiple waves and served completely, preserving FIFO admission."""
    eng, cfg = engine
    n_wave = eng.queue.n_shards * eng.queue.L
    reqs = [Request(rid=500 + i, prompt=[1, 2], max_new=2)
            for i in range(2 * n_wave + 3)]
    eng.submit(reqs)  # one oversized call
    assert eng.run_until_drained(max_steps=600)
    assert all(r.done for r in reqs)
    starts = [r.start_step for r in reqs]
    assert starts == sorted(starts), "FIFO admission across chunked waves"


def test_tier_wait_stats_reports_starved_tiers():
    """Satellite bugfix: tier_wait_stats used to silently omit tiers with
    zero admissions — hiding exactly the starvation it exists to expose.
    Every configured tier must get a row ({"n": 0, ...} when starved) plus
    a ``pending`` count of submitted-but-never-admitted requests."""
    cfg = get_config("mamba2_130m").reduced(n_layers=1)
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.key(0))
    eng = ServeEngine(model, params, make_host_mesh(n_data=1), max_slots=1,
                      max_seq=16, priorities=3)
    # flood tier 0; tier 2 requests arrive but are never admitted in the
    # few steps we run — the starved tier must still be visible
    eng.submit([Request(rid=i, prompt=[1], max_new=2) for i in range(6)],
               prio=0)
    eng.submit([Request(rid=100 + i, prompt=[1], max_new=2)
                for i in range(3)], prio=2)
    for _ in range(3):
        eng.step()
    st = eng.tier_wait_stats()
    assert set(st) == {0, 1, 2}, st              # EVERY configured tier
    assert st[0]["n"] >= 1 and "p99" in st[0]
    assert st[1] == {"n": 0, "pending": 0}, st   # idle tier: zero row
    assert st[2]["n"] == 0 and st[2]["pending"] == 3, st  # starved tier
    assert "p99" not in st[2]


def test_engine_resize_under_staged_submissions():
    """Satellite bugfix companion: resize's enqueue-only drain wave used
    to terminate in a bare ``assert not got``.  Resizing with submissions
    still staged must drain them into the migration, raise nothing, and
    serve every request afterwards in order."""
    cfg = get_config("mamba2_130m").reduced(n_layers=1)
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.key(0))
    eng = ServeEngine(model, params, make_host_mesh(n_data=1), max_slots=2,
                      max_seq=16)
    first = [Request(rid=i, prompt=[1, 2], max_new=2) for i in range(3)]
    eng.submit(first)
    eng.step()                                  # some already in flight
    staged = [Request(rid=100 + i, prompt=[3], max_new=2) for i in range(4)]
    eng.submit(staged)                          # staged but NOT stepped
    mig = eng.resize(1)                         # drain wave runs here
    assert mig["P_to"] == 1
    assert eng.run_until_drained(max_steps=300)
    assert eng.stats["served"] == 7
    starts = [r.start_step for r in staged]
    assert starts == sorted(starts)


def test_engine_deadline_edf_admission():
    """PR 5 tentpole integration: deadline=True swaps the admission fabric
    for the Seap queue with key = deadline step; tighter deadlines are
    admitted first even when staged later, and deadline_stats reports the
    miss rate."""
    from repro.dqueue import SeapQueueState

    cfg = get_config("mamba2_130m").reduced(n_layers=1)
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.key(0))
    eng = ServeEngine(model, params, make_host_mesh(n_data=1), max_slots=2,
                      max_seq=16, deadline=True)
    assert isinstance(eng.queue.state, SeapQueueState)
    loose = [Request(rid=i, prompt=[1, 2], max_new=2) for i in range(6)]
    tight = [Request(rid=100 + i, prompt=[3, 4], max_new=2)
             for i in range(3)]
    eng.submit(loose, deadline=60)    # loose deadlines staged FIRST
    eng.submit(tight, deadline=3)     # tight arrive later, same step
    assert eng.run_until_drained(max_steps=400)
    assert eng.stats["served"] == 9
    t_starts = [r.start_step for r in tight]
    l_starts = [r.start_step for r in loose]
    assert max(t_starts) <= min(l_starts), (t_starts, l_starts)
    ds = eng.deadline_stats()
    assert ds["n"] == 9 and ds["pending"] == 0
    assert 0.0 <= ds["miss_rate"] <= 1.0
    # a deadline-mode engine requires deadlines
    with pytest.raises(ValueError):
        eng.submit([Request(rid=999, prompt=[1])])
    # EDF and SLA tiers are exclusive disciplines
    with pytest.raises(ValueError):
        ServeEngine(model, params, make_host_mesh(n_data=1),
                    deadline=True, priorities=2)


def test_engine_matches_sequential_decode():
    """Engine output == single-request greedy decode (cache isolation)."""
    cfg = get_config("llama3_8b").reduced(n_layers=2)
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.key(1))
    mesh = make_host_mesh(n_data=1)
    prompt = [5, 17, 42]

    # reference: single slot, lone request
    eng1 = ServeEngine(model, params, mesh, max_slots=1, max_seq=16)
    r_ref = Request(rid=0, prompt=list(prompt), max_new=3)
    eng1.submit([r_ref])
    assert eng1.run_until_drained(max_steps=100)

    # engine with interference: same request among others, different slot mix
    eng2 = ServeEngine(model, params, mesh, max_slots=3, max_seq=16)
    others = [Request(rid=i, prompt=[9, 9], max_new=5) for i in (1, 2)]
    target = Request(rid=3, prompt=list(prompt), max_new=3)
    eng2.submit(others + [target])
    assert eng2.run_until_drained(max_steps=200)
    assert target.out == r_ref.out, (target.out, r_ref.out)
