"""Serving engine: continuous batching through the SKUEUE request queue."""
import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.serve import Request, ServeEngine


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("mamba2_130m").reduced(n_layers=2)
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.key(0))
    mesh = make_host_mesh(n_data=1)
    return ServeEngine(model, params, mesh, max_slots=3, max_seq=24), cfg


def test_engine_serves_all_requests(engine):
    eng, cfg = engine
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=list(rng.integers(0, cfg.vocab, 3)),
                    max_new=4) for i in range(7)]
    eng.submit(reqs)
    assert eng.run_until_drained(max_steps=300)
    assert eng.stats["served"] == 7
    for r in reqs:
        assert r.done and len(r.out) == 4


def test_engine_fifo_admission(engine):
    eng, cfg = engine
    base = 100
    first = [Request(rid=base + i, prompt=[1, 2], max_new=2)
             for i in range(4)]
    second = [Request(rid=base + 10 + i, prompt=[3, 4], max_new=2)
              for i in range(4)]
    eng.submit(first)
    eng.step()
    eng.submit(second)
    assert eng.run_until_drained(max_steps=300)
    # every first-wave request starts no later than any second-wave request
    f_starts = [r.start_step for r in first]
    s_starts = [r.start_step for r in second]
    assert max(f_starts) <= min(s_starts), (f_starts, s_starts)


def test_engine_oversized_submit_chunks_across_waves(engine):
    """Regression: a submit burst larger than one queue wave (n_shards * L
    requests) used to index out of bounds; it must now be chunked across
    multiple waves and served completely, preserving FIFO admission."""
    eng, cfg = engine
    n_wave = eng.queue.n_shards * eng.queue.L
    reqs = [Request(rid=500 + i, prompt=[1, 2], max_new=2)
            for i in range(2 * n_wave + 3)]
    eng.submit(reqs)  # one oversized call
    assert eng.run_until_drained(max_steps=600)
    assert all(r.done for r in reqs)
    starts = [r.start_step for r in reqs]
    assert starts == sorted(starts), "FIFO admission across chunked waves"


def test_engine_matches_sequential_decode():
    """Engine output == single-request greedy decode (cache isolation)."""
    cfg = get_config("llama3_8b").reduced(n_layers=2)
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.key(1))
    mesh = make_host_mesh(n_data=1)
    prompt = [5, 17, 42]

    # reference: single slot, lone request
    eng1 = ServeEngine(model, params, mesh, max_slots=1, max_seq=16)
    r_ref = Request(rid=0, prompt=list(prompt), max_new=3)
    eng1.submit([r_ref])
    assert eng1.run_until_drained(max_steps=100)

    # engine with interference: same request among others, different slot mix
    eng2 = ServeEngine(model, params, mesh, max_slots=3, max_seq=16)
    others = [Request(rid=i, prompt=[9, 9], max_new=5) for i in (1, 2)]
    target = Request(rid=3, prompt=list(prompt), max_new=3)
    eng2.submit(others + [target])
    assert eng2.run_until_drained(max_steps=200)
    assert target.out == r_ref.out, (target.out, r_ref.out)
