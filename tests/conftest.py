import os
import sys

# make tests/ helpers (multidev.py) importable under `PYTHONPATH=src pytest`
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

# Fail loudly on silent rank promotion everywhere in the suite.  Set via
# the environment BEFORE jax is imported so the multidev subprocess tests
# (which inherit os.environ) enforce it too.
os.environ.setdefault("JAX_NUMPY_RANK_PROMOTION", "raise")

import jax  # noqa: E402  (import after the env var is pinned)

jax.config.update("jax_numpy_rank_promotion", "raise")
