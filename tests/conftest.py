import os
import sys

# make tests/ helpers (multidev.py) importable under `PYTHONPATH=src pytest`
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
