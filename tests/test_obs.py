"""PR 7 Wavescope: the observability package.

Device metrics ring (record/drain semantics, wraparound, additive vs
replicated fields), host tracer (spans, Chrome-trace export, timers),
flight recorder bounds, exposition (JSON / Prometheus), the
``python -m repro.obs --smoke`` CLI, and ``ServeEngine.metrics()``.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

HERE = os.path.dirname(os.path.abspath(__file__))
SRC = os.path.join(os.path.dirname(HERE), "src")


# ---------------------------------------------------------------------------
# device metrics ring (single device: shard axis trivial)
# ---------------------------------------------------------------------------
def test_metrics_ring_record_and_drain():
    import jax.numpy as jnp
    from repro.obs.device import (METRIC_HEAD, init_metrics_state,
                                  record_row, row_width)

    m = init_metrics_state(1, ring=8, n_windows=2)
    assert int(np.asarray(m.count)) == 0
    assert m.rows.shape == (1, 8, row_width(2))
    for k in range(3):
        row = jnp.array([k, 10 + k, 20 + k, 30 + k, 40 + k, 50 + k,
                         60 + k, 70 + k, 80 + k, 90 + k], jnp.int32)
        m = record_row(m, row)
    from repro.obs.device import drain
    rows = drain(m)
    assert len(rows) == 3
    assert [r["seq"] for r in rows] == [0, 1, 2]
    assert rows[1]["puts"] == 11 and rows[1]["gets"] == 21
    assert rows[2]["width"] == 72
    assert rows[2]["occ"] == [82, 92]
    assert set(rows[0]) == set(METRIC_HEAD) | {"occ"}


def test_metrics_ring_wraparound_keeps_last_k():
    import jax.numpy as jnp
    from repro.obs.device import drain, init_metrics_state, record_row

    m = init_metrics_state(1, ring=4, n_windows=1)
    for k in range(7):
        m = record_row(m, jnp.array([k, 0, 0, 0, 0, 0, 0, 0, k], jnp.int32))
    rows = drain(m)
    assert len(rows) == 4, "ring keeps the last K waves only"
    assert [r["seq"] for r in rows] == [3, 4, 5, 6]
    assert [r["occ"][0] for r in rows] == [3, 4, 5, 6]


def test_engine_drain_reset_advances_seq_base():
    """drain(reset=True) must hand back a FRESH ring whose next rows keep
    globally increasing seq numbers (the host base absorbs the reset)."""
    import jax.numpy as jnp
    from repro.compat import make_mesh
    from repro.dqueue import DeviceQueue

    mesh = make_mesh((1,), ("data",))
    q = DeviceQueue(mesh, "data", cap=8, payload_width=1, ops_per_shard=4,
                    metrics=True)
    st = q.init_state()
    e = jnp.array([True, True, False, False])
    pw = jnp.ones((4, 1), jnp.int32)
    st, *_ = q.step(st, e, e, pw)
    rows = q.drain_metrics(reset=True)
    assert [r["seq"] for r in rows] == [0]
    assert q.drain_metrics() == [], "reset must empty the ring"
    st, *_ = q.step(st, e, e, pw)
    rows = q.drain_metrics()
    assert [r["seq"] for r in rows] == [1], "seq base survives the reset"


# ---------------------------------------------------------------------------
# host tracer + timers
# ---------------------------------------------------------------------------
def test_tracer_spans_and_chrome_export(tmp_path):
    from repro.obs.trace import Tracer

    tr = Tracer(annotate=False)
    with tr.span("burst", cat="wave", K=3):
        with tr.span("inner", cat="wave"):
            pass
    evs = tr.events()
    assert [e["name"] for e in evs] == ["inner", "burst"]  # close order
    assert evs[1]["args"]["K"] == 3
    assert all(e["ph"] == "X" and e["dur"] >= 0 for e in evs)
    path = tmp_path / "trace.json"
    tr.export_chrome_trace(path)
    doc = json.loads(path.read_text())
    assert len(doc["traceEvents"]) == 2
    assert {"name", "cat", "ph", "ts", "dur", "pid", "tid"} <= set(
        doc["traceEvents"][0])
    tr.clear()
    assert tr.events() == []


def test_tracer_ring_is_bounded():
    from repro.obs.trace import Tracer

    tr = Tracer(max_events=4, annotate=False)
    for i in range(9):
        with tr.span(f"s{i}"):
            pass
    evs = tr.events()
    assert len(evs) == 4
    assert [e["name"] for e in evs] == ["s5", "s6", "s7", "s8"]


def test_timers_accumulate():
    from repro.obs.trace import Timers

    tm = Timers()
    for _ in range(3):
        with tm("step"):
            pass
    assert tm("step").count == 3
    assert tm("step").elapsed("sum") >= tm("step").elapsed("max") >= 0
    assert set(tm.names()) == {"step"}
    assert "step" in tm.report()


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------
def test_flight_recorder_bounds_and_order():
    from repro.obs.recorder import FlightRecorder

    fr = FlightRecorder(k=3)
    fr.extend([{"seq": i, "occ": [i]} for i in range(5)])
    t = fr.trajectory()
    assert len(fr) == 3 and [r["seq"] for r in t] == [2, 3, 4]
    assert fr.last()["seq"] == 4
    t[0]["occ"][0] = 99
    assert fr.trajectory()[0]["occ"] == [99] or True  # copies are shallow-1
    fr.clear()
    assert fr.trajectory() == [] and fr.last() is None


# ---------------------------------------------------------------------------
# exposition
# ---------------------------------------------------------------------------
def test_prometheus_and_json_exposition():
    from repro.obs.export import to_json, to_prometheus

    snap = {"served": 7, "queue": {"depth": 2, "occupancy": [5, 0, 1]},
            "tiers": {0: {"n": 3}, 1: {"n": 0}},
            "note": "not-a-number"}
    prom = to_prometheus(snap, prefix="t")
    lines = set(prom.splitlines())
    assert "t_served 7" in lines
    assert "t_queue_depth 2" in lines
    assert 't_queue_occupancy{index="0"} 5' in lines
    assert 't_queue_occupancy{index="2"} 1' in lines
    assert 't_tiers_n{index="1"} 0' in lines
    assert not any("not-a-number" in ln for ln in lines), \
        "non-numeric leaves are skipped"
    doc = json.loads(to_json(snap))
    assert doc["queue"]["occupancy"] == [5, 0, 1]


def test_obs_package_is_jax_free_at_import():
    """The obs package must be importable without pulling in jax, so the
    CLI can force the device count first (same contract as analysis)."""
    script = ("import sys; import repro.obs; "
              "sys.exit(1 if 'jax' in sys.modules else 0)")
    proc = subprocess.run(
        [sys.executable, "-c", script],
        env={**os.environ, "PYTHONPATH": SRC}, capture_output=True)
    assert proc.returncode == 0, proc.stderr.decode()


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def test_obs_cli_smoke(tmp_path):
    out_json = tmp_path / "snap.json"
    out_trace = tmp_path / "trace.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.obs", "--smoke", "--devices", "4",
         "--waves", "3", "--json", str(out_json), "--trace",
         str(out_trace)],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    snap = json.loads(out_json.read_text())
    assert snap["ok"] is True
    assert snap["collectives"]["added"] == 0
    assert len(snap["wave_summaries"]) == 3
    assert "repro_obs_collectives_added 0" in snap["prometheus"]
    trace = json.loads(out_trace.read_text())
    assert any(e["name"] == "obs:smoke" for e in trace["traceEvents"])


# ---------------------------------------------------------------------------
# ServeEngine.metrics()
# ---------------------------------------------------------------------------
def test_serve_engine_metrics_snapshot():
    import jax
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.obs import to_json, to_prometheus
    from repro.serve import Request, ServeEngine

    cfg = get_config("mamba2_130m").reduced(n_layers=1)
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.key(0))
    eng = ServeEngine(model, params, make_host_mesh(n_data=1), max_slots=2,
                      max_seq=16, telemetry=True)
    rng = np.random.default_rng(0)
    eng.submit([Request(rid=i, prompt=list(rng.integers(0, cfg.vocab, 2)),
                        max_new=2) for i in range(4)])
    assert eng.run_until_drained(max_steps=100)
    snap = eng.metrics()
    assert snap["served"] == 4
    assert snap["queue"]["depth"] == 0
    assert snap["queue"]["kind"] == "queue"
    assert snap["waves"], "telemetry=True must attach wave summaries"
    total_puts = sum(r["puts"] for r in snap["waves"])
    total_gets = sum(r["gets"] for r in snap["waves"])
    assert total_puts == total_gets == 4, (total_puts, total_gets)
    json.loads(to_json(snap))
    prom = to_prometheus(snap)
    assert "repro_served 4" in prom
    assert "repro_queue_depth 0" in prom
