"""Batch algebra (Definition 5) and interval stages (Sections III-D/E, VI)."""
from _hyp import given, settings, strategies as st

from repro.core import batch as B
from repro.core.intervals import (AnchorState, BOTTOM, assign_queue,
                                  assign_stack, decompose_queue,
                                  positions_queue, positions_stack)


def test_append_and_totals():
    runs = B.empty()
    for is_enq in (True, True, False, True, False, False):
        B.append_op(runs, is_enq)
    assert runs == [2, 1, 1, 2]
    assert B.totals(runs) == (3, 3)


def test_combine_padding():
    assert B.combine([1, 2], [3]) == [4, 2]
    assert B.combine([0], [1, 1, 5]) == [1, 1, 5]
    assert B.combine_many([[1], [0, 2], [1, 1, 1]]) == [2, 3, 1]


@given(st.lists(st.booleans(), max_size=60))
@settings(max_examples=50, deadline=None)
def test_batch_respects_local_order(ops):
    """The run-length encoding reproduces the op sequence exactly."""
    runs = B.empty()
    for op in ops:
        B.append_op(runs, op)
    decoded = []
    for i, r in enumerate(runs):
        decoded += [i % 2 == 0] * r
    assert decoded == ops or (not ops and decoded == [])


@given(st.lists(st.booleans(), min_size=1, max_size=40), st.integers(0, 20))
@settings(max_examples=80, deadline=None)
def test_queue_assignment_matches_sequential(ops, pre):
    """Stage-2 intervals = serializing all ops one by one at the anchor."""
    runs = B.empty()
    for op in ops:
        B.append_op(runs, op)
    st_state = AnchorState(first=0, last=pre - 1)  # pre elements inside
    ivs = assign_queue(st_state, runs)
    pos = positions_queue(ivs, runs)
    # reference: per-op sequential queue semantics
    f, l = 0, pre - 1
    for op, p in zip(ops, pos):
        if op:  # enqueue
            l += 1
            assert p == l
        else:
            if f <= l:
                assert p == f
                f += 1
            else:
                assert p == BOTTOM
    assert st_state.first == f and st_state.last == l


@given(st.lists(st.lists(st.booleans(), max_size=12), min_size=1, max_size=5))
@settings(max_examples=60, deadline=None)
def test_decompose_covers_combined_exactly(parts_ops):
    """Stage 3: sub-intervals partition the combined intervals; every enqueue
    position unique; dequeues clamp exactly at interval end."""
    parts = []
    for ops in parts_ops:
        runs = B.empty()
        for op in ops:
            B.append_op(runs, op)
        parts.append(runs)
    combined = B.combine_many(parts)
    state = AnchorState(first=0, last=4)  # 5 elements in the queue
    ivs = assign_queue(state, combined)
    sub = decompose_queue(ivs, parts)
    enq_positions, deq_positions = [], []
    for part, sub_iv in zip(parts, sub):
        pos = positions_queue(sub_iv, part)
        k = 0
        for i, r in enumerate(part):
            for _ in range(r):
                (enq_positions if i % 2 == 0 else deq_positions).append(pos[k])
                k += 1
    assert len(enq_positions) == len(set(enq_positions))
    real_deq = [p for p in deq_positions if p != BOTTOM]
    assert len(real_deq) == len(set(real_deq))
    # dequeues return the oldest positions available
    n_deq_served = len(real_deq)
    if n_deq_served:
        assert min(real_deq) == 0  # queue head was 0


def test_stack_tickets_monotone():
    state = AnchorState(first=0, last=0, ticket=0)
    runs = [3, 2, 2, 4]  # 3 push, 2 pop, 2 push, 4 pop
    info = assign_stack(state, runs)
    (x0, y0), t0 = info[0]
    assert (x0, y0, t0) == (1, 3, 1)
    (x1, y1), t1 = info[1]
    assert (x1, y1, t1) == (2, 3, 3)   # pops take the top two
    (x2, y2), t2 = info[2]
    assert (x2, y2, t2) == (2, 3, 4)   # pushes reuse positions, fresh tickets
    (x3, y3), t3 = info[3]
    assert (x3, y3) == (1, 3) and t3 == 5
    assert state.last == 0 and state.ticket == 5


@given(st.lists(st.booleans(), min_size=1, max_size=30))
@settings(max_examples=80, deadline=None)
def test_stack_assignment_matches_sequential(ops):
    runs = B.empty()
    for op in ops:
        B.append_op(runs, op)
    state = AnchorState(first=0, last=0, ticket=0)
    info = assign_stack(state, runs)
    pts = positions_stack(info, runs)
    # reference stack of (pos, ticket)
    ref = []
    tick = 0
    for op, (p, t) in zip(ops, pts):
        if op:
            tick += 1
            ref.append((len(ref) + 1, tick))
            assert (p, t) == ref[-1]
        else:
            if ref:
                rp, rt = ref.pop()
                assert p == rp and t >= rt  # bound admits the element
            else:
                assert p == BOTTOM


def test_stack_batch_constant_size():
    """Theorem 20: with local combining, stack batches are (pops, pushes)."""
    # after local pairing the buffered sequence is pops... then pushes...,
    # i.e. at most 2 runs — validated end-to-end in test_core_protocol.
    runs = B.empty()
    for op in [False] * 5 + [True] * 7:
        B.append_op(runs, op)
    assert len(runs) == 3 and runs[0] == 0  # (0 push, 5 pop, 7 push)
