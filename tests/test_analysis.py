"""PR 6 wavecheck: the static invariant analyzer for the device wave path.

Acceptance: ``run_all()`` reports ZERO violations on an 8-device mesh
(every shipped wave program inside its declared collective budget, fully
donated, recompile-free once warm, int32-overflow-clean, AST-clean), and
the mutation self-test proves a broken Discipline is caught by >= 3
independent rule families.  Plus single-process unit tests for each
analyzer layer (HLO parser, AST lint, overflow taint lint, compile
tracker)."""
import json
import textwrap

from multidev import run_multidev

# ---------------------------------------------------------------------------
# acceptance: the full analyzer on the repo, 8 devices, zero violations
# ---------------------------------------------------------------------------
RUN_ALL = r"""
import json
from repro.analysis import run_all
report = run_all()
print(json.dumps(report))
"""


def test_run_all_zero_violations_8dev():
    report = json.loads(run_multidev(RUN_ALL, n_dev=8).splitlines()[-1])
    assert report["passed"], report["violations"]
    assert report["n_violations"] == 0, report["violations"]
    # every discipline x schedule is present: 4x3 wave programs + legacy
    # step + 4 migrations + 4x2 telemetry-on [obs] twins (PR 7) + 4x2
    # occupancy-bucket [compact] twins at the narrow ladder width (PR 9,
    # L=2 so the ladder is {1, 2} and w=1 is the one narrow rung) plus
    # the 2 runtime-constructed queue twins (PR 10) = 35
    assert len(report["programs"]) == 35, sorted(report["programs"])
    # the [obs] twins lower against the SAME budgets as their off twins
    obs = [n for n in report["programs"] if "[obs]" in n or ",obs]" in n]
    assert len(obs) == 8, sorted(report["programs"])
    # ... and so do the [compact] twins (PR 9): same ≤2-a2a wave contract
    compact = [n for n in report["programs"] if "compact:" in n]
    assert len(compact) == 8, sorted(report["programs"])
    # the budgets are exact on the headline invariant: 2 a2a per wave
    for name, info in report["programs"].items():
        if (name.endswith(".step") or ".step[compact" in name) \
                and "legacy" not in name:
            assert info["collectives"].get("all-to-all") == 2, (name, info)
    legacy = report["programs"]["queue-legacy.step"]
    assert legacy["collectives"].get("all-to-all") == 5, legacy
    for kind in ("queue", "stack", "priority", "seap"):
        mig = report["programs"][f"{kind}.migration"]
        assert mig["collectives"].get("all-to-all") == 1, (kind, mig)
        assert mig["aliases"] >= 2, (kind, mig)
    # the recompile guard actually warmed something, then stayed silent
    rg = report["recompile_guard"]
    assert rg["warm_compiles"] > 0 and rg["second_bounce_compiles"] == 0, rg


SELFTEST = r"""
import json
from repro.analysis.selftest import run_selftest
print(json.dumps(run_selftest()))
"""


def test_mutation_selftest_trips_at_least_three_rules_8dev():
    report = json.loads(run_multidev(SELFTEST, n_dev=8).splitlines()[-1])
    assert report["passed"], report
    assert report["n_tripped"] >= 3, report
    # the broken Discipline itself (extra collective + dropped donation)
    # must be caught — not just the idiom mutations
    assert "collective_budget" in report["tripped_rules"], report
    assert "donation" in report["tripped_rules"], report


# ---------------------------------------------------------------------------
# HLO parser units (pure string handling — no jax)
# ---------------------------------------------------------------------------
_HLO = textwrap.dedent("""\
    HloModule jit_step, is_scheduled=true, \
input_output_alias={ {0}: (0, {}, must-alias), {1}: (1, {}, may-alias) }, \
entry_computation_layout={(s32[8]{0})->s32[8]{0}}

    ENTRY %main (p0: s32[8], p1: s32[8]) -> (s32[8], s32[8]) {
      %p0 = s32[8]{0} parameter(0)
      %p1 = s32[8]{0} parameter(1)
      %a2a.1 = s32[8]{0} all-to-all(s32[8]{0} %p0), replica_groups={}
      %start = (s32[8]{0}, s32[8]{0}) all-to-all-start(s32[8]{0} %p1)
      %done = s32[8]{0} all-to-all-done((s32[8]{0}, s32[8]{0}) %start)
      %cp = s32[8]{0} collective-permute(s32[8]{0} %a2a.1)
      ROOT %t = (s32[8]{0}, s32[8]{0}) tuple(%cp, %done)
    }
""")


def test_hlo_parser_counts_and_aliases():
    from repro.analysis import collective_counts, input_output_aliases
    from repro.analysis.hlo import parse_hlo

    counts = collective_counts(_HLO)
    # async start/done pairs collapse into ONE logical collective
    assert counts["all-to-all"] == 2, counts
    assert counts["collective-permute"] == 1, counts
    aliases = input_output_aliases(_HLO)
    assert len(aliases) == 2, aliases
    assert {a.param for a in aliases} == {0, 1}
    prog = parse_hlo(_HLO)
    assert any(op.opcode == "tuple" for op in prog.ops)


# ---------------------------------------------------------------------------
# AST lint units (pure source handling — no jax)
# ---------------------------------------------------------------------------
def test_astlint_flags_device_scope_sins():
    from repro.analysis import lint_paths
    from repro.analysis.astlint import lint_source

    bad = textwrap.dedent("""
        import jax
        from jax import lax
        def body(c, x):
            k = int(x)
            assert k > 0
            jax.debug.print("occ={}", c)
            return c, x
        def run(c, xs):
            out = lax.scan(body, c, xs)
            while True:
                out[0].block_until_ready()
            return out
    """)
    checks = {v.detail["check"] for v in lint_source(bad, "bad.py")}
    assert checks == {"no-bare-assert", "no-traced-cast",
                      "no-block-in-burst",
                      "no-host-callback-in-wave"}, checks

    # int()/float() OUTSIDE device scope stays legal (host-side code)
    ok = "def host(x):\n    return int(x) + 1\n"
    assert lint_source(ok, "ok.py") == []

    # the sanctioned Wavescope drain is exempt from the callback rule
    sanctioned = textwrap.dedent("""
        def dispatch(self, carry, ops):
            def drain_metrics(m):
                return jax.device_get(m.rows)
            return drain_metrics
    """)
    assert lint_source(sanctioned, "obs.py") == []

    # ... but any other callback nested in a wave method is flagged
    smuggled = textwrap.dedent("""
        def dispatch(self, carry, ops):
            jax.debug.callback(lambda x: None, carry)
            return carry
    """)
    checks = {v.detail["check"] for v in lint_source(smuggled, "bad2.py")}
    assert checks == {"no-host-callback-in-wave"}, checks

    # and the shipped device-path modules are clean
    violations, info = lint_paths()
    assert violations == [], [str(v) for v in violations]
    assert any("wave_engine" in f for f in info["files_checked"])


# ---------------------------------------------------------------------------
# overflow taint lint units (single-device jnp)
# ---------------------------------------------------------------------------
def test_overflow_lint_clean_on_guarded_and_trips_on_naive():
    import jax
    import jax.numpy as jnp

    from repro.analysis import check_int32_overflow
    from repro.analysis.overflow import lint_jaxpr

    sc = jax.ShapeDtypeStruct((), jnp.int32)

    def guarded_mid(lo, hi):
        return (lo & hi) + ((lo ^ hi) >> 1)

    assert lint_jaxpr(guarded_mid, (sc, sc), program="mid",
                      tainted_args=(0, 1)) == []

    def naive_mid(lo, hi):
        return (lo + hi) // 2

    vs = lint_jaxpr(naive_mid, (sc, sc), program="mid",
                    tainted_args=(0, 1))
    assert vs and vs[0].rule == "int32_overflow", vs

    # INF growth is fine when the result feeds a clamp/select guard
    INF = jnp.int32(2 ** 30)

    def clamped(b):
        return jnp.minimum(b + INF, INF)

    assert lint_jaxpr(clamped, (sc,), program="clamped") == []

    # the shipped scan_queue entry points are all clean
    violations, info = check_int32_overflow()
    assert violations == [], [str(v) for v in violations]
    assert info["entries"], info


# ---------------------------------------------------------------------------
# compile tracker unit (single-device)
# ---------------------------------------------------------------------------
def test_compilation_tracker_counts_only_fresh_compiles():
    import jax
    import jax.numpy as jnp

    from repro.analysis import CompilationTracker

    @jax.jit
    def f(x):
        return x * 2 + 1

    x = jnp.arange(7)
    with CompilationTracker() as cold:
        f(x).block_until_ready()
    assert cold.count >= 1, cold.count
    with CompilationTracker() as warm:
        f(x).block_until_ready()          # cache hit: no backend compile
    assert warm.count == 0, warm.count


def test_budget_check_reports_undeclared_collectives():
    from repro.analysis import CollectiveBudget, check_budget

    text = _HLO
    ok = CollectiveBudget(exact={"all-to-all": 2},
                          max={"collective-permute": 4})
    assert check_budget("p", text, ok) == []
    tight = CollectiveBudget(exact={"all-to-all": 1}, max={})
    vs = check_budget("p", text, tight)
    assert vs, "over-budget a2a and undeclared cp must both be flagged"
    assert len(vs) >= 2, [str(v) for v in vs]
