"""PR 10: the runtime seam — mesh invariants, subset/exclusion selection,
zero-recompile membership bounces, SimRuntime wire arithmetic, the
stable-id failure rekey (no resurrection onto dead devices), LocalRuntime
parity with the pre-runtime mesh path, and the real 2-process
DistributedRuntime differential over localhost TCP."""
import numpy as np
import pytest

from multidev import run_multidev


# ---------------------------------------------------------------------------
# pure-host pieces: selection, exclusion, latency arithmetic (no devices)
# ---------------------------------------------------------------------------

class _FakeDev:
    def __init__(self, i):
        self.id = i

    def __repr__(self):
        return f"dev{self.id}"


def test_select_devices_subset_and_exclusion():
    from repro.runtime import select_devices
    devs = [_FakeDev(i) for i in range(8)]
    assert [d.id for d in select_devices(devs, 3)] == [0, 1, 2]
    # exclusion by device object and by bare id, subset from survivors
    assert [d.id for d in select_devices(devs, 3, exclude=(devs[0],))] \
        == [1, 2, 3]
    assert [d.id for d in select_devices(devs, 3, exclude=(0, 2))] \
        == [1, 3, 4]
    # full-width with an exclusion
    assert [d.id for d in select_devices(devs, 7, exclude=(5,))] \
        == [0, 1, 2, 3, 4, 6, 7]


def test_select_devices_exclusion_error_names_offender():
    from repro.runtime import select_devices
    devs = [_FakeDev(i) for i in range(4)]
    with pytest.raises(ValueError) as ei:
        select_devices(devs, 4, exclude=(2,))
    msg = str(ei.value)
    # the error must name the exclusion that broke the build, not just
    # report a count mismatch
    assert "device id(s) [2]" in msg and "3 of 4" in msg
    # unknown exclusions don't get blamed for a plain shortage
    with pytest.raises(ValueError) as ei:
        select_devices(devs, 5)
    assert "device id" not in str(ei.value)


def test_latency_model_arithmetic():
    from repro.runtime import LatencyModel
    m = LatencyModel(base_us=100.0, per_mib_us=8.0,
                     per_collective={"all_reduce": {"base_us": 40.0}})
    # base + per-MiB, in seconds
    assert m.latency_s("all_to_all", 0) == pytest.approx(100e-6)
    assert m.latency_s("all_to_all", 1 << 20) == pytest.approx(108e-6)
    # per-kind base override inherits the default per_mib_us
    assert m.latency_s("all_reduce", 1 << 19) == pytest.approx(44e-6)
    # free wire by default
    assert LatencyModel().latency_s("all_to_all", 1 << 30) == 0.0


def test_sim_burst_and_envelope_rules():
    from repro.runtime import SimRuntime
    # K-wave burst: K+1 launches pipelined, 2K sequential
    assert SimRuntime.burst_launches(4, True) == 5
    assert SimRuntime.burst_launches(4, False) == 8
    assert SimRuntime.burst_launches(1, True) == 2
    # envelope: n_shards*width op rows of (slot ‖ tag ‖ payload) int32
    assert SimRuntime.wave_envelope_bytes(8, 2, 2) == 8 * 2 * 4 * 4
    assert SimRuntime.wave_envelope_bytes(4, 16, 4) == 4 * 16 * 4 * 6


# ---------------------------------------------------------------------------
# mesh invariants + the elastic stack on a runtime (multidev subprocess)
# ---------------------------------------------------------------------------

MESH_INVARIANTS = r"""
import jax
from repro.runtime import LocalRuntime, SimRuntime, build_mesh
from repro.launch.mesh import make_elastic_mesh

rt = LocalRuntime(axis_name="data")
assert rt.pool_size == 8 and rt.n_shards == 8
assert rt.process_role == (0, 1, True)

# mesh shape/axis invariants at every subset width
for n in (1, 3, 8):
    m = rt.mesh(n_shards=n)
    assert m.shape == {"data": n}, m.shape
    assert m.axis_names == ("data",)
    assert [d.id for d in m.devices.flat] == list(range(n))
    # identical device sets -> the identical Mesh OBJECT (jit cache key)
    assert rt.mesh(n_shards=n) is m

# exclusion shifts the subset; the excluded id never appears
m = rt.mesh(n_shards=4, exclude=(1,))
assert [d.id for d in m.devices.flat] == [0, 2, 3, 4]

# make_elastic_mesh delegates to the same selection rules (satellite 1)
m2 = make_elastic_mesh(4, exclude=(1,))
assert [d.id for d in m2.devices.flat] == [0, 2, 3, 4]
try:
    make_elastic_mesh(8, exclude=(3,))
    raise SystemExit("expected ValueError")
except ValueError as e:
    assert "device id(s) [3]" in str(e), e

# reshard_devices: id -> device, order-preserving, quarantine-checked
devs = rt.reshard_devices([5, 2, 0])
assert [d.id for d in devs] == [5, 2, 0]
rt.mark_failed(5)
assert rt.pool_size == 7 and 5 in rt.failed_ids
try:
    rt.reshard_devices([5])
    raise SystemExit("expected quarantine error")
except ValueError as e:
    assert "quarantined" in str(e), e
try:
    rt.reshard_devices([99])
    raise SystemExit("expected unknown-id error")
except ValueError as e:
    assert "unknown device id 99" in str(e), e

# adopt_mesh preserves object identity through as_runtime
from repro.runtime import as_runtime
mesh = build_mesh(list(jax.devices())[:4], "data")
rt2, mesh2, ax = as_runtime(mesh, "data")
assert mesh2 is mesh and ax == "data" and rt2.kind == "local"
assert rt2.mesh(list(mesh.devices.flat)) is mesh
# explicit runtime pin keeps the caller's mesh (the elastic handoff)
rt3, mesh3, _ = as_runtime(mesh, "data", runtime=rt)
assert rt3 is rt and mesh3 is mesh

# SimRuntime is a LocalRuntime topologically
sim = SimRuntime()
assert sim.pool_size == 8 and sim.kind == "sim"
print("MESH-INVARIANTS-OK")
"""


def test_mesh_invariants_multidev():
    out = run_multidev(MESH_INVARIANTS)
    assert "MESH-INVARIANTS-OK" in out


BOUNCE = r"""
from repro.analysis.recompile import CompilationTracker, _bounce
from repro.dqueue import ElasticDeviceQueue
from repro.runtime import LocalRuntime

# the wavecheck recompile guard's bounce, but on a runtime-constructed
# queue: after the warm-up bounce, the identical membership/burst/width
# bounce must hit only cached executables
rt = LocalRuntime()
eq = ElasticDeviceQueue(4, cap=16, payload_width=2, ops_per_shard=2,
                        runtime=rt)
with CompilationTracker() as warm:
    _bounce(eq, K_a=2, K_b=3, grow_by=2)
with CompilationTracker() as second:
    _bounce(eq, K_a=2, K_b=3, grow_by=2)
assert warm.count > 0, "tracker saw no compilation at all"
assert second.count == 0, (
    f"runtime-built elastic queue recompiled {second.count}x on a "
    f"repeated membership bounce")
# the runtime's mesh cache is the elastic wrapper's mesh cache
assert eq._mesh_cache and rt._mesh_cache
for key, mesh in eq._mesh_cache.items():
    assert rt._mesh_cache[key] is mesh
print("BOUNCE-OK", warm.count)
"""


def test_zero_recompile_bounce_on_runtime():
    out = run_multidev(BOUNCE)
    assert "BOUNCE-OK" in out


SIM_CHARGING = r"""
import numpy as np
from repro.dqueue import ElasticDeviceQueue
from repro.runtime import LatencyModel, SimRuntime

lat = LatencyModel(base_us=100.0, per_mib_us=8.0)
sim = SimRuntime(latency=lat)
q = ElasticDeviceQueue(4, cap=16, payload_width=2, ops_per_shard=4,
                       runtime=sim)
n = q.n_shards * q.L

# one step = a 1-wave burst = 2 all_to_all launches
q.step(np.zeros(n, bool), np.zeros(n, bool), np.zeros((n, 2), np.int32))
env = SimRuntime.wave_envelope_bytes(q.n_shards, q.L, q.W)
assert sim.counts == {"all_to_all": 2}, sim.counts
assert sim.bytes_by_kind == {"all_to_all": 2 * env}
expect = 2 * lat.latency_s("all_to_all", env)
assert abs(sim.sim_time_s - expect) < 1e-12, (sim.sim_time_s, expect)

# a K=4 pipelined burst adds K+1 = 5 launches
K = 4
q.run_waves(np.zeros((K, n), bool), np.zeros((K, n), bool),
            np.zeros((K, n, 2), np.int32))
assert sim.counts == {"all_to_all": 7}, sim.counts
expect += 5 * lat.latency_s("all_to_all", env)
assert abs(sim.sim_time_s - expect) < 1e-12

# a migration wave: 1 a2a of bytes_moved + 2 scalar all_reduce, and the
# stats dict gains the charged sim_s
q.grow(2)
mig = q.migrations[-1]
assert "sim_s" in mig and mig["sim_s"] > 0
assert sim.counts["all_reduce"] == 2
expect_mig = (lat.latency_s("all_to_all", int(mig["bytes_moved"]))
              + 2 * lat.latency_s("all_reduce", 4))
assert abs(mig["sim_s"] - expect_mig) < 1e-12
assert sim.snapshot()["sim_time_s"] == sim.sim_time_s
print("SIM-CHARGING-OK")
"""


def test_sim_runtime_charges_the_wave_stack():
    out = run_multidev(SIM_CHARGING)
    assert "SIM-CHARGING-OK" in out


# ---------------------------------------------------------------------------
# satellite 2: LEAVE keyed by stable device id — no resurrection
# ---------------------------------------------------------------------------

NO_RESURRECTION = r"""
import numpy as np
import tempfile
from repro.dqueue import ElasticDeviceQueue
from repro.fault import (FailureInjector, elastic_queue_policy,
                         run_with_restarts)

q = ElasticDeviceQueue(4, cap=64, payload_width=2, ops_per_shard=4)
dead = q.device_ids[3]              # stable id of mesh-index-3's device
got = []

def step_fn(state, step):
    n = q.n_shards * q.L
    e = np.zeros(n, bool); v = np.zeros(n, bool)
    pw = np.zeros((n, 2), np.int32)
    e[:4] = v[:4] = True
    pw[:4, 0] = np.arange(step * 4, step * 4 + 4)
    v[4:6] = True
    _, _, dv, dok, _ = q.step(e, v, pw)
    dv, dok = np.asarray(dv), np.asarray(dok)
    got.extend(int(dv[i, 0]) for i in range(n) if dok[i])
    return {"done": np.int64(step + 1)}

# the failure is keyed by DEVICE id (satellite 2): after the LEAVE the
# regrow-JOIN must draw a replacement from the live pool, never the dead
# device — pre-PR 10 the spare list was recomputed from jax.devices() so
# the dead device was the first spare and state resurrected onto it
inj = FailureInjector(device_fail_at={2: dead})
with tempfile.TemporaryDirectory() as d:
    _, metrics = run_with_restarts(
        init_state=lambda: {"done": np.int64(0)},
        step_fn=step_fn, n_steps=8, ckpt_dir=d, ckpt_every=100,
        injector=inj, elastic=elastic_queue_policy(q, regrow_after=2),
        log=lambda *a: None)
assert metrics["leaves"] == 1 and metrics["joins"] == 1, metrics
assert metrics["restarts"] == 0 and metrics["steps_run"] == 8, metrics
assert q.n_shards == 4
assert dead not in q.device_ids, (dead, q.device_ids)
assert dead in q.runtime.failed_ids
# the dead device stays quarantined against FUTURE growth too
q.grow(2); q.shrink([4, 5])
assert dead not in q.device_ids

# FIFO stream intact across LEAVE + JOIN
while q.size > 0:
    n = q.n_shards * q.L
    _, _, dv, dok, _ = q.step(np.zeros(n, bool), np.ones(n, bool),
                              np.zeros((n, 2), np.int32))
    dv, dok = np.asarray(dv), np.asarray(dok)
    got.extend(int(dv[i, 0]) for i in range(n) if dok[i])
assert got == list(range(32)), got
print("NO-RESURRECTION-OK")
"""


def test_leave_regrow_never_resurrects_dead_device():
    out = run_multidev(NO_RESURRECTION)
    assert "NO-RESURRECTION-OK" in out


SIM_FAILURE = r"""
import numpy as np
import tempfile
from repro.dqueue import ElasticDeviceQueue
from repro.fault import elastic_queue_policy, run_with_restarts
from repro.runtime import SimRuntime

# SimRuntime doubles as the injector: its maybe_fail raises the
# device-id-keyed ShardFailure on schedule
sim = SimRuntime(fail_at={1: 2})
q = ElasticDeviceQueue(4, cap=64, payload_width=2, ops_per_shard=4,
                       runtime=sim)

def step_fn(state, step):
    n = q.n_shards * q.L
    q.step(np.zeros(n, bool), np.zeros(n, bool),
           np.zeros((n, 2), np.int32))
    return state

with tempfile.TemporaryDirectory() as d:
    _, metrics = run_with_restarts(
        init_state=lambda: {}, step_fn=step_fn, n_steps=4, ckpt_dir=d,
        ckpt_every=100, injector=sim,
        elastic=elastic_queue_policy(q), log=lambda *a: None)
assert metrics["leaves"] == 1 and metrics["restarts"] == 0, metrics
assert 2 not in q.device_ids and 2 in sim.failed_ids
print("SIM-FAILURE-OK")
"""


def test_sim_runtime_scheduled_failure_drives_leave():
    out = run_multidev(SIM_FAILURE)
    assert "SIM-FAILURE-OK" in out


# ---------------------------------------------------------------------------
# LocalRuntime parity: the runtime path is bit-identical to the mesh path
# ---------------------------------------------------------------------------

PARITY = r"""
import numpy as np
import jax
from repro.dqueue import DeviceQueue
from repro.launch.mesh import make_elastic_mesh
from repro.runtime import LocalRuntime

mesh = make_elastic_mesh(4)
rng = np.random.default_rng(7)
n = 4 * 4
args = []
for k in range(6):
    e = rng.random(n) < 0.5
    v = rng.random(n) < 0.8
    pw = rng.integers(0, 1 << 20, (n, 2)).astype(np.int32)
    args.append((e, v, pw))

def drive(q):
    st = q.init_state()
    outs = []
    for e, v, pw in args:
        st, pos, matched, dv, dok, ovf = q.step(st, e, v, pw)
        outs.append((np.asarray(pos), np.asarray(matched),
                     np.asarray(dv), np.asarray(dok)))
    return outs, jax.tree.leaves(st)

a, sa = drive(DeviceQueue(mesh, "data", cap=16, payload_width=2,
                          ops_per_shard=4))
b, sb = drive(DeviceQueue(LocalRuntime(devices=list(mesh.devices.flat)),
                          cap=16, payload_width=2, ops_per_shard=4))
for (xa, xb) in zip(a, b):
    for ya, yb in zip(xa, xb):
        assert (ya == yb).all()
for la, lb in zip(sa, sb):
    assert (np.asarray(la) == np.asarray(lb)).all()
print("PARITY-OK")
"""


def test_local_runtime_parity_with_mesh_path():
    out = run_multidev(PARITY)
    assert "PARITY-OK" in out


# ---------------------------------------------------------------------------
# DistributedRuntime: 2 real processes over localhost TCP
# ---------------------------------------------------------------------------

DIST_CHILD = r"""
import collections
import numpy as np
from repro.runtime import DistributedRuntime

rt = DistributedRuntime.from_env()
role = rt.process_role
assert role.count == 2 and rt.pool_size == 8
assert len(rt.local_devices()) == 4

# ---------------- FIFO queue differential under a grow/shrink schedule -
from repro.dqueue import ElasticDeviceQueue

q = ElasticDeviceQueue(6, cap=16, payload_width=2, ops_per_shard=4,
                       runtime=rt)
oracle = collections.deque()
got, want = [], []
rng = np.random.default_rng(42)   # same seed in BOTH processes

def wave():
    n = q.n_shards * q.L
    e = rng.random(n) < 0.6
    v = rng.random(n) < 0.9
    pw = np.zeros((n, 2), np.int32)
    pw[:, 0] = rng.integers(0, 1 << 20, n)
    _, _, dv, dok, _ = q.step(e, v, pw)
    dv = rt.to_host(dv); dok = rt.to_host(dok)
    for i in range(n):
        if e[i] and v[i]:
            oracle.append(int(pw[i, 0]))
    for i in range(n):
        if dok[i]:
            got.append(int(dv[i, 0]))
            want.append(oracle.popleft())

wave(); wave()
q.grow(2)                       # JOIN: 6 -> 8 shards, cross-process reshard
assert q.n_shards == 8
wave()
q.shrink([6, 7])                # LEAVE back to 6
assert q.n_shards == 6
wave()
# drain
while q.size > 0:
    n = q.n_shards * q.L
    _, _, dv, dok, _ = q.step(np.zeros(n, bool), np.ones(n, bool),
                              np.zeros((n, 2), np.int32))
    dv = rt.to_host(dv); dok = rt.to_host(dok)
    for i in range(n):
        if dok[i]:
            got.append(int(dv[i, 0]))
            want.append(oracle.popleft())
assert got == want and not oracle, (len(got), len(want), len(oracle))

# ---------------- LIFO stack: conservation across a membership bounce --
from repro.dqueue import ElasticDeviceStack

s = ElasticDeviceStack(6, cap=16, payload_width=2, ops_per_shard=4,
                       runtime=rt)
n = s.n_shards * s.L
pw = np.zeros((n, 2), np.int32)
pw[:, 0] = np.arange(1, n + 1)
s.step(np.ones(n, bool), np.ones(n, bool), pw)
s.grow(1); s.shrink([6])
popped = []
while s.size > 0:
    m = s.n_shards * s.L
    _, _, dv, dok, _ = s.step(np.zeros(m, bool), np.ones(m, bool),
                              np.zeros((m, 2), np.int32))
    dv = rt.to_host(dv); dok = rt.to_host(dok)
    popped.extend(int(dv[i, 0]) for i in range(m) if dok[i])
assert sorted(popped) == list(range(1, n + 1)), popped

rt.sync()
print(f"DIST-OK proc={role.index} served={len(got)} mig="
      f"{len(q.migrations)}")
"""


def test_distributed_two_process_differential():
    from repro.runtime import launch_localhost
    results = launch_localhost(code=DIST_CHILD, n_procs=2, devs_per_proc=4,
                               timeout=420.0)
    assert len(results) == 2
    for r in results:
        assert r.returncode == 0
        assert f"DIST-OK proc={r.process_id}" in r.stdout, r.stdout
    # both processes served the same (replicated) stream
    served = {r.stdout.split("served=")[1].split()[0] for r in results}
    assert len(served) == 1, served
