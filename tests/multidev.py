"""Helper: tests that need >1 device re-exec themselves in a subprocess with
``--xla_force_host_platform_device_count``.  Import and call ``run_multidev``
from a test; the module under ``main()`` runs inside the child."""
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
SRC = os.path.join(os.path.dirname(HERE), "src")


def run_multidev(script: str, n_dev: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_dev} "
                        + env.get("XLA_FLAGS", "")).strip()
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"multidev subprocess failed:\nSTDOUT:\n{proc.stdout}\n"
            f"STDERR:\n{proc.stderr}")
    return proc.stdout
