"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes, plus hypothesis property tests."""
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

import jax.numpy as jnp

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.hash_route import hash_route_pallas, hash_route_ref
from repro.kernels.segscan import queue_scan_pallas, queue_scan_ref
from repro.kernels.ssd_scan import ssd_scan_pallas, ssd_scan_ref


# ----------------------------------------------------------- segscan -------
@pytest.mark.parametrize("n", [64, 1024, 2048, 4096 + 512])
@pytest.mark.parametrize("p_enq", [0.25, 0.5, 0.9])
def test_segscan_matches_ref(n, p_enq):
    rng = np.random.default_rng(n + int(p_enq * 100))
    e = jnp.array(rng.random(n) < p_enq)
    v = jnp.array(rng.random(n) < 0.85)
    f0, l0 = jnp.int32(3), jnp.int32(7)
    pk, mk, fk, lk = queue_scan_pallas(e, v, f0, l0)
    pr, mr, fr, lr = queue_scan_ref(e, v, f0, l0)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(mk), np.asarray(mr))
    assert (int(fk), int(lk)) == (int(fr), int(lr))


@given(seed=st.integers(0, 1000), n=st.sampled_from([128, 1024, 2500]),
       pre=st.integers(0, 10))
@settings(max_examples=15, deadline=None)
def test_segscan_property(seed, n, pre):
    rng = np.random.default_rng(seed)
    e = jnp.array(rng.random(n) < rng.random())
    v = jnp.array(rng.random(n) < 0.9)
    pk, mk, fk, lk = queue_scan_pallas(e, v, jnp.int32(0), jnp.int32(pre - 1))
    pr, mr, fr, lr = queue_scan_ref(e, v, jnp.int32(0), jnp.int32(pre - 1))
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    assert (int(fk), int(lk)) == (int(fr), int(lr))
    # invariant: matched dequeue positions are unique & consumed FIFO
    deq_pos = np.asarray(pk)[np.asarray(mk) & ~np.asarray(e)]
    assert len(set(deq_pos.tolist())) == len(deq_pos)


# --------------------------------------------------------- hash_route ------
@pytest.mark.parametrize("n,shards", [(1024, 8), (1024, 256), (4096, 16),
                                      (3000, 64)])
def test_hash_route_matches_ref(n, shards):
    rng = np.random.default_rng(n + shards)
    pos = jnp.array(rng.integers(0, 1 << 30, n), jnp.int32)
    valid = jnp.array(rng.random(n) < 0.9)
    ow_k, c_k = hash_route_pallas(pos, valid, shards)
    ow_r, c_r = hash_route_ref(pos, valid, shards)
    np.testing.assert_array_equal(np.asarray(ow_k), np.asarray(ow_r))
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))


def test_hash_route_fairness():
    """Lemma 4 flavour: the hash spreads dense positions evenly."""
    pos = jnp.arange(1 << 14, dtype=jnp.int32)
    valid = jnp.ones((1 << 14,), bool)
    _, counts = hash_route_pallas(pos, valid, 64)
    c = np.asarray(counts)
    assert c.sum() == 1 << 14
    assert c.max() / c.mean() < 1.5


# ----------------------------------------------------- flash attention -----
CASES = [
    # (B, Hq, Hkv, Lq, Lk, D, causal, window, dtype, rtol)
    (2, 4, 4, 128, 128, 64, True, None, jnp.float32, 2e-5),
    (1, 8, 2, 128, 256, 64, True, None, jnp.float32, 2e-5),   # GQA + align
    (1, 4, 4, 256, 256, 128, True, 128, jnp.float32, 2e-5),   # SWA
    (2, 2, 2, 128, 128, 64, False, None, jnp.float32, 2e-5),  # encoder
    (1, 4, 4, 128, 128, 64, True, None, jnp.bfloat16, 2e-2),
    (1, 2, 2, 384, 384, 64, True, 256, jnp.float32, 2e-5),    # non-pow2 seq
]


@pytest.mark.parametrize("case", CASES)
def test_flash_attention_matches_ref(case):
    B, Hq, Hkv, Lq, Lk, D, causal, window, dtype, rtol = case
    rng = np.random.default_rng(hash(case[:8]) % (1 << 31))
    q = jnp.array(rng.standard_normal((B, Hq, Lq, D)), dtype)
    k = jnp.array(rng.standard_normal((B, Hkv, Lk, D)), dtype)
    v = jnp.array(rng.standard_normal((B, Hkv, Lk, D)), dtype)
    o_k = flash_attention(q, k, v, causal=causal, window=window)
    G = Hq // Hkv
    kr = jnp.repeat(k, G, axis=1).reshape(B * Hq, Lk, D)
    vr = jnp.repeat(v, G, axis=1).reshape(B * Hq, Lk, D)
    o_r = attention_ref(q.reshape(B * Hq, Lq, D), kr, vr, causal=causal,
                        window=window).reshape(B, Hq, Lq, D)
    err = float(jnp.max(jnp.abs(o_k.astype(jnp.float32)
                                - o_r.astype(jnp.float32))))
    assert err < rtol * 10, err


def test_flash_attention_swa_ignores_far_context():
    """Sliding window: tokens beyond the window must not affect outputs."""
    rng = np.random.default_rng(0)
    D, L, W = 64, 256, 64
    q = jnp.array(rng.standard_normal((1, 1, L, D)), jnp.float32)
    k = jnp.array(rng.standard_normal((1, 1, L, D)), jnp.float32)
    v = jnp.array(rng.standard_normal((1, 1, L, D)), jnp.float32)
    o1 = flash_attention(q, k, v, causal=True, window=W)
    # perturb keys/values far outside the last query's window
    k2 = k.at[:, :, : L - 2 * W].set(0.0)
    v2 = v.at[:, :, : L - 2 * W].set(0.0)
    o2 = flash_attention(q, k2, v2, causal=True, window=W)
    np.testing.assert_allclose(np.asarray(o1[:, :, -1]),
                               np.asarray(o2[:, :, -1]), rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------- ssd ---------
@pytest.mark.parametrize("shape", [(2, 256, 64, 64, 128), (4, 128, 64, 128, 64),
                                   (1, 512, 32, 64, 128), (2, 128, 64, 64, 32)])
def test_ssd_scan_matches_naive_recurrence(shape):
    BH, L, P, N, chunk = shape
    rng = np.random.default_rng(sum(shape))
    xt = jnp.array(rng.standard_normal((BH, L, P)), jnp.float32)
    loga = jnp.array(-np.abs(rng.standard_normal((BH, L))) * 0.1, jnp.float32)
    B = jnp.array(rng.standard_normal((BH, L, N)) * 0.3, jnp.float32)
    C = jnp.array(rng.standard_normal((BH, L, N)) * 0.3, jnp.float32)
    yk = ssd_scan_pallas(xt, loga, B, C, chunk=chunk)
    yr = ssd_scan_ref(xt, loga, B, C)
    rel = float(jnp.max(jnp.abs(yk - yr)) / (jnp.max(jnp.abs(yr)) + 1e-9))
    assert rel < 2e-5, rel


def test_ssd_chunk_size_invariance():
    """Chunking is an implementation detail: results agree across Q."""
    rng = np.random.default_rng(1)
    BH, L, P, N = 2, 256, 32, 64
    xt = jnp.array(rng.standard_normal((BH, L, P)), jnp.float32)
    loga = jnp.array(-np.abs(rng.standard_normal((BH, L))) * 0.2, jnp.float32)
    B = jnp.array(rng.standard_normal((BH, L, N)) * 0.3, jnp.float32)
    C = jnp.array(rng.standard_normal((BH, L, N)) * 0.3, jnp.float32)
    y64 = ssd_scan_pallas(xt, loga, B, C, chunk=64)
    y128 = ssd_scan_pallas(xt, loga, B, C, chunk=128)
    np.testing.assert_allclose(np.asarray(y64), np.asarray(y128),
                               rtol=1e-4, atol=1e-4)
