"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes, plus hypothesis property tests."""
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

import jax.numpy as jnp

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.hash_route import hash_route_pallas, hash_route_ref
from repro.kernels.segscan import (make_tier_scan, priority_queue_scan_pallas,
                                   queue_scan_pallas, queue_scan_ref,
                                   stack_scan_pallas,
                                   tiered_queue_scan_pallas)
from repro.kernels.ssd_scan import ssd_scan_pallas, ssd_scan_ref


# ----------------------------------------------------------- segscan -------
@pytest.mark.parametrize("n", [64, 1024, 2048, 4096 + 512])
@pytest.mark.parametrize("p_enq", [0.25, 0.5, 0.9])
def test_segscan_matches_ref(n, p_enq):
    rng = np.random.default_rng(n + int(p_enq * 100))
    e = jnp.array(rng.random(n) < p_enq)
    v = jnp.array(rng.random(n) < 0.85)
    f0, l0 = jnp.int32(3), jnp.int32(7)
    pk, mk, fk, lk = queue_scan_pallas(e, v, f0, l0)
    pr, mr, fr, lr = queue_scan_ref(e, v, f0, l0)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(mk), np.asarray(mr))
    assert (int(fk), int(lk)) == (int(fr), int(lr))


@given(seed=st.integers(0, 1000), n=st.sampled_from([128, 1024, 2500]),
       pre=st.integers(0, 10))
@settings(max_examples=15, deadline=None)
def test_segscan_property(seed, n, pre):
    rng = np.random.default_rng(seed)
    e = jnp.array(rng.random(n) < rng.random())
    v = jnp.array(rng.random(n) < 0.9)
    pk, mk, fk, lk = queue_scan_pallas(e, v, jnp.int32(0), jnp.int32(pre - 1))
    pr, mr, fr, lr = queue_scan_ref(e, v, jnp.int32(0), jnp.int32(pre - 1))
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    assert (int(fk), int(lk)) == (int(fr), int(lr))
    # invariant: matched dequeue positions are unique & consumed FIFO
    deq_pos = np.asarray(pk)[np.asarray(mk) & ~np.asarray(e)]
    assert len(set(deq_pos.tolist())) == len(deq_pos)


# ------------------------------------------- segscan PR 9 fused sweeps -----
@pytest.mark.parametrize("n", [64, 1024, 2048 + 256])
@pytest.mark.parametrize("p_push", [0.3, 0.7])
def test_stack_scan_pallas_matches_core(n, p_push):
    """Max-plus pallas sweep == core.scan_queue.stack_scan bit for bit."""
    from repro.core.scan_queue import StackState, stack_scan

    rng = np.random.default_rng(n + int(p_push * 10))
    is_push = jnp.array(rng.random(n) < p_push)
    valid = jnp.array(rng.random(n) < 0.85)
    l0, t0 = jnp.int32(5), jnp.int32(11)
    pk, tk, mk, nlk, ntk = stack_scan_pallas(is_push, valid, l0, t0)
    pr, tr, mr, ss = stack_scan(is_push, StackState(l0, t0), valid=valid)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(tk), np.asarray(tr))
    np.testing.assert_array_equal(np.asarray(mk), np.asarray(mr))
    assert (int(nlk), int(ntk)) == (int(ss.last), int(ss.ticket))


@pytest.mark.parametrize("n,n_tiers", [(300, 3), (1024, 1), (2048, 8)])
def test_tiered_scan_pallas_matches_core_hook_contract(n, n_tiers):
    """ONE grid-(tiers, tiles) pallas sweep == the per-tier masked
    min-plus loop, for the enqueue positions AND the lasts update."""
    from repro.core.scan_queue import priority_queue_scan

    rng = np.random.default_rng(n * n_tiers)
    enq = jnp.array(rng.random(n) < 0.6)
    tier = jnp.array(rng.integers(0, n_tiers, n), jnp.int32)
    firsts = jnp.array(rng.integers(0, 5, n_tiers), jnp.int32)
    lasts = firsts + jnp.array(rng.integers(-1, 4, n_tiers), jnp.int32)
    pos_k, nl_k = tiered_queue_scan_pallas(enq, tier, firsts, lasts,
                                           n_tiers=n_tiers)
    # oracle: enqueue-only priority scan (valid=enq so no dequeues move
    # firsts; tier array doubles as the priority key)
    t_r, pos_r, m_r, nf_r, nl_r, _ = priority_queue_scan(
        enq, tier, enq, firsts, lasts, n_prios=n_tiers)
    np.testing.assert_array_equal(
        np.asarray(pos_k), np.where(np.asarray(m_r), np.asarray(pos_r), -1))
    np.testing.assert_array_equal(np.asarray(nl_k), np.asarray(nl_r))
    np.testing.assert_array_equal(np.asarray(nf_r), np.asarray(firsts))


@pytest.mark.parametrize("n,n_prios", [(200, 2), (1024, 4)])
def test_priority_scan_pallas_and_tier_scan_hook(n, n_prios):
    """The fused priority entry point AND the tier_scan hook threaded
    through the core scan both reproduce the core loop exactly."""
    from repro.core.scan_queue import priority_queue_scan

    rng = np.random.default_rng(n + n_prios)
    enq = jnp.array(rng.random(n) < 0.55)
    valid = jnp.array(rng.random(n) < 0.85)
    prio = jnp.array(rng.integers(0, n_prios, n), jnp.int32)
    firsts = jnp.zeros(n_prios, jnp.int32)
    lasts = jnp.full(n_prios, -1, jnp.int32)
    ref = priority_queue_scan(enq, prio, valid, firsts, lasts,
                              n_prios=n_prios)
    fused = priority_queue_scan_pallas(enq, prio, valid, firsts, lasts,
                                       n_prios=n_prios)
    hooked = priority_queue_scan(enq, prio, valid, firsts, lasts,
                                 n_prios=n_prios,
                                 tier_scan=make_tier_scan(n_prios))
    for a, b in zip(fused, ref[:5]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(hooked, ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_seap_scan_with_tier_scan_hook_matches_core():
    """seap_queue_scan with the pallas bucket sweep == the jnp loop,
    including the directory rebalance outputs."""
    from repro.core.scan_queue import seap_queue_scan
    from repro.core.seap import INT32_MAX, INT32_MIN

    B = 4
    rng = np.random.default_rng(7)
    n = 640
    enq = jnp.array(rng.random(n) < 0.6)
    valid = jnp.array(rng.random(n) < 0.85)
    key = jnp.array(rng.integers(-100, 100, n), jnp.int32)
    firsts = jnp.zeros(B, jnp.int32)
    lasts = jnp.full(B, -1, jnp.int32)
    lo = jnp.array([INT32_MIN, INT32_MAX, INT32_MAX, INT32_MAX], jnp.int32)
    active = jnp.array([True, False, False, False])
    args = (enq, key, valid, firsts, lasts, lo, active,
            jnp.int32(INT32_MAX), jnp.int32(INT32_MIN))
    ref = seap_queue_scan(*args, n_buckets=B, split_occupancy=48)
    hooked = seap_queue_scan(*args, n_buckets=B, split_occupancy=48,
                             tier_scan=make_tier_scan(B))
    for a, b in zip(hooked, ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_default_interpret_env_override(monkeypatch):
    """REPRO_PALLAS_INTERPRET pins the backend autodetect both ways."""
    from repro.kernels import backend

    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert backend.default_interpret() is True
    assert backend.use_fused_dispatch() is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert backend.default_interpret() is False
    assert backend.use_fused_dispatch() is True
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET")
    import jax
    assert backend.default_interpret() == (jax.default_backend() == "cpu")


# --------------------------------------------------------- hash_route ------
@pytest.mark.parametrize("n,shards", [(1024, 8), (1024, 256), (4096, 16),
                                      (3000, 64)])
def test_hash_route_matches_ref(n, shards):
    rng = np.random.default_rng(n + shards)
    pos = jnp.array(rng.integers(0, 1 << 30, n), jnp.int32)
    valid = jnp.array(rng.random(n) < 0.9)
    ow_k, c_k = hash_route_pallas(pos, valid, shards)
    ow_r, c_r = hash_route_ref(pos, valid, shards)
    np.testing.assert_array_equal(np.asarray(ow_k), np.asarray(ow_r))
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))


def test_hash_route_fairness():
    """Lemma 4 flavour: the hash spreads dense positions evenly."""
    pos = jnp.arange(1 << 14, dtype=jnp.int32)
    valid = jnp.ones((1 << 14,), bool)
    _, counts = hash_route_pallas(pos, valid, 64)
    c = np.asarray(counts)
    assert c.sum() == 1 << 14
    assert c.max() / c.mean() < 1.5


# ----------------------------------------------------- flash attention -----
CASES = [
    # (B, Hq, Hkv, Lq, Lk, D, causal, window, dtype, rtol)
    (2, 4, 4, 128, 128, 64, True, None, jnp.float32, 2e-5),
    (1, 8, 2, 128, 256, 64, True, None, jnp.float32, 2e-5),   # GQA + align
    (1, 4, 4, 256, 256, 128, True, 128, jnp.float32, 2e-5),   # SWA
    (2, 2, 2, 128, 128, 64, False, None, jnp.float32, 2e-5),  # encoder
    (1, 4, 4, 128, 128, 64, True, None, jnp.bfloat16, 2e-2),
    (1, 2, 2, 384, 384, 64, True, 256, jnp.float32, 2e-5),    # non-pow2 seq
]


@pytest.mark.parametrize("case", CASES)
def test_flash_attention_matches_ref(case):
    B, Hq, Hkv, Lq, Lk, D, causal, window, dtype, rtol = case
    rng = np.random.default_rng(hash(case[:8]) % (1 << 31))
    q = jnp.array(rng.standard_normal((B, Hq, Lq, D)), dtype)
    k = jnp.array(rng.standard_normal((B, Hkv, Lk, D)), dtype)
    v = jnp.array(rng.standard_normal((B, Hkv, Lk, D)), dtype)
    o_k = flash_attention(q, k, v, causal=causal, window=window)
    G = Hq // Hkv
    kr = jnp.repeat(k, G, axis=1).reshape(B * Hq, Lk, D)
    vr = jnp.repeat(v, G, axis=1).reshape(B * Hq, Lk, D)
    o_r = attention_ref(q.reshape(B * Hq, Lq, D), kr, vr, causal=causal,
                        window=window).reshape(B, Hq, Lq, D)
    err = float(jnp.max(jnp.abs(o_k.astype(jnp.float32)
                                - o_r.astype(jnp.float32))))
    assert err < rtol * 10, err


def test_flash_attention_swa_ignores_far_context():
    """Sliding window: tokens beyond the window must not affect outputs."""
    rng = np.random.default_rng(0)
    D, L, W = 64, 256, 64
    q = jnp.array(rng.standard_normal((1, 1, L, D)), jnp.float32)
    k = jnp.array(rng.standard_normal((1, 1, L, D)), jnp.float32)
    v = jnp.array(rng.standard_normal((1, 1, L, D)), jnp.float32)
    o1 = flash_attention(q, k, v, causal=True, window=W)
    # perturb keys/values far outside the last query's window
    k2 = k.at[:, :, : L - 2 * W].set(0.0)
    v2 = v.at[:, :, : L - 2 * W].set(0.0)
    o2 = flash_attention(q, k2, v2, causal=True, window=W)
    np.testing.assert_allclose(np.asarray(o1[:, :, -1]),
                               np.asarray(o2[:, :, -1]), rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------- ssd ---------
@pytest.mark.parametrize("shape", [(2, 256, 64, 64, 128), (4, 128, 64, 128, 64),
                                   (1, 512, 32, 64, 128), (2, 128, 64, 64, 32)])
def test_ssd_scan_matches_naive_recurrence(shape):
    BH, L, P, N, chunk = shape
    rng = np.random.default_rng(sum(shape))
    xt = jnp.array(rng.standard_normal((BH, L, P)), jnp.float32)
    loga = jnp.array(-np.abs(rng.standard_normal((BH, L))) * 0.1, jnp.float32)
    B = jnp.array(rng.standard_normal((BH, L, N)) * 0.3, jnp.float32)
    C = jnp.array(rng.standard_normal((BH, L, N)) * 0.3, jnp.float32)
    yk = ssd_scan_pallas(xt, loga, B, C, chunk=chunk)
    yr = ssd_scan_ref(xt, loga, B, C)
    rel = float(jnp.max(jnp.abs(yk - yr)) / (jnp.max(jnp.abs(yr)) + 1e-9))
    assert rel < 2e-5, rel


def test_ssd_chunk_size_invariance():
    """Chunking is an implementation detail: results agree across Q."""
    rng = np.random.default_rng(1)
    BH, L, P, N = 2, 256, 32, 64
    xt = jnp.array(rng.standard_normal((BH, L, P)), jnp.float32)
    loga = jnp.array(-np.abs(rng.standard_normal((BH, L))) * 0.2, jnp.float32)
    B = jnp.array(rng.standard_normal((BH, L, N)) * 0.3, jnp.float32)
    C = jnp.array(rng.standard_normal((BH, L, N)) * 0.3, jnp.float32)
    y64 = ssd_scan_pallas(xt, loga, B, C, chunk=64)
    y128 = ssd_scan_pallas(xt, loga, B, C, chunk=128)
    np.testing.assert_allclose(np.asarray(y64), np.asarray(y128),
                               rtol=1e-4, atol=1e-4)
